"""First-class serving-datapath description: :class:`DatapathSpec`.

The paper's guarantee is about a *datapath*, not a weight tensor: AXE
certifies that a site's integer codes never overflow a multi-stage
accumulator of tile size T feeding P_I-bit inner registers that drain into
a P_O-bit outer register (Eq. 22), against a *specific* activation
quantizer. A2Q/A2Q+ (arXiv 2308.13504, 2401.10432) make the same point for
QAT: the certificate only transfers to serving when the serving datapath
matches what calibration certified.

Before this module, that description was smeared across the codebase —
``PTQConfig`` held (w_bits, act_bits, tile, p_bits) at calibration time,
``packed_linear`` re-declared ``p_inner=16`` as a loose kwarg, and the
packed artifact carried no record at all. ``DatapathSpec`` is the single
serializable record that travels from ``calibrate_and_quantize`` through
the packed artifact into the kernel dispatch:

  * produced per site by :meth:`repro.core.PTQConfig.to_datapath_spec`
    (P_O derived from the site's reduction depth K);
  * embedded in every packed leaf twice: as a **static** pytree node
    (``leaf["spec"]`` — zero array leaves, registered via
    ``jax.tree_util.register_static``, so a spec change changes the
    treedef and any jit retraces) and as a tiny array leaf
    (``leaf["spec_arr"]`` — survives array-only round trips such as
    checkpoint save/restore; :func:`repro.quant.serve_packed.
    ensure_datapath_spec` rebuilds the static node from it);
  * consumed by ``repro.models.layers.packed_linear`` /
    ``repro.kernels.w4a8_mm``: the K-tile size (``block_k``), the inner
    accumulator width and the activation quantizer all come from the spec
    instead of call-site kwargs.

Artifact schema versions (see docs/datapath.md):

  * v0 — ``{packed, scale}`` (pre decode-kernel);
  * v1 — ``+ col_sums`` (pack-time zero-point term, PR 2);
  * v2 — ``+ spec / spec_arr`` and, for calibrated artifacts,
    ``+ act_scale / act_zp`` static activation quantizers (this PR).

This module is intentionally dependency-free inside the repo (stdlib +
numpy + jax.tree_util only) so ``repro.core`` and ``repro.models`` can use
it without import cycles.
"""

from __future__ import annotations

import hashlib
import re
from dataclasses import dataclass, replace

import numpy as np
import jax

#: Current packed-artifact schema version (see module docstring).
ARTIFACT_VERSION = 2

#: Number of float64 slots in the array encoding (``to_array``). Slot 10
#: (sparsity) was appended within schema v2: ``from_array`` still accepts
#: the original 10-slot vectors (absent field == dense), so pre-sparsity
#: artifacts load unchanged.
_SPEC_ARR_LEN = 11

#: ``sparsity`` slot encoding (NaN == dense).
_SPARSITY_CODES = {"2:4": 1.0}
_SPARSITY_NAMES = {v: k for k, v in _SPARSITY_CODES.items()}


class DatapathMismatchError(ValueError):
    """A packed artifact and a requested serving datapath disagree.

    Raised *loudly* instead of silently preferring either side: running a
    certificate for one (T, P_I) datapath on another voids the overflow
    guarantee (the exact failure mode A2Q warns about)."""


@jax.tree_util.register_static
@dataclass(frozen=True)
class DatapathSpec:
    """One site's certified serving datapath.

    The defaults are the paper's LLM recipe (§4.2): W4A8,
    asymmetric-unsigned activations, T=128 tiles into a 16-bit inner
    accumulator. ``p_outer`` defaults to the 32-bit register every real
    datapath provides; calibrated specs carry the tighter Eq. 22 value.

    ``act_scale``/``act_zp`` are the *per-site record* of the calibrated
    static activation quantizer (None => dynamic per-tensor quantization at
    serving time). Inside a packed leaf the numeric values live in the
    ``act_scale``/``act_zp`` *array* leaves (stacked over repeats/experts);
    the leaf's static spec node keeps only ``static_act`` so that jit
    retrace keys do not depend on calibration numerics — see
    :meth:`leaf_spec`.
    """

    w_bits: int = 4
    act_bits: int = 8
    act_signed: bool = False
    tile: int | None = 128  # the paper's T; None = monolithic accumulation
    p_inner: int = 16  # P_I (monolithic P when tile is None)
    p_outer: int = 32  # P_O of Eq. 22
    static_act: bool = False  # artifact ships calibrated act quantizer leaves
    act_scale: float | None = None  # per-site record; None once inside a leaf
    act_zp: int = 0
    version: int = ARTIFACT_VERSION
    #: semi-structured weight sparsity pattern (None = dense, "2:4" = at most
    #: 2 nonzeros per contiguous group of 4 along K); halves the effective
    #: reduction depth entering the certificate and selects the sparse
    #: decode kernel
    sparsity: str | None = None

    def __post_init__(self) -> None:
        if self.sparsity is not None and self.sparsity not in _SPARSITY_CODES:
            raise ValueError(f"unknown sparsity pattern {self.sparsity!r}")

    # -- identity -----------------------------------------------------------
    def key(self) -> tuple:
        """The datapath identity: everything the kernel dispatch depends on.

        Calibration numerics are excluded (see class docstring) and so is
        ``p_outer``: it is *derived* per site from (P_I, K, T) via Eq. 22,
        so one requested datapath must match artifacts whose sites have
        different depths — comparing it would make every cross-site
        validation spuriously fail."""
        return (self.w_bits, self.act_bits, self.act_signed, self.tile,
                self.p_inner, self.static_act, self.sparsity)

    def spec_hash(self) -> str:
        """Short stable hash of the datapath identity + schema version."""
        payload = repr((self.key(), self.version)).encode()
        return hashlib.sha1(payload).hexdigest()[:12]

    def matches(self, other: "DatapathSpec") -> bool:
        return self.key() == other.key()

    def require_matches(self, other: "DatapathSpec", context: str = "") -> None:
        if not self.matches(other):
            where = f" ({context})" if context else ""
            raise DatapathMismatchError(
                f"datapath mismatch{where}: artifact certified for "
                f"{self.describe()} but {other.describe()} was requested. "
                f"Re-quantize for the requested datapath or drop the "
                f"override — serving a certificate on a different datapath "
                f"voids the overflow guarantee."
            )

    def describe(self) -> str:
        act = "static" if self.static_act else "dynamic"
        sign = "s" if self.act_signed else "u"
        t = self.tile if self.tile is not None else "mono"
        sp = f" sparsity={self.sparsity}" if self.sparsity is not None else ""
        return (f"W{self.w_bits}A{self.act_bits}{sign} T={t} "
                f"P_I={self.p_inner} P_O={self.p_outer} act={act} "
                f"v{self.version}{sp}")

    # -- derived forms ------------------------------------------------------
    def leaf_spec(self) -> "DatapathSpec":
        """The form embedded as a packed leaf's static node: calibration
        numerics dropped (they live in the leaf's array leaves, stacked
        over repeats/experts, where a single float could not represent
        them — and a static float would needlessly retrace on repack)."""
        return replace(self, act_scale=None, act_zp=0)

    def with_act(self, scale: float, zero_point: int) -> "DatapathSpec":
        return replace(self, static_act=True, act_scale=float(scale),
                       act_zp=int(zero_point))

    def block_k(self, default: int = 128) -> int:
        """The kernel K-tile. ``tile=None`` (monolithic) keeps the default
        hardware tile — any K-subset partial of an l1-budgeted row is
        bounded by the full-K bound, so P_I remains a valid per-tile
        certificate."""
        return self.tile if self.tile else default

    # -- serialization ------------------------------------------------------
    def to_array(self) -> np.ndarray:
        """Encode as a float64 vector (an ordinary checkpoint leaf).

        NaN encodes None for ``tile``/``act_scale``/``sparsity``.
        """
        return np.asarray(
            [
                float(self.version),
                float(self.w_bits),
                float(self.act_bits),
                1.0 if self.act_signed else 0.0,
                float(self.tile) if self.tile is not None else np.nan,
                float(self.p_inner),
                float(self.p_outer),
                1.0 if self.static_act else 0.0,
                float(self.act_scale) if self.act_scale is not None else np.nan,
                float(self.act_zp),
                _SPARSITY_CODES.get(self.sparsity, np.nan),
            ],
            np.float64,
        )

    @classmethod
    def from_array(cls, arr) -> "DatapathSpec":
        a = np.asarray(arr, np.float64).reshape(-1)
        # 10 slots = the pre-sparsity v2 encoding; loads as dense
        if a.shape[0] < _SPEC_ARR_LEN - 1:
            raise ValueError(
                f"spec array has {a.shape[0]} slots, expected "
                f"{_SPEC_ARR_LEN - 1} or {_SPEC_ARR_LEN}"
            )
        if a.shape[0] >= _SPEC_ARR_LEN and not np.isnan(a[10]):
            sparsity = _SPARSITY_NAMES.get(float(a[10]))
            if sparsity is None:
                raise ValueError(f"unknown sparsity code {a[10]!r} in spec array")
        else:
            sparsity = None
        return cls(
            version=int(a[0]),
            w_bits=int(a[1]),
            act_bits=int(a[2]),
            act_signed=bool(a[3]),
            tile=None if np.isnan(a[4]) else int(a[4]),
            p_inner=int(a[5]),
            p_outer=int(a[6]),
            static_act=bool(a[7]),
            act_scale=None if np.isnan(a[8]) else float(a[8]),
            act_zp=int(a[9]),
            sparsity=sparsity,
        )


# ---------------------------------------------------------------------------
# Attention accumulator record (quantized KV paging)
# ---------------------------------------------------------------------------
def _signed_acc_limit(p_bits: int) -> int:
    """Symmetric representation limit of a signed P-bit accumulator —
    mirrors ``repro.core.alphabet.accumulator_range`` (kept inline so this
    module stays dependency-free inside the repo)."""
    return 2 ** (p_bits - 1) - 1


def attn_accumulator_bits(depth: int, hi_a: int, hi_b: int) -> int:
    """Minimum signed accumulator width for a ``depth``-deep dot product
    whose factors are bounded by ``|a| <= hi_a`` and ``|b| <= hi_b`` —
    the Eq. 3 data-type bound specialized to the attention reductions
    (worst case: every product at ``hi_a * hi_b`` with one sign)."""
    if depth < 1:
        raise ValueError("dot-product depth must be >= 1")
    worst = depth * hi_a * hi_b
    p = 2
    while _signed_acc_limit(p) < worst:
        p += 1
    return p


@jax.tree_util.register_static
@dataclass(frozen=True)
class AttnDatapathSpec:
    """The serving datapath of quantized paged attention, as a record.

    Where :class:`DatapathSpec` certifies one *weight* site's accumulator,
    this certifies the two reductions attention itself performs over an
    int8 KV page pool (see ``repro.kernels.paged_attention``):

    * **QK^T** — an ``head_dim``-deep integer dot of per-head-quantized
      signed ``q_bits`` query codes with signed ``kv_bits`` key codes,
      held in a ``p_qk``-bit register;
    * **PV** — a per-page ``block_size``-deep integer dot of unsigned
      ``prob_bits`` softmax-probability codes with signed ``kv_bits``
      value codes, held in a ``p_pv``-bit register (pages drain into the
      float online-softmax outer accumulator — the attention analogue of
      the Eq. 22 inner/outer split, with the page as the tile).

    Because KV/query/probability codes are hard-clipped to their
    alphabets, both bounds are pure data-type bounds (Eq. 3): they hold
    for *any* input and any per-page scales. ``scale_bound`` is the
    per-head page-scale record (max admissible |k|/|v| page scale) that
    converts the integer QK^T bound back to a real-score bound; like
    ``DatapathSpec.act_scale`` it is calibration numerics, excluded from
    the datapath identity.

    Defaults are the int8-KV serving recipe: int8 KV codes, int8 query
    codes, 8-bit probability codes.
    """

    kv_bits: int = 8
    q_bits: int = 8
    prob_bits: int = 8
    head_dim: int = 128  # QK^T reduction depth
    block_size: int = 128  # PV reduction depth (the page = the tile)
    p_qk: int = 22  # = attn_accumulator_bits(128, 127, 127)
    p_pv: int = 23  # = attn_accumulator_bits(128, 255, 127)
    scale_bound: float | None = None  # per-head page-scale record (numerics)

    @property
    def kv_qmax(self) -> int:
        return 2 ** (self.kv_bits - 1) - 1

    @property
    def q_qmax(self) -> int:
        return 2 ** (self.q_bits - 1) - 1

    @property
    def prob_qmax(self) -> int:
        return 2**self.prob_bits - 1

    @classmethod
    def for_cache(cls, head_dim: int, block_size: int, *, kv_bits: int = 8,
                  q_bits: int = 8, prob_bits: int = 8) -> "AttnDatapathSpec":
        """Derive the tight accumulator record for a pool layout — the
        attention analogue of ``PTQConfig.to_datapath_spec`` (P grows with
        the reduction depth)."""
        kv_hi = 2 ** (kv_bits - 1) - 1
        return cls(
            kv_bits=kv_bits, q_bits=q_bits, prob_bits=prob_bits,
            head_dim=head_dim, block_size=block_size,
            p_qk=attn_accumulator_bits(head_dim, 2 ** (q_bits - 1) - 1, kv_hi),
            p_pv=attn_accumulator_bits(block_size, 2**prob_bits - 1, kv_hi),
        )

    # -- identity (the validate_datapath contract) --------------------------
    def key(self) -> tuple:
        """Everything the quantized attention kernel dispatch depends on;
        ``scale_bound`` (numerics) is excluded, mirroring
        :meth:`DatapathSpec.key`."""
        return (self.kv_bits, self.q_bits, self.prob_bits, self.head_dim,
                self.block_size, self.p_qk, self.p_pv)

    def spec_hash(self) -> str:
        return hashlib.sha1(repr(self.key()).encode()).hexdigest()[:12]

    def matches(self, other: "AttnDatapathSpec") -> bool:
        return self.key() == other.key()

    def require_matches(self, other: "AttnDatapathSpec",
                        context: str = "") -> None:
        if not self.matches(other):
            where = f" ({context})" if context else ""
            raise DatapathMismatchError(
                f"attention datapath mismatch{where}: cache built for "
                f"{self.describe()} but {other.describe()} was requested. "
                f"Rebuild the paged cache for the requested datapath — "
                f"serving the accumulator bound of one layout on another "
                f"voids the overflow guarantee."
            )

    def describe(self) -> str:
        return (f"KV{self.kv_bits} Q{self.q_bits} prob{self.prob_bits} "
                f"hd={self.head_dim} bs={self.block_size} "
                f"P_qk={self.p_qk} P_pv={self.p_pv}")

    # -- the certificate ----------------------------------------------------
    def qk_worst_abs(self) -> int:
        """Worst-case |QK^T| partial in integer units (every hd product at
        full magnitude, one sign)."""
        return self.head_dim * self.q_qmax * self.kv_qmax

    def pv_worst_abs(self) -> int:
        """Worst-case |PV| per-page partial in integer units."""
        return self.block_size * self.prob_qmax * self.kv_qmax

    def certify(self) -> bool:
        """True iff both registers hold their worst case — and the bound
        is *tight*: one fewer bit must overflow (asserted in
        ``tests/test_attn_overflow.py``)."""
        return (self.qk_worst_abs() <= _signed_acc_limit(self.p_qk)
                and self.pv_worst_abs() <= _signed_acc_limit(self.p_pv))


def validate_attn_datapath(spec: "AttnDatapathSpec | None",
                           expected: "AttnDatapathSpec") -> None:
    """Certify a paged cache's attention datapath against a request, the
    same contract as :func:`validate_datapath` for weight sites: absence
    of a record (a float-KV cache) is a mismatch, not a match, and any
    disagreement raises loudly instead of silently serving."""
    if spec is None:
        raise DatapathMismatchError(
            f"cache carries no attention datapath (float KV pages) but "
            f"{expected.describe()} was requested; rebuild with "
            f"kv_dtype='int8'"
        )
    spec.require_matches(expected, context="paged cache")


def is_packed_leaf(node) -> bool:
    """Structural test for a packed-artifact leaf dict."""
    return isinstance(node, dict) and "packed" in node


def leaf_datapath(leaf: dict) -> DatapathSpec | None:
    """The spec carried by a packed leaf: the static node when present,
    else decoded from the ``spec_arr`` array leaf, else None (legacy)."""
    spec = leaf.get("spec")
    if spec is not None:
        return spec
    arr = leaf.get("spec_arr")
    if arr is not None:
        flat = np.asarray(jax.device_get(arr), np.float64)
        # stacked (R, ...) / (R, E, ...) leaves broadcast the same spec;
        # reshape by the array's own trailing length, not the current
        # constant — pre-sparsity leaves carry 10-slot vectors
        width = flat.shape[-1] if flat.ndim else flat.shape[0]
        return DatapathSpec.from_array(flat.reshape(-1, width)[0])
    return None


def tree_datapath_fingerprint(tree) -> str:
    """One stable hash over every packed leaf's datapath in a params tree.

    The serving engine threads this through its jits as a *static* argument
    so that swapping artifacts with a different certified datapath retraces
    instead of silently reusing the previously compiled program (same
    contract as the packed-backend static arg).
    """
    hashes: list[str] = []

    def walk(node):
        if is_packed_leaf(node):
            spec = leaf_datapath(node)
            hashes.append(spec.spec_hash() if spec else "legacy")
            hashes.append("+static" if "act_scale" in node else "-static")
            return
        if isinstance(node, dict):
            for k in sorted(node):
                walk(node[k])
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(tree)
    return hashlib.sha1("|".join(hashes).encode()).hexdigest()[:16]


def site_key_for_path(path: str) -> str | None:
    """Canonical plan-site key for a packed-leaf walk path:
    ``"params/layers[2]/mixer/wq" -> "slot2/mixer.wq"`` — the slot-granular
    key space mixed-precision plans use (repeats of a slot share one packed
    leaf, so the leaf index IS the slot). None when the path does not sit
    under a ``layers`` tuple."""
    m = re.search(r"/layers\[(\d+)\]/(.+)$", path)
    if m is None:
        return None
    return f"slot{m.group(1)}/" + m.group(2).replace("/", ".")


def validate_datapath(tree, expected) -> int:
    """Check every packed leaf in ``tree`` against ``expected`` (datapath
    identity only). Returns the number of packed leaves checked; raises
    :class:`DatapathMismatchError` on the first disagreement. Legacy leaves
    (no spec) are a mismatch too — absence of a record is not a match.

    ``expected`` is either one :class:`DatapathSpec` (uniform artifact:
    every packed leaf must match it) or a mapping of plan-site keys
    (``"slot0/mixer.wq"``) to per-site specs — the mixed-precision case.
    The mapping must be *total*: a packed leaf the mapping does not name,
    or a mapping entry with no leaf in the tree, raises (a plan/model
    disagreement must never silently fall back; build the total map with
    :func:`repro.quant.serve_packed.plan_expected_specs`)."""
    uniform = isinstance(expected, DatapathSpec)
    checked = 0
    seen: set[str] = set()

    def walk(node, path):
        nonlocal checked
        if is_packed_leaf(node):
            spec = leaf_datapath(node)
            if spec is None:
                raise DatapathMismatchError(
                    f"packed leaf at {path} carries no DatapathSpec (legacy "
                    f"artifact) but a datapath was requested; run "
                    f"repro.quant.serve_packed.ensure_datapath_spec first"
                )
            if uniform:
                spec.require_matches(expected, context=path)
            else:
                key = site_key_for_path(path)
                if key is None or key not in expected:
                    raise DatapathMismatchError(
                        f"packed leaf at {path} (site {key}) is not named "
                        f"by the mixed-precision site map "
                        f"{sorted(expected)} — refusing to serve an "
                        f"unvalidated site")
                spec.require_matches(expected[key], context=path)
                seen.add(key)
            checked += 1
            return
        if isinstance(node, dict):
            for k in sorted(node):
                walk(node[k], f"{path}/{k}")
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, f"{path}[{i}]")

    walk(tree, "params")
    if not uniform:
        missing = set(expected) - seen
        if missing:
            raise DatapathMismatchError(
                f"mixed-precision site map names sites with no packed leaf "
                f"in the artifact: {sorted(missing)} — a missing site would "
                f"silently serve float; refusing")
    return checked


__all__ = [
    "ARTIFACT_VERSION",
    "AttnDatapathSpec",
    "DatapathMismatchError",
    "DatapathSpec",
    "attn_accumulator_bits",
    "validate_attn_datapath",
    "is_packed_leaf",
    "leaf_datapath",
    "site_key_for_path",
    "tree_datapath_fingerprint",
    "validate_datapath",
]
