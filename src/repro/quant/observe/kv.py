"""Calibrated static KV page scales + per-head KV bit assignment.

The dynamic int8 KV path (``repro.models.layers._append_kv_page_quant``)
re-derives each page's scale *on every appended token*: the scale grows
monotonically and the page's existing codes are rescaled in place — one
extra rounding per growth event, plus the rescale arithmetic on the decode
hot path. When calibration can bound each head's K/V magnitude, a **static
per-(repeat, kv-head) scale** wins: appends become a single
quantize-and-store (requantize-on-append dropped), codes are rounded
exactly once, and the scale is known at plan time, so the searched
:class:`~repro.quant.observe.records.MixedPrecisionPlan` can carry it.

Per-head **bits** ride the same mechanism: a head demoted to ``b < 8``
bits keeps the int8 container but gets its scale computed against
``2**(b-1) - 1`` — a coarser step whose codes stay within the demoted
alphabet for in-calibration inputs, while out-of-calibration drift still
hard-clips at the int8 limit, so the 8-bit
:class:`~repro.quant.spec.AttnDatapathSpec` register bound remains a sound
upper bound for the kernel (no per-head kernel specialization needed).
The demotion buys accumulator watermark, observable through
:class:`~repro.quant.observe.saturation.SaturationCounters`.
"""

from __future__ import annotations

import numpy as np


def observe_kv_ranges(params, cfg, batches, max_len: int | None = None) -> dict:
    """Per-(repeat, kv-head) K/V abs-max over calibration prefills.

    ``params`` may be float or packed serving params (``prefill`` routes
    matmuls through ``pmm`` either way). Returns ``{"slots": {slot_index:
    {"k_absmax": (R, nkv) ndarray, "v_absmax": ...}}}`` covering every
    attention slot of ``cfg.pattern``. The ranges are post-RoPE — exactly
    what the page pools store.
    """
    import jax

    from repro.models.transformer import prefill

    slots: dict[int, dict] = {
        i: {"k_absmax": None, "v_absmax": None}
        for i, spec in enumerate(cfg.pattern)
        if spec.mixer == "attn"
    }
    for batch in batches:
        S = batch["tokens"].shape[1]
        _, cache = prefill(params, batch, cfg, max_len or S)
        for i, rec in slots.items():
            for side in ("k", "v"):
                arr = np.asarray(jax.device_get(cache[i][side]), np.float32)
                # (R, B, L, nkv, hd) -> (R, nkv); zero padding cannot inflate
                amax = np.abs(arr).max(axis=(1, 2, 4))
                key = f"{side}_absmax"
                rec[key] = amax if rec[key] is None else np.maximum(rec[key], amax)
    return {"slots": slots}


def search_kv_bits(
    ranges: dict,
    *,
    kv_bits: int = 8,
    low_bits: int | None = None,
    low_frac: float = 0.25,
) -> dict:
    """Assign per-head KV bits and static scales from observed ranges.

    Every head defaults to ``kv_bits``. When ``low_bits`` is given, heads
    whose abs-max falls below ``low_frac`` of the slot's largest head are
    demoted to ``low_bits`` (small dynamic range -> coarser step costs the
    least). Returns the plan's JSON-able ``kv`` section::

        {"kv_bits_default": 8,
         "slots": {"0": {"k_scale": [[...]], "v_scale": [[...]],
                          "k_bits": [[...]], "v_bits": [[...]]}}}

    Scales are ``absmax / (2**(bits-1) - 1)`` with a 1e-8 floor (matching
    the dynamic path's floor, so empty heads stay harmless).
    """
    out: dict = {"kv_bits_default": kv_bits, "slots": {}}
    for slot, rec in ranges["slots"].items():
        sec = {}
        for side in ("k", "v"):
            amax = np.asarray(rec[f"{side}_absmax"], np.float64)
            bits = np.full(amax.shape, kv_bits, np.int64)
            if low_bits is not None:
                ref = amax.max(axis=-1, keepdims=True)
                bits = np.where(amax < low_frac * ref, low_bits, bits)
            qmax = 2.0 ** (bits - 1) - 1.0
            scale = np.maximum(amax / qmax, 1e-8)
            sec[f"{side}_scale"] = scale.tolist()
            sec[f"{side}_bits"] = bits.tolist()
        out["slots"][str(slot)] = sec
    return out


def plan_kv_scales(kv_section: dict | None):
    """Materialize a plan's ``kv`` section as per-slot device arrays:
    ``{slot_index: {"k": (R, nkv) f32, "v": (R, nkv) f32}}`` — the shape
    the paged engine threads into ``decode_step_paged(kv_scales=...)``.
    Returns None when the section is absent (dynamic KV quantization)."""
    import jax.numpy as jnp

    if not kv_section:
        return None
    return {
        int(slot): {
            "k": jnp.asarray(sec["k_scale"], jnp.float32),
            "v": jnp.asarray(sec["v_scale"], jnp.float32),
        }
        for slot, sec in kv_section["slots"].items()
    }
