"""Headroom-driven mixed-precision search + certificate-exact re-spec.

The key soundness fact (see :func:`repro.core.min_feasible_p_bits`): the
analytic certificate's worst-case partial sums are properties of the
integer codes alone. Any smaller inner register that still holds them is
certified for the *same* codes — no re-solve, no accuracy change. So the
search decomposes:

* **P_I tightening** is free in proxy loss. :func:`apply_plan` re-specs an
  already-quantized model in place (same codes, tighter registers,
  re-issued certificates), which is why the Pareto gate can demand a
  strictly tighter global accumulator budget at *bit-identical* perplexity.
* **w_bits moves** change the codes and require re-calibration
  (``calibrate_and_quantize(plan=...)``); the search emits them only when
  asked (``demote_w_bits`` / ``promote_w8``) and :func:`apply_plan`
  refuses plans whose w_bits disagree with the model it is given.

Objective: minimize proxy loss subject to ``sum_i P_I(i) * repeats(i) <=
acc_budget_bits``. With P-only moves proxy loss is constant, so the
problem reduces to feasibility + slack distribution: every site starts at
its certificate floor, and remaining budget is handed back one bit at a
time to the sites with the *least* projected headroom (the binding sites
— exactly where operating margin buys the most robustness to activation
drift, which the serving saturation counters then monitor).
"""

from __future__ import annotations

import dataclasses

from repro.core import (
    QuantizedLinear,
    accumulator_range,
    certify,
    certify_stacked,
    sweep_config,
)
from repro.quant.spec import DatapathMismatchError, DatapathSpec

from .records import MixedPrecisionPlan, ObserverReport, SiteObservation


def _projected_headroom(obs: SiteObservation, p: int) -> float:
    """Certificate headroom this site would report at inner width ``p``
    (exact: headroom is measured against log2 of the register limit)."""
    if obs.headroom_bits is None:
        return float("inf")
    import math

    _, hi_now = accumulator_range(obs.p_inner)
    _, hi_new = accumulator_range(p)
    return obs.headroom_bits - (math.log2(hi_now) - math.log2(hi_new))


def plan_accumulator_bits(plan: MixedPrecisionPlan, report: ObserverReport) -> int:
    """Global accumulator budget of ``plan`` over ``report``'s sites:
    sum of inner register widths across physical instances (sites the plan
    does not touch keep their observed width)."""
    total = 0
    for s in report:
        spec = plan.get(s.name)
        total += (spec.p_inner if spec is not None else s.p_inner) * s.n_repeats
    return total


def search_plan(
    report: ObserverReport,
    *,
    acc_budget_bits: int | None = None,
    margin_bits: int = 0,
    promote_w8: int = 0,
    sparsify: int = 0,
) -> MixedPrecisionPlan:
    """Assign per-site ``(w_bits, P_I)`` to meet a global accumulator
    budget at minimum proxy loss.

    ``acc_budget_bits``: target for ``sum P_I * repeats`` over all sites
    (None = the certificate-exact floor, i.e. maximum tightening).
    Infeasible budgets (below the floor + margin) raise ``ValueError`` —
    meeting them would require changing codes, which is a re-calibration
    decision, not a silent one.

    ``margin_bits``: whole bits of operating margin added to every site's
    floor before spending (guards against calibration-set drift; the
    saturation counters measure the realized margin in serving).

    ``promote_w8``: promote the N *most binding* sites (least headroom) to
    ``w_bits=8`` with an unconstrained 32-bit register — they leave the
    integer accumulator budget entirely (the serving engine routes w8
    leaves through the dequant path). These entries change codes, so the
    resulting plan must go through ``calibrate_and_quantize(plan=...)``.

    ``sparsify``: mark the N eligible sites with the *most* headroom (the
    sites with the most slack to absorb pruning error) for 2:4
    semi-structured sparsity — the certificate is then issued against the
    halved effective depth (docs/datapath.md), tightening their register
    floor. Eligible: certified, ``K % 4 == 0``, ``w_bits <= 4``, not
    already sparse, not w8-promoted. Like ``promote_w8`` these entries
    change codes: the plan must go through ``calibrate_and_quantize
    (plan=...)`` (mask-aware GPFQ/OPTQ), and the sites are excluded from
    this search's P_I tightening — their floors move after re-calibration.

    All selections tie-break on the site name, so equal-headroom reports
    (e.g. every site saturated, or every site all-zero) produce the same
    plan on every run regardless of dict ordering.
    """
    movable: list[SiteObservation] = []
    promoted: list[SiteObservation] = []
    candidates = sorted(
        (s for s in report if s.headroom_bits is not None),
        key=lambda s: (s.headroom_bits, s.name),
    )
    promoted = candidates[: max(promote_w8, 0)]
    promoted_names = {s.name for s in promoted}
    sparsify_eligible = sorted(
        (
            s for s in candidates
            if s.name not in promoted_names
            and s.k % 4 == 0
            and s.spec is not None
            and s.spec.w_bits <= 4
            and s.spec.sparsity is None
        ),
        key=lambda s: (-s.headroom_bits, s.name),
    )
    sparsified = sparsify_eligible[: max(sparsify, 0)]
    sparsified_names = {s.name for s in sparsified}
    movable = [
        s
        for s in report
        if s.headroom_bits is not None
        and s.name not in promoted_names
        and s.name not in sparsified_names
    ]

    floors = {s.name: min(s.p_floor + margin_bits, s.p_inner) for s in movable}
    floor_total = sum(floors[s.name] * s.n_repeats for s in movable)
    uniform_total = sum(s.p_inner * s.n_repeats for s in movable)
    budget = floor_total if acc_budget_bits is None else acc_budget_bits
    if budget < floor_total:
        raise ValueError(
            f"accumulator budget {budget} is below the certificate-exact "
            f"floor {floor_total} (+{margin_bits}b margin); tightening "
            f"further requires re-quantizing codes (try promote_w8 or a "
            f"smaller w_bits sweep)"
        )

    assigned = dict(floors)
    # hand slack back one bit at a time to the binding site
    slack = min(budget, uniform_total) - floor_total
    while slack > 0:
        grantable = [
            s for s in movable
            if assigned[s.name] < s.p_inner and s.n_repeats <= slack
        ]
        if not grantable:
            break
        worst = min(
            grantable,
            key=lambda s: (_projected_headroom(s, assigned[s.name]), s.name),
        )
        assigned[worst.name] += 1
        slack -= worst.n_repeats

    sites: dict[str, DatapathSpec] = {}
    for s in movable:
        p = assigned[s.name]
        if p == s.p_inner:
            continue  # nothing to change: omit, site keeps its spec
        sites[s.name] = dataclasses.replace(
            s.spec,
            p_inner=p,
            p_outer=_outer_bits(p, s.k, s.spec.tile, s.spec.sparsity),
        )
    for s in sparsified:
        sites[s.name] = dataclasses.replace(s.spec, sparsity="2:4")
    for s in promoted:
        sites[s.name] = dataclasses.replace(
            s.spec,
            w_bits=8,
            tile=None,
            p_inner=32,
            p_outer=32,
            static_act=False,
            act_scale=None,
            act_zp=0,
        )

    searched_total = sum(
        (sites[s.name].p_inner if s.name in sites else s.p_inner) * s.n_repeats
        for s in movable
    )
    return MixedPrecisionPlan(
        sites=sites,
        meta={
            "objective": "min proxy loss s.t. sum(P_I * repeats) <= budget",
            "acc_budget_bits": budget,
            "uniform_bits": uniform_total,
            "floor_bits": floor_total,
            "searched_bits": searched_total,
            "margin_bits": margin_bits,
            "binding_site": report.binding_site(),
            "promoted_w8": sorted(promoted_names),
            "sparsified": sorted(sparsified_names),
        },
    )


def _outer_bits(p_inner: int, k: int, tile: int | None,
                sparsity: str | None = None) -> int:
    from repro.core import effective_depth, outer_accumulator_bits

    if tile is None or effective_depth(tile, sparsity) >= effective_depth(k, sparsity):
        return p_inner
    return outer_accumulator_bits(p_inner, k, tile, sparsity=sparsity)


def apply_plan(qm, plan: MixedPrecisionPlan):
    """Certificate-exact re-spec: serve ``qm``'s existing codes under the
    plan's tighter registers.

    Returns a new :class:`~repro.quant.pipeline.QuantizedModel` sharing
    ``qm``'s arrays — same ``q_int`` codes, same scales, same activation
    quantizers, so its forward (and perplexity) is bit-identical — with
    per-site specs/configs replaced and certificates *re-issued* at the new
    width. A plan entry that the codes do not actually fit raises
    ``ValueError`` (cannot happen for plans derived from this model's own
    report); entries changing anything but ``(p_inner, p_outer)`` raise
    :class:`DatapathMismatchError` and need ``calibrate_and_quantize
    (plan=...)`` instead. Plan keys naming no site raise too.
    """
    from repro.quant.pipeline import QuantizedBlock, QuantizedModel

    period = qm.cfg.period
    consumed = set()
    new_blocks = []
    for i, b in enumerate(qm.blocks):
        nb = QuantizedBlock(spec=b.spec, norm1=b.norm1, norm2=b.norm2)
        for comp_name in ("mixer", "ffn"):
            comp = getattr(b, comp_name)
            if comp is None:
                continue
            new_linears = {}
            for name, ql in comp.linears.items():
                key = f"slot{i % period}/{comp_name}.{name}"
                spec = plan.get(key)
                if spec is not None:
                    consumed.add(key)
                    ql = _respec_linear(ql, spec, context=key)
                new_linears[name] = ql
            setattr(nb, comp_name, dataclasses.replace(comp, linears=new_linears))
        new_blocks.append(nb)

    unknown = sorted(set(plan) - consumed)
    if unknown:
        raise DatapathMismatchError(
            f"plan names unknown sites {unknown}; model enumerates "
            f"{sorted(consumed)}"
        )
    return QuantizedModel(
        cfg=qm.cfg,
        ptq=qm.ptq,
        embedding=qm.embedding,
        final_norm=qm.final_norm,
        blocks=new_blocks,
    )


def _respec_linear(ql: QuantizedLinear, spec: DatapathSpec, context: str) -> QuantizedLinear:
    old = ql.spec
    if old is not None:
        same_codes = (
            old.w_bits, old.act_bits, old.act_signed, old.tile, old.sparsity,
        ) == (
            spec.w_bits, spec.act_bits, spec.act_signed, spec.tile,
            spec.sparsity,
        )
        if not same_codes:
            raise DatapathMismatchError(
                f"plan entry for {context} changes the code alphabet "
                f"({old.describe()} -> {spec.describe()}); re-specing only "
                f"covers (P_I, P_O) — run calibrate_and_quantize(plan=...) "
                f"for w/act/tile/sparsity moves"
            )
    cfg = sweep_config(ql.cfg, p_bits=spec.p_inner, constrain=spec.p_inner < 32)
    do_cert = certify_stacked if ql.stacked else certify
    cert = do_cert(
        ql.q_int, cfg.act_alphabet, spec.p_inner, spec.tile,
        sparsity=spec.sparsity,
    )
    if not bool(cert):
        raise ValueError(
            f"plan entry for {context} requests P_I={spec.p_inner} but the "
            f"site's codes do not fit (certificate failed); the plan was "
            f"not derived from this model's observations"
        )
    new_spec = dataclasses.replace(
        old if old is not None else spec,
        p_inner=spec.p_inner,
        p_outer=spec.p_outer,
    )
    return dataclasses.replace(ql, cert=cert, cfg=cfg, spec=new_spec)
