"""repro.quant.observe — observers + headroom-driven mixed-precision search.

The closed loop this package implements (ROADMAP item 3):

  calibrate (uniform)  ->  observe            ->  search          ->  re-spec / re-calibrate
  per-site certs           per-site act ranges    per-site (w, P_I)   tightened artifact,
  (headroom_bits)          + cert headroom        + per-head KV bits   same Eq. 3 guarantee

* :mod:`records`    — :class:`SiteObservation` / :class:`ObserverReport`
  (calibration-time observer layer, fed by ``LayerStats``'s ``ActObserver``
  through the pipeline taps) and the :class:`MixedPrecisionPlan` schema.
* :mod:`search`     — :func:`search_plan` (headroom -> per-site ``(w_bits,
  P_I)`` under a global accumulator budget) and :func:`apply_plan`
  (certificate-exact re-spec of an already-quantized model: same integer
  codes, tighter registers, re-issued certificates — zero accuracy change).
* :mod:`saturation` — :class:`SaturationCounters`, the serving-side
  off-hot-path observer (static-quantizer clip counts, per-site /
  per-KV-head accumulator watermarks); see
  ``repro.models.layers.attach_observer``.
* :mod:`kv`         — :func:`observe_kv_ranges` (calibrated static KV page
  scales, dropping requantize-on-append) and per-head KV bit assignment.
"""

from .records import (
    MixedPrecisionPlan,
    ObserverReport,
    SiteObservation,
    collect_observations,
)
from .search import apply_plan, plan_accumulator_bits, search_plan
from .saturation import SaturationCounters
from .kv import observe_kv_ranges, plan_kv_scales, search_kv_bits

__all__ = [
    "MixedPrecisionPlan",
    "ObserverReport",
    "SiteObservation",
    "SaturationCounters",
    "apply_plan",
    "collect_observations",
    "observe_kv_ranges",
    "plan_accumulator_bits",
    "plan_kv_scales",
    "search_kv_bits",
    "search_plan",
]
