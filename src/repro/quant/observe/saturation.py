"""Serving-side saturation counters: the off-hot-path observer.

:class:`SaturationCounters` accumulates, host-side, what the decode path
reports through ``jax.debug.callback`` when an observer is attached to
``repro.models.layers`` (see ``attach_observer`` / ``site_scope`` there):

* **static-quantizer clip counts** — how many activation values the
  calibrated static quantizer clipped (the realized version of the
  ``min_seen``/``max_seen`` vs ``lo``/``hi`` gap the calibration observer
  predicted);
* **activation-code extrema** — the sub-alphabet actually exercised, per
  site.

Everything heavier is computed *at report time* on the host, never in the
serving graph:

* per-site **accumulator watermarks**: the observed code extrema joined
  with the packed leaf's integer weights (unpacked once, host-side) give
  the exact worst partial sum *restricted to the observed code range* —
  an empirical watermark bounded above by the analytic certificate;
* per-KV-head **attention watermarks**: page-pool code extrema against the
  :class:`~repro.quant.spec.AttnDatapathSpec` register bounds.

The counters are pure Python state: when no observer is attached (the
default) the serving jaxpr contains no callback, no counter, no extra op —
asserted structurally by ``PagedEngine.assert_observation_transparent``.
"""

from __future__ import annotations

import math
import threading
from dataclasses import dataclass, field

import numpy as np


@dataclass
class _SiteCounter:
    n_calls: int = 0
    clip_count: int = 0
    clip_total: int = 0
    code_min: float = math.inf
    code_max: float = -math.inf


@dataclass
class SaturationCounters:
    """Host-side accumulation of per-site serving observations."""

    sites: dict[str, _SiteCounter] = field(default_factory=dict)

    def __post_init__(self):
        self._lock = threading.Lock()

    # -- recording (jax.debug.callback target) ------------------------------
    def record(self, label: str, n_total: int, n_clip, code_min, code_max) -> None:
        """Fold one decode step's observation for ``label``. ``label`` and
        ``n_total`` arrive bound via ``functools.partial`` (static);
        the rest are device scalars delivered by ``jax.debug.callback``."""
        with self._lock:
            c = self.sites.setdefault(label, _SiteCounter())
            c.n_calls += 1
            c.clip_count += int(n_clip)
            c.clip_total += int(n_total)
            c.code_min = min(c.code_min, float(code_min))
            c.code_max = max(c.code_max, float(code_max))

    def reset(self) -> None:
        with self._lock:
            self.sites.clear()

    # -- reporting -----------------------------------------------------------
    def report(self, params=None, pools=None, attn_spec=None) -> dict:
        """ServeMetrics-style summary dict.

        ``params``: optional serving params tree — enables per-site
        accumulator watermarks (unpacks each observed site's integer codes
        host-side, once). ``pools`` + ``attn_spec``: optional paged-cache
        pool list and :class:`AttnDatapathSpec` — enables per-KV-head
        attention watermarks. All optional inputs only add sections; the
        counter core never touches device state.
        """
        out: dict = {"sites": {}}
        with self._lock:
            items = [(k, _SiteCounter(**vars(v))) for k, v in self.sites.items()]
        for label, c in sorted(items):
            sec = {
                "n_calls": c.n_calls,
                "clip_count": c.clip_count,
                "clip_total": c.clip_total,
                "clip_frac": c.clip_count / c.clip_total if c.clip_total else 0.0,
                "code_min": c.code_min if c.n_calls else None,
                "code_max": c.code_max if c.n_calls else None,
            }
            if params is not None and c.n_calls:
                leaf = _find_site_leaf(params, label)
                if leaf is not None:
                    sec.update(_leaf_watermark(leaf, c.code_min, c.code_max))
            out["sites"][label] = sec
        if pools is not None and attn_spec is not None:
            out["kv_heads"] = _kv_watermarks(pools, attn_spec)
        return out


# ---------------------------------------------------------------------------
# Report-time analysis (host only)
# ---------------------------------------------------------------------------
def _find_site_leaf(params, label: str):
    """Resolve "slot0/mixer.wq" against a serving params tree."""
    try:
        slot_part, site = label.split("/", 1)
        kind, name = site.split(".", 1)
        slot = int(slot_part.removeprefix("slot"))
        comp = params["layers"][slot][kind]
    except (KeyError, ValueError, IndexError, TypeError):
        return None
    return _find_named_packed(comp, name)


def _find_named_packed(node, name: str):
    if isinstance(node, dict):
        v = node.get(name)
        if isinstance(v, dict) and "packed" in v:
            return v
        for child in node.values():
            if isinstance(child, dict) and "packed" not in child:
                found = _find_named_packed(child, name)
                if found is not None:
                    return found
    return None


def _leaf_watermark(leaf, code_min: float, code_max: float) -> dict:
    """Exact worst-case accumulator use of a leaf's codes *restricted to
    the observed activation-code range* — the per-site watermark. Bounded
    above by the analytic certificate (which assumes the full alphabet)."""
    import jax

    from repro.core.alphabet import accumulator_range
    from repro.kernels.w4a8_mm import unpack_int4
    from repro.quant.spec import leaf_datapath

    spec = leaf_datapath(leaf)
    if spec is None:
        return {}
    w = np.asarray(jax.device_get(unpack_int4(leaf["packed"])), np.float64)
    k = w.shape[-2]
    w = w.reshape(-1, k, w.shape[-1])  # fold repeat/expert stacking
    t = spec.tile if spec.tile else k
    pad = (-k) % t
    if pad:
        w = np.pad(w, [(0, 0), (0, pad), (0, 0)])
    n_tiles = (k + pad) // t
    # (R, C, n_tiles, T)
    q_ct = w.transpose(0, 2, 1).reshape(w.shape[0], w.shape[2], n_tiles, t)
    pos = np.clip(q_ct, 0, None).sum(-1)
    neg = np.clip(q_ct, None, 0).sum(-1)
    emp_hi = float((code_max * pos + code_min * neg).max())
    emp_lo = float((code_min * pos + code_max * neg).min())
    peak = max(emp_hi, -emp_lo, 1.0)
    _, hi_lim = accumulator_range(spec.p_inner)
    return {
        "watermark_hi": emp_hi,
        "watermark_lo": emp_lo,
        "watermark_bits": math.log2(peak) + 1.0,  # + sign bit
        "p_inner": spec.p_inner,
        "headroom_bits_observed": math.log2(hi_lim) - math.log2(peak),
    }


def _bits_needed(peak: float) -> float:
    return math.log2(max(peak, 1.0)) + 1.0


def _kv_watermarks(pools, attn_spec) -> dict:
    """Per-KV-head attention accumulator watermarks from pool codes."""
    import jax

    out: dict = {}
    for slot, pool in enumerate(pools):
        if not isinstance(pool, dict) or "k_scales" not in pool:
            continue
        k = np.asarray(jax.device_get(pool["k_pages"]), np.float64)
        v = np.asarray(jax.device_get(pool["v_pages"]), np.float64)
        # (..., nb, bs, nkv, hd) -> per-head max |code| (keep the nkv axis)
        k_max = np.abs(k).max(axis=(-1, -3, -4)).reshape(-1, k.shape[-2]).max(0)
        v_max = np.abs(v).max(axis=(-1, -3, -4)).reshape(-1, v.shape[-2]).max(0)
        heads = {}
        for h in range(k_max.shape[0]):
            qk_peak = attn_spec.head_dim * attn_spec.q_qmax * float(k_max[h])
            pv_peak = attn_spec.block_size * attn_spec.prob_qmax * float(v_max[h])
            heads[f"head{h}"] = {
                "k_code_max": float(k_max[h]),
                "v_code_max": float(v_max[h]),
                "qk_watermark_bits": _bits_needed(qk_peak),
                "pv_watermark_bits": _bits_needed(pv_peak),
                "p_qk": attn_spec.p_qk,
                "p_pv": attn_spec.p_pv,
            }
        out[f"slot{slot}"] = heads
    return out
