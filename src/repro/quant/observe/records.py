"""Observer records: what calibration saw, per site — and the plan schema.

A :class:`SiteObservation` aggregates one *slot-granular* site (``layer %
period`` — repeats of a slot share one packed leaf in the serving artifact,
so any per-site decision must be shared across repeats; see
``repro.quant.serve_packed._site_rec_leaf``). It joins three sources:

* the site's **overflow certificate** (``repro.core.overflow``): exact
  worst-case partial sums of the integer codes, its ``headroom_bits``
  margin, and the certificate-exact register floor
  (:func:`repro.core.min_feasible_p_bits`);
* the site's **activation observer** (``repro.core.calibration
  .ActObserver.snapshot``): percentile-calibrated quantizer range vs the
  true extremes — the expected static-quantizer clip mass that the serving
  :class:`~repro.quant.observe.saturation.SaturationCounters` then
  measures for real;
* the site's **footprint** (weight count, repeats) for HBM/accumulator
  budget accounting.

:class:`MixedPrecisionPlan` is the search output: per-site
:class:`~repro.quant.spec.DatapathSpec` overrides (the same object the v2
artifact embeds per packed leaf) plus an optional KV section (static page
scales + per-head bits). It duck-types as the mapping
``calibrate_and_quantize(plan=...)`` consumes and round-trips through JSON.
"""

from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass, field
from typing import Iterator

import numpy as np

from repro.core import min_feasible_p_bits
from repro.quant.spec import DatapathMismatchError, DatapathSpec

#: Plan file schema version (bumped independently of ARTIFACT_VERSION —
#: plans are a calibration-side artifact, not a serving-side one).
PLAN_VERSION = 1


@dataclass(frozen=True)
class SiteObservation:
    """Everything the search needs to know about one slot-granular site."""

    name: str  # "slot0/mixer.wq"
    k: int  # reduction depth
    n_repeats: int  # physical instances sharing this slot's datapath
    spec: DatapathSpec  # the datapath calibration certified
    headroom_bits: float | None  # min over repeats; None = no certificate
    p_floor: int  # certificate-exact minimum P_I (max over repeats)
    n_weights: int  # integer codes per instance (HBM accounting)
    act: dict  # merged ActObserver.snapshot() over repeats

    @property
    def p_inner(self) -> int:
        return self.spec.p_inner

    @property
    def slack_bits(self) -> int:
        """Whole register bits the certificate leaves unused."""
        return max(self.p_inner - self.p_floor, 0)


@dataclass
class ObserverReport:
    """Per-site observations for a whole quantized model."""

    sites: dict[str, SiteObservation]

    def __iter__(self) -> Iterator[SiteObservation]:
        return iter(self.sites.values())

    def accumulator_bits(self) -> int:
        """Global accumulator budget actually configured: sum of inner
        register widths over every physical site instance."""
        return sum(s.p_inner * s.n_repeats for s in self)

    def floor_accumulator_bits(self) -> int:
        """The certificate-exact lower bound on the same sum."""
        return sum(s.p_floor * s.n_repeats for s in self)

    def binding_site(self) -> str | None:
        """The site whose certificate headroom binds (arg-min)."""
        worst, name = None, None
        for s in self:
            if s.headroom_bits is not None and (worst is None or s.headroom_bits < worst):
                worst, name = s.headroom_bits, s.name
        return name

    def summary(self) -> dict:
        return {
            "n_sites": len(self.sites),
            "accumulator_bits": self.accumulator_bits(),
            "floor_accumulator_bits": self.floor_accumulator_bits(),
            "binding_site": self.binding_site(),
        }


def _merge_snapshots(snaps: list[dict]) -> dict:
    """Union of per-repeat activation ranges (the slot's quantizer must
    cover every repeat it serves)."""
    if not snaps:
        return {}
    out = dict(snaps[0])
    for s in snaps[1:]:
        out["lo"] = min(out["lo"], s["lo"])
        out["hi"] = max(out["hi"], s["hi"])
        out["min_seen"] = min(out["min_seen"], s["min_seen"])
        out["max_seen"] = max(out["max_seen"], s["max_seen"])
        out["absmax"] = max(out["absmax"], s["absmax"])
        out["n_batches"] = out["n_batches"] + s["n_batches"]
    return out


def collect_observations(qm) -> ObserverReport:
    """Build the per-site observer report from a calibrated model.

    ``qm``: :class:`~repro.quant.pipeline.QuantizedModel`. Repeats of a
    slot are folded: headroom takes the min, the register floor the max
    (one datapath must certify every repeat), activation ranges the union.
    Sites whose repeats were calibrated for *different* datapaths raise
    :class:`DatapathMismatchError` — the packed exporter would refuse them
    anyway, and a search over inconsistent inputs is meaningless.
    """
    period = qm.cfg.period
    by_slot: dict[str, list] = {}
    for i, b in enumerate(qm.blocks):
        for name, ql in b.quantized_linears():
            by_slot.setdefault(f"slot{i % period}/{name}", []).append(ql)

    sites: dict[str, SiteObservation] = {}
    for key, qls in by_slot.items():
        spec = qls[0].spec
        for ql in qls[1:]:
            if ql.spec is not None and spec is not None and not spec.matches(ql.spec):
                raise DatapathMismatchError(
                    f"site {key}: repeats calibrated for different datapaths "
                    f"({spec.describe()} vs {ql.spec.describe()})"
                )
        k = int(qls[0].q_int.shape[-2])
        certs = [ql.cert for ql in qls if ql.cert is not None]
        headroom = min(ql.headroom_bits for ql in certs) if certs else None
        p_floor = (
            max(min_feasible_p_bits(c, k) for c in certs)
            if certs
            else (spec.p_inner if spec is not None else 32)
        )
        snaps = []
        for ql in qls:
            obs = ql.aux.get("observer") if isinstance(ql.aux, dict) else None
            if obs is not None:
                snaps.append(obs.snapshot())
        sites[key] = SiteObservation(
            name=key,
            k=k,
            n_repeats=len(qls),
            spec=spec,
            headroom_bits=float(headroom) if headroom is not None else None,
            p_floor=int(p_floor),
            n_weights=int(np.prod(qls[0].q_int.shape)),
            act=_merge_snapshots(snaps),
        )
    return ObserverReport(sites=sites)


# ---------------------------------------------------------------------------
# The plan schema
# ---------------------------------------------------------------------------
_SPEC_FIELDS = (
    "w_bits", "act_bits", "act_signed", "tile", "p_inner", "p_outer",
    "static_act", "act_scale", "act_zp", "version", "sparsity",
)


def _spec_to_json(spec: DatapathSpec) -> dict:
    return {f: getattr(spec, f) for f in _SPEC_FIELDS}


def _spec_from_json(d: dict) -> DatapathSpec:
    return DatapathSpec(**{f: d[f] for f in _SPEC_FIELDS if f in d})


@dataclass
class MixedPrecisionPlan:
    """A searched per-site datapath assignment, as a portable artifact.

    ``sites`` maps slot-granular names ("slot0/mixer.wq") to the
    :class:`DatapathSpec` the site should be served with. ``kv`` is the
    optional attention section: per-attention-slot calibrated static page
    scales (lists, shape (R, n_kv_heads)) and per-head KV bit widths —
    see :mod:`repro.quant.observe.kv`. ``meta`` records the search
    provenance (objective, budgets, binding site).

    Duck-types as the mapping ``calibrate_and_quantize(plan=...)`` and the
    expected-spec side of ``validate_datapath`` consume: ``get``/``__iter__``
    /``__contains__``/``__len__`` delegate to ``sites``.
    """

    sites: dict[str, DatapathSpec] = field(default_factory=dict)
    kv: dict | None = None
    meta: dict = field(default_factory=dict)

    # -- mapping protocol (over sites) --------------------------------------
    def get(self, key: str, default=None):
        return self.sites.get(key, default)

    def __getitem__(self, key: str) -> DatapathSpec:
        return self.sites[key]

    def __contains__(self, key: str) -> bool:
        return key in self.sites

    def __iter__(self) -> Iterator[str]:
        return iter(self.sites)

    def __len__(self) -> int:
        return len(self.sites)

    def keys(self):
        return self.sites.keys()

    def items(self):
        return self.sites.items()

    # -- serialization ------------------------------------------------------
    def to_json(self) -> dict:
        return {
            "plan_version": PLAN_VERSION,
            "sites": {k: _spec_to_json(v) for k, v in sorted(self.sites.items())},
            "kv": self.kv,
            "meta": self.meta,
        }

    @classmethod
    def from_json(cls, d: dict) -> "MixedPrecisionPlan":
        v = d.get("plan_version")
        if v != PLAN_VERSION:
            raise ValueError(f"unsupported plan_version {v!r} (expected {PLAN_VERSION})")
        return cls(
            sites={k: _spec_from_json(s) for k, s in d.get("sites", {}).items()},
            kv=d.get("kv"),
            meta=d.get("meta", {}),
        )

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "MixedPrecisionPlan":
        with open(path) as f:
            return cls.from_json(json.load(f))

    def describe(self) -> str:
        lines = [f"mixed-precision plan ({len(self.sites)} sites)"]
        for k, s in sorted(self.sites.items()):
            lines.append(f"  {k}: {s.describe()}")
        if self.kv is not None:
            lines.append(f"  kv: static scales, bits={self.kv.get('kv_bits')}")
        return "\n".join(lines)


def dataclass_replace(spec: DatapathSpec, **kw) -> DatapathSpec:
    """`dataclasses.replace` re-export (keeps call sites import-light)."""
    return dataclasses.replace(spec, **kw)
