from .pipeline import (
    QuantizedBlock,
    QuantizedComponent,
    QuantizedModel,
    calibrate_and_quantize,
    float_ppl,
    quantized_forward,
    quantized_ppl,
)

__all__ = [
    "QuantizedBlock",
    "QuantizedComponent",
    "QuantizedModel",
    "calibrate_and_quantize",
    "float_ppl",
    "quantized_forward",
    "quantized_ppl",
]
