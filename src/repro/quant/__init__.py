from .spec import (
    ARTIFACT_VERSION,
    DatapathMismatchError,
    DatapathSpec,
    tree_datapath_fingerprint,
    validate_datapath,
)
from .pipeline import (
    QuantizedBlock,
    QuantizedComponent,
    QuantizedModel,
    calibrate_and_quantize,
    float_ppl,
    quantized_forward,
    quantized_ppl,
)

__all__ = [
    "ARTIFACT_VERSION",
    "DatapathMismatchError",
    "DatapathSpec",
    "QuantizedBlock",
    "QuantizedComponent",
    "QuantizedModel",
    "calibrate_and_quantize",
    "float_ppl",
    "quantized_forward",
    "quantized_ppl",
    "tree_datapath_fingerprint",
    "validate_datapath",
]
