from .pipeline import (
    QuantizedBlock,
    QuantizedModel,
    calibrate_and_quantize,
    quantized_forward,
)

__all__ = [
    "QuantizedBlock",
    "QuantizedModel",
    "calibrate_and_quantize",
    "quantized_forward",
]
