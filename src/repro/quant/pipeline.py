"""End-to-end AXE PTQ pipeline for decoder LMs (paper §4 recipe):

  load float params -> [SmoothQuant equalization] -> layer-by-layer
  calibration with *lockstep analog/quantized propagation* (GPFQ's
  "first l-1 layers quantized" setup, Eq. 9) -> AXE-GPFQ / AXE-OPTQ per
  linear site -> bias correction -> overflow certification -> quantized
  model.

The pipeline is family-agnostic: every block component (mixer or ffn of a
:class:`~repro.models.config.LayerSpec`) is handled by a registered
:class:`~repro.quant.families.base.BlockAdapter` that enumerates its
quantizable (K, C) linear sites and expresses its forward over *paired*
(analog, quantized) activation streams with each site routed through a tap.
Dense attn+mlp, MoE, Mamba and mLSTM/sLSTM adapters ship by default
(hybrid patterns like Jamba's compose for free); see
:mod:`repro.quant.families` to register more.

Embedding and LM head stay high-precision per the paper (§C.1). The
quantized forward has two execution paths:

  * simulation (fake-quant weights + activations, CPU/test path) — exactly
    the integer semantics, carried in fp32;
  * kernel (packed int4 + uint8 codes through repro.kernels.w4a8_mm) — the
    TPU path, interpret-mode on CPU (see repro.quant.serve_packed).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterator

import jax
import jax.numpy as jnp

from repro.core import (
    LayerStats,
    PTQConfig,
    QuantizedLinear,
    quantize_linear,
    smoothquant_scales,
    sweep_config,
)
from repro.core.quantizers import fake_quantize_act
from repro.models.config import LayerSpec, ModelConfig
from repro.models.layers import embed, lm_logits, norm

from .families import SiteSpec, TapContext, check_supported, get_adapter
from .spec import DatapathMismatchError


@dataclass
class QuantizedComponent:
    """One quantized block component (mixer or ffn).

    ``params`` keeps the component's high-precision leaves (norms excluded —
    they live on the block); leaves consumed by quantized sites are replaced
    with ``None`` (adapters only reach weights through taps, so the float
    originals can be dropped). ``linears`` maps site name ->
    :class:`~repro.core.QuantizedLinear`, ``specs`` site name -> its spec.
    """

    adapter: str
    kind: str
    params: dict
    linears: dict[str, QuantizedLinear]
    specs: dict[str, SiteSpec]


@dataclass
class QuantizedBlock:
    """One decoder layer: generic site-name -> QuantizedLinear mappings plus
    the float norms, for any registered family."""

    spec: LayerSpec
    norm1: dict | None = None
    norm2: dict | None = None
    mixer: QuantizedComponent | None = None
    ffn: QuantizedComponent | None = None

    def quantized_linears(self) -> Iterator[tuple[str, QuantizedLinear]]:
        """Yield ("mixer.wq"-style qualified name, QuantizedLinear)."""
        for comp_name in ("mixer", "ffn"):
            comp = getattr(self, comp_name)
            if comp is not None:
                for name, ql in comp.linears.items():
                    yield f"{comp_name}.{name}", ql

    # -- dense-family compatibility accessors --------------------------------
    def _site(self, comp: QuantizedComponent | None, *names: str):
        if comp is None:
            return None
        for n in names:
            if n in comp.linears:
                return comp.linears[n]
        return None

    @property
    def wq(self):
        return self._site(self.mixer, "wq")

    @property
    def wk(self):
        return self._site(self.mixer, "wk")

    @property
    def wv(self):
        return self._site(self.mixer, "wv")

    @property
    def wo(self):
        return self._site(self.mixer, "wo")

    @property
    def wg(self):
        # gelu models historically stored wi in the wg slot
        return self._site(self.ffn, "wg", "wi")

    @property
    def wu(self):
        return self._site(self.ffn, "wu")

    @property
    def wd(self):
        return self._site(self.ffn, "wd")


@dataclass
class QuantizedModel:
    cfg: ModelConfig
    ptq: PTQConfig
    embedding: dict
    final_norm: dict
    blocks: list[QuantizedBlock] = field(default_factory=list)

    def quantized_linears(self) -> Iterator[tuple[str, QuantizedLinear]]:
        """Yield ("layer3/ffn.wd", QuantizedLinear) over the whole model."""
        for i, b in enumerate(self.blocks):
            for name, ql in b.quantized_linears():
                yield f"layer{i}/{name}", ql

    def datapath_specs(self) -> dict:
        """{"layer3/ffn.wd": DatapathSpec} — the per-site serving datapaths
        this model was certified for (static act quantizers included).
        This is what the packed artifact embeds; see repro.quant.spec."""
        return {name: ql.spec for name, ql in self.quantized_linears()}

    @property
    def certified(self) -> bool:
        for _, ql in self.quantized_linears():
            if ql.cert is not None and not bool(ql.cert):
                return False
        return True

    def cert_summary(self) -> dict:
        """Aggregate certificate report.

        ``ok`` is explicit no-vacuous-truth semantics: a model with *no*
        certificates (e.g. ``constrain=False``) reports ``ok: False`` and
        ``min_headroom_bits: None`` — absence of a certificate is not a
        guarantee. ``min_headroom_site`` names the arg-min (binding) site,
        so search/debug output can say *where* the budget binds, not just
        by how much.
        """
        worst = None
        worst_site = None
        n = 0
        for name, ql in self.quantized_linears():
            if ql.cert is not None:
                h = ql.cert.headroom_bits
                if worst is None or h < worst:
                    worst, worst_site = h, name
                n += 1
        return {
            "n_certified": n,
            "min_headroom_bits": worst,
            "min_headroom_site": worst_site,
            "ok": n > 0 and self.certified,
        }


def _layer_params(params, cfg: ModelConfig, layer: int):
    slot = layer % cfg.period
    rep = layer // cfg.period
    return jax.tree.map(lambda x: x[rep], params["layers"][slot])


def _flat(x):
    return x.reshape(-1, x.shape[-1])


def _weight_at(p: dict, path: tuple[str, ...]):
    for key in path:
        p = p[key]
    return p


def _strip_quantized(p: dict, specs: dict[str, SiteSpec]) -> dict:
    """Replace quantized weight leaves with None (keys kept so adapters'
    float-leaf access patterns are unchanged)."""
    out = dict(p)
    for spec in specs.values():
        d = out
        for key in spec.path[:-1]:
            d[key] = dict(d[key])
            d = d[key]
        d[spec.path[-1]] = None
    return out


def _apply_quantized(ql: QuantizedLinear, x: jax.Array, use_bias: bool) -> jax.Array:
    """Simulated-integer site evaluation: fake-quant activations, real
    matmul against dequantized weights, optional corrected bias."""
    xq = fake_quantize_act(x, ql.act)
    y = xq @ ql.w_q
    if use_bias and ql.bias is not None:
        y = y + ql.bias
    return y


def _site_ptq(ptq: PTQConfig, site: SiteSpec, override) -> PTQConfig:
    """Per-site PTQConfig: mixed-precision plan entry wins, then the site's
    static ``SiteSpec.datapath`` override, then the model-wide config.

    ``override`` is a :class:`~repro.quant.spec.DatapathSpec` (or None).
    ``constrain`` follows the spec: a >= 32-bit inner register means the
    site runs the unconstrained solver (matching ``to_datapath_spec``'s
    inverse mapping), so plan -> calibrate -> ``datapath_specs()`` round-
    trips on the spec ``key()``.
    """
    dp = override if override is not None else site.datapath
    if dp is None:
        if ptq.sparsity is not None and site.k % 4 != 0:
            # 2:4 groups need K % 4 == 0: this site stays dense under a
            # model-wide sparse recipe (mirrors serve_packed eligibility)
            return sweep_config(ptq, sparsity=None)
        return ptq
    constrained = dp.p_inner is not None and dp.p_inner < 32
    return sweep_config(
        ptq,
        w_bits=dp.w_bits,
        act_bits=dp.act_bits,
        act_signed=dp.act_signed,
        p_bits=dp.p_inner if constrained else ptq.p_bits,
        tile=dp.tile if constrained else ptq.tile,
        constrain=constrained,
        sparsity=dp.sparsity if site.k % 4 == 0 else None,
    )


def _calibrate_component(
    adapter, p, nrm, x_a, x_q, cfg, ptq, positions, equalize,
    plan=None, site_prefix="",
):
    """Norm -> optional SmoothQuant fold -> tapped dual-stream forward.

    ``plan``: optional {"slot0/mixer.wq": DatapathSpec} mixed-precision
    overrides; ``site_prefix`` ("slot0/mixer.") qualifies this component's
    site names against it.

    Returns ((y_a, y_q) component outputs, QuantizedComponent, updated norm).
    """
    h_a = norm(nrm, x_a, cfg.norm)
    h_q = norm(nrm, x_q, cfg.norm)
    if equalize:
        w_absmax = adapter.input_weight_absmax(p, cfg)
        if w_absmax is not None:
            absmax = jnp.max(jnp.abs(_flat(h_q)), axis=0)
            s_eq = smoothquant_scales(absmax, w_absmax)
            nrm["w"] = nrm["w"] / s_eq
            if "b" in nrm:
                nrm["b"] = nrm["b"] / s_eq
            h_a = norm(nrm, x_a, cfg.norm)
            h_q = norm(nrm, x_q, cfg.norm)
            p = adapter.scale_input_weights(p, s_eq, cfg)

    specs = {s.name: s for s in adapter.enumerate_sites(cfg)}
    linears: dict[str, QuantizedLinear] = {}
    # LayerStats shared across sites fed by the same activation pair (e.g.
    # wq/wk/wv): keyed by identity so the O(K^2) accumulation and the
    # eigendecomposition inside the solver run once per distinct input.
    stats_cache: list[tuple[jax.Array, jax.Array, LayerStats]] = []

    def tap(name, xp, stats_from=None):
        spec = specs[name]
        sa, sq = stats_from if stats_from is not None else xp
        stats = None
        for ca, cq, cs in stats_cache:
            if ca is sa and cq is sq and cs.k == spec.k:
                stats = cs
                break
        if stats is None:
            stats = LayerStats(k=spec.k)
            stats.update(_flat(sa), _flat(sq))
            stats_cache.append((sa, sq, stats))
        w = _weight_at(p, spec.path)
        override = plan.get(site_prefix + name) if plan else None
        ql = quantize_linear(w, stats, _site_ptq(ptq, spec, override))
        ql.aux["observer"] = stats.observer
        linears[name] = ql
        x_a_in, x_q_in = xp
        return (x_a_in @ w, _apply_quantized(ql, x_q_in, spec.use_bias))

    ctx = TapContext(cfg=cfg, positions=positions)
    y_a, y_q = adapter.forward_with_taps(p, (h_a, h_q), ctx, tap)
    comp = QuantizedComponent(
        adapter=adapter.name,
        kind=adapter.kind,
        params=_strip_quantized(p, specs),
        linears=linears,
        specs=specs,
    )
    return (y_a, y_q), comp, nrm


def calibrate_and_quantize(
    params,
    cfg: ModelConfig,
    batches: list[dict],
    ptq: PTQConfig,
    equalize: bool = True,
    plan=None,
) -> QuantizedModel:
    """Run the full PTQ pipeline. ``batches``: list of {"tokens": (B, S)}.

    ``plan``: optional slot-granular mixed-precision overrides,
    {"slot{s}/{mixer|ffn}.{site}": DatapathSpec} (slot = layer % period —
    repeats of a slot share one packed leaf, so they must share one
    datapath; see :mod:`repro.quant.observe`). Keys naming no quantized
    site raise :class:`~repro.quant.spec.DatapathMismatchError` — a typo'd
    plan must not silently calibrate uniform.
    """
    check_supported(cfg)
    tokens = jnp.concatenate([b["tokens"] for b in batches], axis=0)
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    x_a = embed(params["embedding"], tokens, cfg)  # analog activations
    x_q = x_a  # quantized-network activations (lockstep)
    qm = QuantizedModel(
        cfg=cfg, ptq=ptq, embedding=params["embedding"],
        final_norm=params["final_norm"],
    )

    for layer in range(cfg.n_layers):
        p = _layer_params(params, cfg, layer)
        spec = cfg.pattern[layer % cfg.period]
        slot = layer % cfg.period
        block = QuantizedBlock(spec=spec)
        if spec.mixer != "none":
            adapter = get_adapter("mixer", spec.mixer)
            (y_a, y_q), comp, nrm = _calibrate_component(
                adapter, dict(p["mixer"]), dict(p["norm1"]),
                x_a, x_q, cfg, ptq, positions, equalize,
                plan=plan, site_prefix=f"slot{slot}/mixer.",
            )
            x_a = x_a + y_a
            x_q = x_q + y_q
            block.norm1 = nrm
            block.mixer = comp
        if spec.ffn != "none":
            adapter = get_adapter("ffn", spec.ffn)
            (y_a, y_q), comp, nrm = _calibrate_component(
                adapter, dict(p["ffn"]), dict(p["norm2"]),
                x_a, x_q, cfg, ptq, positions, equalize,
                plan=plan, site_prefix=f"slot{slot}/ffn.",
            )
            x_a = x_a + y_a
            x_q = x_q + y_q
            block.norm2 = nrm
            block.ffn = comp
        qm.blocks.append(block)
    if plan:
        known = {
            f"slot{i % cfg.period}/{name}"
            for i, b in enumerate(qm.blocks)
            for name, _ in b.quantized_linears()
        }
        unknown = sorted(k for k in plan if k not in known)
        if unknown:
            raise DatapathMismatchError(
                f"mixed-precision plan names unknown sites {unknown}; "
                f"model enumerates {sorted(known)}"
            )
    return qm


def _quantized_component_forward(comp: QuantizedComponent, h, cfg, positions):
    """Single-stream simulated-integer component forward: the same adapter
    code path as calibration, with taps resolving to stored artifacts and
    the paired streams collapsed (see families.base.both)."""
    adapter = get_adapter(comp.kind, comp.adapter)

    def tap(name, xp, stats_from=None):
        y = _apply_quantized(comp.linears[name], xp[1], comp.specs[name].use_bias)
        return (y, y)

    ctx = TapContext(cfg=cfg, positions=positions)
    return adapter.forward_with_taps(comp.params, (h, h), ctx, tap)[1]



def quantized_forward(qm: QuantizedModel, batch: dict) -> jax.Array:
    """Simulated-integer forward of the quantized model -> logits."""
    cfg = qm.cfg
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = embed(qm.embedding, tokens, cfg)
    for b in qm.blocks:
        if b.mixer is not None:
            h = norm(b.norm1, x, cfg.norm)
            x = x + _quantized_component_forward(b.mixer, h, cfg, positions)
        if b.ffn is not None:
            h = norm(b.norm2, x, cfg.norm)
            x = x + _quantized_component_forward(b.ffn, h, cfg, positions)
    x = norm(qm.final_norm, x, cfg.norm)
    return lm_logits(qm.embedding, x, cfg)


def quantized_ppl(qm: QuantizedModel, batches: list[dict]) -> float:
    """Perplexity of the quantized model over eval batches."""
    tot, n = 0.0, 0
    for b in batches:
        logits = quantized_forward(qm, b).astype(jnp.float32)
        pred = logits[:, :-1]
        labels = b["tokens"][:, 1:]
        logz = jax.nn.logsumexp(pred, axis=-1)
        gold = jnp.take_along_axis(pred, labels[..., None], axis=-1)[..., 0]
        tot += float(jnp.sum(logz - gold))
        n += labels.size
    return math.exp(tot / n)


def float_ppl(params, cfg: ModelConfig, batches: list[dict]) -> float:
    from repro.models.transformer import loss_fn

    tot, n = 0.0, 0
    for b in batches:
        _, m = loss_fn(params, b, cfg)
        tot += float(m["ce"]) * (b["tokens"].shape[0] * (b["tokens"].shape[1] - 1))
        n += b["tokens"].shape[0] * (b["tokens"].shape[1] - 1)
    return math.exp(tot / n)
