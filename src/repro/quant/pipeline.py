"""End-to-end AXE PTQ pipeline for decoder LMs (paper §4 recipe):

  load float params -> [SmoothQuant equalization] -> layer-by-layer
  calibration with *lockstep analog/quantized propagation* (GPFQ's
  "first l-1 layers quantized" setup, Eq. 9) -> AXE-GPFQ / AXE-OPTQ per
  linear -> bias correction -> overflow certification -> quantized model.

Supported family: uniform ("attn", "mlp") patterns (the dense LM family,
incl. the tiny-lm paper-reproduction ladder). Embedding and LM head stay
high-precision per the paper (§C.1). The quantized forward has two
execution paths:

  * simulation (fake-quant weights + activations, CPU/test path) — exactly
    the integer semantics, carried in fp32;
  * kernel (packed int4 + uint8 codes through repro.kernels.w4a8_mm) — the
    TPU path, interpret-mode on CPU.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.core import (
    LayerStats,
    PTQConfig,
    QuantizedLinear,
    quantize_linear,
    smoothquant_scales,
)
from repro.core.quantizers import fake_quantize_act, quantize_act
from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_rope,
    embed,
    lm_logits,
    norm,
)

LINEAR_SITES = ("qkv", "wo", "mlp_in", "wd")


@dataclass
class QuantizedBlock:
    """One decoder layer's quantized linears + the float norms."""

    norm1: dict
    norm2: dict
    wq: QuantizedLinear
    wk: QuantizedLinear
    wv: QuantizedLinear
    wo: QuantizedLinear
    # swiglu: (wg, wu, wd); gelu: (wi, wd) with wu None
    wg: QuantizedLinear
    wu: QuantizedLinear | None
    wd: QuantizedLinear


@dataclass
class QuantizedModel:
    cfg: ModelConfig
    ptq: PTQConfig
    embedding: dict
    final_norm: dict
    blocks: list[QuantizedBlock] = field(default_factory=list)

    @property
    def certified(self) -> bool:
        for b in self.blocks:
            for ql in (b.wq, b.wk, b.wv, b.wo, b.wg, b.wu, b.wd):
                if ql is not None and ql.cert is not None and not bool(ql.cert):
                    return False
        return True

    def cert_summary(self) -> dict:
        worst = float("inf")
        n = 0
        for b in self.blocks:
            for ql in (b.wq, b.wk, b.wv, b.wo, b.wg, b.wu, b.wd):
                if ql is not None and ql.cert is not None:
                    worst = min(worst, ql.cert.headroom_bits)
                    n += 1
        return {"n_certified": n, "min_headroom_bits": worst, "ok": self.certified}


def _layer_params(params, cfg: ModelConfig, layer: int):
    slot = layer % cfg.period
    rep = layer // cfg.period
    return jax.tree.map(lambda x: x[rep], params["layers"][slot])


def _attn_mix(q, k, v, cfg: ModelConfig, positions):
    """Float attention mixing (scores/softmax stay high-precision, §C.1)."""
    B, S, _ = q.shape
    hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    g = nh // nkv
    q = apply_rope(q.reshape(B, S, nh, hd), positions, cfg.rope_theta)
    k = apply_rope(k.reshape(B, S, nkv, hd), positions, cfg.rope_theta)
    v = v.reshape(B, S, nkv, hd)
    qg = q.reshape(B, S, nkv, g, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) / math.sqrt(hd)
    causal = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(causal, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return out.reshape(B, S, nh * hd)


def _check_supported(cfg: ModelConfig):
    for spec in cfg.pattern:
        if (spec.mixer, spec.ffn) != ("attn", "mlp"):
            raise NotImplementedError(
                f"PTQ pipeline supports the dense attn+mlp family; "
                f"{cfg.name} has ({spec.mixer}, {spec.ffn}). AXE itself applies "
                f"per-linear (see DESIGN.md §4); extend the pipeline taps to "
                f"add the family."
            )


def calibrate_and_quantize(
    params,
    cfg: ModelConfig,
    batches: list[dict],
    ptq: PTQConfig,
    equalize: bool = True,
) -> QuantizedModel:
    """Run the full PTQ pipeline. ``batches``: list of {"tokens": (B, S)}."""
    _check_supported(cfg)
    tokens = jnp.concatenate([b["tokens"] for b in batches], axis=0)
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    x_a = embed(params["embedding"], tokens, cfg)  # analog activations
    x_q = x_a  # quantized-network activations (lockstep)
    d = cfg.d_model
    qm = QuantizedModel(
        cfg=cfg, ptq=ptq, embedding=params["embedding"],
        final_norm=params["final_norm"],
    )

    def flat(x):
        return x.reshape(-1, x.shape[-1])

    for layer in range(cfg.n_layers):
        p = _layer_params(params, cfg, layer)
        mixer, ffn = p["mixer"], p["ffn"]
        norm1, norm2 = dict(p["norm1"]), dict(p["norm2"])

        # ---- attention ----
        h_a = norm(norm1, x_a, cfg.norm)
        h_q = norm(norm1, x_q, cfg.norm)
        wq_w, wk_w, wv_w = mixer["wq"], mixer["wk"], mixer["wv"]
        if equalize:
            absmax = jnp.max(jnp.abs(flat(h_q)), axis=0)
            w_absmax = jnp.max(
                jnp.abs(jnp.concatenate([wq_w, wk_w, wv_w], axis=1)), axis=1
            )
            s_eq = smoothquant_scales(absmax, w_absmax)
            norm1["w"] = norm1["w"] / s_eq
            if "b" in norm1:
                norm1["b"] = norm1["b"] / s_eq
            h_a = norm(norm1, x_a, cfg.norm)
            h_q = norm(norm1, x_q, cfg.norm)
            wq_w, wk_w, wv_w = (w * s_eq[:, None] for w in (wq_w, wk_w, wv_w))

        stats = LayerStats(k=d)
        stats.update(flat(h_a), flat(h_q))
        ql_q = quantize_linear(wq_w, stats, ptq)
        ql_k = quantize_linear(wk_w, stats, ptq)
        ql_v = quantize_linear(wv_w, stats, ptq)

        ao = _attn_mix(h_a @ wq_w, h_a @ wk_w, h_a @ wv_w, cfg, positions)
        h_qq = fake_quantize_act(h_q, ql_q.act)
        aq = _attn_mix(h_qq @ ql_q.w_q, h_qq @ ql_k.w_q, h_qq @ ql_v.w_q,
                       cfg, positions)

        stats_o = LayerStats(k=cfg.n_heads * cfg.head_dim)
        stats_o.update(flat(ao), flat(aq))
        ql_o = quantize_linear(mixer["wo"], stats_o, ptq)

        x_a = x_a + ao @ mixer["wo"]
        x_q = x_q + ql_o(aq)

        # ---- mlp ----
        h_a = norm(norm2, x_a, cfg.norm)
        h_q = norm(norm2, x_q, cfg.norm)
        swiglu = cfg.act == "swiglu"
        win_a = ffn["wg"] if swiglu else ffn["wi"]
        wu_w = ffn.get("wu")
        if equalize:
            absmax = jnp.max(jnp.abs(flat(h_q)), axis=0)
            cat = jnp.concatenate([win_a] + ([wu_w] if swiglu else []), axis=1)
            s_eq = smoothquant_scales(absmax, jnp.max(jnp.abs(cat), axis=1))
            norm2["w"] = norm2["w"] / s_eq
            if "b" in norm2:
                norm2["b"] = norm2["b"] / s_eq
            h_a = norm(norm2, x_a, cfg.norm)
            h_q = norm(norm2, x_q, cfg.norm)
            win_a = win_a * s_eq[:, None]
            if swiglu:
                wu_w = wu_w * s_eq[:, None]

        stats_in = LayerStats(k=d)
        stats_in.update(flat(h_a), flat(h_q))
        ql_g = quantize_linear(win_a, stats_in, ptq)
        ql_u = quantize_linear(wu_w, stats_in, ptq) if swiglu else None

        h_qq = fake_quantize_act(h_q, ql_g.act)
        if swiglu:
            mid_a = jax.nn.silu(h_a @ win_a) * (h_a @ wu_w)
            mid_q = jax.nn.silu(h_qq @ ql_g.w_q) * (h_qq @ ql_u.w_q)
        else:
            mid_a = jax.nn.gelu(h_a @ win_a)
            mid_q = jax.nn.gelu(h_qq @ ql_g.w_q)

        stats_d = LayerStats(k=win_a.shape[1])
        stats_d.update(flat(mid_a), flat(mid_q))
        ql_d = quantize_linear(ffn["wd"], stats_d, ptq)

        x_a = x_a + mid_a @ ffn["wd"]
        x_q = x_q + ql_d(mid_q)

        qm.blocks.append(
            QuantizedBlock(
                norm1=norm1, norm2=norm2,
                wq=ql_q, wk=ql_k, wv=ql_v, wo=ql_o,
                wg=ql_g, wu=ql_u, wd=ql_d,
            )
        )
    return qm


def quantized_forward(qm: QuantizedModel, batch: dict) -> jax.Array:
    """Simulated-integer forward of the quantized model -> logits."""
    cfg = qm.cfg
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = embed(qm.embedding, tokens, cfg)
    for b in qm.blocks:
        h = norm(b.norm1, x, cfg.norm)
        hq = fake_quantize_act(h, b.wq.act)
        ao = _attn_mix(hq @ b.wq.w_q, hq @ b.wk.w_q, hq @ b.wv.w_q, cfg, positions)
        x = x + b.wo(ao)
        h = norm(b.norm2, x, cfg.norm)
        hq = fake_quantize_act(h, b.wg.act)
        if qm.cfg.act == "swiglu":
            mid = jax.nn.silu(hq @ b.wg.w_q) * (hq @ b.wu.w_q)
        else:
            mid = jax.nn.gelu(hq @ b.wg.w_q)
        x = x + b.wd(mid)
    x = norm(qm.final_norm, x, cfg.norm)
    return lm_logits(qm.embedding, x, cfg)


def quantized_ppl(qm: QuantizedModel, batches: list[dict]) -> float:
    """Perplexity of the quantized model over eval batches."""
    tot, n = 0.0, 0
    for b in batches:
        logits = quantized_forward(qm, b).astype(jnp.float32)
        pred = logits[:, :-1]
        labels = b["tokens"][:, 1:]
        logz = jax.nn.logsumexp(pred, axis=-1)
        gold = jnp.take_along_axis(pred, labels[..., None], axis=-1)[..., 0]
        tot += float(jnp.sum(logz - gold))
        n += labels.size
    return math.exp(tot / n)


def float_ppl(params, cfg: ModelConfig, batches: list[dict]) -> float:
    from repro.models.transformer import loss_fn

    tot, n = 0.0, 0
    for b in batches:
        _, m = loss_fn(params, b, cfg)
        tot += float(m["ce"]) * (b["tokens"].shape[0] * (b["tokens"].shape[1] - 1))
        n += b["tokens"].shape[0] * (b["tokens"].shape[1] - 1)
    return math.exp(tot / n)
