"""Quantizable-site adapter protocol (the family-agnostic PTQ contract).

The AXE guarantee is *per-linear*: any K-deep dot product can be constrained
to a (T, P) accumulation datapath (paper §3.3; A2Q arXiv:2308.13504, A2Q+
arXiv:2401.10432 establish the same for any MAC reduction). The pipeline
therefore never needs to know a model family's internals — it only needs,
per block component (mixer or ffn):

  * ``enumerate_sites(cfg)`` — the named (K, C) linear reductions the
    component owns, derived purely from the model config (so serving-side
    consumers can enumerate without materializing parameters);
  * ``forward_with_taps(p, x, ctx, tap)`` — the component forward expressed
    over *paired* (analog, quantized) streams, with every quantizable matmul
    routed through ``tap``. The same function serves three roles:
      1. calibration: the pipeline's tap streams layer statistics from the
         paired inputs, quantizes the site, and returns
         ``(x_a @ W, fake_quant(x_q) @ W_q)`` — GPFQ's lockstep "first l-1
         layers quantized" propagation (paper Eq. 9);
      2. simulated-integer inference: the tap looks up the stored
         :class:`~repro.core.QuantizedLinear` and returns its output for
         both streams (the pair collapses — see :func:`both`);
      3. site-name-driven packing/export (via the enumeration alone).
  * two optional SmoothQuant hooks describing which weights consume the
    component's (normed) input, so equalization stays functionally
    invariant per family.

Everything that is *not* a tap stays in high precision: softmax/RoPE, the
selective-SSM scan, mLSTM/sLSTM cell recurrences and gate nonlinearities,
MoE router logits — mirroring the paper's §C.1 exclusions (documented per
family in docs/families.md).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax

from repro.models.config import ModelConfig

#: A pair of (analog, quantized) activation streams. During simulated-integer
#: inference both elements are the *same object*, which :func:`both` exploits
#: to evaluate the float ops between taps only once.
Pair = tuple[jax.Array, jax.Array]

#: tap(site_name, x_pair, stats_from=...) -> y_pair. Provided by the
#: pipeline; adapters never touch weights of quantizable sites directly.
TapFn = Callable[..., Pair]


@dataclass(frozen=True)
class SiteSpec:
    """One quantizable linear reduction inside a block component.

    ``path`` addresses the float weight inside the component's param dict;
    ``k``/``c`` are the per-matrix reduction depth and output width;
    ``stacked`` is the leading expert-stack size for (E, K, C) weights
    (MoE experts) and None for plain 2D sites. ``use_bias`` controls whether
    the bias-corrected bias is applied when the quantized site is evaluated
    (the pipeline convention: only the output-side projection of each
    component carries the correction at runtime).

    ``datapath`` is an optional per-site
    :class:`~repro.quant.spec.DatapathSpec` override; sites that leave it
    None get the recipe-wide spec specialized to their depth via
    :meth:`datapath_for` (P_O depends on K, so it is *always* per-site).
    Expert-stacked sites share one datapath across the stack — their
    activation-quantizer *scales* stack per expert in the packed artifact,
    the accumulator shape does not.
    """

    name: str
    path: tuple[str, ...]
    k: int
    c: int
    stacked: int | None = None
    use_bias: bool = False
    datapath: "object | None" = None

    def datapath_for(self, ptq) -> "object":
        """Resolve this site's serving datapath: the explicit override if
        one was attached, else ``ptq.to_datapath_spec`` at this site's
        reduction depth (``ptq`` may be a PTQConfig or a DatapathSpec)."""
        if self.datapath is not None:
            return self.datapath
        if hasattr(ptq, "to_datapath_spec"):
            return ptq.to_datapath_spec(self.k)
        return ptq


@dataclass
class TapContext:
    """Per-call context threaded through ``forward_with_taps``."""

    cfg: ModelConfig
    positions: jax.Array | None = None


def both(f, *pairs: Pair) -> Pair:
    """Apply a float (non-tap) op to each stream of the paired activations.

    When every input pair carries the same object on both sides (the
    simulated-integer forward), the op runs once and the identity is
    preserved — so a whole block forward written against pairs costs a
    single stream outside calibration.
    """
    q = f(*(p[1] for p in pairs))
    if all(p[0] is p[1] for p in pairs):
        return (q, q)
    return (f(*(p[0] for p in pairs)), q)


class BlockAdapter:
    """Base class for family adapters. Subclasses set ``kind`` ("mixer" or
    "ffn") and ``name`` (the :class:`~repro.models.config.LayerSpec` value
    they implement) and override the four protocol methods."""

    kind: str = ""
    name: str = ""

    def enumerate_sites(self, cfg: ModelConfig) -> tuple[SiteSpec, ...]:
        raise NotImplementedError

    def input_weight_absmax(self, p, cfg: ModelConfig) -> jax.Array | None:
        """Per-input-dim abs-max of the weight(s) consuming the component's
        normed input, for SmoothQuant scale derivation. ``None`` disables
        equalization for this component."""
        return None

    def scale_input_weights(self, p: dict, s_eq: jax.Array, cfg: ModelConfig) -> dict:
        """Return params with every consumer of the normed input row-scaled
        by ``s_eq`` (keeping the float function invariant after 1/s_eq is
        folded into the preceding norm)."""
        return p

    def forward_with_taps(self, p: dict, x: Pair, ctx: TapContext, tap: TapFn) -> Pair:
        raise NotImplementedError
