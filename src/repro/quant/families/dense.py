"""Dense transformer adapters: GQA attention mixer + (SwiGLU | GELU) MLP.

This is the paper's original family (§4 recipe), re-expressed as the first
two :class:`~repro.quant.families.base.BlockAdapter` implementations — the
refactor is behavior-preserving: the dense pipeline produces bit-identical
quantized weights and perplexities to the pre-registry monolithic loop
(pinned by tests/test_quant_pipeline.py golden values).

High-precision (§C.1): RoPE, attention scores/softmax, the SwiGLU/GELU
nonlinearities, norms, embedding and LM head.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope

from .base import BlockAdapter, Pair, SiteSpec, TapContext, TapFn, both


def attn_mix(q, k, v, cfg: ModelConfig, positions):
    """Float attention mixing (scores/softmax stay high-precision, §C.1)."""
    B, S, _ = q.shape
    hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    g = nh // nkv
    q = apply_rope(q.reshape(B, S, nh, hd), positions, cfg.rope_theta)
    k = apply_rope(k.reshape(B, S, nkv, hd), positions, cfg.rope_theta)
    v = v.reshape(B, S, nkv, hd)
    qg = q.reshape(B, S, nkv, g, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) / math.sqrt(hd)
    causal = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(causal, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return out.reshape(B, S, nh * hd)


class AttentionAdapter(BlockAdapter):
    kind = "mixer"
    name = "attn"

    def enumerate_sites(self, cfg: ModelConfig) -> tuple[SiteSpec, ...]:
        d, hd = cfg.d_model, cfg.head_dim
        nh, nkv = cfg.n_heads, cfg.n_kv_heads
        return (
            SiteSpec("wq", ("wq",), d, nh * hd),
            SiteSpec("wk", ("wk",), d, nkv * hd),
            SiteSpec("wv", ("wv",), d, nkv * hd),
            SiteSpec("wo", ("wo",), nh * hd, d, use_bias=True),
        )

    def input_weight_absmax(self, p, cfg: ModelConfig):
        cat = jnp.concatenate([p["wq"], p["wk"], p["wv"]], axis=1)
        return jnp.max(jnp.abs(cat), axis=1)

    def scale_input_weights(self, p, s_eq, cfg: ModelConfig):
        p = dict(p)
        for name in ("wq", "wk", "wv"):
            p[name] = p[name] * s_eq[:, None]
        return p

    def forward_with_taps(self, p, x: Pair, ctx: TapContext, tap: TapFn) -> Pair:
        q = tap("wq", x)
        k = tap("wk", x)
        v = tap("wv", x)
        mix = both(
            lambda qs, ks, vs: attn_mix(qs, ks, vs, ctx.cfg, ctx.positions),
            q, k, v,
        )
        return tap("wo", mix)


class MLPAdapter(BlockAdapter):
    kind = "ffn"
    name = "mlp"

    def enumerate_sites(self, cfg: ModelConfig) -> tuple[SiteSpec, ...]:
        d, f = cfg.d_model, cfg.d_ff
        if cfg.act == "swiglu":
            return (
                SiteSpec("wg", ("wg",), d, f),
                SiteSpec("wu", ("wu",), d, f),
                SiteSpec("wd", ("wd",), f, d, use_bias=True),
            )
        return (
            SiteSpec("wi", ("wi",), d, f),
            SiteSpec("wd", ("wd",), f, d, use_bias=True),
        )

    def input_weight_absmax(self, p, cfg: ModelConfig):
        if cfg.act == "swiglu":
            cat = jnp.concatenate([p["wg"], p["wu"]], axis=1)
        else:
            cat = p["wi"]
        return jnp.max(jnp.abs(cat), axis=1)

    def scale_input_weights(self, p, s_eq, cfg: ModelConfig):
        p = dict(p)
        names = ("wg", "wu") if cfg.act == "swiglu" else ("wi",)
        for name in names:
            p[name] = p[name] * s_eq[:, None]
        return p

    def forward_with_taps(self, p, x: Pair, ctx: TapContext, tap: TapFn) -> Pair:
        if ctx.cfg.act == "swiglu":
            g = tap("wg", x)
            u = tap("wu", x)
            mid = both(lambda gs, us: jax.nn.silu(gs) * us, g, u)
        else:
            mid = both(jax.nn.gelu, tap("wi", x))
        return tap("wd", mid)
