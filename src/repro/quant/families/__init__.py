"""Quantizable-site registry — one adapter per (kind, LayerSpec value).

The registry is the single source of truth for *what* gets quantized in
every model family. The PTQ pipeline (:mod:`repro.quant.pipeline`), the
packed-serving transform (:mod:`repro.quant.serve_packed`) and the PTQ
launcher (:mod:`repro.launch.quantize`) all consume it; none of them hold
hardcoded leaf-name lists anymore.

Registering a new family:

    from repro.quant.families import register_adapter
    from repro.quant.families.base import BlockAdapter

    class MyMixerAdapter(BlockAdapter):
        kind = "mixer"; name = "my_mixer"
        ...

    register_adapter(MyMixerAdapter())

See docs/families.md for the adapter protocol and per-family site tables.
"""

from __future__ import annotations

from repro.models.config import ModelConfig

from .base import BlockAdapter, Pair, SiteSpec, TapContext, TapFn, both
from .dense import AttentionAdapter, MLPAdapter
from .moe import MoEAdapter
from .ssm import MambaAdapter
from .xlstm import MLSTMAdapter, SLSTMAdapter

_REGISTRY: dict[tuple[str, str], BlockAdapter] = {}


def register_adapter(adapter: BlockAdapter) -> BlockAdapter:
    """Register (or replace) the adapter for (adapter.kind, adapter.name)."""
    if adapter.kind not in ("mixer", "ffn"):
        raise ValueError(f"adapter kind must be 'mixer' or 'ffn', got {adapter.kind!r}")
    _REGISTRY[(adapter.kind, adapter.name)] = adapter
    return adapter


def registered_families() -> dict[str, tuple[str, ...]]:
    """{"mixer": (names...), "ffn": (names...)} of registered adapters."""
    out: dict[str, list[str]] = {"mixer": [], "ffn": []}
    for kind, name in sorted(_REGISTRY):
        out[kind].append(name)
    return {k: tuple(v) for k, v in out.items()}


def get_adapter(kind: str, name: str) -> BlockAdapter:
    """Look up the adapter for a LayerSpec component, or raise a
    NotImplementedError that lists what *is* registered."""
    try:
        return _REGISTRY[(kind, name)]
    except KeyError:
        fam = registered_families()
        raise NotImplementedError(
            f"no PTQ adapter registered for {kind} {name!r}. Registered "
            f"mixers: {fam['mixer']}; ffns: {fam['ffn']}. AXE applies to any "
            f"K-deep linear reduction — implement the BlockAdapter protocol "
            f"(repro.quant.families.base, docs/families.md) and "
            f"register_adapter() it."
        ) from None


def check_supported(cfg: ModelConfig) -> None:
    """Raise NotImplementedError unless every pattern component has an
    adapter ("none" components are skipped)."""
    for spec in cfg.pattern:
        for kind, name in (("mixer", spec.mixer), ("ffn", spec.ffn)):
            if name != "none":
                get_adapter(kind, name)


for _adapter in (
    AttentionAdapter(),
    MLPAdapter(),
    MoEAdapter(),
    MambaAdapter(),
    MLSTMAdapter(),
    SLSTMAdapter(),
):
    register_adapter(_adapter)

__all__ = [
    "BlockAdapter",
    "Pair",
    "SiteSpec",
    "TapContext",
    "TapFn",
    "both",
    "check_supported",
    "get_adapter",
    "register_adapter",
    "registered_families",
]
