"""MoE FFN adapter: GShard-style top-k routed experts with expert-stacked
(E, K, C) weights.

Quantizable sites are the stacked expert matrices themselves — AXE applies
per expert slice, since each expert performs an ordinary K-deep MAC
reduction. The stacked weights go through the vmapped
:func:`repro.core.quantize_linear` path, which produces per-expert
certificates identical to quantizing each slice independently (tested).

High-precision (§C.1): the router logits/softmax/top-k and the dispatch/
combine einsums (0/1 and gate-weighted mixing matrices, not MAC reductions
over quantized weights).

Calibration statistics for the expert up-projections are streamed from the
*pre-dispatch* tokens (the normed block input): every expert consumes a
capacity-selected subset of exactly those rows, so the shared (K, K)
sufficient statistics stay O(K^2) regardless of expert count while
remaining a superset of what each expert sees. Routing during lockstep
calibration is computed from the quantized stream (what the deployed
quantized network will route on) and the same dispatch is applied to the
analog stream so the (X, Xq) sample rows stay paired.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.moe import route

from .base import BlockAdapter, Pair, SiteSpec, TapContext, TapFn, both


class MoEAdapter(BlockAdapter):
    kind = "ffn"
    name = "moe"

    def enumerate_sites(self, cfg: ModelConfig) -> tuple[SiteSpec, ...]:
        mo = cfg.moe
        d, f, e = cfg.d_model, mo.d_ff_expert, mo.n_experts
        if cfg.act == "swiglu":
            return (
                SiteSpec("wg", ("wg",), d, f, stacked=e),
                SiteSpec("wu", ("wu",), d, f, stacked=e),
                SiteSpec("wd", ("wd",), f, d, stacked=e, use_bias=True),
            )
        return (
            SiteSpec("wi", ("wi",), d, f, stacked=e),
            SiteSpec("wd", ("wd",), f, d, stacked=e, use_bias=True),
        )

    def input_weight_absmax(self, p, cfg: ModelConfig):
        ws = [p["wg"], p["wu"]] if cfg.act == "swiglu" else [p["wi"]]
        cat = jnp.concatenate(ws, axis=2)  # (E, d, sum f)
        return jnp.max(jnp.abs(cat), axis=(0, 2))

    def scale_input_weights(self, p, s_eq, cfg: ModelConfig):
        p = dict(p)
        names = ("wg", "wu") if cfg.act == "swiglu" else ("wi",)
        for name in names:
            p[name] = p[name] * s_eq[None, :, None]
        # the router also consumes the normed input: scale it too so the
        # float function (and therefore the routing) stays invariant
        p["router"] = p["router"] * s_eq[:, None]
        return p

    def forward_with_taps(self, p, x: Pair, ctx: TapContext, tap: TapFn) -> Pair:
        cfg = ctx.cfg
        B, S, d = x[1].shape
        e = cfg.moe.n_experts
        # route on the quantized stream (what the deployed network routes
        # on), via the float model's own routing code, then apply the same
        # dispatch to both streams so sample rows stay paired
        xf_q, dispatch, combine, _, _, c = route(p["router"], x[1], cfg)
        G, g, _ = xf_q.shape
        # keep the pair-identity collapse when both streams are one object
        xf = (xf_q, xf_q) if x[0] is x[1] else (x[0].reshape(G, g, d), xf_q)

        xe = both(
            lambda t: jnp.einsum("gsec,gsd->egcd", dispatch, t).reshape(e, G * c, d),
            xf,
        )
        if cfg.act == "swiglu":
            hg = tap("wg", xe, stats_from=x)
            hu = tap("wu", xe, stats_from=x)
            mid = both(lambda a, b: jax.nn.silu(a) * b, hg, hu)
        else:
            mid = both(jax.nn.gelu, tap("wi", xe, stats_from=x))
        ye = tap("wd", mid)  # (E, G*c, d)

        def comb(ys):
            y = jnp.einsum("gsec,egcd->gsd", combine, ys.reshape(e, G, c, d))
            return y.reshape(B, S, d).astype(x[1].dtype)

        return both(comb, ye)
