"""xLSTM adapters: mLSTM (matrix-memory) and sLSTM (scalar-memory) mixers.

mLSTM quantizable sites: ``up`` (d, 2*d_in), ``wq``/``wk``/``wv``
(d_in, d_in) and ``down`` (d_in, d — corrected bias at runtime). The i/f
gate projections (d_in, n_heads) are left in high precision: they feed the
exponential-gating log-space stabilizers, whose dynamic range is exactly
what low-precision accumulation must not touch (and at n_heads output
channels they are a negligible fraction of block FLOPs).

sLSTM quantizable sites: ``w_in`` (d, 4d — the z/i/f/o input projection),
``up`` and ``down`` of the block FFN. The block-diagonal recurrent matrices
``r`` stay high-precision: they sit inside the sequential nonlinear
recurrence (h feeds back through the gates), the one place the paper's
static worst-case input model does not cover.

The cell recurrences themselves (chunkwise-parallel mLSTM, scanned sLSTM)
run exactly as in :mod:`repro.models.xlstm` — shared code, not a fork.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.ssm import _causal_conv
from repro.models.xlstm import (
    _mlstm_merge,
    mlstm_cell_chunkwise,
    slstm_headnorm,
    slstm_scan,
)

from .base import BlockAdapter, Pair, SiteSpec, TapContext, TapFn, both


class MLSTMAdapter(BlockAdapter):
    kind = "mixer"
    name = "mlstm"

    def enumerate_sites(self, cfg: ModelConfig) -> tuple[SiteSpec, ...]:
        d = cfg.d_model
        d_in = cfg.xlstm.mlstm_expand * d
        return (
            SiteSpec("up", ("up",), d, 2 * d_in),
            SiteSpec("wq", ("wq",), d_in, d_in),
            SiteSpec("wk", ("wk",), d_in, d_in),
            SiteSpec("wv", ("wv",), d_in, d_in),
            SiteSpec("down", ("down",), d_in, d, use_bias=True),
        )

    def input_weight_absmax(self, p, cfg: ModelConfig):
        return jnp.max(jnp.abs(p["up"]), axis=1)

    def scale_input_weights(self, p, s_eq, cfg: ModelConfig):
        p = dict(p)
        p["up"] = p["up"] * s_eq[:, None]
        return p

    def forward_with_taps(self, p, x: Pair, ctx: TapContext, tap: TapFn) -> Pair:
        cfg = ctx.cfg
        xl = cfg.xlstm
        d_in = xl.mlstm_expand * cfg.d_model
        heads = xl.mlstm_heads
        dh = d_in // heads

        xz = tap("up", x)
        xin = both(lambda t: t[..., :d_in], xz)
        z = both(lambda t: t[..., d_in:], xz)
        xc = both(
            lambda t: jax.nn.silu(_causal_conv(t, p["conv_w"], p["conv_b"])[0]),
            xin,
        )
        q = tap("wq", xc)
        k = tap("wk", xc)
        v = tap("wv", xin)

        def cell_merge(qs, ks, vs, xcs, xins, zs):
            B, S, _ = qs.shape
            qh = qs.reshape(B, S, heads, dh).transpose(0, 2, 1, 3) * (dh**-0.5)
            kh = ks.reshape(B, S, heads, dh).transpose(0, 2, 1, 3)
            vh = vs.reshape(B, S, heads, dh).transpose(0, 2, 1, 3)
            ig = (xins @ p["wi"]).transpose(0, 2, 1).astype(jnp.float32)
            fg = (xins @ p["wf"] + p["f_bias"]).transpose(0, 2, 1).astype(jnp.float32)
            h_cell = mlstm_cell_chunkwise(qh, kh, vh, ig, fg, xl.chunk)
            return _mlstm_merge(p, h_cell, xcs, zs, cfg)

        merged = both(cell_merge, q, k, v, xc, xin, z)
        return tap("down", merged)


class SLSTMAdapter(BlockAdapter):
    kind = "mixer"
    name = "slstm"

    def enumerate_sites(self, cfg: ModelConfig) -> tuple[SiteSpec, ...]:
        d = cfg.d_model
        f = int(d * cfg.xlstm.slstm_proj_factor)
        return (
            SiteSpec("w_in", ("w_in",), d, 4 * d),
            SiteSpec("up", ("up",), d, f),
            SiteSpec("down", ("down",), f, d, use_bias=True),
        )

    def input_weight_absmax(self, p, cfg: ModelConfig):
        return jnp.max(jnp.abs(p["w_in"]), axis=1)

    def scale_input_weights(self, p, s_eq, cfg: ModelConfig):
        p = dict(p)
        p["w_in"] = p["w_in"] * s_eq[:, None]
        return p

    def forward_with_taps(self, p, x: Pair, ctx: TapContext, tap: TapFn) -> Pair:
        cfg = ctx.cfg
        proj = both(lambda t: t + p["b"], tap("w_in", x))
        h = both(
            lambda pr: slstm_scan(p, pr, cfg)[0].astype(x[1].dtype), proj
        )
        hn = both(lambda hs: slstm_headnorm(p, hs, cfg), h)
        mid = both(jax.nn.gelu, tap("up", hn))
        return tap("down", mid)
