"""Mamba-1 selective-SSM mixer adapter.

Quantizable sites — the four dense projections around the scan:

  * ``in_proj``  (d_model, 2*d_in): the x/z up-projection
  * ``x_proj``   (d_in, dt_rank + 2N): input-dependent (dt, B, C) heads
  * ``dt_proj``  (dt_rank, d_in): the low-rank dt expansion
  * ``out_proj`` (d_in, d_model): output projection (carries the corrected
    bias at runtime, like the dense family's ``wo``)

High-precision (mirroring the paper's §C.1 exclusions): the depthwise causal
conv, SiLU gates, softplus(dt), the A/D state parameters and the selective
scan itself (a data-dependent recurrence, not a static-weight MAC reduction
— AXE's certificate machinery does not apply to it).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.ssm import _causal_conv, selective_scan

from .base import BlockAdapter, Pair, SiteSpec, TapContext, TapFn, both


class MambaAdapter(BlockAdapter):
    kind = "mixer"
    name = "mamba"

    def enumerate_sites(self, cfg: ModelConfig) -> tuple[SiteSpec, ...]:
        s = cfg.ssm
        d = cfg.d_model
        d_in = s.expand * d
        dtr = cfg.dt_rank
        return (
            SiteSpec("in_proj", ("in_proj",), d, 2 * d_in),
            SiteSpec("x_proj", ("x_proj",), d_in, dtr + 2 * s.d_state),
            SiteSpec("dt_proj", ("dt_proj",), dtr, d_in),
            SiteSpec("out_proj", ("out_proj",), d_in, d, use_bias=True),
        )

    def input_weight_absmax(self, p, cfg: ModelConfig):
        return jnp.max(jnp.abs(p["in_proj"]), axis=1)

    def scale_input_weights(self, p, s_eq, cfg: ModelConfig):
        p = dict(p)
        p["in_proj"] = p["in_proj"] * s_eq[:, None]
        return p

    def forward_with_taps(self, p, x: Pair, ctx: TapContext, tap: TapFn) -> Pair:
        cfg = ctx.cfg
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        dtr = cfg.dt_rank

        xz = tap("in_proj", x)
        xin_raw = both(lambda t: t[..., :d_in], xz)
        z = both(lambda t: t[..., d_in:], xz)
        xin = both(
            lambda t: jax.nn.silu(
                _causal_conv(t, p["conv_w"], p["conv_b"])[0]
            ),
            xin_raw,
        )

        proj = tap("x_proj", xin)
        dt_r = both(lambda t: t[..., :dtr], proj)
        b_ssm = both(lambda t: t[..., dtr : dtr + s.d_state], proj)
        c_ssm = both(lambda t: t[..., dtr + s.d_state :], proj)
        dt = both(
            lambda t: jax.nn.softplus(t + p["dt_bias"]), tap("dt_proj", dt_r)
        )

        a = -jnp.exp(p["A_log"].astype(jnp.float32))
        y = both(
            lambda xi, d_, b_, c_: selective_scan(
                xi, d_, b_, c_, a, p["D"], s.d_state
            ),
            xin, dt, b_ssm, c_ssm,
        )
        gated = both(lambda ys, zs: ys.astype(zs.dtype) * jax.nn.silu(zs), y, z)
        return tap("out_proj", gated)
