"""Packed-int4 serving parameters (the §Perf-3 / beyond-paper decode path).

``pack_decode_params`` transforms a model's layer weights into
{"packed": (..., K/2, N) int8, "scale": (..., 1, N)} leaves; the model
layers dequantize transparently via ``resolve_weight``. Decode at large
batch is weight-traffic-bound, so int4 packing cuts the dominant HBM term
~4x vs bf16 (the paper's W4A8 + AXE certificate is what makes the
low-precision *accumulation* of this datapath safe — see
repro.kernels.w4a8_mm for the true-integer TPU kernel).

Which leaves get packed is *not* hardcoded: the quantizable-site registry
(:mod:`repro.quant.families`) enumerates every family's sites from the
model config alone, so dense, MoE (expert-stacked), Mamba and xLSTM stacks
— and hybrids like Jamba — all pack through the same transform. Sites
whose reduction depth K is odd (e.g. an odd Mamba dt_rank) are left in
high precision rather than padded.

Works under ``jax.eval_shape`` (all ops traceable), so the 405B dry-run can
lower the quantized decode graph without materializing weights. For real
deployments the packed codes come from the AXE pipeline
(repro.launch.quantize); the RTN packing here is the shape-compatible
fallback used when no calibrated artifact is supplied.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.w4a8_mm import pack_int4
from repro.models.config import ModelConfig

from .families import check_supported, get_adapter


def packable_sites(cfg: ModelConfig):
    """Per pattern slot: {"mixer": (SiteSpec...), "ffn": (SiteSpec...)} of
    sites with an even (packable) reduction depth."""
    slots = []
    for spec in cfg.pattern:
        slot = {}
        for kind, name in (("mixer", spec.mixer), ("ffn", spec.ffn)):
            if name == "none":
                slot[kind] = ()
                continue
            sites = get_adapter(kind, name).enumerate_sites(cfg)
            slot[kind] = tuple(s for s in sites if s.k % 2 == 0)
        slots.append(slot)
    return slots


def _pack_leaf(w: jax.Array) -> dict:
    """(..., K, N) -> packed int4 + per-channel scale (stack-aware: leading
    repeat/expert axes pass straight through). ``col_sums`` is the
    per-channel sum of int4 codes over K, precomputed here once so the
    decode kernel's zero-point correction never needs a full
    ``unpack_int4`` of the weights at serving time (repro.kernels.w4a8_mm
    epilogue: corr[n] = act_zp * col_sums[n])."""
    scale = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2, keepdims=True) / 7.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.rint(w.astype(jnp.float32) / scale), -7, 7)
    return {
        "packed": pack_int4(q),
        "scale": scale.astype(jnp.bfloat16),
        "col_sums": jnp.sum(q, axis=-2, keepdims=True).astype(jnp.int32),
    }


def pack_decode_params(params, cfg: ModelConfig):
    """Replace every registered quantizable-site weight with its packed
    artifact. Raises NotImplementedError (listing the registry) when the
    pattern contains a component with no family adapter."""
    check_supported(cfg)
    new_layers = []
    for slot_params, slot_sites in zip(params["layers"], packable_sites(cfg)):
        new_slot = dict(slot_params)
        for kind in ("mixer", "ffn"):
            if kind not in new_slot:
                continue
            packable = {s.path[-1] for s in slot_sites[kind]}
            new_slot[kind] = {
                k: (_pack_leaf(v) if k in packable else v)
                for k, v in slot_params[kind].items()
            }
        new_layers.append(new_slot)
    return {
        "embedding": params["embedding"],
        "layers": tuple(new_layers),
        "final_norm": params["final_norm"],
    }


def ensure_col_sums(params):
    """Fill the pack-time ``col_sums`` term into packed leaves that predate
    it (artifacts packed before the decode-kernel PR). One full unpack per
    leaf, once, outside any trace — the alternative (the in-graph fallback
    in ``packed_linear``) re-reads the whole weight on every decode step.
    Float trees pass through untouched."""
    from repro.kernels.w4a8_mm import unpack_int4

    def fix(node):
        if isinstance(node, dict):
            if "packed" in node and "col_sums" not in node:
                col = jnp.sum(
                    unpack_int4(node["packed"]).astype(jnp.int32),
                    axis=-2, keepdims=True,
                )
                return {**node, "col_sums": col}
            return {k: fix(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(fix(v) for v in node)
        return node

    return fix(params)


def packed_weight_bytes(cfg: ModelConfig) -> dict:
    """Analytic per-step weight traffic for the roofline correction:
    bf16 baseline vs fused-dequant packed int4 (what the w4a8_mm kernel
    realizes on TPU — the in-graph dequant here would otherwise be charged
    at unfused bf16 rates by the HLO byte parser). Site-enumeration-driven,
    so MoE/SSM/xLSTM stacks are counted too."""
    per_pattern = 0
    for slot in packable_sites(cfg):
        for kind in ("mixer", "ffn"):
            per_pattern += sum(s.k * s.c * (s.stacked or 1) for s in slot[kind])
    elems = per_pattern * cfg.repeats
    return {
        "weight_elems": elems,
        "bf16_bytes": 2 * elems,
        "packed_bytes": elems // 2,
    }
