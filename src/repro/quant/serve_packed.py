"""Packed-int4 serving parameters (the §Perf-3 / beyond-paper decode path).

``pack_decode_params`` transforms a model's layer weights into packed
artifact leaves; the model layers dequantize transparently via
``resolve_weight`` or ride the fused W4A8 kernel via ``packed_linear``.
Decode at large batch is weight-traffic-bound, so int4 packing cuts the
dominant HBM term ~4x vs bf16 (the paper's W4A8 + AXE certificate is what
makes the low-precision *accumulation* of this datapath safe — see
repro.kernels.w4a8_mm for the true-integer TPU kernel).

Every packed leaf embeds the :class:`~repro.quant.spec.DatapathSpec` it was
packed for — tile T, inner/outer accumulator widths, activation-quantizer
kind — as a static ``spec`` node plus a persistable ``spec_arr`` array
twin, and (for calibrated artifacts) the static activation quantizer as
``act_scale``/``act_zp`` leaves. The kernel dispatch reads all of its
accumulator knobs from the spec; nothing is re-declared as kwargs
downstream. See docs/datapath.md for the schema and version history.

Which leaves get packed is *not* hardcoded: the quantizable-site registry
(:mod:`repro.quant.families`) enumerates every family's sites from the
model config alone, so dense, MoE (expert-stacked), Mamba and xLSTM stacks
— and hybrids like Jamba — all pack through the same transform. Sites
whose reduction depth K is odd (e.g. an odd Mamba dt_rank) are left in
high precision rather than padded.

Works under ``jax.eval_shape`` (all ops traceable), so the 405B dry-run can
lower the quantized decode graph without materializing weights. For real
deployments the packed codes come from the AXE pipeline
(:func:`serving_params_from_quantized` in memory, or
``repro.launch.quantize`` -> :func:`packed_params_from_artifact` via disk);
the RTN packing in ``pack_decode_params`` is the shape-compatible fallback
used when no calibrated artifact is supplied.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.w4a8_mm import (
    compress_2to4,
    pack_int4,
    unpack_int4,
    unpack_sparse24,
)
from repro.models.config import ModelConfig

from .families import SiteSpec, check_supported, get_adapter
from .spec import (
    ARTIFACT_VERSION,
    _SPEC_ARR_LEN,
    DatapathMismatchError,
    DatapathSpec,
    is_packed_leaf,
    leaf_datapath,
)

__all__ = [
    "ensure_col_sums",
    "ensure_datapath_spec",
    "export_quantized_artifact",
    "load_flat_artifact",
    "pack_decode_params",
    "packable_sites",
    "packed_params_from_artifact",
    "packed_weight_bytes",
    "plan_expected_specs",
    "serving_params_from_quantized",
    "upgrade_packed_params",
]


def packable_sites(cfg: ModelConfig):
    """Per pattern slot: {"mixer": (SiteSpec...), "ffn": (SiteSpec...)} of
    sites with an even (packable) reduction depth."""
    slots = []
    for spec in cfg.pattern:
        slot = {}
        for kind, name in (("mixer", spec.mixer), ("ffn", spec.ffn)):
            if name == "none":
                slot[kind] = ()
                continue
            sites = get_adapter(kind, name).enumerate_sites(cfg)
            slot[kind] = tuple(s for s in sites if s.k % 2 == 0)
        slots.append(slot)
    return slots


def _spec_arr_leaf(spec: DatapathSpec, lead: tuple[int, ...]) -> jax.Array:
    """The persistable array twin of the static spec node, broadcast over
    the leaf's leading stack axes (repeats / experts). Stored f32 — every
    field is a small integer or an activation scale, and f32 keeps the
    leaf dtype independent of the jax x64 flag."""
    arr = jnp.asarray(spec.to_array(), jnp.float32)
    return jnp.broadcast_to(arr, (*lead, arr.shape[0]))


def _rtn_codes(w: jax.Array, w_bits: int) -> tuple[jax.Array, jax.Array]:
    """Round-to-nearest integer codes + per-channel scales for a
    (..., K, N) weight, via the same symmetric alphabet/quantizer the
    calibration path uses (repro.core.quantizers) — stack-aware (the
    channel reduction runs over axis -2) and with the serving-side 1e-8
    scale floor."""
    from repro.core.alphabet import weight_alphabet
    from repro.core.quantizers import quantize_int, weight_scales

    alpha = weight_alphabet(w_bits)
    scale = weight_scales(w.astype(jnp.float32), alpha, axis=-2, eps=1e-8)
    return quantize_int(w.astype(jnp.float32) / scale, alpha), scale


def _pack_leaf(w: jax.Array, spec: DatapathSpec | None = None) -> dict:
    """(..., K, N) -> packed int4 + per-channel scale (stack-aware: leading
    repeat/expert axes pass straight through). ``col_sums`` is the
    per-channel sum of int4 codes over K, precomputed here once so the
    decode kernel's zero-point correction never needs a full
    ``unpack_int4`` of the weights at serving time (repro.kernels.w4a8_mm
    epilogue: corr[n] = act_zp * col_sums[n]). The leaf embeds ``spec``
    (static node + ``spec_arr`` array twin); RTN packing never ships
    static activation quantizers — those come from calibration
    (:func:`serving_params_from_quantized`) — so ``static_act`` is cleared
    here: the embedded record must describe the datapath this leaf
    actually serves, not the one the caller wished for."""
    from dataclasses import replace

    spec = replace((spec or DatapathSpec()).leaf_spec(), static_act=False)
    if spec.w_bits > 4:
        # pack_int4 would mask codes to 4 bits and silently corrupt the
        # weights — callers must keep such sites in high precision
        # (pack_decode_params / _site_rec_leaf fall back to a dequantized
        # float leaf)
        raise ValueError(
            f"int4 packing supports w_bits <= 4, got {spec.w_bits}; "
            f"serve this site as a high-precision leaf instead"
        )
    q, scale = _rtn_codes(w, spec.w_bits)
    lead = w.shape[:-2]
    if spec.sparsity is not None:
        # mask-then-round 2:4 baseline (no error feedback — calibrated
        # sparse codes come from the AXE pipeline's mask-aware GPFQ/OPTQ).
        # Traceable, so eval_shape dry-runs still lower the sparse graph.
        from repro.core.sparsity import mask_2to4

        if w.shape[-2] % 4 != 0:
            raise ValueError(
                f"2:4 sparsity needs K % 4 == 0, got K={w.shape[-2]}; "
                f"serve this site dense or in high precision instead"
            )
        q = q * mask_2to4(q)
        packed, meta = compress_2to4(q)
        return {
            "packed": packed,
            "meta": meta,
            "scale": scale.astype(jnp.bfloat16),
            "col_sums": jnp.sum(q, axis=-2, keepdims=True).astype(jnp.int32),
            "spec": spec,
            "spec_arr": _spec_arr_leaf(spec, lead),
        }
    return {
        "packed": pack_int4(q),
        "scale": scale.astype(jnp.bfloat16),
        "col_sums": jnp.sum(q, axis=-2, keepdims=True).astype(jnp.int32),
        "spec": spec,
        "spec_arr": _spec_arr_leaf(spec, lead),
    }


def pack_decode_params(params, cfg: ModelConfig, ptq=None):
    """Replace every registered quantizable-site weight with its packed
    artifact (RTN codes — the shape-compatible fallback when no calibrated
    artifact is supplied). ``ptq`` (a :class:`~repro.core.PTQConfig` or a
    base :class:`~repro.quant.spec.DatapathSpec`) selects the datapath each
    leaf is stamped with, specialized per site depth via
    ``SiteSpec.datapath_for``; default is the recipe datapath. Raises
    NotImplementedError (listing the registry) when the pattern contains a
    component with no family adapter."""
    check_supported(cfg)
    new_layers = []
    for slot_params, slot_sites in zip(params["layers"], packable_sites(cfg)):
        new_slot = dict(slot_params)
        for kind in ("mixer", "ffn"):
            if kind not in new_slot:
                continue
            by_name = {s.path[-1]: s for s in slot_sites[kind]}

            def leaf_for(k, v):
                if k not in by_name:
                    return v
                site = by_name[k]
                spec = (site.datapath_for(ptq) if ptq is not None
                        else site.datapath) or DatapathSpec()
                if spec.sparsity is not None and site.k % 4 != 0:
                    # 2:4 groups need K % 4 == 0; Eq. 22 halves depth and
                    # tile together, so stripping sparsity leaves p_outer
                    # valid for the dense codes this site actually serves
                    from dataclasses import replace

                    spec = replace(spec, sparsity=None)
                if spec.w_bits > 4:
                    # no int4 container for these codes: serve the site as
                    # an RTN-dequantized high-precision leaf instead
                    q, s = _rtn_codes(v, spec.w_bits)
                    return (q * s).astype(v.dtype)
                return _pack_leaf(v, spec)

            new_slot[kind] = {
                k: leaf_for(k, v) for k, v in slot_params[kind].items()
            }
        new_layers.append(new_slot)
    return {
        "embedding": params["embedding"],
        "layers": tuple(new_layers),
        "final_norm": params["final_norm"],
    }


# ---------------------------------------------------------------------------
# Calibrated artifacts: QuantizedModel -> serving tree, and the disk format
# ---------------------------------------------------------------------------
def _site_rec_leaf(recs: list[dict], site: SiteSpec, name: str):
    """Stack per-repeat site records into one serving leaf.

    Each record: {"q": (…, K, C) int8-valued codes, "scale": (…, 1, C),
    "spec": DatapathSpec (with act numerics), "bias": optional}. Returns a
    packed leaf dict, or a plain dequantized float array when the site
    cannot ride the int4 datapath (w_bits > 4 / odd K).
    """
    spec0 = recs[0]["spec"]
    for r, rec in enumerate(recs):
        if not spec0.matches(rec["spec"]):
            raise DatapathMismatchError(
                f"site {name}: repeat 0 certified {spec0.describe()} but "
                f"repeat {r} certified {rec['spec'].describe()} — one leaf "
                f"cannot serve two datapaths"
            )
    if spec0.w_bits > 4 or site.k % 2 != 0 or (
        spec0.sparsity is not None and site.k % 4 != 0
    ):
        # no int4 container (wide codes / odd K): serve the dequantized
        # weight in high precision. The corrected bias is part of the
        # certified function, so it rides along in a {"w", "bias"} leaf
        # (repro.models.layers.pmm dispatches it) instead of being dropped.
        w_q = jnp.stack(
            [jnp.asarray(r["q"], jnp.float32) * jnp.asarray(r["scale"], jnp.float32)
             for r in recs]
        )
        if site.use_bias and recs[0].get("bias") is not None:
            return {
                "w": w_q,
                "bias": jnp.stack(
                    [jnp.asarray(r["bias"], jnp.float32) for r in recs]
                ),
            }
        return w_q
    lead = (len(recs),) + ((site.stacked,) if site.stacked else ())
    q = jnp.stack([jnp.asarray(r["q"], jnp.float32) for r in recs])
    if spec0.sparsity is not None:
        # the certificate was issued against the 2:4 effective depth —
        # codes that are not actually 2:4 would be served under a bound
        # they do not satisfy, so refuse loudly at pack/load time
        from repro.core.sparsity import is_2to4

        if not is_2to4(np.asarray(q)):
            raise DatapathMismatchError(
                f"site {name}: certified sparsity={spec0.sparsity!r} but the "
                f"codes are not 2:4 (some group of 4 along K has more than "
                f"2 nonzeros) — the certificate's effective-depth bound "
                f"would not hold for these weights"
            )
        packed_codes, meta = compress_2to4(q)
    else:
        packed_codes, meta = pack_int4(q), None
    leaf = {
        "packed": packed_codes,
        "scale": jnp.stack([jnp.asarray(r["scale"], jnp.float32) for r in recs]),
        "col_sums": jnp.sum(q, axis=-2, keepdims=True).astype(jnp.int32),
        "spec": spec0.leaf_spec(),
        "spec_arr": jnp.stack(
            [
                jnp.broadcast_to(
                    arr := jnp.asarray(r["spec"].to_array(), jnp.float32),
                    (*lead[1:], arr.shape[0]),
                )
                for r in recs
            ]
        ),
    }
    if meta is not None:
        leaf["meta"] = meta
    if spec0.static_act:
        # stacked scales: one scalar per repeat, broadcast per expert for
        # MoE stacks so the vmapped kernel maps a per-expert quantizer
        leaf["act_scale"] = jnp.stack(
            [jnp.full(lead[1:], r["spec"].act_scale, jnp.float32) for r in recs]
        )
        leaf["act_zp"] = jnp.stack(
            [jnp.full(lead[1:], float(r["spec"].act_zp), jnp.float32) for r in recs]
        )
    if site.use_bias and recs[0].get("bias") is not None:
        leaf["bias"] = jnp.stack(
            [jnp.asarray(r["bias"], jnp.float32) for r in recs]
        )
    return leaf


def _set_path(tree: dict, path: tuple[str, ...], value) -> None:
    d = tree
    for key in path[:-1]:
        d[key] = dict(d[key])
        d = d[key]
    d[path[-1]] = value


def serving_params_from_quantized(qm) -> dict:
    """Build the packed serving tree straight from a calibrated
    :class:`~repro.quant.QuantizedModel` — codes, per-channel scales,
    *static* activation quantizers, corrected biases and the per-site
    :class:`~repro.quant.spec.DatapathSpec`, with no kwarg re-specification
    anywhere downstream. Float leaves (norms — equalization-folded —
    routers, conv/SSM parameters) come from the quantized model too, so
    the tree is faithful to what calibration certified."""
    cfg = qm.cfg
    new_layers = []
    for s in range(cfg.period):
        blocks = [qm.blocks[r * cfg.period + s] for r in range(cfg.repeats)]
        slot: dict = {}
        for norm_name in ("norm1", "norm2"):
            norms = [getattr(b, norm_name) for b in blocks]
            if norms[0] is not None:
                slot[norm_name] = {
                    k: jnp.stack([jnp.asarray(n[k]) for n in norms])
                    for k in norms[0]
                }
        for kind in ("mixer", "ffn"):
            comps = [getattr(b, kind) for b in blocks]
            if comps[0] is None:
                continue
            out = {
                k: jnp.stack([jnp.asarray(c.params[k]) for c in comps])
                for k, v in comps[0].params.items()
                if v is not None
            }
            for site in comps[0].specs.values():
                recs = [
                    {
                        "q": c.linears[site.name].q_int,
                        "scale": c.linears[site.name].scale,
                        "spec": c.linears[site.name].spec,
                        "bias": c.linears[site.name].bias,
                    }
                    for c in comps
                ]
                _set_path(out, site.path,
                          _site_rec_leaf(recs, site, f"slot{s}/{kind}.{site.name}"))
            slot[kind] = out
        new_layers.append(slot)
    return {
        "embedding": qm.embedding,
        "layers": tuple(new_layers),
        "final_norm": qm.final_norm,
    }


def export_quantized_artifact(qm) -> tuple[dict, dict]:
    """Flatten a calibrated QuantizedModel into the versioned on-disk
    artifact: {"layer{i}/{kind}.{site}/{q,scale,bias,spec}"} numpy leaves
    plus the equalization-touched float leaves (norms, MoE routers), and a
    meta dict carrying the schema version. Codes are stored raw int8
    (packing happens at load, where the serving layout is known)."""
    artifact: dict[str, np.ndarray] = {}
    site_specs = []
    for name, ql in qm.quantized_linears():
        artifact[f"{name}/q"] = np.asarray(ql.q_int, np.int8)
        artifact[f"{name}/scale"] = np.asarray(ql.scale, np.float32)
        if ql.bias is not None:
            artifact[f"{name}/bias"] = np.asarray(ql.bias, np.float32)
        spec = ql.spec if ql.spec is not None else ql.cfg.to_datapath_spec(
            ql.q_int.shape[-2], ql.act
        )
        artifact[f"{name}/spec"] = spec.to_array()
        site_specs.append(spec)
    site_keys = {s.key() for s in site_specs}
    for i, b in enumerate(qm.blocks):
        for norm_name in ("norm1", "norm2"):
            nrm = getattr(b, norm_name)
            if nrm is not None:
                for k, v in nrm.items():
                    artifact[f"layer{i}/{norm_name}/{k}"] = np.asarray(v)
        # the MoE router consumes the equalized input: its folded weights
        # must travel with the artifact or routing diverges at serving
        if b.ffn is not None and b.ffn.params.get("router") is not None:
            artifact[f"layer{i}/ffn.float/router"] = np.asarray(
                b.ffn.params["router"]
            )
    meta = {
        "artifact_version": ARTIFACT_VERSION,
        "arch": qm.cfg.name,
        "n_layers": qm.cfg.n_layers,
        # heterogeneous per-site datapaths: the loader switches to strict
        # site accounting (a dropped site would silently change which
        # datapath serves — satellite of the mixed-precision search)
        "mixed_precision": len(site_keys) > 1,
        "datapath": (
            site_specs[0].describe() if len(site_keys) == 1
            else f"mixed: {len(site_keys)} site datapaths"
        ) if site_specs else "empty",
    }
    return artifact, meta


def load_flat_artifact(directory: str) -> tuple[dict, dict]:
    """Template-free load of a flat artifact directory written by
    ``repro.checkpoint.save_pytree`` on a flat dict: parse the manifest
    directly instead of requiring a matching target pytree."""
    import json
    import os

    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {}
    for entry in manifest["leaves"]:
        name = entry["name"]
        # keystr of a flat string key: "['layer0/mixer.wq/q']"
        if name.startswith("['") and name.endswith("']"):
            name = name[2:-2]
        flat[name] = np.load(os.path.join(directory, entry["file"]))
    return flat, manifest.get("meta", {})


def packed_params_from_artifact(flat: dict, params, cfg: ModelConfig,
                                meta: dict | None = None,
                                strict: bool | None = None):
    """Rebuild the packed serving tree from a saved AXE artifact.

    ``params`` supplies the high-precision leaves the artifact does not
    carry (embedding, non-equalized component floats); quantized sites,
    norms and routers are overridden from the artifact. Validates the
    artifact schema version loudly — a mismatched or unversioned artifact
    raises :class:`~repro.quant.spec.DatapathMismatchError` instead of
    being served with guessed semantics.

    ``strict`` (default: the artifact meta's ``mixed_precision`` flag)
    refuses *partial* coverage: a site the model enumerates but the
    artifact does not carry raises instead of silently staying float.
    Quantized artifact keys that match **no** enumerated site always
    raise — the artifact and the model disagree about what the model is.
    """
    if meta is not None:
        v = meta.get("artifact_version")
        if v != ARTIFACT_VERSION:
            raise DatapathMismatchError(
                f"artifact schema version {v!r} != supported "
                f"{ARTIFACT_VERSION}; re-export with repro.launch.quantize "
                f"(see docs/datapath.md for the version history)"
            )
        for field, want in (("arch", cfg.name), ("n_layers", cfg.n_layers)):
            got = meta.get(field)
            if got is not None and got != want:
                raise DatapathMismatchError(
                    f"artifact was exported for {field}={got!r} but the "
                    f"serving config is {field}={want!r} — an arch-"
                    f"mismatched artifact would silently serve float "
                    f"weights instead of the certified codes"
                )
    check_supported(cfg)
    if strict is None:
        strict = bool(meta and meta.get("mixed_precision"))
    n_sites_loaded = 0
    consumed: set[str] = set()
    missing: list[str] = []
    new_layers = []
    for s, pattern_spec in enumerate(cfg.pattern):
        slot = dict(params["layers"][s])
        layer_ids = [r * cfg.period + s for r in range(cfg.repeats)]
        for norm_name in ("norm1", "norm2"):
            key0 = f"layer{layer_ids[0]}/{norm_name}/w"
            if key0 in flat and norm_name in slot:
                slot[norm_name] = {
                    k: jnp.stack([
                        jnp.asarray(flat[f"layer{i}/{norm_name}/{k}"])
                        for i in layer_ids
                    ])
                    for k in slot[norm_name]
                }
        for kind, fam in (("mixer", pattern_spec.mixer), ("ffn", pattern_spec.ffn)):
            if fam == "none" or kind not in slot:
                continue
            out = dict(slot[kind])
            if f"layer{layer_ids[0]}/ffn.float/router" in flat and kind == "ffn":
                out["router"] = jnp.stack([
                    jnp.asarray(flat[f"layer{i}/ffn.float/router"])
                    for i in layer_ids
                ])
            for site in get_adapter(kind, fam).enumerate_sites(cfg):
                names = [f"layer{i}/{kind}.{site.name}" for i in layer_ids]
                present = [n for n in names if f"{n}/q" in flat]
                consumed.update(f"{n}/q" for n in present)
                if len(present) != len(names):
                    # all-or-nothing per slot: a partially covered slot can
                    # never stack one leaf, and silent float fallback is
                    # exactly what strict loading forbids
                    if present or strict:
                        missing.append(
                            f"slot{s}/{kind}.{site.name} (have "
                            f"{len(present)}/{len(names)} repeats)")
                    continue
                recs = [
                    {
                        "q": flat[f"{n}/q"],
                        "scale": flat[f"{n}/scale"],
                        "spec": DatapathSpec.from_array(flat[f"{n}/spec"]),
                        "bias": flat.get(f"{n}/bias"),
                    }
                    for n in names
                ]
                _set_path(out, site.path, _site_rec_leaf(recs, site, names[0]))
                n_sites_loaded += 1
            slot[kind] = out
        new_layers.append(slot)
    if missing:
        raise DatapathMismatchError(
            f"artifact does not cover {len(missing)} site(s) the model "
            f"enumerates: {missing} — refusing the silent float fallback "
            f"(strict={strict}; pass strict=False only for deliberately "
            f"partial uniform artifacts)"
        )
    unknown = sorted(
        k for k in flat if k.endswith("/q") and k not in consumed)
    if unknown:
        raise DatapathMismatchError(
            f"artifact carries quantized sites this model does not "
            f"enumerate: {unknown} — the artifact and the serving config "
            f"disagree about the model's site set"
        )
    if n_sites_loaded == 0:
        raise DatapathMismatchError(
            "no quantized site in the artifact matched this model config — "
            "refusing to silently serve the float weights (wrong --arch, "
            "or an empty/foreign artifact directory?)"
        )
    return {
        "embedding": params["embedding"],
        "layers": tuple(new_layers),
        "final_norm": params["final_norm"],
    }


def plan_expected_specs(cfg: ModelConfig, plan, base: DatapathSpec) -> dict:
    """Total ``site-key -> DatapathSpec`` map for
    :func:`repro.quant.spec.validate_datapath`: every *packed* site the
    model enumerates, valued by the mixed-precision plan's override when
    present, else the uniform ``base``. Sites that cannot ride the int4
    container (w_bits > 4 — e.g. plan-promoted w8 sites — or an odd
    reduction depth) serve dequantized float leaves, not packed ones, and
    are excluded, mirroring ``_site_rec_leaf``. A plan key naming a site
    the model does not enumerate raises here, before anything serves."""
    expected: dict[str, DatapathSpec] = {}
    known: set[str] = set()
    plan = plan if plan is not None else {}
    for s, pattern_spec in enumerate(cfg.pattern):
        for kind, fam in (("mixer", pattern_spec.mixer),
                          ("ffn", pattern_spec.ffn)):
            if fam == "none":
                continue
            for site in get_adapter(kind, fam).enumerate_sites(cfg):
                key = f"slot{s}/{kind}.{site.name}"
                known.add(key)
                spec = plan.get(key)
                spec = base if spec is None else spec
                if spec.w_bits > 4 or site.k % 2 != 0 or (
                    spec.sparsity is not None and site.k % 4 != 0
                ):
                    continue
                expected[key] = spec
    unknown = sorted(set(plan) - known)
    if unknown:
        raise DatapathMismatchError(
            f"mixed-precision plan names sites this model does not "
            f"enumerate: {unknown}; model sites: {sorted(known)}")
    return expected


# ---------------------------------------------------------------------------
# Legacy-artifact upgrade shims (one-time, outside any trace)
# ---------------------------------------------------------------------------
def ensure_col_sums(params):
    """Fill the pack-time ``col_sums`` term into packed leaves that predate
    it (artifacts packed before the decode-kernel PR). One full unpack per
    leaf, once, outside any trace — the alternative (the in-graph fallback
    in ``packed_linear``) re-reads the whole weight on every decode step.
    Float trees pass through untouched."""

    def fix(node):
        if isinstance(node, dict):
            if "packed" in node and "col_sums" not in node:
                if "meta" in node:  # 2:4 sparse leaf: expand via the gather
                    q = unpack_sparse24(node["packed"], node["meta"])
                else:
                    q = unpack_int4(node["packed"])
                col = jnp.sum(
                    q.astype(jnp.int32), axis=-2, keepdims=True,
                )
                return {**node, "col_sums": col}
            return {k: fix(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(fix(v) for v in node)
        return node

    return fix(params)


def ensure_datapath_spec(params, default: DatapathSpec | None = None):
    """Attach a :class:`DatapathSpec` to packed leaves that predate the
    spec schema: decoded from the leaf's ``spec_arr`` array twin when one
    survived an array-only round trip, else ``default`` (the recipe
    datapath, stamped with the legacy schema version so the upgrade is
    visible). Runs once, outside any trace; complete leaves pass through
    with their spec object untouched."""

    def fix(node):
        if isinstance(node, dict):
            if is_packed_leaf(node) and "spec" not in node:
                spec = leaf_datapath(node)  # decodes spec_arr when present
                if spec is not None:
                    # the array twin is authoritative (it may carry
                    # per-repeat act numerics); only the static node is
                    # rebuilt, in its numerics-free leaf form so the
                    # treedef matches a natively packed leaf
                    return {**node, "spec": spec.leaf_spec()}
                from dataclasses import replace

                spec = replace(
                    (default or DatapathSpec()).leaf_spec(),
                    version=1 if "col_sums" in node else 0,
                )
                lead = node["packed"].shape[:-2]
                return {**node, "spec": spec,
                        "spec_arr": _spec_arr_leaf(spec, lead)}
            return {k: fix(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(fix(v) for v in node)
        return node

    return fix(params)


def upgrade_packed_params(params, default: DatapathSpec | None = None):
    """The full legacy-artifact upgrade: ``ensure_datapath_spec`` +
    ``ensure_col_sums``. The spec shim runs first so the stamped legacy
    version reflects the schema the leaf actually arrived with (a
    pre-col_sums leaf is v0, not "v1 because the other shim already ran").
    Idempotent on complete artifacts (leaf arrays and spec nodes pass
    through by identity)."""
    return ensure_col_sums(ensure_datapath_spec(params, default))


# ---------------------------------------------------------------------------
# Byte accounting
# ---------------------------------------------------------------------------
def packed_weight_bytes(cfg: ModelConfig, *, scale_bytes_per: int = 2,
                        static_act: bool = False,
                        with_bias: bool = False,
                        sparsity: str | None = None) -> dict:
    """Analytic per-step artifact traffic for the roofline correction:
    bf16 baseline vs the full packed artifact (codes + per-channel scale +
    ``col_sums`` zero-point term + spec twin + optional static-act and
    bias leaves). Site-enumeration-driven, so MoE/SSM/xLSTM stacks are
    counted too. Defaults describe the RTN ``pack_decode_params`` tree
    (bf16 scales, dynamic act, no bias); calibrated trees
    (:func:`serving_params_from_quantized`) use f32 scales, static act and
    biases on the output projections.

    ``sparsity="2:4"`` counts the compressed layout for every eligible
    site (K % 4 == 0): K/4 code bytes (the 2 kept int4 codes per group
    packed into one byte) plus K/4 metadata bytes (2-bit index pairs).
    At int4 the total weight stream matches dense (codes halve, metadata
    takes the other half) — the compressed layout's win is the halved
    *effective accumulation depth* (docs/datapath.md), not bytes.
    Ineligible sites are counted dense."""
    elems = code = scale = col = spec_b = act = bias = meta_b = 0
    for slot in packable_sites(cfg):
        for kind in ("mixer", "ffn"):
            for s in slot[kind]:
                st = s.stacked or 1
                elems += s.k * s.c * st
                if sparsity is not None and s.k % 4 == 0:
                    code += s.k * s.c * st // 4  # 2 kept codes per group
                    meta_b += s.k * s.c * st // 4  # int8 index pair per group
                else:
                    code += s.k * s.c * st // 2  # int8 byte holds 2 codes
                scale += s.c * st * scale_bytes_per
                col += s.c * st * 4  # int32
                spec_b += st * _SPEC_ARR_LEN * 4  # f32 spec_arr twin
                if static_act:
                    act += st * (4 + 4)  # f32 act_scale + act_zp
                if with_bias and s.use_bias:
                    bias += s.c * st * 4
    r = cfg.repeats
    total = (code + meta_b + scale + col + spec_b + act + bias) * r
    return {
        "weight_elems": elems * r,
        "bf16_bytes": 2 * elems * r,
        "packed_code_bytes": code * r,
        "meta_bytes": meta_b * r,
        "scale_bytes": scale * r,
        "col_sums_bytes": col * r,
        "spec_bytes": spec_b * r,
        "act_bytes": act * r,
        "bias_bytes": bias * r,
        "packed_bytes": total,
    }
