"""Packed-int4 serving parameters (the §Perf-3 / beyond-paper decode path).

``pack_decode_params`` transforms a dense (attn+mlp) model's layer weights
into {"packed": (K/2, N) int8, "scale": (1, N)} leaves; the model layers
dequantize transparently via ``resolve_weight``. Decode at large batch is
weight-traffic-bound, so int4 packing cuts the dominant HBM term ~4x vs
bf16 (the paper's W4A8 + AXE certificate is what makes the low-precision
*accumulation* of this datapath safe — see repro.kernels.w4a8_mm for the
true-integer TPU kernel).

Works under ``jax.eval_shape`` (all ops traceable), so the 405B dry-run can
lower the quantized decode graph without materializing weights. For real
deployments the packed codes come from the AXE pipeline
(repro.launch.quantize); the RTN packing here is the shape-compatible
fallback used when no calibrated artifact is supplied.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.w4a8_mm import pack_int4
from repro.models.config import ModelConfig

PACKABLE = ("wq", "wk", "wv", "wo", "wg", "wu", "wi", "wd")


def _pack_leaf(w: jax.Array) -> dict:
    """(..., K, N) -> packed int4 + per-channel scale (stacked-aware)."""
    scale = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2, keepdims=True) / 7.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.rint(w.astype(jnp.float32) / scale), -7, 7)
    if w.ndim == 2:
        packed = pack_int4(q)
    else:  # stacked over repeats: (R, K, N)
        packed = jax.vmap(pack_int4)(q)
    return {"packed": packed, "scale": scale.astype(jnp.bfloat16)}


def pack_decode_params(params, cfg: ModelConfig):
    """Replace every packable layer weight with its packed artifact."""
    for spec in cfg.pattern:
        if (spec.mixer, spec.ffn) != ("attn", "mlp"):
            raise NotImplementedError(
                "packed decode currently supports the dense attn+mlp family"
            )
    new_layers = []
    for slot in params["layers"]:
        new_slot = {"norm1": slot["norm1"], "norm2": slot["norm2"]}
        new_slot["mixer"] = {
            k: (_pack_leaf(v) if k in PACKABLE else v)
            for k, v in slot["mixer"].items()
        }
        new_slot["ffn"] = {
            k: (_pack_leaf(v) if k in PACKABLE else v)
            for k, v in slot["ffn"].items()
        }
        new_layers.append(new_slot)
    return {
        "embedding": params["embedding"],
        "layers": tuple(new_layers),
        "final_norm": params["final_norm"],
    }


def packed_weight_bytes(cfg: ModelConfig) -> dict:
    """Analytic per-step weight traffic for the roofline correction:
    bf16 baseline vs fused-dequant packed int4 (what the w4a8_mm kernel
    realizes on TPU — the in-graph dequant here would otherwise be charged
    at unfused bf16 rates by the HLO byte parser)."""
    d, hd, nh, nkv, f = (cfg.d_model, cfg.head_dim, cfg.n_heads,
                         cfg.n_kv_heads, cfg.d_ff)
    per_layer = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
    per_layer += 3 * d * f if cfg.act == "swiglu" else 2 * d * f
    elems = per_layer * cfg.n_layers
    return {
        "weight_elems": elems,
        "bf16_bytes": 2 * elems,
        "packed_bytes": elems // 2,
    }
