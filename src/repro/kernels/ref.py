"""Pure-jnp oracles for every Pallas kernel (the allclose targets of
tests/test_kernels.py)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def w4a8_matmul_ref(x_int8, w_packed, w_scale, act_scale, act_zp):
    """Dequantize-then-matmul in fp32 — exact integer semantics."""
    from .w4a8_mm import unpack_int4

    q = unpack_int4(w_packed).astype(jnp.int32)  # (K, N)
    x = x_int8.astype(jnp.int32)
    acc = (x @ q).astype(jnp.float32)
    corr = (jnp.sum(q, axis=0) * act_zp).astype(jnp.float32)
    return (acc - corr[None, :]) * act_scale * w_scale.astype(jnp.float32)[None, :]


def w4a8_tile_partials_ref(x_int8, w_packed, tile: int):
    """Per-K-tile int32 partial sums (the inner-accumulator watermark)."""
    from .w4a8_mm import unpack_int4

    q = unpack_int4(w_packed).astype(jnp.int32)
    x = x_int8.astype(jnp.int32)
    m, k = x.shape
    n = q.shape[1]
    nt = k // tile
    xt = x.reshape(m, nt, tile)
    qt = q.reshape(nt, tile, n)
    return jnp.einsum("mti,tin->mtn", xt, qt)  # (M, n_tiles, N)


def gpfq_solve_ref(w_int, xg, xh, *, w_bits, lam, budget_b, tile, rounding="nearest"):
    """Memory-efficient GPFQ loop (core implementation is the oracle)."""
    import jax.numpy as jnp

    from repro.core.gpfq import _gpfq_loop

    K, C = w_int.shape
    n_tiles = (K + tile - 1) // tile
    tile_ids = jnp.arange(K) // tile
    Q, _, _, _ = _gpfq_loop(
        w_int,
        xg,
        xh,
        jnp.broadcast_to(jnp.asarray(lam, jnp.float32), (n_tiles, C)),
        jnp.asarray(-budget_b, jnp.float32),
        jnp.asarray(budget_b, jnp.float32),
        tile_ids,
        jnp.zeros((n_tiles, C), jnp.float32),
        jnp.zeros((n_tiles, C), jnp.float32),
        jnp.ones((1, C), jnp.float32),  # dense: dummy support row
        w_bits=w_bits,
        w_signed=True,
        rounding=rounding,
        strict=True,
        mode="split",
        has_axe=True,
        has_mask=False,
    )
    return Q


def quant_rmsnorm_ref(x, gamma, act_scale, act_zp, *, eps=1e-6, bits=8):
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    y = xf * scale * gamma.astype(jnp.float32)
    q = jnp.rint(y / act_scale) + act_zp
    return jnp.clip(q, 0, 2**bits - 1).astype(jnp.uint8)
