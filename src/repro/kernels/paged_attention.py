"""Block-table-aware decode attention over a paged KV cache — Pallas TPU.

The serving engine stores KV state in fixed-size *pages* of ``block_size``
tokens drawn from one global pool per layer (see
``docs/serving_scheduler.md``); a per-sequence block table maps logical
position ``p`` to physical page ``table[b, p // block_size]``. Decode
attention therefore cannot stream the cache contiguously — it must chase
the block table. Two implementations share one contract:

* :func:`paged_attention_reference` — gather the sequence's pages into a
  dense ``(B, P*bs, nkv, hd)`` view and run exactly the math of
  ``repro.models.layers.attention_decode`` (same op order, same f32
  score path). This is the CPU/serving fallback AND the oracle: for a
  table whose capacity equals the dense engine's ``S_max`` it is
  bit-identical to the dense-slab path, which is what the engine golden
  tests pin.
* :func:`paged_decode_attention` — the Pallas kernel. Grid ``(B, P)``
  with the page axis sequential; the block table and sequence lengths
  ride in as *scalar prefetch* operands so each page's BlockSpec
  index_map can dereference ``table[b, j]`` before the body runs — HBM
  traffic per row is O(block-table width), not O(pool size). (Sentinel
  entries past a row's length clamp to page ``nb-1`` and are fetched
  then fully masked; skipping their DMA needs a per-row page-count grid
  — part of the TPU-hardware validation follow-up in the ROADMAP.)
  Scores accumulate via online
  softmax (running max / normalizer / weighted accumulator in VMEM
  scratch, exactly the ``_chunked_causal_attention`` recurrence), so
  kernel-vs-reference agreement is to float tolerance, not bitwise.

Both implementations additionally serve **int8 quantized KV pages**
(``kv_dtype=int8`` in ``transformer.init_paged_cache``): the pools hold
int8 codes plus per-(page, kv-head) symmetric scales
(:func:`quantize_kv_pages`), halving pool HBM. The reference dequantizes
per page and runs exactly the float math (the correctness anchor —
bit-identical to quantize→dequantize applied to the dense-slab math);
the kernel runs the *integer* datapath the
:class:`~repro.quant.spec.AttnDatapathSpec` record certifies: an
``hd``-deep int8×int8 QK^T dot held in a ``P_qk``-bit register and a
per-page ``block_size``-deep prob×value dot held in a ``P_pv``-bit
register, each page draining into the float online-softmax outer
accumulator (the attention analogue of Eq. 22's inner/outer split, with
the page as the tile). ``assert_bounds=True`` verifies the register
watermarks against the record in interpret mode, mirroring
``w4a8_mm``'s ``assert_inner``.

Validated against the reference in interpret mode over shape/raggedness
sweeps (``tests/test_paged_attention.py``) — the same testing pattern as
``w4a8_mm``. Compiled-mode perf is a TPU-hardware question (ROADMAP).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _softcap(scores, cap):
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


# ---------------------------------------------------------------------------
# int8 KV page quantization (per-page, per-kv-head symmetric scales)
# ---------------------------------------------------------------------------
def quantize_kv_pages(pages, kv_bits: int = 8):
    """Symmetric per-(page, kv-head) quantization of float KV pages.

    pages: (..., block_size, nkv, hd) float -> (codes int8 of the same
    shape, scales (..., nkv) f32). The scale is shared by every position
    and head-dim lane of a page (constant over the PV reduction — that is
    what keeps the per-page PV accumulation a pure integer dot, see
    :class:`~repro.quant.spec.AttnDatapathSpec`); never-written positions
    are zeros and cannot raise the max.
    """
    qmax = 2 ** (kv_bits - 1) - 1
    xf = pages.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=(-3, -1))  # reduce (block_size, hd)
    scales = jnp.maximum(amax / qmax, 1e-8)
    codes = jnp.clip(jnp.rint(xf / scales[..., None, :, None]), -qmax, qmax)
    return codes.astype(jnp.int8), scales


def quantize_kv_pages_static(pages, scales):
    """Quantize float KV pages under *calibrated static* per-kv-head scales
    (``scales``: broadcastable to the pages' (..., nkv) page-scale shape —
    see ``repro.quant.observe.kv``). Unlike :func:`quantize_kv_pages` no
    per-page max reduction runs: the scale is a constant, codes hard-clip
    at the int8 container limit (out-of-calibration drift saturates — the
    serving saturation counters measure it), and the returned scales leaf
    is just the broadcast stamp, so pool consumers are unchanged."""
    qmax = 127
    xf = pages.astype(jnp.float32)
    stamp = jnp.broadcast_to(scales, (*pages.shape[:-3], pages.shape[-2]))
    codes = jnp.clip(jnp.rint(xf / stamp[..., None, :, None]), -qmax, qmax)
    return codes.astype(jnp.int8), stamp.astype(jnp.float32)


def dequantize_kv_pages(codes, scales):
    """Inverse of :func:`quantize_kv_pages` (always f32 — the score math's
    dtype, so reference and dense-slab paths see identical values)."""
    return codes.astype(jnp.float32) * scales[..., None, :, None]


def paged_attention_reference(q, k_pages, v_pages, block_table, seq_lens, *,
                              softcap=None, k_scales=None, v_scales=None):
    """Gather-based paged decode attention (the oracle + CPU path).

    q: (B, nh, hd) — the current token's query rows.
    k_pages / v_pages: (num_blocks, block_size, nkv, hd) — the layer's pool.
    block_table: (B, P) int32 — physical page per logical page; entries
        ``>= num_blocks`` are free-slot sentinels (clamped; masked anyway).
    seq_lens: (B,) int32 — valid positions per row (the just-written token
        included), i.e. attend over positions ``< seq_lens[b]``.
    k_scales / v_scales: (num_blocks, nkv) f32 — present iff the pool holds
        int8 codes; pages dequantize per page and the math below is
        exactly the float path (the int8 correctness anchor).
    """
    B, nh, hd = q.shape
    nb, bs, nkv, _ = k_pages.shape
    g = nh // nkv
    tab = jnp.minimum(block_table, nb - 1)
    if k_scales is not None:
        k = dequantize_kv_pages(k_pages[tab], k_scales[tab]).reshape(
            B, -1, nkv, hd)
        v = dequantize_kv_pages(v_pages[tab], v_scales[tab]).reshape(
            B, -1, nkv, hd)
    else:
        k = k_pages[tab].reshape(B, -1, nkv, hd)  # (B, P*bs, nkv, hd)
        v = v_pages[tab].reshape(B, -1, nkv, hd)
    qg = q.reshape(B, nkv, g, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k).astype(jnp.float32)
    s = _softcap(s / math.sqrt(hd), softcap)
    valid = jnp.arange(k.shape[1])[None, :] < seq_lens[:, None]  # (B, P*bs)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v)
    return out.reshape(B, nh, hd)


def _init_softmax_state(j, m_ref, l_ref, acc_ref):
    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)


def _mask_scores(s, j, b, lens_ref, bs, nh):
    """Length-mask one page's (nkv, g, bs) scores -> (nh, bs)."""
    pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)  # (1, bs)
    valid = pos < lens_ref[b]
    return jnp.where(valid[None], s, -jnp.inf).reshape(nh, bs)


def _softmax_accumulate(s, m_ref, l_ref, acc_ref, pv_of):
    """One page's online-softmax update (the ``_chunked_causal_attention``
    carry), shared by the float and int8 kernel bodies. ``pv_of(p)`` maps
    the page's probabilities (nh, bs) to (effective weights for the
    normalizer, PV numerator (nh, hd)) — the float body uses p itself,
    the int8 body its quantized codes, keeping numerator and denominator
    consistent by construction."""
    m_prev = m_ref[...]  # (nh, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - m_safe)  # fully-masked rows: exp(-inf) = 0
    corr = jnp.exp(jnp.where(jnp.isfinite(m_prev), m_prev - m_safe, -jnp.inf))
    p_eff, pv = pv_of(p)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p_eff, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new


def _finalize_output(j, n_pages, o_ref, m_ref, l_ref, acc_ref, out_dtype):
    @pl.when(j == n_pages - 1)
    def _epilogue():
        denom = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / denom).astype(out_dtype)


def _kernel(tab_ref, lens_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
            acc_ref, *, bs: int, nkv: int, g: int, hd: int, n_pages: int,
            softcap, out_dtype):
    b, j = pl.program_id(0), pl.program_id(1)
    nh = nkv * g
    _init_softmax_state(j, m_ref, l_ref, acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (nh, hd)
    k = k_ref[0].astype(jnp.float32)  # (bs, nkv, hd)
    v = v_ref[0].astype(jnp.float32)
    qg = q.reshape(nkv, g, hd)
    s = jnp.einsum("kgd,skd->kgs", qg, k).astype(jnp.float32)
    s = _softcap(s / math.sqrt(hd), softcap)
    s = _mask_scores(s, j, b, lens_ref, bs, nh)

    def pv_of(p):
        pv = jnp.einsum("kgs,skd->kgd", p.reshape(nkv, g, bs), v)
        return p, pv.reshape(nh, hd)

    _softmax_accumulate(s, m_ref, l_ref, acc_ref, pv_of)
    _finalize_output(j, n_pages, o_ref, m_ref, l_ref, acc_ref, out_dtype)


def _register_check(watermark, p_bits: int, what: str):
    """Interpret-mode verification that an integer register watermark stays
    inside its certified P-bit range (the w4a8_mm ``assert_inner`` idiom,
    pl.debug_check with a host-assert fallback for older pallas)."""
    limit = 2 ** (p_bits - 1) - 1
    if hasattr(pl, "debug_check"):
        pl.debug_check(watermark <= limit, f"{what} accumulator overflow")
    else:  # pragma: no cover - older pallas releases
        def _check(w, lim=limit, name=what):
            assert int(w) <= lim, f"{name} accumulator overflow: {w} > {lim}"

        jax.debug.callback(_check, watermark)


def _quant_kernel(tab_ref, lens_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                  o_ref, m_ref, l_ref, acc_ref, *, bs: int, nkv: int, g: int,
                  hd: int, n_pages: int, softcap, out_dtype, spec,
                  assert_bounds: bool):
    """The int8-KV body: same online-softmax recurrence as :func:`_kernel`,
    but both reductions run in the integer domain the ``spec``
    (:class:`~repro.quant.spec.AttnDatapathSpec`) certifies — QK^T as an
    hd-deep q-code × k-code dot in a P_qk-bit register, PV as a per-page
    block_size-deep prob-code × v-code dot in a P_pv-bit register, with
    scales applied once per page on the way into the float outer state."""
    b, j = pl.program_id(0), pl.program_id(1)
    nh = nkv * g
    _init_softmax_state(j, m_ref, l_ref, acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (nh, hd)
    # per-head symmetric quantization of the query rows (the A-side codes)
    q_amax = jnp.max(jnp.abs(q), axis=-1, keepdims=True)  # (nh, 1)
    q_scale = jnp.maximum(q_amax / spec.q_qmax, 1e-8)
    q_codes = jnp.clip(jnp.rint(q / q_scale), -spec.q_qmax,
                       spec.q_qmax).astype(jnp.int32)
    k_codes = k_ref[0].astype(jnp.int32)  # (bs, nkv, hd) int8 codes
    k_scale = ks_ref[0]  # (nkv,) f32 — this page's per-head scale

    # hd-deep integer QK^T dot, held in the P_qk register
    s_int = jnp.einsum("kgd,skd->kgs", q_codes.reshape(nkv, g, hd), k_codes,
                       preferred_element_type=jnp.int32)
    if assert_bounds:
        _register_check(jnp.max(jnp.abs(s_int)), spec.p_qk, "QK^T")
    s = (s_int.astype(jnp.float32) * q_scale.reshape(nkv, g, 1)
         * k_scale[:, None, None])
    s = _softcap(s / math.sqrt(hd), softcap)
    s = _mask_scores(s, j, b, lens_ref, bs, nh)

    def pv_of(p):
        # probability codes (unsigned prob_bits) — the PV A-side operand;
        # the normalizer accumulates the *quantized* probabilities so the
        # final weighted average stays consistent with the PV numerator
        p_codes = jnp.rint(p * spec.prob_qmax).astype(jnp.int32)
        v_codes = v_ref[0].astype(jnp.int32)  # (bs, nkv, hd)
        v_scale = vs_ref[0]  # (nkv,)
        # per-page block_size-deep integer PV dot, held in the P_pv
        # register — the page is the tile; partials drain scaled into the
        # f32 outer accumulator
        pv_int = jnp.einsum("kgs,skd->kgd", p_codes.reshape(nkv, g, bs),
                            v_codes, preferred_element_type=jnp.int32)
        if assert_bounds:
            _register_check(jnp.max(jnp.abs(pv_int)), spec.p_pv, "PV")
        pv = pv_int.astype(jnp.float32) * (v_scale[:, None, None]
                                           / spec.prob_qmax)
        return (p_codes.astype(jnp.float32) / spec.prob_qmax,
                pv.reshape(nh, hd))

    _softmax_accumulate(s, m_ref, l_ref, acc_ref, pv_of)
    _finalize_output(j, n_pages, o_ref, m_ref, l_ref, acc_ref, out_dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret",
                                             "attn_spec", "assert_bounds"))
def paged_decode_attention(q, k_pages, v_pages, block_table, seq_lens, *,
                           k_scales=None, v_scales=None, attn_spec=None,
                           softcap: float | None = None,
                           interpret: bool = False,
                           assert_bounds: bool = False):
    """Paged decode attention as a Pallas kernel; same contract as
    :func:`paged_attention_reference`. The block table and lengths are
    scalar-prefetched so the K/V BlockSpec index_maps can walk
    ``table[b, j]`` — only the sequence's own pages transit HBM->VMEM.

    Passing ``k_scales``/``v_scales`` selects the int8 body, whose QK^T /
    PV registers are certified by an
    :class:`~repro.quant.spec.AttnDatapathSpec`; ``attn_spec`` is a
    *request* validated against the record derived from the pool layout
    (a disagreement raises ``DatapathMismatchError``, never a silent
    fallback — the ``validate_datapath`` contract). ``assert_bounds``
    checks the register watermarks in interpret mode."""
    from repro.quant.spec import AttnDatapathSpec, validate_attn_datapath

    B, nh, hd = q.shape
    nb, bs, nkv, _ = k_pages.shape
    _, n_pages = block_table.shape
    g = nh // nkv
    assert nh == nkv * g, (nh, nkv)
    quantized = k_scales is not None
    if attn_spec is not None and not quantized:
        # absence of a record (float pages) is a mismatch, not a match —
        # the same contract as validate_datapath on unpacked leaves
        validate_attn_datapath(None, attn_spec)

    def page_idx(b, j, tab, lens):
        return (jnp.minimum(tab[b, j], nb - 1), 0, 0, 0)

    in_specs = [
        pl.BlockSpec((1, nh, hd), lambda b, j, tab, lens: (b, 0, 0)),
        pl.BlockSpec((1, bs, nkv, hd), page_idx),
        pl.BlockSpec((1, bs, nkv, hd), page_idx),
    ]
    operands = [block_table, seq_lens, q, k_pages, v_pages]
    if quantized:
        def scale_idx(b, j, tab, lens):
            return (jnp.minimum(tab[b, j], nb - 1), 0)

        in_specs += [pl.BlockSpec((1, nkv), scale_idx)] * 2
        operands += [k_scales.astype(jnp.float32),
                     v_scales.astype(jnp.float32)]
        derived = AttnDatapathSpec.for_cache(
            hd, bs, kv_bits=8 * k_pages.dtype.itemsize)
        if attn_spec is not None:
            derived.require_matches(attn_spec, context="paged_decode_attention")
        kernel = functools.partial(
            _quant_kernel, bs=bs, nkv=nkv, g=g, hd=hd, n_pages=n_pages,
            softcap=softcap, out_dtype=q.dtype, spec=derived,
            assert_bounds=assert_bounds,
        )
    else:
        kernel = functools.partial(
            _kernel, bs=bs, nkv=nkv, g=g, hd=hd, n_pages=n_pages,
            softcap=softcap, out_dtype=q.dtype,
        )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, nh, hd), lambda b, j, tab, lens: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((nh, 1), jnp.float32),  # running max m
            pltpu.VMEM((nh, 1), jnp.float32),  # running normalizer l
            pltpu.VMEM((nh, hd), jnp.float32),  # weighted accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, nh, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
