"""Block-table-aware decode attention over a paged KV cache — Pallas TPU.

The serving engine stores KV state in fixed-size *pages* of ``block_size``
tokens drawn from one global pool per layer (see
``docs/serving_scheduler.md``); a per-sequence block table maps logical
position ``p`` to physical page ``table[b, p // block_size]``. Decode
attention therefore cannot stream the cache contiguously — it must chase
the block table. Two implementations share one contract:

* :func:`paged_attention_reference` — gather the sequence's pages into a
  dense ``(B, P*bs, nkv, hd)`` view and run exactly the math of
  ``repro.models.layers.attention_decode`` (same op order, same f32
  score path). This is the CPU/serving fallback AND the oracle: for a
  table whose capacity equals the dense engine's ``S_max`` it is
  bit-identical to the dense-slab path, which is what the engine golden
  tests pin.
* :func:`paged_decode_attention` — the Pallas kernel. Grid ``(B, P)``
  with the page axis sequential; the block table and sequence lengths
  ride in as *scalar prefetch* operands so each page's BlockSpec
  index_map can dereference ``table[b, j]`` before the body runs — HBM
  traffic per row is O(block-table width), not O(pool size). (Sentinel
  entries past a row's length clamp to page ``nb-1`` and are fetched
  then fully masked; skipping their DMA needs a per-row page-count grid
  — part of the TPU-hardware validation follow-up in the ROADMAP.)
  Scores accumulate via online
  softmax (running max / normalizer / weighted accumulator in VMEM
  scratch, exactly the ``_chunked_causal_attention`` recurrence), so
  kernel-vs-reference agreement is to float tolerance, not bitwise.

Validated against the reference in interpret mode over shape/raggedness
sweeps (``tests/test_paged_attention.py``) — the same testing pattern as
``w4a8_mm``. Compiled-mode perf is a TPU-hardware question (ROADMAP).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _softcap(scores, cap):
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


def paged_attention_reference(q, k_pages, v_pages, block_table, seq_lens, *,
                              softcap=None):
    """Gather-based paged decode attention (the oracle + CPU path).

    q: (B, nh, hd) — the current token's query rows.
    k_pages / v_pages: (num_blocks, block_size, nkv, hd) — the layer's pool.
    block_table: (B, P) int32 — physical page per logical page; entries
        ``>= num_blocks`` are free-slot sentinels (clamped; masked anyway).
    seq_lens: (B,) int32 — valid positions per row (the just-written token
        included), i.e. attend over positions ``< seq_lens[b]``.
    """
    B, nh, hd = q.shape
    nb, bs, nkv, _ = k_pages.shape
    g = nh // nkv
    tab = jnp.minimum(block_table, nb - 1)
    k = k_pages[tab].reshape(B, -1, nkv, hd)  # (B, P*bs, nkv, hd)
    v = v_pages[tab].reshape(B, -1, nkv, hd)
    qg = q.reshape(B, nkv, g, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k).astype(jnp.float32)
    s = _softcap(s / math.sqrt(hd), softcap)
    valid = jnp.arange(k.shape[1])[None, :] < seq_lens[:, None]  # (B, P*bs)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v)
    return out.reshape(B, nh, hd)


def _kernel(tab_ref, lens_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
            acc_ref, *, bs: int, nkv: int, g: int, hd: int, n_pages: int,
            softcap, out_dtype):
    b, j = pl.program_id(0), pl.program_id(1)
    nh = nkv * g

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (nh, hd)
    k = k_ref[0].astype(jnp.float32)  # (bs, nkv, hd)
    v = v_ref[0].astype(jnp.float32)
    qg = q.reshape(nkv, g, hd)
    s = jnp.einsum("kgd,skd->kgs", qg, k).astype(jnp.float32)
    s = _softcap(s / math.sqrt(hd), softcap)
    pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)  # (1, bs)
    valid = pos < lens_ref[b]
    s = jnp.where(valid[None], s, -jnp.inf).reshape(nh, bs)

    # online-softmax recurrence (the _chunked_causal_attention carry)
    m_prev = m_ref[...]  # (nh, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - m_safe)  # fully-masked rows: exp(-inf) = 0
    corr = jnp.exp(jnp.where(jnp.isfinite(m_prev), m_prev - m_safe, -jnp.inf))
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    pv = jnp.einsum("kgs,skd->kgd", p.reshape(nkv, g, bs), v)
    acc_ref[...] = acc_ref[...] * corr + pv.reshape(nh, hd)
    m_ref[...] = m_new

    @pl.when(j == n_pages - 1)
    def _epilogue():
        denom = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / denom).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, block_table, seq_lens, *,
                           softcap: float | None = None,
                           interpret: bool = False):
    """Paged decode attention as a Pallas kernel; same contract as
    :func:`paged_attention_reference`. The block table and lengths are
    scalar-prefetched so the K/V BlockSpec index_maps can walk
    ``table[b, j]`` — only the sequence's own pages transit HBM->VMEM."""
    B, nh, hd = q.shape
    nb, bs, nkv, _ = k_pages.shape
    _, n_pages = block_table.shape
    g = nh // nkv
    assert nh == nkv * g, (nh, nkv)

    def page_idx(b, j, tab, lens):
        return (jnp.minimum(tab[b, j], nb - 1), 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, n_pages),
        in_specs=[
            pl.BlockSpec((1, nh, hd), lambda b, j, tab, lens: (b, 0, 0)),
            pl.BlockSpec((1, bs, nkv, hd), page_idx),
            pl.BlockSpec((1, bs, nkv, hd), page_idx),
        ],
        out_specs=pl.BlockSpec((1, nh, hd), lambda b, j, tab, lens: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((nh, 1), jnp.float32),  # running max m
            pltpu.VMEM((nh, 1), jnp.float32),  # running normalizer l
            pltpu.VMEM((nh, hd), jnp.float32),  # weighted accumulator
        ],
    )
    kernel = functools.partial(
        _kernel, bs=bs, nkv=nkv, g=g, hd=hd, n_pages=n_pages,
        softcap=softcap, out_dtype=q.dtype,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, nh, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(block_table, seq_lens, q, k_pages, v_pages)
