"""Memory-efficient GPFQ panel solver as a Pallas kernel.

GPFQ is sequential in K (each weight's correction depends on all previous
quantization errors), so a GPU implementation runs a Python loop with
batched channel updates. The TPU-idiomatic equivalent is a *sequential grid
dimension* with the running error matrix U resident in VMEM scratch — the
systolic analogue of a persistent CUDA block (DESIGN.md §3):

    grid = (C / block_c, K)       # channels parallel, K sequential
    per step k: stream one row of H and one row of G H^-1 from HBM,
                compute v = w_k * (<h_k, g_k>/|h_k|^2) + (h_k U)/|h_k|^2,
                soft-threshold (Pi_lambda), clip to the running AXE budgets
                (Psi_{a,b}, Eqs. 19-21), round, commit, rank-1-update U.

The AXE budget state (pos/neg committed mass per (tile, channel)) also lives
in VMEM scratch. Work per step is O(K * block_c): the matvec h_k @ U and the
two rank-1 updates — MXU-friendly contractions.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(
    w_ref,  # (K, bc) integer-domain weights
    xg_ref,  # (1, K) row k of G H^-1
    xh_ref,  # (1, K) row k of H
    hg_ref,  # (1, 1) <h_k, g_k>
    hn_ref,  # (1, 1) |h_k|^2
    lam_ref,  # (n_tiles, bc) soft thresholds
    tid_ref,  # (1, 1) tile id of step k
    q_ref,  # out: (K, bc)
    u_ref,  # scratch: (K, bc) running error
    pos_ref,  # scratch: (n_tiles, bc)
    neg_ref,  # scratch: (n_tiles, bc)
    *,
    n_k: int,
    qmin: float,
    qmax: float,
    budget_b: float,
    rounding: str,
):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        u_ref[...] = jnp.zeros_like(u_ref)
        pos_ref[...] = jnp.zeros_like(pos_ref)
        neg_ref[...] = jnp.zeros_like(neg_ref)

    h = xh_ref[...]  # (1, K)
    g = xg_ref[...]
    denom = jnp.maximum(hn_ref[0, 0], 1e-20)
    w_k = w_ref[k, :]  # (bc,)
    v = w_k * (hg_ref[0, 0] / denom) + (h @ u_ref[...])[0] / denom  # (bc,)

    t = tid_ref[0, 0]
    lam = lam_ref[t, :]
    v = jnp.sign(v) * jnp.maximum(jnp.abs(v) - lam, 0.0)  # Pi_lambda
    lo = jnp.minimum(-budget_b - neg_ref[t, :], 0.0)  # Psi_{a,b}
    hi = jnp.maximum(budget_b - pos_ref[t, :], 0.0)
    v = jnp.clip(v, lo, hi)
    if rounding == "nearest":
        q = jnp.clip(jnp.rint(v), qmin, qmax)
    else:  # round-to-zero
        q = jnp.clip(jnp.trunc(v), qmin, qmax)

    pos_ref[t, :] += jnp.maximum(q, 0.0)
    neg_ref[t, :] += jnp.minimum(q, 0.0)
    # U += g^T w_k - h^T q   (two rank-1 updates, (K, bc))
    u_ref[...] += g.T @ w_k[None, :] - h.T @ q[None, :]
    q_ref[k, :] = q


@functools.partial(
    jax.jit,
    static_argnames=(
        "budget_b", "w_bits", "tile", "block_c", "rounding", "interpret",
    ),
)
def gpfq_solve(
    w_int: jax.Array,  # (K, C) integer-domain weights
    xg: jax.Array,  # (K, K) = G H^-1
    xh: jax.Array,  # (K, K) = H
    lam: jax.Array,  # (n_tiles, C) soft thresholds (zeros disable)
    budget_b: float,  # strict budget B of Eq. 21 (inf disables)
    *,
    w_bits: int = 4,
    tile: int = 128,
    block_c: int = 128,
    rounding: str = "nearest",
    interpret: bool = False,
):
    k, c = w_int.shape
    assert xg.shape == (k, k) and xh.shape == (k, k)
    assert c % block_c == 0, (c, block_c)
    n_tiles = (k + tile - 1) // tile
    assert lam.shape == (n_tiles, c), (lam.shape, (n_tiles, c))

    hn = jnp.sum(xh * xh, axis=1).reshape(k, 1)  # |h_k|^2
    hg = jnp.sum(xh * xg, axis=1).reshape(k, 1)  # <h_k, g_k>
    tids = (jnp.arange(k, dtype=jnp.int32) // tile).reshape(k, 1)

    qmax = float(2 ** (w_bits - 1) - 1)
    kernel = functools.partial(
        _kernel,
        n_k=k,
        qmin=-qmax,
        qmax=qmax,
        budget_b=float(budget_b),
        rounding=rounding,
    )
    return pl.pallas_call(
        kernel,
        grid=(c // block_c, k),
        in_specs=[
            pl.BlockSpec((k, block_c), lambda ci, kk: (0, ci)),
            pl.BlockSpec((1, k), lambda ci, kk: (kk, 0)),
            pl.BlockSpec((1, k), lambda ci, kk: (kk, 0)),
            pl.BlockSpec((1, 1), lambda ci, kk: (kk, 0)),
            pl.BlockSpec((1, 1), lambda ci, kk: (kk, 0)),
            pl.BlockSpec((n_tiles, block_c), lambda ci, kk: (0, ci)),
            pl.BlockSpec((1, 1), lambda ci, kk: (kk, 0)),
        ],
        out_specs=pl.BlockSpec((k, block_c), lambda ci, kk: (0, ci)),
        out_shape=jax.ShapeDtypeStruct((k, c), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((k, block_c), jnp.float32),
            pltpu.VMEM((n_tiles, block_c), jnp.float32),
            pltpu.VMEM((n_tiles, block_c), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(w_int.astype(jnp.float32), xg, xh, hg, hn, lam, tids)
