"""W4A8 integer GEMM with multi-stage accumulation — the inference hot-spot
AXE certifies (paper §3.3 / §4.2), as a Pallas TPU kernel.

Datapath (Figure 2 of the paper, mapped to the TPU memory hierarchy):

  * weights arrive int4-PACKED (two codes per int8 byte along K) — half the
    HBM->VMEM traffic of int8 weights;
  * activations arrive as int8 codes (asymmetric, zero-point handled by a
    per-channel correction term computed once outside the kernel);
  * the K axis is processed in tiles of T = ``block_k`` (128 = one MXU pass,
    the paper's T): each tile's dot product is the *inner* accumulator —
    AXE guarantees it fits P_I bits (16 in the LLM recipe), which is what
    would let a hypothetical int16 systolic datapath run at 2x throughput;
  * per-tile partials are accumulated across the sequential K grid dimension
    into a VMEM int32 scratch — the *outer* accumulator (P_O of Eq. 22);
  * the epilogue applies s_x * s_w[n] and the zero-point correction, and
    writes bf16/f32.

Validated against ref.py in interpret mode over shape/dtype sweeps
(tests/test_kernels.py); the ``assert_inner`` flag additionally checks the
P_I bound *inside* the kernel on every tile (interpret mode only — on
hardware the bound is a theorem, not a runtime check).

Two shape regimes share the kernel body:

  * prefill-shaped (M = B*S, hundreds+): the classic 128x128x128 grid; a
    ragged last M block is padded internally and sliced off after the call;
  * decode-shaped (M = batch, often < 8): :func:`w4a8_decode_matmul` —
    GEMV-style grid with a single sub-128 M block (rounded up to the 8-row
    sublane), N x K tiled as in prefill, and the per-channel ``col_sums``
    zero-point term taken from the packed artifact instead of recomputed
    from a full ``unpack_int4`` on every call (that unpack would re-read
    the whole weight, exactly the HBM traffic packing exists to avoid).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def unpack_int4(packed: jax.Array) -> jax.Array:
    """(..., K//2, N) int8 -> (..., K, N) int8 in [-8, 7]; row 2k = low
    nibble. Leading dims (repeat stacks, MoE expert stacks) pass through."""
    low = jnp.left_shift(packed, 4)
    low = jnp.right_shift(low, 4)  # arithmetic: sign-extends
    high = jnp.right_shift(packed, 4)
    *lead, k2, n = packed.shape
    out = jnp.stack([low, high], axis=-2)  # (..., K//2, 2, N)
    return out.reshape(*lead, 2 * k2, n)


def pack_int4(q: jax.Array) -> jax.Array:
    """(..., K, N) int codes in [-8, 7] -> (..., K//2, N) int8 packed."""
    q = q.astype(jnp.int8)
    *lead, k, n = q.shape
    assert k % 2 == 0, "K must be even to pack int4"
    pairs = q.reshape(*lead, k // 2, 2, n)
    low = jnp.bitwise_and(pairs[..., 0, :], 0x0F)
    high = jnp.left_shift(jnp.bitwise_and(pairs[..., 1, :], 0x0F), 4)
    return jnp.bitwise_or(low, high).astype(jnp.int8)


# ---------------------------------------------------------------------------
# 2:4 semi-structured compression (codes + metadata indices).
#
# Layout: per group of 4 rows along K, per column, the (<= 2) surviving codes
# are stored as one packed int4 byte (low nibble = first kept code, high =
# second) and one metadata byte (bits 0-1 = in-group position of the first,
# bits 2-3 = of the second). Groups with fewer than 2 nonzeros pad with
# zero-valued codes pointing at unused slots — expansion is insensitive to
# which slots because a zero code contributes zero. Weight HBM traffic is
# K/4 + K/4 bytes per column vs K/2 dense-packed: a further 2x reduction.
# ---------------------------------------------------------------------------
def compress_2to4(q: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(..., K, N) 2:4-sparse int codes -> (packed (..., K//4, N) int8,
    meta (..., K//4, N) int8). Traceable (works under ``jax.eval_shape``);
    the 2:4 property itself is validated by ``certify`` at quantization
    time, not here."""
    q = q.astype(jnp.int8)
    *lead, k, n = q.shape
    if k % 4:
        raise ValueError(f"2:4 compression needs K % 4 == 0, got K={k}")
    g = q.reshape(*lead, k // 4, 4, n)
    # nonzero slots first (stable: ties keep in-group index order)
    order = jnp.argsort(g == 0, axis=-2, stable=True)
    idx = order[..., :2, :]  # (..., k//4, 2, n) positions of kept codes
    vals = jnp.take_along_axis(g, idx, axis=-2)  # (..., k//4, 2, n)
    meta = jnp.bitwise_or(
        idx[..., 0, :].astype(jnp.int8),
        jnp.left_shift(idx[..., 1, :].astype(jnp.int8), 2),
    )
    return pack_int4(vals.reshape(*lead, k // 2, n)), meta


def unpack_sparse24(packed: jax.Array, meta: jax.Array) -> jax.Array:
    """Gather-reference expansion: (..., K//4, N) packed + meta ->
    (..., K, N) int8 dense-with-zeros, bit-identical to the codes that were
    compressed. Leading dims (repeat/expert stacks) pass through."""
    vals = unpack_int4(packed)  # (..., K//2, N)
    *lead, k2, n = vals.shape
    g4 = k2 // 2
    v = vals.reshape(*lead, g4, 2, n)
    m = meta.astype(jnp.int32)
    i0 = jnp.bitwise_and(m, 3)[..., :, None, :]  # (..., g4, 1, n)
    i1 = jnp.bitwise_and(jnp.right_shift(m, 2), 3)[..., :, None, :]
    pos = jnp.arange(4, dtype=jnp.int32).reshape(
        *(1,) * len(lead), 1, 4, 1
    )  # in-group slot ids
    dense = jnp.where(pos == i0, v[..., 0:1, :], jnp.int8(0)) + jnp.where(
        pos == i1, v[..., 1:2, :], jnp.int8(0)
    )
    return dense.reshape(*lead, g4 * 4, n).astype(jnp.int8)


def _expand_sparse24_block(wp, meta):
    """In-kernel expansion of one (bk//4, bn) packed+meta block to a dense
    (bk, bn) int32 block. Mirrors :func:`unpack_sparse24` exactly (same
    nibble decode, same position compare), so the kernel matmul consumes
    bit-identical codes to the gather reference."""
    vals = unpack_int4(wp)  # (bk//2, bn) int8
    g4, bn = meta.shape
    v = vals.reshape(g4, 2, bn).astype(jnp.int32)
    m = meta.astype(jnp.int32)
    i0 = jnp.bitwise_and(m, 3)[:, None, :]
    i1 = jnp.bitwise_and(jnp.right_shift(m, 2), 3)[:, None, :]
    pos = jax.lax.broadcasted_iota(jnp.int32, (g4, 4, bn), 1)
    dense = jnp.where(pos == i0, v[:, 0:1, :], 0) + jnp.where(pos == i1, v[:, 1:2, :], 0)
    return dense.reshape(g4 * 4, bn)


def _kernel(x_ref, wp_ref, sw_ref, corr_ref, out_ref, acc_ref, *,
            n_k: int, p_inner: int, assert_inner: bool, out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.int32)  # (bm, bk) int8 codes
    w = unpack_int4(wp_ref[...]).astype(jnp.int32)  # (bk, bn)
    # inner accumulator: one K-tile MAC — AXE certifies |partial| < 2^(P_I-1)
    partial = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    if assert_inner:  # interpret-mode verification of the paper's guarantee
        limit = 2 ** (p_inner - 1) - 1
        watermark = jnp.max(jnp.abs(partial))
        if hasattr(pl, "debug_check"):
            pl.debug_check(watermark <= limit, "inner accumulator overflow")
        else:  # older pallas: host-side assert (interpret mode only)
            def _check(w, lim=limit):
                assert int(w) <= lim, f"inner accumulator overflow: {w} > {lim}"

            jax.debug.callback(_check, watermark)
    # outer accumulator (P_O of Eq. 22)
    acc_ref[...] += partial

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32)
        # zero-point correction (zp * sum_k q[k,n], precomputed per channel)
        # then the fused dequant scale s_x * s_w[n]
        out_ref[...] = ((acc - corr_ref[...]) * sw_ref[...]).astype(out_dtype)


def datapath_kernel_args(spec) -> dict:
    """Map a :class:`~repro.quant.spec.DatapathSpec` onto the kernel's
    accumulator knobs. This is the only place the translation lives: the
    K-tile size is the certified T (monolithic specs keep the 128-lane MXU
    tile — any K-subset partial of an l1-budgeted row is bounded by the
    full-K bound, so P_I stays a valid per-tile certificate) and the inner
    accumulator width is the certified P_I."""
    return {"block_k": spec.block_k(), "p_inner": spec.p_inner}


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _fit_block(dim: int, pref: int) -> int:
    """Largest block <= pref that divides dim (pref itself when it divides)."""
    if dim % pref == 0:
        return pref
    g = math.gcd(dim, pref)
    return g if g else dim


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "p_inner",
                     "assert_inner", "interpret", "out_dtype"),
)
def w4a8_matmul(
    x_int8: jax.Array,  # (M, K) int8 activation codes
    w_packed: jax.Array,  # (K//2, N) int8 packed int4 weights
    w_scale: jax.Array,  # (N,) f32 per-channel weight scales
    act_scale: float,
    act_zp: int,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,  # the paper's tile size T
    p_inner: int = 16,
    assert_inner: bool = False,
    interpret: bool = False,
    out_dtype=jnp.float32,
    col_sums: jax.Array | None = None,  # (N,) or (1, N) int32, pack-time
):
    m, k = x_int8.shape
    k2, n = w_packed.shape
    assert k == 2 * k2, (x_int8.shape, w_packed.shape)

    # Ragged shapes: M is padded with zero rows (garbage rows sliced off
    # after the call — the zero-point correction makes them nonzero, but
    # they are never read); N and K fall back to the largest divisor block.
    if m <= block_m:
        bm = _round_up(m, 8)  # decode regime: one sub-block_m M block
    else:
        # prefill regime with a ragged tail: shrink the M block until the
        # zero-row padding is small (<= max(bm/4, 8) rows) instead of
        # paying up to a whole extra block of wasted MXU work (m=130 with
        # bm=128 would pad to 256; an 8-row block pads to 136)
        bm, c = 8, block_m
        while c >= 8:
            if _round_up(m, c) - m <= max(c // 4, 8):
                bm = c
                break
            c //= 2
    bn = _fit_block(n, block_n)
    bk = _fit_block(k, block_k)
    assert bk % 2 == 0, f"K tile {bk} must be even for packed int4 (K={k})"
    m_pad = _round_up(m, bm)
    if m_pad != m:
        x_int8 = jnp.pad(x_int8, ((0, m_pad - m), (0, 0)))

    # per-channel zero-point correction: zp * sum_k q[k, n] (int32), and the
    # fused dequant scale s_x * s_w — both computed once outside the kernel.
    # col_sums is precomputed at pack time on the decode path; the fallback
    # unpack here is the prefill/one-off path.
    if col_sums is None:
        col_sums = jnp.sum(unpack_int4(w_packed).astype(jnp.int32), axis=0)
    corr = (col_sums.reshape(-1).astype(jnp.float32) * act_zp)[None, :]  # (1, N)
    sw = (w_scale.reshape(-1).astype(jnp.float32) * act_scale)[None, :]  # (1, N)

    n_k = k // bk
    grid = (m_pad // bm, n // bn, n_k)
    kernel = functools.partial(
        _kernel,
        n_k=n_k,
        p_inner=p_inner,
        assert_inner=assert_inner,
        out_dtype=out_dtype,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x_int8, w_packed, sw, corr)
    return out[:m] if m_pad != m else out


def w4a8_decode_matmul(
    x_int8: jax.Array,  # (B, K) activation codes — M = decode batch
    w_packed: jax.Array,  # (K//2, N)
    w_scale: jax.Array,  # (N,) or (1, N)
    col_sums: jax.Array,  # (N,) or (1, N) int32 — REQUIRED, from pack time
    act_scale,
    act_zp,
    **kw,
):
    """Decode-shaped W4A8 GEMM: single sub-128 M block (padded up to the
    8-row sublane), N x K tiled as in prefill, int4 unpack + zero-point
    correction + per-channel dequant fused in the epilogue. Same
    ``p_inner``/``assert_inner`` certificate semantics as the prefill path.

    Requiring ``col_sums`` (stored in the packed serving artifact) is what
    keeps this path free of any full-weight ``unpack_int4``: the jaxpr
    touches the packed codes only inside the kernel, block by block.
    """
    assert col_sums is not None
    kw.setdefault("block_m", 128)  # min() against M inside w4a8_matmul
    return w4a8_matmul(
        x_int8, w_packed, w_scale, act_scale, act_zp, col_sums=col_sums, **kw
    )


# ---------------------------------------------------------------------------
# Sparse (2:4) decode path.
# ---------------------------------------------------------------------------
def _sparse_kernel(x_ref, wp_ref, meta_ref, sw_ref, corr_ref, out_ref, acc_ref, *,
                   n_k: int, p_inner: int, assert_inner: bool, out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.int32)  # (bm, bk) int8 codes
    # expand the compressed block in VMEM: the HBM->VMEM weight traffic is
    # bk/4 + bk/4 bytes per column (codes + metadata) instead of bk/2 dense
    w = _expand_sparse24_block(wp_ref[...], meta_ref[...])  # (bk, bn) int32
    partial = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    if assert_inner:  # interpret-mode verification (2:4 tightens the bound)
        limit = 2 ** (p_inner - 1) - 1
        watermark = jnp.max(jnp.abs(partial))
        if hasattr(pl, "debug_check"):
            pl.debug_check(watermark <= limit, "inner accumulator overflow")
        else:
            def _check(w, lim=limit):
                assert int(w) <= lim, f"inner accumulator overflow: {w} > {lim}"

            jax.debug.callback(_check, watermark)
    acc_ref[...] += partial

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32)
        out_ref[...] = ((acc - corr_ref[...]) * sw_ref[...]).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "p_inner",
                     "assert_inner", "interpret", "out_dtype"),
)
def w4a8_sparse_matmul(
    x_int8: jax.Array,  # (M, K) int8 activation codes
    w_packed: jax.Array,  # (K//4, N) int8 packed 2:4 codes (2 nibbles/group)
    w_meta: jax.Array,  # (K//4, N) int8 in-group position metadata
    w_scale: jax.Array,  # (N,) f32 per-channel weight scales
    act_scale: float,
    act_zp: int,
    *,
    col_sums: jax.Array,  # (N,) or (1, N) int32 — REQUIRED, from pack time
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    p_inner: int = 16,
    assert_inner: bool = False,
    interpret: bool = False,
    out_dtype=jnp.float32,
):
    """W4A8 GEMM over 2:4-compressed weights, bit-identical to running
    :func:`w4a8_matmul` on the dense-with-zeros codes: the in-kernel
    expansion reconstructs the exact same int values, the MXU partial sums
    the exact same int32 integers, and the epilogue applies the exact same
    float math in the same order. ``col_sums`` must be the dense codes'
    per-channel sums (= the sums of the kept codes — zeros add nothing).

    Same ragged-M handling as the dense kernel; decode batches (M < 8)
    round up to the 8-row sublane.
    """
    m, k4 = x_int8.shape[0], w_packed.shape[0]
    k = 4 * k4
    assert x_int8.shape[1] == k, (x_int8.shape, w_packed.shape)
    assert w_meta.shape == w_packed.shape, (w_meta.shape, w_packed.shape)
    n = w_packed.shape[1]

    if m <= block_m:
        bm = _round_up(m, 8)
    else:
        bm, c = 8, block_m
        while c >= 8:
            if _round_up(m, c) - m <= max(c // 4, 8):
                bm = c
                break
            c //= 2
    bn = _fit_block(n, block_n)
    bk = _fit_block(k, block_k)
    assert bk % 4 == 0, f"K tile {bk} must be a multiple of 4 for 2:4 codes (K={k})"
    m_pad = _round_up(m, bm)
    if m_pad != m:
        x_int8 = jnp.pad(x_int8, ((0, m_pad - m), (0, 0)))

    corr = (col_sums.reshape(-1).astype(jnp.float32) * act_zp)[None, :]
    sw = (w_scale.reshape(-1).astype(jnp.float32) * act_scale)[None, :]

    n_k = k // bk
    grid = (m_pad // bm, n // bn, n_k)
    kernel = functools.partial(
        _sparse_kernel,
        n_k=n_k,
        p_inner=p_inner,
        assert_inner=assert_inner,
        out_dtype=out_dtype,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 4, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // 4, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x_int8, w_packed, w_meta, sw, corr)
    return out[:m] if m_pad != m else out


def w4a8_sparse_decode_matmul(
    x_int8: jax.Array,  # (B, K)
    w_packed: jax.Array,  # (K//4, N)
    w_meta: jax.Array,  # (K//4, N)
    w_scale: jax.Array,
    col_sums: jax.Array,
    act_scale,
    act_zp,
    **kw,
):
    """Decode-shaped counterpart of :func:`w4a8_sparse_matmul` — the sparse
    analogue of :func:`w4a8_decode_matmul` (col_sums required, packed codes
    and metadata only ever touched block-by-block inside the kernel)."""
    assert col_sums is not None
    kw.setdefault("block_m", 128)
    return w4a8_sparse_matmul(
        x_int8, w_packed, w_meta, w_scale, act_scale, act_zp,
        col_sums=col_sums, **kw
    )
