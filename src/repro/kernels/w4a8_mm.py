"""W4A8 integer GEMM with multi-stage accumulation — the inference hot-spot
AXE certifies (paper §3.3 / §4.2), as a Pallas TPU kernel.

Datapath (Figure 2 of the paper, mapped to the TPU memory hierarchy):

  * weights arrive int4-PACKED (two codes per int8 byte along K) — half the
    HBM->VMEM traffic of int8 weights;
  * activations arrive as int8 codes (asymmetric, zero-point handled by a
    per-channel correction term computed once outside the kernel);
  * the K axis is processed in tiles of T = ``block_k`` (128 = one MXU pass,
    the paper's T): each tile's dot product is the *inner* accumulator —
    AXE guarantees it fits P_I bits (16 in the LLM recipe), which is what
    would let a hypothetical int16 systolic datapath run at 2x throughput;
  * per-tile partials are accumulated across the sequential K grid dimension
    into a VMEM int32 scratch — the *outer* accumulator (P_O of Eq. 22);
  * the epilogue applies s_x * s_w[n] and the zero-point correction, and
    writes bf16/f32.

Validated against ref.py in interpret mode over shape/dtype sweeps
(tests/test_kernels.py); the ``assert_inner`` flag additionally checks the
P_I bound *inside* the kernel on every tile (interpret mode only — on
hardware the bound is a theorem, not a runtime check).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def unpack_int4(packed: jax.Array) -> jax.Array:
    """(..., K//2, N) int8 -> (..., K, N) int8 in [-8, 7]; row 2k = low
    nibble. Leading dims (repeat stacks, MoE expert stacks) pass through."""
    low = jnp.left_shift(packed, 4)
    low = jnp.right_shift(low, 4)  # arithmetic: sign-extends
    high = jnp.right_shift(packed, 4)
    *lead, k2, n = packed.shape
    out = jnp.stack([low, high], axis=-2)  # (..., K//2, 2, N)
    return out.reshape(*lead, 2 * k2, n)


def pack_int4(q: jax.Array) -> jax.Array:
    """(..., K, N) int codes in [-8, 7] -> (..., K//2, N) int8 packed."""
    q = q.astype(jnp.int8)
    *lead, k, n = q.shape
    assert k % 2 == 0, "K must be even to pack int4"
    pairs = q.reshape(*lead, k // 2, 2, n)
    low = jnp.bitwise_and(pairs[..., 0, :], 0x0F)
    high = jnp.left_shift(jnp.bitwise_and(pairs[..., 1, :], 0x0F), 4)
    return jnp.bitwise_or(low, high).astype(jnp.int8)


def _kernel(x_ref, wp_ref, sw_ref, corr_ref, out_ref, acc_ref, *,
            n_k: int, p_inner: int, assert_inner: bool, out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.int32)  # (bm, bk) int8 codes
    w = unpack_int4(wp_ref[...]).astype(jnp.int32)  # (bk, bn)
    # inner accumulator: one K-tile MAC — AXE certifies |partial| < 2^(P_I-1)
    partial = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    if assert_inner:  # interpret-mode verification of the paper's guarantee
        limit = 2 ** (p_inner - 1) - 1
        pl.debug_check(jnp.max(jnp.abs(partial)) <= limit,
                       "inner accumulator overflow")
    # outer accumulator (P_O of Eq. 22)
    acc_ref[...] += partial

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32)
        # zero-point correction (zp * sum_k q[k,n], precomputed per channel)
        # then the fused dequant scale s_x * s_w[n]
        out_ref[...] = ((acc - corr_ref[...]) * sw_ref[...]).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "p_inner",
                     "assert_inner", "interpret", "out_dtype"),
)
def w4a8_matmul(
    x_int8: jax.Array,  # (M, K) int8 activation codes
    w_packed: jax.Array,  # (K//2, N) int8 packed int4 weights
    w_scale: jax.Array,  # (N,) f32 per-channel weight scales
    act_scale: float,
    act_zp: int,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,  # the paper's tile size T
    p_inner: int = 16,
    assert_inner: bool = False,
    interpret: bool = False,
    out_dtype=jnp.float32,
):
    m, k = x_int8.shape
    k2, n = w_packed.shape
    assert k == 2 * k2, (x_int8.shape, w_packed.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0

    # per-channel zero-point correction: zp * sum_k q[k, n] (int32), and the
    # fused dequant scale s_x * s_w — both computed once outside the kernel
    col_sums = jnp.sum(unpack_int4(w_packed).astype(jnp.int32), axis=0)  # (N,)
    corr = (col_sums * act_zp).astype(jnp.float32)[None, :]  # (1, N)
    sw = (w_scale.astype(jnp.float32) * act_scale)[None, :]  # (1, N)

    n_k = k // block_k
    grid = (m // block_m, n // block_n, n_k)
    kernel = functools.partial(
        _kernel,
        n_k=n_k,
        p_inner=p_inner,
        assert_inner=assert_inner,
        out_dtype=out_dtype,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k // 2, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x_int8, w_packed, sw, corr)
