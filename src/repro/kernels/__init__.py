"""Pallas TPU kernels for the paper's compute hot-spots:

  * w4a8_mm       — packed-int4 x int8 GEMM with multi-stage accumulation
                    (the datapath AXE certifies; paper §3.3, Fig. 2)
  * gpfq_solve    — sequential-grid GPFQ panel solver (VMEM-resident error)
  * quant_rmsnorm — fused RMSNorm + int8 activation quantization

Each has a pure-jnp oracle in ref.py and jit wrappers in ops.py; validated
in interpret mode on CPU (tests/test_kernels.py), compiled for TPU on real
hardware.
"""

from . import ops, ref
from .ops import (
    gpfq_quantize_panel,
    norm_and_quantize,
    pack_int4,
    quantize_activations,
    quantized_linear_w4a8,
    unpack_int4,
    w4a8_decode_matmul,
    w4a8_matmul,
)
