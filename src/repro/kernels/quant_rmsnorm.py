"""Fused RMSNorm + asymmetric int8 activation quantization.

In the W4A8 serving path every norm output is immediately quantized to int8
codes (paper §C.1: per-tensor asymmetric activations). Fusing the norm with
the quantizer keeps the fp32 intermediate in VMEM and writes only the 1-byte
codes back to HBM — a 4x cut of the layer-boundary write traffic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(x_ref, g_ref, qp_ref, out_ref, *, eps: float, qmax: int):
    x = x_ref[...].astype(jnp.float32)  # (bm, D)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * g_ref[...].astype(jnp.float32)
    inv_scale, zp = qp_ref[0, 0], qp_ref[0, 1]
    q = jnp.rint(y * inv_scale) + zp
    out_ref[...] = jnp.clip(q, 0, qmax).astype(jnp.uint8)


@functools.partial(
    jax.jit, static_argnames=("block_m", "bits", "eps", "interpret")
)
def quant_rmsnorm(
    x: jax.Array,  # (M, D)
    gamma: jax.Array,  # (D,)
    act_scale: float,
    act_zp: int,
    *,
    block_m: int = 256,
    bits: int = 8,
    eps: float = 1e-6,
    interpret: bool = False,
):
    m, d = x.shape
    assert m % block_m == 0, (m, block_m)
    kernel = functools.partial(_kernel, eps=eps, qmax=2**bits - 1)
    qp = jnp.stack(
        [1.0 / jnp.asarray(act_scale, jnp.float32), jnp.asarray(act_zp, jnp.float32)]
    )[None, :]  # (1, 2) quantizer params (traced-safe)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.uint8),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(x, gamma[None, :], qp)
