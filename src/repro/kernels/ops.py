"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on TPU —
the same call sites serve tests and production.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .gpfq_solve import gpfq_solve
from .quant_rmsnorm import quant_rmsnorm
from .w4a8_mm import pack_int4, unpack_int4, w4a8_matmul


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def quantized_linear_w4a8(
    x_codes: jax.Array,
    w_packed: jax.Array,
    w_scale: jax.Array,
    act_scale: float,
    act_zp: int,
    **kw,
):
    """Serving-path W4A8 linear: integer GEMM + dequant epilogue."""
    kw.setdefault("interpret", default_interpret())
    return w4a8_matmul(x_codes, w_packed, w_scale, act_scale, act_zp, **kw)


def norm_and_quantize(x, gamma, act_scale, act_zp, **kw):
    kw.setdefault("interpret", default_interpret())
    return quant_rmsnorm(x, gamma, act_scale, act_zp, **kw)


def gpfq_quantize_panel(w_int, xg, xh, lam, budget_b, **kw):
    kw.setdefault("interpret", default_interpret())
    return gpfq_solve(w_int, xg, xh, lam, budget_b, **kw)


__all__ = [
    "default_interpret",
    "gpfq_quantize_panel",
    "norm_and_quantize",
    "pack_int4",
    "quantized_linear_w4a8",
    "unpack_int4",
    "w4a8_matmul",
]
