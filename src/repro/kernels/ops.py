"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on TPU —
the same call sites serve tests and production.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .gpfq_solve import gpfq_solve
from .quant_rmsnorm import quant_rmsnorm
from .w4a8_mm import pack_int4, unpack_int4, w4a8_decode_matmul, w4a8_matmul


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def quantize_activations(x: jax.Array):
    """Dynamic per-tensor asymmetric int8 activation quantization (the
    serving-path A8 half of W4A8 when no calibrated activation quantizer is
    attached to the artifact). Returns (codes uint8, scale f32, zp f32) —
    all traced, so the whole thing stays on device.
    """
    xf = x.astype(jnp.float32)
    lo = jnp.minimum(jnp.min(xf), 0.0)
    hi = jnp.maximum(jnp.max(xf), 0.0)
    scale = jnp.maximum((hi - lo) / 255.0, 1e-8)
    zp = jnp.clip(jnp.rint(-lo / scale), 0.0, 255.0)
    codes = jnp.clip(jnp.rint(xf / scale) + zp, 0.0, 255.0).astype(jnp.uint8)
    return codes, scale, zp


def quantized_linear_w4a8(
    x_codes: jax.Array,
    w_packed: jax.Array,
    w_scale: jax.Array,
    act_scale: float,
    act_zp: int,
    **kw,
):
    """Serving-path W4A8 linear: integer GEMM + dequant epilogue."""
    kw.setdefault("interpret", default_interpret())
    return w4a8_matmul(x_codes, w_packed, w_scale, act_scale, act_zp, **kw)


def norm_and_quantize(x, gamma, act_scale, act_zp, **kw):
    kw.setdefault("interpret", default_interpret())
    return quant_rmsnorm(x, gamma, act_scale, act_zp, **kw)


def gpfq_quantize_panel(w_int, xg, xh, lam, budget_b, **kw):
    kw.setdefault("interpret", default_interpret())
    return gpfq_solve(w_int, xg, xh, lam, budget_b, **kw)


__all__ = [
    "default_interpret",
    "gpfq_quantize_panel",
    "norm_and_quantize",
    "pack_int4",
    "quantize_activations",
    "quantized_linear_w4a8",
    "unpack_int4",
    "w4a8_decode_matmul",
    "w4a8_matmul",
]
