"""Fault-tolerant checkpointing: manifest + per-leaf .npy shards, an async
writer thread, resharding restore, and retention.

Restore is *elastic*: leaves are stored as full logical arrays with a JSON
manifest of the pytree structure; on restart they are ``device_put`` against
whatever mesh/shardings the new job derives — a different pod count or a
recovered mesh shape reshards transparently. Combined with the pipeline's
pure-function-of-index batching, a preempted job resumes bit-identically.

(On a real multi-host cluster each host would write its addressable shards
and the manifest would carry the global shape + index map; the single-host
layout here keeps the same API so the launcher code does not change.)
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save_pytree(tree, directory: str, extra_meta: dict | None = None) -> None:
    """Atomic checkpoint write (tmp dir + rename)."""
    names, leaves, _ = _flatten_with_names(tree)
    parent = os.path.dirname(os.path.abspath(directory)) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=parent, prefix=".ckpt_tmp_")
    try:
        manifest = {"leaves": [], "meta": extra_meta or {}}
        for i, (name, leaf) in enumerate(zip(names, leaves)):
            arr = np.asarray(jax.device_get(leaf))
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append(
                {"name": name, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(directory):
            shutil.rmtree(directory)
        os.replace(tmp, directory)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def load_pytree(target, directory: str, shardings=None):
    """Restore into the structure of ``target`` (arrays or ShapeDtypeStructs).

    ``shardings``: optional matching pytree of NamedShardings — leaves are
    placed (and thereby resharded) directly onto the current mesh.
    """
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    names, t_leaves, treedef = _flatten_with_names(target)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    s_leaves = None
    if shardings is not None:
        s_leaves = treedef.flatten_up_to(shardings)
    out = []
    for i, (name, tl) in enumerate(zip(names, t_leaves)):
        entry = by_name.get(name)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = np.load(os.path.join(directory, entry["file"]))
        if tuple(arr.shape) != tuple(tl.shape):
            raise ValueError(f"{name}: checkpoint shape {arr.shape} != target {tl.shape}")
        arr = arr.astype(tl.dtype)
        if s_leaves is not None:
            out.append(jax.device_put(arr, s_leaves[i]))
        else:
            out.append(jax.device_put(arr))
    return treedef.unflatten(out), manifest["meta"]


class CheckpointManager:
    """Step-indexed checkpoints with retention and an async writer.

    save() snapshots to host memory synchronously (cheap, consistent) and
    writes to disk on a background thread so the train loop never blocks on
    I/O — the standard fault-tolerance pattern. ``wait()`` joins outstanding
    writes (called before exit and in tests).
    """

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(root, exist_ok=True)

    def directory(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def latest_step(self) -> int | None:
        steps = [
            int(d.split("_")[1])
            for d in os.listdir(self.root)
            if d.startswith("step_") and os.path.exists(os.path.join(self.root, d, "manifest.json"))
        ]
        return max(steps) if steps else None

    def save(self, step: int, tree, meta: dict | None = None, blocking: bool = False):
        # snapshot to host synchronously: the async writer must not race
        # against the train loop donating/overwriting device buffers
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        meta = dict(meta or {}, step=step, time=time.time())

        def work():
            save_pytree(host_tree, self.directory(step), meta)
            self._gc()

        self.wait()
        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def restore(self, step: int, target, shardings=None):
        return load_pytree(target, self.directory(step), shardings)

    def restore_latest(self, target, shardings=None):
        step = self.latest_step()
        if step is None:
            return None
        tree, meta = self.restore(step, target, shardings)
        return step, tree, meta

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.root) if d.startswith("step_")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self.directory(s), ignore_errors=True)
