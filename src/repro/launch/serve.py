"""Serving launcher: batched generation from a (possibly resumed) checkpoint.

    PYTHONPATH=src python -m repro.launch.serve --arch tiny-lm-s \
        --ckpt-dir /tmp/run1 --batch 8 --prompt-len 32 --max-new 64

``--packed`` packs the weights to the int4 serving artifact first (RTN,
dynamic activation quantization); ``--artifact DIR`` instead loads a
calibrated AXE artifact written by ``repro.launch.quantize --out`` — the
versioned schema carrying per-site DatapathSpecs and *static* activation
quantizers, so the served datapath is exactly what calibration certified.
``--packed-backend`` selects the packed-matmul datapath (auto = fused W4A8
kernel on TPU, in-graph dequant elsewhere; interpret = kernel path in
pallas interpret mode, for validation). ``--host-loop`` uses the per-token
host reference loop instead of the fused on-device generation loop.

``--paged`` serves through the paged-KV continuous-batching engine
(``repro.serving.PagedEngine``) instead of the fixed-slot engine:
``--block-size`` sets the KV page granularity, ``--max-concurrency`` the
engine slot count, ``--num-blocks`` the shared page-pool size (defaults to
enough pages for a full-length batch at ``--max-concurrency``).
``--admit-window`` / ``--admit-batch`` / ``--prefill-chunk`` /
``--watermark LOW HIGH`` switch the engine into the throughput scheduler
(windowed priority admission, batched cold prefill, chunked long-prompt
prefill, watermark reservation with preempt-and-requeue) — token streams
stay bit-identical to the default FIFO loop. See
docs/serving_scheduler.md.

``--mesh dp,tp`` serves the paged engine SPMD over a (data, model) mesh —
kv-head-sharded pools, replicated admin leaves, fully-replicated host
reads; token streams are bit-identical to the single-device engine. Under
``jax.distributed`` the same flag spans every process (docs/multihost.md;
``scripts/run_multiprocess.py`` drives the multi-process battery).
"""

from __future__ import annotations

import argparse
import contextlib
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke
from repro.data import DataConfig, TokenBatcher
from repro.models.layers import use_packed_backend
from repro.models.transformer import init_model
from repro.quant.serve_packed import (
    load_flat_artifact,
    pack_decode_params,
    packed_params_from_artifact,
)
from repro.quant.spec import tree_datapath_fingerprint
from repro.serving import GenerationEngine, PagedConfig, PagedEngine, SamplerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--packed", action="store_true",
                    help="serve from the packed-int4 W4A8 artifact")
    ap.add_argument("--artifact", type=str, default=None,
                    help="directory of a calibrated AXE artifact "
                         "(repro.launch.quantize --out); loads packed codes "
                         "+ per-site DatapathSpecs + static act quantizers")
    ap.add_argument("--packed-backend", type=str, default="auto",
                    choices=("auto", "dequant", "kernel", "interpret"))
    ap.add_argument("--host-loop", action="store_true",
                    help="per-token host loop instead of the fused device loop")
    ap.add_argument("--paged", action="store_true",
                    help="paged-KV continuous-batching engine")
    ap.add_argument("--block-size", type=int, default=64,
                    help="KV page size in tokens (--paged)")
    ap.add_argument("--max-concurrency", type=int, default=8,
                    help="engine slots for continuous batching (--paged)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="KV page-pool size (--paged); default fits "
                         "--max-concurrency full-length sequences")
    ap.add_argument("--kv-dtype", type=str, default="act",
                    choices=("act", "int8"),
                    help="KV page element type (--paged): act keeps the "
                         "model act_dtype; int8 stores quantized pages + "
                         "per-page scales (pool HBM ~halves, attention "
                         "serves the AttnDatapathSpec integer datapath)")
    ap.add_argument("--kv-hbm-mb", type=float, default=None,
                    help="size the page pool to an HBM budget (MB) instead "
                         "of --num-blocks — at int8 the same budget holds "
                         "~2x the pages, so admission capacity ~doubles")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share full prompt blocks across requests "
                         "(--paged, attention-only patterns): repeated "
                         "prefixes prefill only their uncached suffix; "
                         "pages are refcounted with LRU eviction")
    ap.add_argument("--admit-window", type=int, default=1,
                    help="queued requests one admission pass may examine "
                         "(--paged; >1 lets urgent classes jump the line)")
    ap.add_argument("--admit-batch", type=int, default=1,
                    help="max cold arrivals co-admitted through one padded "
                         "multi-row prefill program (--paged)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="prefill long prompts in page-aligned chunks of "
                         "at most this many tokens, interleaved with decode "
                         "(--paged; must be a multiple of --block-size)")
    ap.add_argument("--watermark", type=int, nargs=2, default=None,
                    metavar=("LOW", "HIGH"),
                    help="free-page watermarks (--paged): admit against a "
                         "LOW-page reserve instead of each request's worst "
                         "case; decode growth preempts-and-requeues on "
                         "exhaustion, and after a preemption fresh arrivals "
                         "wait for HIGH free pages (hysteresis)")
    ap.add_argument("--plan", type=str, default=None,
                    help="mixed-precision plan.json (repro.launch.search "
                         "--out): validates the artifact's per-site "
                         "datapaths against the plan and, with --paged "
                         "--kv-dtype int8, threads the plan's calibrated "
                         "static KV page scales into the engine")
    ap.add_argument("--mesh", type=str, default=None, metavar="DP,TP",
                    help="serve SPMD over a (data, model) mesh (--paged): "
                         "'dp,tp' whose product equals the global device "
                         "count, or 'auto' for all devices data-parallel. "
                         "Pools shard kv_heads, admin leaves replicate "
                         "(docs/multihost.md)")
    ap.add_argument("--observe", action="store_true",
                    help="attach serving saturation counters (--paged): "
                         "static-quantizer clip counts + per-site/per-head "
                         "accumulator watermarks, reported after "
                         "generation; the decode jaxpr gains only debug "
                         "callbacks (structurally asserted)")
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    params = init_model(jax.random.key(args.seed), cfg)
    if args.ckpt_dir:
        restored = CheckpointManager(args.ckpt_dir).restore_latest({"params": params})
        if restored is not None:
            _, tree, _ = restored
            params = tree["params"]
            print(f"[serve] restored step {restored[0]}")
    if args.artifact:
        flat, meta = load_flat_artifact(args.artifact)
        params = packed_params_from_artifact(flat, params, cfg, meta=meta)
        print(f"[serve] loaded artifact v{meta.get('artifact_version')} "
              f"datapath={tree_datapath_fingerprint(params)} "
              f"({meta.get('datapath', '?')})")
    elif args.packed:
        params = pack_decode_params(params, cfg)
        print("[serve] packed int4 serving params (RTN fallback, dynamic act)")

    plan = None
    if args.plan:
        from repro.quant.observe import MixedPrecisionPlan
        from repro.quant.serve_packed import plan_expected_specs
        from repro.quant.spec import DatapathSpec, validate_datapath

        if not args.artifact:
            raise SystemExit("--plan validates a calibrated artifact's "
                             "per-site datapaths (add --artifact DIR)")
        plan = MixedPrecisionPlan.load(args.plan)
        base_d = plan.meta.get("base_spec")
        if base_d is None:
            raise SystemExit(f"{args.plan} carries no base_spec meta — "
                             f"re-export with repro.launch.search")
        n = validate_datapath(
            params, plan_expected_specs(cfg, plan, DatapathSpec(**base_d)))
        print(f"[serve] plan validated: {n} per-site datapaths match "
              f"({len(plan.sites)} searched, kv={'static' if plan.kv else 'dynamic'})")

    data = TokenBatcher(
        DataConfig(vocab=cfg.vocab, seq_len=args.prompt_len,
                   global_batch=args.batch, seed=args.seed)
    )
    prompts = np.asarray(data.batch(0)["tokens"])
    sampler = SamplerConfig(temperature=args.temperature, seed=args.seed)
    if not args.paged and (args.kv_dtype != "act" or args.kv_hbm_mb is not None
                           or args.prefix_cache):
        raise SystemExit("--kv-dtype/--kv-hbm-mb/--prefix-cache apply to the "
                         "paged engine only (add --paged)")
    sched_flags = (args.admit_window != 1 or args.admit_batch != 1
                   or args.prefill_chunk is not None
                   or args.watermark is not None)
    if not args.paged and sched_flags:
        raise SystemExit("--admit-window/--admit-batch/--prefill-chunk/"
                         "--watermark apply to the paged engine only "
                         "(add --paged)")
    if args.observe and not args.paged:
        raise SystemExit("--observe applies to the paged engine only "
                         "(add --paged)")
    if args.mesh is not None and not args.paged:
        raise SystemExit("--mesh applies to the paged engine only "
                         "(add --paged)")
    if args.paged:
        if args.host_loop:
            raise SystemExit("--host-loop applies to the fixed-slot engine only")
        from repro.serving.scheduler import blocks_for_budget, kv_pool_bytes

        pages_per_seq = -(-(args.prompt_len + args.max_new - 1) // args.block_size)
        if args.kv_hbm_mb is not None and args.num_blocks is not None:
            raise SystemExit("--num-blocks and --kv-hbm-mb both size the "
                             "page pool — pass one, not both")
        if args.kv_hbm_mb is not None:
            num_blocks = blocks_for_budget(
                int(args.kv_hbm_mb * 2**20), cfg, args.block_size,
                args.kv_dtype)
            if num_blocks < pages_per_seq:
                raise SystemExit(
                    f"--kv-hbm-mb {args.kv_hbm_mb} affords {num_blocks} "
                    f"pages < the {pages_per_seq} one request needs")
        else:
            num_blocks = args.num_blocks or args.max_concurrency * pages_per_seq
        from repro.serving import SchedulerPolicy

        try:
            policy = SchedulerPolicy(
                admit_window=args.admit_window, batch_max=args.admit_batch,
                prefill_chunk=args.prefill_chunk,
                watermark=tuple(args.watermark) if args.watermark else None)
        except ValueError as e:
            raise SystemExit(f"scheduler policy: {e}") from None
        mesh = None
        if args.mesh is not None:
            from repro.launch.mesh import parse_mesh_spec

            mesh = parse_mesh_spec(args.mesh)
            print(f"[serve] mesh: {dict(mesh.shape)} over "
                  f"{len(mesh.devices.flat)} devices "
                  f"({jax.process_count()} process(es))")
        try:
            engine = PagedEngine(
                params, cfg,
                PagedConfig(block_size=args.block_size, num_blocks=num_blocks,
                            max_concurrency=args.max_concurrency,
                            kv_dtype=args.kv_dtype,
                            prefix_cache=args.prefix_cache, sched=policy),
                sampler,
                observe=args.observe,
                kv_scales=plan.kv if plan is not None else None,
                mesh=mesh,
            )
        except ValueError as e:
            raise SystemExit(f"paged engine: {e}") from None
        pool_mb = kv_pool_bytes(cfg, num_blocks, args.block_size,
                                args.kv_dtype) / 2**20
        attn_dp = (f" attn_datapath=[{engine.attn_spec.describe()}]"
                   if engine.attn_spec else "")
        pc = " prefix_cache=on" if args.prefix_cache else ""
        pol = ("" if policy.is_legacy else
               f" policy=(window={policy.admit_window} "
               f"batch={policy.batch_max} chunk={policy.prefill_chunk} "
               f"watermark={policy.watermark})")
        print(f"[serve] paged engine: block_size={args.block_size} "
              f"num_blocks={num_blocks} slots={args.max_concurrency} "
              f"kv_dtype={args.kv_dtype} pool={pool_mb:.2f}MB{pc}{pol}{attn_dp}")
        gen = engine.generate
    else:
        engine = GenerationEngine(params, cfg, sampler)
        gen = engine.generate_host_loop if args.host_loop else engine.generate
    backend_ctx = (
        use_packed_backend(args.packed_backend)
        if args.packed_backend != "auto"
        else contextlib.nullcontext()
    )
    with backend_ctx:
        gen(prompts, args.max_new)  # warm the jit bucket outside the timed region
        t0 = time.time()
        out = gen(prompts, args.max_new)
        dt = time.time() - t0
    n_new = out.shape[1] - prompts.shape[1]
    loop = "paged" if args.paged else ("host-loop" if args.host_loop else "fused")
    print(f"[serve] batch={args.batch} new_tokens={n_new} {loop} "
          f"{dt:.2f}s  {args.batch * n_new / dt:.1f} tok/s")
    print("[serve] sample:", out[0, -min(16, out.shape[1]):].tolist())
    if args.observe:
        import json as _json

        engine.assert_observation_transparent()
        rep = engine.saturation_report()
        worst = None
        for name, sec in rep["sites"].items():
            h = sec.get("headroom_bits_observed")
            if h is not None and (worst is None or h < worst[1]):
                worst = (name, h)
        print(f"[serve] observed {len(rep['sites'])} sites; "
              f"binding watermark: "
              f"{worst[0] if worst else '-'}"
              f"{f' ({worst[1]:.2f} headroom bits)' if worst else ''}")
        print("[serve] saturation report:",
              _json.dumps(rep, indent=2, default=float))
    return out


if __name__ == "__main__":
    main()
