"""Serving launcher: batched generation from a (possibly resumed) checkpoint.

    PYTHONPATH=src python -m repro.launch.serve --arch tiny-lm-s \
        --ckpt-dir /tmp/run1 --batch 8 --prompt-len 32 --max-new 64
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke
from repro.data import DataConfig, TokenBatcher
from repro.models.transformer import init_model
from repro.serving import GenerationEngine, SamplerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    params = init_model(jax.random.key(args.seed), cfg)
    if args.ckpt_dir:
        restored = CheckpointManager(args.ckpt_dir).restore_latest({"params": params})
        if restored is not None:
            _, tree, _ = restored
            params = tree["params"]
            print(f"[serve] restored step {restored[0]}")

    data = TokenBatcher(
        DataConfig(vocab=cfg.vocab, seq_len=args.prompt_len,
                   global_batch=args.batch, seed=args.seed)
    )
    prompts = np.asarray(data.batch(0)["tokens"])
    engine = GenerationEngine(
        params, cfg, SamplerConfig(temperature=args.temperature, seed=args.seed)
    )
    t0 = time.time()
    out = engine.generate(prompts, args.max_new)
    dt = time.time() - t0
    n_new = out.shape[1] - prompts.shape[1]
    print(f"[serve] batch={args.batch} new_tokens={n_new} "
          f"{dt:.2f}s  {args.batch * n_new / dt:.1f} tok/s")
    print("[serve] sample:", out[0, -min(16, out.shape[1]):].tolist())
    return out


if __name__ == "__main__":
    main()
