"""Training launcher with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch tiny-lm-s \
        --steps 300 --batch 16 --seq 128 --ckpt-dir /tmp/run1

Fault tolerance & scale features exercised here:
  * periodic async checkpoints + SIGTERM/SIGINT emergency checkpoint
    (preemption-safe);
  * automatic resume from the latest checkpoint (restart == continue:
    data pipeline skip-ahead is O(1) and bit-exact);
  * straggler watermark: per-step wall times tracked; steps slower than
    ``straggler_factor`` x the running median are logged with their rank —
    on a real cluster this feeds the controller's replace/restart policy;
  * elastic mesh: the step is built against whatever devices exist at
    start-up; a restart on a different topology reshards the checkpoint.
"""

from __future__ import annotations

import argparse
import signal
import statistics
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke
from repro.data import DataConfig, TokenBatcher
from repro.launch.mesh import make_host_mesh
from repro.optim import OptimizerConfig
from repro.runtime.sharding import batch_shardings, axis_rules
from repro.runtime.steps import (
    TrainRunConfig,
    init_train_state,
    make_train_step,
    train_state_shardings,
)


class StragglerMonitor:
    def __init__(self, factor: float = 2.0, window: int = 50):
        self.factor = factor
        self.times: list[float] = []
        self.window = window
        self.flagged = 0

    def record(self, dt: float, step: int) -> bool:
        self.times.append(dt)
        hist = self.times[-self.window :]
        if len(hist) >= 10:
            med = statistics.median(hist)
            if dt > self.factor * med:
                self.flagged += 1
                print(f"[straggler] step {step}: {dt*1e3:.0f}ms vs median "
                      f"{med*1e3:.0f}ms (proc {jax.process_index()})")
                return True
        return False


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="use reduced config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.scaled(max_seq_len=max(cfg.max_seq_len, args.seq))
    run = TrainRunConfig(
        optimizer=OptimizerConfig(lr=args.lr, total_steps=args.steps),
        num_microbatches=args.microbatches,
    )
    mesh = make_host_mesh()
    print(f"[train] arch={cfg.name} devices={mesh.devices.size} "
          f"batch={args.batch}x{args.seq}")

    data = TokenBatcher(
        DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
                   seed=args.seed)
    )
    state = init_train_state(jax.random.key(args.seed), cfg, run)
    state_sh = train_state_shardings(state, mesh)
    state = jax.device_put(state, state_sh)

    step_fn = make_train_step(cfg, run, mesh)

    def wrapped(state, batch):
        with axis_rules(mesh):
            return step_fn(state, batch)

    jstep = jax.jit(wrapped, donate_argnums=(0,))

    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir)
        restored = ckpt.restore_latest(state, state_sh)
        if restored is not None:
            start_step, state, meta = restored
            print(f"[train] resumed from step {start_step}")

    # preemption safety: emergency checkpoint on SIGTERM/SIGINT
    stop = {"now": False}

    def _sig(_s, _f):
        stop["now"] = True

    old_term = signal.signal(signal.SIGTERM, _sig)
    old_int = signal.signal(signal.SIGINT, _sig)

    monitor = StragglerMonitor()
    losses = []
    try:
        for step in range(start_step, args.steps):
            host = data.batch(step)
            batch = jax.device_put(host, batch_shardings(host, mesh))
            t0 = time.time()
            state, metrics = jstep(state, batch)
            loss = float(metrics["loss"])  # blocks: also our step timer
            dt = time.time() - t0
            monitor.record(dt, step)
            losses.append(loss)
            if step % args.log_every == 0:
                print(f"[train] step {step} loss {loss:.4f} "
                      f"ppl {float(metrics['ppl']):.2f} "
                      f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, state, {"loss": loss})
            if stop["now"]:
                print(f"[train] signal received: emergency checkpoint @ {step+1}")
                if ckpt:
                    ckpt.save(step + 1, state, {"loss": loss}, blocking=True)
                break
    finally:
        signal.signal(signal.SIGTERM, old_term)
        signal.signal(signal.SIGINT, old_int)
        if ckpt:
            if not stop["now"]:
                ckpt.save(args.steps, state, {"loss": losses[-1] if losses else None},
                          blocking=True)
            ckpt.wait()

    if losses:
        k = max(len(losses) // 10, 1)
        print(f"[train] first-{k} mean loss {np.mean(losses[:k]):.4f} -> "
              f"last-{k} mean {np.mean(losses[-k:]):.4f}")
    return state, losses


if __name__ == "__main__":
    main()
