"""Instruction-level cost model over compiled HLO text with while-loop
trip-count multiplication.

XLA's ``compiled.cost_analysis()`` counts while bodies ONCE — a known
limitation that silently undercounts any scan-based model (our stacks scan
over layers, microbatches and sequence chunks by design, so the undercount
would be 10-1000x). This module re-derives the three roofline inputs from
the compiled module text, where every while op carries
``backend_config={"known_trip_count":{"n": ...}}``:

  * FLOPs            — 2 * prod(result dims) * prod(contracting dims) per
                       ``dot``, times the product of enclosing trip counts;
  * HBM bytes        — HloCostAnalysis convention (operands + result per
                       instruction, fusions opaque), times trip counts;
  * collective bytes — result-shape bytes per collective, wire-factored
                       (all-reduce 2x, others 1x), times trip counts.

Validated against ``cost_analysis()`` on scan-free modules in
tests/test_hlo_analysis.py (FLOPs exact, bytes within a few %).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "u2": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
}

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_SKIP_OPS = {
    "parameter", "get-tuple-element", "tuple", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

_COLLECTIVES = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
    "ragged-all-to-all": 1.0,
}

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"  # result name
    r"(\([^)]*\)|[\w\[\],{}]+)\s+"  # shape: tuple (no nested parens, may
    #                                 contain /*index=N*/ comments) or array
    r"([\w\-]+)"  # opcode
    r"\((.*)$"  # operands + attrs (rest of line)
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s+\(.*\)\s+->\s+.*\{")
_TRIP_RE = re.compile(r'known_trip_count[\\"={:]+n[\\"]*:[\\"]*(\d+)')
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%([\w.\-]+), body=%([\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _ARRAY_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _array_dims(shape_str: str) -> list[int]:
    m = _ARRAY_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    rest: str  # operand list + attributes (raw)

    def operand_names(self) -> list[str]:
        # operands are inside the first balanced (...) of rest
        depth, end = 1, 0
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        inner = self.rest[:end]
        return _OPERAND_RE.findall(inner)

    @property
    def attrs(self) -> str:
        return self.rest


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    shapes: dict[str, str] = field(default_factory=dict)

    # --- fused slice metadata (computed lazily) -------------------------
    _slice_params: dict | None = None  # param idx -> slice result shape str
    _root_dus_update: str | None = None  # update shape str when root is DUS

    def fused_access_info(self):
        """For fused computations: which parameters are only touched through
        a dynamic-slice/gather (charge the slice, not the full operand), and
        whether the root is a dynamic-update-slice (charge 2x update)."""
        if self._slice_params is not None:
            return self._slice_params, self._root_dus_update
        param_idx = {}  # name -> parameter index
        consumers: dict[str, list[Instr]] = {}
        root = self.instrs[-1] if self.instrs else None
        for ins in self.instrs:
            if ins.opcode == "parameter":
                m = re.match(r"(\d+)", ins.rest)
                if m:
                    param_idx[ins.name] = int(m.group(1))
            for op in ins.operand_names():
                consumers.setdefault(op, []).append(ins)
        slice_params = {}
        for pname, idx in param_idx.items():
            cons = consumers.get(pname, [])
            if cons and all(c.opcode in ("dynamic-slice", "gather") for c in cons):
                slice_params[idx] = cons[0].shape
            elif cons and all(c.opcode == "dynamic-update-slice" for c in cons):
                # full buffer only passed through as the DUS destination
                ops = cons[0].operand_names()
                upd = self.shapes.get(ops[1]) if len(ops) > 1 else None
                if upd is not None:
                    slice_params[idx] = upd
        dus_update = None
        if root is not None and root.opcode == "dynamic-update-slice":
            ops = root.operand_names()
            if len(ops) > 1:
                dus_update = self.shapes.get(ops[1])
        self._slice_params = slice_params
        self._root_dus_update = dus_update
        return slice_params, dus_update


def parse_computations(hlo_text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = None
    current: Computation | None = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            current = Computation(m.group(1))
            comps[current.name] = current
            if line.strip().startswith("ENTRY"):
                entry = current.name
            continue
        if current is None:
            continue
        if line.strip() == "}":
            current = None
            continue
        im = _INSTR_RE.match(line)
        if im:
            ins = Instr(im.group(1), im.group(2), im.group(3), im.group(4))
            current.instrs.append(ins)
            current.shapes[ins.name] = ins.shape
    if entry is None:
        # fall back: last computation
        entry = list(comps)[-1]
    return comps, entry


def _multipliers(
    comps: dict[str, Computation], entry: str
) -> tuple[dict[str, float], set[str]]:
    """Product of enclosing while trip counts per computation.

    Returns (multiplier map, flops_only set). Computations reached through a
    ``fusion``'s ``calls=`` are *opaque for bytes* (the fusion instruction
    itself already charges operands+output, HloCostAnalysis-style) but are
    still scanned for ``dot`` FLOPs — some backends fuse dots.
    """
    mult = {entry: 1.0}
    flops_only: set[str] = set()
    stack = [entry]
    while stack:
        cname = stack.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for ins in comp.instrs:
            if ins.opcode == "while":
                cb = _COND_BODY_RE.search(ins.attrs)
                trip_m = _TRIP_RE.search(ins.attrs)
                trip = float(trip_m.group(1)) if trip_m else 1.0
                if cb:
                    cond, body = cb.group(1), cb.group(2)
                    for sub, f in ((body, trip), (cond, 1.0)):
                        nm = m * f
                        if mult.get(sub, 0.0) < nm:
                            mult[sub] = nm
                            stack.append(sub)
            else:
                for cm in _CALLS_RE.finditer(ins.attrs):
                    sub = cm.group(1)
                    if ins.opcode == "fusion":
                        flops_only.add(sub)
                    if mult.get(sub, 0.0) < m:
                        mult[sub] = m
                        stack.append(sub)
                bm = _BRANCHES_RE.search(ins.attrs)
                if bm:
                    for sub in _OPERAND_RE.findall(bm.group(1)):
                        if mult.get(sub, 0.0) < m:
                            mult[sub] = m
                            stack.append(sub)
    return mult, flops_only


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = 1
    for d in _array_dims(ins.shape):
        out_elems *= d
    ops = ins.operand_names()
    if not ops:
        return 0.0
    lhs_shape = comp.shapes.get(ops[0])
    if lhs_shape is None:
        return 0.0
    lhs_dims = _array_dims(lhs_shape)
    cm = _LHS_CONTRACT_RE.search(ins.attrs)
    contract = 1
    if cm:
        for d in cm.group(1).split(","):
            if d:
                contract *= lhs_dims[int(d)] if int(d) < len(lhs_dims) else 1
    return 2.0 * out_elems * contract


@dataclass
class HloCosts:
    flops: float = 0.0
    bytes: float = 0.0
    coll_wire_bytes: float = 0.0
    coll_by_op: dict = field(default_factory=dict)
    coll_counts: dict = field(default_factory=dict)
    n_while: int = 0
    max_trip_product: float = 1.0

    def to_dict(self):
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "coll_wire_bytes": self.coll_wire_bytes,
            "coll_by_op": self.coll_by_op,
            "coll_counts": self.coll_counts,
            "n_while": self.n_while,
            "max_trip_product": self.max_trip_product,
        }


def analyze(hlo_text: str) -> HloCosts:
    comps, entry = parse_computations(hlo_text)
    mult, flops_only = _multipliers(comps, entry)
    out = HloCosts()
    out.coll_by_op = {k: 0.0 for k in _COLLECTIVES}
    out.coll_counts = {k: 0 for k in _COLLECTIVES}

    for cname, comp in comps.items():
        m = mult.get(cname)
        if m is None:
            continue  # unreachable computation
        out.max_trip_product = max(out.max_trip_product, m)
        bytes_opaque = cname in flops_only
        for ins in comp.instrs:
            if ins.opcode == "while":
                out.n_while += 1
                continue
            if ins.opcode in _SKIP_OPS:
                continue
            if ins.opcode == "dot":
                out.flops += m * _dot_flops(ins, comp)
            if bytes_opaque:
                continue  # fusion internals: bytes charged at the call site
            # bytes: HloCostAnalysis convention — output + resolvable operands,
            # EXCEPT sliced accesses, which only touch the slice (charging the
            # full operand of a dynamic-slice would overcount a scanned stack
            # of layer params by the trip count):
            #   dynamic-slice / gather       -> 2x result (read slice + write)
            #   dynamic-update-slice/scatter -> 2x update (read + write region)
            if ins.opcode in ("dynamic-slice", "gather"):
                b = 2 * shape_bytes(ins.shape)
            elif ins.opcode in ("dynamic-update-slice", "scatter"):
                ops = ins.operand_names()
                upd = comp.shapes.get(ops[1]) if len(ops) > 1 else None
                b = 2 * shape_bytes(upd) if upd else shape_bytes(ins.shape)
            elif ins.opcode == "fusion":
                # fusions that merely slice into / update a big buffer must
                # be charged at slice granularity, not full-operand (a
                # scanned layer stack is otherwise overcounted trip times)
                called = None
                cm = _CALLS_RE.search(ins.attrs)
                if cm:
                    called = comps.get(cm.group(1))
                slice_params, dus_update = (
                    called.fused_access_info() if called else ({}, None)
                )
                b = (2 * shape_bytes(dus_update) if dus_update
                     else shape_bytes(ins.shape))
                for i, op in enumerate(ins.operand_names()):
                    if i in slice_params:
                        b += shape_bytes(slice_params[i])
                        continue
                    s = comp.shapes.get(op)
                    if s is not None:
                        b += shape_bytes(s)
            else:
                b = shape_bytes(ins.shape)
                for op in ins.operand_names():
                    s = comp.shapes.get(op)
                    if s is not None:
                        b += shape_bytes(s)
            out.bytes += m * b
            base = None
            for coll in _COLLECTIVES:
                if ins.opcode == coll or ins.opcode.startswith(coll + "-"):
                    base = coll
                    break
            if base is not None:
                cb = shape_bytes(ins.shape)
                out.coll_by_op[base] += m * cb
                out.coll_counts[base] += 1
                out.coll_wire_bytes += m * cb * _COLLECTIVES[base]
    return out
