"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

The compiled module is already SPMD-partitioned, so ``cost_analysis()``
FLOPs/bytes are *per device*. Collective bytes are not in cost_analysis;
we parse the compiled HLO text and charge each collective by its result
shape with a per-op wire factor:

    all-gather          1x result     (each device receives result-size)
    reduce-scatter      1x operand ~ result * n (we see the scattered result;
                        charge operand = result * group)  -> handled via shape
    all-reduce          2x operand    (ring RS + AG)
    all-to-all          1x operand
    collective-permute  1x operand

This is a first-order wire model; §Perf iterates on the *relative* change of
the dominant term, for which a consistent convention is what matters.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
HBM_PER_CHIP = 16e9  # bytes of HBM per v5e chip (capacity, not bandwidth)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)
_WIRE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO shape string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum result bytes per collective kind from compiled HLO text."""
    per_op: dict[str, float] = {op: 0.0 for op in _COLLECTIVE_OPS}
    counts: dict[str, int] = {op: 0 for op in _COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if not ls.startswith("%") and not ls.startswith("ROOT"):
            continue
        m = re.match(r"(?:ROOT\s+)?%[\w.\-]+\s*=\s*(\([^)]*\)|[^=\s]+)\s+([\w\-]+)", ls)
        if not m:
            continue
        shape_str, opname = m.group(1), m.group(2)
        base = None
        for op in _COLLECTIVE_OPS:
            if opname == op or opname.startswith(op + "-"):
                base = op
                break
        if base is None:
            continue
        per_op[base] += _shape_bytes(shape_str)
        counts[base] += 1
    wire = sum(_WIRE_FACTOR[op] * b for op, b in per_op.items())
    return {"bytes_by_op": per_op, "counts": counts, "wire_bytes": wire}


@dataclass
class Roofline:
    flops: float  # per device
    hbm_bytes: float  # per device
    coll_bytes: float  # per device, wire-factored
    model_flops_global: float  # 6ND (train) / 2ND (serve)
    chips: int

    compute_s: float = field(init=False)
    memory_s: float = field(init=False)
    collective_s: float = field(init=False)

    def __post_init__(self):
        self.compute_s = self.flops / PEAK_FLOPS
        self.memory_s = self.hbm_bytes / HBM_BW
        self.collective_s = self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (per device) — remat/redundancy waste."""
        per_dev_model = self.model_flops_global / self.chips
        return per_dev_model / max(self.flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / bound time: fraction of the roofline the
        step achieves if executed at the dominant term's bandwidth."""
        per_dev_model = self.model_flops_global / self.chips
        return (per_dev_model / PEAK_FLOPS) / max(self.bound_s, 1e-30)

    def to_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops,
            "hbm_bytes_per_dev": self.hbm_bytes,
            "coll_bytes_per_dev": self.coll_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops_global": self.model_flops_global,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "chips": self.chips,
        }


def model_flops(cfg, shape, n_params_active: int) -> float:
    """MODEL_FLOPS: 6 N D for training, 2 N D for forward-only serving
    (deviation from the assignment's single 6ND noted in EXPERIMENTS.md)."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_params_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_params_active * tokens
    # decode: one token per sequence
    return 2.0 * n_params_active * shape.global_batch
