"""Mixed-precision search launcher: calibrate -> observe -> search -> export.

    PYTHONPATH=src python -m repro.launch.search --arch tiny-lm-s \
        --ckpt-dir /tmp/run1 --p-bits 20 --out /tmp/run1_mixed

The closed loop (docs/mixed_precision.md):

1. **Calibrate** a uniform AXE baseline at the conservative ``--p-bits``
   register (the slack the search reclaims lives here).
2. **Observe**: join each site's overflow certificate with its calibration
   activation observer (:func:`repro.quant.observe.collect_observations`).
3. **Search**: assign per-site ``(w_bits, P_I)`` under a global
   accumulator budget (:func:`repro.quant.observe.search_plan`).
   P_I-only tightening is certificate-exact — same integer codes, smaller
   registers, re-issued certificates, *bit-identical* perplexity — so the
   searched artifact dominates the uniform one by construction.
   ``--promote-w8 N`` additionally promotes the N most register-binding
   sites to 8-bit weights (a code change: triggers re-calibration).
4. **KV** (``--kv-static``): calibrate static per-(repeat, kv-head) page
   scales from prefill ranges and fold per-head bit demotion into them
   (:mod:`repro.quant.observe.kv`) — the paged engine then drops
   requantize-on-append.
5. **Export** the v2 artifact plus ``plan.json`` — served by
   ``repro.launch.serve --artifact DIR --plan DIR/plan.json``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax

from repro.checkpoint import CheckpointManager, save_pytree
from repro.configs import get_config, get_smoke
from repro.core import PTQConfig
from repro.data import DataConfig, TokenBatcher
from repro.models.transformer import init_model
from repro.quant import calibrate_and_quantize
from repro.quant.observe import (
    apply_plan,
    collect_observations,
    observe_kv_ranges,
    plan_accumulator_bits,
    search_kv_bits,
    search_plan,
)
from repro.quant.pipeline import float_ppl, quantized_ppl
from repro.quant.serve_packed import (
    export_quantized_artifact,
    serving_params_from_quantized,
)

#: plan.meta key fields serializing the uniform base spec — enough to
#: rebuild the DatapathSpec identity (key()) for unplanned sites at serve
#: time (repro.launch.serve --plan)
BASE_SPEC_FIELDS = ("w_bits", "act_bits", "act_signed", "tile", "p_inner",
                    "static_act")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--algorithm", default="gpfq",
                    choices=("gpfq", "optq", "rtn", "ep_init"))
    ap.add_argument("--w-bits", type=int, default=4)
    ap.add_argument("--act-bits", type=int, default=8)
    ap.add_argument("--p-bits", type=int, default=20,
                    help="uniform baseline inner register (conservative on "
                         "purpose: per-site slack below it is what the "
                         "search reclaims)")
    ap.add_argument("--tile", type=int, default=128)
    ap.add_argument("--acc-budget-bits", type=int, default=None,
                    help="global sum(P_I * repeats) budget; default = the "
                         "certificate floor + margins (tightest feasible)")
    ap.add_argument("--margin-bits", type=int, default=0,
                    help="operating margin added to every site's "
                         "certificate floor before distributing slack")
    ap.add_argument("--sparsify", type=int, default=0,
                    help="mark the N most-headroomed eligible sites for 2:4 "
                         "semi-structured sparsity (code-changing move: "
                         "forces a mask-aware re-calibration)")
    ap.add_argument("--promote-w8", type=int, default=0,
                    help="promote the N most register-binding sites to "
                         "8-bit weights (changes codes: re-calibrates)")
    ap.add_argument("--kv-static", action="store_true",
                    help="calibrate static per-(repeat, kv-head) KV page "
                         "scales (drops requantize-on-append at serving)")
    ap.add_argument("--kv-bits", type=int, default=8)
    ap.add_argument("--kv-low-bits", type=int, default=None,
                    help="demote low-range kv heads to this many bits "
                         "(folded into the static scale)")
    ap.add_argument("--kv-low-frac", type=float, default=0.25)
    ap.add_argument("--calib-batches", type=int, default=4)
    ap.add_argument("--calib-batch-size", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--eval-batches", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    data = TokenBatcher(
        DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                   global_batch=args.calib_batch_size, seed=args.seed)
    )
    params = init_model(jax.random.key(args.seed), cfg)
    if args.ckpt_dir:
        restored = CheckpointManager(args.ckpt_dir).restore_latest(
            {"params": params})
        if restored is None:
            raise SystemExit(f"no checkpoint under {args.ckpt_dir}")
        _, tree, _ = restored
        params = tree["params"] if "params" in tree else tree

    ptq = PTQConfig(
        w_bits=args.w_bits, act_bits=args.act_bits, p_bits=args.p_bits,
        tile=args.tile, algorithm=args.algorithm, constrain=True,
    )
    calib = [data.batch(10_000 + i) for i in range(args.calib_batches)]
    evalb = list(data.eval_batches(args.eval_batches))

    # 1. uniform baseline
    qm = calibrate_and_quantize(params, cfg, calib, ptq)
    cert_u = qm.cert_summary()
    ppl_u = quantized_ppl(qm, evalb)

    # 2-3. observe + search
    report = collect_observations(qm)
    plan = search_plan(report, acc_budget_bits=args.acc_budget_bits,
                       margin_bits=args.margin_bits,
                       promote_w8=args.promote_w8,
                       sparsify=args.sparsify)
    base = dataclasses.replace(ptq.to_datapath_spec(cfg.d_model),
                               static_act=True)
    plan.meta["base_spec"] = {k: getattr(base, k) for k in BASE_SPEC_FIELDS}

    if args.promote_w8 or args.sparsify:
        # w_bits / sparsity moves change the codes: the plan must drive a
        # fresh constrained solve, not a re-spec of the existing codes
        qm2 = calibrate_and_quantize(params, cfg, calib, ptq, plan=plan)
    else:
        # P_I-only: certificate-exact re-spec, bit-identical outputs
        qm2 = apply_plan(qm, plan)
    cert_s = qm2.cert_summary()
    ppl_s = quantized_ppl(qm2, evalb)

    # 4. optional calibrated static KV scales (observed on the *serving*
    # tree — the equalization-folded datapath prefill actually runs)
    if args.kv_static:
        sp = serving_params_from_quantized(qm2)
        ranges = observe_kv_ranges(sp, cfg, calib)
        plan.kv = search_kv_bits(ranges, kv_bits=args.kv_bits,
                                 low_bits=args.kv_low_bits,
                                 low_frac=args.kv_low_frac)

    searched_bits = plan_accumulator_bits(plan, report)
    report_out = {
        "arch": cfg.name,
        "uniform": {
            "p_bits": args.p_bits,
            "accumulator_bits": report.accumulator_bits(),
            "ppl": ppl_u,
            "cert": cert_u,
        },
        "searched": {
            "accumulator_bits": searched_bits,
            "ppl": ppl_s,
            "cert": cert_s,
            "plan_sites": {k: v.p_inner for k, v in plan.sites.items()},
            "promoted_w8": plan.meta.get("promoted_w8", []),
            "sparsified": plan.meta.get("sparsified", []),
            "kv_static": bool(plan.kv),
        },
        "savings_rate": report.accumulator_bits() / max(searched_bits, 1),
        "observe": report.summary(),
    }
    print(json.dumps(report_out, indent=2, default=float))

    if args.out:
        os.makedirs(args.out, exist_ok=True)
        artifact, meta = export_quantized_artifact(qm2)
        save_pytree(artifact, os.path.join(args.out, "quantized"),
                    {**meta, "plan": "plan.json"})
        plan.save(os.path.join(args.out, "plan.json"))
        print(f"[search] artifact v{meta['artifact_version']} "
              f"({len(artifact)} leaves) + plan.json -> {args.out}")
    return report_out


if __name__ == "__main__":
    main()
