import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "REPRO_DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512"
)
# ^ MUST precede every other import (jax locks the device count on first
# init). Tests override via REPRO_DRYRUN_XLA_FLAGS in a subprocess.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
placeholder devices and extract the roofline terms (EXPERIMENTS.md §Dry-run
/ §Roofline).

    PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx-132b \
        --shape train_4k --mesh single --out results/
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Success here proves the distribution config is coherent: sharding
mismatches, compile-time OOM analysis and unsupported collectives all
surface as hard failures.
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import (
    ARCH_IDS,
    SHAPES,
    applicable,
    batch_specs,
    cache_specs,
    get_config,
)
from repro.launch.analysis import HBM_PER_CHIP, ICI_BW, Roofline, model_flops
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.models.config import param_count
from repro.optim import OptimizerConfig
from repro.runtime.steps import (
    TrainRunConfig,
    lower_decode_step,
    lower_prefill_step,
    lower_train_step,
)

# per-(arch) training overrides: microbatching bounds activation memory on
# the big cells; bf16 moments keep 400B-class optimizer state inside HBM.
TRAIN_OVERRIDES: dict[str, TrainRunConfig] = {}


def train_run_config(arch: str, cfg, shape) -> TrainRunConfig:
    if arch in TRAIN_OVERRIDES:
        return TRAIN_OVERRIDES[arch]
    n = param_count(cfg)["total"]
    big = n > 2e10
    moment_dtype = "bfloat16" if big else "float32"
    accum = "bfloat16" if big else "float32"
    # microbatching bounds activation + logits memory; 8 keeps the
    # per-microbatch global batch (32) divisible by both mesh data extents
    # (16 single-pod, 2x16 multi-pod)
    return TrainRunConfig(
        optimizer=OptimizerConfig(moment_dtype=moment_dtype),
        num_microbatches=8,
        accum_dtype=accum,
    )


def run_cell(arch: str, shape_name: str, mesh, mesh_label: str,
             quantized: bool = False) -> dict:
    cfg = get_config(arch)
    if os.environ.get("REPRO_PERF_BASELINE") == "1":
        # §Perf A/B: pre-iteration defaults (stepwise recurrent prefill,
        # unpadded vocab; MoE legacy sharding via the moe.py env switch)
        cfg = cfg.scaled(prefill_mode="stepwise", vocab_pad_multiple=1)
    shape = SHAPES[shape_name]
    chips = mesh.devices.size
    t0 = time.time()
    bspec = batch_specs(cfg, shape)

    if shape.kind == "train":
        run = train_run_config(arch, cfg, shape)
        _, lowered, _ = lower_train_step(cfg, run, mesh, bspec)
    elif shape.kind == "prefill":
        _, lowered, _ = lower_prefill_step(cfg, mesh, bspec, max_len=shape.seq_len)
    else:
        cspec = cache_specs(cfg, shape)
        _, lowered, _ = lower_decode_step(cfg, mesh, bspec, cspec,
                                          quantized=quantized)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # old jax: one dict per device
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    # trip-count-corrected instruction-level costs (XLA's cost_analysis
    # counts while bodies once — see hlo_analysis module docstring)
    parsed = analyze(hlo)

    pc = param_count(cfg)
    hbm_bytes = parsed.bytes
    quant_correction = None
    if quantized:
        # the in-graph dequant is charged at unfused bf16 rates by the HLO
        # byte parser; on TPU it fuses into the GEMM's VMEM pipeline (the
        # w4a8_mm kernel datapath), so weight HBM traffic is the packed
        # 0.5 B/elem. Correct: remove (fusion-out 2B + dot-read 2B) per
        # weight element (packed read stays charged by the parser).
        from repro.quant.serve_packed import packed_weight_bytes

        wb = packed_weight_bytes(cfg)
        overcount = 4.0 * wb["weight_elems"] / chips
        quant_correction = {
            "raw_bytes_per_dev": parsed.bytes,
            "removed_unfused_dequant_bytes_per_dev": overcount,
            **{k: v for k, v in wb.items()},
        }
        hbm_bytes = max(parsed.bytes - overcount, 0.0)
    rl = Roofline(
        flops=parsed.flops,
        hbm_bytes=hbm_bytes,
        coll_bytes=parsed.coll_wire_bytes,
        model_flops_global=model_flops(cfg, shape, pc["active"]),
        chips=chips,
    )
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_label,
        "chips": chips,
        "quantized": quantized,
        "quant_correction": quant_correction,
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_per_device": mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "collectives": {
            "bytes_by_op": parsed.coll_by_op,
            "counts": parsed.coll_counts,
            "wire_bytes": parsed.coll_wire_bytes,
        },
        "hlo_stats": {
            "n_while": parsed.n_while,
            "max_trip_product": parsed.max_trip_product,
            "xla_cost_analysis_flops_uncorrected": float(cost.get("flops", 0.0)),
            "xla_cost_analysis_bytes_uncorrected": float(cost.get("bytes accessed", 0.0)),
        },
        "roofline": rl.to_dict(),
        "params": pc,
    }
    print(compiled.memory_analysis())
    ca_scalars = {k: v for k, v in cost.items() if isinstance(v, (int, float))}
    print(json.dumps({k: ca_scalars[k] for k in ("flops", "bytes accessed") if k in ca_scalars}))
    return result


#: default targets for --paged-budget: the three production-scale serving
#: archs whose KV pools the mesh-sharded engine is meant to hold
BUDGET_ARCHS = ("llama3-405b", "dbrx-132b", "jamba-1.5-large-398b")


def _sharded_bytes(tree, shardings, mesh) -> float:
    """Per-device bytes of an abstract pytree under *resolved* shardings:
    each leaf is divided by the product of the mesh-axis sizes its
    PartitionSpec actually uses — a replicated leaf divides by 1, so
    divisibility fallbacks (e.g. a kv-head count the model axis does not
    divide) surface as real budget, not optimistic /chips arithmetic."""
    import numpy as np

    total = 0.0
    for leaf, sh in zip(jax.tree.leaves(tree),
                        jax.tree.leaves(shardings,
                                        is_leaf=lambda x: hasattr(x, "spec"))):
        factor = 1
        for entry in sh.spec:
            for ax in ((entry,) if isinstance(entry, str) else (entry or ())):
                factor *= mesh.shape[ax]
        total += leaf.size * np.dtype(leaf.dtype).itemsize / factor
    return total


def paged_budget(arch: str, mesh, mesh_label: str, *, block_size: int = 64,
                 num_slots: int = 64, kv_dtype: str = "act") -> dict:
    """Analytic HBM + interconnect budget for mesh-sharded paged serving.

    Weight and cache bytes come from the *real* sharding resolution
    (``runtime.sharding.paged_engine_shardings`` on abstract leaves), not
    from naive division by the chip count. The page pool's per-device
    cost is measured as the finite difference between a 2-page and a
    1-page abstract cache — pool leaves scale with ``num_blocks`` while
    recurrent per-slot state (Jamba's Mamba layers) and the admin leaves
    do not — and the leftover HBM is converted into the largest pool via
    ``scheduler.blocks_for_budget`` arithmetic. The interconnect side is
    the first-order decode floor: every layer's TP all-reduce of the
    d_model residual, at the ring convention (2x operand bytes) of
    :mod:`repro.launch.analysis`, over ICI_BW.
    """
    import numpy as np

    from repro.models.transformer import abstract_params, init_paged_cache
    from repro.runtime.sharding import paged_engine_shardings

    cfg = get_config(arch)
    params = abstract_params(cfg)
    max_pages = -(-cfg.max_seq_len // block_size)

    def cache_bytes_per_dev(num_blocks: int) -> float:
        cache = init_paged_cache(cfg, num_slots=num_slots,
                                 num_blocks=num_blocks,
                                 block_size=block_size, max_pages=max_pages,
                                 abstract=True,
                                 kv_dtype=None if kv_dtype == "act" else kv_dtype)
        p_sh, c_sh = paged_engine_shardings(params, cache, cfg, mesh)
        return _sharded_bytes(cache, c_sh, mesh), p_sh

    b1, p_sh = cache_bytes_per_dev(1)
    b2, _ = cache_bytes_per_dev(2)
    page_bytes_per_dev = b2 - b1
    fixed_cache_bytes_per_dev = b1 - page_bytes_per_dev
    weight_bytes_per_dev = _sharded_bytes(params, p_sh, mesh)

    kv_budget = HBM_PER_CHIP - weight_bytes_per_dev - fixed_cache_bytes_per_dev
    max_blocks = int(kv_budget // page_bytes_per_dev) if kv_budget > 0 else 0
    # decode interconnect floor: one d_model all-reduce per block output,
    # 2x operand wire bytes (ring reduce-scatter + all-gather)
    n_blocks_model = cfg.repeats * len(cfg.pattern)
    act_bytes = np.dtype(cfg.act_dtype).itemsize
    wire_per_tok = 2.0 * n_blocks_model * cfg.d_model * act_bytes
    return {
        "arch": arch,
        "mesh": mesh_label,
        "mesh_shape": {k: v for k, v in mesh.shape.items()},
        "chips": int(mesh.devices.size),
        "block_size": block_size,
        "num_slots": num_slots,
        "kv_dtype": kv_dtype,
        "hbm_per_chip_bytes": HBM_PER_CHIP,
        "weight_bytes_per_dev": weight_bytes_per_dev,
        "fixed_cache_bytes_per_dev": fixed_cache_bytes_per_dev,
        "kv_page_bytes_per_dev": page_bytes_per_dev,
        "kv_hbm_budget_per_dev": max(kv_budget, 0.0),
        "max_pool_blocks": max_blocks,
        "pool_token_capacity": max_blocks * block_size,
        "max_concurrent_max_seq": (max_blocks // max_pages) if max_pages else 0,
        "fits": bool(max_blocks >= 1),
        "interconnect": {
            "decode_wire_bytes_per_tok_per_dev": wire_per_tok,
            "decode_ici_floor_us_per_tok": 1e6 * wire_per_tok / ICI_BW,
        },
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--mesh-shape", type=str, default=None,
                    help="override, e.g. '2,4' or '2,2,2' (tests)")
    ap.add_argument("--all", action="store_true", help="every applicable cell")
    ap.add_argument("--quantized", action="store_true",
                    help="decode with the packed-int4 W4A8 serving artifact")
    ap.add_argument("--paged-budget", action="store_true",
                    help="analytic mesh-sharded paged-serving HBM/ICI "
                         "budgets (no compile) for --arch or the "
                         f"production serving archs {BUDGET_ARCHS}")
    ap.add_argument("--kv-dtype", choices=("act", "int8"), default="act",
                    help="page pool element type for --paged-budget")
    ap.add_argument("--block-size", type=int, default=64,
                    help="page size for --paged-budget")
    ap.add_argument("--out", type=str, default=None, help="output dir for JSON")
    args = ap.parse_args(argv)

    meshes = []
    if args.mesh_shape:
        shape = tuple(int(x) for x in args.mesh_shape.split(","))
        meshes.append((make_mesh(shape), args.mesh_shape))
    else:
        if args.mesh in ("single", "both"):
            meshes.append((make_production_mesh(multi_pod=False), "single"))
        if args.mesh in ("multi", "both"):
            meshes.append((make_production_mesh(multi_pod=True), "multi"))

    if args.paged_budget:
        failures = 0
        archs = (args.arch,) if args.arch else BUDGET_ARCHS
        for arch in archs:
            for mesh, label in meshes:
                try:
                    b = paged_budget(arch, mesh, label,
                                     block_size=args.block_size,
                                     kv_dtype=args.kv_dtype)
                except Exception as e:
                    failures += 1
                    print(f"[dryrun] FAIL paged-budget {arch}|{label}: "
                          f"{type(e).__name__}: {e}")
                    continue
                verdict = "OK  " if b["fits"] else "OOM "
                if not b["fits"]:
                    failures += 1
                print(f"[dryrun] {verdict}paged-budget {arch}|{label}  "
                      f"weights={b['weight_bytes_per_dev'] / 1e9:.1f}GB/dev "
                      f"pool={b['max_pool_blocks']}blocks"
                      f"({b['pool_token_capacity']}tok) "
                      f"ici_floor={b['interconnect']['decode_ici_floor_us_per_tok']:.0f}us/tok")
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    fname = f"{arch}__paged_budget__{label}.json".replace("/", "_")
                    with open(os.path.join(args.out, fname), "w") as f:
                        json.dump(b, f, indent=1)
        return 1 if failures else 0

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            for sname, sp in SHAPES.items():
                if applicable(cfg, sp):
                    cells.append((arch, sname))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells.append((args.arch, args.shape))

    failures = 0
    suffix = "__w4a8" if args.quantized else ""
    for arch, sname in cells:
        for mesh, label in meshes:
            tag = f"{arch}|{sname}{suffix}|{label}"
            try:
                result = run_cell(arch, sname, mesh, label,
                                  quantized=args.quantized)
                print(f"[dryrun] OK   {tag}  compile={result['compile_s']}s "
                      f"dominant={result['roofline']['dominant']}")
            except Exception as e:
                failures += 1
                result = {
                    "arch": arch, "shape": sname, "mesh": label,
                    "status": "fail", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:],
                }
                print(f"[dryrun] FAIL {tag}: {type(e).__name__}: {e}")
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                fname = f"{arch}__{sname}{suffix}__{label}.json".replace("/", "_")
                with open(os.path.join(args.out, fname), "w") as f:
                    json.dump(result, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
