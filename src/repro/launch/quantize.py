"""PTQ launcher: checkpoint -> calibration -> AXE quantization -> certified
quantized artifact.

    PYTHONPATH=src python -m repro.launch.quantize --arch tiny-lm-s \
        --ckpt-dir /tmp/run1 --algorithm gpfq --w-bits 4 --act-bits 8 \
        --p-bits 16 --tile 128 --out /tmp/run1_w4a8
"""

from __future__ import annotations

import argparse
import json
import os
from dataclasses import replace

import jax

from repro.checkpoint import CheckpointManager, save_pytree
from repro.configs import get_config, get_smoke
from repro.core import PTQConfig
from repro.data import DataConfig, TokenBatcher
from repro.models.transformer import init_model
from repro.quant import calibrate_and_quantize
from repro.quant.pipeline import float_ppl, quantized_ppl
from repro.quant.serve_packed import export_quantized_artifact


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--algorithm", default="gpfq",
                    choices=("gpfq", "optq", "rtn", "ep_init"))
    ap.add_argument("--w-bits", type=int, default=4)
    ap.add_argument("--act-bits", type=int, default=8)
    ap.add_argument("--p-bits", type=int, default=16)
    ap.add_argument("--tile", type=int, default=128)
    ap.add_argument("--no-constrain", action="store_true",
                    help="unconstrained Base algorithm (Table 1)")
    ap.add_argument("--sparsity", default=None, choices=("2:4",),
                    help="2:4 semi-structured weight sparsity: mask-aware "
                         "solve, certificates against the halved effective "
                         "depth (sites with K %% 4 != 0 stay dense)")
    ap.add_argument("--calib-batches", type=int, default=4)
    ap.add_argument("--calib-batch-size", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--eval-batches", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    data = TokenBatcher(
        DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                   global_batch=args.calib_batch_size, seed=args.seed)
    )

    params = init_model(jax.random.key(args.seed), cfg)
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir)
        restored = ckpt.restore_latest({"params": params})
        if restored is None:
            raise SystemExit(f"no checkpoint under {args.ckpt_dir}")
        _, tree, _ = restored
        params = tree["params"] if "params" in tree else tree

    ptq = PTQConfig(
        w_bits=args.w_bits,
        act_bits=args.act_bits,
        p_bits=args.p_bits,
        tile=args.tile,
        algorithm=args.algorithm,
        constrain=not args.no_constrain,
        sparsity=args.sparsity,
    )
    calib = [data.batch(10_000 + i) for i in range(args.calib_batches)]
    evalb = list(data.eval_batches(args.eval_batches))

    qm = calibrate_and_quantize(params, cfg, calib, ptq)
    cert = qm.cert_summary()
    ppl_f = float_ppl(params, cfg, evalb)
    ppl_q = quantized_ppl(qm, evalb)
    report = {
        "arch": cfg.name,
        "ptq": {k: getattr(ptq, k) for k in
                ("w_bits", "act_bits", "p_bits", "tile", "algorithm", "constrain")},
        "cert": cert,
        "float_ppl": ppl_f,
        "quant_ppl": ppl_q,
        "naive_p_star_K_dmodel": ptq.naive_p_star(cfg.d_model),
        "outer_bits_K_dmodel": ptq.outer_bits(cfg.d_model),
        # exported artifacts always carry the calibrated static act
        # quantizers, so describe the datapath as served, not as configured
        "datapath": replace(
            ptq.to_datapath_spec(cfg.d_model), static_act=True
        ).describe(),
    }
    print(json.dumps(report, indent=2, default=float))

    if args.out:
        os.makedirs(args.out, exist_ok=True)
        # registry-driven: every site of every family (incl. expert-stacked
        # MoE weights) lands in the artifact under its qualified name,
        # together with its DatapathSpec (static act quantizer included),
        # corrected bias, and the equalization-folded norms/routers — the
        # versioned schema repro.launch.serve --artifact reloads
        artifact, meta = export_quantized_artifact(qm)
        save_pytree(artifact, os.path.join(args.out, "quantized"),
                    {**meta, **report})
        print(f"[quantize] artifact v{meta['artifact_version']} "
              f"({len(artifact)} leaves) -> {args.out}/quantized")
    return report


if __name__ == "__main__":
    main()
