"""Mesh factories. A FUNCTION, not a module-level constant — importing this
module never touches jax device state (required by the dry-run contract).

Production target: TPU v5e, 256 chips/pod as a 16x16 (data, model) mesh;
multi-pod adds a leading DCN "pod" axis (2 pods = 512 chips). ``make_mesh``
is the elastic entry point: any (pod, data, model) shape whose product
matches the available device count works with the same sharding rules
(divisibility fallbacks degrade per-tensor annotations gracefully).
"""

from __future__ import annotations

import jax


def _mk(shape, axes):
    try:
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    except (AttributeError, TypeError):
        # older jax: no AxisType / no axis_types kwarg — Auto is the default
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape: tuple[int, ...]):
    """Elastic mesh: 1D -> (data,), 2D -> (data, model), 3D -> (pod, data, model)."""
    axes = {1: ("data",), 2: ("data", "model"), 3: ("pod", "data", "model")}[len(shape)]
    return _mk(tuple(shape), axes)


def make_host_mesh():
    """All locally visible devices as a data-parallel mesh (tests/smoke)."""
    n = len(jax.devices())
    return _mk((n,), ("data",))


def parse_mesh_spec(spec: str):
    """``"dp,tp"`` (e.g. ``"2,4"``) -> a global (data, model) serving mesh.

    The product must equal the *global* device count — under
    ``jax.distributed`` that spans every process, so each host passes the
    same spec and gets the same mesh (device order is the global
    ``jax.devices()`` order, identical on all processes). ``"auto"``
    spreads all devices over the data axis."""
    if spec == "auto":
        return make_mesh((len(jax.devices()),))
    try:
        shape = tuple(int(x) for x in spec.split(","))
    except ValueError:
        raise SystemExit(
            f"--mesh {spec!r} is not 'dp,tp' integers (e.g. '2,4') or 'auto'")
    if len(shape) != 2 or any(s < 1 for s in shape):
        raise SystemExit(
            f"--mesh {spec!r}: exactly two positive factors dp,tp expected")
    n = len(jax.devices())
    if shape[0] * shape[1] != n:
        raise SystemExit(
            f"--mesh {spec!r}: dp*tp = {shape[0] * shape[1]} but "
            f"{n} global devices are visible")
    return make_mesh(shape)
