"""Core layers: norms, RoPE, GQA attention (full / online-softmax chunked /
decode), MLPs, embeddings. Pure-JAX pytree parameters (dicts of arrays) —
no framework dependency, fully shardable under pjit.

Activation sharding annotations go through
:func:`repro.runtime.sharding.logical_constraint` so the same model code runs
single-device (tests) and on the production mesh (dry-run / launcher).
"""

from __future__ import annotations

import math
import os
import threading
from contextlib import contextmanager

import jax
import jax.numpy as jnp

from .config import ModelConfig


def _dtype(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# Packed-weight matmul dispatch (the W4A8 serving datapath)
# ---------------------------------------------------------------------------
# Backends:
#   dequant   — unpack int4 -> bf16 in-graph, then a dense matmul. The
#               CPU / interpretability fallback (and the pre-kernel
#               behavior); XLA fuses the unpack into the consumer, but the
#               weights still transit the matmul at full width.
#   kernel    — the fused repro.kernels.w4a8_mm Pallas datapath: dynamic
#               int8 activation quantization + packed-int4 integer GEMM
#               with dequant fused in the epilogue. TPU only.
#   interpret — the kernel path with pallas interpret=True: exact same
#               graph/dataflow, runs anywhere (tests, CPU validation).
#   auto      — kernel on TPU, dequant elsewhere (the default).
_PACKED_BACKENDS = ("auto", "dequant", "kernel", "interpret")
_packed_state = threading.local()


def packed_backend() -> str:
    """Resolve the active packed-matmul backend to a concrete one."""
    mode = getattr(_packed_state, "override", None) or os.environ.get(
        "REPRO_PACKED_BACKEND", "auto"
    )
    if mode not in _PACKED_BACKENDS:
        raise ValueError(f"packed backend {mode!r} not in {_PACKED_BACKENDS}")
    if mode == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "dequant"
    return mode


@contextmanager
def use_packed_backend(mode: str):
    """Force a packed-matmul backend for the enclosed trace (tests/benches)."""
    prev = getattr(_packed_state, "override", None)
    _packed_state.override = mode
    try:
        yield
    finally:
        _packed_state.override = prev


# ---------------------------------------------------------------------------
# Serving-side observation (saturation counters)
# ---------------------------------------------------------------------------
# Off-hot-path observer seam: when BOTH an observer is attached
# (attach_observer) AND a site scope is active (site_scope — the paged
# decode body sets one per pattern slot), pmm reports each packed site's
# static-quantizer clip count and activation-code extrema to the observer
# through jax.debug.callback. With no observer attached (the default) the
# checks are plain-Python None tests at trace time: the serving jaxpr is
# byte-identical — asserted by PagedEngine.assert_observation_transparent.
# Prefill/admit traces never set a scope, so they stay clean even while
# observing (the counters are a *decode* telemetry channel).
_observe_state = threading.local()


def active_observer():
    """The attached SaturationCounters-like observer, or None."""
    return getattr(_observe_state, "observer", None)


@contextmanager
def attach_observer(obs):
    """Attach a serving observer (repro.quant.observe.SaturationCounters)
    for the enclosed traces/executions."""
    prev = getattr(_observe_state, "observer", None)
    _observe_state.observer = obs
    try:
        yield
    finally:
        _observe_state.observer = prev


@contextmanager
def site_scope(label: str):
    """Name the current component ("slot0/mixer") so packed sites report
    under slot-granular labels matching the mixed-precision plan keys."""
    prev = getattr(_observe_state, "scope", None)
    _observe_state.scope = label
    try:
        yield
    finally:
        _observe_state.scope = prev


def _record_site_observation(obs, label: str, x, leaf) -> None:
    """Emit one site's observation into the traced graph: static-quantizer
    pre-clip count + code extrema, delivered host-side via debug.callback
    (nothing heavier — watermark math runs at report time)."""
    from functools import partial

    spec = leaf_spec(leaf)
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    if spec.static_act and "act_scale" in leaf:
        from repro.core.alphabet import act_alphabet

        alpha = act_alphabet(spec.act_bits, signed=spec.act_signed)
        scale = leaf["act_scale"].astype(jnp.float32).reshape(())
        zp = leaf["act_zp"].astype(jnp.float32).reshape(())
        raw = jnp.rint(x2 / scale) + zp
        n_clip = jnp.sum(((raw < alpha.qmin) | (raw > alpha.qmax)).astype(jnp.int32))
        codes = jnp.clip(raw, alpha.qmin, alpha.qmax)
    else:
        from repro.kernels.ops import quantize_activations

        codes, _, _ = quantize_activations(x2)
        codes = codes.astype(jnp.float32)
        n_clip = jnp.zeros((), jnp.int32)
    jax.debug.callback(
        partial(obs.record, label, int(x2.size)),
        n_clip, jnp.min(codes), jnp.max(codes),
    )


def is_packed(v) -> bool:
    return isinstance(v, dict) and "packed" in v


def is_dequant_site(v) -> bool:
    """A high-precision site leaf from a calibrated artifact whose codes
    have no int4 container (w_bits > 4 / odd K): {"w": dequantized weight,
    "bias": corrected bias}. Serves in float, but keeps the bias-corrected
    function the certificate was issued for."""
    return isinstance(v, dict) and "w" in v and "packed" not in v


def dequant_weight(leaf):
    """In-graph dequantization of a packed leaf (the fallback datapath).

    2:4 sparse-compressed leaves (a ``meta`` index leaf beside the packed
    codes) expand through the gather reference — bit-identical integer
    codes to the dense-with-zeros layout they were compressed from."""
    from repro.kernels.w4a8_mm import unpack_int4, unpack_sparse24

    if "meta" in leaf:
        q = unpack_sparse24(leaf["packed"], leaf["meta"])
    else:
        q = unpack_int4(leaf["packed"])
    return q.astype(leaf["scale"].dtype) * leaf["scale"]


def leaf_spec(leaf):
    """The :class:`~repro.quant.spec.DatapathSpec` governing a packed leaf.

    Trace-safe: only the static ``spec`` node is consulted (the ``spec_arr``
    array twin is for persistence — decode it outside traces via
    ``repro.quant.spec.leaf_datapath`` / ``serve_packed.ensure_datapath_spec``).
    Legacy leaves without a spec get the default recipe datapath, which is
    exactly the behavior they were packed under.
    """
    from repro.quant.spec import DatapathSpec

    spec = leaf.get("spec")
    return spec if spec is not None else DatapathSpec()


def _static_act_codes(x2, leaf, spec):
    """Activation codes from the artifact's calibrated static quantizer —
    pure elementwise ops, no data-dependent max/min reduction in the graph
    (the serving-time half of the end-to-end certificate). The code range
    comes from the same alphabet the certificate math used
    (repro.core.alphabet), so serving cannot diverge from certification."""
    from repro.core.alphabet import act_alphabet

    scale = leaf["act_scale"].astype(jnp.float32).reshape(())
    zp = leaf["act_zp"].astype(jnp.float32).reshape(())
    alpha = act_alphabet(spec.act_bits, signed=spec.act_signed)
    codes = jnp.clip(jnp.rint(x2.astype(jnp.float32) / scale) + zp,
                     alpha.qmin, alpha.qmax)
    return codes.astype(jnp.int8 if spec.act_signed else jnp.uint8), scale, zp


def packed_linear(x, leaf, *, spec=None, assert_inner: bool = False):
    """x: (..., K) @ packed leaf (K//2, N) -> (..., N), dispatched to the
    fused W4A8 kernel (kernel/interpret backends) or the in-graph dequant
    fallback. The kernel path never materializes the full bf16 weight: the
    zero-point ``col_sums`` term comes precomputed from the packed artifact
    and the int4 codes are unpacked block-by-block inside the epilogue.

    The accumulation datapath — K-tile size T, inner width P_I — and the
    activation quantizer come from the leaf's embedded
    :class:`~repro.quant.spec.DatapathSpec`, NOT from kwargs: the artifact
    is the single source of truth for what was certified. Passing ``spec``
    here is a *request*, and a request that disagrees with the artifact
    raises :class:`~repro.quant.spec.DatapathMismatchError` instead of
    silently preferring either side. When the artifact carries calibrated
    ``act_scale``/``act_zp`` leaves, activations are quantized statically
    (no dynamic per-tensor max reduction in the serving graph); otherwise
    the dynamic ``quantize_activations`` fallback runs.

    The P_I bound is only a *guarantee* for AXE-constrained codes
    (launch.quantize artifacts) — RTN-packed leaves carry no l1 budget and
    can trip ``assert_inner``. NOTE: the backend and the spec are read at
    trace time; any jit wrapping this must put the resolved
    ``packed_backend()`` and the tree's datapath fingerprint in its cache
    key (GenerationEngine does) or retrace when either changes.
    """
    embedded = leaf.get("spec")
    if spec is not None and embedded is not None:
        embedded.require_matches(spec, context="packed_linear")
    resolved = embedded if embedded is not None else spec
    if resolved is None:
        resolved = leaf_spec(leaf)

    # A 2:4-compressed leaf carries a "meta" index leaf; the spec and the
    # leaf layout must agree or the decode would silently mis-expand.
    if (resolved.sparsity is not None) != ("meta" in leaf):
        from repro.quant.spec import DatapathMismatchError

        raise DatapathMismatchError(
            "packed_linear: datapath field 'sparsity' disagrees with the leaf "
            f"layout (spec sparsity={resolved.sparsity!r}, leaf "
            f"{'carries' if 'meta' in leaf else 'lacks'} a 2:4 metadata leaf)"
        )

    backend = packed_backend()
    if backend == "dequant":
        y = x @ dequant_weight(leaf)
        if "bias" in leaf:
            y = y + leaf["bias"].reshape(-1).astype(y.dtype)
        return y

    from repro.kernels.w4a8_mm import (
        datapath_kernel_args,
        unpack_int4,
        unpack_sparse24,
        w4a8_decode_matmul,
        w4a8_sparse_decode_matmul,
    )

    *lead, k = x.shape
    x2 = x.reshape(-1, k)
    if resolved.static_act and "act_scale" in leaf:
        codes, act_scale, act_zp = _static_act_codes(x2, leaf, resolved)
    else:
        from repro.kernels.ops import quantize_activations

        codes, act_scale, act_zp = quantize_activations(x2)
    col_sums = leaf.get("col_sums")
    if col_sums is None:  # legacy artifact without the pack-time term
        if "meta" in leaf:
            col_sums = jnp.sum(
                unpack_sparse24(leaf["packed"], leaf["meta"]).astype(jnp.int32),
                axis=-2,
            )
        else:
            col_sums = jnp.sum(
                unpack_int4(leaf["packed"]).astype(jnp.int32), axis=-2
            )
    if "meta" in leaf:
        y = w4a8_sparse_decode_matmul(
            codes,
            leaf["packed"],
            leaf["meta"],
            leaf["scale"].reshape(-1).astype(jnp.float32),
            col_sums.reshape(-1),
            act_scale,
            act_zp,
            **datapath_kernel_args(resolved),
            assert_inner=assert_inner,
            interpret=(backend == "interpret"),
            out_dtype=x.dtype,
        )
    else:
        y = w4a8_decode_matmul(
            codes,
            leaf["packed"],
            leaf["scale"].reshape(-1).astype(jnp.float32),
            col_sums.reshape(-1),
            act_scale,
            act_zp,
            **datapath_kernel_args(resolved),
            assert_inner=assert_inner,
            interpret=(backend == "interpret"),
            out_dtype=x.dtype,
        )
    y = y.reshape(*lead, y.shape[-1])
    if "bias" in leaf:
        y = y + leaf["bias"].reshape(-1).astype(y.dtype)
    return y


def pmm(params, name, x):
    """Packed-aware matmul: ``x @ params[name]`` with transparent dispatch
    when the leaf is a packed-int4 serving artifact. The single seam every
    quantizable-site matmul in the model forwards goes through — which is
    what routes dense, MoE, Mamba and xLSTM packed decode onto the integer
    datapath at once."""
    v = params[name]
    if is_packed(v):
        obs = active_observer()
        scope = getattr(_observe_state, "scope", None)
        if obs is not None and scope is not None:
            _record_site_observation(obs, f"{scope}.{name}", x, v)
        return packed_linear(x, v)
    if is_dequant_site(v):
        y = x @ v["w"]
        if "bias" in v:
            y = y + v["bias"].reshape(-1).astype(y.dtype)
        return y
    return x @ v


def constraint(x, names):
    """Logical sharding constraint (no-op without an active mesh)."""
    from repro.runtime.sharding import logical_constraint

    return logical_constraint(x, names)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_norm(cfg: ModelConfig):
    p = {"w": jnp.ones((cfg.d_model,), _dtype(cfg.param_dtype))}
    if cfg.norm == "layernorm":
        p["b"] = jnp.zeros((cfg.d_model,), _dtype(cfg.param_dtype))
    return p


def norm(params, x, kind: str = "rmsnorm", eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        out = xf * scale * params["w"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * params["w"].astype(jnp.float32) + params["b"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA)
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {
        "wq": (jax.random.normal(ks[0], (d, nh * hd)) * s).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, nkv * hd)) * s).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, nkv * hd)) * s).astype(dt),
        "wo": (jax.random.normal(ks[3], (nh * hd, d)) * s).astype(dt),
    }


def resolve_weight(params, name):
    """Weight accessor that transparently dequantizes packed-int4 leaves
    (the W4A8 serving artifact — see repro.quant.serve_packed). Call sites
    that are plain matmuls should prefer :func:`pmm`, which can route the
    packed leaf through the fused w4a8_mm kernel instead of materializing
    the full-width weight; resolve_weight remains for consumers that need
    the dense array (einsums, analysis, the dequant fallback)."""
    v = params[name]
    if is_packed(v):
        return dequant_weight(v)
    if is_dequant_site(v):
        # NOTE: the dense weight only — callers needing the corrected bias
        # (pmm, moe._expert_matmul) apply it at the matmul
        return v["w"]
    return v


def _qkv(params, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = pmm(params, "wq", x).reshape(B, S, nh, hd)
    k = pmm(params, "wk", x).reshape(B, S, nkv, hd)
    v = pmm(params, "wv", x).reshape(B, S, nkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constraint(q, ("batch", None, "heads", None))
    k = constraint(k, ("batch", None, "kv_heads", None))
    v = constraint(v, ("batch", None, "kv_heads", None))
    return q, k, v


def _softcap(scores, cap):
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


def _full_causal_attention(q, k, v, cfg: ModelConfig):
    """Materialized causal attention (S <= attn_chunk_threshold).

    ``k``/``v`` may carry ``T >= S`` positions: the leading ``T - S`` keys
    are a *prefix context* every query attends to (the shared-prefix
    suffix-prefill path — see :func:`attention`'s ``prefix_kv``); query
    row ``i`` sits at absolute position ``(T - S) + i``, so the mask is
    the usual causal triangle shifted by the prefix length. ``T == S``
    reduces to the plain causal mask."""
    B, S, nh, hd = q.shape
    T = k.shape[1]
    nkv = k.shape[2]
    g = nh // nkv
    qg = q.reshape(B, S, nkv, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    scores = _softcap(scores / math.sqrt(hd), cfg.attn_logit_softcap)
    causal = jnp.arange(T)[None, :] <= (jnp.arange(S) + (T - S))[:, None]
    scores = jnp.where(causal, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, S, nh, hd)


def _chunked_causal_attention(q, k, v, cfg: ModelConfig):
    """Online-softmax attention, scanned over KV chunks — O(S * chunk)
    peak memory instead of O(S^2). The pure-JAX flash-attention analogue
    (the TPU-kernel version of this belongs in repro.kernels if attention
    ever becomes the quantization target; for this paper it is not)."""
    B, S0, nh, hd = q.shape
    nkv = k.shape[2]
    g = nh // nkv
    chunk = cfg.attn_chunk
    pad = (-S0) % chunk
    if pad:  # ragged tail: causal mask keeps padded KV unattended
        padding = [(0, 0), (0, pad), (0, 0), (0, 0)]
        q, k, v = (jnp.pad(t, padding) for t in (q, k, v))
    S = S0 + pad
    n_chunks = S // chunk
    qg = q.reshape(B, S, nkv, g, hd)
    k_ch = k.reshape(B, n_chunks, chunk, nkv, hd).transpose(1, 0, 2, 3, 4)
    v_ch = v.reshape(B, n_chunks, chunk, nkv, hd).transpose(1, 0, 2, 3, 4)
    q_pos = jnp.arange(S)

    def body(carry, kv):
        m, l, acc, idx = carry
        kc, vc = kv  # (B, chunk, nkv, hd)
        kv_pos = idx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kc).astype(jnp.float32)
        s = _softcap(s / math.sqrt(hd), cfg.attn_logit_softcap)
        mask = q_pos[:, None] >= kv_pos[None, :]  # (S, chunk)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows (future chunks): keep m finite
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(q.dtype), vc
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new, idx + 1), None

    m0 = jnp.full((B, nkv, g, S), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, nkv, g, S), jnp.float32)
    acc0 = jnp.zeros((B, nkv, g, S, hd), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, acc0, 0), (k_ch, v_ch))
    out = acc / jnp.maximum(l[..., None], 1e-20)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, nh, hd)
    return out[:, :S0].astype(q.dtype)


def attention(params, x, cfg: ModelConfig, positions, prefix_kv=None):
    """Training / prefill attention. Returns (y, (k, v)) — k/v for caching.

    ``prefix_kv``: optional ``(prefix_k, prefix_v)`` of shape
    ``(B, L, nkv, hd)`` — already-RoPE'd KV for a cached prompt prefix
    (the prefix-cache suffix-prefill path). Queries attend over
    ``concat(prefix, suffix)`` with the rectangular causal mask;
    ``positions`` must then carry the absolute offsets (``L + i``). The
    returned ``(k, v)`` stay suffix-only — that is what gets scattered
    into fresh pages (the prefix pages already exist and are shared)."""
    B, S, _ = x.shape
    q, k, v = _qkv(params, x, cfg, positions)
    if prefix_kv is not None:
        pk, pv = prefix_kv
        k_all = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
        v_all = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
        # suffixes are short by construction (the cached prefix absorbed
        # the bulk); the materialized rectangular path is the right tool
        out = _full_causal_attention(q, k_all, v_all, cfg)
    elif S > cfg.attn_chunk_threshold:
        out = _chunked_causal_attention(q, k, v, cfg)
    else:
        out = _full_causal_attention(q, k, v, cfg)
    y = pmm(params, "wo", out.reshape(B, S, cfg.n_heads * cfg.head_dim))
    return constraint(y, ("batch", None, "residual")), (k, v)


_PAGED_ATTN_IMPLS = ("auto", "ref", "kernel", "interpret")


def resolve_paged_attn_impl(impl: str = "auto") -> str:
    """Resolve the paged decode-attention implementation: the Pallas
    block-table kernel on TPU, the gather reference elsewhere. "interpret"
    runs the kernel path under pallas interpret mode (tests/validation)."""
    if impl not in _PAGED_ATTN_IMPLS:
        raise ValueError(f"paged attn impl {impl!r} not in {_PAGED_ATTN_IMPLS}")
    if impl == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "ref"
    return impl


def _append_kv_page_quant(pages, scales, page, off, x, kv_bits: int = 8):
    """Quantize-on-append into an int8 page pool with per-(page, kv-head)
    scales. ``x``: (B, nkv, hd) — the new token's K or V rows, landing at
    ``(page[b], off[b])``. The page scale *grows monotonically*: when the
    new token's magnitude exceeds the page's current scale, the existing
    codes rescale in place (one bounded extra rounding of at most half a
    step at the new scale); an ``off == 0`` write is the page's first
    token (fresh or recycled), so the stale scale resets — whatever codes
    the page held belong to a freed sequence and are past-length-masked
    anyway. Inactive rows carry the ``page >= num_blocks`` sentinel: the
    whole page/scale write drops, so idle slots never corrupt live pages.
    """
    qmax = 2 ** (kv_bits - 1) - 1
    nb, bs = pages.shape[0], pages.shape[1]
    p_idx = jnp.minimum(page, nb - 1)
    old = jnp.where((off == 0)[:, None], 0.0, scales[p_idx])  # (B, nkv)
    tok = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / qmax  # (B, nkv)
    new = jnp.maximum(jnp.maximum(old, tok), 1e-8)
    codes = pages[p_idx].astype(jnp.float32)  # (B, bs, nkv, hd)
    codes = jnp.rint(codes * (old / new)[:, None, :, None])
    tok_codes = jnp.rint(x.astype(jnp.float32) / new[..., None])  # (B, nkv, hd)
    sel = (jnp.arange(bs)[None, :] == off[:, None])[..., None, None]
    codes = jnp.clip(jnp.where(sel, tok_codes[:, None], codes), -qmax, qmax)
    pages = pages.at[page].set(codes.astype(pages.dtype), mode="drop")
    scales = scales.at[page].set(new, mode="drop")
    return pages, scales


def _append_kv_page_static(pages, scales, page, off, x, scale_static):
    """Append into an int8 page pool under *calibrated static* per-kv-head
    scales (``scale_static``: (nkv,) f32 — see repro.quant.observe.kv).

    The requantize-on-append machinery of :func:`_append_kv_page_quant` is
    gone: no scale growth, no in-place rescale of existing codes, every
    code rounded exactly once. The page's scale leaf is stamped with the
    static value so gather/dequant consumers (and the quantized attention
    kernel) read the pool identically to the dynamic path. Codes hard-clip
    at the int8 container limit: out-of-calibration drift saturates (the
    serving saturation counters measure it) instead of overflowing, so the
    8-bit :class:`~repro.quant.spec.AttnDatapathSpec` bound still holds.
    Inactive rows use the same ``page >= num_blocks`` drop sentinel.
    """
    nb = pages.shape[0]
    qmax = 127  # int8 container limit (alphabet may be coarser via scale)
    tok_codes = jnp.clip(
        jnp.rint(x.astype(jnp.float32) / scale_static[None, :, None]),
        -qmax, qmax,
    )  # (B, nkv, hd)
    pages = pages.at[page, off].set(tok_codes.astype(pages.dtype), mode="drop")
    stamp = jnp.broadcast_to(scale_static[None, :], (x.shape[0], x.shape[1]))
    scales = scales.at[page].set(stamp, mode="drop")
    return pages, scales


def paged_attention_decode(params, x, cfg: ModelConfig, pool,
                           block_table, seq_lens, active, *,
                           impl: str = "ref", attn_spec=None,
                           static_kv_scales=None):
    """Single-token decode against a *paged* KV cache.

    x: (B, 1, d) — B is the engine's slot count. ``pool`` is the layer's
    page-pool dict: ``k_pages``/``v_pages`` are
    ``(num_blocks, block_size, nkv, hd)`` (``cfg.act_dtype`` float, or
    int8 codes when the pool also carries ``k_scales``/``v_scales``
    per-(page, kv-head) scale leaves — the quantized layout of
    ``init_paged_cache(kv_dtype="int8")``); ``block_table`` (B, P) int32
    maps logical pages to pool pages (entries ``>= num_blocks`` are
    free-slot sentinels); ``seq_lens`` (B,) int32 is each slot's current
    length — the new token's KV lands at logical position ``seq_lens[b]``
    and attention covers positions ``<= seq_lens[b]``. ``active`` (B,)
    bool masks the page write for idle slots (their table rows may point
    at pages since re-allocated to other sequences — the write is routed
    out of bounds and dropped, so an idle slot can never corrupt a live
    one). Idle rows still produce (garbage) outputs; the engine discards
    them. ``attn_spec`` is the optional
    :class:`~repro.quant.spec.AttnDatapathSpec` request forwarded to the
    quantized kernel for validation against the pool layout.
    ``static_kv_scales``: optional ``{"k": (nkv,), "v": (nkv,)}`` f32 —
    calibrated static page scales from a mixed-precision plan; appends
    then take the :func:`_append_kv_page_static` path (no requantize-on-
    append). Only valid for quantized pools.

    Returns (y, new_pool).
    """
    from repro.kernels.paged_attention import (
        paged_attention_reference,
        paged_decode_attention,
    )

    B = x.shape[0]
    positions = seq_lens[:, None]  # (B, 1) — per-slot RoPE positions
    q, k, v = _qkv(params, x, cfg, positions)
    k_pages, v_pages = pool["k_pages"], pool["v_pages"]
    quantized = "k_scales" in pool
    nb, bs = k_pages.shape[0], k_pages.shape[1]
    if attn_spec is not None:
        # validate the request against the pool-derived record on EVERY
        # impl (the gather reference included) — a disagreeing record must
        # raise here too, never silently serve (the validate_datapath
        # contract; float pools count as "no record")
        from repro.quant.spec import AttnDatapathSpec, validate_attn_datapath

        derived = (
            AttnDatapathSpec.for_cache(
                cfg.head_dim, bs, kv_bits=8 * k_pages.dtype.itemsize)
            if quantized else None
        )
        validate_attn_datapath(derived, attn_spec)
    page = jnp.where(active, block_table[jnp.arange(B), seq_lens // bs], nb)
    off = seq_lens % bs
    if quantized:
        if static_kv_scales is not None:
            k_pages, k_scales = _append_kv_page_static(
                k_pages, pool["k_scales"], page, off, k[:, 0],
                static_kv_scales["k"])
            v_pages, v_scales = _append_kv_page_static(
                v_pages, pool["v_scales"], page, off, v[:, 0],
                static_kv_scales["v"])
        else:
            k_pages, k_scales = _append_kv_page_quant(
                k_pages, pool["k_scales"], page, off, k[:, 0])
            v_pages, v_scales = _append_kv_page_quant(
                v_pages, pool["v_scales"], page, off, v[:, 0])
        new_pool = {"k_pages": k_pages, "v_pages": v_pages,
                    "k_scales": k_scales, "v_scales": v_scales}
        scale_kw = {"k_scales": k_scales, "v_scales": v_scales}
    else:
        k_pages = k_pages.at[page, off].set(k[:, 0].astype(k_pages.dtype),
                                            mode="drop")
        v_pages = v_pages.at[page, off].set(v[:, 0].astype(v_pages.dtype),
                                            mode="drop")
        new_pool = {"k_pages": k_pages, "v_pages": v_pages}
        scale_kw = {}
    lens_now = seq_lens + 1  # attend over positions < lens_now (self incl.)
    if impl == "ref":
        out = paged_attention_reference(
            q[:, 0], k_pages, v_pages, block_table, lens_now,
            softcap=cfg.attn_logit_softcap, **scale_kw,
        )
    else:
        out = paged_decode_attention(
            q[:, 0], k_pages, v_pages, block_table, lens_now,
            softcap=cfg.attn_logit_softcap, attn_spec=attn_spec,
            interpret=(impl == "interpret"), **scale_kw,
        )
    y = pmm(params, "wo", out.reshape(B, 1, cfg.n_heads * cfg.head_dim))
    return y, new_pool


def attention_decode(params, x, cfg: ModelConfig, cache_k, cache_v, index):
    """Single-token decode against a (B, S_max, nkv, hd) KV cache.

    ``index``: scalar int32 — current position (cache fill level).
    Returns (y, new_k, new_v).
    """
    B, S1, _ = x.shape  # S1 == 1
    positions = jnp.full((B, S1), index, jnp.int32)
    q, k, v = _qkv(params, x, cfg, positions)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, index, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, index, 0, 0))
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = nh // nkv
    qg = q.reshape(B, nkv, g, hd)  # S1 == 1 squeezed
    s = jnp.einsum("bkgd,bskd->bkgs", qg, cache_k).astype(jnp.float32)
    s = _softcap(s / math.sqrt(hd), cfg.attn_logit_softcap)
    valid = jnp.arange(cache_k.shape[1])[None, :] <= index  # (1, S_max)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", p, cache_v)
    y = pmm(params, "wo", out.reshape(B, 1, nh * hd))
    return y, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = _dtype(cfg.param_dtype)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {
            "wg": (jax.random.normal(ks[0], (d, f)) * s_in).astype(dt),
            "wu": (jax.random.normal(ks[1], (d, f)) * s_in).astype(dt),
            "wd": (jax.random.normal(ks[2], (f, d)) * s_out).astype(dt),
        }
    return {
        "wi": (jax.random.normal(ks[0], (d, f)) * s_in).astype(dt),
        "wd": (jax.random.normal(ks[1], (f, d)) * s_out).astype(dt),
    }


def mlp(params, x, cfg: ModelConfig):
    if cfg.act == "swiglu":
        h = jax.nn.silu(pmm(params, "wg", x)) * pmm(params, "wu", x)
    else:
        h = jax.nn.gelu(pmm(params, "wi", x))
    h = constraint(h, ("batch", None, "ffn"))
    return constraint(pmm(params, "wd", h), ("batch", None, "residual"))


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------
def init_embedding(key, cfg: ModelConfig):
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 2)
    v = cfg.vocab_padded  # padded so the vocab dim always TP-shards
    p = {"embed": (jax.random.normal(ks[0], (v, cfg.d_model)) * 0.02).astype(dt)}
    if not cfg.tie_embeddings:
        p["head"] = (
            jax.random.normal(ks[1], (v, cfg.d_model)) / math.sqrt(cfg.d_model)
        ).astype(dt)
    return p


def embed(params, tokens, cfg: ModelConfig):
    x = jnp.take(params["embed"], tokens, axis=0)
    return constraint(x, ("batch", None, "residual"))


def lm_logits(params, x, cfg: ModelConfig):
    head = params.get("head", params["embed"])
    logits = jnp.einsum("bsd,vd->bsv", x, head)
    if cfg.vocab_padded != cfg.vocab:
        # mask pad rows so softmax/logsumexp are exact over the real vocab
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = jnp.where(pad_mask, jnp.finfo(logits.dtype).min, logits)
    return constraint(logits, ("batch", None, "vocab"))
