"""Model assembly: heterogeneous layer stacks with scan-over-repeats.

The stack is ``repeats`` x ``pattern`` (see config.py). Parameters for each
pattern slot are stacked over repeats (leading axis R) and the forward pass
is a single ``lax.scan`` whose body unrolls the period — compiled HLO size is
O(period), independent of depth (126-layer LLaMA-405B compiles the same body
as a 2-layer smoke model). Decode threads per-layer recurrent state (KV
caches / SSM states) through the same scan as stacked xs/ys.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import LayerSpec, ModelConfig
from .layers import (
    attention,
    attention_decode,
    constraint,
    embed,
    init_attention,
    init_embedding,
    init_mlp,
    init_norm,
    lm_logits,
    mlp,
    norm,
)
from .moe import init_moe, moe
from .ssm import init_mamba, mamba, mamba_decode, mamba_state_shapes
from .xlstm import (
    init_mlstm,
    init_slstm,
    mlstm,
    mlstm_decode,
    mlstm_state_shapes,
    slstm,
    slstm_decode,
    slstm_state_shapes,
)

AUX_LOSS_WEIGHT = 0.01


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_slot(key, spec: LayerSpec, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    p = {"norm1": init_norm(cfg)}
    if spec.mixer == "attn":
        p["mixer"] = init_attention(ks[0], cfg)
    elif spec.mixer == "mamba":
        p["mixer"] = init_mamba(ks[0], cfg)
    elif spec.mixer == "mlstm":
        p["mixer"] = init_mlstm(ks[0], cfg)
    elif spec.mixer == "slstm":
        p["mixer"] = init_slstm(ks[0], cfg)
    elif spec.mixer != "none":
        raise ValueError(f"unknown mixer {spec.mixer}")
    if spec.ffn != "none":
        p["norm2"] = init_norm(cfg)
        p["ffn"] = init_moe(ks[1], cfg) if spec.ffn == "moe" else init_mlp(ks[1], cfg)
    return p


def init_model(key, cfg: ModelConfig):
    k_embed, k_layers = jax.random.split(key)
    slot_keys = jax.random.split(k_layers, cfg.period * cfg.repeats).reshape(
        cfg.period, cfg.repeats
    )
    layers = tuple(
        jax.vmap(lambda k, s=spec: _init_slot(k, s, cfg))(slot_keys[i])
        for i, spec in enumerate(cfg.pattern)
    )
    return {
        "embedding": init_embedding(k_embed, cfg),
        "layers": layers,
        "final_norm": init_norm(cfg),
    }


def abstract_params(cfg: ModelConfig, seed: int = 0):
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
    return jax.eval_shape(lambda k: init_model(k, cfg), jax.random.key(seed))


# ---------------------------------------------------------------------------
# Training / scoring forward
# ---------------------------------------------------------------------------
def _apply_block(p, spec: LayerSpec, cfg: ModelConfig, x, positions):
    aux = jnp.zeros((), jnp.float32)
    if spec.mixer != "none":
        h = norm(p["norm1"], x, cfg.norm)
        if spec.mixer == "attn":
            y, _ = attention(p["mixer"], h, cfg, positions)
        elif spec.mixer == "mamba":
            y = mamba(p["mixer"], h, cfg)
        elif spec.mixer == "mlstm":
            y = mlstm(p["mixer"], h, cfg)
        else:
            y = slstm(p["mixer"], h, cfg)
        x = x + y
    if spec.ffn != "none":
        h = norm(p["norm2"], x, cfg.norm)
        if spec.ffn == "moe":
            y, a = moe(p["ffn"], h, cfg)
            aux = aux + a
        else:
            y = mlp(p["ffn"], h, cfg)
        x = x + y
    return x, aux


def _embed_inputs(params, batch, cfg: ModelConfig, pos_offset: int = 0):
    """Token embedding plus the (stub) modality frontend prefix.

    ``pos_offset`` shifts the RoPE positions — nonzero only on the
    prefix-cache suffix-prefill path, where ``batch["tokens"]`` is the
    uncached tail of a prompt whose first ``pos_offset`` tokens already
    sit in shared KV pages."""
    x = embed(params["embedding"], batch["tokens"], cfg)
    if cfg.frontend == "vision_stub" and cfg.frontend_tokens:
        # precomputed patch embeddings arrive as inputs (assignment spec)
        x = jnp.concatenate([batch["pixel_embeds"].astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(
        pos_offset + jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return x, positions


def forward(params, batch, cfg: ModelConfig):
    """batch: {"tokens": (B, S_text) int32, ["pixel_embeds": (B, P, D)]}.

    Returns (logits over the full sequence incl. frontend prefix, aux_loss).
    """
    x, positions = _embed_inputs(params, batch, cfg)
    x = constraint(x, ("batch", None, "residual"))

    def body(carry, layer_params):
        x, aux = carry
        for i, spec in enumerate(cfg.pattern):
            x, a = _apply_block(layer_params[i], spec, cfg, x, positions)
            aux = aux + a
        return (x, aux), None

    if cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    carry = (x, jnp.zeros((), jnp.float32))
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body, carry, params["layers"])
    else:  # unrolled (validation of the trip-count cost model)
        for r in range(cfg.repeats):
            layer_r = jax.tree.map(lambda p: p[r], params["layers"])
            carry, _ = body(carry, layer_r)
        x, aux = carry
    x = norm(params["final_norm"], x, cfg.norm)
    return lm_logits(params["embedding"], x, cfg), aux


def loss_fn(params, batch, cfg: ModelConfig):
    """Next-token cross entropy over text positions (frontend prefix masked)."""
    logits, aux = forward(params, batch, cfg)
    p = cfg.frontend_tokens if cfg.frontend == "vision_stub" else 0
    text_logits = logits[:, p:, :]
    pred = text_logits[:, :-1].astype(jnp.float32)
    labels = batch["tokens"][:, 1:]
    logz = jax.nn.logsumexp(pred, axis=-1)
    gold = jnp.take_along_axis(pred, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = batch.get("mask")
    if mask is not None:
        m = mask[:, 1:].astype(jnp.float32)
        nll = nll * m
        denom = jnp.maximum(jnp.sum(m), 1.0)
    else:
        denom = jnp.asarray(nll.size, jnp.float32)
    ce = jnp.sum(nll) / denom
    total = ce + AUX_LOSS_WEIGHT * aux
    return total, {"ce": ce, "aux": aux, "ppl": jnp.exp(ce)}


# ---------------------------------------------------------------------------
# KV / recurrent caches
# ---------------------------------------------------------------------------
def _slot_cache_shapes(spec: LayerSpec, cfg: ModelConfig, batch: int, max_len: int):
    if spec.mixer == "attn":
        hd = cfg.head_dim
        kv = jax.ShapeDtypeStruct(
            (batch, max_len, cfg.n_kv_heads, hd), jnp.dtype(cfg.act_dtype)
        )
        return {"k": kv, "v": kv}
    if spec.mixer == "mamba":
        return mamba_state_shapes(cfg, batch)
    if spec.mixer == "mlstm":
        return mlstm_state_shapes(cfg, batch)
    if spec.mixer == "slstm":
        return slstm_state_shapes(cfg, batch)
    return {}


def init_cache(cfg: ModelConfig, batch: int, max_len: int, abstract: bool = False):
    """Stacked (R, ...) cache pytree per pattern slot."""

    def make(sds):
        shape = (cfg.repeats, *sds.shape)
        if abstract:
            return jax.ShapeDtypeStruct(shape, sds.dtype)
        return jnp.zeros(shape, sds.dtype)

    return tuple(
        {k: make(v) for k, v in _slot_cache_shapes(spec, cfg, batch, max_len).items()}
        for spec in cfg.pattern
    )


def init_paged_cache(cfg: ModelConfig, num_slots: int, num_blocks: int,
                     block_size: int, max_pages: int, abstract: bool = False,
                     kv_dtype: str | None = None):
    """Paged decode cache: one KV *page pool* per attention slot plus the
    shared continuous-batching state (see docs/serving_scheduler.md).

    Attention KV lives in ``(R, num_blocks, block_size, nkv, hd)`` pools
    indexed through a per-slot ``block_table`` — HBM scales with the pool,
    not with ``num_slots * max_seq_len``. Recurrent mixers (Mamba/xLSTM)
    keep their O(1)-per-sequence dense state, batched over ``num_slots``
    (continuous batching swaps a slot's state wholesale at admission).
    Page allocation state (``free_list`` stack + ``free_top``) is part of
    the pytree so pop/push happen inside the jitted admit/release programs.

    ``kv_dtype`` selects the pool element type (default ``cfg.act_dtype``).
    ``kv_dtype="int8"`` stores *quantized* pages: int8 codes plus
    per-(page, kv-head) symmetric ``k_scales``/``v_scales`` f32 leaves
    (``(R, num_blocks, nkv)``) — pool HBM halves vs bf16 and attention
    runs the :class:`~repro.quant.spec.AttnDatapathSpec`-certified integer
    datapath (see ``repro.kernels.paged_attention``). Scales start at
    zero; admission stamps them per scattered page and the decode append
    resets them on a page's first write, so recycled pages can never leak
    a stale scale into a live sequence.

    ``page_refcounts`` counts readers per physical page: the live
    block-table rows containing it, plus one when the prefix cache holds
    it (docs/serving_scheduler.md, "Prefix cache"). A page returns to the
    free-list stack only when the count drops to zero — the refcount-aware
    subset-push release program. All-zero init preserves the original
    exclusive-ownership semantics (cold admits set each popped page to 1).
    """

    def make(shape, dtype):
        if abstract:
            return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))
        return jnp.zeros(shape, dtype)

    kv_dtype = kv_dtype or cfg.act_dtype
    kv_quantized = jnp.dtype(kv_dtype) == jnp.int8
    pools = []
    for spec in cfg.pattern:
        if spec.mixer == "attn":
            kv = (cfg.repeats, num_blocks, block_size, cfg.n_kv_heads,
                  cfg.head_dim)
            pool = {"k_pages": make(kv, kv_dtype),
                    "v_pages": make(kv, kv_dtype)}
            if kv_quantized:
                sc = (cfg.repeats, num_blocks, cfg.n_kv_heads)
                pool["k_scales"] = make(sc, jnp.float32)
                pool["v_scales"] = make(sc, jnp.float32)
            pools.append(pool)
        else:
            shapes = _slot_cache_shapes(spec, cfg, num_slots, block_size)
            pools.append({
                k: make((cfg.repeats, *v.shape), v.dtype)
                for k, v in shapes.items()
            })
    if abstract:
        free_list = jax.ShapeDtypeStruct((num_blocks,), jnp.int32)
        table = jax.ShapeDtypeStruct((num_slots, max_pages), jnp.int32)
    else:
        free_list = jnp.arange(num_blocks, dtype=jnp.int32)
        # entries == num_blocks are "no page" sentinels (clamped on gather,
        # dropped on scatter)
        table = jnp.full((num_slots, max_pages), num_blocks, jnp.int32)
    return {
        "pools": tuple(pools),
        "block_table": table,
        "seq_lens": make((num_slots,), jnp.int32),
        "active": make((num_slots,), bool),
        "uids": make((num_slots,), jnp.int32),
        "steps": make((num_slots,), jnp.int32),
        "last_tok": make((num_slots,), jnp.int32),
        "free_list": free_list,
        "free_top": make((), jnp.int32),
        "page_refcounts": make((num_blocks,), jnp.int32),
    }


def decode_step_paged(params, tokens, cache, cfg: ModelConfig, *,
                      attn_impl: str = "ref", attn_spec=None,
                      kv_scales=None):
    """One decode step over the paged cache. tokens: (num_slots, 1) int32.

    Unlike :func:`decode_step`'s single scalar ``index``, every slot
    advances at its own ``cache["seq_lens"]`` position (heterogeneous
    lengths are the point of paging); idle slots (``active`` False) compute
    but write nothing and do not advance. ``attn_spec`` is the optional
    :class:`~repro.quant.spec.AttnDatapathSpec` request, forwarded when
    the pools hold int8 quantized pages. ``kv_scales``: optional tuple
    aligned with ``cfg.pattern`` of calibrated static KV page scales
    (attention slots: ``{"k": (R, nkv), "v": (R, nkv)}`` f32; others:
    ``{}``) — joined to the scan xs only when present, so the default
    jaxpr is unchanged. Returns (logits, new_cache).

    Each pattern slot's component runs under a ``site_scope`` label
    ("slot0/mixer"), so an attached serving observer receives slot-granular
    site reports matching mixed-precision plan keys (repeats fold into the
    scan and aggregate under one label).
    """
    from repro.models.layers import paged_attention_decode, site_scope

    x = embed(params["embedding"], tokens, cfg)
    table = cache["block_table"]
    lens = cache["seq_lens"]
    active = cache["active"]

    def body(x, xs):
        if kv_scales is not None:
            layer_params, slot_caches, slot_kv = xs
        else:
            (layer_params, slot_caches), slot_kv = xs, None
        new_caches = []
        for i, spec in enumerate(cfg.pattern):
            p = layer_params[i]
            c_in = slot_caches[i]
            if spec.mixer == "attn":
                h = norm(p["norm1"], x, cfg.norm)
                sks = slot_kv[i] if slot_kv is not None and slot_kv[i] else None
                with site_scope(f"slot{i}/mixer"):
                    y, c_out = paged_attention_decode(
                        p["mixer"], h, cfg, c_in, table, lens, active,
                        impl=attn_impl, attn_spec=attn_spec,
                        static_kv_scales=sks,
                    )
                x = x + y
            elif spec.mixer != "none":
                h = norm(p["norm1"], x, cfg.norm)
                with site_scope(f"slot{i}/mixer"):
                    y, c_out = _mixer_decode(p, spec, cfg, h, c_in, 0)
                x = x + y
            else:
                c_out = c_in
            if spec.ffn != "none":
                h = norm(p["norm2"], x, cfg.norm)
                with site_scope(f"slot{i}/ffn"):
                    if spec.ffn == "moe":
                        y, _ = moe(p["ffn"], h, cfg)
                    else:
                        y = mlp(p["ffn"], h, cfg)
                x = x + y
            new_caches.append(c_out)
        return x, tuple(new_caches)

    xs = (params["layers"], cache["pools"])
    if kv_scales is not None:
        xs = (*xs, tuple(kv_scales))
    x, pools = jax.lax.scan(body, x, xs)
    x = norm(params["final_norm"], x, cfg.norm)
    logits = lm_logits(params["embedding"], x, cfg)
    new_cache = dict(cache)
    new_cache["pools"] = pools
    new_cache["seq_lens"] = lens + active.astype(lens.dtype)
    return logits, new_cache


def _mixer_decode(p, spec: LayerSpec, cfg: ModelConfig, h, cache, index):
    if spec.mixer == "attn":
        y, ck, cv = attention_decode(p["mixer"], h, cfg, cache["k"], cache["v"], index)
        return y, {"k": ck, "v": cv}
    if spec.mixer == "mamba":
        y, conv, ssm = mamba_decode(p["mixer"], h, cfg, cache["conv"], cache["ssm"])
        return y, {"conv": conv, "ssm": ssm}
    if spec.mixer == "mlstm":
        y, conv, C, n, m = mlstm_decode(
            p["mixer"], h, cfg, cache["conv"], cache["C"], cache["n"], cache["m"]
        )
        return y, {"conv": conv, "C": C, "n": n, "m": m}
    if spec.mixer == "slstm":
        y, hh, c, n, m = slstm_decode(
            p["mixer"], h, cfg, cache["h"], cache["c"], cache["n"], cache["m"]
        )
        return y, {"h": hh, "c": c, "n": n, "m": m}
    return jnp.zeros_like(h), {}


def decode_step(params, tokens, cache, index, cfg: ModelConfig):
    """One decode step. tokens: (B, 1) int32; index: scalar int32 position.

    Returns (logits (B, 1, V), new_cache).
    """
    x = embed(params["embedding"], tokens, cfg)

    def body(x, xs):
        layer_params, slot_caches = xs
        new_caches = []
        for i, spec in enumerate(cfg.pattern):
            p = layer_params[i]
            c_in = slot_caches[i]
            if spec.mixer != "none":
                h = norm(p["norm1"], x, cfg.norm)
                y, c_out = _mixer_decode(p, spec, cfg, h, c_in, index)
                x = x + y
            else:
                c_out = c_in
            if spec.ffn != "none":
                h = norm(p["norm2"], x, cfg.norm)
                if spec.ffn == "moe":
                    y, _ = moe(p["ffn"], h, cfg)
                else:
                    y = mlp(p["ffn"], h, cfg)
                x = x + y
            new_caches.append(c_out)
        return x, tuple(new_caches)

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = norm(params["final_norm"], x, cfg.norm)
    return lm_logits(params["embedding"], x, cfg), new_cache


# ---------------------------------------------------------------------------
# Prefill (forward + state emission for subsequent decode)
# ---------------------------------------------------------------------------
def _mixer_prefill(p, spec: LayerSpec, cfg: ModelConfig, h, positions, max_len,
                   prefix_kv=None):
    """Returns (y, cache_dict) with states positioned for decode at index S."""
    B, S, _ = h.shape
    if spec.mixer == "attn":
        y, (k, v) = attention(p["mixer"], h, cfg, positions,
                              prefix_kv=prefix_kv)
        pad = [(0, 0), (0, max_len - S), (0, 0), (0, 0)]
        return y, {
            "k": jnp.pad(k.astype(jnp.dtype(cfg.act_dtype)), pad),
            "v": jnp.pad(v.astype(jnp.dtype(cfg.act_dtype)), pad),
        }
    if cfg.prefill_mode == "parallel":
        # chunkwise-parallel prefill: the training-path kernels emit the
        # end-of-sequence state directly (§Perf iteration 1 — replaces the
        # O(S)-sequential stepwise fallback below; ~1000x memory-term win
        # on the 32k prefill cells, see EXPERIMENTS.md §Perf)
        if spec.mixer == "mamba":
            return mamba(p["mixer"], h, cfg, return_state=True)
        if spec.mixer == "mlstm":
            return mlstm(p["mixer"], h, cfg, return_state=True)
        if spec.mixer == "slstm":
            return slstm(p["mixer"], h, cfg, return_state=True)

    # stepwise fallback: rerun the sequence through the decode recurrence —
    # state-exact but sequential (kept as the §Perf baseline)
    cache = {
        k: jnp.zeros(v.shape, v.dtype)
        for k, v in _slot_cache_shapes(spec, cfg, B, max_len).items()
    }

    def step(carry, xt):
        x_t = xt[:, None, :]  # (B, 1, D)
        y_t, c_out = _mixer_decode(p, spec, cfg, x_t, carry, 0)
        return c_out, y_t[:, 0]

    cache, ys = jax.lax.scan(step, cache, h.transpose(1, 0, 2))
    return ys.transpose(1, 0, 2), cache


def prefill(params, batch, cfg: ModelConfig, max_len: int,
            prefix_kv=None, pos_offset: int = 0):
    """Run the prompt, returning (logits, cache ready for decode at index S).

    ``prefix_kv`` enables *suffix prefill* against a cached prompt prefix
    (the prefix-cache admit path): a tuple aligned with ``cfg.pattern``
    whose attention entries are ``{"k", "v"}`` of shape
    ``(R, B, L, nkv, hd)`` — per-repeat RoPE'd KV for the first ``L``
    prompt tokens, gathered (and dequantized, for int8 pools) from shared
    pages — and whose other entries are ``{}``. ``batch["tokens"]`` then
    carries only the uncached suffix and ``pos_offset`` must equal ``L``.
    The returned cache stays suffix-only: exactly what gets scattered
    into the request's *fresh* pages. With ``prefix_kv=None`` this is the
    original cold prefill, bit for bit (separate scan branch)."""
    x, positions = _embed_inputs(params, batch, cfg, pos_offset)

    def body(x, xs):
        layer_params = xs[0] if prefix_kv is not None else xs
        caches = []
        for i, spec in enumerate(cfg.pattern):
            p = layer_params[i]
            if spec.mixer != "none":
                h = norm(p["norm1"], x, cfg.norm)
                pkv = None
                if prefix_kv is not None and spec.mixer == "attn":
                    pkv = (xs[1][i]["k"], xs[1][i]["v"])
                y, c = _mixer_prefill(p, spec, cfg, h, positions, max_len,
                                      prefix_kv=pkv)
                x = x + y
            else:
                c = {}
            if spec.ffn != "none":
                h = norm(p["norm2"], x, cfg.norm)
                if spec.ffn == "moe":
                    y, _ = moe(p["ffn"], h, cfg)
                else:
                    y = mlp(p["ffn"], h, cfg)
                x = x + y
            caches.append(c)
        return x, tuple(caches)

    xs = params["layers"] if prefix_kv is None else (params["layers"],
                                                     tuple(prefix_kv))
    x, cache = jax.lax.scan(body, x, xs)
    x = norm(params["final_norm"], x, cfg.norm)
    return lm_logits(params["embedding"], x, cfg), cache
