"""Mamba-1 selective SSM block (Gu & Dao, arXiv:2312.00752), pure JAX.

Training/prefill uses a *chunked associative scan*: within a chunk the
recurrence h_t = Abar_t h_{t-1} + Bbar_t x_t runs as a parallel
``associative_scan`` (TPU-friendly, log-depth), across chunks a ``lax.scan``
carries the (B, d_in, d_state) state so peak memory is O(chunk), not O(S).
Decode is the exact single-step recurrence (used for the 500k-token
long-context cells — state size is sequence-length independent).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig


def init_mamba(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    dtr = cfg.dt_rank
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    sc = 1.0 / math.sqrt(d)
    sci = 1.0 / math.sqrt(d_in)
    # S4D-real initialization for A; dt bias sampled for softplus(dt) in
    # [dt_min, dt_max] as in the reference implementation
    a = jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32)[None, :], (d_in, 1))
    dt_min, dt_max = 1e-3, 1e-1
    u = jax.random.uniform(ks[5], (d_in,))
    dt_init = jnp.exp(u * (math.log(dt_max) - math.log(dt_min)) + math.log(dt_min))
    dt_bias = dt_init + jnp.log1p(-jnp.exp(-dt_init))  # inverse softplus
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * d_in)) * sc).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, d_in)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((d_in,), dt),
        "x_proj": (jax.random.normal(ks[2], (d_in, dtr + 2 * s.d_state)) * sci).astype(dt),
        "dt_proj": (jax.random.normal(ks[3], (dtr, d_in)) * (1.0 / math.sqrt(dtr))).astype(dt),
        "dt_bias": dt_bias.astype(dt),
        "A_log": jnp.log(a).astype(dt),
        "D": jnp.ones((d_in,), dt),
        "out_proj": (jax.random.normal(ks[4], (d_in, d)) * sci).astype(dt),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: (B, S, d_in); w: (K, d_in).

    ``state``: (B, K-1, d_in) trailing inputs from the previous segment
    (decode); returns (y, new_state).
    """
    ksz = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], ksz - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(ksz)) + b
    new_state = xp[:, -(ksz - 1) :, :] if ksz > 1 else state
    return y, new_state


def _ssm_params(params, x, cfg: ModelConfig):
    """Input-dependent (dt, B, C) and the fixed A. x: (B, S, d_in)."""
    from .layers import pmm

    s = cfg.ssm
    dtr = cfg.dt_rank
    proj = pmm(params, "x_proj", x)  # (B, S, dtr + 2N)
    dt_r, b_ssm, c_ssm = jnp.split(proj, [dtr, dtr + s.d_state], axis=-1)
    dt = jax.nn.softplus(
        pmm(params, "dt_proj", dt_r) + params["dt_bias"]
    )  # (B,S,d_in)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))  # (d_in, N)
    return dt, b_ssm, c_ssm, a


def _scan_chunk(h0, abar, bu):
    """Parallel first-order recurrence within a chunk.

    h_t = abar_t * h_{t-1} + bu_t, h_0 given. abar/bu: (B, L, d_in, N).
    """

    def op(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    a_acc, b_acc = jax.lax.associative_scan(op, (abar, bu), axis=1)
    return a_acc * h0[:, None] + b_acc  # (B, L, d_in, N)


def selective_scan(
    xin, dt, b_ssm, c_ssm, a, d_param, d_state: int,
    chunk: int = 256, return_state: bool = False,
):
    """Chunked selective-SSM core: (xin, dt, B, C) -> y (B, S, d_in) fp32.

    Pure-array signature so the PTQ families adapter can run the exact same
    high-precision scan on its paired calibration streams. ``return_state``
    additionally returns the end-of-sequence (B, d_in, N) state.
    """
    B, S, d_in = xin.shape
    dtf = dt.astype(jnp.float32)
    abar = jnp.exp(dtf[..., None] * a)  # (B, S, d_in, N)
    bu = (dtf * xin.astype(jnp.float32))[..., None] * b_ssm.astype(jnp.float32)[:, :, None, :]

    S0 = S
    L = min(chunk, S)
    pad = (-S) % L
    if pad:  # ragged tail: abar=1, bu=0 keeps state; outputs sliced off below
        abar = jnp.pad(abar, [(0, 0), (0, pad), (0, 0), (0, 0)], constant_values=1.0)
        bu = jnp.pad(bu, [(0, 0), (0, pad), (0, 0), (0, 0)])
        S = S + pad
    nc = S // L
    abar_c = abar.reshape(B, nc, L, d_in, d_state).transpose(1, 0, 2, 3, 4)
    bu_c = bu.reshape(B, nc, L, d_in, d_state).transpose(1, 0, 2, 3, 4)

    def body(h, inputs):
        ab, bb = inputs  # (B, L, d_in, N)
        hs = _scan_chunk(h, ab, bb)
        return hs[:, -1], hs

    h0 = jnp.zeros((B, d_in, d_state), jnp.float32)
    h_last, hs = jax.lax.scan(body, h0, (abar_c, bu_c))  # (nc, B, L, d_in, N)
    hs = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, d_in, d_state)[:, :S0]
    y = jnp.einsum("bsdn,bsn->bsd", hs, c_ssm.astype(jnp.float32))
    y = y + d_param.astype(jnp.float32) * xin.astype(jnp.float32)
    if return_state:
        return y, h_last
    return y


def mamba(params, x, cfg: ModelConfig, chunk: int = 256, return_state: bool = False):
    """Training/prefill forward. x: (B, S, d_model) -> (B, S, d_model).

    ``return_state``: also return the decode-ready end-of-sequence state
    {"conv", "ssm"} (chunkwise-parallel prefill — §Perf iteration 1)."""
    from .layers import constraint, pmm

    B, S0, _ = x.shape
    s = cfg.ssm
    xz = pmm(params, "in_proj", x)
    xin_raw, z = jnp.split(xz, 2, axis=-1)
    xin, _ = _causal_conv(xin_raw, params["conv_w"], params["conv_b"])
    xin = jax.nn.silu(xin)
    xin = constraint(xin, ("batch", None, "ffn"))

    dt, b_ssm, c_ssm, a = _ssm_params(params, xin, cfg)
    y, h_last = selective_scan(
        xin, dt, b_ssm, c_ssm, a, params["D"], s.d_state,
        chunk=chunk, return_state=True,
    )
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = constraint(pmm(params, "out_proj", y), ("batch", None, "residual"))
    if not return_state:
        return out
    ksz = params["conv_w"].shape[0]
    pad_needed = max(ksz - 1 - S0, 0)
    tail = xin_raw[:, max(S0 - (ksz - 1), 0) : S0, :]
    if pad_needed:
        tail = jnp.pad(tail, [(0, 0), (pad_needed, 0), (0, 0)])
    return out, {"conv": tail.astype(jnp.dtype(cfg.act_dtype)), "ssm": h_last}


def mamba_decode(params, x, cfg: ModelConfig, conv_state, ssm_state):
    """Exact single-token step. x: (B, 1, d_model).

    conv_state: (B, d_conv-1, d_in); ssm_state: (B, d_in, N) fp32.
    Returns (y, conv_state, ssm_state).
    """
    from .layers import pmm

    xz = pmm(params, "in_proj", x)
    xin, z = jnp.split(xz, 2, axis=-1)
    xin, conv_state = _causal_conv(xin, params["conv_w"], params["conv_b"], conv_state)
    xin = jax.nn.silu(xin)

    dt, b_ssm, c_ssm, a = _ssm_params(params, xin, cfg)
    dtf = dt[:, 0].astype(jnp.float32)  # (B, d_in)
    abar = jnp.exp(dtf[..., None] * a)  # (B, d_in, N)
    bu = (dtf * xin[:, 0].astype(jnp.float32))[..., None] * b_ssm[:, 0].astype(jnp.float32)[:, None, :]
    ssm_state = abar * ssm_state + bu
    y = jnp.einsum("bdn,bn->bd", ssm_state, c_ssm[:, 0].astype(jnp.float32))
    y = y + params["D"].astype(jnp.float32) * xin[:, 0].astype(jnp.float32)
    y = y.astype(x.dtype)[:, None, :] * jax.nn.silu(z)
    return pmm(params, "out_proj", y), conv_state, ssm_state


def mamba_state_shapes(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    return {
        "conv": jax.ShapeDtypeStruct((batch, s.d_conv - 1, d_in), jnp.dtype(cfg.act_dtype)),
        "ssm": jax.ShapeDtypeStruct((batch, d_in, s.d_state), jnp.float32),
    }
