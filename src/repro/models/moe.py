"""Mixture-of-Experts FFN with GShard-style grouped dispatch (top-k routing,
capacity-bounded, einsum dispatch/combine) — the classic TPU-shardable MoE
formulation (GShard arXiv:2006.16668, Switch arXiv:2101.03961).

Expert parallelism: the expert axis of the stacked weights is sharded over
the mesh's ``data`` axis when divisible (EP), with tensor parallelism over
``model`` inside each expert; XLA SPMD inserts the dispatch/combine
all-to-alls from the sharding constraints on the (E, G, C, D) tensors.
"""

from __future__ import annotations

import math
import os

import jax
import jax.numpy as jnp

from .config import ModelConfig

# §Perf A/B switch: "1" restores the pre-iteration-2 sharding behavior
# (unconstrained dispatch tensors, expert-axis-or-nothing) for the
# EXPERIMENTS.md before/after measurements.
_PERF_BASELINE = os.environ.get("REPRO_PERF_BASELINE") == "1"


def init_moe(key, cfg: ModelConfig):
    mo = cfg.moe
    d, f, e = cfg.d_model, mo.d_ff_expert, mo.n_experts
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    p = {"router": (jax.random.normal(ks[0], (d, e)) * s_in).astype(dt)}
    if cfg.act == "swiglu":
        p["wg"] = (jax.random.normal(ks[1], (e, d, f)) * s_in).astype(dt)
        p["wu"] = (jax.random.normal(ks[2], (e, d, f)) * s_in).astype(dt)
    else:
        p["wi"] = (jax.random.normal(ks[1], (e, d, f)) * s_in).astype(dt)
    p["wd"] = (jax.random.normal(ks[3], (e, f, d)) * s_out).astype(dt)
    return p


def expert_capacity(cfg: ModelConfig, group: int) -> int:
    mo = cfg.moe
    c = int(math.ceil(group * mo.top_k * mo.capacity_factor / mo.n_experts))
    return max(4, min(c, group))


def _dispatch_combine(gates_topv, gates_topi, e: int, c: int):
    """Build (G, g, E, C) dispatch (0/1) and combine (gate-weighted) arrays.

    gates_topv/topi: (G, g, k). Token-major priority: earlier tokens in the
    group win capacity slots (standard GShard tie-break). Assignments beyond
    capacity are dropped (their gate mass is simply lost, as usual).
    """
    G, g, k = gates_topi.shape
    # (G, g, k, E) one-hot of expert choice
    onehot = jax.nn.one_hot(gates_topi, e, dtype=jnp.int32)
    # flatten (token, slot) in token-major order to rank assignments
    flat = onehot.reshape(G, g * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat  # rank within expert queue
    keep = (pos < c) & (flat > 0)
    pos = pos.reshape(G, g, k, e)
    keep = keep.reshape(G, g, k, e)
    # (G, g, k, E, C) one-hot position, reduced over the slot axis k so the
    # persistent tensors are only (G, g, E, C)
    pos_oh = jax.nn.one_hot(pos, c, dtype=gates_topv.dtype) * keep[..., None]
    combine = jnp.einsum("gsk,gskec->gsec", gates_topv, pos_oh)
    dispatch = (combine > 0).astype(gates_topv.dtype)
    return dispatch, combine


def route(router_w, x, cfg: ModelConfig):
    """Group tokens and build the routing tensors (shared by the float model
    and the PTQ families adapter, which must route identically).

    x: (B, S, d). Returns (xf (G, g, d), dispatch, combine, gates, topi, c).
    """
    mo = cfg.moe
    B, S, d = x.shape
    n_tok = B * S
    g = min(mo.group_size, n_tok)
    if n_tok % g:
        g = math.gcd(n_tok, g)
    G = n_tok // g
    c = expert_capacity(cfg, g)
    xf = x.reshape(G, g, d)

    logits = (xf @ router_w).astype(jnp.float32)  # (G, g, E)
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, mo.top_k)  # (G, g, k)
    topv = topv / jnp.maximum(jnp.sum(topv, axis=-1, keepdims=True), 1e-9)
    dispatch, combine = _dispatch_combine(topv.astype(x.dtype), topi, mo.n_experts, c)
    return xf, dispatch, combine, gates, topi, c


def _expert_matmul(params, name, xe):
    """Per-expert stacked matmul ``einsum("egcd,edf->egcf")`` with packed
    dispatch: when the (E, K//2, N) leaf is a packed artifact and the W4A8
    kernel backend is active, vmap the fused kernel over the expert axis
    (activation quantization per expert — static when the leaf carries the
    calibrated stacked ``act_scale``/``act_zp``, dynamic otherwise)
    instead of dequantizing the whole expert stack in-graph."""
    from .layers import (
        is_dequant_site,
        is_packed,
        packed_backend,
        packed_linear,
        resolve_weight,
    )

    leaf = params[name]
    if not (is_packed(leaf) and packed_backend() != "dequant"):
        out = jnp.einsum("egcd,edf->egcf", xe, resolve_weight(params, name))
        if (is_packed(leaf) or is_dequant_site(leaf)) and "bias" in leaf:
            # calibrated artifacts carry the bias-corrected bias (E, 1, C);
            # apply it here too so both backends compute the same function
            out = out + leaf["bias"][:, None].astype(out.dtype)
        return out
    E, G, C, D = xe.shape
    out = jax.vmap(packed_linear)(xe.reshape(E, G * C, D), leaf)
    return out.reshape(E, G, C, -1)


def moe(params, x, cfg: ModelConfig):
    """x: (B, S, d_model) -> (B, S, d_model), plus aux losses in out dict."""
    from .layers import constraint

    mo = cfg.moe
    B, S, d = x.shape
    xf, dispatch, combine, gates, topi, c = route(params["router"], x, cfg)
    # token-side tensors stay sharded with the tokens (unconstrained they
    # were replicated by SPMD -> TB-scale all-gathers; §Perf iteration 2)
    if not _PERF_BASELINE:
        dispatch = constraint(dispatch, ("batch", None, None, None))
        combine = constraint(combine, ("batch", None, None, None))

    from repro.runtime.sharding import prefer_expert_sharding

    if _PERF_BASELINE or prefer_expert_sharding(mo.n_experts):
        # EP: all-to-all from token-sharded G to expert-sharded E
        exp_names = ("expert", None, None, None)
        hid_names = ("expert", None, None, "ffn")
    else:
        # expert count does not divide the data axis (granite 40e on 16):
        # keep tokens sharded, experts via FSDP-gathered weights, no a2a
        exp_names = (None, "batch", None, None)
        hid_names = (None, "batch", None, "ffn")

    xe = jnp.einsum("gsec,gsd->egcd", dispatch, xf)
    xe = constraint(xe, exp_names)
    if cfg.act == "swiglu":
        h = jax.nn.silu(_expert_matmul(params, "wg", xe))
        h = h * _expert_matmul(params, "wu", xe)
    else:
        h = jax.nn.gelu(_expert_matmul(params, "wi", xe))
    h = constraint(h, hid_names)
    ye = _expert_matmul(params, "wd", h)
    ye = constraint(ye, exp_names)
    y = jnp.einsum("gsec,egcd->gsd", combine, ye)

    # Switch-style load-balance auxiliary loss
    density = jnp.mean(jax.nn.one_hot(topi[..., 0], mo.n_experts), axis=(0, 1))
    router_prob = jnp.mean(gates, axis=(0, 1))
    aux_loss = mo.n_experts * jnp.sum(density * router_prob)

    y = y.reshape(B, S, d).astype(x.dtype)
    return constraint(y, ("batch", None, "residual")), aux_loss
