"""Model configuration schema for the repro model zoo.

A model is a token embedding, a stack of layers, a final norm and an LM
head. Each layer is a (mixer, ffn) pair:

    mixer ∈ {"attn", "mamba", "mlstm", "slstm", "none"}
    ffn   ∈ {"mlp", "moe", "none"}

Heterogeneous stacks (Jamba's 1:7 attention:Mamba interleave, xLSTM's
sLSTM/mLSTM mix) are expressed as a *pattern* — a tuple of LayerSpec of
length ``period`` — repeated ``n_layers // period`` times. The runtime scans
over repeats with the period unrolled inside the scan body, so the compiled
HLO is O(period), not O(n_layers): essential for the 126-layer dry-runs on
this box.
"""

from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    group_size: int = 1024  # dispatch group size (GShard-style)
    router_jitter: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 selective SSM."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int | None = None  # default ceil(d_model / 16)


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block internals (arXiv:2405.04517)."""

    mlstm_expand: int = 2  # up-projection factor for mLSTM blocks
    mlstm_heads: int = 4
    slstm_heads: int = 4
    slstm_proj_factor: float = 4.0 / 3.0  # post-block FFN factor
    chunk: int = 64  # chunkwise-parallel length for mLSTM


@dataclass(frozen=True)
class LayerSpec:
    mixer: str  # attn | mamba | mlstm | slstm | none
    ffn: str  # mlp | moe | none


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    pattern: tuple[LayerSpec, ...] = (LayerSpec("attn", "mlp"),)
    d_head: int | None = None
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    xlstm: XLSTMConfig | None = None
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "swiglu"  # swiglu | gelu
    rope_theta: float = 10_000.0
    max_seq_len: int = 8192
    tie_embeddings: bool = False
    attn_logit_softcap: float | None = None
    # modality frontends are STUBS: precomputed embeddings arrive as inputs
    frontend: str | None = None  # None | "vision_stub" | "audio_stub"
    frontend_tokens: int = 0  # e.g. 256 patch embeddings prepended (vlm)
    param_dtype: str = "float32"
    act_dtype: str = "float32"
    # attention over long sequences: online-softmax chunking threshold
    attn_chunk: int = 1024
    attn_chunk_threshold: int = 8192
    remat: str = "block"  # none | block
    scan_layers: bool = True  # False: python-unrolled stack (analysis/validation)
    # "parallel": chunkwise state-emitting prefill for recurrent mixers
    # (§Perf iteration 1); "stepwise": the per-token recurrence (baseline,
    # exact but O(S) sequential steps)
    prefill_mode: str = "parallel"
    # pad the embedding/head vocab rows to this multiple so the vocab dim
    # always TP-shards (pad logits are masked to -inf; §Perf iteration 2 —
    # an unshardable 49155-row head replicated the logits and all-reduced
    # them every microbatch). 128 is a no-op for every assigned arch except
    # granite (49155 -> 49280). 1 disables.
    vocab_pad_multiple: int = 128

    def __post_init__(self):
        if self.n_layers % len(self.pattern) != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"pattern period {len(self.pattern)}"
            )
        if self.n_heads % self.n_kv_heads != 0:
            raise ValueError(f"{self.name}: n_heads must be divisible by n_kv_heads")
        needs_moe = any(s.ffn == "moe" for s in self.pattern)
        if needs_moe and self.moe is None:
            raise ValueError(f"{self.name}: pattern uses moe but moe config is None")
        needs_ssm = any(s.mixer == "mamba" for s in self.pattern)
        if needs_ssm and self.ssm is None:
            raise ValueError(f"{self.name}: pattern uses mamba but ssm config is None")
        needs_xlstm = any(s.mixer in ("mlstm", "slstm") for s in self.pattern)
        if needs_xlstm and self.xlstm is None:
            raise ValueError(f"{self.name}: pattern uses xlstm but xlstm config is None")

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def repeats(self) -> int:
        return self.n_layers // self.period

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        m = max(self.vocab_pad_multiple, 1)
        return ((self.vocab + m - 1) // m) * m

    @property
    def sub_quadratic(self) -> bool:
        """True when the stack's sequence mixing is not dominated by full
        attention (SSM / linear-recurrent / hybrid families)."""
        attn_frac = sum(1 for s in self.pattern if s.mixer == "attn") / self.period
        return attn_frac < 0.5

    @property
    def dt_rank(self) -> int:
        if self.ssm is None:
            return 0
        return self.ssm.dt_rank or -(-self.d_model // 16)

    def scaled(self, **updates) -> "ModelConfig":
        return replace(self, **updates)


def uniform_pattern(mixer: str = "attn", ffn: str = "mlp") -> tuple[LayerSpec, ...]:
    return (LayerSpec(mixer, ffn),)


def jamba_pattern(period: int = 8, attn_at: int = 4) -> tuple[LayerSpec, ...]:
    """Jamba (arXiv:2403.19887): 1 attention per ``period`` layers, MoE every
    other layer; the rest are Mamba + dense MLP."""
    specs = []
    for i in range(period):
        mixer = "attn" if i == attn_at else "mamba"
        ffn = "moe" if i % 2 == 1 else "mlp"
        specs.append(LayerSpec(mixer, ffn))
    return tuple(specs)


def xlstm_pattern(period: int = 8, slstm_at: int = 0) -> tuple[LayerSpec, ...]:
    """xLSTM [7:1] mix: one sLSTM block per period, the rest mLSTM; blocks
    carry their own projections (d_ff == 0 → ffn "none")."""
    return tuple(
        LayerSpec("slstm" if i == slstm_at else "mlstm", "none") for i in range(period)
    )


# Count parameters analytically (used by roofline MODEL_FLOPS and docs).
def param_count(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab
    hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    total = v * d  # embedding
    if not cfg.tie_embeddings:
        total += v * d  # head
    active = total
    per_pattern = []
    for spec in cfg.pattern:
        n = 0
        n_active = 0
        if spec.mixer == "attn":
            n += d * (nh * hd) + 2 * d * (nkv * hd) + (nh * hd) * d
        elif spec.mixer == "mamba":
            s = cfg.ssm
            d_in = s.expand * d
            n += d * 2 * d_in  # in_proj (x, z)
            n += d_in * s.d_conv  # conv
            n += d_in * (cfg.dt_rank + 2 * s.d_state)  # x -> dt, B, C
            n += cfg.dt_rank * d_in  # dt_proj
            n += d_in * s.d_state + d_in  # A_log, D
            n += d_in * d  # out_proj
        elif spec.mixer == "mlstm":
            x = cfg.xlstm
            d_in = x.mlstm_expand * d
            n += d * 2 * d_in  # up proj (x, z)
            n += 3 * d_in * d_in  # q, k, v
            n += 2 * d_in  # i, f gates (per-dim proj to heads folded)
            n += d_in * d  # down proj
        elif spec.mixer == "slstm":
            x = cfg.xlstm
            n += 4 * d * d + 4 * d * (d // x.slstm_heads)  # in + block-diag recurrent
            f = x.slstm_proj_factor
            n += int(2 * d * d * f)  # post FFN up/down
        n_active += n
        if spec.ffn == "mlp":
            m = 3 * d * cfg.d_ff if cfg.act == "swiglu" else 2 * d * cfg.d_ff
            n += m
            n_active += m
        elif spec.ffn == "moe":
            mo = cfg.moe
            per_exp = 3 * d * mo.d_ff_expert if cfg.act == "swiglu" else 2 * d * mo.d_ff_expert
            n += mo.n_experts * per_exp + d * mo.n_experts
            n_active += mo.top_k * per_exp + d * mo.n_experts
        per_pattern.append((n, n_active))
    total += cfg.repeats * sum(p[0] for p in per_pattern)
    active += cfg.repeats * sum(p[1] for p in per_pattern)
    # norms (2 per layer + final) are negligible but counted
    total += (2 * cfg.n_layers + 1) * d
    active += (2 * cfg.n_layers + 1) * d
    return {"total": int(total), "active": int(active)}
