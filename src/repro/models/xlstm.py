"""xLSTM blocks (Beck et al., arXiv:2405.04517): mLSTM (matrix memory,
exponential gating) and sLSTM (scalar memory, real recurrence).

mLSTM has no nonlinearity across time in its state update, so we implement
the *chunkwise-parallel* form for training/prefill (intra-chunk quadratic
attention-like compute + inter-chunk recurrent state, all in stabilized
log-space) and the exact recurrent form for decode and as the test oracle.
State size is O(d_head^2) per head — sequence-length independent, which is
what makes the 500k-token long-context cells tractable.

sLSTM's recurrence is nonlinear (h feeds back through the gates), so there is
no parallel form — training scans over time, exactly as the paper designs it.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .ssm import _causal_conv

NEG = -1e30  # finite stand-in for -inf in log-space stabilizers


# ===========================================================================
# mLSTM
# ===========================================================================
def init_mlstm(key, cfg: ModelConfig):
    x = cfg.xlstm
    d = cfg.d_model
    d_in = x.mlstm_expand * d
    h = x.mlstm_heads
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(d)
    si = 1.0 / math.sqrt(d_in)
    return {
        "up": (jax.random.normal(ks[0], (d, 2 * d_in)) * s).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (4, d_in)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((d_in,), dt),
        "wq": (jax.random.normal(ks[2], (d_in, d_in)) * si).astype(dt),
        "wk": (jax.random.normal(ks[3], (d_in, d_in)) * si).astype(dt),
        "wv": (jax.random.normal(ks[4], (d_in, d_in)) * si).astype(dt),
        "wi": (jax.random.normal(ks[5], (d_in, h)) * si).astype(dt),
        "wf": (jax.random.normal(ks[6], (d_in, h)) * si).astype(dt),
        "f_bias": jnp.full((h,), 3.0, dt),  # forget gates open at init
        "skip": jnp.ones((d_in,), dt),
        "norm_w": jnp.ones((d_in,), dt),
        "down": (jax.random.normal(ks[7], (d_in, d)) * si).astype(dt),
    }


def _mlstm_qkvgates(params, xin, cfg: ModelConfig, conv_state=None):
    """Shared pre-cell computation. xin: (B, S, d_in)."""
    x = cfg.xlstm
    h = x.mlstm_heads
    B, S, d_in = xin.shape
    dh = d_in // h
    from .layers import pmm

    xc, conv_state = _causal_conv(xin, params["conv_w"], params["conv_b"], conv_state)
    xc = jax.nn.silu(xc)
    q = pmm(params, "wq", xc).reshape(B, S, h, dh).transpose(0, 2, 1, 3)
    k = pmm(params, "wk", xc).reshape(B, S, h, dh).transpose(0, 2, 1, 3)
    v = pmm(params, "wv", xin).reshape(B, S, h, dh).transpose(0, 2, 1, 3)
    q = q * (dh**-0.5)
    ig = (xin @ params["wi"]).transpose(0, 2, 1).astype(jnp.float32)  # (B,H,S)
    fg = (xin @ params["wf"] + params["f_bias"]).transpose(0, 2, 1).astype(jnp.float32)
    return q, k, v, ig, fg, xc, conv_state


def mlstm_cell_recurrent(q, k, v, ig, fg):
    """Exact recurrence (test oracle + decode building block).

    q/k/v: (B, H, S, dh); ig/fg: (B, H, S). Returns h: (B, H, S, dh).
    """
    B, H, S, dh = q.shape
    lf = jax.nn.log_sigmoid(fg)

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, it, lft = inp
        m_new = jnp.maximum(lft + m, it)
        fp = jnp.exp(lft + m - m_new)
        ip = jnp.exp(it - m_new)
        C = fp[..., None, None] * C + ip[..., None, None] * (kt[..., :, None] * vt[..., None, :])
        n = fp[..., None] * n + ip[..., None] * kt
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        qn = jnp.einsum("bhd,bhd->bh", qt, n)
        den = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))
        return (C, n, m_new), num / den[..., None]

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.full((B, H), NEG, jnp.float32)
    xs = (
        q.transpose(2, 0, 1, 3).astype(jnp.float32),
        k.transpose(2, 0, 1, 3).astype(jnp.float32),
        v.transpose(2, 0, 1, 3).astype(jnp.float32),
        ig.transpose(2, 0, 1),
        lf.transpose(2, 0, 1),
    )
    _, hs = jax.lax.scan(step, (C0, n0, m0), xs)
    return hs.transpose(1, 2, 0, 3)  # (B, H, S, dh)


def mlstm_cell_chunkwise(q, k, v, ig, fg, chunk: int = 64, return_state: bool = False):
    """Chunkwise-parallel mLSTM (stabilized), the training path.

    Matches :func:`mlstm_cell_recurrent` to fp32 tolerance (tested).
    ``return_state``: also return the end-of-sequence (C, n, m) carry for
    decode (chunkwise-parallel prefill — §Perf iteration 1).
    """
    B, H, S0, dh = q.shape
    L = min(chunk, S0)
    pad = (-S0) % L
    if pad:  # ragged tail: i-gate = -inf (no input), f-gate = +30
        # (log-sigmoid ~ 0: no decay) so padded steps leave the carried
        # state exactly untouched; outputs there are sliced off below
        p4 = [(0, 0), (0, 0), (0, pad), (0, 0)]
        q, k, v = (jnp.pad(t, p4) for t in (q, k, v))
        ig = jnp.pad(ig, [(0, 0), (0, 0), (0, pad)], constant_values=NEG)
        fg = jnp.pad(fg, [(0, 0), (0, 0), (0, pad)], constant_values=30.0)
    S = S0 + pad
    nc = S // L
    lf = jax.nn.log_sigmoid(fg)

    q_c = q.reshape(B, H, nc, L, dh).transpose(2, 0, 1, 3, 4).astype(jnp.float32)
    k_c = k.reshape(B, H, nc, L, dh).transpose(2, 0, 1, 3, 4).astype(jnp.float32)
    v_c = v.reshape(B, H, nc, L, dh).transpose(2, 0, 1, 3, 4).astype(jnp.float32)
    ig_c = ig.reshape(B, H, nc, L).transpose(2, 0, 1, 3)
    lf_c = lf.reshape(B, H, nc, L).transpose(2, 0, 1, 3)
    tril = jnp.tril(jnp.ones((L, L), bool))

    def body(carry, inp):
        C, n, m = carry  # (B,H,dh,dh), (B,H,dh), (B,H)
        qc, kc, vc, ic, lfc = inp
        b = jnp.cumsum(lfc, axis=-1)  # (B,H,L) inclusive log-decay
        s_cm = jax.lax.cummax(ic - b, axis=ic.ndim - 1)
        m_t = b + jnp.maximum(m[..., None], s_cm)  # (B,H,L)
        # inter-chunk contribution from carried state
        inter_scale = jnp.exp(b + m[..., None] - m_t)  # (B,H,L)
        h_inter = jnp.einsum("bhld,bhde->bhle", qc, C) * inter_scale[..., None]
        n_inter = n[:, :, None, :] * inter_scale[..., None]  # (B,H,L,dh)
        # intra-chunk attention-like term
        logd = ic[:, :, None, :] + b[:, :, :, None] - b[:, :, None, :] - m_t[..., None]
        dmat = jnp.where(tril[None, None], jnp.exp(logd), 0.0)  # (B,H,Lt,Lj)
        smat = jnp.einsum("bhtd,bhjd->bhtj", qc, kc) * dmat
        h_intra = jnp.einsum("bhtj,bhjd->bhtd", smat, vc)
        n_intra = jnp.einsum("bhtj,bhjd->bhtd", dmat, kc)
        n_vec = n_inter + n_intra
        qn = jnp.einsum("bhld,bhld->bhl", qc, n_vec)
        den = jnp.maximum(jnp.abs(qn), jnp.exp(-m_t))
        h = (h_inter + h_intra) / den[..., None]
        # carry to next chunk
        g = b[..., -1]  # total chunk decay
        m_next = g + jnp.maximum(m, s_cm[..., -1])
        w_c = jnp.exp(ic + g[..., None] - b - m_next[..., None])  # (B,H,L)
        C = C * jnp.exp(g + m - m_next)[..., None, None] + jnp.einsum(
            "bhl,bhld,bhle->bhde", w_c, kc, vc
        )
        n = n * jnp.exp(g + m - m_next)[..., None] + jnp.einsum("bhl,bhld->bhd", w_c, kc)
        return (C, n, m_next), h

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.full((B, H), NEG, jnp.float32)
    carry, hs = jax.lax.scan(body, (C0, n0, m0), (q_c, k_c, v_c, ig_c, lf_c))
    # (nc, B, H, L, dh) -> (B, H, S, dh)
    out = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, dh)[:, :, :S0]
    if return_state:
        return out, carry  # padded tail steps have i-gate=-inf: state exact
    return out


def _mlstm_merge(params, h_cell, xc, z, cfg: ModelConfig):
    """Head-merge, per-head norm, learnable conv skip, z-gate — everything
    between the cell and the down projection (split out so the PTQ adapter
    can tap the down projection separately)."""
    B, H, S, dh = h_cell.shape
    h = h_cell.transpose(0, 2, 1, 3)  # (B,S,H,dh)
    # per-head RMS norm ("multi-head norm" in the official block)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    h = h * jax.lax.rsqrt(var + 1e-6)
    h = h.reshape(B, S, H * dh).astype(z.dtype) * params["norm_w"]
    h = h + params["skip"] * xc
    return h * jax.nn.silu(z)


def _mlstm_out(params, h_cell, xc, z, cfg: ModelConfig):
    from .layers import pmm

    return pmm(params, "down", _mlstm_merge(params, h_cell, xc, z, cfg))


def mlstm(params, x, cfg: ModelConfig, return_state: bool = False):
    """Training/prefill mLSTM block. x: (B, S, d_model)."""
    from .layers import constraint, pmm

    xz = pmm(params, "up", x)
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = constraint(xin, ("batch", None, "ffn"))
    z = constraint(z, ("batch", None, "ffn"))
    q, k, v, ig, fg, xc, _ = _mlstm_qkvgates(params, xin, cfg)
    cell = mlstm_cell_chunkwise(q, k, v, ig, fg, cfg.xlstm.chunk,
                                return_state=return_state)
    if return_state:
        h_cell, (C, n, m) = cell
    else:
        h_cell = cell
    y = _mlstm_out(params, h_cell, xc, z, cfg)
    y = constraint(y, ("batch", None, "residual"))
    if not return_state:
        return y
    S0 = x.shape[1]
    tail = xin[:, max(S0 - 3, 0):, :]
    if S0 < 3:
        tail = jnp.pad(tail, [(0, 0), (3 - S0, 0), (0, 0)])
    return y, {"conv": tail.astype(jnp.dtype(cfg.act_dtype)), "C": C, "n": n, "m": m}


def mlstm_decode(params, x, cfg: ModelConfig, conv_state, C, n, m):
    """Single-token step. States: conv (B,3,d_in), C (B,H,dh,dh) fp32,
    n (B,H,dh) fp32, m (B,H) fp32."""
    from .layers import pmm

    xz = pmm(params, "up", x)
    xin, z = jnp.split(xz, 2, axis=-1)
    q, k, v, ig, fg, xc, conv_state = _mlstm_qkvgates(params, xin, cfg, conv_state)
    qt = q[:, :, 0].astype(jnp.float32)
    kt = k[:, :, 0].astype(jnp.float32)
    vt = v[:, :, 0].astype(jnp.float32)
    it, lft = ig[:, :, 0], jax.nn.log_sigmoid(fg[:, :, 0])
    m_new = jnp.maximum(lft + m, it)
    fp = jnp.exp(lft + m - m_new)
    ip = jnp.exp(it - m_new)
    C = fp[..., None, None] * C + ip[..., None, None] * (kt[..., :, None] * vt[..., None, :])
    n = fp[..., None] * n + ip[..., None] * kt
    num = jnp.einsum("bhd,bhde->bhe", qt, C)
    qn = jnp.einsum("bhd,bhd->bh", qt, n)
    den = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))
    h_cell = (num / den[..., None])[:, :, None, :]  # (B,H,1,dh)
    y = _mlstm_out(params, h_cell, xc, z, cfg)
    return y, conv_state, C, n, m_new


def mlstm_state_shapes(cfg: ModelConfig, batch: int):
    x = cfg.xlstm
    d_in = x.mlstm_expand * cfg.d_model
    h = x.mlstm_heads
    dh = d_in // h
    return {
        "conv": jax.ShapeDtypeStruct((batch, 3, d_in), jnp.dtype(cfg.act_dtype)),
        "C": jax.ShapeDtypeStruct((batch, h, dh, dh), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, h, dh), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, h), jnp.float32),
    }


# ===========================================================================
# sLSTM
# ===========================================================================
def init_slstm(key, cfg: ModelConfig):
    x = cfg.xlstm
    d = cfg.d_model
    h = x.slstm_heads
    dh = d // h
    f = int(d * x.slstm_proj_factor)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    s = 1.0 / math.sqrt(d)
    sh = 1.0 / math.sqrt(dh)
    return {
        "w_in": (jax.random.normal(ks[0], (d, 4 * d)) * s).astype(dt),  # z,i,f,o
        "r": (jax.random.normal(ks[1], (4, h, dh, dh)) * sh).astype(dt),
        "b": jnp.concatenate(
            [jnp.zeros((2 * d,)), jnp.full((d,), 3.0), jnp.zeros((d,))]
        ).astype(dt),
        "norm_w": jnp.ones((d,), dt),
        "up": (jax.random.normal(ks[2], (d, f)) * s).astype(dt),
        "down": (jax.random.normal(ks[3], (f, d)) * (1.0 / math.sqrt(f))).astype(dt),
    }


def _slstm_step(params, xt_proj, state, cfg: ModelConfig):
    """One recurrence step. xt_proj: (B, 4d) precomputed input projection."""
    x = cfg.xlstm
    h_heads = x.slstm_heads
    d = cfg.d_model
    dh = d // h_heads
    h_prev, c, n, m = state  # (B,d), (B,d), (B,d), (B,d)
    B = h_prev.shape[0]
    hh = h_prev.reshape(B, h_heads, dh).astype(jnp.float32)
    rec = jnp.einsum("bhd,ghde->gbhe", hh, params["r"].astype(jnp.float32))  # (4,B,H,dh)
    rec = rec.reshape(4, B, d)
    pre = xt_proj.astype(jnp.float32).reshape(B, 4, d).transpose(1, 0, 2) + rec
    zt = jnp.tanh(pre[0])
    it, ft, ot = pre[1], pre[2], pre[3]
    m_new = jnp.maximum(ft + m, it)
    ip = jnp.exp(it - m_new)
    fp = jnp.exp(ft + m - m_new)
    c = fp * c + ip * zt
    n = fp * n + ip
    h_new = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1e-12)
    return (h_new, c, n, m_new)


def slstm_scan(params, proj, cfg: ModelConfig):
    """Run the (inherently sequential) sLSTM recurrence over precomputed
    input projections. proj: (B, S, 4d). Returns (h (B, S, d), final state).
    Split out so the PTQ adapter can tap the surrounding projections."""
    B, S, _ = proj.shape
    d = cfg.d_model

    def step(state, xt):
        new = _slstm_step(params, xt, state, cfg)
        return new, new[0]

    z0 = jnp.zeros((B, d), jnp.float32)
    m0 = jnp.full((B, d), NEG, jnp.float32)
    final, hs = jax.lax.scan(step, (z0, z0, z0, m0), proj.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2), final


def slstm_headnorm(params, h, cfg: ModelConfig):
    """Head-wise RMS norm + elementwise weight preceding the block FFN."""
    B, S, d = h.shape
    hheads = h.reshape(B, S, cfg.xlstm.slstm_heads, -1)
    var = jnp.mean(jnp.square(hheads.astype(jnp.float32)), axis=-1, keepdims=True)
    hn = (hheads * jax.lax.rsqrt(var + 1e-6).astype(h.dtype)).reshape(B, S, d)
    return hn * params["norm_w"]


def slstm(params, x, cfg: ModelConfig, return_state: bool = False):
    """Training/prefill sLSTM block — sequential scan (no parallel form).

    x: (B, S, d_model)."""
    from .layers import constraint, pmm

    proj = pmm(params, "w_in", x) + params["b"]  # (B, S, 4d)
    hs, final = slstm_scan(params, proj, cfg)
    h = hs.astype(x.dtype)  # (B,S,d)
    # head-wise norm then the block's gated FFN (proj factor 4/3)
    hn = slstm_headnorm(params, h, cfg)
    y = pmm(params, "down", jax.nn.gelu(pmm(params, "up", hn)))
    y = constraint(y, ("batch", None, "residual"))
    if not return_state:
        return y
    hf, cf, nf, mf = final
    return y, {"h": hf, "c": cf, "n": nf, "m": mf}


def slstm_decode(params, x, cfg: ModelConfig, h, c, n, m):
    """Single-token step. x: (B, 1, d_model); states (B, d) fp32."""
    from .layers import pmm

    B = x.shape[0]
    d = cfg.d_model
    proj = (pmm(params, "w_in", x[:, 0]) + params["b"]).astype(jnp.float32)
    h, c, n, m = _slstm_step(params, proj, (h, c, n, m), cfg)
    hheads = h.reshape(B, 1, cfg.xlstm.slstm_heads, -1)
    var = jnp.mean(jnp.square(hheads), axis=-1, keepdims=True)
    hn = (hheads * jax.lax.rsqrt(var + 1e-6)).reshape(B, 1, d).astype(x.dtype)
    hn = hn * params["norm_w"]
    y = pmm(params, "down", jax.nn.gelu(pmm(params, "up", hn)))
    return y, h, c, n, m


def slstm_state_shapes(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    return {
        "h": jax.ShapeDtypeStruct((batch, d), jnp.float32),
        "c": jax.ShapeDtypeStruct((batch, d), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, d), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, d), jnp.float32),
    }
