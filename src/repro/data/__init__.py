from .pipeline import (
    DataConfig,
    FileTokenSource,
    SyntheticMarkovSource,
    TokenBatcher,
    make_source,
)

__all__ = [
    "DataConfig",
    "FileTokenSource",
    "SyntheticMarkovSource",
    "TokenBatcher",
    "make_source",
]
