"""Deterministic, checkpointable data pipeline.

Two sources:
  * :class:`SyntheticMarkovSource` — a fixed random Markov-chain "teacher"
    over the vocabulary (low-entropy, learnable structure). A model trained
    on it shows genuine loss decrease and meaningful perplexity, which is
    what the paper-reproduction benchmarks need in an offline container.
  * :class:`FileTokenSource` — memory-mapped binary token file (uint16/32),
    the production path.

:class:`TokenBatcher` handles per-host sharding (each host materializes only
its slice of the global batch) and O(1) skip-ahead on restart: batch index i
is a pure function of (seed, i), so resuming from a checkpointed step never
replays or skips data.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"  # synthetic | file
    path: str | None = None
    branching: int = 4  # synthetic: candidate successors per state (lower = easier)


class SyntheticMarkovSource:
    """Order-1 Markov teacher: each token has ``branching`` plausible
    successors with Zipf-ish probabilities, derived deterministically from
    the seed. Entropy ~ log(branching) nats < log(vocab): learnable."""

    def __init__(self, vocab: int, seed: int = 0, branching: int = 4):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        self.succ = rng.integers(0, vocab, size=(vocab, branching))
        probs = 1.0 / np.arange(1, branching + 1)
        self.probs = probs / probs.sum()

    def sample(self, n_seqs: int, seq_len: int, rng: np.random.Generator) -> np.ndarray:
        toks = np.empty((n_seqs, seq_len), np.int32)
        state = rng.integers(0, self.vocab, size=n_seqs)
        toks[:, 0] = state
        for t in range(1, seq_len):
            choice = rng.choice(len(self.probs), size=n_seqs, p=self.probs)
            state = self.succ[state, choice]
            toks[:, t] = state
        return toks


class FileTokenSource:
    """Memory-mapped flat token file; random crops per batch index."""

    def __init__(self, path: str, vocab: int, dtype=np.uint16):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.vocab = vocab

    def sample(self, n_seqs: int, seq_len: int, rng: np.random.Generator) -> np.ndarray:
        hi = len(self.tokens) - seq_len - 1
        starts = rng.integers(0, hi, size=n_seqs)
        return np.stack(
            [self.tokens[s : s + seq_len].astype(np.int32) for s in starts]
        )


def make_source(cfg: DataConfig):
    if cfg.source == "synthetic":
        return SyntheticMarkovSource(cfg.vocab, cfg.seed, cfg.branching)
    if cfg.source == "file":
        return FileTokenSource(cfg.path, cfg.vocab)
    raise ValueError(f"unknown source {cfg.source!r}")


class TokenBatcher:
    """Stateless-per-index batcher: ``batch(i)`` is a pure function of
    (seed, i, host slice) — restart-safe and elastically reshardable (a
    restart on a different host count slices the same global batch
    differently but identically in content)."""

    def __init__(self, cfg: DataConfig, host_index: int = 0, host_count: int = 1):
        self.cfg = cfg
        self.source = make_source(cfg)
        if cfg.global_batch % host_count:
            raise ValueError("global batch must divide host count")
        self.per_host = cfg.global_batch // host_count
        self.host_index = host_index

    def batch(self, index: int) -> dict:
        rng = np.random.default_rng((self.cfg.seed, index))
        full = self.source.sample(self.cfg.global_batch, self.cfg.seq_len, rng)
        lo = self.host_index * self.per_host
        return {"tokens": full[lo : lo + self.per_host]}

    def eval_batches(self, n: int, offset: int = 1_000_000):
        for i in range(n):
            yield self.batch(offset + i)
