"""Continuous-batching generation engine over a paged KV cache.

Where :class:`~repro.serving.engine.GenerationEngine` is fixed-slot (all
sequences enter and leave together, against a dense ``(B, S_max, ...)``
cache slab), this engine runs an open system: requests queue in a
host-side :class:`~repro.serving.scheduler.Scheduler`, are admitted into
whichever engine slot is free, decode together regardless of phase, and
free their KV pages the moment they finish — so a skewed-length trace
keeps every slot busy instead of idling behind the batch's longest
member, and HBM holds ``num_blocks`` pages instead of
``max_concurrency * S_max`` dense rows.

Three jitted device programs, all operating on one cache pytree
(:func:`repro.models.transformer.init_paged_cache`):

* **admit** — pop ``n_pages`` from the device free-list stack, prefill
  the prompt (B=1), scatter its KV into the popped pages, splice
  recurrent-mixer state into the slot via ``dynamic_update_slice``, and
  sample the first token. One trace per (prompt_len, n_pages) bucket.
* **decode chunk** — up to ``chunk_max`` fused decode steps
  (``lax.while_loop`` with a *dynamic* trip count ``k``, so one trace
  serves every chunk length); every live slot advances at its own
  length. The host syncs once per chunk, not once per token.
* **release** — push the slot's pages back onto the free-list stack and
  clear its active bit.

Sampling is per-request deterministic: slot ``b``'s step ``t`` key is
``fold_in(fold_in(key(seed), uid_b), t)``, so a request's sampled tokens
do not depend on what else happens to be in flight. Greedy decode is
bit-identical to the fixed-slot engine (golden-pinned in
``tests/test_paged_engine.py``).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import (
    packed_backend,
    resolve_paged_attn_impl,
    use_packed_backend,
)
from repro.models.transformer import (
    decode_step_paged,
    init_paged_cache,
    prefill,
)
from repro.quant.serve_packed import upgrade_packed_params
from repro.quant.spec import (
    AttnDatapathSpec,
    tree_datapath_fingerprint,
    validate_attn_datapath,
    validate_datapath,
)
from repro.serving.engine import SamplerConfig, _sample
from repro.serving.scheduler import Request, Scheduler


@dataclass(frozen=True)
class PagedConfig:
    """Paged-cache + continuous-batching knobs.

    ``num_blocks`` sizes the shared KV pool (HBM bytes scale with it —
    see docs/serving_scheduler.md for the accounting); ``block_size`` is
    the page granularity; ``max_pages_per_seq`` caps one sequence's block
    table row (defaults to ``ceil(max_seq_len / block_size)``);
    ``chunk_max`` bounds how many decode steps run per host sync.
    ``kv_dtype="int8"`` stores *quantized* KV pages (int8 codes +
    per-(page, kv-head) scale leaves — pool HBM halves, so an HBM budget
    admits ~2x the sequences; see ``scheduler.blocks_for_budget``) and
    attention runs the AttnDatapathSpec-certified integer datapath;
    ``"act"`` keeps ``cfg.act_dtype`` float pages.
    """

    block_size: int = 64
    num_blocks: int = 256
    max_concurrency: int = 8
    max_pages_per_seq: int | None = None
    chunk_max: int = 32
    attn_impl: str = "auto"  # auto | ref | kernel | interpret
    kv_dtype: str = "act"  # act (= cfg.act_dtype) | int8 (quantized pages)


def _fold_keys(seed: int, uids, steps):
    base = jax.random.key(seed)
    return jax.vmap(
        lambda u, t: jax.random.fold_in(jax.random.fold_in(base, u), t)
    )(uids, steps)


def _sample_rows(logits, temperature: float, keys):
    """Per-row sampling with per-slot keys (request-deterministic) —
    vmaps the fixed-slot engine's ``_sample`` so both engines share one
    sampler (the greedy bit-identity guarantee rests on this)."""
    if temperature <= 0.0:
        return _sample(logits, temperature, None)
    return jax.vmap(lambda k, row: _sample(row, temperature, k))(keys, logits)


class PagedEngine:
    def __init__(self, params, cfg: ModelConfig, paged: PagedConfig = PagedConfig(),
                 sampler: SamplerConfig = SamplerConfig(), datapath=None,
                 attn_datapath=None):
        self.params = upgrade_packed_params(params)
        if datapath is not None:
            validate_datapath(self.params, datapath)
        self.datapath_fingerprint = tree_datapath_fingerprint(self.params)
        self.cfg = cfg
        self.sampler = sampler
        if paged.kv_dtype not in ("act", "int8"):
            raise ValueError(f"kv_dtype {paged.kv_dtype!r} not in ('act', 'int8')")
        max_pages = paged.max_pages_per_seq or -(-cfg.max_seq_len // paged.block_size)
        self.paged = paged = PagedConfig(
            block_size=paged.block_size, num_blocks=paged.num_blocks,
            max_concurrency=paged.max_concurrency, max_pages_per_seq=max_pages,
            chunk_max=paged.chunk_max, attn_impl=paged.attn_impl,
            kv_dtype=paged.kv_dtype,
        )
        #: the attention accumulator record the quantized kernel serves
        #: (None for float KV) — the attention analogue of the per-site
        #: DatapathSpec; ``attn_datapath`` is a *request* validated
        #: against it exactly like ``datapath`` against the packed leaves
        self.attn_spec = (
            AttnDatapathSpec.for_cache(cfg.head_dim, paged.block_size)
            if paged.kv_dtype == "int8" else None
        )
        if attn_datapath is not None:
            validate_attn_datapath(self.attn_spec, attn_datapath)
        self.cache = init_paged_cache(
            cfg, paged.max_concurrency, paged.num_blocks, paged.block_size,
            max_pages,
            kv_dtype="int8" if paged.kv_dtype == "int8" else None,
        )
        #: trace counters (python side effects — bump at trace time only)
        self.admit_traces = 0
        self.chunk_traces = 0
        self._uid_gen = 0

        # the cache pytree is DONATED to every program: it crosses the jit
        # boundary once per chunk/admit (unlike the dense engine, whose
        # cache lives inside one fused generate call), and without
        # donation each call would materialize a second full copy of the
        # KV page pools — 2x the HBM the pool was sized for
        @partial(jax.jit, static_argnames=("n_pages", "backend", "attn_impl",
                                           "datapath"),
                 donate_argnames=("cache",))
        def _admit(params, cache, prompt, slot, uid, n_pages, backend,
                   attn_impl, datapath):
            with use_packed_backend(backend):
                return self._admit_impl(params, cache, prompt, slot, uid,
                                        n_pages)

        @partial(jax.jit, static_argnames=("backend", "attn_impl", "datapath",
                                           "attn_spec"),
                 donate_argnames=("cache",))
        def _chunk(params, cache, k, backend, attn_impl, datapath, attn_spec):
            with use_packed_backend(backend):
                return self._chunk_impl(params, cache, k, attn_impl, attn_spec)

        @partial(jax.jit, static_argnames=("n_pages",),
                 donate_argnames=("cache",))
        def _release(cache, slot, n_pages):
            return self._release_impl(cache, slot, n_pages)

        self._admit = _admit
        self._chunk = _chunk
        self._release = _release

    # ------------------------------------------------------------------
    # Device programs (traced bodies)
    # ------------------------------------------------------------------
    def _admit_impl(self, params, cache, prompt, slot, uid, n_pages: int):
        """Admit one request into ``slot``: allocate pages, prefill, splice
        state, sample the generation's first token."""
        self.admit_traces += 1
        cfg, paged = self.cfg, self.paged
        bs = paged.block_size
        _, s0 = prompt.shape  # (1, S0)
        n_prompt_pages = -(-s0 // bs)
        prefill_len = n_prompt_pages * bs

        # pop n_pages off the free-list stack (host guarantees capacity)
        top = cache["free_top"]
        pages = jax.lax.dynamic_slice(cache["free_list"], (top,), (n_pages,))
        row = jnp.full((paged.max_pages_per_seq,), paged.num_blocks, jnp.int32)
        row = row.at[:n_pages].set(pages)
        table = jax.lax.dynamic_update_slice(
            cache["block_table"], row[None], (slot, jnp.int32(0))
        )

        logits, dense = prefill(params, {"tokens": prompt}, cfg, prefill_len)
        prompt_pages = pages[:n_prompt_pages]
        pools = []
        for i, spec in enumerate(cfg.pattern):
            c = cache["pools"][i]
            d = dense[i]
            if spec.mixer == "attn":
                # (R, 1, prefill_len, nkv, hd) -> per-page scatter into pool
                def to_pages(a):
                    r, _, _, nkv, hd = a.shape
                    return a.reshape(r, n_prompt_pages, bs, nkv, hd)

                if "k_scales" in c:
                    # quantize-on-scatter: codes + per-(page, head) scales
                    # stamped together (padded tail positions are zeros and
                    # cannot raise a page's max)
                    from repro.kernels.paged_attention import quantize_kv_pages

                    kc, ks = quantize_kv_pages(to_pages(d["k"]))
                    vc, vs = quantize_kv_pages(to_pages(d["v"]))
                    pools.append({
                        "k_pages": c["k_pages"].at[:, prompt_pages].set(kc),
                        "v_pages": c["v_pages"].at[:, prompt_pages].set(vc),
                        "k_scales": c["k_scales"].at[:, prompt_pages].set(ks),
                        "v_scales": c["v_scales"].at[:, prompt_pages].set(vs),
                    })
                else:
                    kp = c["k_pages"].at[:, prompt_pages].set(
                        to_pages(d["k"]).astype(c["k_pages"].dtype))
                    vp = c["v_pages"].at[:, prompt_pages].set(
                        to_pages(d["v"]).astype(c["v_pages"].dtype))
                    pools.append({"k_pages": kp, "v_pages": vp})
            elif spec.mixer != "none":
                # recurrent state: splice the (R, 1, ...) prefill state into
                # the slot's lane of the (R, num_slots, ...) batch
                merged = {}
                for k, leaf in c.items():
                    idx = (jnp.int32(0), slot) + (jnp.int32(0),) * (leaf.ndim - 2)
                    merged[k] = jax.lax.dynamic_update_slice(
                        leaf, d[k].astype(leaf.dtype), idx)
                pools.append(merged)
            else:
                pools.append(c)

        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(self.sampler.seed), uid),
            jnp.int32(0))
        nxt = _sample(logits[:, -1], self.sampler.temperature, key)  # (1,)

        new = dict(cache)
        new["pools"] = tuple(pools)
        new["block_table"] = table
        new["free_top"] = top + n_pages
        new["seq_lens"] = cache["seq_lens"].at[slot].set(s0)
        new["active"] = cache["active"].at[slot].set(True)
        new["uids"] = cache["uids"].at[slot].set(uid)
        new["steps"] = cache["steps"].at[slot].set(1)
        new["last_tok"] = cache["last_tok"].at[slot].set(nxt[0])
        return new, nxt[0]

    def _chunk_impl(self, params, cache, k, attn_impl: str, attn_spec):
        """Up to ``chunk_max`` decode steps; ``k`` is a *dynamic* trip
        count so every chunk length reuses one trace."""
        self.chunk_traces += 1
        cfg, samp = self.cfg, self.sampler
        n_slots, chunk_max = self.paged.max_concurrency, self.paged.chunk_max
        buf = jnp.zeros((n_slots, chunk_max), jnp.int32)

        def cond(st):
            t, _, _ = st
            return t < k

        def body(st):
            t, cache, buf = st
            logits, cache = decode_step_paged(
                params, cache["last_tok"][:, None], cache, cfg,
                attn_impl=attn_impl, attn_spec=attn_spec)
            keys = _fold_keys(samp.seed, cache["uids"], cache["steps"])
            nxt = _sample_rows(logits[:, -1], samp.temperature, keys)
            active = cache["active"]
            cache = dict(cache)
            cache["last_tok"] = jnp.where(active, nxt, cache["last_tok"])
            cache["steps"] = cache["steps"] + active.astype(jnp.int32)
            buf = jax.lax.dynamic_update_slice(buf, nxt[:, None], (0, t))
            return t + 1, cache, buf

        _, cache, buf = jax.lax.while_loop(cond, body, (jnp.int32(0), cache, buf))
        return cache, buf

    def _release_impl(self, cache, slot, n_pages: int):
        """Push the slot's pages back onto the free-list stack."""
        row = jax.lax.dynamic_slice(
            cache["block_table"], (slot, jnp.int32(0)),
            (1, self.paged.max_pages_per_seq))[0]
        top = cache["free_top"] - n_pages
        new = dict(cache)
        new["free_list"] = jax.lax.dynamic_update_slice(
            cache["free_list"], row[:n_pages], (top,))
        new["free_top"] = top
        new["active"] = cache["active"].at[slot].set(False)
        return new

    # ------------------------------------------------------------------
    # Host loop
    # ------------------------------------------------------------------
    def submit_all(self, requests) -> Scheduler:
        paged = self.paged
        sched = Scheduler(paged.max_concurrency, paged.num_blocks,
                          paged.block_size, paged.max_pages_per_seq)
        for r in requests:
            sched.submit(r)
        return sched

    def serve(self, requests) -> dict[int, np.ndarray]:
        """Run a request list to completion under continuous batching.

        Returns {uid: (S0_uid + n_generated,) int32} — generation is
        trimmed at the first EOS (when the sampler sets one), matching the
        fixed-slot engine's post-EOS padding semantics after re-padding.
        """
        sched = self.submit_all(requests)
        backend = packed_backend()
        attn_impl = resolve_paged_attn_impl(self.paged.attn_impl)
        eos = self.sampler.eos_id
        results: dict[int, np.ndarray] = {}

        def finish(slot):
            st = sched.finish(slot)
            self.cache = self._release(self.cache, jnp.int32(slot), st.n_pages)
            results[st.req.uid] = np.concatenate(
                [st.req.prompt, np.asarray(st.tokens, np.int32)])

        while sched.has_work:
            adm = sched.try_admit()
            while adm is not None:
                slot, req, n_pages = adm
                self.cache, tok0 = self._admit(
                    self.params, self.cache,
                    jnp.asarray(req.prompt, jnp.int32)[None], jnp.int32(slot),
                    jnp.int32(req.uid), n_pages, backend, attn_impl,
                    self.datapath_fingerprint)
                tok0 = int(jax.device_get(tok0))
                sched.record(slot, [tok0])
                if sched.remaining(slot) == 0 or tok0 == eos:
                    finish(slot)
                adm = sched.try_admit()
            if not sched.active:
                if sched.queue:  # cannot happen: submit() validates fit
                    raise RuntimeError("queued requests can never be admitted")
                continue
            k = min(self.paged.chunk_max, sched.min_remaining())
            self.cache, buf = self._chunk(
                self.params, self.cache, jnp.int32(k), backend, attn_impl,
                self.datapath_fingerprint, self.attn_spec)
            buf = np.asarray(jax.device_get(buf))
            for slot in list(sched.active):
                toks = buf[slot, :k].tolist()[: sched.remaining(slot)]
                if eos is not None and eos in toks:
                    toks = toks[: toks.index(eos) + 1]
                sched.record(slot, toks)
                if sched.remaining(slot) == 0 or (
                        eos is not None and toks and toks[-1] == eos):
                    finish(slot)
        return results

    def generate(self, prompts: np.ndarray, max_new_tokens: int) -> np.ndarray:
        """Fixed-slot-compatible entry: prompts (B, S0) -> (B, S0 + max_new).

        Post-EOS positions are EOS-padded, matching
        :meth:`GenerationEngine.generate` exactly (greedy decode of an
        equal-length batch is bit-identical — golden-pinned)."""
        prompts = np.asarray(prompts, np.int32)
        reqs = []
        for row in prompts:
            reqs.append(Request(uid=self._uid_gen, prompt=row,
                                max_new=max_new_tokens))
            self._uid_gen += 1
        results = self.serve(reqs)
        eos = self.sampler.eos_id
        s_out = prompts.shape[1] + max_new_tokens
        out = np.full((len(reqs), s_out), 0 if eos is None else eos, np.int32)
        for i, r in enumerate(reqs):
            seq = results[r.uid]
            out[i, :seq.size] = seq
        return out
