"""Continuous-batching generation engine over a paged KV cache.

Where :class:`~repro.serving.engine.GenerationEngine` is fixed-slot (all
sequences enter and leave together, against a dense ``(B, S_max, ...)``
cache slab), this engine runs an open system: requests queue in a
host-side :class:`~repro.serving.scheduler.Scheduler`, are admitted into
whichever engine slot is free, decode together regardless of phase, and
free their KV pages the moment they finish — so a skewed-length trace
keeps every slot busy instead of idling behind the batch's longest
member, and HBM holds ``num_blocks`` pages instead of
``max_concurrency * S_max`` dense rows.

Three jitted device programs, all operating on one cache pytree
(:func:`repro.models.transformer.init_paged_cache`):

* **admit** — pop ``n_pages`` from the device free-list stack, prefill
  the prompt (B=1), scatter its KV into the popped pages, splice
  recurrent-mixer state into the slot via ``dynamic_update_slice``, and
  sample the first token. One trace per (prompt_len, n_pages) bucket.
* **decode chunk** — up to ``chunk_max`` fused decode steps
  (``lax.while_loop`` with a *dynamic* trip count ``k``, so one trace
  serves every chunk length); every live slot advances at its own
  length. The host syncs once per chunk, not once per token.
* **release** — drop one reader from each of the slot's pages
  (``page_refcounts`` leaf) and push the ones that hit zero back onto the
  free-list stack (dynamic count — one trace for every page mix), then
  clear the active bit.

With ``PagedConfig.prefix_cache=True`` two more admit variants join:
**suffix admit** (block table points at cached prefix pages, prefill runs
only the uncached tail against the gathered prefix KV) and **cached
admit** (fully cached prompt: no prefill forward pass at all — the
program takes no params and is structurally FLOP-free; the first token
defers to the next decode chunk with an unchanged sampling stream). See
``repro.serving.prefix_cache`` and docs/serving_scheduler.md.

Sampling is per-request deterministic: slot ``b``'s step ``t`` key is
``fold_in(fold_in(key(seed), uid_b), t)``, so a request's sampled tokens
do not depend on what else happens to be in flight. Greedy decode is
bit-identical to the fixed-slot engine (golden-pinned in
``tests/test_paged_engine.py``).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import (
    packed_backend,
    resolve_paged_attn_impl,
    use_packed_backend,
)
from repro.models.transformer import (
    decode_step_paged,
    init_paged_cache,
    prefill,
)
from repro.quant.serve_packed import upgrade_packed_params
from repro.quant.spec import (
    AttnDatapathSpec,
    tree_datapath_fingerprint,
    validate_attn_datapath,
    validate_datapath,
)
from repro.serving.engine import SamplerConfig, _sample
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import PoolState, Request, Scheduler


@dataclass(frozen=True)
class PagedConfig:
    """Paged-cache + continuous-batching knobs.

    ``num_blocks`` sizes the shared KV pool (HBM bytes scale with it —
    see docs/serving_scheduler.md for the accounting); ``block_size`` is
    the page granularity; ``max_pages_per_seq`` caps one sequence's block
    table row (defaults to ``ceil(max_seq_len / block_size)``);
    ``chunk_max`` bounds how many decode steps run per host sync.
    ``kv_dtype="int8"`` stores *quantized* KV pages (int8 codes +
    per-(page, kv-head) scale leaves — pool HBM halves, so an HBM budget
    admits ~2x the sequences; see ``scheduler.blocks_for_budget``) and
    attention runs the AttnDatapathSpec-certified integer datapath;
    ``"act"`` keeps ``cfg.act_dtype`` float pages.
    """

    block_size: int = 64
    num_blocks: int = 256
    max_concurrency: int = 8
    max_pages_per_seq: int | None = None
    chunk_max: int = 32
    attn_impl: str = "auto"  # auto | ref | kernel | interpret
    kv_dtype: str = "act"  # act (= cfg.act_dtype) | int8 (quantized pages)
    #: share full, immutable prompt blocks across requests through a
    #: host-side radix map over block digests (repro.serving.prefix_cache)
    #: plus per-page refcounts; repeated prefixes prefill only their
    #: uncached suffix (a fully cached prompt runs NO prefill forward
    #: pass). Requires an attention-only pattern: recurrent mixers keep
    #: dense per-slot state that is not paged and cannot be shared.
    prefix_cache: bool = False


def _fold_keys(seed: int, uids, steps):
    base = jax.random.key(seed)
    return jax.vmap(
        lambda u, t: jax.random.fold_in(jax.random.fold_in(base, u), t)
    )(uids, steps)


def _sample_rows(logits, temperature: float, keys):
    """Per-row sampling with per-slot keys (request-deterministic) —
    vmaps the fixed-slot engine's ``_sample`` so both engines share one
    sampler (the greedy bit-identity guarantee rests on this)."""
    if temperature <= 0.0:
        return _sample(logits, temperature, None)
    return jax.vmap(lambda k, row: _sample(row, temperature, k))(keys, logits)


class PagedEngine:
    def __init__(self, params, cfg: ModelConfig, paged: PagedConfig = PagedConfig(),
                 sampler: SamplerConfig = SamplerConfig(), datapath=None,
                 attn_datapath=None):
        self.params = upgrade_packed_params(params)
        if datapath is not None:
            validate_datapath(self.params, datapath)
        self.datapath_fingerprint = tree_datapath_fingerprint(self.params)
        self.cfg = cfg
        self.sampler = sampler
        if paged.kv_dtype not in ("act", "int8"):
            raise ValueError(f"kv_dtype {paged.kv_dtype!r} not in ('act', 'int8')")
        max_pages = paged.max_pages_per_seq or -(-cfg.max_seq_len // paged.block_size)
        self.paged = paged = PagedConfig(
            block_size=paged.block_size, num_blocks=paged.num_blocks,
            max_concurrency=paged.max_concurrency, max_pages_per_seq=max_pages,
            chunk_max=paged.chunk_max, attn_impl=paged.attn_impl,
            kv_dtype=paged.kv_dtype, prefix_cache=paged.prefix_cache,
        )
        if paged.prefix_cache:
            recurrent = sorted({s.mixer for s in cfg.pattern
                                if s.mixer not in ("attn", "none")})
            if recurrent:
                raise ValueError(
                    f"prefix_cache=True needs an attention-only pattern: "
                    f"{recurrent} mixers keep dense per-slot state that is "
                    f"not paged and cannot be shared across requests"
                )
        self.prefix_cache = (
            PrefixCache(paged.num_blocks, paged.block_size)
            if paged.prefix_cache else None
        )
        #: host mirror of the device page allocator + refcounts — persists
        #: across serve() calls (cached pages stay out of the free stack)
        self.pool_state = PoolState.fresh(paged.num_blocks)
        #: the attention accumulator record the quantized kernel serves
        #: (None for float KV) — the attention analogue of the per-site
        #: DatapathSpec; ``attn_datapath`` is a *request* validated
        #: against it exactly like ``datapath`` against the packed leaves
        self.attn_spec = (
            AttnDatapathSpec.for_cache(cfg.head_dim, paged.block_size)
            if paged.kv_dtype == "int8" else None
        )
        if attn_datapath is not None:
            validate_attn_datapath(self.attn_spec, attn_datapath)
        self.cache = init_paged_cache(
            cfg, paged.max_concurrency, paged.num_blocks, paged.block_size,
            max_pages,
            kv_dtype="int8" if paged.kv_dtype == "int8" else None,
        )
        #: trace counters (python side effects — bump at trace time only)
        self.admit_traces = 0
        self.suffix_traces = 0
        self.cached_traces = 0
        self.chunk_traces = 0
        self.release_traces = 0
        self._uid_gen = 0

        # the cache pytree is DONATED to every program: it crosses the jit
        # boundary once per chunk/admit (unlike the dense engine, whose
        # cache lives inside one fused generate call), and without
        # donation each call would materialize a second full copy of the
        # KV page pools — 2x the HBM the pool was sized for
        @partial(jax.jit, static_argnames=("n_pages", "backend", "attn_impl",
                                           "datapath"),
                 donate_argnames=("cache",))
        def _admit(params, cache, prompt, slot, uid, incs, n_pages, backend,
                   attn_impl, datapath):
            with use_packed_backend(backend):
                return self._admit_impl(params, cache, prompt, slot, uid,
                                        incs, n_pages)

        @partial(jax.jit, static_argnames=("n_pages", "n_shared", "backend",
                                           "attn_impl", "datapath"),
                 donate_argnames=("cache",))
        def _admit_suffix(params, cache, suffix, shared_pages, slot, uid,
                          incs, n_pages, n_shared, backend, attn_impl,
                          datapath):
            with use_packed_backend(backend):
                return self._admit_suffix_impl(params, cache, suffix,
                                               shared_pages, slot, uid, incs,
                                               n_pages, n_shared)

        @partial(jax.jit, static_argnames=("n_pages", "n_shared"),
                 donate_argnames=("cache",))
        def _admit_cached(cache, shared_pages, cow_src, slot, uid, s0,
                          last_tok, incs, n_pages, n_shared):
            return self._admit_cached_impl(cache, shared_pages, cow_src,
                                           slot, uid, s0, last_tok, incs,
                                           n_pages, n_shared)

        @partial(jax.jit, static_argnames=("backend", "attn_impl", "datapath",
                                           "attn_spec"),
                 donate_argnames=("cache",))
        def _chunk(params, cache, k, backend, attn_impl, datapath, attn_spec):
            with use_packed_backend(backend):
                return self._chunk_impl(params, cache, k, attn_impl, attn_spec)

        @partial(jax.jit, donate_argnames=("cache",))
        def _release(cache, slot, pages, n):
            return self._release_impl(cache, slot, pages, n)

        self._admit = _admit
        self._admit_suffix = _admit_suffix
        self._admit_cached = _admit_cached
        self._chunk = _chunk
        self._release = _release

    # ------------------------------------------------------------------
    # Device programs (traced bodies)
    # ------------------------------------------------------------------
    def _admit_impl(self, params, cache, prompt, slot, uid, incs,
                    n_pages: int):
        """Admit one request into ``slot``: allocate pages, prefill, splice
        state, sample the generation's first token. ``incs`` is the host's
        per-row-position refcount increment vector (1 per entry, +1 extra
        for fresh blocks the prefix cache registers)."""
        self.admit_traces += 1
        cfg, paged = self.cfg, self.paged
        bs = paged.block_size
        _, s0 = prompt.shape  # (1, S0)
        n_prompt_pages = -(-s0 // bs)
        prefill_len = n_prompt_pages * bs

        # pop n_pages off the free-list stack (host guarantees capacity)
        top = cache["free_top"]
        pages = jax.lax.dynamic_slice(cache["free_list"], (top,), (n_pages,))
        row = jnp.full((paged.max_pages_per_seq,), paged.num_blocks, jnp.int32)
        row = row.at[:n_pages].set(pages)
        table = jax.lax.dynamic_update_slice(
            cache["block_table"], row[None], (slot, jnp.int32(0))
        )

        logits, dense = prefill(params, {"tokens": prompt}, cfg, prefill_len)
        prompt_pages = pages[:n_prompt_pages]
        pools = []
        for i, spec in enumerate(cfg.pattern):
            c = cache["pools"][i]
            d = dense[i]
            if spec.mixer == "attn":
                # (R, 1, prefill_len, nkv, hd) -> per-page scatter into pool
                def to_pages(a):
                    r, _, _, nkv, hd = a.shape
                    return a.reshape(r, n_prompt_pages, bs, nkv, hd)

                if "k_scales" in c:
                    # quantize-on-scatter: codes + per-(page, head) scales
                    # stamped together (padded tail positions are zeros and
                    # cannot raise a page's max)
                    from repro.kernels.paged_attention import quantize_kv_pages

                    kc, ks = quantize_kv_pages(to_pages(d["k"]))
                    vc, vs = quantize_kv_pages(to_pages(d["v"]))
                    pools.append({
                        "k_pages": c["k_pages"].at[:, prompt_pages].set(kc),
                        "v_pages": c["v_pages"].at[:, prompt_pages].set(vc),
                        "k_scales": c["k_scales"].at[:, prompt_pages].set(ks),
                        "v_scales": c["v_scales"].at[:, prompt_pages].set(vs),
                    })
                else:
                    kp = c["k_pages"].at[:, prompt_pages].set(
                        to_pages(d["k"]).astype(c["k_pages"].dtype))
                    vp = c["v_pages"].at[:, prompt_pages].set(
                        to_pages(d["v"]).astype(c["v_pages"].dtype))
                    pools.append({"k_pages": kp, "v_pages": vp})
            elif spec.mixer != "none":
                # recurrent state: splice the (R, 1, ...) prefill state into
                # the slot's lane of the (R, num_slots, ...) batch
                merged = {}
                for k, leaf in c.items():
                    idx = (jnp.int32(0), slot) + (jnp.int32(0),) * (leaf.ndim - 2)
                    merged[k] = jax.lax.dynamic_update_slice(
                        leaf, d[k].astype(leaf.dtype), idx)
                pools.append(merged)
            else:
                pools.append(c)

        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(self.sampler.seed), uid),
            jnp.int32(0))
        nxt = _sample(logits[:, -1], self.sampler.temperature, key)  # (1,)

        new = dict(cache)
        new["pools"] = tuple(pools)
        new["block_table"] = table
        new["free_top"] = top + n_pages
        new["page_refcounts"] = cache["page_refcounts"].at[row].add(
            incs, mode="drop")  # sentinel row entries drop
        new["seq_lens"] = cache["seq_lens"].at[slot].set(s0)
        new["active"] = cache["active"].at[slot].set(True)
        new["uids"] = cache["uids"].at[slot].set(uid)
        new["steps"] = cache["steps"].at[slot].set(1)
        new["last_tok"] = cache["last_tok"].at[slot].set(nxt[0])
        return new, nxt[0]

    def _admit_suffix_impl(self, params, cache, suffix, shared_pages, slot,
                           uid, incs, n_pages: int, n_shared: int):
        """Shared-prefix admit: the request's first ``n_shared`` logical
        blocks point at existing (refcounted, immutable) pages; only the
        uncached suffix runs a prefill forward pass, attending over the
        cached prefix KV gathered — and dequantized, for int8 pools —
        straight out of the shared pages. One trace per
        (suffix_len, n_pages, n_shared) bucket."""
        self.suffix_traces += 1
        cfg, paged = self.cfg, self.paged
        bs = paged.block_size
        _, t = suffix.shape  # (1, T) — the uncached prompt tail
        prefix_len = n_shared * bs
        s0 = prefix_len + t
        n_suffix_pages = -(-t // bs)
        prefill_len = n_suffix_pages * bs
        n_pop = n_pages - n_shared

        top = cache["free_top"]
        popped = jax.lax.dynamic_slice(cache["free_list"], (top,), (n_pop,))
        row = jnp.full((paged.max_pages_per_seq,), paged.num_blocks, jnp.int32)
        row = row.at[:n_shared].set(shared_pages)
        row = row.at[n_shared:n_shared + n_pop].set(popped)
        table = jax.lax.dynamic_update_slice(
            cache["block_table"], row[None], (slot, jnp.int32(0)))

        def gather_prefix(pages, scales=None):
            g = pages[:, shared_pages]  # (R, n_shared, bs, nkv, hd)
            if scales is not None:  # int8 codes -> float (page-exact)
                g = g.astype(jnp.float32) * (
                    scales[:, shared_pages][..., None, :, None])
            r, _, _, nkv, hd = g.shape
            return g.reshape(r, 1, prefix_len, nkv, hd)

        prefix_kv = []
        for i, spec in enumerate(cfg.pattern):
            if spec.mixer != "attn":
                prefix_kv.append({})
                continue
            c = cache["pools"][i]
            if "k_scales" in c:
                prefix_kv.append(
                    {"k": gather_prefix(c["k_pages"], c["k_scales"]),
                     "v": gather_prefix(c["v_pages"], c["v_scales"])})
            else:
                prefix_kv.append({"k": gather_prefix(c["k_pages"]),
                                  "v": gather_prefix(c["v_pages"])})

        logits, dense = prefill(params, {"tokens": suffix}, cfg, prefill_len,
                                prefix_kv=tuple(prefix_kv),
                                pos_offset=prefix_len)
        suffix_pages = popped[:n_suffix_pages]
        pools = []
        for i, spec in enumerate(cfg.pattern):
            c = cache["pools"][i]
            d = dense[i]
            if spec.mixer == "attn":
                def to_pages(a):
                    r, _, _, nkv, hd = a.shape
                    return a.reshape(r, n_suffix_pages, bs, nkv, hd)

                if "k_scales" in c:
                    from repro.kernels.paged_attention import quantize_kv_pages

                    kc, ks = quantize_kv_pages(to_pages(d["k"]))
                    vc, vs = quantize_kv_pages(to_pages(d["v"]))
                    pools.append({
                        "k_pages": c["k_pages"].at[:, suffix_pages].set(kc),
                        "v_pages": c["v_pages"].at[:, suffix_pages].set(vc),
                        "k_scales": c["k_scales"].at[:, suffix_pages].set(ks),
                        "v_scales": c["v_scales"].at[:, suffix_pages].set(vs),
                    })
                else:
                    kp = c["k_pages"].at[:, suffix_pages].set(
                        to_pages(d["k"]).astype(c["k_pages"].dtype))
                    vp = c["v_pages"].at[:, suffix_pages].set(
                        to_pages(d["v"]).astype(c["v_pages"].dtype))
                    pools.append({"k_pages": kp, "v_pages": vp})
            else:  # "none" mixers only — engine gates recurrent patterns
                pools.append(c)

        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(self.sampler.seed), uid),
            jnp.int32(0))
        nxt = _sample(logits[:, -1], self.sampler.temperature, key)  # (1,)

        new = dict(cache)
        new["pools"] = tuple(pools)
        new["block_table"] = table
        new["free_top"] = top + n_pop
        new["page_refcounts"] = cache["page_refcounts"].at[row].add(
            incs, mode="drop")
        new["seq_lens"] = cache["seq_lens"].at[slot].set(s0)
        new["active"] = cache["active"].at[slot].set(True)
        new["uids"] = cache["uids"].at[slot].set(uid)
        new["steps"] = cache["steps"].at[slot].set(1)
        new["last_tok"] = cache["last_tok"].at[slot].set(nxt[0])
        return new, nxt[0]

    def _admit_cached_impl(self, cache, shared_pages, cow_src, slot, uid, s0,
                           last_tok, incs, n_pages: int, n_shared: int):
        """Fully-cached admit: NO prefill forward pass (takes no params at
        all — structurally FLOP-free, see :meth:`cached_admit_primitives`).
        The prompt's blocks are all cached; the last one is copied into a
        freshly popped private page (copy-on-write: decode appends rewrite
        position ``s0 - 1`` and grow page scales, which must never touch a
        shared page). The first token is *deferred*: ``seq_lens = s0 - 1``,
        ``steps = 0`` and ``last_tok = prompt[-1]`` hand the last prompt
        token to the next decode chunk, whose first step computes exactly
        the cold prefill's final-position logits and samples with the same
        ``fold_in(uid, 0)`` key — the sampling stream is unchanged."""
        self.cached_traces += 1
        paged = self.paged
        n_pop = n_pages - n_shared
        top = cache["free_top"]
        popped = jax.lax.dynamic_slice(cache["free_list"], (top,), (n_pop,))
        dest = popped[0]
        row = jnp.full((paged.max_pages_per_seq,), paged.num_blocks, jnp.int32)
        row = row.at[:n_shared].set(shared_pages)
        row = row.at[n_shared:n_shared + n_pop].set(popped)
        table = jax.lax.dynamic_update_slice(
            cache["block_table"], row[None], (slot, jnp.int32(0)))

        pools = []
        for i, spec in enumerate(self.cfg.pattern):
            c = cache["pools"][i]
            if spec.mixer == "attn":
                # CoW: copy codes AND scales — the private copy must
                # dequantize identically until the first append
                pools.append({k: leaf.at[:, dest].set(leaf[:, cow_src])
                              for k, leaf in c.items()})
            else:
                pools.append(c)

        new = dict(cache)
        new["pools"] = tuple(pools)
        new["block_table"] = table
        new["free_top"] = top + n_pop
        new["page_refcounts"] = cache["page_refcounts"].at[row].add(
            incs, mode="drop")
        new["seq_lens"] = cache["seq_lens"].at[slot].set(s0 - 1)
        new["active"] = cache["active"].at[slot].set(True)
        new["uids"] = cache["uids"].at[slot].set(uid)
        new["steps"] = cache["steps"].at[slot].set(0)
        new["last_tok"] = cache["last_tok"].at[slot].set(last_tok)
        return new

    def _chunk_impl(self, params, cache, k, attn_impl: str, attn_spec):
        """Up to ``chunk_max`` decode steps; ``k`` is a *dynamic* trip
        count so every chunk length reuses one trace."""
        self.chunk_traces += 1
        cfg, samp = self.cfg, self.sampler
        n_slots, chunk_max = self.paged.max_concurrency, self.paged.chunk_max
        buf = jnp.zeros((n_slots, chunk_max), jnp.int32)

        def cond(st):
            t, _, _ = st
            return t < k

        def body(st):
            t, cache, buf = st
            logits, cache = decode_step_paged(
                params, cache["last_tok"][:, None], cache, cfg,
                attn_impl=attn_impl, attn_spec=attn_spec)
            keys = _fold_keys(samp.seed, cache["uids"], cache["steps"])
            nxt = _sample_rows(logits[:, -1], samp.temperature, keys)
            active = cache["active"]
            cache = dict(cache)
            cache["last_tok"] = jnp.where(active, nxt, cache["last_tok"])
            cache["steps"] = cache["steps"] + active.astype(jnp.int32)
            buf = jax.lax.dynamic_update_slice(buf, nxt[:, None], (0, t))
            return t + 1, cache, buf

        _, cache, buf = jax.lax.while_loop(cond, body, (jnp.int32(0), cache, buf))
        return cache, buf

    def _release_impl(self, cache, slot, pages, n):
        """Refcount-aware subset-push release: drop one reader from the
        first ``n`` of ``pages`` (a sentinel-padded ``max_pages_per_seq``-
        wide list) and push only the pages whose count hits zero — shared
        prefix pages stay resident for their other readers (or for the
        cache itself). ``n`` is *dynamic*: one trace serves every page
        count (and, with ``slot = max_concurrency``, the prefix cache's
        own evictions — the slot scatter drops)."""
        self.release_traces += 1
        W = self.paged.max_pages_per_seq
        nb = self.paged.num_blocks
        valid = jnp.arange(W) < n
        idx = jnp.where(valid, pages, nb)  # sentinel -> dropped scatters
        rc = cache["page_refcounts"].at[idx].add(
            -valid.astype(jnp.int32), mode="drop")
        freed = valid & (rc[jnp.minimum(idx, nb - 1)] == 0)
        count = jnp.sum(freed.astype(jnp.int32))
        # compact freed pages to the front in row order (stable sort on
        # the not-freed flag) and push them at [top - count, top)
        order = jnp.argsort(~freed, stable=True)
        push = idx[order]
        top = cache["free_top"] - count
        dest = jnp.where(jnp.arange(W) < count, top + jnp.arange(W), nb)
        new = dict(cache)
        new["free_list"] = cache["free_list"].at[dest].set(push, mode="drop")
        new["free_top"] = top
        new["page_refcounts"] = rc
        new["active"] = cache["active"].at[slot].set(False, mode="drop")
        return new

    # ------------------------------------------------------------------
    # Host loop
    # ------------------------------------------------------------------
    def submit_all(self, requests) -> Scheduler:
        paged = self.paged
        sched = Scheduler(paged.max_concurrency, paged.num_blocks,
                          paged.block_size, paged.max_pages_per_seq,
                          prefix_cache=self.prefix_cache,
                          pool_state=self.pool_state)
        for r in requests:
            sched.submit(r)
        return sched

    def _pad_row(self, pages) -> jnp.ndarray:
        """Sentinel-pad a physical page list to the block-table width (the
        release/evict programs take one fixed-width dynamic-count list)."""
        out = np.full(self.paged.max_pages_per_seq, self.paged.num_blocks,
                      np.int32)
        out[:len(pages)] = pages
        return jnp.asarray(out)

    def _do_admit(self, adm, backend, attn_impl):
        """Run one admission's device programs (evict, then the admit
        variant the scheduler picked). Returns the request's first sampled
        token, or None for a fully cached prompt — its first sample is
        deferred to the next decode chunk."""
        if adm.evict_pages is not None and adm.evict_pages.size:
            self.cache = self._release(
                self.cache, jnp.int32(self.paged.max_concurrency),
                self._pad_row(adm.evict_pages),
                jnp.int32(adm.evict_pages.size))
        req = adm.req
        incs = jnp.asarray(adm.incs)
        shared = jnp.asarray(np.asarray(adm.shared_pages, np.int32))
        if adm.cow_src is not None:
            self.cache = self._admit_cached(
                self.cache, shared, jnp.int32(adm.cow_src),
                jnp.int32(adm.slot), jnp.int32(req.uid),
                jnp.int32(req.prompt.size), jnp.int32(req.prompt[-1]),
                incs, adm.n_pages, adm.n_shared)
            return None
        if adm.n_shared:
            suffix = req.prompt[adm.n_shared * self.paged.block_size:]
            self.cache, tok0 = self._admit_suffix(
                self.params, self.cache, jnp.asarray(suffix, jnp.int32)[None],
                shared, jnp.int32(adm.slot), jnp.int32(req.uid), incs,
                adm.n_pages, adm.n_shared, backend, attn_impl,
                self.datapath_fingerprint)
        else:
            self.cache, tok0 = self._admit(
                self.params, self.cache,
                jnp.asarray(req.prompt, jnp.int32)[None], jnp.int32(adm.slot),
                jnp.int32(req.uid), incs, adm.n_pages, backend, attn_impl,
                self.datapath_fingerprint)
        return int(jax.device_get(tok0))

    def serve(self, requests, *, _probe=None, _late=None) -> dict[int, np.ndarray]:
        """Run a request list to completion under continuous batching.

        Returns {uid: (S0_uid + n_generated,) int32} — generation is
        trimmed at the first EOS (when the sampler sets one), matching the
        fixed-slot engine's post-EOS padding semantics after re-padding.

        ``_probe(engine, sched)`` (tests) runs after every admit/chunk/
        release transition; ``_late(sched, pass_idx)`` runs once per
        scheduler pass (after the decode chunk, when one ran) and may
        submit mid-flight arrivals — even when the pass drained every
        active request at admission, so injected work is never stranded.
        """
        sched = self.submit_all(requests)
        backend = packed_backend()
        attn_impl = resolve_paged_attn_impl(self.paged.attn_impl)
        eos = self.sampler.eos_id
        results: dict[int, np.ndarray] = {}
        chunk_idx = 0

        def finish(slot):
            st = sched.finish(slot)
            self.cache = self._release(self.cache, jnp.int32(slot),
                                       self._pad_row(st.row),
                                       jnp.int32(st.n_pages))
            results[st.req.uid] = np.concatenate(
                [st.req.prompt, np.asarray(st.tokens, np.int32)])
            if _probe is not None:
                _probe(self, sched)

        while sched.has_work:
            adm = sched.try_admit()
            while adm is not None:
                tok0 = self._do_admit(adm, backend, attn_impl)
                if tok0 is not None:
                    sched.record(adm.slot, [tok0])
                if _probe is not None:
                    _probe(self, sched)
                if tok0 is not None and (
                        sched.remaining(adm.slot) == 0 or tok0 == eos):
                    finish(adm.slot)
                adm = sched.try_admit()
            if sched.active:
                k = min(self.paged.chunk_max, sched.min_remaining())
                self.cache, buf = self._chunk(
                    self.params, self.cache, jnp.int32(k), backend, attn_impl,
                    self.datapath_fingerprint, self.attn_spec)
                buf = np.asarray(jax.device_get(buf))
                if _probe is not None:
                    _probe(self, sched)
                for slot in list(sched.active):
                    toks = buf[slot, :k].tolist()[: sched.remaining(slot)]
                    if eos is not None and eos in toks:
                        toks = toks[: toks.index(eos) + 1]
                    sched.record(slot, toks)
                    if sched.remaining(slot) == 0 or (
                            eos is not None and toks and toks[-1] == eos):
                        finish(slot)
            elif sched.queue:  # cannot happen: submit() validates fit
                raise RuntimeError("queued requests can never be admitted")
            if _late is not None:
                _late(sched, chunk_idx)
            chunk_idx += 1
        return results

    # ------------------------------------------------------------------
    # Structural zero-FLOP certificate for the fully-cached admit
    # ------------------------------------------------------------------
    _FLOP_PRIMITIVES = frozenset({"dot_general", "conv_general_dilated"})

    def cached_admit_primitives(self, n_pages: int = 2,
                                n_shared: int = 1) -> set[str]:
        """All primitives (recursively) in the fully-cached admit jaxpr.
        The program takes no model params, so a single matmul appearing
        here would be a bug — :meth:`assert_cached_admit_flop_free` gates
        on the intersection with ``_FLOP_PRIMITIVES``."""
        W = self.paged.max_pages_per_seq
        i32 = jnp.int32
        traces = self.cached_traces  # make_jaxpr retraces; don't count it
        closed = jax.make_jaxpr(
            partial(self._admit_cached_impl, n_pages=n_pages,
                    n_shared=n_shared)
        )(self.cache, jnp.zeros((n_shared,), i32), i32(0), i32(0), i32(0),
          i32(1), i32(0), jnp.zeros((W,), i32))
        self.cached_traces = traces
        prims: set[str] = set()

        def walk(jaxpr):
            for eqn in jaxpr.eqns:
                prims.add(eqn.primitive.name)
                for v in eqn.params.values():
                    for sub in jax.tree.leaves(
                            v, is_leaf=lambda x: isinstance(
                                x, (jax.core.Jaxpr, jax.core.ClosedJaxpr))):
                        if isinstance(sub, jax.core.ClosedJaxpr):
                            walk(sub.jaxpr)
                        elif isinstance(sub, jax.core.Jaxpr):
                            walk(sub)

        walk(closed.jaxpr)
        return prims

    def assert_cached_admit_flop_free(self) -> None:
        """Admitting a fully cached prompt must run zero prefill FLOPs:
        its program is gathers/scatters only (no dot_general, no conv)."""
        hot = self.cached_admit_primitives() & self._FLOP_PRIMITIVES
        if hot:
            raise AssertionError(
                f"fully-cached admit contains FLOP primitives {sorted(hot)}")

    def generate(self, prompts: np.ndarray, max_new_tokens: int) -> np.ndarray:
        """Fixed-slot-compatible entry: prompts (B, S0) -> (B, S0 + max_new).

        Post-EOS positions are EOS-padded, matching
        :meth:`GenerationEngine.generate` exactly (greedy decode of an
        equal-length batch is bit-identical — golden-pinned)."""
        prompts = np.asarray(prompts, np.int32)
        reqs = []
        for row in prompts:
            reqs.append(Request(uid=self._uid_gen, prompt=row,
                                max_new=max_new_tokens))
            self._uid_gen += 1
        results = self.serve(reqs)
        eos = self.sampler.eos_id
        s_out = prompts.shape[1] + max_new_tokens
        out = np.full((len(reqs), s_out), 0 if eos is None else eos, np.int32)
        for i, r in enumerate(reqs):
            seq = results[r.uid]
            out[i, :seq.size] = seq
        return out
