"""Continuous-batching generation engine over a paged KV cache.

Where :class:`~repro.serving.engine.GenerationEngine` is fixed-slot (all
sequences enter and leave together, against a dense ``(B, S_max, ...)``
cache slab), this engine runs an open system: requests queue in a
host-side :class:`~repro.serving.scheduler.Scheduler`, are admitted into
whichever engine slot is free, decode together regardless of phase, and
free their KV pages the moment they finish — so a skewed-length trace
keeps every slot busy instead of idling behind the batch's longest
member, and HBM holds ``num_blocks`` pages instead of
``max_concurrency * S_max`` dense rows.

Three jitted device programs, all operating on one cache pytree
(:func:`repro.models.transformer.init_paged_cache`):

* **admit** — pop ``n_pages`` from the device free-list stack, prefill
  the prompt (B=1), scatter its KV into the popped pages, splice
  recurrent-mixer state into the slot via ``dynamic_update_slice``, and
  sample the first token. One trace per (prompt_len, n_pages) bucket.
* **decode chunk** — up to ``chunk_max`` fused decode steps
  (``lax.while_loop`` with a *dynamic* trip count ``k``, so one trace
  serves every chunk length); every live slot advances at its own
  length. The host syncs once per chunk, not once per token.
* **release** — drop one reader from each of the slot's pages
  (``page_refcounts`` leaf) and push the ones that hit zero back onto the
  free-list stack (dynamic count — one trace for every page mix), then
  clear the active bit.

With ``PagedConfig.prefix_cache=True`` two more admit variants join:
**suffix admit** (block table points at cached prefix pages, prefill runs
only the uncached tail against the gathered prefix KV) and **cached
admit** (fully cached prompt: no prefill forward pass at all — the
program takes no params and is structurally FLOP-free; the first token
defers to the next decode chunk with an unchanged sampling stream). See
``repro.serving.prefix_cache`` and docs/serving_scheduler.md.

Sampling is per-request deterministic: slot ``b``'s step ``t`` key is
``fold_in(fold_in(key(seed), uid_b), t)``, so a request's sampled tokens
do not depend on what else happens to be in flight. Greedy decode is
bit-identical to the fixed-slot engine (golden-pinned in
``tests/test_paged_engine.py``).
"""

from __future__ import annotations

import time
from collections import deque
from contextlib import nullcontext
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import (
    attach_observer,
    packed_backend,
    resolve_paged_attn_impl,
    use_packed_backend,
)
from repro.quant.observe import SaturationCounters, plan_kv_scales
from repro.models.transformer import (
    decode_step_paged,
    init_paged_cache,
    prefill,
)
from repro.quant.serve_packed import upgrade_packed_params
from repro.runtime import sharding as shardlib
from repro.quant.spec import (
    AttnDatapathSpec,
    tree_datapath_fingerprint,
    validate_attn_datapath,
    validate_datapath,
)
from repro.serving.engine import SamplerConfig, _sample
from repro.serving.metrics import ServeMetrics
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import (
    PoolState,
    Request,
    Scheduler,
    SchedulerPolicy,
)


@dataclass(frozen=True)
class PagedConfig:
    """Paged-cache + continuous-batching knobs.

    ``num_blocks`` sizes the shared KV pool (HBM bytes scale with it —
    see docs/serving_scheduler.md for the accounting); ``block_size`` is
    the page granularity; ``max_pages_per_seq`` caps one sequence's block
    table row (defaults to ``ceil(max_seq_len / block_size)``);
    ``chunk_max`` bounds how many decode steps run per host sync.
    ``kv_dtype="int8"`` stores *quantized* KV pages (int8 codes +
    per-(page, kv-head) scale leaves — pool HBM halves, so an HBM budget
    admits ~2x the sequences; see ``scheduler.blocks_for_budget``) and
    attention runs the AttnDatapathSpec-certified integer datapath;
    ``"act"`` keeps ``cfg.act_dtype`` float pages.
    """

    block_size: int = 64
    num_blocks: int = 256
    max_concurrency: int = 8
    max_pages_per_seq: int | None = None
    chunk_max: int = 32
    attn_impl: str = "auto"  # auto | ref | kernel | interpret
    kv_dtype: str = "act"  # act (= cfg.act_dtype) | int8 (quantized pages)
    #: share full, immutable prompt blocks across requests through a
    #: host-side radix map over block digests (repro.serving.prefix_cache)
    #: plus per-page refcounts; repeated prefixes prefill only their
    #: uncached suffix (a fully cached prompt runs NO prefill forward
    #: pass). Requires an attention-only pattern: recurrent mixers keep
    #: dense per-slot state that is not paged and cannot be shared.
    prefix_cache: bool = False
    #: admission/decode policy (repro.serving.scheduler.SchedulerPolicy).
    #: The default is legacy FIFO — bit-compatible with prior releases
    #: and the baseline the latency bench compares against. Any
    #: non-default field (windowed/batched admission, chunked prefill,
    #: watermark + preemption) switches serve() to the throughput loop.
    sched: SchedulerPolicy = SchedulerPolicy()


def _fold_keys(seed: int, uids, steps):
    base = jax.random.key(seed)
    return jax.vmap(
        lambda u, t: jax.random.fold_in(jax.random.fold_in(base, u), t)
    )(uids, steps)


def _sample_rows(logits, temperature: float, keys):
    """Per-row sampling with per-slot keys (request-deterministic) —
    vmaps the fixed-slot engine's ``_sample`` so both engines share one
    sampler (the greedy bit-identity guarantee rests on this)."""
    if temperature <= 0.0:
        return _sample(logits, temperature, None)
    return jax.vmap(lambda k, row: _sample(row, temperature, k))(keys, logits)


class PagedEngine:
    def __init__(self, params, cfg: ModelConfig, paged: PagedConfig = PagedConfig(),
                 sampler: SamplerConfig = SamplerConfig(), datapath=None,
                 attn_datapath=None, observe: bool = False, kv_scales=None,
                 mesh=None, shard_rules=None):
        self.params = upgrade_packed_params(params)
        if datapath is not None:
            validate_datapath(self.params, datapath)
        self.datapath_fingerprint = tree_datapath_fingerprint(self.params)
        self.cfg = cfg
        self.sampler = sampler
        if paged.kv_dtype not in ("act", "int8"):
            raise ValueError(f"kv_dtype {paged.kv_dtype!r} not in ('act', 'int8')")
        max_pages = paged.max_pages_per_seq or -(-cfg.max_seq_len // paged.block_size)
        self.paged = paged = PagedConfig(
            block_size=paged.block_size, num_blocks=paged.num_blocks,
            max_concurrency=paged.max_concurrency, max_pages_per_seq=max_pages,
            chunk_max=paged.chunk_max, attn_impl=paged.attn_impl,
            kv_dtype=paged.kv_dtype, prefix_cache=paged.prefix_cache,
            sched=paged.sched,
        )
        recurrent = sorted({s.mixer for s in cfg.pattern
                            if s.mixer not in ("attn", "none")})
        if paged.prefix_cache and recurrent:
            raise ValueError(
                f"prefix_cache=True needs an attention-only pattern: "
                f"{recurrent} mixers keep dense per-slot state that is "
                f"not paged and cannot be shared across requests"
            )
        pol = paged.sched
        if pol.batch_max > 1 or pol.prefill_chunk is not None:
            # batched rows compete for MoE expert capacity (per-row
            # routing is not independent of co-batched traffic), and
            # recurrent mixers step state through pad tokens — both break
            # the per-request bit-identity guarantee, so the policy
            # refuses them rather than silently drifting
            has_moe = any(s.ffn == "moe" for s in cfg.pattern)
            if recurrent or has_moe:
                what = "batched admission" if pol.batch_max > 1 else \
                    "chunked prefill"
                raise ValueError(
                    f"{what} needs an attention-only, MoE-free pattern "
                    f"(recurrent mixers {recurrent or 'none'}, moe ffn "
                    f"{has_moe}): padded multi-row / chunked prefill would "
                    f"change routing or stepwise state and break greedy "
                    f"bit-identity with the FIFO engine"
                )
        if pol.prefill_chunk is not None and (
                pol.prefill_chunk % paged.block_size != 0):
            raise ValueError(
                f"prefill_chunk {pol.prefill_chunk} must be a multiple of "
                f"block_size {paged.block_size} (chunks scatter whole pages)")
        if pol.watermark is not None and pol.watermark[1] > paged.num_blocks:
            raise ValueError(
                f"watermark high {pol.watermark[1]} > num_blocks "
                f"{paged.num_blocks} — admission could never resume")
        self.prefix_cache = (
            PrefixCache(paged.num_blocks, paged.block_size)
            if paged.prefix_cache else None
        )
        #: host mirror of the device page allocator + refcounts — persists
        #: across serve() calls (cached pages stay out of the free stack)
        self.pool_state = PoolState.fresh(paged.num_blocks)
        #: the attention accumulator record the quantized kernel serves
        #: (None for float KV) — the attention analogue of the per-site
        #: DatapathSpec; ``attn_datapath`` is a *request* validated
        #: against it exactly like ``datapath`` against the packed leaves
        self.attn_spec = (
            AttnDatapathSpec.for_cache(cfg.head_dim, paged.block_size)
            if paged.kv_dtype == "int8" else None
        )
        if attn_datapath is not None:
            validate_attn_datapath(self.attn_spec, attn_datapath)
        #: serving-side observer (repro.quant.observe.saturation): host
        #: state fed through ``jax.debug.callback``. None (the default)
        #: keeps every serving jaxpr callback-free — structurally
        #: certified by :meth:`assert_observation_transparent`.
        self.observer = SaturationCounters() if observe else None
        #: calibrated static KV page scales from a mixed-precision plan's
        #: ``kv`` section (repro.quant.observe.kv): {slot: {"k": (R, nkv)
        #: f32, "v": ...}}. Appends and prefill scatters quantize against
        #: these constants — no per-page max reduction, no
        #: requantize-on-append on the decode hot path.
        if kv_scales is not None and "slots" in kv_scales:
            kv_scales = plan_kv_scales(kv_scales)
        if kv_scales and paged.kv_dtype != "int8":
            raise ValueError(
                "static kv_scales need kv_dtype='int8' (float pools carry "
                "no scale leaves)")
        self.kv_scales = kv_scales or None
        #: pattern-aligned tuple joined to decode_step_paged's scan xs
        #: (empty dicts contribute no scan leaves; None = fully dynamic,
        #: which leaves the decode jaxpr byte-identical to the baseline)
        self._kv_scales_seq = None
        if self.kv_scales:
            for slot in self.kv_scales:
                if not (0 <= slot < len(cfg.pattern)
                        and cfg.pattern[slot].mixer == "attn"):
                    raise ValueError(
                        f"kv_scales names slot {slot}, which is not an "
                        f"attention slot of the {len(cfg.pattern)}-slot "
                        f"pattern")
            self._kv_scales_seq = tuple(
                {"k": jnp.asarray(self.kv_scales[i]["k"], jnp.float32),
                 "v": jnp.asarray(self.kv_scales[i]["v"], jnp.float32)}
                if i in self.kv_scales else {}
                for i in range(len(cfg.pattern)))
        # observation and static KV participate in trace identity: suffix
        # the jit cache key so a plan-bearing engine never reuses a
        # dynamic-scale trace (and vice versa)
        if observe:
            self.datapath_fingerprint += "+obs"
        if self._kv_scales_seq is not None:
            self.datapath_fingerprint += "+kv-static"
        self.cache = init_paged_cache(
            cfg, paged.max_concurrency, paged.num_blocks, paged.block_size,
            max_pages,
            kv_dtype="int8" if paged.kv_dtype == "int8" else None,
        )
        #: SPMD mesh for the three program families (docs/multihost.md):
        #: pools shard kv_heads, admin leaves replicate, every host-read
        #: output is fully replicated. None = the single-controller engine.
        self.mesh = mesh
        self._out_params = self._out_cache = self._out_rep = None
        if mesh is not None:
            resolved = resolve_paged_attn_impl(paged.attn_impl)
            if resolved != "ref":
                raise ValueError(
                    f"mesh-native serving requires the partitionable 'ref' "
                    f"attention impl (resolved {resolved!r}): the Pallas "
                    f"block-table kernel is a single-device program until "
                    f"the TPU pass wraps it in shard_map (ROADMAP item 4)")
            if observe and jax.process_count() > 1:
                raise ValueError(
                    "observe=True is single-controller: the saturation "
                    "debug_callback would fire per-process on partial "
                    "shards — run observation on a one-process mesh")
            self._out_params, self._out_cache = shardlib.paged_engine_shardings(
                self.params, self.cache, cfg, mesh, shard_rules)
            self._out_rep = shardlib.replicated(mesh)
            # global placement: every process holds the identical full
            # value (seed-deterministic init), so the host copy IS the
            # global value — multihost-safe by construction
            self.params = shardlib.host_to_global(self.params,
                                                  self._out_params)
            self.cache = shardlib.host_to_global(self.cache, self._out_cache)
        #: trace counters (python side effects — bump at trace time only)
        self.admit_traces = 0
        self.suffix_traces = 0
        self.cached_traces = 0
        self.chunk_traces = 0
        self.release_traces = 0
        self.batch_traces = 0
        self.stub_traces = 0
        self.prefill_chunk_traces = 0
        self.grow_traces = 0
        #: host-observed preemption count across serve() calls
        self.preemptions = 0
        self._uid_gen = 0

        def _osh(*out):
            """Explicit out_shardings for a mesh-native program: the cache
            operand comes back under exactly its input shardings (donation
            stays alias-exact) and every token output fully replicated —
            the contract that keeps host reads local on every process.
            Empty under the single-controller engine (XLA default)."""
            if mesh is None:
                return {}
            return {"out_shardings": out[0] if len(out) == 1 else out}

        _cache_sh, _rep = self._out_cache, self._out_rep

        # the cache pytree is DONATED to every program: it crosses the jit
        # boundary once per chunk/admit (unlike the dense engine, whose
        # cache lives inside one fused generate call), and without
        # donation each call would materialize a second full copy of the
        # KV page pools — 2x the HBM the pool was sized for
        @partial(jax.jit, static_argnames=("n_pages", "backend", "attn_impl",
                                           "datapath"),
                 donate_argnames=("cache",), **_osh(_cache_sh, _rep))
        def _admit(params, cache, prompt, slot, uid, incs, n_pages, backend,
                   attn_impl, datapath):
            with use_packed_backend(backend):
                return self._admit_impl(params, cache, prompt, slot, uid,
                                        incs, n_pages)

        @partial(jax.jit, static_argnames=("n_pages", "n_shared", "backend",
                                           "attn_impl", "datapath"),
                 donate_argnames=("cache",), **_osh(_cache_sh, _rep))
        def _admit_suffix(params, cache, suffix, shared_pages, slot, uid,
                          incs, n_pages, n_shared, backend, attn_impl,
                          datapath):
            with use_packed_backend(backend):
                return self._admit_suffix_impl(params, cache, suffix,
                                               shared_pages, slot, uid, incs,
                                               n_pages, n_shared)

        @partial(jax.jit, static_argnames=("n_pages", "n_shared"),
                 donate_argnames=("cache",), **_osh(_cache_sh))
        def _admit_cached(cache, shared_pages, cow_src, slot, uid, s0,
                          last_tok, incs, n_pages, n_shared):
            return self._admit_cached_impl(cache, shared_pages, cow_src,
                                           slot, uid, s0, last_tok, incs,
                                           n_pages, n_shared)

        @partial(jax.jit, static_argnames=("backend", "attn_impl", "datapath",
                                           "attn_spec"),
                 donate_argnames=("cache",), **_osh(_cache_sh, _rep))
        def _chunk(params, cache, k, backend, attn_impl, datapath, attn_spec):
            with use_packed_backend(backend):
                return self._chunk_impl(params, cache, k, attn_impl, attn_spec)

        @partial(jax.jit, donate_argnames=("cache",), **_osh(_cache_sh))
        def _release(cache, slot, pages, n):
            return self._release_impl(cache, slot, pages, n)

        @partial(jax.jit, static_argnames=("n_rows", "n_prompt_pages",
                                           "backend", "attn_impl",
                                           "datapath"),
                 donate_argnames=("cache",), **_osh(_cache_sh, _rep))
        def _admit_batch(params, cache, tokens, s0s, slots, uids, rows,
                         scatter_idx, incs, total_pop, n_rows,
                         n_prompt_pages, backend, attn_impl, datapath):
            with use_packed_backend(backend):
                return self._admit_batch_impl(params, cache, tokens, s0s,
                                              slots, uids, rows, scatter_idx,
                                              incs, total_pop, n_prompt_pages)

        @partial(jax.jit, donate_argnames=("cache",), **_osh(_cache_sh))
        def _admit_stub(cache, row, slot, uid, incs, n_pages):
            return self._admit_stub_impl(cache, row, slot, uid, incs, n_pages)

        @partial(jax.jit, donate_argnames=("cache",), **_osh(_cache_sh))
        def _grow(cache, slot, row, add, n_new):
            return self._grow_impl(cache, slot, row, add, n_new)

        @partial(jax.jit, static_argnames=("n_prior", "n_chunk_pages",
                                           "final", "backend", "attn_impl",
                                           "datapath"),
                 donate_argnames=("cache",), **_osh(_cache_sh, _rep))
        def _prefill_chunk(params, cache, tokens, slot, uid, s0, incs,
                           n_prior, n_chunk_pages, final, backend, attn_impl,
                           datapath):
            with use_packed_backend(backend):
                return self._prefill_chunk_impl(params, cache, tokens, slot,
                                                uid, s0, incs, n_prior,
                                                n_chunk_pages, final)

        self._admit = _admit
        self._admit_suffix = _admit_suffix
        self._admit_cached = _admit_cached
        self._chunk = _chunk
        self._release = _release
        self._admit_batch = _admit_batch
        self._admit_stub = _admit_stub
        self._grow = _grow
        self._prefill_chunk = _prefill_chunk

    # ------------------------------------------------------------------
    # Device programs (traced bodies)
    # ------------------------------------------------------------------
    def _quantize_pages(self, slot: int, k_pages, v_pages):
        """Quantize dense KV pages for pool slot ``slot`` — against the
        plan's calibrated static per-kv-head scales when the engine holds
        them (constant stamp, no per-page max reduction), else the dynamic
        per-(page, head) abs-max path. Returns (kc, ks, vc, vs)."""
        from repro.kernels.paged_attention import (
            quantize_kv_pages,
            quantize_kv_pages_static,
        )

        sks = self._kv_scales_seq[slot] if self._kv_scales_seq else None
        if sks:
            kc, ks = quantize_kv_pages_static(k_pages, sks["k"][:, None, :])
            vc, vs = quantize_kv_pages_static(v_pages, sks["v"][:, None, :])
        else:
            kc, ks = quantize_kv_pages(k_pages)
            vc, vs = quantize_kv_pages(v_pages)
        return kc, ks, vc, vs

    def _admit_impl(self, params, cache, prompt, slot, uid, incs,
                    n_pages: int):
        """Admit one request into ``slot``: allocate pages, prefill, splice
        state, sample the generation's first token. ``incs`` is the host's
        per-row-position refcount increment vector (1 per entry, +1 extra
        for fresh blocks the prefix cache registers)."""
        self.admit_traces += 1
        cfg, paged = self.cfg, self.paged
        bs = paged.block_size
        _, s0 = prompt.shape  # (1, S0)
        n_prompt_pages = -(-s0 // bs)
        prefill_len = n_prompt_pages * bs

        # pop n_pages off the free-list stack (host guarantees capacity)
        top = cache["free_top"]
        pages = jax.lax.dynamic_slice(cache["free_list"], (top,), (n_pages,))
        row = jnp.full((paged.max_pages_per_seq,), paged.num_blocks, jnp.int32)
        row = row.at[:n_pages].set(pages)
        table = jax.lax.dynamic_update_slice(
            cache["block_table"], row[None], (slot, jnp.int32(0))
        )

        logits, dense = prefill(params, {"tokens": prompt}, cfg, prefill_len)
        prompt_pages = pages[:n_prompt_pages]
        pools = []
        for i, spec in enumerate(cfg.pattern):
            c = cache["pools"][i]
            d = dense[i]
            if spec.mixer == "attn":
                # (R, 1, prefill_len, nkv, hd) -> per-page scatter into pool
                def to_pages(a):
                    r, _, _, nkv, hd = a.shape
                    return a.reshape(r, n_prompt_pages, bs, nkv, hd)

                if "k_scales" in c:
                    # quantize-on-scatter: codes + per-(page, head) scales
                    # stamped together (padded tail positions are zeros and
                    # cannot raise a page's max)
                    kc, ks, vc, vs = self._quantize_pages(i, to_pages(d["k"]),
                                                          to_pages(d["v"]))
                    pools.append({
                        "k_pages": c["k_pages"].at[:, prompt_pages].set(kc),
                        "v_pages": c["v_pages"].at[:, prompt_pages].set(vc),
                        "k_scales": c["k_scales"].at[:, prompt_pages].set(ks),
                        "v_scales": c["v_scales"].at[:, prompt_pages].set(vs),
                    })
                else:
                    kp = c["k_pages"].at[:, prompt_pages].set(
                        to_pages(d["k"]).astype(c["k_pages"].dtype))
                    vp = c["v_pages"].at[:, prompt_pages].set(
                        to_pages(d["v"]).astype(c["v_pages"].dtype))
                    pools.append({"k_pages": kp, "v_pages": vp})
            elif spec.mixer != "none":
                # recurrent state: splice the (R, 1, ...) prefill state into
                # the slot's lane of the (R, num_slots, ...) batch
                merged = {}
                for k, leaf in c.items():
                    idx = (jnp.int32(0), slot) + (jnp.int32(0),) * (leaf.ndim - 2)
                    merged[k] = jax.lax.dynamic_update_slice(
                        leaf, d[k].astype(leaf.dtype), idx)
                pools.append(merged)
            else:
                pools.append(c)

        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(self.sampler.seed), uid),
            jnp.int32(0))
        nxt = _sample(logits[:, -1], self.sampler.temperature, key)  # (1,)

        new = dict(cache)
        new["pools"] = tuple(pools)
        new["block_table"] = table
        new["free_top"] = top + n_pages
        new["page_refcounts"] = cache["page_refcounts"].at[row].add(
            incs, mode="drop")  # sentinel row entries drop
        new["seq_lens"] = cache["seq_lens"].at[slot].set(s0)
        new["active"] = cache["active"].at[slot].set(True)
        new["uids"] = cache["uids"].at[slot].set(uid)
        new["steps"] = cache["steps"].at[slot].set(1)
        new["last_tok"] = cache["last_tok"].at[slot].set(nxt[0])
        return new, nxt[0]

    def _gather_prefix_kv(self, cache, pages, prefix_len: int):
        """Gather the KV held in ``pages`` — dequantized, for int8 pools
        (page-exact: codes x per-(page, head) scale) — as dense
        ``(R, 1, prefix_len, nkv, hd)`` prefix tensors for a suffix or
        chunked prefill. Returns a tuple aligned with ``cfg.pattern``
        (non-attention entries empty — the engine gates recurrent
        patterns off every path that calls this)."""

        def gather(p, scales=None):
            g = p[:, pages]  # (R, n_pages, bs, nkv, hd)
            if scales is not None:  # int8 codes -> float (page-exact)
                g = g.astype(jnp.float32) * (
                    scales[:, pages][..., None, :, None])
            r, _, _, nkv, hd = g.shape
            return g.reshape(r, 1, prefix_len, nkv, hd)

        prefix_kv = []
        for i, spec in enumerate(self.cfg.pattern):
            if spec.mixer != "attn":
                prefix_kv.append({})
                continue
            c = cache["pools"][i]
            if "k_scales" in c:
                prefix_kv.append(
                    {"k": gather(c["k_pages"], c["k_scales"]),
                     "v": gather(c["v_pages"], c["v_scales"])})
            else:
                prefix_kv.append({"k": gather(c["k_pages"]),
                                  "v": gather(c["v_pages"])})
        return tuple(prefix_kv)

    def _scatter_dense_pages(self, cache, dense, pages, n_pages: int):
        """Scatter a B=1 prefill's dense KV into ``pages`` (quantize-on-
        scatter for int8 pools: codes + per-(page, head) scales stamped
        together). Non-attention pools pass through untouched ("none"
        mixers only — the engine gates recurrent patterns)."""
        bs = self.paged.block_size
        pools = []
        for i, spec in enumerate(self.cfg.pattern):
            c = cache["pools"][i]
            if spec.mixer != "attn":
                pools.append(c)
                continue
            d = dense[i]

            def to_pages(a):
                r, _, _, nkv, hd = a.shape
                return a.reshape(r, n_pages, bs, nkv, hd)

            if "k_scales" in c:
                kc, ks, vc, vs = self._quantize_pages(i, to_pages(d["k"]),
                                                      to_pages(d["v"]))
                pools.append({
                    "k_pages": c["k_pages"].at[:, pages].set(kc),
                    "v_pages": c["v_pages"].at[:, pages].set(vc),
                    "k_scales": c["k_scales"].at[:, pages].set(ks),
                    "v_scales": c["v_scales"].at[:, pages].set(vs),
                })
            else:
                kp = c["k_pages"].at[:, pages].set(
                    to_pages(d["k"]).astype(c["k_pages"].dtype))
                vp = c["v_pages"].at[:, pages].set(
                    to_pages(d["v"]).astype(c["v_pages"].dtype))
                pools.append({"k_pages": kp, "v_pages": vp})
        return tuple(pools)

    def _admit_suffix_impl(self, params, cache, suffix, shared_pages, slot,
                           uid, incs, n_pages: int, n_shared: int):
        """Shared-prefix admit: the request's first ``n_shared`` logical
        blocks point at existing (refcounted, immutable) pages; only the
        uncached suffix runs a prefill forward pass, attending over the
        cached prefix KV gathered — and dequantized, for int8 pools —
        straight out of the shared pages. One trace per
        (suffix_len, n_pages, n_shared) bucket."""
        self.suffix_traces += 1
        cfg, paged = self.cfg, self.paged
        bs = paged.block_size
        _, t = suffix.shape  # (1, T) — the uncached prompt tail
        prefix_len = n_shared * bs
        s0 = prefix_len + t
        n_suffix_pages = -(-t // bs)
        prefill_len = n_suffix_pages * bs
        n_pop = n_pages - n_shared

        top = cache["free_top"]
        popped = jax.lax.dynamic_slice(cache["free_list"], (top,), (n_pop,))
        row = jnp.full((paged.max_pages_per_seq,), paged.num_blocks, jnp.int32)
        row = row.at[:n_shared].set(shared_pages)
        row = row.at[n_shared:n_shared + n_pop].set(popped)
        table = jax.lax.dynamic_update_slice(
            cache["block_table"], row[None], (slot, jnp.int32(0)))

        prefix_kv = self._gather_prefix_kv(cache, shared_pages, prefix_len)
        logits, dense = prefill(params, {"tokens": suffix}, cfg, prefill_len,
                                prefix_kv=prefix_kv, pos_offset=prefix_len)
        pools = self._scatter_dense_pages(cache, dense,
                                          popped[:n_suffix_pages],
                                          n_suffix_pages)

        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(self.sampler.seed), uid),
            jnp.int32(0))
        nxt = _sample(logits[:, -1], self.sampler.temperature, key)  # (1,)

        new = dict(cache)
        new["pools"] = tuple(pools)
        new["block_table"] = table
        new["free_top"] = top + n_pop
        new["page_refcounts"] = cache["page_refcounts"].at[row].add(
            incs, mode="drop")
        new["seq_lens"] = cache["seq_lens"].at[slot].set(s0)
        new["active"] = cache["active"].at[slot].set(True)
        new["uids"] = cache["uids"].at[slot].set(uid)
        new["steps"] = cache["steps"].at[slot].set(1)
        new["last_tok"] = cache["last_tok"].at[slot].set(nxt[0])
        return new, nxt[0]

    def _admit_cached_impl(self, cache, shared_pages, cow_src, slot, uid, s0,
                           last_tok, incs, n_pages: int, n_shared: int):
        """Fully-cached admit: NO prefill forward pass (takes no params at
        all — structurally FLOP-free, see :meth:`cached_admit_primitives`).
        The prompt's blocks are all cached; the last one is copied into a
        freshly popped private page (copy-on-write: decode appends rewrite
        position ``s0 - 1`` and grow page scales, which must never touch a
        shared page). The first token is *deferred*: ``seq_lens = s0 - 1``,
        ``steps = 0`` and ``last_tok = prompt[-1]`` hand the last prompt
        token to the next decode chunk, whose first step computes exactly
        the cold prefill's final-position logits and samples with the same
        ``fold_in(uid, 0)`` key — the sampling stream is unchanged."""
        self.cached_traces += 1
        paged = self.paged
        n_pop = n_pages - n_shared
        top = cache["free_top"]
        popped = jax.lax.dynamic_slice(cache["free_list"], (top,), (n_pop,))
        dest = popped[0]
        row = jnp.full((paged.max_pages_per_seq,), paged.num_blocks, jnp.int32)
        row = row.at[:n_shared].set(shared_pages)
        row = row.at[n_shared:n_shared + n_pop].set(popped)
        table = jax.lax.dynamic_update_slice(
            cache["block_table"], row[None], (slot, jnp.int32(0)))

        pools = []
        for i, spec in enumerate(self.cfg.pattern):
            c = cache["pools"][i]
            if spec.mixer == "attn":
                # CoW: copy codes AND scales — the private copy must
                # dequantize identically until the first append
                pools.append({k: leaf.at[:, dest].set(leaf[:, cow_src])
                              for k, leaf in c.items()})
            else:
                pools.append(c)

        new = dict(cache)
        new["pools"] = tuple(pools)
        new["block_table"] = table
        new["free_top"] = top + n_pop
        new["page_refcounts"] = cache["page_refcounts"].at[row].add(
            incs, mode="drop")
        new["seq_lens"] = cache["seq_lens"].at[slot].set(s0 - 1)
        new["active"] = cache["active"].at[slot].set(True)
        new["uids"] = cache["uids"].at[slot].set(uid)
        new["steps"] = cache["steps"].at[slot].set(0)
        new["last_tok"] = cache["last_tok"].at[slot].set(last_tok)
        return new

    def _chunk_impl(self, params, cache, k, attn_impl: str, attn_spec):
        """Up to ``chunk_max`` decode steps; ``k`` is a *dynamic* trip
        count so every chunk length reuses one trace."""
        self.chunk_traces += 1
        cfg, samp = self.cfg, self.sampler
        n_slots, chunk_max = self.paged.max_concurrency, self.paged.chunk_max
        buf = jnp.zeros((n_slots, chunk_max), jnp.int32)

        def cond(st):
            t, _, _ = st
            return t < k

        def body(st):
            t, cache, buf = st
            logits, cache = decode_step_paged(
                params, cache["last_tok"][:, None], cache, cfg,
                attn_impl=attn_impl, attn_spec=attn_spec,
                kv_scales=self._kv_scales_seq)
            keys = _fold_keys(samp.seed, cache["uids"], cache["steps"])
            nxt = _sample_rows(logits[:, -1], samp.temperature, keys)
            active = cache["active"]
            cache = dict(cache)
            cache["last_tok"] = jnp.where(active, nxt, cache["last_tok"])
            cache["steps"] = cache["steps"] + active.astype(jnp.int32)
            buf = jax.lax.dynamic_update_slice(buf, nxt[:, None], (0, t))
            return t + 1, cache, buf

        _, cache, buf = jax.lax.while_loop(cond, body, (jnp.int32(0), cache, buf))
        return cache, buf

    def _release_impl(self, cache, slot, pages, n):
        """Refcount-aware subset-push release: drop one reader from the
        first ``n`` of ``pages`` (a sentinel-padded ``max_pages_per_seq``-
        wide list) and push only the pages whose count hits zero — shared
        prefix pages stay resident for their other readers (or for the
        cache itself). ``n`` is *dynamic*: one trace serves every page
        count (and, with ``slot = max_concurrency``, the prefix cache's
        own evictions — the slot scatter drops)."""
        self.release_traces += 1
        W = self.paged.max_pages_per_seq
        nb = self.paged.num_blocks
        valid = jnp.arange(W) < n
        idx = jnp.where(valid, pages, nb)  # sentinel -> dropped scatters
        rc = cache["page_refcounts"].at[idx].add(
            -valid.astype(jnp.int32), mode="drop")
        freed = valid & (rc[jnp.minimum(idx, nb - 1)] == 0)
        count = jnp.sum(freed.astype(jnp.int32))
        # compact freed pages to the front in row order (stable sort on
        # the not-freed flag) and push them at [top - count, top)
        order = jnp.argsort(~freed, stable=True)
        push = idx[order]
        top = cache["free_top"] - count
        dest = jnp.where(jnp.arange(W) < count, top + jnp.arange(W), nb)
        new = dict(cache)
        new["free_list"] = cache["free_list"].at[dest].set(push, mode="drop")
        new["free_top"] = top
        new["page_refcounts"] = rc
        new["active"] = cache["active"].at[slot].set(False, mode="drop")
        return new

    def _admit_batch_impl(self, params, cache, tokens, s0s, slots, uids,
                          rows, scatter_idx, incs, total_pop,
                          n_prompt_pages: int):
        """Co-admit ``n`` cold requests in ONE padded multi-row prefill.
        ``tokens`` is ``(n, n_prompt_pages * bs)`` zero-padded; per-row KV
        at positions ``>= s0s[r]`` is zero-masked before the page scatter
        so every page (codes *and* int8 scales — pad zeros cannot raise a
        page max) is bit-identical to the B=1 admit's, and each row's
        first token is sampled from its own last-prompt-position logits
        with the same ``fold_in(uid, 0)`` key. Rows/pages are
        host-computed (the host free-list mirror pops in device order);
        the device just stamps them and advances ``free_top``. One trace
        per (n_rows, n_prompt_pages) bucket."""
        self.batch_traces += 1
        cfg, paged = self.cfg, self.paged
        bs = paged.block_size
        n, prefill_len = tokens.shape
        assert prefill_len == n_prompt_pages * bs

        logits, dense = prefill(params, {"tokens": tokens}, cfg, prefill_len)
        # (n, L): True at real prompt positions, False at pad positions
        pos_valid = jnp.arange(prefill_len)[None, :] < s0s[:, None]
        idx_flat = scatter_idx.reshape(-1)  # (n * P,) sentinel-masked
        pools = []
        for i, spec in enumerate(cfg.pattern):
            c = cache["pools"][i]
            if spec.mixer != "attn":  # "none" only — engine gates batching
                pools.append(c)
                continue
            d = dense[i]

            def to_pages(a):
                # zero-mask pad positions (matches the B=1 jnp.pad zeros),
                # then (R, n, L, nkv, hd) -> (R, n*P, bs, nkv, hd)
                a = jnp.where(pos_valid[None, :, :, None, None], a, 0)
                r, _, _, nkv, hd = a.shape
                return a.reshape(r, n * n_prompt_pages, bs, nkv, hd)

            if "k_scales" in c:
                kc, ks, vc, vs = self._quantize_pages(i, to_pages(d["k"]),
                                                      to_pages(d["v"]))
                pools.append({
                    "k_pages": c["k_pages"].at[:, idx_flat].set(
                        kc, mode="drop"),
                    "v_pages": c["v_pages"].at[:, idx_flat].set(
                        vc, mode="drop"),
                    "k_scales": c["k_scales"].at[:, idx_flat].set(
                        ks, mode="drop"),
                    "v_scales": c["v_scales"].at[:, idx_flat].set(
                        vs, mode="drop"),
                })
            else:
                kp = c["k_pages"].at[:, idx_flat].set(
                    to_pages(d["k"]).astype(c["k_pages"].dtype), mode="drop")
                vp = c["v_pages"].at[:, idx_flat].set(
                    to_pages(d["v"]).astype(c["v_pages"].dtype), mode="drop")
                pools.append({"k_pages": kp, "v_pages": vp})

        # each row's logits at its own last prompt position
        l_last = jnp.take_along_axis(
            logits, (s0s - 1)[:, None, None], axis=1)[:, 0]  # (n, V)
        keys = _fold_keys(self.sampler.seed, uids, jnp.zeros_like(uids))
        nxt = _sample_rows(l_last, self.sampler.temperature, keys)  # (n,)

        new = dict(cache)
        new["pools"] = tuple(pools)
        new["block_table"] = cache["block_table"].at[slots].set(rows)
        new["free_top"] = cache["free_top"] + total_pop
        new["page_refcounts"] = cache["page_refcounts"].at[
            rows.reshape(-1)].add(incs.reshape(-1), mode="drop")
        new["seq_lens"] = cache["seq_lens"].at[slots].set(s0s)
        new["active"] = cache["active"].at[slots].set(True)
        new["uids"] = cache["uids"].at[slots].set(uids)
        new["steps"] = cache["steps"].at[slots].set(1)
        new["last_tok"] = cache["last_tok"].at[slots].set(nxt)
        return new, nxt

    def _admit_stub_impl(self, cache, row, slot, uid, incs, n_pages):
        """Claim a slot + its full page row for a chunked prefill without
        touching the model: ``active = False`` (decode chunks skip the
        slot), ``seq_lens = steps = 0``. FLOP-free by construction;
        ``n_pages`` is dynamic — one trace serves every row size."""
        self.stub_traces += 1
        new = dict(cache)
        new["block_table"] = cache["block_table"].at[slot].set(row)
        new["free_top"] = cache["free_top"] + n_pages
        new["page_refcounts"] = cache["page_refcounts"].at[row].add(
            incs, mode="drop")
        new["seq_lens"] = cache["seq_lens"].at[slot].set(0)
        new["active"] = cache["active"].at[slot].set(False)
        new["uids"] = cache["uids"].at[slot].set(uid)
        new["steps"] = cache["steps"].at[slot].set(0)
        new["last_tok"] = cache["last_tok"].at[slot].set(0)
        return new

    def _grow_impl(self, cache, slot, row, add, n_new):
        """Watermark growth: stamp the slot's extended (host-computed) row,
        bump refcounts on exactly the new pages (``add`` is 1 there, 0
        elsewhere) and advance ``free_top``. Dynamic ``n_new`` — one trace
        serves every growth size."""
        self.grow_traces += 1
        new = dict(cache)
        new["block_table"] = cache["block_table"].at[slot].set(row)
        new["free_top"] = cache["free_top"] + n_new
        new["page_refcounts"] = cache["page_refcounts"].at[row].add(
            add, mode="drop")
        return new

    def _prefill_chunk_impl(self, params, cache, tokens, slot, uid, s0,
                            incs, n_prior: int, n_chunk_pages: int,
                            final: bool):
        """One page-aligned prefill chunk for a stub-admitted slot: gather
        the slot's first ``n_prior`` pages as dense prefix KV (the PR 6
        ``pos_offset`` suffix machinery), prefill this chunk's tokens at
        offset ``n_prior * bs`` and scatter them into the row's next
        ``n_chunk_pages`` pages. The ``final`` chunk samples the first
        token with the cold admit's exact ``fold_in(uid, 0)`` key and
        flips the slot live (``seq_lens = s0``, ``steps = 1``); earlier
        chunks leave the slot inactive so interleaved decode chunks skip
        it. One trace per (chunk_len, n_prior, final) bucket. Always
        returns ``(cache, tok)`` — ``tok = -1`` on non-final chunks — so
        the program's output pytree (and its mesh out_shardings) is
        identical across the final/non-final traces."""
        self.prefill_chunk_traces += 1
        cfg, paged = self.cfg, self.paged
        bs = paged.block_size
        _, t = tokens.shape  # (1, T) — this chunk's prompt tokens
        prefix_len = n_prior * bs
        prefill_len = n_chunk_pages * bs

        row = cache["block_table"][slot]  # (W,) — stamped at stub admit
        prefix_kv = (self._gather_prefix_kv(cache, row[:n_prior], prefix_len)
                     if n_prior else None)
        logits, dense = prefill(params, {"tokens": tokens}, cfg, prefill_len,
                                prefix_kv=prefix_kv, pos_offset=prefix_len)
        pools = self._scatter_dense_pages(
            cache, dense, row[n_prior:n_prior + n_chunk_pages], n_chunk_pages)

        new = dict(cache)
        new["pools"] = tuple(pools)
        if not final:
            return new, jnp.int32(-1)

        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(self.sampler.seed), uid),
            jnp.int32(0))
        nxt = _sample(logits[:, -1], self.sampler.temperature, key)  # (1,)
        # deferred prefix-cache registration lands with the final chunk
        new["page_refcounts"] = cache["page_refcounts"].at[row].add(
            incs, mode="drop")
        new["seq_lens"] = cache["seq_lens"].at[slot].set(s0)
        new["active"] = cache["active"].at[slot].set(True)
        new["steps"] = cache["steps"].at[slot].set(1)
        new["last_tok"] = cache["last_tok"].at[slot].set(nxt[0])
        return new, nxt[0]

    # ------------------------------------------------------------------
    # Host loop
    # ------------------------------------------------------------------
    def _make_scheduler(self) -> Scheduler:
        paged = self.paged
        return Scheduler(paged.max_concurrency, paged.num_blocks,
                         paged.block_size, paged.max_pages_per_seq,
                         prefix_cache=self.prefix_cache,
                         pool_state=self.pool_state,
                         policy=paged.sched)

    def submit_all(self, requests) -> Scheduler:
        sched = self._make_scheduler()
        for r in requests:
            sched.submit(r)
        return sched

    def _pad_row(self, pages) -> jnp.ndarray:
        """Sentinel-pad a physical page list to the block-table width (the
        release/evict programs take one fixed-width dynamic-count list)."""
        out = np.full(self.paged.max_pages_per_seq, self.paged.num_blocks,
                      np.int32)
        out[:len(pages)] = pages
        return out

    def _do_admit(self, adm, backend, attn_impl):
        """Run one admission's device programs (evict, then the admit
        variant the scheduler picked). Returns the request's first sampled
        token, or None for a fully cached prompt — its first sample is
        deferred to the next decode chunk."""
        if adm.evict_pages is not None and adm.evict_pages.size:
            self.cache = self._release(
                self.cache, np.int32(self.paged.max_concurrency),
                self._pad_row(adm.evict_pages),
                np.int32(adm.evict_pages.size))
        req = adm.req
        incs = np.asarray(adm.incs, np.int32)
        if adm.chunked:
            # stub admit: claim the slot + full row FLOP-free; the prompt
            # prefills later, one page-aligned chunk per scheduler pass
            self.cache = self._admit_stub(
                self.cache, self._pad_row(adm.row), np.int32(adm.slot),
                np.int32(req.uid), incs, np.int32(adm.n_pages))
            return None
        shared = np.asarray(adm.shared_pages, np.int32)
        if adm.cow_src is not None:
            self.cache = self._admit_cached(
                self.cache, shared, np.int32(adm.cow_src),
                np.int32(adm.slot), np.int32(req.uid),
                np.int32(req.prompt.size), np.int32(req.prompt[-1]),
                incs, adm.n_pages, adm.n_shared)
            return None
        if adm.n_shared:
            suffix = req.prompt[adm.n_shared * self.paged.block_size:]
            self.cache, tok0 = self._admit_suffix(
                self.params, self.cache,
                np.asarray(suffix, np.int32)[None],
                shared, np.int32(adm.slot), np.int32(req.uid), incs,
                adm.n_pages, adm.n_shared, backend, attn_impl,
                self.datapath_fingerprint)
        else:
            self.cache, tok0 = self._admit(
                self.params, self.cache,
                np.asarray(req.prompt, np.int32)[None], np.int32(adm.slot),
                np.int32(req.uid), incs, adm.n_pages, backend, attn_impl,
                self.datapath_fingerprint)
        return int(shardlib.host_read(tok0))

    def _do_admit_batch(self, group, backend, attn_impl) -> np.ndarray:
        """Run one batched-admission group (>= 2 cold requests) through a
        single padded multi-row prefill program. Returns the first sampled
        token per group member, in group order."""
        paged = self.paged
        bs, W = paged.block_size, paged.max_pages_per_seq
        n = len(group)
        s0s = np.asarray([a.req.prompt.size for a in group], np.int32)
        P = max(-(-int(s) // bs) for s in s0s)
        tokens = np.zeros((n, P * bs), np.int32)
        rows = np.full((n, W), paged.num_blocks, np.int32)
        scat = np.full((n, P), paged.num_blocks, np.int32)
        incs = np.zeros((n, W), np.int32)
        total_pop = 0
        for j, a in enumerate(group):
            tokens[j, :s0s[j]] = a.req.prompt
            rows[j, :a.n_pages] = a.row
            scat[j, :-(-int(s0s[j]) // bs)] = a.row[:-(-int(s0s[j]) // bs)]
            incs[j] = a.incs
            total_pop += a.n_pages  # cold: every row page freshly popped
        slots = np.asarray([a.slot for a in group], np.int32)
        uids = np.asarray([a.req.uid for a in group], np.int32)
        if self.mesh is not None:
            # per-host prompt sharding: the padded token block splits by
            # row over the data axis when the group size divides it
            # (divisibility fallback -> replicated); the per-row admin
            # vectors stay replicated host inputs
            tokens = shardlib.host_to_global(
                tokens, shardlib.rows_sharding(tokens.shape, self.mesh))
        self.cache, toks = self._admit_batch(
            self.params, self.cache, tokens, s0s,
            slots, uids, rows,
            scat, incs, np.int32(total_pop),
            n, P, backend, attn_impl, self.datapath_fingerprint)
        return np.asarray(shardlib.host_read(toks))

    def _do_prefill_chunk(self, slot, sched, backend, attn_impl):
        """Advance one stub-admitted slot by one page-aligned prefill
        chunk. Returns the request's first sampled token when this chunk
        completed the prompt, else None."""
        tokens, n_prior, final, incs = sched.take_prefill_chunk(slot)
        st = sched.active[slot]
        n_chunk_pages = -(-tokens.size // self.paged.block_size)
        self.cache, tok0 = self._prefill_chunk(
            self.params, self.cache, np.asarray(tokens, np.int32)[None],
            np.int32(slot), np.int32(st.req.uid),
            np.int32(st.req.prompt.size), np.asarray(incs, np.int32),
            n_prior, n_chunk_pages, final, backend, attn_impl,
            self.datapath_fingerprint)
        if final:
            return int(shardlib.host_read(tok0))
        return None

    @staticmethod
    def _arrival_feed(requests, arrivals):
        """Sort an arrival-time trace into a (time, request) deque —
        ``None`` when the whole list is submitted up front."""
        if arrivals is None:
            return None
        if len(arrivals) != len(requests):
            raise ValueError(
                f"arrivals has {len(arrivals)} entries for {len(requests)} "
                f"requests")
        order = sorted(range(len(requests)),
                       key=lambda i: (float(arrivals[i]), i))
        return deque((float(arrivals[i]), requests[i]) for i in order)

    def serve(self, requests, *, arrivals=None, metrics=None,
              _probe=None, _late=None) -> dict[int, np.ndarray]:
        """Run a request list to completion under continuous batching.

        Returns {uid: (S0_uid + n_generated,) int32} — generation is
        trimmed at the first EOS (when the sampler sets one), matching the
        fixed-slot engine's post-EOS padding semantics after re-padding.

        ``arrivals`` (optional, seconds, aligned with ``requests``) paces
        submission on the wall clock instead of submitting everything up
        front; ``metrics`` (a :class:`~repro.serving.metrics.ServeMetrics`)
        collects per-request TTFT / inter-token timestamps. Greedy results
        are identical either way — timing changes *when* work runs, never
        what any request's token stream is.

        The serve loop is picked by ``PagedConfig.sched``: the default
        legacy-FIFO policy runs the original head-of-line loop
        (bit-compatible, trace-shape-compatible); any other policy runs
        the throughput loop (windowed/batched admission, chunked prefill,
        watermark growth + preempt-and-requeue).

        ``_probe(engine, sched)`` (tests) runs after every admit/chunk/
        release transition; ``_late(sched, pass_idx)`` runs once per
        scheduler pass (after the decode chunk, when one ran) and may
        submit mid-flight arrivals — even when the pass drained every
        active request at admission, so injected work is never stranded.
        """
        # the observer must be attached when the decode chunk *traces*
        # (the callback is baked into the jaxpr); it is engine-constant
        # (observe=True at construction), so every trace under this
        # engine's "+obs" fingerprint is consistently observing
        if (arrivals is not None and self.mesh is not None
                and jax.process_count() > 1):
            # wall-clock pacing is single-controller: two processes would
            # observe different clocks, submit in different orders, and
            # issue diverging device programs (the SPMD deadlock class).
            # Multi-process traffic must arrive deterministically — all up
            # front, or through the pass-indexed ``_late`` hook.
            raise ValueError(
                "arrivals= is wall-clock-paced and single-controller; "
                "multi-process serving needs deterministic submission "
                "(submit everything up front or use the _late hook)")
        ctx = (attach_observer(self.observer) if self.observer is not None
               else nullcontext())
        with ctx:
            if self.paged.sched.is_legacy:
                return self._serve_legacy(requests, arrivals, metrics,
                                          _probe, _late)
            return self._serve_throughput(requests, arrivals, metrics,
                                          _probe, _late)

    def _serve_legacy(self, requests, arrivals, metrics, _probe, _late):
        sched = self._make_scheduler()
        pending = self._arrival_feed(requests, arrivals)
        if pending is None:
            for r in requests:
                sched.submit(r)
                if metrics is not None:
                    metrics.submitted(r.uid, r.priority, 0.0)
        backend = packed_backend()
        attn_impl = resolve_paged_attn_impl(self.paged.attn_impl)
        eos = self.sampler.eos_id
        results: dict[int, np.ndarray] = {}
        chunk_idx = 0
        t0 = time.perf_counter()

        def now():
            return time.perf_counter() - t0

        def submit_due():
            while pending and pending[0][0] <= now():
                t, r = pending.popleft()
                sched.submit(r)
                if metrics is not None:
                    metrics.submitted(r.uid, r.priority, t)

        def note(slot, toks):
            sched.record(slot, toks)
            if metrics is not None and toks:
                metrics.tokens(sched.active[slot].req.uid, len(toks), now())

        def finish(slot):
            st = sched.finish(slot)
            self.cache = self._release(self.cache, np.int32(slot),
                                       self._pad_row(st.row),
                                       np.int32(st.n_pages))
            results[st.req.uid] = np.concatenate(
                [st.req.prompt, np.asarray(st.tokens, np.int32)])
            if _probe is not None:
                _probe(self, sched)

        while sched.has_work or pending:
            if pending:
                submit_due()
                if not sched.has_work:
                    time.sleep(max(0.0, pending[0][0] - now()))
                    continue
            adm = sched.try_admit()
            while adm is not None:
                tok0 = self._do_admit(adm, backend, attn_impl)
                if tok0 is not None:
                    note(adm.slot, [tok0])
                if _probe is not None:
                    _probe(self, sched)
                if tok0 is not None and (
                        sched.remaining(adm.slot) == 0 or tok0 == eos):
                    finish(adm.slot)
                adm = sched.try_admit()
            if sched.active:
                k = min(self.paged.chunk_max, sched.min_remaining())
                self.cache, buf = self._chunk(
                    self.params, self.cache, np.int32(k), backend, attn_impl,
                    self.datapath_fingerprint, self.attn_spec)
                # the chunk's ONE host sync: buf is fully replicated by
                # the out_shardings contract, so this read is local on
                # every process (docs/multihost.md)
                buf = np.asarray(shardlib.host_read(buf))
                if _probe is not None:
                    _probe(self, sched)
                for slot in list(sched.active):
                    toks = buf[slot, :k].tolist()[: sched.remaining(slot)]
                    if eos is not None and eos in toks:
                        toks = toks[: toks.index(eos) + 1]
                    note(slot, toks)
                    if sched.remaining(slot) == 0 or (
                            eos is not None and toks and toks[-1] == eos):
                        finish(slot)
            elif sched.queue:  # cannot happen: submit() validates fit
                raise RuntimeError("queued requests can never be admitted")
            if _late is not None:
                _late(sched, chunk_idx)
            chunk_idx += 1
        return results

    def _serve_throughput(self, requests, arrivals, metrics, _probe, _late):
        """Throughput-mode serve loop. One pass = (1) one page-aligned
        prefill chunk per already-prefilling slot — in-flight prompts are
        older than anything queued, so they advance ahead of fresh
        admissions and a burst of arrivals cannot starve a long prompt's
        final chunk; (2) an admission pass — windowed, priority-ordered,
        cold arrivals co-admitted through the batched prefill program
        (slots stubbed here get their first chunk at the end of the same
        pass); (3) a planned decode chunk — cache eviction / preemption /
        watermark growth committed in plan order, then ``k`` fused steps.
        Token streams are bit-identical to the legacy loop: admission
        variants write identical pages and the per-request
        ``fold_in(uid, step)`` sampling stream is order-free."""
        sched = self._make_scheduler()
        pending = self._arrival_feed(requests, arrivals)
        if pending is None:
            for r in requests:
                sched.submit(r)
                if metrics is not None:
                    metrics.submitted(r.uid, r.priority, 0.0)
        backend = packed_backend()
        attn_impl = resolve_paged_attn_impl(self.paged.attn_impl)
        eos = self.sampler.eos_id
        results: dict[int, np.ndarray] = {}
        pass_idx = 0
        t0 = time.perf_counter()

        def now():
            return time.perf_counter() - t0

        def submit_due():
            while pending and pending[0][0] <= now():
                t, r = pending.popleft()
                sched.submit(r)
                if metrics is not None:
                    metrics.submitted(r.uid, r.priority, t)

        def note(slot, toks):
            sched.record(slot, toks)
            if metrics is not None and toks:
                metrics.tokens(sched.active[slot].req.uid, len(toks), now())

        def finish(slot):
            st = sched.finish(slot)
            self.cache = self._release(self.cache, np.int32(slot),
                                       self._pad_row(st.row),
                                       np.int32(st.n_pages))
            results[st.req.uid] = np.concatenate(
                [st.req.prompt, np.asarray(st.tokens, np.int32)])
            if _probe is not None:
                _probe(self, sched)

        def maybe_finish(slot):
            st = sched.active.get(slot)
            if st is None or st.prefilling:
                return
            if sched.remaining(slot) == 0 or (
                    eos is not None and st.tokens and st.tokens[-1] == eos):
                finish(slot)

        while sched.has_work or pending:
            if pending:
                submit_due()
                if not sched.has_work:
                    time.sleep(max(0.0, pending[0][0] - now()))
                    continue
            progressed = False

            def take_chunk(slot):
                tok0 = self._do_prefill_chunk(slot, sched, backend, attn_impl)
                if _probe is not None:
                    _probe(self, sched)
                if tok0 is not None:
                    note(slot, [tok0])
                    maybe_finish(slot)

            # In-flight prefills advance *before* new admissions: a
            # prefilling slot is older than anything still queued, and a
            # burst of batched admits must not starve its next chunk (the
            # final chunk is the request's first token).
            chunked_first = sched.prefilling_slots()
            for slot in chunked_first:
                progressed = True
                take_chunk(slot)
            # ``admit_pass`` commits every group host-side up front; the
            # device only catches up as each group's program runs, so
            # probes and releases (a finish's device push must not
            # interleave with this pass's remaining device pops — the
            # free-list replay order is the lockstep contract) wait until
            # the whole pass has executed.
            admitted = []
            for group in sched.admit_pass():
                progressed = True
                if len(group) == 1:
                    adm = group[0]
                    tok0 = self._do_admit(adm, backend, attn_impl)
                    if tok0 is not None:
                        note(adm.slot, [tok0])
                else:
                    toks = self._do_admit_batch(group, backend, attn_impl)
                    for adm, t in zip(group, toks):
                        note(adm.slot, [int(t)])
                admitted.extend(group)
            if admitted:
                if _probe is not None:
                    _probe(self, sched)
                for adm in admitted:
                    maybe_finish(adm.slot)
            for slot in sched.prefilling_slots():
                if slot in chunked_first:
                    continue  # one chunk per slot per pass
                progressed = True
                take_chunk(slot)
            plan = sched.plan_chunk(self.paged.chunk_max)
            if plan is not None:
                for v in plan.victims:
                    progressed = True  # freed pages: replanned next pass
                    st = sched.preempt(v)
                    self.preemptions += 1
                    self.cache = self._release(self.cache, np.int32(v),
                                               self._pad_row(st.row),
                                               np.int32(st.n_pages))
                    if metrics is not None:
                        metrics.preempted(st.req.uid)
                    if _probe is not None:
                        _probe(self, sched)
                if plan.evict_nodes:
                    pages = sched._commit_evict(plan.evict_nodes)
                    self.cache = self._release(
                        self.cache, np.int32(self.paged.max_concurrency),
                        self._pad_row(pages), np.int32(pages.size))
                    if _probe is not None:
                        _probe(self, sched)
                for slot, n_new in plan.grow:
                    pages, held = sched.commit_grow(slot, n_new)
                    add = np.zeros(self.paged.max_pages_per_seq, np.int32)
                    add[held:held + n_new] = 1
                    self.cache = self._grow(
                        self.cache, np.int32(slot),
                        self._pad_row(sched.active[slot].row),
                        add, np.int32(n_new))
                    if _probe is not None:
                        _probe(self, sched)
                if plan.slots:
                    progressed = True
                    self.cache, buf = self._chunk(
                        self.params, self.cache, np.int32(plan.k), backend,
                        attn_impl, self.datapath_fingerprint, self.attn_spec)
                    buf = np.asarray(shardlib.host_read(buf))
                    sched.advance_decode(plan.k)
                    if _probe is not None:
                        _probe(self, sched)
                    for slot in plan.slots:
                        toks = buf[slot, :plan.k].tolist()[
                            : sched.remaining(slot)]
                        if eos is not None and eos in toks:
                            toks = toks[: toks.index(eos) + 1]
                        note(slot, toks)
                        maybe_finish(slot)
            if not progressed and not sched.active and sched.queue \
                    and not pending:
                raise RuntimeError("queued requests can never be admitted")
            if _late is not None:
                _late(sched, pass_idx)
            pass_idx += 1
        return results

    # ------------------------------------------------------------------
    # Structural zero-FLOP certificate for the fully-cached admit
    # ------------------------------------------------------------------
    _FLOP_PRIMITIVES = frozenset({"dot_general", "conv_general_dilated"})

    def cached_admit_primitives(self, n_pages: int = 2,
                                n_shared: int = 1) -> set[str]:
        """All primitives (recursively) in the fully-cached admit jaxpr.
        The program takes no model params, so a single matmul appearing
        here would be a bug — :meth:`assert_cached_admit_flop_free` gates
        on the intersection with ``_FLOP_PRIMITIVES``."""
        W = self.paged.max_pages_per_seq
        i32 = jnp.int32
        traces = self.cached_traces  # make_jaxpr retraces; don't count it
        closed = jax.make_jaxpr(
            partial(self._admit_cached_impl, n_pages=n_pages,
                    n_shared=n_shared)
        )(self.cache, jnp.zeros((n_shared,), i32), i32(0), i32(0), i32(0),
          i32(1), i32(0), jnp.zeros((W,), i32))
        self.cached_traces = traces
        prims: set[str] = set()

        def walk(jaxpr):
            for eqn in jaxpr.eqns:
                prims.add(eqn.primitive.name)
                for v in eqn.params.values():
                    for sub in jax.tree.leaves(
                            v, is_leaf=lambda x: isinstance(
                                x, (jax.core.Jaxpr, jax.core.ClosedJaxpr))):
                        if isinstance(sub, jax.core.ClosedJaxpr):
                            walk(sub.jaxpr)
                        elif isinstance(sub, jax.core.Jaxpr):
                            walk(sub)

        walk(closed.jaxpr)
        return prims

    def assert_cached_admit_flop_free(self) -> None:
        """Admitting a fully cached prompt must run zero prefill FLOPs:
        its program is gathers/scatters only (no dot_general, no conv)."""
        hot = self.cached_admit_primitives() & self._FLOP_PRIMITIVES
        if hot:
            raise AssertionError(
                f"fully-cached admit contains FLOP primitives {sorted(hot)}")

    # ------------------------------------------------------------------
    # Observation (repro.quant.observe) — structural transparency
    # ------------------------------------------------------------------
    def decode_chunk_jaxpr(self, observer=None):
        """jaxpr of one decode chunk, traced fresh (the serving trace's
        exact body under the resolved backend). Default: NO observer
        attached — the baseline serving program. Pass a
        :class:`~repro.quant.observe.SaturationCounters` to trace the
        observing variant (adds ``debug_callback`` equations, nothing
        else)."""
        traces = self.chunk_traces  # make_jaxpr retraces; don't count it
        attn_impl = resolve_paged_attn_impl(self.paged.attn_impl)
        ctx = (attach_observer(observer) if observer is not None
               else nullcontext())
        with ctx, use_packed_backend(packed_backend()):
            closed = jax.make_jaxpr(
                partial(self._chunk_impl, attn_impl=attn_impl,
                        attn_spec=self.attn_spec)
            )(self.params, self.cache, jnp.int32(1))
        self.chunk_traces = traces
        return closed

    def assert_observation_transparent(self) -> None:
        """Observation must be free when off: the decode-chunk jaxpr with
        no observer attached contains no callback equation (it is exactly
        what an ``observe=False`` engine traces); with one attached, the
        callbacks appear. Raises AssertionError otherwise."""
        bare = str(self.decode_chunk_jaxpr())
        if "debug_callback" in bare:
            raise AssertionError(
                "decode chunk contains debug_callback with no observer "
                "attached — observation is not transparent")

        def has_packed(node):
            # the pmm hook only fires on packed integer leaves; a float
            # engine legitimately records nothing
            if isinstance(node, dict):
                return "packed" in node or any(
                    has_packed(v) for v in node.values())
            if isinstance(node, (list, tuple)):
                return any(has_packed(v) for v in node)
            return False

        if self.observer is not None and has_packed(self.params):
            observed = str(self.decode_chunk_jaxpr(self.observer))
            if "debug_callback" not in observed:
                raise AssertionError(
                    "observer attached but the decode chunk records "
                    "nothing (no debug_callback in the jaxpr)")

    def saturation_report(self) -> dict:
        """ServeMetrics-style saturation/watermark report from the
        serving observer (see ``repro.quant.observe.saturation``): per-site
        static-quantizer clip counts, code extrema, accumulator watermarks
        against the packed leaves, and per-KV-head attention watermarks
        for int8 pools. Requires ``observe=True`` at construction."""
        if self.observer is None:
            raise ValueError(
                "engine was built with observe=False — no counters to "
                "report; rebuild with PagedEngine(..., observe=True)")
        return self.observer.report(params=self.params,
                                    pools=self.cache["pools"],
                                    attn_spec=self.attn_spec)

    def assert_sampling_keys_collective_safe(self) -> None:
        """The per-request sampling stream must be identical on every
        device and process: keys derive in-graph as
        ``fold_in(fold_in(key(seed), uid), step)`` from *replicated* admin
        leaves, so the SPMD program — forced to return fully replicated
        key data — must agree bit-exactly with the eager single-device
        computation on the same (uids, steps). Mesh engines only; raises
        AssertionError on any divergence."""
        if self.mesh is None:
            raise ValueError(
                "engine has no mesh — the single-controller sampling "
                "stream is trivially host-consistent")
        uids = np.asarray(shardlib.host_read(self.cache["uids"]), np.int32)
        steps = np.asarray(shardlib.host_read(self.cache["steps"]), np.int32)
        seed = self.sampler.seed
        fn = jax.jit(lambda u, t: jax.random.key_data(_fold_keys(seed, u, t)),
                     out_shardings=self._out_rep)
        got = np.asarray(shardlib.host_read(fn(uids, steps)))
        want = np.asarray(jax.device_get(jax.random.key_data(
            _fold_keys(seed, jnp.asarray(uids), jnp.asarray(steps)))))
        np.testing.assert_array_equal(
            got, want,
            err_msg="SPMD sampling keys diverge from the single-device "
                    "stream — per-request determinism is broken")

    def generate(self, prompts: np.ndarray, max_new_tokens: int) -> np.ndarray:
        """Fixed-slot-compatible entry: prompts (B, S0) -> (B, S0 + max_new).

        Post-EOS positions are EOS-padded, matching
        :meth:`GenerationEngine.generate` exactly (greedy decode of an
        equal-length batch is bit-identical — golden-pinned)."""
        prompts = np.asarray(prompts, np.int32)
        reqs = []
        for row in prompts:
            reqs.append(Request(uid=self._uid_gen, prompt=row,
                                max_new=max_new_tokens))
            self._uid_gen += 1
        results = self.serve(reqs)
        eos = self.sampler.eos_id
        s_out = prompts.shape[1] + max_new_tokens
        out = np.full((len(reqs), s_out), 0 if eos is None else eos, np.int32)
        for i, r in enumerate(reqs):
            seq = results[r.uid]
            out[i, :seq.size] = seq
        return out
