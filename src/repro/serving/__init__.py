from .engine import GenerationEngine, SamplerConfig
from .paged_engine import PagedConfig, PagedEngine
from .scheduler import Request, Scheduler

__all__ = [
    "GenerationEngine",
    "PagedConfig",
    "PagedEngine",
    "Request",
    "SamplerConfig",
    "Scheduler",
]
