from .engine import GenerationEngine, SamplerConfig
from .paged_engine import PagedConfig, PagedEngine
from .prefix_cache import PrefixCache
from .scheduler import PoolState, Request, Scheduler

__all__ = [
    "GenerationEngine",
    "PagedConfig",
    "PagedEngine",
    "PoolState",
    "PrefixCache",
    "Request",
    "SamplerConfig",
    "Scheduler",
]
