from .engine import GenerationEngine, SamplerConfig
from .metrics import ServeMetrics
from .paged_engine import PagedConfig, PagedEngine
from .prefix_cache import PrefixCache
from .scheduler import PoolState, Request, Scheduler, SchedulerPolicy

__all__ = [
    "GenerationEngine",
    "PagedConfig",
    "PagedEngine",
    "PoolState",
    "PrefixCache",
    "Request",
    "SamplerConfig",
    "Scheduler",
    "SchedulerPolicy",
    "ServeMetrics",
]
