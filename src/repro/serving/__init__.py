from .engine import GenerationEngine, SamplerConfig

__all__ = ["GenerationEngine", "SamplerConfig"]
