"""Batched generation engine: prefill + device-resident decode loop.

A fixed-slot batch engine (continuous-batching-lite): all sequences in a
batch decode together with per-sequence done masks and early exit when all
finish. :meth:`GenerationEngine.generate` runs the whole prefill + multi-
token decode as ONE jitted program — the per-token loop is a
``lax.while_loop`` with on-device sampling, EOS masking and all-done early
exit, so a generate call costs one compile per (batch, max_len) bucket
(jit's shape cache) and exactly one device->host sync (the final
``jax.device_get`` of the token matrix). With packed-int4 params and the
kernel backend active (repro.models.layers.use_packed_backend), every
quantizable matmul inside the loop rides the fused W4A8 integer datapath.

:meth:`GenerationEngine.generate_host_loop` keeps the per-token host loop
as the semantics reference (and perf baseline). It has been fixed to stop
round-tripping tokens through numpy on every step: EOS masking happens on
device, and the only per-step host sync is the scalar all-done check (none
at all when ``eos_id`` is None).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import packed_backend, use_packed_backend
from repro.models.transformer import decode_step, prefill
from repro.quant.serve_packed import upgrade_packed_params
from repro.quant.spec import tree_datapath_fingerprint, validate_datapath


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0  # 0 => greedy
    eos_id: int | None = None
    seed: int = 0


def _sample(logits, temperature: float, key):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / temperature
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


class GenerationEngine:
    def __init__(self, params, cfg: ModelConfig, sampler: SamplerConfig = SamplerConfig(),
                 datapath=None):
        # legacy packed artifacts are upgraded ONCE here (pack-time
        # col_sums term + embedded DatapathSpec) so the traced decode graph
        # never re-derives either from a full unpack_int4 per step
        self.params = upgrade_packed_params(params)
        if datapath is not None:
            # loud end-to-end check: serving a certificate on a different
            # datapath than requested voids the overflow guarantee
            validate_datapath(self.params, datapath)
        #: aggregate hash of every packed leaf's DatapathSpec — a *static*
        #: argument of every jit below, so swapping in an artifact with a
        #: different certified datapath (tile, P_I, static-vs-dynamic act)
        #: retraces instead of silently reusing the old program
        self.datapath_fingerprint = tree_datapath_fingerprint(self.params)
        self.cfg = cfg
        self.sampler = sampler
        #: number of times the fused generate program was (re)traced —
        #: bucketing means repeated same-shape calls keep this at 1
        self.gen_traces = 0

        # the packed-matmul backend is resolved at *trace* time, so it is
        # threaded through every jit below as a static arg — switching
        # backends (use_packed_backend / REPRO_PACKED_BACKEND) between
        # calls retraces instead of silently reusing the old graph
        @partial(jax.jit, static_argnames=("temperature", "backend", "datapath"))
        def _step(params, tokens, cache, index, key, temperature, backend,
                  datapath):
            with use_packed_backend(backend):
                logits, cache = decode_step(params, tokens, cache, index, cfg)
                nxt = _sample(logits[:, -1], temperature, key)
            return nxt, cache

        self._step = _step
        self._prefill_cache = {}

        @partial(jax.jit, static_argnames=("max_new", "backend", "datapath"))
        def _gen(params, prompts, max_new, backend, datapath):
            with use_packed_backend(backend):
                return self._gen_impl(params, prompts, max_new)

        self._gen = _gen

    def _get_prefill(self, max_len: int, backend: str):
        fn = self._prefill_cache.get((max_len, backend, self.datapath_fingerprint))
        if fn is None:

            def run(p, b, _ml=max_len, _be=backend):
                with use_packed_backend(_be):
                    return prefill(p, b, self.cfg, _ml)

            fn = jax.jit(run)
            self._prefill_cache[(max_len, backend, self.datapath_fingerprint)] = fn
        return fn

    # ------------------------------------------------------------------
    # Fused on-device loop (the serving path)
    # ------------------------------------------------------------------
    def _gen_impl(self, params, prompts, max_new: int):
        """Traced once per (B, S0, max_new) bucket."""
        self.gen_traces += 1  # python side effect: runs at trace time only
        cfg, samp = self.cfg, self.sampler
        temperature, eos = samp.temperature, samp.eos_id
        B, S0 = prompts.shape
        max_len = S0 + max_new

        logits, cache = prefill(params, {"tokens": prompts}, cfg, max_len)
        key = jax.random.key(samp.seed)
        key, sub = jax.random.split(key)
        nxt = _sample(logits[:, -1], temperature, sub)
        if eos is not None:
            done = nxt == eos
            # unwritten tail positions (early exit) must read as post-EOS pad
            toks = jnp.full((B, max_new), eos, jnp.int32)
        else:
            done = jnp.zeros((B,), bool)
            toks = jnp.zeros((B, max_new), jnp.int32)
        toks = jax.lax.dynamic_update_slice(toks, nxt[:, None], (0, 0))

        def cond(st):
            t, _, _, done, _, _ = st
            return jnp.logical_and(t < max_new, jnp.logical_not(jnp.all(done)))

        def body(st):
            t, nxt, cache, done, key, toks = st
            key, sub = jax.random.split(key)
            logits, cache = decode_step(params, nxt[:, None], cache, S0 + t - 1, cfg)
            new = _sample(logits[:, -1], temperature, sub)
            if eos is not None:
                new = jnp.where(done, eos, new)
                done = done | (new == eos)
            toks = jax.lax.dynamic_update_slice(toks, new[:, None], (0, t))
            return (t + 1, new, cache, done, key, toks)

        st = (jnp.int32(1), nxt, cache, done, key, toks)
        st = jax.lax.while_loop(cond, body, st)
        return jnp.concatenate([prompts, st[5]], axis=1)

    def generate(self, prompts: np.ndarray, max_new_tokens: int) -> np.ndarray:
        """prompts: (B, S0) int32. Returns (B, S0 + max_new_tokens).

        One device round-trip total: prompts up, the finished token matrix
        down (the single explicit ``jax.device_get``).
        """
        out = self._gen(self.params, jnp.asarray(prompts, jnp.int32),
                        max_new_tokens, packed_backend(),
                        self.datapath_fingerprint)
        return np.asarray(jax.device_get(out))

    # ------------------------------------------------------------------
    # Host-loop reference (kept as baseline + semantics oracle)
    # ------------------------------------------------------------------
    def generate_host_loop(self, prompts: np.ndarray, max_new_tokens: int) -> np.ndarray:
        """Per-token host loop, semantics-identical to :meth:`generate`."""
        B, S0 = prompts.shape
        max_len = S0 + max_new_tokens
        temperature, eos = self.sampler.temperature, self.sampler.eos_id
        backend = packed_backend()
        dev_prompts = jnp.asarray(prompts, jnp.int32)
        logits, cache = self._get_prefill(max_len, backend)(
            self.params, {"tokens": dev_prompts}
        )
        key = jax.random.key(self.sampler.seed)
        key, sub = jax.random.split(key)
        nxt = _sample(logits[:, -1], temperature, sub)
        done = (nxt == eos) if eos is not None else None
        out = [nxt]
        for t in range(1, max_new_tokens):
            key, sub = jax.random.split(key)
            nxt, cache = self._step(
                self.params, nxt[:, None], cache, jnp.int32(S0 + t - 1), sub,
                temperature, backend, self.datapath_fingerprint,
            )
            if eos is not None:
                # mask + done tracking on device: no per-token np round-trip
                nxt = jnp.where(done, eos, nxt)
                done = done | (nxt == eos)
            out.append(nxt)
            if eos is not None and bool(jnp.all(done)):
                # pad remaining positions with eos and stop early (the only
                # per-step host sync, a scalar — and only when eos is set)
                pad = jnp.full((B,), eos, jnp.int32)
                out.extend([pad] * (max_new_tokens - 1 - t))
                break
        gen = jnp.stack(out, axis=1)
        return np.asarray(jax.device_get(jnp.concatenate([dev_prompts, gen], axis=1)))
