"""Batched generation engine: prefill + decode with jitted step reuse.

A fixed-slot batch engine (continuous-batching-lite): all sequences in a
batch decode together with per-sequence done masks and early exit when all
finish. The decode step is compiled once per (batch, max_len) bucket —
repeated calls reuse the jit cache, which is what a production server's
bucketing achieves.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import decode_step, prefill


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0  # 0 => greedy
    eos_id: int | None = None
    seed: int = 0


def _sample(logits, temperature: float, key):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / temperature
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


class GenerationEngine:
    def __init__(self, params, cfg: ModelConfig, sampler: SamplerConfig = SamplerConfig()):
        self.params = params
        self.cfg = cfg
        self.sampler = sampler

        @partial(jax.jit, static_argnames=("temperature",))
        def _step(params, tokens, cache, index, key, temperature):
            logits, cache = decode_step(params, tokens, cache, index, cfg)
            nxt = _sample(logits[:, -1], temperature, key)
            return nxt, cache

        self._step = _step
        self._prefill_cache = {}

    def _get_prefill(self, max_len: int):
        fn = self._prefill_cache.get(max_len)
        if fn is None:
            fn = jax.jit(lambda p, b: prefill(p, b, self.cfg, max_len))
            self._prefill_cache[max_len] = fn
        return fn

    def generate(self, prompts: np.ndarray, max_new_tokens: int) -> np.ndarray:
        """prompts: (B, S0) int32. Returns (B, S0 + max_new_tokens)."""
        B, S0 = prompts.shape
        max_len = S0 + max_new_tokens
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        logits, cache = self._get_prefill(max_len)(self.params, batch)
        key = jax.random.key(self.sampler.seed)
        key, sub = jax.random.split(key)
        nxt = _sample(logits[:, -1], self.sampler.temperature, sub)
        out = [np.asarray(nxt)]
        done = np.zeros((B,), bool)
        if self.sampler.eos_id is not None:
            done |= np.asarray(nxt) == self.sampler.eos_id
        for t in range(1, max_new_tokens):
            key, sub = jax.random.split(key)
            nxt, cache = self._step(
                self.params, nxt[:, None], cache, jnp.int32(S0 + t - 1), sub,
                self.sampler.temperature,
            )
            tok = np.asarray(nxt)
            if self.sampler.eos_id is not None:
                tok = np.where(done, self.sampler.eos_id, tok)
                done |= tok == self.sampler.eos_id
            out.append(tok)
            nxt = jnp.asarray(tok)
            if self.sampler.eos_id is not None and done.all():
                # pad remaining positions with eos and stop early
                pad = np.full((B,), self.sampler.eos_id, np.int32)
                out.extend([pad] * (max_new_tokens - 1 - t))
                break
        gen = np.stack(out, axis=1)
        return np.concatenate([prompts, gen], axis=1)
