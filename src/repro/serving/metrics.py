"""Per-request serving latency accounting: TTFT and inter-token latency
percentiles, bucketed by priority class.

The serve loop stamps wall-clock times host-side: one ``submitted`` per
request (its arrival), one ``tokens`` per admit / decode chunk (every
token the chunk produced shares the chunk-end timestamp — intra-chunk
gaps therefore read as zero and the inter-token distribution's tail
measures exactly the stalls an operator feels: head-of-line prefills,
admission waits, preemption restarts). ``ttft`` is the gap from arrival
to the *first* token ever produced — a preempt-and-requeue restart
re-emits tokens but cannot move a request's TTFT.

``summary()`` emits microsecond-suffixed percentile keys
(``ttft_p50_us`` … ``itl_p99_us``), overall and per class under
``class_<p>`` — the shapes ``scripts/bench_compare.py`` classifies as
lower-is-better.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class _Rec:
    priority: int
    t_submit: float
    times: list = field(default_factory=list)  # one wall-clock per token
    n_preempt: int = 0


class ServeMetrics:
    def __init__(self):
        self._recs: dict[int, _Rec] = {}

    # ------------------------------------------------------------------
    # Recording (called by the serve loops)
    # ------------------------------------------------------------------
    def submitted(self, uid: int, priority: int, t: float) -> None:
        if uid not in self._recs:  # resubmission after preemption keeps t0
            self._recs[uid] = _Rec(priority=priority, t_submit=t)

    def tokens(self, uid: int, n: int, t: float) -> None:
        self._recs[uid].times.extend([t] * n)

    def preempted(self, uid: int) -> None:
        rec = self._recs[uid]
        rec.n_preempt += 1
        # the produced tokens are discarded and will be re-emitted; keep
        # only the first timestamp so TTFT survives and the restart's
        # re-decode gap lands in the inter-token distribution honestly
        del rec.times[1:]

    # ------------------------------------------------------------------
    # Reporting
    # ------------------------------------------------------------------
    @staticmethod
    def _pcts(vals: list[float]) -> dict:
        if not vals:
            return {}
        a = np.asarray(vals, np.float64) * 1e6  # seconds -> us
        return {"p50_us": float(np.percentile(a, 50)),
                "p99_us": float(np.percentile(a, 99))}

    def _section(self, recs: list[_Rec]) -> dict:
        ttft = [r.times[0] - r.t_submit for r in recs if r.times]
        itl: list[float] = []
        for r in recs:
            itl.extend(float(b - a) for a, b in zip(r.times, r.times[1:]))
        out = {"n_requests": len(recs),
               "n_preemptions": sum(r.n_preempt for r in recs)}
        out.update({f"ttft_{k}": v for k, v in self._pcts(ttft).items()})
        out.update({f"itl_{k}": v for k, v in self._pcts(itl).items()})
        return out

    def summary(self) -> dict:
        recs = list(self._recs.values())
        out = self._section(recs)
        for p in sorted({r.priority for r in recs}):
            out[f"class_{p}"] = self._section(
                [r for r in recs if r.priority == p])
        return out
