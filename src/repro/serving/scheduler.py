"""Host-side continuous-batching scheduler for the paged decode engine.

The scheduler is deliberately device-free (pure Python + numpy): it owns
the *accounting* — which requests are queued, which engine slot and how
many KV pages each active request holds — while the actual page indices
live on device in the cache pytree's ``free_list`` stack (popped/pushed
inside the engine's jitted admit/release programs). The two stay
consistent because every admit/release goes through both in lockstep.

Admission policy: FIFO, head-of-line. A request is admitted when (a) an
engine slot is free and (b) the pool has enough free pages for its
*worst case* — ``ceil((S0 + max_new - 1) / block_size)`` pages, the
number of KV positions a fully-decoded sequence writes. Reserving the
worst case up front means exhaustion can only ever surface as a stalled
admission (the queue waits for a running sequence to finish), never as a
mid-decode allocation failure that would need preemption.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np


# ---------------------------------------------------------------------------
# Pool HBM accounting (the docs/serving_scheduler.md formula)
# ---------------------------------------------------------------------------
def kv_page_bytes(cfg, block_size: int, kv_dtype: str = "act") -> int:
    """Bytes one KV page costs across every attention layer of the stack
    (K and V, codes plus — for ``kv_dtype="int8"`` — the per-(page,
    kv-head) f32 scale leaves). This is the unit the admission reservation
    multiplies: a request's worst case is ``pages_for(S0, max_new)`` of
    these."""
    n_attn = sum(1 for s in cfg.pattern if s.mixer == "attn") * cfg.repeats
    itemsize = 1 if kv_dtype == "int8" else np.dtype(cfg.act_dtype).itemsize
    per_page = 2 * block_size * cfg.n_kv_heads * cfg.head_dim * itemsize
    if kv_dtype == "int8":
        per_page += 2 * cfg.n_kv_heads * 4  # k_scales + v_scales, f32
    return n_attn * per_page


def kv_pool_bytes(cfg, num_blocks: int, block_size: int,
                  kv_dtype: str = "act") -> int:
    """Total KV pool HBM for a ``num_blocks``-page pool — int8 pages cost
    about half the bf16 pool (exactly half plus the scale leaves)."""
    return num_blocks * kv_page_bytes(cfg, block_size, kv_dtype)


def blocks_for_budget(budget_bytes: int, cfg, block_size: int,
                      kv_dtype: str = "act") -> int:
    """Largest page pool an HBM budget affords. Because int8 pages cost
    ~half the bf16 bytes, the same budget holds ~2x the pages — and since
    admission reserves the worst case in *pages*, the scheduler admits
    ~2x the sequences before stalling (asserted in tests/test_scheduler.py).

    A budget smaller than one page raises (a zero-page pool can never
    admit anything — ``--kv-hbm-mb`` misconfiguration should fail at
    launch, not as an unexplained admission stall).
    """
    per_page = kv_page_bytes(cfg, block_size, kv_dtype)
    n = budget_bytes // per_page
    if n < 1:
        raise ValueError(
            f"KV HBM budget {budget_bytes} B is below one page: a single "
            f"block_size={block_size} {kv_dtype} page costs {per_page} B "
            f"across the stack's attention layers — raise the budget or "
            f"shrink block_size"
        )
    return n


@dataclass(frozen=True)
class Request:
    """One generation request: ``uid`` must be unique per engine lifetime
    (it seeds the request's sampling key stream, making sampled output
    deterministic per request regardless of co-batched traffic)."""

    uid: int
    prompt: np.ndarray  # (S0,) int32
    max_new: int

    def __post_init__(self):
        prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        object.__setattr__(self, "prompt", prompt)
        if prompt.size < 1:
            raise ValueError(f"request {self.uid}: empty prompt")
        if self.max_new < 1:
            raise ValueError(f"request {self.uid}: max_new must be >= 1")


@dataclass
class _Active:
    req: Request
    n_pages: int
    produced: int = 0  # tokens generated so far (admission token included)
    tokens: list = field(default_factory=list)
    row: np.ndarray | None = None  # (n_pages,) physical pages, row order
    nodes: list = field(default_factory=list)  # prefix-cache nodes held


@dataclass
class PoolState:
    """Host mirror of the device page allocator: the ``free_list`` stack
    (free region = ``free_list[free_top:]``), ``free_top``, and the
    per-page refcounts. The device admit/release programs and this mirror
    perform the identical pops/pushes in the identical order, so the host
    always knows which physical pages a request holds without a device
    readback — which is what lets the prefix cache hand *physical* page
    indices to a later admission. Owned by the engine (it must persist
    across ``serve()`` calls: cached pages stay out of the free stack
    between traces), shared with each ``Scheduler``.
    """

    free_list: np.ndarray
    free_top: int
    page_rc: np.ndarray

    @classmethod
    def fresh(cls, num_blocks: int) -> "PoolState":
        return cls(free_list=np.arange(num_blocks, dtype=np.int32),
                   free_top=0,
                   page_rc=np.zeros(num_blocks, np.int32))

    @property
    def free_pages(self) -> int:
        return self.free_list.size - self.free_top

    def pop(self, n: int) -> np.ndarray:
        pages = self.free_list[self.free_top:self.free_top + n].copy()
        self.free_top += n
        self.page_rc[pages] += 1
        return pages

    def push(self, pages) -> None:
        """Push freed pages (rc already at 0) — same order as the device
        subset-push: ``free_list[top - n + j] = pages[j]``."""
        n = len(pages)
        if not n:
            return
        self.free_top -= n
        self.free_list[self.free_top:self.free_top + n] = pages


@dataclass(frozen=True)
class Admission:
    """One admission decision, host side. ``row`` is the request's full
    physical block-table row: ``n_shared`` leading pages borrowed from the
    prefix cache (refcount bumped, never written), then ``n_pop`` freshly
    popped pages (``cow_src`` is copied into the first of them on a fully
    cached prompt — the copy-on-write tail). ``evict_pages`` must be
    pushed back on device *before* the admit pops. Unpacks as the legacy
    ``(slot, req, n_pages)`` triple."""

    slot: int
    req: Request
    n_pages: int
    n_shared: int = 0
    cow_src: int | None = None
    row: np.ndarray | None = None
    evict_pages: np.ndarray | None = None
    incs: np.ndarray | None = None

    @property
    def n_pop(self) -> int:
        return self.n_pages - self.n_shared

    @property
    def shared_pages(self) -> np.ndarray:
        return self.row[:self.n_shared]

    def __iter__(self):  # legacy (slot, req, n_pages) unpacking
        return iter((self.slot, self.req, self.n_pages))

    def __getitem__(self, i):  # legacy triple indexing
        return (self.slot, self.req, self.n_pages)[i]


class Scheduler:
    def __init__(self, max_concurrency: int, num_blocks: int, block_size: int,
                 max_pages_per_seq: int, prefix_cache=None,
                 pool_state: PoolState | None = None):
        self.max_concurrency = max_concurrency
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.max_pages_per_seq = max_pages_per_seq
        self.queue: deque[Request] = deque()
        self.free_slots: list[int] = sorted(range(max_concurrency), reverse=True)
        self.active: dict[int, _Active] = {}
        self.prefix_cache = prefix_cache
        self.pool = pool_state if pool_state is not None else PoolState.fresh(
            num_blocks)
        if prefix_cache is not None and prefix_cache.block_size != block_size:
            raise ValueError("prefix cache block_size != scheduler block_size")
        self._inflight: set[int] = set()

    @property
    def free_pages(self) -> int:
        """Pages poppable right now (the device free stack's depth) —
        excludes pages the prefix cache holds at refcount 1, which are
        reclaimable only through eviction."""
        return self.pool.free_pages

    # ------------------------------------------------------------------
    # Accounting
    # ------------------------------------------------------------------
    def pages_for(self, prompt_len: int, max_new: int) -> int:
        """Worst-case page need: a sequence writes KV for positions
        ``0 .. S0 + max_new - 2`` (the final sampled token is returned but
        never fed back, so its KV is never written — same as the dense
        engine's cache sizing)."""
        return -(-(prompt_len + max_new - 1) // self.block_size)

    def submit(self, req: Request) -> None:
        need = self.pages_for(req.prompt.size, req.max_new)
        if need > self.max_pages_per_seq:
            raise ValueError(
                f"request {req.uid}: needs {need} pages > block table width "
                f"{self.max_pages_per_seq} (prompt {req.prompt.size} + "
                f"max_new {req.max_new}, block_size {self.block_size})"
            )
        if need > self.num_blocks:
            raise ValueError(
                f"request {req.uid}: needs {need} pages > pool size "
                f"{self.num_blocks} — can never be admitted"
            )
        if req.uid in self._inflight:
            # serve() keys its results dict by uid: a duplicate would
            # silently clobber one request's output — fail loudly instead
            raise ValueError(
                f"request uid {req.uid} is already in flight (queued or "
                f"active); uids must be unique until the request finishes"
            )
        self._inflight.add(req.uid)
        self.queue.append(req)

    def try_admit(self) -> Admission | None:
        """Pop the queue head into a free slot if slot + pages allow;
        returns an :class:`Admission` (legacy-unpackable as
        ``(slot, request, n_pages)``) or None — a stalled admission leaves
        scheduler, pool mirror and prefix cache untouched.

        With a prefix cache attached, the head's worst-case reservation
        *subtracts* its cached prefix: only ``n_pages - n_shared`` pages
        must be popped, and a shortage may additionally be covered by
        evicting cold cache entries (all-or-nothing, LRU leaf-first)."""
        if not self.queue or not self.free_slots:
            return None
        req = self.queue[0]
        n_pages = self.pages_for(req.prompt.size, req.max_new)
        s0, bs = req.prompt.size, self.block_size

        matched, cow_node = [], None
        if self.prefix_cache is not None:
            matched = self.prefix_cache.match(req.prompt)
            if matched and len(matched) * bs == s0:
                # fully cached prompt: the last cached block doubles as
                # the decode tail (position s0-1 onward) — share all but
                # that block, and copy-on-write its page at admit
                cow_node = matched[-1]
                matched = matched[:-1]
        n_shared = len(matched)
        n_pop = n_pages - n_shared

        evict_plan = []
        if n_pop > self.pool.free_pages:
            if self.prefix_cache is None:
                return None  # stall: wait for a running sequence to free
            protect = {n.key for n in matched}
            if cow_node is not None:
                protect.add(cow_node.key)
            evict_plan = self.prefix_cache.plan_evict(
                n_pop - self.pool.free_pages, protect)
            if evict_plan is None:
                return None  # shortage not coverable — stall, no mutation

        # ---- commit ----
        self.queue.popleft()
        slot = self.free_slots.pop()
        evict_pages = np.asarray([n.page for n in evict_plan], np.int32)
        if evict_plan:
            self.prefix_cache.evict(evict_plan)
            self.pool.page_rc[evict_pages] -= 1
            assert (self.pool.page_rc[evict_pages] == 0).all()
            self.pool.push(evict_pages)
        shared = np.asarray([n.page for n in matched], np.int32)
        popped = self.pool.pop(n_pop)  # rc 0 -> 1 (exclusive row ref)
        row = np.concatenate([shared, popped])
        incs = np.zeros(self.max_pages_per_seq, np.int32)
        incs[:n_pages] = 1  # every row entry is one reader
        nodes = list(matched)
        if self.prefix_cache is not None:
            n_full = s0 // bs
            self.pool.page_rc[shared] += 1
            self.prefix_cache.acquire(matched, n_full)
            if cow_node is not None:
                self.prefix_cache.touch(cow_node)
            else:
                # freshly prefilled full blocks join the cache: +1 cache
                # ref on top of the row ref
                new_nodes = self.prefix_cache.insert(req.prompt, row,
                                                     start_block=n_shared)
                nodes += new_nodes
                for j, node in enumerate(new_nodes):
                    # inserted block i sits at row index n_shared + i
                    self.pool.page_rc[node.page] += 1
                    incs[n_shared + j] += 1
        self.active[slot] = _Active(req=req, n_pages=n_pages, row=row,
                                    nodes=nodes)
        return Admission(
            slot=slot, req=req, n_pages=n_pages, n_shared=n_shared,
            cow_src=None if cow_node is None else cow_node.page,
            row=row, evict_pages=evict_pages, incs=incs,
        )

    def record(self, slot: int, tokens) -> None:
        st = self.active[slot]
        st.tokens.extend(int(t) for t in tokens)
        st.produced += len(tokens)

    def finish(self, slot: int) -> _Active:
        """Release the slot and the row's refcounts; pages whose count
        drops to zero return to the free stack (in row order — matching
        the device subset-push program). Returns the record."""
        st = self.active.pop(slot)
        self._inflight.discard(st.req.uid)
        if self.prefix_cache is not None:
            self.prefix_cache.release(st.nodes)
        self.pool.page_rc[st.row] -= 1
        assert (self.pool.page_rc[st.row] >= 0).all()
        self.pool.push([p for p in st.row if self.pool.page_rc[p] == 0])
        self.free_slots.append(slot)
        self.free_slots.sort(reverse=True)
        return st

    # ------------------------------------------------------------------
    # Loop predicates
    # ------------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.active)

    def remaining(self, slot: int) -> int:
        st = self.active[slot]
        return st.req.max_new - st.produced

    def min_remaining(self) -> int:
        return min(self.remaining(s) for s in self.active)
