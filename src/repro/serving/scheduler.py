"""Host-side continuous-batching scheduler for the paged decode engine.

The scheduler is deliberately device-free (pure Python + numpy): it owns
the *accounting* — which requests are queued, which engine slot and how
many KV pages each active request holds — while the actual page indices
live on device in the cache pytree's ``free_list`` stack (popped/pushed
inside the engine's jitted admit/release programs). The two stay
consistent because every admit/release goes through both in lockstep.

Admission policy: FIFO, head-of-line. A request is admitted when (a) an
engine slot is free and (b) the pool has enough free pages for its
*worst case* — ``ceil((S0 + max_new - 1) / block_size)`` pages, the
number of KV positions a fully-decoded sequence writes. Reserving the
worst case up front means exhaustion can only ever surface as a stalled
admission (the queue waits for a running sequence to finish), never as a
mid-decode allocation failure that would need preemption.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np


# ---------------------------------------------------------------------------
# Pool HBM accounting (the docs/serving_scheduler.md formula)
# ---------------------------------------------------------------------------
def kv_page_bytes(cfg, block_size: int, kv_dtype: str = "act") -> int:
    """Bytes one KV page costs across every attention layer of the stack
    (K and V, codes plus — for ``kv_dtype="int8"`` — the per-(page,
    kv-head) f32 scale leaves). This is the unit the admission reservation
    multiplies: a request's worst case is ``pages_for(S0, max_new)`` of
    these."""
    n_attn = sum(1 for s in cfg.pattern if s.mixer == "attn") * cfg.repeats
    itemsize = 1 if kv_dtype == "int8" else np.dtype(cfg.act_dtype).itemsize
    per_page = 2 * block_size * cfg.n_kv_heads * cfg.head_dim * itemsize
    if kv_dtype == "int8":
        per_page += 2 * cfg.n_kv_heads * 4  # k_scales + v_scales, f32
    return n_attn * per_page


def kv_pool_bytes(cfg, num_blocks: int, block_size: int,
                  kv_dtype: str = "act") -> int:
    """Total KV pool HBM for a ``num_blocks``-page pool — int8 pages cost
    about half the bf16 pool (exactly half plus the scale leaves)."""
    return num_blocks * kv_page_bytes(cfg, block_size, kv_dtype)


def blocks_for_budget(budget_bytes: int, cfg, block_size: int,
                      kv_dtype: str = "act") -> int:
    """Largest page pool an HBM budget affords. Because int8 pages cost
    ~half the bf16 bytes, the same budget holds ~2x the pages — and since
    admission reserves the worst case in *pages*, the scheduler admits
    ~2x the sequences before stalling (asserted in tests/test_scheduler.py).
    """
    return budget_bytes // kv_page_bytes(cfg, block_size, kv_dtype)


@dataclass(frozen=True)
class Request:
    """One generation request: ``uid`` must be unique per engine lifetime
    (it seeds the request's sampling key stream, making sampled output
    deterministic per request regardless of co-batched traffic)."""

    uid: int
    prompt: np.ndarray  # (S0,) int32
    max_new: int

    def __post_init__(self):
        prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        object.__setattr__(self, "prompt", prompt)
        if prompt.size < 1:
            raise ValueError(f"request {self.uid}: empty prompt")
        if self.max_new < 1:
            raise ValueError(f"request {self.uid}: max_new must be >= 1")


@dataclass
class _Active:
    req: Request
    n_pages: int
    produced: int = 0  # tokens generated so far (admission token included)
    tokens: list = field(default_factory=list)


class Scheduler:
    def __init__(self, max_concurrency: int, num_blocks: int, block_size: int,
                 max_pages_per_seq: int):
        self.max_concurrency = max_concurrency
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.max_pages_per_seq = max_pages_per_seq
        self.queue: deque[Request] = deque()
        self.free_slots: list[int] = sorted(range(max_concurrency), reverse=True)
        self.free_pages = num_blocks
        self.active: dict[int, _Active] = {}

    # ------------------------------------------------------------------
    # Accounting
    # ------------------------------------------------------------------
    def pages_for(self, prompt_len: int, max_new: int) -> int:
        """Worst-case page need: a sequence writes KV for positions
        ``0 .. S0 + max_new - 2`` (the final sampled token is returned but
        never fed back, so its KV is never written — same as the dense
        engine's cache sizing)."""
        return -(-(prompt_len + max_new - 1) // self.block_size)

    def submit(self, req: Request) -> None:
        need = self.pages_for(req.prompt.size, req.max_new)
        if need > self.max_pages_per_seq:
            raise ValueError(
                f"request {req.uid}: needs {need} pages > block table width "
                f"{self.max_pages_per_seq} (prompt {req.prompt.size} + "
                f"max_new {req.max_new}, block_size {self.block_size})"
            )
        if need > self.num_blocks:
            raise ValueError(
                f"request {req.uid}: needs {need} pages > pool size "
                f"{self.num_blocks} — can never be admitted"
            )
        self.queue.append(req)

    def try_admit(self) -> tuple[int, Request, int] | None:
        """Pop the queue head into a free slot if slot + pages allow;
        returns (slot, request, n_pages) or None (admission stalls — the
        request stays queued, nothing is allocated)."""
        if not self.queue or not self.free_slots:
            return None
        req = self.queue[0]
        need = self.pages_for(req.prompt.size, req.max_new)
        if need > self.free_pages:
            return None  # stall: wait for a running sequence to free pages
        self.queue.popleft()
        slot = self.free_slots.pop()
        self.free_pages -= need
        self.active[slot] = _Active(req=req, n_pages=need)
        return slot, req, need

    def record(self, slot: int, tokens) -> None:
        st = self.active[slot]
        st.tokens.extend(int(t) for t in tokens)
        st.produced += len(tokens)

    def finish(self, slot: int) -> _Active:
        """Release the slot and its page reservation; returns the record."""
        st = self.active.pop(slot)
        self.free_pages += st.n_pages
        self.free_slots.append(slot)
        self.free_slots.sort(reverse=True)
        return st

    # ------------------------------------------------------------------
    # Loop predicates
    # ------------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.active)

    def remaining(self, slot: int) -> int:
        st = self.active[slot]
        return st.req.max_new - st.produced

    def min_remaining(self) -> int:
        return min(self.remaining(s) for s in self.active)
