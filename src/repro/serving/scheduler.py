"""Host-side continuous-batching scheduler for the paged decode engine.

The scheduler is deliberately device-free (pure Python + numpy): it owns
the *accounting* — which requests are queued, which engine slot and how
many KV pages each active request holds — while the actual page indices
live on device in the cache pytree's ``free_list`` stack (popped/pushed
inside the engine's jitted admit/release programs). The two stay
consistent because every admit/release goes through both in lockstep.

Two admission policies share the accounting (:class:`SchedulerPolicy`):

* **Legacy FIFO** (the default, bit-compatible with every prior release):
  head-of-line, one request at a time, worst-case page reservation —
  ``ceil((S0 + max_new - 1) / block_size)`` pages up front, so exhaustion
  only ever surfaces as a stalled admission, never as a mid-decode
  allocation failure.
* **Throughput mode** (any non-default policy field): :meth:`
  Scheduler.admit_pass` scans an ``admit_window`` of the queue in
  priority order (FIFO within a class), co-admits compatible cold
  arrivals into batched-prefill groups of up to ``batch_max`` rows,
  admits long prompts as *chunked-prefill* stubs, and — with a
  ``watermark`` — replaces the worst-case reservation with an initial
  prompt-sized allocation plus on-demand page growth before each decode
  chunk. Pool pressure is resolved by LRU cache eviction first, then by
  preempting the lowest-priority youngest victim (:meth:`plan_chunk`);
  a preempted request requeues at the front and is *protected* from
  re-victimization until it has produced a token (no-livelock guard:
  its initial allocation always affords one decode step).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np


# ---------------------------------------------------------------------------
# Pool HBM accounting (the docs/serving_scheduler.md formula)
# ---------------------------------------------------------------------------
def kv_page_bytes(cfg, block_size: int, kv_dtype: str = "act") -> int:
    """Bytes one KV page costs across every attention layer of the stack
    (K and V, codes plus — for ``kv_dtype="int8"`` — the per-(page,
    kv-head) f32 scale leaves). This is the unit the admission reservation
    multiplies: a request's worst case is ``pages_for(S0, max_new)`` of
    these."""
    n_attn = sum(1 for s in cfg.pattern if s.mixer == "attn") * cfg.repeats
    itemsize = 1 if kv_dtype == "int8" else np.dtype(cfg.act_dtype).itemsize
    per_page = 2 * block_size * cfg.n_kv_heads * cfg.head_dim * itemsize
    if kv_dtype == "int8":
        per_page += 2 * cfg.n_kv_heads * 4  # k_scales + v_scales, f32
    return n_attn * per_page


def kv_pool_bytes(cfg, num_blocks: int, block_size: int,
                  kv_dtype: str = "act") -> int:
    """Total KV pool HBM for a ``num_blocks``-page pool — int8 pages cost
    about half the bf16 pool (exactly half plus the scale leaves)."""
    return num_blocks * kv_page_bytes(cfg, block_size, kv_dtype)


def blocks_for_budget(budget_bytes: int, cfg, block_size: int,
                      kv_dtype: str = "act") -> int:
    """Largest page pool an HBM budget affords. Because int8 pages cost
    ~half the bf16 bytes, the same budget holds ~2x the pages — and since
    admission reserves the worst case in *pages*, the scheduler admits
    ~2x the sequences before stalling (asserted in tests/test_scheduler.py).

    A budget smaller than one page raises (a zero-page pool can never
    admit anything — ``--kv-hbm-mb`` misconfiguration should fail at
    launch, not as an unexplained admission stall).
    """
    per_page = kv_page_bytes(cfg, block_size, kv_dtype)
    n = budget_bytes // per_page
    if n < 1:
        raise ValueError(
            f"KV HBM budget {budget_bytes} B is below one page: a single "
            f"block_size={block_size} {kv_dtype} page costs {per_page} B "
            f"across the stack's attention layers — raise the budget or "
            f"shrink block_size"
        )
    return n


@dataclass(frozen=True)
class SchedulerPolicy:
    """Admission/decode policy knobs. The default is *legacy FIFO* —
    head-of-line admission, B=1 prefill, worst-case reservation — and is
    bit-compatible with the pre-policy engine (the serving benches use it
    as the baseline). Any non-default field switches the engine's serve
    loop into throughput mode.

    ``admit_window``: how many queued requests one admission pass may
    examine (head-only when 1). ``batch_max``: max rows co-admitted into
    one padded multi-row prefill program (cold prompts only — cache-hit
    admits keep their specialized n=1 variants). ``prefill_chunk``: when
    set, cold prompts longer than this prefill in page-aligned chunks of
    at most this many tokens, interleaved with decode chunks (must be a
    multiple of the engine block size). ``watermark``: ``(low, high)``
    free-page watermarks — admission keeps a ``low``-page reserve for
    decode growth instead of reserving each request's worst case, and
    after a preemption new arrivals wait until ``high`` pages are free
    (hysteresis; preempted requeues are exempt so they can resume)."""

    admit_window: int = 1
    batch_max: int = 1
    prefill_chunk: int | None = None
    watermark: tuple[int, int] | None = None

    def __post_init__(self):
        if self.admit_window < 1:
            raise ValueError("admit_window must be >= 1")
        if self.batch_max < 1:
            raise ValueError("batch_max must be >= 1")
        if self.prefill_chunk is not None and self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1 (or None)")
        if self.watermark is not None:
            low, high = self.watermark
            if not (0 <= low <= high):
                raise ValueError(
                    f"watermark must satisfy 0 <= low <= high, got "
                    f"({low}, {high})")

    @property
    def is_legacy(self) -> bool:
        """True for the default policy: the engine then runs the original
        FIFO serve loop (admission drains fully before every decode chunk,
        one admit trace per request — several tests pin that shape)."""
        return (self.admit_window == 1 and self.batch_max == 1
                and self.prefill_chunk is None and self.watermark is None)


@dataclass(frozen=True)
class Request:
    """One generation request: ``uid`` must be unique per engine lifetime
    (it seeds the request's sampling key stream, making sampled output
    deterministic per request regardless of co-batched traffic —
    including across a preempt-and-requeue restart). ``priority`` is the
    scheduling class, 0 = most urgent; admission prefers lower values and
    preemption victimizes higher ones."""

    uid: int
    prompt: np.ndarray  # (S0,) int32
    max_new: int
    priority: int = 0

    def __post_init__(self):
        prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        object.__setattr__(self, "prompt", prompt)
        if prompt.size < 1:
            raise ValueError(f"request {self.uid}: empty prompt")
        if self.max_new < 1:
            raise ValueError(f"request {self.uid}: max_new must be >= 1")


@dataclass
class _Active:
    req: Request
    n_pages: int  # pages currently held (== row.size; grows under watermark)
    target_pages: int  # worst-case need — n_pages never exceeds this
    produced: int = 0  # tokens generated so far (admission token included)
    tokens: list = field(default_factory=list)
    row: np.ndarray | None = None  # (n_pages,) physical pages, row order
    nodes: list = field(default_factory=list)  # prefix-cache nodes held
    seq: int = 0  # host mirror of the device seq_lens entry
    prefilling: bool = False  # chunked prefill still in progress
    prefill_pos: int = 0  # tokens prefilled so far (page-aligned)
    protected: bool = False  # preempted-and-readmitted, no token yet
    admit_tick: int = 0  # admission order (victim selection: youngest)


@dataclass
class PoolState:
    """Host mirror of the device page allocator: the ``free_list`` stack
    (free region = ``free_list[free_top:]``), ``free_top``, and the
    per-page refcounts. The device admit/release programs and this mirror
    perform the identical pops/pushes in the identical order, so the host
    always knows which physical pages a request holds without a device
    readback — which is what lets the prefix cache hand *physical* page
    indices to a later admission. Owned by the engine (it must persist
    across ``serve()`` calls: cached pages stay out of the free stack
    between traces), shared with each ``Scheduler``.

    Under a multi-process mesh every process runs this mirror
    independently: it is pure seeded numpy driven only by the (identical)
    request trace and the (replicated) device token reads, so the replay
    is byte-identical on every host by construction — the multi-process
    battery (scripts/run_multiprocess.py) allgathers :meth:`digest` and
    asserts exactly that.
    """

    free_list: np.ndarray
    free_top: int
    page_rc: np.ndarray

    @classmethod
    def fresh(cls, num_blocks: int) -> "PoolState":
        return cls(free_list=np.arange(num_blocks, dtype=np.int32),
                   free_top=0,
                   page_rc=np.zeros(num_blocks, np.int32))

    @property
    def free_pages(self) -> int:
        return self.free_list.size - self.free_top

    def pop(self, n: int) -> np.ndarray:
        pages = self.free_list[self.free_top:self.free_top + n].copy()
        self.free_top += n
        self.page_rc[pages] += 1
        return pages

    def push(self, pages) -> None:
        """Push freed pages (rc already at 0) — same order as the device
        subset-push: ``free_list[top - n + j] = pages[j]``."""
        n = len(pages)
        if not n:
            return
        self.free_top -= n
        self.free_list[self.free_top:self.free_top + n] = pages

    def digest(self) -> str:
        """Stable byte-level digest of the allocator state (free stack,
        top, refcounts) — what the multi-process determinism battery
        compares across hosts and against the device's replicated
        ``free_list``/``page_refcounts`` leaves."""
        import hashlib

        h = hashlib.blake2b(digest_size=16)
        h.update(np.asarray(self.free_list, np.int32).tobytes())
        h.update(np.int64(self.free_top).tobytes())
        h.update(np.asarray(self.page_rc, np.int32).tobytes())
        return h.hexdigest()


@dataclass(frozen=True)
class Admission:
    """One admission decision, host side. ``row`` is the request's full
    physical block-table row: ``n_shared`` leading pages borrowed from the
    prefix cache (refcount bumped, never written), then ``n_pop`` freshly
    popped pages (``cow_src`` is copied into the first of them on a fully
    cached prompt — the copy-on-write tail). ``evict_pages`` must be
    pushed back on device *before* the admit pops. ``chunked`` marks a
    chunked-prefill stub: pages are allocated and the block table row is
    installed, but no prefill runs at admit — the engine drives it
    forward via :meth:`Scheduler.take_prefill_chunk`. Under a watermark
    policy ``n_pages`` is the *initial* allocation (prompt pages plus one
    decode page), not the worst case — ``target_pages`` is the cap the
    slot may grow to."""

    slot: int
    req: Request
    n_pages: int
    target_pages: int
    n_shared: int = 0
    cow_src: int | None = None
    row: np.ndarray | None = None
    evict_pages: np.ndarray | None = None
    incs: np.ndarray | None = None
    chunked: bool = False

    @property
    def n_pop(self) -> int:
        return self.n_pages - self.n_shared

    @property
    def shared_pages(self) -> np.ndarray:
        return self.row[:self.n_shared]


@dataclass
class _Plan:
    """A pure (no-mutation) admission plan for one request — the stall
    test ran against the current pool/cache state; :meth:`Scheduler._commit`
    turns it into an :class:`Admission`."""

    req: Request
    n_pages: int
    target_pages: int
    n_shared: int
    matched: list
    cow_node: object | None
    evict_plan: list
    chunked: bool


@dataclass
class ChunkPlan:
    """One decode chunk's resource decisions (:meth:`Scheduler.plan_chunk`),
    computed atomically against the host mirror but not yet committed.
    The engine applies it in order: preempt ``victims`` (device release +
    requeue), evict ``evict_nodes`` (cache pages pushed), grow ``grow``
    slots (pages popped, block-table rows extended), then run ``k`` fused
    decode steps over ``slots``."""

    k: int
    slots: list[int]  # decoding (non-prefilling) slots the chunk advances
    victims: list[int] = field(default_factory=list)
    evict_nodes: list = field(default_factory=list)
    grow: list[tuple[int, int]] = field(default_factory=list)  # (slot, n_new)


class Scheduler:
    def __init__(self, max_concurrency: int, num_blocks: int, block_size: int,
                 max_pages_per_seq: int, prefix_cache=None,
                 pool_state: PoolState | None = None,
                 policy: SchedulerPolicy = SchedulerPolicy()):
        self.max_concurrency = max_concurrency
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.max_pages_per_seq = max_pages_per_seq
        self.policy = policy
        self.queue: deque[Request] = deque()
        self.free_slots: list[int] = sorted(range(max_concurrency), reverse=True)
        self.active: dict[int, _Active] = {}
        self.prefix_cache = prefix_cache
        self.pool = pool_state if pool_state is not None else PoolState.fresh(
            num_blocks)
        if prefix_cache is not None and prefix_cache.block_size != block_size:
            raise ValueError("prefix cache block_size != scheduler block_size")
        if policy.prefill_chunk is not None and (
                policy.prefill_chunk % block_size != 0):
            raise ValueError(
                f"prefill_chunk {policy.prefill_chunk} must be a multiple of "
                f"block_size {block_size} (chunks scatter whole pages)")
        if policy.watermark is not None and policy.watermark[1] > num_blocks:
            raise ValueError(
                f"watermark high {policy.watermark[1]} > pool size "
                f"{num_blocks} — admission could never resume")
        self._inflight: set[int] = set()
        #: uids preempted and awaiting re-admission — they bypass the
        #: post-preemption hysteresis gate and readmit *protected*
        self._preempted: set[int] = set()
        self._cooldown = False  # watermark hysteresis after a preemption
        self._tick = 0  # admission order clock (victim selection)
        self.preemptions = 0

    @property
    def free_pages(self) -> int:
        """Pages poppable right now (the device free stack's depth) —
        excludes pages the prefix cache holds at refcount 1, which are
        reclaimable only through eviction."""
        return self.pool.free_pages

    # ------------------------------------------------------------------
    # Accounting
    # ------------------------------------------------------------------
    def pages_for(self, prompt_len: int, max_new: int) -> int:
        """Worst-case page need: a sequence writes KV for positions
        ``0 .. S0 + max_new - 2`` (the final sampled token is returned but
        never fed back, so its KV is never written — same as the dense
        engine's cache sizing)."""
        return -(-(prompt_len + max_new - 1) // self.block_size)

    def submit(self, req: Request) -> None:
        need = self.pages_for(req.prompt.size, req.max_new)
        if need > self.max_pages_per_seq:
            raise ValueError(
                f"request {req.uid}: needs {need} pages > block table width "
                f"{self.max_pages_per_seq} (prompt {req.prompt.size} + "
                f"max_new {req.max_new}, block_size {self.block_size})"
            )
        # Pool-size rejection runs against the *post-prefix-match*
        # requirement: a long prompt whose leading blocks are already
        # resident only ever pops the uncached remainder, so the
        # worst-case bound would spuriously reject it. The match is
        # advisory (cache contents move before admission) — admit-time
        # planning remains the authority and a request that still cannot
        # fit stalls there (surfacing as the serve loop's loud
        # "can never be admitted" error, never a silent hang).
        n_cached = (len(self.prefix_cache.match(req.prompt))
                    if self.prefix_cache is not None else 0)
        if need - n_cached > self.num_blocks:
            raise ValueError(
                f"request {req.uid}: needs {need - n_cached} fresh pages "
                f"(worst case {need} minus {n_cached} cached prefix blocks) "
                f"> pool size {self.num_blocks} — can never be admitted"
            )
        if req.uid in self._inflight:
            # serve() keys its results dict by uid: a duplicate would
            # silently clobber one request's output — fail loudly instead
            raise ValueError(
                f"request uid {req.uid} is already in flight (queued or "
                f"active); uids must be unique until the request finishes"
            )
        self._inflight.add(req.uid)
        self.queue.append(req)

    # ------------------------------------------------------------------
    # Admission planning (pure) and commit
    # ------------------------------------------------------------------
    def _plan(self, req: Request) -> _Plan | None:
        """Plan one admission against the current state — returns None on
        a stall and mutates nothing (pool, cache and queue untouched)."""
        target = self.pages_for(req.prompt.size, req.max_new)
        s0, bs = req.prompt.size, self.block_size
        wm = self.policy.watermark

        matched, cow_node = [], None
        if self.prefix_cache is not None:
            matched = self.prefix_cache.match(req.prompt)
            if matched and len(matched) * bs == s0:
                # fully cached prompt: the last cached block doubles as
                # the decode tail (position s0-1 onward) — share all but
                # that block, and copy-on-write its page at admit
                cow_node = matched[-1]
                matched = matched[:-1]
        n_shared = len(matched)

        chunked = (self.policy.prefill_chunk is not None and n_shared == 0
                   and cow_node is None and s0 > self.policy.prefill_chunk)
        if wm is None:
            n_pages = target
        else:
            # initial allocation: the prompt's pages plus the page holding
            # position s0 — so a freshly (re-)admitted slot always affords
            # one decode step without growth (the no-livelock guarantee
            # preemption protection relies on)
            n_pages = min(target, s0 // bs + 1)
            n_pages = max(n_pages,
                          n_shared + (1 if cow_node is not None else 0))
            if self._cooldown and req.uid not in self._preempted:
                # post-preemption hysteresis: fresh arrivals wait for the
                # pool to recover to the high watermark; the preempted
                # request itself is exempt so it can resume
                return None
        n_pop = n_pages - n_shared

        reserve = wm[0] if (wm is not None and self.active) else 0
        evict_plan: list = []
        shortage = n_pop + reserve - self.pool.free_pages
        if shortage > 0:
            if self.prefix_cache is None:
                return None  # stall: wait for a running sequence to free
            protect = {n.key for n in matched}
            if cow_node is not None:
                protect.add(cow_node.key)
            evict_plan = self.prefix_cache.plan_evict(shortage, protect)
            if evict_plan is None:
                return None  # shortage not coverable — stall, no mutation
        return _Plan(req=req, n_pages=n_pages, target_pages=target,
                     n_shared=n_shared, matched=matched, cow_node=cow_node,
                     evict_plan=evict_plan, chunked=chunked)

    def _commit_evict(self, plan: list) -> np.ndarray:
        """Drop an eviction plan from the cache and the host pool mirror
        (returns the pages — the engine pairs this with a device release
        at the sentinel slot)."""
        pages = np.asarray([n.page for n in plan], np.int32)
        if len(plan):
            self.prefix_cache.evict(plan)
            self.pool.page_rc[pages] -= 1
            assert (self.pool.page_rc[pages] == 0).all()
            self.pool.push(pages)
        return pages

    def _commit(self, plan: _Plan) -> Admission:
        """Commit a plan: dequeue, allocate, register cache refs."""
        req = plan.req
        s0, bs = req.prompt.size, self.block_size
        self.queue.remove(req)
        slot = self.free_slots.pop()
        evict_pages = self._commit_evict(plan.evict_plan)
        matched, cow_node = plan.matched, plan.cow_node
        n_pages, n_shared = plan.n_pages, plan.n_shared
        shared = np.asarray([n.page for n in matched], np.int32)
        popped = self.pool.pop(plan.n_pages - n_shared)  # rc 0 -> 1
        row = np.concatenate([shared, popped])
        incs = np.zeros(self.max_pages_per_seq, np.int32)
        incs[:n_pages] = 1  # every row entry is one reader
        nodes = list(matched)
        if self.prefix_cache is not None:
            n_full = s0 // bs
            self.pool.page_rc[shared] += 1
            self.prefix_cache.acquire(matched, n_full)
            if cow_node is not None:
                self.prefix_cache.touch(cow_node)
            elif not plan.chunked:
                # freshly prefilled full blocks join the cache: +1 cache
                # ref on top of the row ref (a chunked stub defers this to
                # its final prefill chunk — blocks must not be matchable
                # before their KV is actually written)
                new_nodes = self.prefix_cache.insert(req.prompt, row,
                                                     start_block=n_shared)
                nodes += new_nodes
                for j, node in enumerate(new_nodes):
                    # inserted block i sits at row index n_shared + i
                    self.pool.page_rc[node.page] += 1
                    incs[n_shared + j] += 1
        self._tick += 1
        st = _Active(req=req, n_pages=n_pages, target_pages=plan.target_pages,
                     row=row, nodes=nodes, admit_tick=self._tick)
        if plan.chunked:
            st.prefilling = True
            st.seq = 0
        else:
            st.seq = s0 - 1 if cow_node is not None else s0
        if req.uid in self._preempted:
            self._preempted.discard(req.uid)
            st.protected = True
        self.active[slot] = st
        return Admission(
            slot=slot, req=req, n_pages=n_pages,
            target_pages=plan.target_pages, n_shared=n_shared,
            cow_src=None if cow_node is None else cow_node.page,
            row=row, evict_pages=evict_pages, incs=incs,
            chunked=plan.chunked,
        )

    def try_admit(self) -> Admission | None:
        """Pop the queue head into a free slot if slot + pages allow;
        returns an :class:`Admission` or None — a stalled admission leaves
        scheduler, pool mirror and prefix cache untouched.

        With a prefix cache attached, the head's worst-case reservation
        *subtracts* its cached prefix: only ``n_pages - n_shared`` pages
        must be popped, and a shortage may additionally be covered by
        evicting cold cache entries (all-or-nothing, LRU leaf-first)."""
        if not self.queue or not self.free_slots:
            return None
        plan = self._plan(self.queue[0])
        if plan is None:
            return None
        return self._commit(plan)

    def admit_pass(self) -> list[list[Admission]]:
        """One throughput-mode admission pass: repeatedly scan the first
        ``admit_window`` queued requests in (priority, FIFO) order and
        commit the first plannable one, until no slot or no candidate
        fits. Consecutive *cold* admissions (no cache hit, no eviction,
        not chunked) group into batched-prefill lists of up to
        ``batch_max`` rows; everything else is its own singleton group.
        Returns the groups in commit order — device pops must replay in
        exactly this order."""
        pol = self.policy
        wm = pol.watermark
        if self._cooldown and wm is not None and (
                not self.active or self.pool.free_pages >= wm[1]):
            self._cooldown = False
        groups: list[list[Admission]] = []
        cur: list[Admission] = []

        def flush():
            nonlocal cur
            if cur:
                groups.append(cur)
                cur = []

        while self.free_slots and self.queue:
            window = [self.queue[i]
                      for i in range(min(pol.admit_window, len(self.queue)))]
            window.sort(key=lambda r: r.priority)  # stable: FIFO in class
            committed = None
            for req in window:
                plan = self._plan(req)
                if plan is not None:
                    committed = self._commit(plan)
                    break
            if committed is None:
                break
            adm = committed
            groupable = (pol.batch_max > 1 and not adm.chunked
                         and adm.n_shared == 0 and adm.cow_src is None
                         and adm.evict_pages.size == 0)
            if groupable and len(cur) < pol.batch_max:
                cur.append(adm)
            else:
                flush()
                if groupable:
                    cur = [adm]
                else:
                    groups.append([adm])
        flush()
        return groups

    # ------------------------------------------------------------------
    # Chunked prefill
    # ------------------------------------------------------------------
    def prefilling_slots(self) -> list[int]:
        return sorted(s for s, st in self.active.items() if st.prefilling)

    def take_prefill_chunk(self, slot: int):
        """Advance a chunked-prefill slot by one chunk. Returns
        ``(tokens, n_prior_pages, final, incs)``: the chunk's tokens, the
        page count already written (the device program gathers their KV as
        the attention prefix), whether this chunk completes the prompt,
        and — on the final chunk — the per-row-position refcount bumps for
        blocks the prefix cache registers (the deferred insert happens
        here, once the KV is actually about to exist)."""
        st = self.active[slot]
        assert st.prefilling
        s0, bs = st.req.prompt.size, self.block_size
        start = st.prefill_pos
        end = min(s0, start + self.policy.prefill_chunk)
        final = end == s0
        tokens = st.req.prompt[start:end]
        n_prior = start // bs  # chunks are page-aligned by construction
        incs = np.zeros(self.max_pages_per_seq, np.int32)
        st.prefill_pos = end
        if final:
            st.prefilling = False
            st.seq = s0
            if self.prefix_cache is not None:
                # deferred insert: skip blocks a concurrent request cached
                # meanwhile (this row keeps private duplicates for those)
                new_nodes = self.prefix_cache.insert(
                    st.req.prompt, st.row, start_block=0, skip_existing=True)
                st.nodes += new_nodes
                for node in new_nodes:
                    self.pool.page_rc[node.page] += 1
                    j = int(np.where(st.row == node.page)[0][0])
                    incs[j] += 1
        return tokens, n_prior, final, incs

    # ------------------------------------------------------------------
    # Decode-chunk planning: growth, eviction, preemption
    # ------------------------------------------------------------------
    def plan_chunk(self, chunk_max: int) -> ChunkPlan | None:
        """Plan the next fused decode chunk: pick the trip count ``k``
        (min over decoding slots' remaining budgets, so no slot overruns
        its worst case) and the page growth each slot needs to write ``k``
        more positions. A shortage is covered in escalating order: LRU
        cache eviction (all-or-nothing), then preemption of the lowest-
        priority youngest unprotected victim (repeat), then shrinking the
        chunk to one step — at which point the remaining (all-protected)
        slots need no growth by the initial-allocation invariant, so the
        plan always terminates with a runnable chunk or no slots at all.

        Pure: commits happen via :meth:`preempt` / :meth:`_commit_evict` /
        :meth:`commit_grow` in the order the plan lists them."""
        decoding = [s for s, st in self.active.items() if not st.prefilling]
        if not decoding:
            return None
        bs = self.block_size
        victims: list[int] = []
        evict_nodes: list = []
        free = self.pool.free_pages
        sim_rc = None  # lazily copied refcounts for victim-free simulation
        cap = chunk_max
        while True:
            k = min(cap, min(self.remaining(s) for s in decoding))
            need = {}
            for s in decoding:
                st = self.active[s]
                need[s] = max(0, -(-(st.seq + k) // bs) - st.n_pages)
            total = sum(need.values())
            if total <= free:
                break
            if self.prefix_cache is not None:
                plan = self.prefix_cache.plan_evict(total - free, set())
                if plan is not None:
                    evict_nodes = plan
                    break
            cand = [s for s in decoding if not self.active[s].protected]
            if cand:
                # lowest priority class (max value), then youngest
                v = max(cand, key=lambda s: (self.active[s].req.priority,
                                             self.active[s].admit_tick))
                victims.append(v)
                decoding.remove(v)
                if not decoding:
                    break
                if sim_rc is None:
                    sim_rc = self.pool.page_rc.copy()
                vrow = self.active[v].row
                sim_rc[vrow] -= 1
                free += int((sim_rc[vrow] == 0).sum())
                continue
            if k == 1:  # cannot happen: protected slots need no growth
                raise RuntimeError(
                    "unresolvable page pressure at chunk size 1 — "
                    "initial-allocation invariant violated")
            cap = 1
        if not decoding:
            return ChunkPlan(k=0, slots=[], victims=victims)
        grow = [(s, need[s]) for s in sorted(decoding) if need[s] > 0]
        return ChunkPlan(k=k, slots=sorted(decoding), victims=victims,
                         evict_nodes=evict_nodes, grow=grow)

    def preempt(self, slot: int) -> _Active:
        """Abort a running request and requeue it at the queue front: its
        pages release exactly like :meth:`finish` (the engine pairs this
        with the device release program) and its produced tokens are
        discarded — on re-admission the per-request ``fold_in(uid, step)``
        sampling stream replays from step 0, so the restart is
        bit-identical to an uninterrupted run. The uid joins the
        protected set: it bypasses admission hysteresis and is never
        re-victimized before producing a token."""
        st = self.active.pop(slot)
        if self.prefix_cache is not None:
            self.prefix_cache.release(st.nodes)
        self.pool.page_rc[st.row] -= 1
        assert (self.pool.page_rc[st.row] >= 0).all()
        self.pool.push([p for p in st.row if self.pool.page_rc[p] == 0])
        self.free_slots.append(slot)
        self.free_slots.sort(reverse=True)
        self._preempted.add(st.req.uid)
        self._cooldown = self.policy.watermark is not None
        self.queue.appendleft(st.req)
        self.preemptions += 1
        return st

    def commit_grow(self, slot: int, n_new: int) -> tuple[np.ndarray, int]:
        """Pop ``n_new`` pages for a decoding slot's growth; returns the
        pages and the slot's previous page count (the device program
        appends at that row offset)."""
        st = self.active[slot]
        held = st.n_pages
        pages = self.pool.pop(n_new)
        st.row = np.concatenate([st.row, pages])
        st.n_pages += n_new
        assert st.n_pages <= st.target_pages
        return pages, held

    def advance_decode(self, k: int) -> None:
        """Mirror a completed k-step decode chunk: every decoding slot's
        device ``seq_lens`` advanced by ``k``."""
        for st in self.active.values():
            if not st.prefilling:
                st.seq += k

    # ------------------------------------------------------------------
    def record(self, slot: int, tokens) -> None:
        st = self.active[slot]
        st.tokens.extend(int(t) for t in tokens)
        st.produced += len(tokens)
        if tokens:
            st.protected = False  # livelock guard satisfied: a token landed

    def finish(self, slot: int) -> _Active:
        """Release the slot and the row's refcounts; pages whose count
        drops to zero return to the free stack (in row order — matching
        the device subset-push program). Returns the record."""
        st = self.active.pop(slot)
        self._inflight.discard(st.req.uid)
        if self.prefix_cache is not None:
            self.prefix_cache.release(st.nodes)
        self.pool.page_rc[st.row] -= 1
        assert (self.pool.page_rc[st.row] >= 0).all()
        self.pool.push([p for p in st.row if self.pool.page_rc[p] == 0])
        self.free_slots.append(slot)
        self.free_slots.sort(reverse=True)
        return st

    # ------------------------------------------------------------------
    # Loop predicates
    # ------------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.active)

    def remaining(self, slot: int) -> int:
        st = self.active[slot]
        return st.req.max_new - st.produced

    def min_remaining(self) -> int:
        return min(self.remaining(s) for s in self.active)
