"""Block-granular prefix cache over the paged KV pool.

Production traffic concentrates on a handful of system prompts; the
paged engine's block tables make sharing their KV a ref-count away. The
cache is a host-side radix map over *full* token blocks: block ``i`` of
a prompt is keyed by the chained digest ``H(key_{i-1} || tokens_i)``, so
a key commits to the entire block-aligned prefix, and the longest cached
chain for a new prompt is a walk from the root. Only full, immutable
blocks are ever cached — the tail page a request is still appending into
is always private (copy-on-write at admit for a fully-cached prompt), so
``_append_kv_page_quant``'s grow-only scale rescale can never corrupt
another reader.

Why sharing is *exact* for int8 pages: the page is the quantization
tile (per-(page, kv-head) scales — the attention analogue of the paper's
Eq. 22 tile), so a cached page's codes+scale are one immutable value
every reader dequantizes identically. See docs/datapath.md and the
"Prefix cache" section of docs/serving_scheduler.md.

Ownership is counted in pages: ``page_rc[p]`` = number of live block
table rows containing ``p``, plus one while the cache itself holds ``p``.
The cache's host-side bookkeeping here pairs with the device-side
``page_refcounts`` leaf (``init_paged_cache``) kept in lockstep by the
engine's admit/release programs.

Eviction is LRU leaf-first and **all-or-nothing**: nodes are evictable
only with no active readers and no cached children (a child's chain
would break if an ancestor vanished), and an admission either finds its
full shortage among evictable nodes or leaves the cache untouched — a
stalled admission never mutates anything (the scheduler property tests
rely on this).
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass

import numpy as np

_DIGEST_SIZE = 16


def block_digests(prompt, block_size: int) -> list[bytes]:
    """Chained blake2b digests of the prompt's *full* token blocks.

    ``digests[i]`` commits to tokens ``[0, (i+1) * block_size)`` — the
    whole aligned prefix, not just block ``i`` — so equal keys imply
    equal prefixes (up to hash collision) and the radix walk needs no
    token re-comparison. The ragged tail (``len % block_size`` tokens)
    is never hashed: partial blocks are never cached.
    """
    prompt = np.asarray(prompt, np.int32).reshape(-1)
    out: list[bytes] = []
    h = b""
    for i in range(prompt.size // block_size):
        block = prompt[i * block_size:(i + 1) * block_size].tobytes()
        h = hashlib.blake2b(h + block, digest_size=_DIGEST_SIZE).digest()
        out.append(h)
    return out


@dataclass
class _Node:
    """One cached full block: ``page`` is the physical pool page holding
    its KV; ``readers`` counts live requests whose block table includes
    that page via this node (matchers and the inserting request alike);
    ``n_children`` guards interior nodes from eviction; ``tick`` is the
    LRU clock."""

    key: bytes
    parent: bytes | None
    page: int
    readers: int = 0
    n_children: int = 0
    tick: int = 0


class PrefixCache:
    def __init__(self, num_blocks: int, block_size: int):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.nodes: dict[bytes, _Node] = {}
        self._tick = 0
        #: block-granular stats: ``hits / lookups`` is the hit rate the
        #: serving benchmark reports as ``prefix_cache.hit_rate``
        self.lookups = 0
        self.hits = 0

    # ------------------------------------------------------------------
    # Queries (pure — safe to call from a stalled admission)
    # ------------------------------------------------------------------
    @property
    def pages_held(self) -> int:
        return len(self.nodes)

    def match(self, prompt) -> list[_Node]:
        """Longest cached chain of full blocks covering the prompt, as a
        root-first node list. Pure peek: no ticks, readers or stats move
        (commit via :meth:`acquire` once the admission is certain)."""
        matched = []
        for key in block_digests(prompt, self.block_size):
            node = self.nodes.get(key)
            if node is None:
                break
            matched.append(node)
        return matched

    def plan_evict(self, shortage: int, protect: set[bytes]):
        """Pick ``shortage`` LRU evictable nodes (readers == 0, no cached
        children, not in ``protect``), cascading leaf-first so a cold
        subtree can be cleared within one plan. Returns the node list, or
        ``None`` when the shortage cannot be fully covered (all-or-
        nothing: the caller must then stall without evicting)."""
        if shortage <= 0:
            return []
        plan: list[_Node] = []
        gone: set[bytes] = set()
        n_children = {}  # simulated child counts under the plan
        while len(plan) < shortage:
            best = None
            for node in self.nodes.values():
                if node.key in gone or node.key in protect or node.readers:
                    continue
                if n_children.get(node.key, node.n_children):
                    continue
                if best is None or node.tick < best.tick:
                    best = node
            if best is None:
                return None
            plan.append(best)
            gone.add(best.key)
            if best.parent is not None:
                parent = self.nodes[best.parent]
                n_children[parent.key] = (
                    n_children.get(parent.key, parent.n_children) - 1)
        return plan

    # ------------------------------------------------------------------
    # Mutations (commit side of an admission / release)
    # ------------------------------------------------------------------
    def acquire(self, matched: list[_Node], n_lookup_blocks: int) -> None:
        """Commit a match: bump readers + LRU ticks and record stats
        (``n_lookup_blocks`` = the prompt's full-block count)."""
        self._tick += 1
        for node in matched:
            node.readers += 1
            node.tick = self._tick
        self.lookups += n_lookup_blocks
        self.hits += len(matched)

    def touch(self, node: _Node) -> None:
        """LRU bump without a reader (the full-hit tail node: its page is
        copied at admit, not referenced afterwards)."""
        self._tick += 1
        node.tick = self._tick

    def insert(self, prompt, row: np.ndarray, start_block: int,
               skip_existing: bool = False) -> list[_Node]:
        """Register the prompt's full blocks ``start_block ..`` (freshly
        prefilled into physical pages ``row[start_block + i]``) as cached,
        with the inserting request as first reader. Returns the new nodes
        (the caller releases their readers at finish).

        ``skip_existing`` tolerates blocks another request cached between
        planning and insertion (the chunked-prefill deferred insert: the
        inserter matched nothing at admit because its own blocks were not
        yet written, but an identical concurrent prompt may have won the
        race) — existing nodes are left untouched, the inserter's row
        simply keeps its private duplicate pages for those blocks."""
        digests = block_digests(prompt, self.block_size)
        self._tick += 1
        created = []
        for i in range(start_block, len(digests)):
            key = digests[i]
            if key in self.nodes:
                assert skip_existing, "insert over an existing node"
                continue
            parent = digests[i - 1] if i else None
            if parent is not None:
                # the parent is always resident: either matched/skipped
                # (in the map already) or created earlier in this loop
                self.nodes[parent].n_children += 1
            node = _Node(key=key, parent=parent, page=int(row[i]),
                         readers=1, tick=self._tick)
            self.nodes[key] = node
            created.append(node)
        return created

    def release(self, nodes: list[_Node]) -> None:
        for node in nodes:
            node.readers -= 1
            assert node.readers >= 0

    def evict(self, plan: list[_Node]) -> None:
        """Drop a :meth:`plan_evict` plan from the map (page pushes happen
        in the scheduler/engine, which own the refcounts)."""
        for node in plan:
            assert node.readers == 0 and node.key in self.nodes
            del self.nodes[node.key]
            if node.parent is not None and node.parent in self.nodes:
                self.nodes[node.parent].n_children -= 1

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        return {
            "lookups": self.lookups,
            "hits": self.hits,
            "hit_rate": self.hits / self.lookups if self.lookups else 0.0,
            "pages_held": self.pages_held,
        }
