"""AdamW with decoupled weight decay, global-norm clipping and warmup+cosine
schedule — pure-pytree, shardable (moments inherit the parameter shardings,
i.e. ZeRO-style partitioned optimizer state for free under pjit).

Moments are kept in fp32 regardless of parameter dtype (bf16 training keeps
fp32 statistics; this is the usual large-scale recipe).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"


def lr_at_step(cfg: OptimizerConfig, step) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_ratio * lr."""
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(math.pi * t)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params, cfg: OptimizerConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    def zeros(p):
        return jnp.zeros(p.shape, dt)

    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(params, grads, state, cfg: OptimizerConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = lr_at_step(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(m.dtype)
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * jnp.square(gf)
        m_hat = m_new / bc1
        v_hat = v_new / bc2
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps) + cfg.weight_decay * p.astype(m.dtype)
        p_new = (p.astype(jnp.float32) - lr * delta.astype(jnp.float32)).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
