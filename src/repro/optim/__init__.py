from .adamw import (
    OptimizerConfig,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    init_opt_state,
    lr_at_step,
)

__all__ = [
    "OptimizerConfig",
    "adamw_update",
    "clip_by_global_norm",
    "global_norm",
    "init_opt_state",
    "lr_at_step",
]
