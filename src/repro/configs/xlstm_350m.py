"""xlstm-350m [ssm]: 24L d_model=1024 4H d_ff=0 vocab=50304.

sLSTM + mLSTM blocks [arXiv:2405.04517; unverified], 1:7 sLSTM:mLSTM mix.
d_ff == 0: xLSTM blocks carry their own up/down projections (ffn = none).
Sub-quadratic: runs the long_500k cell (state is O(d_head^2), not O(S)).
"""

from repro.models.config import ModelConfig, XLSTMConfig, xlstm_pattern

ARCH_ID = "xlstm-350m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        n_layers=24,
        d_model=1024,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab=50304,
        pattern=xlstm_pattern(period=8, slstm_at=0),
        xlstm=XLSTMConfig(mlstm_expand=2, mlstm_heads=4, slstm_heads=4, chunk=64),
        max_seq_len=524_288,
        param_dtype="bfloat16",
        act_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return config().scaled(
        name=ARCH_ID + "-smoke",
        n_layers=8,
        d_model=64,
        n_heads=2,
        n_kv_heads=2,
        vocab=128,
        xlstm=XLSTMConfig(mlstm_expand=2, mlstm_heads=2, slstm_heads=2, chunk=8),
        max_seq_len=64,
        param_dtype="float32",
        act_dtype="float32",
    )
