"""musicgen-medium [audio]: 48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048.

Decoder-only transformer over EnCodec tokens [arXiv:2306.05284; hf]. The
EnCodec frontend is a STUB per the assignment: inputs are precomputed codec
token ids (vocab 2048); text-conditioning cross-attention is out of scope for
the backbone cells and omitted (noted in DESIGN.md).
"""

from repro.models.config import ModelConfig, uniform_pattern

ARCH_ID = "musicgen-medium"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="audio",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,
        d_ff=6144,
        vocab=2048,
        pattern=uniform_pattern("attn", "mlp"),
        norm="layernorm",
        act="gelu",
        frontend="audio_stub",
        max_seq_len=32_768,
        param_dtype="bfloat16",
        act_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return config().scaled(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=128,
        max_seq_len=64,
        param_dtype="float32",
        act_dtype="float32",
    )
