"""Assigned input-shape set (one per LM-family cell) and the ShapeDtypeStruct
stand-ins consumed by the dry-run (no device allocation).

  train_4k     seq 4,096   x global_batch 256   (training)
  prefill_32k  seq 32,768  x global_batch 32    (inference prefill)
  decode_32k   seq 32,768  x global_batch 128   (decode: 1 token, 32k cache)
  long_500k    seq 524,288 x global_batch 1     (long-context decode;
               sub-quadratic families only — see DESIGN.md §4)
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import init_cache

TRAIN, PREFILL, DECODE = "train", "prefill", "decode"


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, TRAIN),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, PREFILL),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, DECODE),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, DECODE),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> bool:
    """long_500k runs only for sub-quadratic (SSM/hybrid/linear-attn)
    families — the skip for pure full-attention archs is recorded in
    DESIGN.md §4. All assigned archs are decoder-style, so decode shapes
    apply everywhere else."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStructs for the *data* inputs of the step function."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in (TRAIN, PREFILL):
        specs = {}
        s_text = s
        if cfg.frontend == "vision_stub" and cfg.frontend_tokens:
            s_text = s - cfg.frontend_tokens
            specs["pixel_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_tokens, cfg.d_model), jnp.dtype(cfg.act_dtype)
            )
        specs["tokens"] = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
        return specs
    # decode: one new token against a seq_len-deep cache
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def cache_specs(cfg: ModelConfig, shape: ShapeSpec):
    assert shape.kind == DECODE
    return init_cache(cfg, shape.global_batch, shape.seq_len, abstract=True)
