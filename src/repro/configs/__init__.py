"""Architecture registry: the 10 assigned archs + paper-experiment models.

``get_config("<arch-id>")`` / ``get_smoke("<arch-id>")`` accept the dashed
public ids. Every entry is a plain :class:`repro.models.config.ModelConfig`
— selectable from every launcher via ``--arch``.
"""

from __future__ import annotations

from repro.models.config import ModelConfig

from . import (
    dbrx_132b,
    granite_moe_3b_a800m,
    internvl2_76b,
    jamba_1_5_large_398b,
    llama3_405b,
    musicgen_medium,
    phi4_mini_3_8b,
    smollm_360m,
    stablelm_3b,
    xlstm_350m,
)
from .paper import PAPER_MODELS
from .shapes import SHAPES, ShapeSpec, applicable, batch_specs, cache_specs

_MODULES = (
    musicgen_medium,
    xlstm_350m,
    stablelm_3b,
    smollm_360m,
    llama3_405b,
    phi4_mini_3_8b,
    granite_moe_3b_a800m,
    dbrx_132b,
    internvl2_76b,
    jamba_1_5_large_398b,
)

REGISTRY = {m.ARCH_ID: m for m in _MODULES}
ARCH_IDS = tuple(REGISTRY)


def get_config(arch: str) -> ModelConfig:
    if arch in REGISTRY:
        return REGISTRY[arch].config()
    if arch in PAPER_MODELS:
        return PAPER_MODELS[arch]()
    raise KeyError(f"unknown arch {arch!r}; known: {sorted(REGISTRY) + sorted(PAPER_MODELS)}")


def get_smoke(arch: str) -> ModelConfig:
    if arch in REGISTRY:
        return REGISTRY[arch].smoke()
    raise KeyError(f"unknown arch {arch!r}")


__all__ = [
    "ARCH_IDS",
    "REGISTRY",
    "SHAPES",
    "ShapeSpec",
    "applicable",
    "batch_specs",
    "cache_specs",
    "get_config",
    "get_smoke",
]
