"""smollm-360m [dense]: 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.

LLaMA-architecture small model [hf:HuggingFaceTB/SmolLM-135M; hf]:
RMSNorm + SwiGLU + RoPE, 3-way grouped-query attention.
"""

from repro.models.config import ModelConfig, uniform_pattern

ARCH_ID = "smollm-360m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=32,
        d_model=960,
        n_heads=15,
        n_kv_heads=5,
        d_ff=2560,
        vocab=49152,
        pattern=uniform_pattern("attn", "mlp"),
        max_seq_len=32_768,
        param_dtype="bfloat16",
        act_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return config().scaled(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=60,
        n_heads=3,
        n_kv_heads=1,
        d_ff=128,
        vocab=128,
        max_seq_len=64,
        param_dtype="float32",
        act_dtype="float32",
    )
