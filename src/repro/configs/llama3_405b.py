"""llama3-405b [dense]: 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256 [arXiv:2407.21783; unverified]. RoPE theta 500k, 128k vocab.

The heaviest dense cell in the pool: train_4k at global_batch 256 requires
microbatched gradient accumulation + per-block remat (see launch/train.py
defaults) and FSDP+TP sharding to fit v5e HBM.
"""

from repro.models.config import ModelConfig, uniform_pattern

ARCH_ID = "llama3-405b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=126,
        d_model=16384,
        n_heads=128,
        n_kv_heads=8,
        d_ff=53248,
        vocab=128256,
        pattern=uniform_pattern("attn", "mlp"),
        rope_theta=500_000.0,
        max_seq_len=32_768,
        param_dtype="bfloat16",
        act_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return config().scaled(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=128,
        n_heads=8,
        n_kv_heads=2,
        d_ff=256,
        vocab=256,
        max_seq_len=64,
        param_dtype="float32",
        act_dtype="float32",
    )
