"""phi4-mini-3.8b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064 [arXiv:2412.08905; hf]. RoPE + SwiGLU + GQA; the 200k vocab
makes the embedding/head the sharding-critical tensors.
"""

from repro.models.config import ModelConfig, uniform_pattern

ARCH_ID = "phi4-mini-3.8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=8192,
        vocab=200064,
        pattern=uniform_pattern("attn", "mlp"),
        max_seq_len=32_768,
        param_dtype="bfloat16",
        act_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return config().scaled(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=96,
        n_heads=6,
        n_kv_heads=2,
        d_ff=192,
        vocab=256,
        max_seq_len=64,
        param_dtype="float32",
        act_dtype="float32",
    )
