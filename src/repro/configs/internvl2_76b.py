"""internvl2-76b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — InternViT + InternLM2 [arXiv:2404.16821; unverified].

Per the assignment, the entry specifies the transformer BACKBONE
(InternLM2-76B-class); the InternViT frontend is a STUB — ``input_specs()``
supplies 256 precomputed patch embeddings per sample, prepended to the text
sequence, and the loss is masked over the image prefix.
"""

from repro.models.config import ModelConfig, uniform_pattern

ARCH_ID = "internvl2-76b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=28672,
        vocab=128256,
        pattern=uniform_pattern("attn", "mlp"),
        frontend="vision_stub",
        frontend_tokens=256,
        max_seq_len=32_768,
        param_dtype="bfloat16",
        act_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return config().scaled(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=128,
        frontend_tokens=8,
        max_seq_len=64,
        param_dtype="float32",
        act_dtype="float32",
    )
