"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16 experts top-4, fine-grained [hf:databricks/dbrx-base; unverified].

16 experts divide the 16-way data axis exactly — the canonical
expert-parallel cell (1 expert per data-mesh row, TP over model inside).
"""

from repro.models.config import ModelConfig, MoEConfig, uniform_pattern

ARCH_ID = "dbrx-132b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=10752,
        vocab=100352,
        pattern=uniform_pattern("attn", "moe"),
        moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752, group_size=1024),
        max_seq_len=32_768,
        param_dtype="bfloat16",
        act_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return config().scaled(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=64,
        vocab=128,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64, group_size=64),
        max_seq_len=64,
        param_dtype="float32",
        act_dtype="float32",
    )
