"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16 experts top-2, Mamba:attention 1:7 interleave
[arXiv:2403.19887; hf].

Pattern period 8 (attention at slot 4, Mamba elsewhere; MoE every other
layer) repeated 9x. Sub-quadratic: runs the long_500k cell — Mamba state is
O(d_state * d_in) per layer and only 9 attention layers carry a KV cache.
"""

from repro.models.config import ModelConfig, MoEConfig, SSMConfig, jamba_pattern

ARCH_ID = "jamba-1.5-large-398b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=24576,
        vocab=65536,
        pattern=jamba_pattern(period=8, attn_at=4),
        moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576, group_size=1024),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
        max_seq_len=524_288,
        param_dtype="bfloat16",
        act_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return config().scaled(
        name=ARCH_ID + "-smoke",
        n_layers=8,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=128,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128, group_size=64),
        ssm=SSMConfig(d_state=4, d_conv=4, expand=2),
        max_seq_len=64,
        param_dtype="float32",
        act_dtype="float32",
    )
