"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

Fine-grained MoE: every FFN is a 40-expert top-8 layer with small (512)
expert hidden size. 40 experts do not divide the 16-way data axis, so the
sharding rules fall back to replicated-expert + TP-inside-expert (see
DESIGN.md §5) — exercising the divisibility-fallback path by design.
dispatch group_size is lowered to 256 to bound GShard dispatch overhead at
top_k=8.
"""

from repro.models.config import ModelConfig, MoEConfig, uniform_pattern

ARCH_ID = "granite-moe-3b-a800m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        d_ff=512,
        vocab=49155,
        pattern=uniform_pattern("attn", "moe"),
        moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512, group_size=256),
        max_seq_len=32_768,
        param_dtype="bfloat16",
        act_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return config().scaled(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=32,
        vocab=128,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, group_size=64),
        max_seq_len=64,
        param_dtype="float32",
        act_dtype="float32",
    )
