"""stablelm-3b [dense]: 32L d_model=2560 32H (kv=32) d_ff=6912 vocab=50304.

[hf:stabilityai/stablelm-2-1_6b; unverified]. Full multi-head attention
(kv == heads), LayerNorm + SwiGLU, RoPE.
"""

from repro.models.config import ModelConfig, uniform_pattern

ARCH_ID = "stablelm-3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=32,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=6912,
        vocab=50304,
        pattern=uniform_pattern("attn", "mlp"),
        norm="layernorm",
        act="swiglu",
        max_seq_len=32_768,
        param_dtype="bfloat16",
        act_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return config().scaled(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=128,
        max_seq_len=64,
        param_dtype="float32",
        act_dtype="float32",
    )
