"""Models for reproducing the paper's own experiments (§4) at a scale this
box can *train and evaluate* (no pretrained Pythia/OPT/GPT2 checkpoints are
available offline — see DESIGN.md §8).

``tiny-lm-*`` is a Pythia-style ladder (parallel-free decoder, GQA, SwiGLU)
used by the benchmark harness: each rung is trained on the deterministic
synthetic corpus (repro.data) and then PTQ'd, reproducing the paper's
orderings (AXE vs EP-init vs naive; multi-stage vs monolithic scaling).
Widths grow with depth held constant, matching the paper's §4.2 argument that
l1 mass grows with *width* (K), which is what the accumulator constraint
feels.
"""

from __future__ import annotations

from repro.models.config import (
    LayerSpec,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    XLSTMConfig,
    uniform_pattern,
)


def _tiny(name: str, d_model: int, n_layers: int, d_ff: int, heads: int) -> ModelConfig:
    return ModelConfig(
        name=name,
        family="dense",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=heads,
        n_kv_heads=heads,
        d_ff=d_ff,
        vocab=512,
        pattern=uniform_pattern("attn", "mlp"),
        max_seq_len=256,
        param_dtype="float32",
        act_dtype="float32",
        remat="none",
    )


def _tiny_moe() -> ModelConfig:
    """4-expert top-2 MoE at tiny-lm-xs width: the expert-stacked (E, K, C)
    PTQ path end-to-end."""
    return _tiny("tiny-moe", 64, 2, 128, 4).scaled(
        family="moe",
        pattern=uniform_pattern("attn", "moe"),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128),
    )


def _tiny_ssm() -> ModelConfig:
    """2-layer Mamba-1 stack (no FFN blocks, as in the original arch)."""
    return _tiny("tiny-ssm", 64, 2, 128, 4).scaled(
        family="ssm",
        pattern=uniform_pattern("mamba", "none"),
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2),
    )


def _tiny_xlstm() -> ModelConfig:
    """2-layer xLSTM with one mLSTM and one sLSTM block (period-2 pattern)."""
    return _tiny("tiny-xlstm", 64, 2, 128, 4).scaled(
        family="xlstm",
        pattern=(LayerSpec("mlstm", "none"), LayerSpec("slstm", "none")),
        xlstm=XLSTMConfig(mlstm_expand=2, mlstm_heads=4, slstm_heads=4, chunk=32),
    )


def _tiny_hybrid() -> ModelConfig:
    """Jamba-flavored period-2 hybrid (mamba+mlp, attn+moe): exercises
    adapter composition across families inside one stack."""
    return _tiny("tiny-hybrid", 64, 2, 128, 4).scaled(
        family="hybrid",
        pattern=(LayerSpec("mamba", "mlp"), LayerSpec("attn", "moe")),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128),
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2),
    )


PAPER_MODELS = {
    # width ladder (K doubles each rung) for the Table 1/3 scaling study
    "tiny-lm-xs": lambda: _tiny("tiny-lm-xs", 64, 4, 192, 4),
    "tiny-lm-s": lambda: _tiny("tiny-lm-s", 128, 4, 384, 4),
    "tiny-lm-m": lambda: _tiny("tiny-lm-m", 256, 4, 768, 8),
    "tiny-lm-l": lambda: _tiny("tiny-lm-l", 512, 4, 1536, 8),
    # per-family PTQ coverage rungs (quant families registry e2e)
    "tiny-moe": _tiny_moe,
    "tiny-ssm": _tiny_ssm,
    "tiny-xlstm": _tiny_xlstm,
    "tiny-hybrid": _tiny_hybrid,
}
