"""Models for reproducing the paper's own experiments (§4) at a scale this
box can *train and evaluate* (no pretrained Pythia/OPT/GPT2 checkpoints are
available offline — see DESIGN.md §8).

``tiny-lm-*`` is a Pythia-style ladder (parallel-free decoder, GQA, SwiGLU)
used by the benchmark harness: each rung is trained on the deterministic
synthetic corpus (repro.data) and then PTQ'd, reproducing the paper's
orderings (AXE vs EP-init vs naive; multi-stage vs monolithic scaling).
Widths grow with depth held constant, matching the paper's §4.2 argument that
l1 mass grows with *width* (K), which is what the accumulator constraint
feels.
"""

from __future__ import annotations

from repro.models.config import ModelConfig, uniform_pattern


def _tiny(name: str, d_model: int, n_layers: int, d_ff: int, heads: int) -> ModelConfig:
    return ModelConfig(
        name=name,
        family="dense",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=heads,
        n_kv_heads=heads,
        d_ff=d_ff,
        vocab=512,
        pattern=uniform_pattern("attn", "mlp"),
        max_seq_len=256,
        param_dtype="float32",
        act_dtype="float32",
        remat="none",
    )


PAPER_MODELS = {
    # width ladder (K doubles each rung) for the Table 1/3 scaling study
    "tiny-lm-xs": lambda: _tiny("tiny-lm-xs", 64, 4, 192, 4),
    "tiny-lm-s": lambda: _tiny("tiny-lm-s", 128, 4, 384, 4),
    "tiny-lm-m": lambda: _tiny("tiny-lm-m", 256, 4, 768, 8),
    "tiny-lm-l": lambda: _tiny("tiny-lm-l", 512, 4, 1536, 8),
}
