"""Gradient compression for the slow cross-pod (DCN) axis.

At 1000+ node scale the intra-pod ICI reductions are fast but the cross-pod
all-reduce rides the data-center network; int8 compression cuts those bytes
4x (vs fp32) at negligible quality cost for gradient averaging. Implemented
as a partial-manual ``shard_map``: manual over the ``pod`` axis only, with
the ``data``/``model`` axes left to the SPMD partitioner (``auto``), so it
composes with FSDP/TP shardings unchanged.

Each leaf is scaled by its global absmax (psum-max over pods), quantized to
int8, summed in int32, and dequantized — a standard stochastic-free
uniform compressor (error feedback is deliberately omitted: gradient
*averages* tolerate 8-bit rounding; see tests/test_compression.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp



def shard_map_compat(f, mesh, in_specs, out_specs, manual_axes: frozenset):
    """Partial-manual shard_map across jax API generations: ``jax.shard_map``
    (axis_names = the manual set, check_vma) on new jax, the experimental
    ``shard_map`` (auto = the complement, check_rep) on older releases."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False, axis_names=manual_axes,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    from .sharding import declared_manual_axes

    def f_marked(*args):
        # old jax's abstract mesh carries no AxisType: declare the manual
        # axes explicitly so logical constraints inside the region drop them
        with declared_manual_axes(manual_axes):
            return f(*args)

    return _shard_map(
        f_marked, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False, auto=frozenset(mesh.axis_names) - manual_axes,
    )

def int8_psum(tree, axis_name: str):
    """Compressed psum of a pytree over ``axis_name`` (inside shard_map)."""

    def one(g):
        gf = g.astype(jnp.float32)
        absmax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
        scale = jnp.maximum(absmax, 1e-30) / 127.0
        q = jnp.clip(jnp.rint(gf / scale), -127, 127).astype(jnp.int8)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return (total.astype(jnp.float32) * scale).astype(g.dtype)

    return jax.tree.map(one, tree)


def compressed_grad_fn(grad_fn, mesh, batch_spec_fn):
    """Wrap ``grad_fn(params, batch) -> (aux, grads)`` so the cross-pod
    gradient reduction goes through :func:`int8_psum`.

    Only valid when the mesh has a ``pod`` axis; parameters must not be
    sharded over it (they are not — see runtime.sharding rules).
    """
    from jax.sharding import PartitionSpec as P

    if "pod" not in mesh.shape:
        return grad_fn

    def inner(params, batch):
        aux, grads = grad_fn(params, batch)
        grads = int8_psum(grads, "pod")
        n = jax.lax.psum(1, "pod")
        grads = jax.tree.map(lambda g: g / n, grads)
        aux = jax.tree.map(lambda x: jax.lax.pmean(x, "pod"), aux)
        return aux, grads

    def wrapped(params, batch):
        batch_specs = jax.tree.map(lambda _: P("pod"), batch)
        return shard_map_compat(
            inner,
            mesh,
            (jax.tree.map(lambda _: P(), params), batch_specs),
            P(),
            frozenset({"pod"}),
        )(params, batch)

    return wrapped
