"""Jitted distributed step functions: train (grad-accumulated, compressed,
donated), prefill and decode — the functions the dry-run lowers and the
launchers execute.

All sharding is derived from :mod:`repro.runtime.sharding` rules; the same
builders serve a single CPU device (tests), the 16x16 single-pod mesh and
the 2x16x16 multi-pod mesh (dry-run / production).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.transformer import (
    decode_step as _decode_step,
    init_model,
    loss_fn,
    prefill as _prefill,
)
from repro.optim import OptimizerConfig, adamw_update, init_opt_state
from .compression import compressed_grad_fn
from .sharding import (
    axis_rules,
    batch_shardings,
    cache_shardings,
    param_shardings,
    replicated,
    set_mesh,
)


@dataclass(frozen=True)
class TrainRunConfig:
    optimizer: OptimizerConfig = OptimizerConfig()
    num_microbatches: int = 1
    grad_compression: str = "none"  # none | int8-pod
    accum_dtype: str = "float32"  # gradient accumulation dtype


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------
def init_train_state(key, cfg: ModelConfig, run: TrainRunConfig):
    params = init_model(key, cfg)
    return {"params": params, "opt": init_opt_state(params, run.optimizer)}


def abstract_train_state(cfg: ModelConfig, run: TrainRunConfig, seed: int = 0):
    return jax.eval_shape(
        lambda k: init_train_state(k, cfg, run), jax.random.key(seed)
    )


def train_state_shardings(state, mesh: Mesh):
    return {
        "params": param_shardings(state["params"], mesh),
        "opt": {
            "m": param_shardings(state["opt"]["m"], mesh),
            "v": param_shardings(state["opt"]["v"], mesh),
            "step": replicated(mesh),
        },
    }


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------
def _split_microbatches(batch, n_mb: int):
    def resh(x):
        b = x.shape[0]
        if b % n_mb:
            raise ValueError(f"global batch {b} not divisible by microbatches {n_mb}")
        return x.reshape(n_mb, b // n_mb, *x.shape[1:])

    return jax.tree.map(resh, batch)


def make_train_step(cfg: ModelConfig, run: TrainRunConfig, mesh: Mesh | None = None):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def grad_fn(params, mb):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, mb, cfg
        )
        return dict(metrics, loss=loss), grads

    def accumulate(params, batch):
        if run.num_microbatches <= 1:
            return grad_fn(params, batch)
        mbs = _split_microbatches(batch, run.num_microbatches)
        acc_dt = jnp.dtype(run.accum_dtype)

        def body(carry, mb):
            g_acc, m_acc = carry
            metrics, grads = grad_fn(params, mb)
            g_acc = jax.tree.map(lambda a, g: a + g.astype(acc_dt), g_acc, grads)
            m_acc = jax.tree.map(lambda a, m: a + m, m_acc, metrics)
            return (g_acc, m_acc), None

        g0 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, acc_dt), params
        )
        m0 = {
            "loss": jnp.zeros((), jnp.float32),
            "ce": jnp.zeros((), jnp.float32),
            "aux": jnp.zeros((), jnp.float32),
            "ppl": jnp.zeros((), jnp.float32),
        }
        (g_acc, m_acc), _ = jax.lax.scan(body, (g0, m0), mbs)
        inv = 1.0 / run.num_microbatches
        grads = jax.tree.map(lambda g, p: (g * inv).astype(p.dtype), g_acc, params)
        metrics = jax.tree.map(lambda m: m * inv, m_acc)
        return metrics, grads

    reducer = accumulate
    if run.grad_compression == "int8-pod" and mesh is not None:
        reducer = compressed_grad_fn(accumulate, mesh, None)

    def train_step(state, batch):
        metrics, grads = reducer(state["params"], batch)
        params, opt, opt_metrics = adamw_update(
            state["params"], grads, state["opt"], run.optimizer
        )
        metrics.update(opt_metrics)
        return {"params": params, "opt": opt}, metrics

    return train_step


def lower_train_step(
    cfg: ModelConfig,
    run: TrainRunConfig,
    mesh: Mesh,
    batch_spec: dict,
):
    """Shard + lower the train step on ``mesh`` (dry-run and launcher path)."""
    state = abstract_train_state(cfg, run)
    state_sh = train_state_shardings(state, mesh)
    batch_sh = batch_shardings(batch_spec, mesh)
    step = make_train_step(cfg, run, mesh)

    def wrapped(state, batch):
        with axis_rules(mesh):
            return step(state, batch)

    jitted = jax.jit(
        wrapped,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, replicated(mesh)),
        donate_argnums=(0,),
    )
    with set_mesh(mesh):
        lowered = jitted.lower(state, batch_spec)
    return jitted, lowered, (state, state_sh, batch_sh)


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ModelConfig, max_len: int, mesh: Mesh | None = None):
    def prefill_step(params, batch):
        with axis_rules(mesh) if mesh is not None else _null():
            return _prefill(params, batch, cfg, max_len)

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh: Mesh | None = None):
    def decode(params, tokens, cache, index):
        with axis_rules(mesh) if mesh is not None else _null():
            return _decode_step(params, tokens, cache, index, cfg)

    return decode


def lower_prefill_step(cfg: ModelConfig, mesh: Mesh, batch_spec: dict, max_len: int):
    from repro.models.transformer import abstract_params

    params = abstract_params(cfg)
    params_sh = param_shardings(params, mesh)
    batch_sh = batch_shardings(batch_spec, mesh)
    step = make_prefill_step(cfg, max_len, mesh)
    jitted = jax.jit(step, in_shardings=(params_sh, batch_sh))
    with set_mesh(mesh):
        lowered = jitted.lower(params, batch_spec)
    return jitted, lowered, (params, params_sh)


def lower_decode_step(
    cfg: ModelConfig, mesh: Mesh, batch_spec: dict, cache_spec,
    quantized: bool = False,
):
    from repro.models.transformer import abstract_params

    params = abstract_params(cfg)
    if quantized:  # W4A8 packed-weight serving artifact (§Perf-3)
        from repro.quant.serve_packed import pack_decode_params
        from .sharding import SERVING_QUANT_RULES

        params = jax.eval_shape(lambda p: pack_decode_params(p, cfg), params)
        params_sh = param_shardings(params, mesh, SERVING_QUANT_RULES)
    else:
        params_sh = param_shardings(params, mesh)
    tokens_spec = batch_spec["tokens"]
    tokens_sh = batch_shardings({"tokens": tokens_spec}, mesh)["tokens"]
    cache_sh = cache_shardings(cache_spec, cfg, mesh)
    index_spec = jax.ShapeDtypeStruct((), jnp.int32)
    step = make_decode_step(cfg, mesh)
    from .sharding import DEFAULT_RULES, resolve_spec

    b = tokens_spec.shape[0]
    logits_shape = (b, 1, cfg.vocab)
    logits_sh = NamedSharding(
        mesh, resolve_spec(logits_shape, ("batch", None, "vocab"), mesh, DEFAULT_RULES)
    )
    jitted = jax.jit(
        step,
        in_shardings=(params_sh, tokens_sh, cache_sh, replicated(mesh)),
        out_shardings=(logits_sh, cache_sh),
        donate_argnums=(2,),
    )
    with set_mesh(mesh):
        lowered = jitted.lower(params, tokens_spec, cache_spec, index_spec)
    return jitted, lowered, (params, params_sh, cache_sh)


class _null:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
