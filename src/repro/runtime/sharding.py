"""Logical-axis sharding rules (DP/FSDP/TP/EP/SP) with divisibility fallbacks.

The model code annotates activations with *logical* axis names and the
parameter pytree is matched by leaf name; this module resolves both to
``NamedSharding``s on whatever mesh is active. Every resolution checks
divisibility (tensor dim % product of mesh axis sizes) and silently drops
the annotation when it does not divide — the degrade-gracefully property
that lets one set of rules serve 10 architectures and any mesh shape
(elastic restarts included).

Logical axes:
  batch   -> ("pod", "data")   pure data parallel (pod = DCN axis)
  expert  -> "data"            expert parallelism for MoE stacks
  model / heads / kv_heads / ffn / vocab -> "model"   tensor parallelism
  data_in -> "data"            FSDP-style weight sharding (row dim)
"""

from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "expert": ("data",),
    "data_in": ("data",),
    "model": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ffn": ("model",),
    "vocab": ("model",),
    "seq": ("model",),  # sequence parallelism (opt-in annotations)
}

# Serving rules for packed-int4 decode (§Perf-3): weights are 4x smaller so
# they fit *without* the FSDP dim — TP over both mesh axes, keeping weights
# stationary (no per-token all-gather; activations, which are tiny at
# decode, move instead).
SERVING_QUANT_RULES: dict[str, tuple[str, ...]] = {
    **DEFAULT_RULES,
    "data_in": (),
    "model": ("model", "data"),
    "heads": ("model", "data"),
    "kv_heads": ("model",),
    "ffn": ("model", "data"),
    "vocab": ("model", "data"),
}

_state = threading.local()


def _current() -> tuple[Mesh, dict] | None:
    return getattr(_state, "active", None)


@contextmanager
def axis_rules(mesh: Mesh, rules: dict[str, tuple[str, ...]] | None = None):
    """Activate logical-axis resolution against ``mesh`` for model code."""
    prev = _current()
    _state.active = (mesh, rules or DEFAULT_RULES)
    try:
        yield
    finally:
        _state.active = prev


def set_mesh(mesh: Mesh):
    """Context manager activating ``mesh`` for jit/lower: ``jax.set_mesh``
    on new jax, the Mesh's own context manager on older releases."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


@contextmanager
def declared_manual_axes(axes: frozenset):
    """Explicitly mark mesh axes as manual for the enclosed trace — the
    fallback for jax releases whose abstract mesh carries no AxisType
    (see runtime.compression.shard_map_compat)."""
    prev = getattr(_state, "manual", frozenset())
    _state.manual = prev | axes
    try:
        yield
    finally:
        _state.manual = prev


def _manual_axes() -> frozenset:
    """Mesh axes currently under manual (shard_map) control — they must not
    appear in sharding constraints issued from inside the region."""
    declared = getattr(_state, "manual", frozenset())
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is None or am.empty:
            return declared
        return declared | frozenset(
            n for n in am.axis_names
            if am._name_to_type[n] == jax.sharding.AxisType.Manual
        )
    except Exception:
        return declared


def _mesh_axes_for(logical: str | None, mesh: Mesh, rules: dict) -> tuple[str, ...]:
    if logical is None:
        return ()
    manual = _manual_axes()
    return tuple(
        a for a in rules.get(logical, ()) if a in mesh.shape and a not in manual
    )


def resolve_spec(shape: tuple[int, ...], names, mesh: Mesh, rules: dict) -> P:
    """Logical names -> PartitionSpec with per-dim divisibility fallback.

    A mesh axis consumed by an earlier dim is unavailable to later dims
    (PartitionSpec forbids reuse) — this is what makes compound rules like
    MoE ("expert", "data_in", ...) degrade to FSDP row-sharding exactly when
    the expert count does not divide the data axis (granite's 40 experts),
    and to expert-parallel when it does (dbrx's 16).
    """
    spec = []
    used: set[str] = set()
    for dim, name in zip(shape, names):
        axes = tuple(a for a in _mesh_axes_for(name, mesh, rules) if a not in used)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if axes and dim % size == 0 and dim > 0:
            spec.append(axes if len(axes) > 1 else axes[0])
            used.update(axes)
        else:
            spec.append(None)
    return P(*spec)


def prefer_expert_sharding(n_experts: int) -> bool:
    """True when the expert axis can actually shard ``n_experts`` on the
    active mesh (EP); False -> MoE activations stay token-sharded and the
    experts compute replicated-weightless via FSDP gathers (§Perf-2)."""
    active = _current()
    if active is None:
        return True
    mesh, rules = active
    axes = _mesh_axes_for("expert", mesh, rules)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return bool(axes) and size > 1 and n_experts % size == 0


def logical_constraint(x, names):
    """with_sharding_constraint by logical names; no-op without active rules."""
    active = _current()
    if active is None:
        return x
    mesh, rules = active
    if len(names) != x.ndim:
        raise ValueError(f"names {names} rank != array rank {x.ndim}")
    spec = resolve_spec(x.shape, names, mesh, rules)
    # inside a partial-manual shard_map the context abstract mesh carries
    # Manual axis types — shardings must be built against it, not the
    # outer concrete mesh, or broadcast/constraint ops reject the mix
    target = mesh
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and not am.empty:
            target = am
    except Exception:
        pass
    return jax.lax.with_sharding_constraint(x, NamedSharding(target, spec))


# ---------------------------------------------------------------------------
# Parameter sharding by leaf name
# ---------------------------------------------------------------------------
# 2D weights, (in, out) convention: name -> logical names per dim.
_W2 = {
    # row-parallel producers: input dim FSDP-sharded, output dim TP-sharded
    "wq": ("data_in", "model"),
    "wk": ("data_in", "model"),
    "wv": ("data_in", "model"),
    "wg": ("data_in", "model"),
    "wu": ("data_in", "model"),
    "wi": ("data_in", "model"),
    "up": ("data_in", "model"),
    "in_proj": ("data_in", "model"),
    "w_in": ("data_in", "model"),
    # column-parallel consumers: input dim TP-sharded, output dim FSDP-sharded
    "wo": ("model", "data_in"),
    "wd": ("model", "data_in"),
    "down": ("model", "data_in"),
    "out_proj": ("model", "data_in"),
    # vocab-parallel embeddings (rows = vocab)
    "embed": ("vocab", "data_in"),
    "head": ("vocab", "data_in"),
    # mamba inner projections (d_in is the TP dim)
    "x_proj": ("model", None),
    "dt_proj": (None, "model"),
    "A_log": ("model", None),
    "conv_w": (None, "model"),
    "router": (None, None),
}
# 3D MoE expert stacks: EP over data when the expert count divides it,
# otherwise (dedup/divisibility fallback in resolve_spec) FSDP row-sharding
# over data — replicated expert weights were the §Perf-2 baseline pathology
# (full-gradient all-reduce every microbatch).
_W3 = {
    "wg": ("expert", "data_in", "model"),
    "wu": ("expert", "data_in", "model"),
    "wi": ("expert", "data_in", "model"),
    "wd": ("expert", "model", "data_in"),
}
_W1 = {
    "conv_b": ("model",),
    "D": ("model",),
    "dt_bias": ("model",),
    "skip": ("model",),
    "f_bias": (None,),
    "norm_w": (None,),
    "w": (None,),
    "b": (None,),
}
_W4 = {
    "r": (None, None, None, "model"),  # sLSTM block-diag recurrent
}


_PACKED_LEAF_SUFFIXES = (
    "packed", "meta", "scale", "col_sums", "bias", "act_scale", "act_zp",
    "spec_arr",
)
#: packed-leaf members that are tiny per-site metadata (static activation
#: quantizer scalars, the serialized DatapathSpec twin): always replicated
_REPLICATED_SUFFIXES = ("act_scale", "act_zp", "spec_arr")


def _leaf_logical_names(path, leaf) -> tuple:
    keys = [e.key for e in path if hasattr(e, "key")]
    name = keys[-1] if keys else None
    # packed-int4 serving artifacts: {"packed", "meta", "scale", "col_sums",
    # "bias", "act_scale", "act_zp", "spec_arr"} under the weight name.
    # "meta" (2:4 sparse index leaf, (K//4, N)) co-shards with "packed":
    # both fall through to the weight-name table below, so a device holding
    # a shard of the codes holds the matching shard of the indices.
    suffix = None
    if name in _PACKED_LEAF_SUFFIXES and len(keys) >= 2:
        suffix, name = name, keys[-2]
    if suffix in _REPLICATED_SUFFIXES:
        return (None,) * leaf.ndim
    ndim = leaf.ndim
    stacked = _is_stacked(path)
    base = ndim - (1 if stacked else 0)
    table = {1: _W1, 2: _W2, 3: _W3, 4: _W4}.get(base, {})
    names = table.get(name, (None,) * base)
    if suffix in ("scale", "col_sums", "bias"):
        # (1, N) / (N,) per-channel vectors: shard only the channel dim —
        # except the expert axis of MoE stacks, which must co-shard with
        # the packed codes (expert-parallel decode: a device holding an
        # expert's codes must hold its scales/col_sums, or every EP
        # matmul pays a cross-device gather of the dequant metadata)
        chan = names[-1] if names else None
        mids = [None] * (base - 1)
        if mids and names and names[0] == "expert":
            mids[0] = "expert"
        names = (*mids, chan)
    if stacked:
        names = (None, *names)  # leading repeats axis: never sharded
    return names


def _is_stacked(path) -> bool:
    """Leaves under params["layers"] are stacked over repeats."""
    for entry in path:
        if hasattr(entry, "key") and entry.key == "layers":
            return True
    return False


def param_shardings(params, mesh: Mesh, rules: dict | None = None):
    """NamedSharding pytree for a parameter (or optimizer-state) pytree."""
    rules = rules or DEFAULT_RULES

    def one(path, leaf):
        names = _leaf_logical_names(path, leaf)
        spec = resolve_spec(leaf.shape, names, mesh, rules)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


def batch_shardings(batch, mesh: Mesh, rules: dict | None = None):
    """Batch dict: dim 0 = global batch -> ("pod", "data")."""
    rules = rules or DEFAULT_RULES

    def one(leaf):
        names = ("batch",) + (None,) * (leaf.ndim - 1)
        return NamedSharding(mesh, resolve_spec(leaf.shape, names, mesh, rules))

    return jax.tree_util.tree_map(one, batch)


#: paged-cache bookkeeping leaves (block tables, free-list stack, per-slot
#: scalars): tiny int32/bool state that every device must see in full —
#: always replicated. The page *pools* shard like dense KV (kv_heads dim);
#: the page axis itself never shards: pages are dynamically indexed across
#: sequences, so splitting it would turn every block-table chase into a
#: cross-device gather.
_PAGED_ADMIN_LEAVES = (
    "block_table", "seq_lens", "active", "uids", "steps", "last_tok",
    "free_list", "free_top", "page_refcounts",
)


def cache_shardings(cache, cfg, mesh: Mesh, rules: dict | None = None):
    """Decode caches: (R, B, ...) — batch on dim 1, trailing dims by kind.

    KV caches prefer head sharding over ``model``; when kv_heads does not
    divide the model axis (GQA kv=8 on a 16-wide TP axis — llama3/dbrx/
    granite/jamba), fall back to *sequence-sharded* KV (context-parallel
    decode: XLA reduces the attention softmax/contraction over the sharded
    sequence dim). That is what keeps a 126-layer 32k-deep cache inside
    16 GB/chip — see EXPERIMENTS.md §Dry-run.
    """
    rules = rules or DEFAULT_RULES
    model_size = mesh.shape.get("model", 1)
    paged = isinstance(cache, dict) and "free_list" in cache

    def one(path, leaf):
        name = None
        for entry in reversed(path):
            if hasattr(entry, "key"):
                name = entry.key
                break
        if paged:
            top = next((e.key for e in path if hasattr(e, "key")), None)
            if top != "pools" and top not in _PAGED_ADMIN_LEAVES:
                # loud by design: a silently-replicated new pool leaf is
                # exactly the bug class the mesh CI lane exists to catch —
                # every paged top-level leaf must be either under "pools"
                # (sharding decided by kind below) or a declared admin leaf
                raise ValueError(
                    f"unknown paged cache leaf {top!r}: not under 'pools' and "
                    f"not in _PAGED_ADMIN_LEAVES {_PAGED_ADMIN_LEAVES}; declare "
                    "its sharding explicitly in runtime.sharding"
                )
        if name in _PAGED_ADMIN_LEAVES:
            return NamedSharding(mesh, P())
        if name in ("k_pages", "v_pages") and leaf.ndim == 5:
            # (R, num_blocks, block_size, nkv, hd): shard kv heads only
            # (divisibility fallback in resolve_spec -> replicated)
            names = (None, None, None, "kv_heads", None)
            return NamedSharding(mesh, resolve_spec(leaf.shape, names, mesh, rules))
        if name in ("k_scales", "v_scales") and leaf.ndim == 3:
            # (R, num_blocks, nkv): int8-KV per-(page, head) scales — must
            # co-shard with the pools' kv_heads axis so a device holding a
            # head's codes also holds its scales; page axis never shards
            names = (None, None, "kv_heads")
            return NamedSharding(mesh, resolve_spec(leaf.shape, names, mesh, rules))
        if name in ("k", "v") and leaf.ndim == 5:
            nkv = leaf.shape[3]
            if nkv % model_size == 0:
                trailing = (None, "kv_heads", None)
            else:
                # seq-sharded KV fallback. Measured alternative (§Perf-3,
                # REFUTED): sharding head_dim instead keeps the per-token
                # cache write local, but the partitioner then all-gathers
                # the hd-sharded cache for the score contraction — coll
                # 5.1 s vs the 2.6 s select-rewrite this avoids. The real
                # fix is a two-level (prefix + append-buffer) cache,
                # documented in EXPERIMENTS.md §Perf as future work.
                trailing = ("seq", None, None)
        else:
            trailing = {
                "conv": (None, "ffn"),
                "ssm": ("ffn", None),
                "C": ("heads", None, None),
                "n": ("heads", None),
                "m": ("heads",),
                "h": (None,),
                "c": (None,),
            }.get(name, (None,) * (leaf.ndim - 2))
        names = (None, "batch", *trailing)
        names = names[: leaf.ndim]
        return NamedSharding(mesh, resolve_spec(leaf.shape, names, mesh, rules))

    return jax.tree_util.tree_map_with_path(one, cache)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Mesh-native paged serving (docs/multihost.md)
# ---------------------------------------------------------------------------


def paged_engine_shardings(params, cache, cfg, mesh: Mesh,
                           rules: dict | None = None):
    """(param_shardings, cache_shardings) for the paged engine's jitted
    programs — the out_shardings contract: every program returns its cache
    operand under exactly these shardings (pools kv-head-sharded, admin
    leaves replicated) and every token/stream output fully replicated, so
    a host read of any output touches only local shards."""
    rules = SERVING_QUANT_RULES if rules is None else rules
    return (
        param_shardings(params, mesh, rules),
        cache_shardings(cache, cfg, mesh, rules),
    )


def rows_sharding(shape: tuple[int, ...], mesh: Mesh,
                  rules: dict | None = None) -> NamedSharding:
    """Row (dim 0) sharding for per-request host inputs — batched-admit
    prompt blocks shard per-host over the data axis when the row count
    divides it (divisibility fallback -> replicated)."""
    rules = DEFAULT_RULES if rules is None else rules
    names = ("batch",) + (None,) * (len(shape) - 1)
    return NamedSharding(mesh, resolve_spec(shape, names, mesh, rules))


def host_to_global(tree, shardings):
    """Place a host (or local single-device) pytree onto global shardings.

    Multihost-safe: built via ``jax.make_array_from_callback`` from the
    host copy, so it works whether the sharding spans one process or many.
    Every process must hold the identical full value (true for the paged
    engine: params/cache init is seed-deterministic on every host)."""

    def put(x, sh):
        arr = np.asarray(x)
        return jax.make_array_from_callback(
            arr.shape, sh, lambda idx, a=arr: a[idx]
        )

    return jax.tree.map(put, tree, shardings)


def host_read(x):
    """Fetch an array to host memory, multihost-safe.

    ``jax.device_get`` refuses non-fully-addressable arrays (any replicated
    output of a multi-process computation). Replicated means every shard
    holds the full value, so reading one local shard *is* the global read —
    this is what makes the engine's one-device_get-per-chunk rule hold
    unchanged under a multi-process mesh."""
    if isinstance(x, jax.Array) and not x.is_fully_addressable:
        return np.asarray(x.addressable_data(0))
    return jax.device_get(x)
