"""Uniform affine quantizers (paper Eq. 1 / §C.1) in JAX.

Two domains are used throughout the code base:

  * the *real* domain: weights/activations as floating point arrays;
  * the *integer* domain: elements of an :class:`~repro.core.alphabet.Alphabet`.

The greedy algorithms (GPFQ/OPTQ) and all accumulator bookkeeping run in the
integer domain — weights are pre-divided by their per-channel scale so that
the budgets of Eq. 21 are exact integer-unit quantities. These helpers handle
the scale derivation, the domain changes and the two rounding modes the paper
studies (round-to-nearest vs round-to-zero).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from .alphabet import Alphabet

ROUND_NEAREST = "nearest"
ROUND_ZERO = "zero"

ROUNDING_SLACK = {ROUND_NEAREST: 0.5, ROUND_ZERO: 0.0}


def round_fn(x: jax.Array, mode: str) -> jax.Array:
    if mode == ROUND_NEAREST:
        return jnp.rint(x)
    if mode == ROUND_ZERO:
        return jnp.trunc(x)
    raise ValueError(f"unknown rounding mode {mode!r}")


def quantize_int(x: jax.Array, alphabet: Alphabet, rounding: str = ROUND_NEAREST) -> jax.Array:
    """Integer-domain quantizer: round then clip to the alphabet (float carrier)."""
    return jnp.clip(round_fn(x, rounding), alphabet.qmin, alphabet.qmax)


# ---------------------------------------------------------------------------
# Weight quantization (symmetric, per-channel scales; paper Eq. 27)
# ---------------------------------------------------------------------------
def weight_scales(w: jax.Array, alphabet: Alphabet, axis: int = 0, eps: float = 1e-12) -> jax.Array:
    """s = max|w| / (2^(M-1)-1) per output channel.

    ``w`` has shape (K, C) with rows = input dims; channel axis is 1, so the
    reduction runs over ``axis`` (default 0 = input dim).
    """
    absmax = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    return jnp.maximum(absmax / float(alphabet.qmax), eps)


def to_int_domain(w: jax.Array, scale: jax.Array) -> jax.Array:
    return w / scale


def from_int_domain(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q * scale


def quantize_weights_rtn(
    w: jax.Array, alphabet: Alphabet, rounding: str = ROUND_NEAREST
) -> tuple[jax.Array, jax.Array]:
    """Baseline direct (non-greedy) weight quantization.

    Returns (q_int, scale) with q_int float-carried integers in the alphabet.
    """
    scale = weight_scales(w, alphabet)
    q = quantize_int(to_int_domain(w, scale), alphabet, rounding)
    return q, scale


# ---------------------------------------------------------------------------
# Activation quantization (asymmetric unsigned, per-tensor; paper §C.1)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ActQuantParams:
    """Per-tensor activation quantizer state: x_int = clip(round(x/s) + z)."""

    scale: float
    zero_point: int
    bits: int
    signed: bool = False

    @property
    def alphabet(self) -> Alphabet:
        return Alphabet(bits=self.bits, signed=self.signed, symmetric=True)


def calibrate_act_quant(
    lo: jax.Array | float, hi: jax.Array | float, alphabet: Alphabet
) -> ActQuantParams:
    """Derive (scale, zero_point) from a calibrated [lo, hi] real range.

    ``lo``/``hi`` are typically low/high percentiles of the calibration
    activations (paper uses the 99th percentile). Zero is always exactly
    representable (uniform *integer* quantization, §2.1).
    """
    lo = float(lo)
    hi = float(hi)
    lo = min(lo, 0.0)
    hi = max(hi, 0.0)
    span = max(hi - lo, 1e-12)
    if alphabet.signed:
        # symmetric signed: scale from absmax, zero_point = 0
        scale = max(abs(lo), abs(hi)) / float(alphabet.qmax)
        return ActQuantParams(scale=max(scale, 1e-12), zero_point=0,
                              bits=alphabet.bits, signed=True)
    scale = span / float(alphabet.span)
    zero_point = int(round(-lo / scale))
    zero_point = max(0, min(alphabet.qmax, zero_point))
    return ActQuantParams(scale=scale, zero_point=zero_point,
                          bits=alphabet.bits, signed=False)


@partial(jax.jit, static_argnames=("bits", "signed"))
def _quantize_act(x, scale, zero_point, bits: int, signed: bool):
    alpha = Alphabet(bits=bits, signed=signed, symmetric=True)
    q = jnp.rint(x / scale) + zero_point
    return jnp.clip(q, alpha.qmin, alpha.qmax)


def quantize_act(x: jax.Array, p: ActQuantParams) -> jax.Array:
    """Real -> integer activation codes (float carrier)."""
    return _quantize_act(x, p.scale, p.zero_point, p.bits, p.signed)


def dequantize_act(xq: jax.Array, p: ActQuantParams) -> jax.Array:
    return (xq - p.zero_point) * p.scale


def fake_quantize_act(x: jax.Array, p: ActQuantParams) -> jax.Array:
    """Quantize-dequantize (simulated integer activation path)."""
    return dequantize_act(quantize_act(x, p), p)
