"""AXE orchestration: quantize one linear layer end-to-end (paper §3.3).

This is the user-facing entry point of the paper's contribution: given a
layer's float weights and its streamed calibration statistics, produce
integer weights that (a) minimize layer reconstruction error via GPFQ or
OPTQ and (b) *provably* never overflow the requested accumulation datapath
(monolithic P bits, or multi-stage (T, P_I) tiles).

The result bundles everything a quantized runtime needs: integer codes,
per-channel scales, activation quantizer parameters, corrected bias, and the
overflow certificate.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp

from .alphabet import (
    Alphabet,
    act_alphabet,
    min_accumulator_bits,
    outer_accumulator_bits,
    weight_alphabet,
)
from .calibration import LayerStats
from .ep_init import ep_init
from .equalization import bias_correction
from .gpfq import AxeConfig, GreedyResult, gpfq_memory_efficient
from .optq import optq
from .overflow import CertReport, certify
from .quantizers import (
    ActQuantParams,
    ROUND_NEAREST,
    quantize_weights_rtn,
    to_int_domain,
    weight_scales,
)

GPFQ = "gpfq"
OPTQ = "optq"
RTN = "rtn"  # direct round-to-nearest (no error correction) baseline
EPINIT = "ep_init"  # projection + round-to-zero baseline (A2Q+ applied post-hoc)


@dataclass(frozen=True)
class PTQConfig:
    """One knob object for the whole PTQ recipe.

    Defaults follow the paper's LLM setting (§4.2): W4A8, GPFQ, multi-stage
    T=128 tiles into a 16-bit inner accumulator, round-to-nearest, soft+strict
    constraints, activation asymmetric-unsigned with 99th-percentile ranges.
    ``constrain=False`` gives the unconstrained Base algorithm of Table 1.
    """

    w_bits: int = 4
    act_bits: int = 8
    act_signed: bool = False
    algorithm: str = GPFQ
    constrain: bool = True
    p_bits: int = 16
    tile: int | None = 128
    rounding: str = ROUND_NEAREST
    soft: bool = True
    strict: bool = True
    z_multiplier: float = 1.0
    act_order: bool = True
    act_percentile: float = 99.0
    damp_frac: float = 0.01  # OPTQ hessian damping
    gpfq_eta: float = 1e-6  # GPFQ sqrt damping

    @property
    def w_alphabet(self) -> Alphabet:
        return weight_alphabet(self.w_bits)

    @property
    def act_alphabet(self) -> Alphabet:
        return act_alphabet(self.act_bits, signed=self.act_signed)

    @property
    def axe(self) -> AxeConfig | None:
        if not self.constrain:
            return None
        return AxeConfig(
            p_bits=self.p_bits,
            tile=self.tile,
            soft=self.soft,
            strict=self.strict,
            z_multiplier=self.z_multiplier,
        )

    def naive_p_star(self, k: int) -> int:
        """Eq. 3 bound for this (M, N) pair — the naive-manipulation baseline."""
        return min_accumulator_bits(k, self.act_bits, self.w_bits, self.act_signed)

    def outer_bits(self, k: int) -> int:
        if not self.constrain:
            return 32
        if self.tile is None:
            return self.p_bits
        return outer_accumulator_bits(self.p_bits, k, self.tile)


@dataclass
class QuantizedLinear:
    """Deployable artifact for one linear layer."""

    q_int: jax.Array  # (K, C) integer codes (int8 storage; int4 packs 2/byte)
    scale: jax.Array  # (1, C)
    act: ActQuantParams
    bias: jax.Array | None  # (C,) corrected bias
    cert: CertReport | None
    cfg: PTQConfig
    aux: dict = field(default_factory=dict)

    @property
    def w_q(self) -> jax.Array:
        return self.q_int * self.scale

    def __call__(self, x: jax.Array) -> jax.Array:
        """Simulated-quantized forward (fake-quant activations, real matmul).

        The true-integer path (packed int4 x int8 with multi-stage
        accumulation) lives in :mod:`repro.kernels.w4a8`.
        """
        from .quantizers import fake_quantize_act

        xq = fake_quantize_act(x, self.act)
        y = xq @ self.w_q
        if self.bias is not None:
            y = y + self.bias
        return y


def quantize_linear(
    w: jax.Array,
    stats: LayerStats,
    cfg: PTQConfig,
    bias: jax.Array | None = None,
) -> QuantizedLinear:
    """Quantize one (K, C) linear layer from its streamed statistics."""
    k = w.shape[0]
    if stats.k != k:
        raise ValueError(f"stats built for K={stats.k}, weights have K={k}")
    act_params = stats.observer.act_quant(cfg.act_alphabet)

    if cfg.algorithm == GPFQ:
        h_half, g = stats.gpfq_stats(cfg.gpfq_eta)
        res = gpfq_memory_efficient(
            w, h_half, g, cfg.w_alphabet, cfg.act_alphabet,
            axe=cfg.axe, rounding=cfg.rounding, act_order=cfg.act_order,
        )
    elif cfg.algorithm == OPTQ:
        hess = stats.optq_hessian(cfg.damp_frac)
        res = optq(
            w, hess, cfg.w_alphabet, cfg.act_alphabet,
            axe=cfg.axe, rounding=cfg.rounding, act_order=cfg.act_order,
        )
    elif cfg.algorithm == RTN:
        q_int, scale = quantize_weights_rtn(w, cfg.w_alphabet, cfg.rounding)
        res = GreedyResult(q_int=q_int, scale=scale, w_alphabet=cfg.w_alphabet)
    elif cfg.algorithm == EPINIT:
        scale = weight_scales(w, cfg.w_alphabet)
        w_int = to_int_domain(w, scale)
        axe = cfg.axe or AxeConfig(p_bits=cfg.p_bits, tile=cfg.tile)
        from .alphabet import strict_budgets

        budgets = strict_budgets(axe.p_bits, cfg.act_alphabet, 0.0)
        # EP-init projects each tile row onto the l1 ball of the *strict*
        # radius (RTZ keeps it valid post-rounding), per A2Q+ / paper §2.3.
        from .ep_init import tiled, untiled

        t = axe.tile or k
        w_ct = tiled(w_int.T, t)  # (C, n_tiles, T)
        # Conservative A2Q-style radius ||q||_1 <= (2^(P-1)-1)/nu: certifiable
        # *without* the zero-centering assumption of the A2Q+/Eq.4 budget,
        # which a post-hoc projection cannot enforce (paper §2.3 discussion).
        radius = budgets.B
        q_ct = ep_init(w_ct, radius, cfg.w_alphabet)
        q_int = untiled(q_ct, k).T
        res = GreedyResult(q_int=q_int, scale=scale, w_alphabet=cfg.w_alphabet)
    else:
        raise ValueError(f"unknown algorithm {cfg.algorithm!r}")

    new_bias = bias_correction(stats.x_mean, w, res.w_q, bias)

    cert = None
    if cfg.constrain or cfg.algorithm == EPINIT:
        cert = certify(res.q_int, cfg.act_alphabet, cfg.p_bits, cfg.tile)

    return QuantizedLinear(
        q_int=res.q_int,
        scale=res.scale,
        act=act_params,
        bias=new_bias,
        cert=cert,
        cfg=cfg,
        aux=res.aux,
    )


def sweep_config(cfg: PTQConfig, **updates) -> PTQConfig:
    """Convenience for Pareto sweeps: replace fields on a frozen config."""
    return replace(cfg, **updates)
