"""AXE orchestration: quantize one linear layer end-to-end (paper §3.3).

This is the user-facing entry point of the paper's contribution: given a
layer's float weights and its streamed calibration statistics, produce
integer weights that (a) minimize layer reconstruction error via GPFQ or
OPTQ and (b) *provably* never overflow the requested accumulation datapath
(monolithic P bits, or multi-stage (T, P_I) tiles).

The result bundles everything a quantized runtime needs: integer codes,
per-channel scales, activation quantizer parameters, corrected bias, and the
overflow certificate.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp

from .alphabet import (
    Alphabet,
    act_alphabet,
    min_accumulator_bits,
    outer_accumulator_bits,
    weight_alphabet,
)
from .calibration import LayerStats
from .ep_init import ep_init
from .equalization import bias_correction
from .gpfq import AxeConfig, GreedyResult, gpfq_memory_efficient
from .optq import optq
from .overflow import CertReport, StackedCertReport, certify, certify_stacked
from .quantizers import (
    ActQuantParams,
    ROUND_NEAREST,
    quantize_weights_rtn,
    to_int_domain,
    weight_scales,
)

GPFQ = "gpfq"
OPTQ = "optq"
RTN = "rtn"  # direct round-to-nearest (no error correction) baseline
EPINIT = "ep_init"  # projection + round-to-zero baseline (A2Q+ applied post-hoc)


@dataclass(frozen=True)
class PTQConfig:
    """One knob object for the whole PTQ recipe.

    Defaults follow the paper's LLM setting (§4.2): W4A8, GPFQ, multi-stage
    T=128 tiles into a 16-bit inner accumulator, round-to-nearest, soft+strict
    constraints, activation asymmetric-unsigned with 99th-percentile ranges.
    ``constrain=False`` gives the unconstrained Base algorithm of Table 1.
    """

    w_bits: int = 4
    act_bits: int = 8
    act_signed: bool = False
    algorithm: str = GPFQ
    constrain: bool = True
    p_bits: int = 16
    tile: int | None = 128
    sparsity: str | None = None  # None | "2:4" semi-structured weight sparsity
    rounding: str = ROUND_NEAREST
    soft: bool = True
    strict: bool = True
    z_multiplier: float = 1.0
    act_order: bool = True
    act_percentile: float = 99.0
    damp_frac: float = 0.01  # OPTQ hessian damping
    gpfq_eta: float = 1e-6  # GPFQ sqrt damping

    @property
    def w_alphabet(self) -> Alphabet:
        return weight_alphabet(self.w_bits)

    @property
    def act_alphabet(self) -> Alphabet:
        return act_alphabet(self.act_bits, signed=self.act_signed)

    @property
    def axe(self) -> AxeConfig | None:
        if not self.constrain:
            return None
        return AxeConfig(
            p_bits=self.p_bits,
            tile=self.tile,
            soft=self.soft,
            strict=self.strict,
            z_multiplier=self.z_multiplier,
        )

    def naive_p_star(self, k: int) -> int:
        """Eq. 3 bound for this (M, N) pair — the naive-manipulation baseline."""
        return min_accumulator_bits(
            k, self.act_bits, self.w_bits, self.act_signed, sparsity=self.sparsity
        )

    def outer_bits(self, k: int) -> int:
        if not self.constrain:
            return 32
        if self.tile is None:
            return self.p_bits
        return outer_accumulator_bits(self.p_bits, k, self.tile, sparsity=self.sparsity)

    def to_datapath_spec(self, k: int, act: "ActQuantParams | None" = None):
        """The per-site :class:`~repro.quant.spec.DatapathSpec` this recipe
        certifies for a K-deep site: P_O from Eq. 22 at this depth, and the
        calibrated static activation quantizer when ``act`` is given.

        This is the single source of truth for the serving datapath — the
        packed artifact embeds it and ``packed_linear`` consumes it; no
        call site re-declares (tile, P_I) as kwargs.
        """
        # lazy: repro.quant.spec is dependency-free, but importing it at
        # module top would trigger repro.quant.__init__ -> pipeline ->
        # repro.core while repro.core is still initializing
        from repro.quant.spec import DatapathSpec

        spec = DatapathSpec(
            w_bits=self.w_bits,
            act_bits=self.act_bits,
            act_signed=self.act_signed,
            tile=self.tile if self.constrain else None,
            p_inner=self.p_bits if self.constrain else 32,
            p_outer=self.outer_bits(k),
            sparsity=self.sparsity,
        )
        if act is not None:
            spec = spec.with_act(act.scale, act.zero_point)
        return spec


@dataclass
class QuantizedLinear:
    """Deployable artifact for one linear layer."""

    q_int: jax.Array  # (K, C) integer codes, or (E, K, C) expert-stacked
    scale: jax.Array  # (1, C), or (E, 1, C) stacked
    act: ActQuantParams
    bias: jax.Array | None  # (C,) corrected bias; (E, 1, C) stacked
    cert: CertReport | StackedCertReport | None
    cfg: PTQConfig
    #: the serving datapath this artifact was certified for, including the
    #: calibrated static activation quantizer (repro.quant.spec)
    spec: object | None = None
    aux: dict = field(default_factory=dict)

    @property
    def stacked(self) -> bool:
        """True for expert-stacked (E, K, C) artifacts (MoE)."""
        return self.q_int.ndim == 3

    @property
    def w_q(self) -> jax.Array:
        return self.q_int * self.scale

    def __call__(self, x: jax.Array) -> jax.Array:
        """Simulated-quantized forward (fake-quant activations, real matmul).

        Stacked artifacts accept (E, n, K) inputs (matmul broadcasting over
        the expert axis). The true-integer path (packed int4 x int8 with
        multi-stage accumulation) lives in :mod:`repro.kernels.w4a8`.
        """
        from .quantizers import fake_quantize_act

        xq = fake_quantize_act(x, self.act)
        y = xq @ self.w_q
        if self.bias is not None:
            y = y + self.bias
        return y


def _make_solver(stats: LayerStats, cfg: PTQConfig, k: int):
    """Build solve((K, C) w) -> GreedyResult with the heavy stats-derived
    quantities (eigendecomposition / Hessian) computed exactly once — so the
    expert-stacked path can vmap ``solve`` over the stack with shared
    statistics."""
    if cfg.algorithm == GPFQ:
        h_half, g = stats.gpfq_stats(cfg.gpfq_eta)

        def solve(w):
            return gpfq_memory_efficient(
                w, h_half, g, cfg.w_alphabet, cfg.act_alphabet,
                axe=cfg.axe, rounding=cfg.rounding, act_order=cfg.act_order,
                sparsity=cfg.sparsity,
            )
    elif cfg.algorithm == OPTQ:
        hess = stats.optq_hessian(cfg.damp_frac)

        def solve(w):
            return optq(
                w, hess, cfg.w_alphabet, cfg.act_alphabet,
                axe=cfg.axe, rounding=cfg.rounding, act_order=cfg.act_order,
                sparsity=cfg.sparsity,
            )
    elif cfg.algorithm == RTN:

        def solve(w):
            q_int, scale = quantize_weights_rtn(w, cfg.w_alphabet, cfg.rounding)
            if cfg.sparsity is not None:
                # mask-then-round baseline: no error feedback to redistribute
                from .sparsity import mask_2to4

                q_int = q_int * mask_2to4(q_int)
            return GreedyResult(q_int=q_int, scale=scale, w_alphabet=cfg.w_alphabet)
    elif cfg.algorithm == EPINIT:
        axe = cfg.axe or AxeConfig(p_bits=cfg.p_bits, tile=cfg.tile)
        from .alphabet import strict_budgets
        from .ep_init import tiled, untiled

        budgets = strict_budgets(axe.p_bits, cfg.act_alphabet, 0.0)
        t = axe.tile or k

        def solve(w):
            scale = weight_scales(w, cfg.w_alphabet)
            w_int = to_int_domain(w, scale)
            if cfg.sparsity is not None:
                # mask first: l1 projection + RTZ both keep exact zeros at zero
                from .sparsity import mask_2to4

                w_int = w_int * mask_2to4(w_int)
            # EP-init projects each tile row onto the l1 ball of the *strict*
            # radius (RTZ keeps it valid post-rounding), per A2Q+ / §2.3.
            w_ct = tiled(w_int.T, t)  # (C, n_tiles, T)
            # Conservative A2Q-style radius ||q||_1 <= (2^(P-1)-1)/nu:
            # certifiable *without* the zero-centering assumption of the
            # A2Q+/Eq.4 budget, which a post-hoc projection cannot enforce
            # (paper §2.3 discussion).
            q_ct = ep_init(w_ct, budgets.B, cfg.w_alphabet)
            q_int = untiled(q_ct, k).T
            return GreedyResult(q_int=q_int, scale=scale, w_alphabet=cfg.w_alphabet)
    else:
        raise ValueError(f"unknown algorithm {cfg.algorithm!r}")
    return solve


def quantize_linear(
    w: jax.Array,
    stats: LayerStats,
    cfg: PTQConfig,
    bias: jax.Array | None = None,
) -> QuantizedLinear:
    """Quantize one linear layer from its streamed statistics.

    ``w`` is (K, C), or expert-stacked (E, K, C) — the MoE path: the solver
    is vmapped over the stack with shared calibration statistics, which is
    exactly equivalent to quantizing each (K, C) slice independently
    (tested), and certificates are issued per expert.
    """
    k = w.shape[-2]
    if stats.k != k:
        raise ValueError(f"stats built for K={stats.k}, weights have K={k}")
    act_params = stats.observer.act_quant(cfg.act_alphabet)
    dp_spec = cfg.to_datapath_spec(k, act_params)
    solve = _make_solver(stats, cfg, k)
    want_cert = cfg.constrain or cfg.algorithm == EPINIT

    if w.ndim == 3:  # expert-stacked
        if bias is not None:
            raise ValueError("stacked quantization does not take an input bias")
        q_int, scale = jax.vmap(lambda we: (lambda r: (r.q_int, r.scale))(solve(we)))(w)
        delta = jnp.einsum("k,ekc->ec", stats.x_mean, w - q_int * scale)
        cert = (
            certify_stacked(q_int, cfg.act_alphabet, cfg.p_bits, cfg.tile, sparsity=cfg.sparsity)
            if want_cert
            else None
        )
        return QuantizedLinear(
            q_int=q_int,
            scale=scale,
            act=act_params,
            bias=delta[:, None, :],
            cert=cert,
            cfg=cfg,
            spec=dp_spec,
        )

    res = solve(w)
    new_bias = bias_correction(stats.x_mean, w, res.w_q, bias)
    cert = (
        certify(res.q_int, cfg.act_alphabet, cfg.p_bits, cfg.tile, sparsity=cfg.sparsity)
        if want_cert
        else None
    )
    return QuantizedLinear(
        q_int=res.q_int,
        scale=res.scale,
        act=act_params,
        bias=new_bias,
        cert=cert,
        cfg=cfg,
        spec=dp_spec,
        aux=res.aux,
    )


def sweep_config(cfg: PTQConfig, **updates) -> PTQConfig:
    """Convenience for Pareto sweeps: replace fields on a frozen config."""
    return replace(cfg, **updates)
