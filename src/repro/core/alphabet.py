"""Quantization alphabets and accumulator-bound arithmetic (paper Eqs. 3, 4, 17, 21, 22).

Everything in this module is *exact integer-domain math* — no arrays bigger
than scalars, no jax tracing required (plain python / numpy scalars), so the
whole bound algebra is unit-testable in isolation and reused by both the
quantization algorithms (`gpfq.py`, `optq.py`) and the certification pass
(`overflow.py`).

Conventions (paper §2):
  * signed M-bit *sign-magnitude* weight alphabet
        A_M = { -(2^(M-1)-1), ..., 2^(M-1)-1 }
  * activation alphabet is either
        unsigned asymmetric:  { 0, ..., 2^N - 1 }        (mu=0, nu=2^N-1)
        signed symmetric:     { -(2^(N-1)-1), ..., 2^(N-1)-1 }
    In both cases ``nu - mu`` spans the full N-bit range used by the bounds.
  * accumulator is a signed P-bit register; we certify against the
    symmetric range [-(2^(P-1)-1), 2^(P-1)-1], which is valid for both
    sign-magnitude and two's-complement registers (conservative for the
    latter by exactly one representable value).
"""

from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class Alphabet:
    """A fixed b-bit integer alphabet [qmin, qmax]."""

    bits: int
    signed: bool
    symmetric: bool = True  # only meaningful for signed alphabets

    @property
    def qmin(self) -> int:
        if not self.signed:
            return 0
        if self.symmetric:
            return -(2 ** (self.bits - 1) - 1)
        return -(2 ** (self.bits - 1))

    @property
    def qmax(self) -> int:
        if not self.signed:
            return 2**self.bits - 1
        return 2 ** (self.bits - 1) - 1

    @property
    def mu(self) -> int:
        """Paper's mu: smallest representable value."""
        return self.qmin

    @property
    def nu(self) -> int:
        """Paper's nu: largest representable value."""
        return self.qmax

    @property
    def span(self) -> int:
        return self.qmax - self.qmin

    def __post_init__(self) -> None:
        if self.bits < 1 or self.bits > 32:
            raise ValueError(f"unsupported bit width {self.bits}")


def weight_alphabet(bits: int) -> Alphabet:
    """Signed symmetric (sign-magnitude) weight alphabet A_M."""
    return Alphabet(bits=bits, signed=True, symmetric=True)


def act_alphabet(bits: int, signed: bool = False) -> Alphabet:
    """Activation alphabet A_N. Default: unsigned asymmetric (paper §C.1)."""
    return Alphabet(bits=bits, signed=signed, symmetric=True)


def accumulator_range(p_bits: int) -> tuple[int, int]:
    """Symmetric representation range of a signed P-bit accumulator."""
    m = 2 ** (p_bits - 1) - 1
    return -m, m


# ---------------------------------------------------------------------------
# Semi-structured sparsity: effective reduction depth.
# ---------------------------------------------------------------------------
SPARSITY_2_4 = "2:4"
SPARSITY_PATTERNS = (SPARSITY_2_4,)


def effective_depth(k: int, sparsity: str | None) -> int:
    """Number of nonzero addends in a ``k``-deep reduction under ``sparsity``.

    The accumulator bound of Eq. 3 depends on code ranges and reduction
    depth only — 2:4 semi-structured sparsity guarantees at most 2 nonzero
    weights per contiguous group of 4 along K, so at most ``k/2`` products
    contribute to any dot product and the certificate tightens accordingly.
    """
    if sparsity is None:
        return k
    if sparsity == SPARSITY_2_4:
        return max((k + 1) // 2, 1)
    raise ValueError(f"unknown sparsity pattern {sparsity!r}")


# ---------------------------------------------------------------------------
# Eq. 3 — data-type bound: minimum P* for naive (M, N, K) manipulation.
# ---------------------------------------------------------------------------
def min_accumulator_bits(
    k: int,
    n_bits: int,
    m_bits: int,
    signed_input: bool,
    sparsity: str | None = None,
) -> int:
    """P* = ceil(log2(2^(log2(K) + N + M - 1 - 1_signed) + 1) + 1)   (Eq. 3).

    The conservative bit width that makes *any* K-deep dot product of N-bit
    inputs with M-bit weights representable. Under a sparsity pattern the
    depth entering the bound is the *effective* depth (the maximum count of
    nonzero addends): 2:4 halves it, which tightens P* by one bit.
    """
    if k < 1:
        raise ValueError("dot-product depth must be >= 1")
    k = effective_depth(k, sparsity)
    exponent = math.log2(k) + n_bits + m_bits - 1 - (1 if signed_input else 0)
    return int(math.ceil(math.log2(2**exponent + 1) + 1))


# ---------------------------------------------------------------------------
# Eq. 4 — zero-centered l1 budget (reference; used for the soft penalty's Z).
# ---------------------------------------------------------------------------
def l1_budget_zero_centered(p_bits: int, act: Alphabet) -> float:
    """||q||_1 <= (2^P - 2) / (2^N - 1)   (Eq. 4), in integer units."""
    return (2.0**p_bits - 2.0) / float(act.span)


# ---------------------------------------------------------------------------
# Eq. 17 / 21 — strict per-sign boundary budgets.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Budgets:
    """Per-channel (or per-tile) strict budgets.

    ``mode == "split"``  (unsigned activations, mu == 0):
        running positive sum beta <= B, running negative sum alpha >= A,
        independently (Eqs. 17/19/20).
    ``mode == "joint"``  (signed activations, mu == -nu):
        running l1 norm beta - alpha <= B (A is -B, kept for symmetry).
    """

    A: float  # lower budget (<= 0)
    B: float  # upper budget (>= 0)
    mode: str  # "split" | "joint"


def strict_budgets(p_bits: int, act: Alphabet, rounding_slack: float) -> Budgets:
    """-A = B = (2^(P-1) - 1)/(2^N - 1) - max(Delta)   (Eq. 21).

    ``rounding_slack`` is max(Delta): 0.5 for round-to-nearest, 0.0 for
    round-to-zero. For signed symmetric activations the same magnitude
    becomes a *joint* l1 budget: nu * (beta - alpha) <= 2^(P-1) - 1.
    """
    top = 2.0 ** (p_bits - 1) - 1.0
    if not act.signed:
        b = top / float(act.nu) - rounding_slack
        if b < 0:
            raise ValueError(
                f"accumulator P={p_bits} too small for N={act.bits}-bit activations"
            )
        return Budgets(A=-b, B=b, mode="split")
    # signed symmetric: u.q = nu * ||q||_1
    b = top / float(act.nu) - rounding_slack
    if b < 0:
        raise ValueError(
            f"accumulator P={p_bits} too small for N={act.bits}-bit activations"
        )
    return Budgets(A=-b, B=b, mode="joint")


# ---------------------------------------------------------------------------
# Eq. 22 — multi-stage accumulation.
# ---------------------------------------------------------------------------
def outer_accumulator_bits(
    p_inner: int, k: int, tile: int, sparsity: str | None = None
) -> int:
    """P_O = ceil(P_I + log2(K_eff) - log2(T_eff))   (Eq. 22).

    ``sparsity`` substitutes *effective* depths: 2:4 halves both the total
    addend count and the per-tile addend count, so the tile count — and with
    it P_O - P_I — is unchanged; the parameter exists so call sites state
    the exact datapath they certify (and stay correct if a future pattern
    scales the two differently).
    """
    k = effective_depth(k, sparsity)
    tile = effective_depth(tile, sparsity)
    if k < tile:
        tile = k
    return int(math.ceil(p_inner + math.log2(k) - math.log2(tile)))


def num_tiles(k: int, tile: int) -> int:
    return (k + tile - 1) // tile


def worst_case_dot_bounds(
    pos_sum: float, neg_sum: float, act: Alphabet
) -> tuple[float, float]:
    """Worst-case (min, max) of x.q over x in A_N^K given the sum of positive
    elements of q (``pos_sum`` = beta >= 0) and the sum of negative elements
    (``neg_sum`` = alpha <= 0).   (Eq. 6)
    """
    hi = act.nu * pos_sum + act.mu * neg_sum
    lo = act.mu * pos_sum + act.nu * neg_sum
    return lo, hi
