"""2:4 semi-structured sparsity masks (ROADMAP item 2).

A 2:4 pattern keeps at most 2 nonzero weights in every contiguous group of
4 along the reduction axis K. The accumulator certificate (Eq. 3 / Eq. 6)
only sees the surviving codes, so the pattern *halves the effective
reduction depth* and tightens the certified floor — see
:func:`repro.core.alphabet.effective_depth`.

Mask selection is magnitude top-2 per (group-of-4, channel), computed on
the integer-domain target so it commutes with the per-channel positive
scale. Ties break toward the lower in-group index (stable argsort), which
keeps masks deterministic — the kernel metadata packing and the plan
re-calibration path both rely on that.

Everything here is traceable (works under ``jax.jit`` / ``jax.eval_shape``);
the host-side :func:`check_2to4` validator is the only numpy consumer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .alphabet import SPARSITY_2_4, effective_depth

GROUP = 4  # in-group population of the N:M pattern (N=2, M=4)
KEEP = 2


def validate_sparsity(sparsity: str | None) -> None:
    """Raise unless ``sparsity`` names a supported pattern (or is None)."""
    if sparsity is not None and sparsity != SPARSITY_2_4:
        raise ValueError(f"unknown sparsity pattern {sparsity!r}")


def mask_2to4(w: jax.Array) -> jax.Array:
    """Top-2-magnitude 2:4 mask for ``w`` with K on axis -2: (..., K, C).

    Returns a {0, 1} array of ``w``'s dtype. Requires ``K % 4 == 0``.
    Ranking is per (group, channel); among equal magnitudes the lower
    in-group index wins (stable sort), so all-equal groups keep positions
    0 and 1 — deterministic across runs and devices.
    """
    k = w.shape[-2]
    if k % GROUP:
        raise ValueError(f"2:4 sparsity needs K % 4 == 0, got K={k}")
    lead = w.shape[:-2]
    n = w.shape[-1]
    g = jnp.abs(w).reshape(*lead, k // GROUP, GROUP, n)
    # rank[i] = how many in-group slots beat slot i (stable: ties -> index)
    order = jnp.argsort(-g, axis=-2, stable=True)
    rank = jnp.argsort(order, axis=-2, stable=True)
    keep = (rank < KEEP).astype(w.dtype)
    return keep.reshape(*lead, k, n)


def apply_mask(w: jax.Array, sparsity: str | None) -> jax.Array:
    """Magnitude-mask ``w`` (K on axis -2) to the requested pattern."""
    validate_sparsity(sparsity)
    if sparsity is None:
        return w
    return w * mask_2to4(w)


def is_2to4(q: np.ndarray | jax.Array) -> bool:
    """True iff every group of 4 along axis -2 has at most 2 nonzeros."""
    q = np.asarray(q)
    k = q.shape[-2]
    if k % GROUP:
        return False
    lead = q.shape[:-2]
    g = q.reshape(*lead, k // GROUP, GROUP, q.shape[-1])
    return bool(((g != 0).sum(axis=-2) <= KEEP).all())


def check_2to4(q: np.ndarray | jax.Array, what: str = "codes") -> None:
    """Loud host-side validation that ``q`` satisfies the 2:4 pattern."""
    k = np.asarray(q).shape[-2]
    if k % GROUP:
        raise ValueError(f"{what} claim 2:4 sparsity but K={k} is not a multiple of 4")
    if not is_2to4(q):
        raise ValueError(f"{what} claim 2:4 sparsity but a group of 4 has > 2 nonzeros")


__all__ = [
    "GROUP",
    "KEEP",
    "SPARSITY_2_4",
    "apply_mask",
    "check_2to4",
    "effective_depth",
    "is_2to4",
    "mask_2to4",
    "validate_sparsity",
]
