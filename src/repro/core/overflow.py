"""Overflow-avoidance certification (the paper's central guarantee).

Given integer-domain quantized weights Q, an activation alphabet A_N, and an
accumulation datapath (monolithic P or multi-stage (T, P_I, P_O)), these
routines compute the *exact worst case* of every (tile-)partial dot product
over all x in A_N^K (Eq. 6) and compare it against the accumulator range.
This is an analytic certificate — no input distribution assumptions — plus a
simulation harness that evaluates real integer accumulations in int64 and
reports the bit usage watermark (used by the property tests).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .alphabet import Alphabet, accumulator_range, outer_accumulator_bits
from .ep_init import tiled


@dataclass
class CertReport:
    ok: bool
    p_bits: int  # inner accumulator target
    p_outer: int  # outer accumulator (== p_bits when monolithic)
    tile: int | None
    worst_hi: float  # max over channels/tiles of worst-case partial sum
    worst_lo: float
    headroom_bits: float  # log2 margin below the limit (>= 0 iff ok)
    outer_hi: float
    outer_lo: float
    outer_ok: bool
    # the reduction depth the certificate was issued against; lets
    # min_feasible_p_bits re-derive Eq. 22 even when the caller omits k
    k: int | None = None
    # sparsity pattern of the certified codes (None | "2:4"); 2:4 halves the
    # effective depth entering the Eq. 22 re-derivation
    sparsity: str | None = None

    def __bool__(self) -> bool:
        return self.ok and self.outer_ok


@dataclass
class StackedCertReport:
    """Aggregate certificate for an expert-stacked (E, K, C) weight.

    Behaves like a :class:`CertReport` where it matters (truthiness, the
    ``ok``/``headroom_bits`` summary fields) while keeping every per-expert
    report addressable — each expert slice is an independent K-deep MAC
    reduction and is certified independently.
    """

    reports: tuple[CertReport, ...]

    def __bool__(self) -> bool:
        return all(bool(r) for r in self.reports)

    @property
    def ok(self) -> bool:
        return all(r.ok for r in self.reports)

    @property
    def headroom_bits(self) -> float:
        return min(r.headroom_bits for r in self.reports)

    @property
    def p_bits(self) -> int:
        return self.reports[0].p_bits

    @property
    def tile(self) -> int | None:
        return self.reports[0].tile

    @property
    def k(self) -> int | None:
        return self.reports[0].k

    @property
    def sparsity(self) -> str | None:
        return self.reports[0].sparsity


def tile_signed_sums(q_int: jax.Array, tile: int | None) -> tuple[jax.Array, jax.Array]:
    """Per (channel, tile) sums of positive / negative integer weights.

    ``q_int``: (K, C). Returns (pos, neg) with shape (C, n_tiles).
    """
    k = q_int.shape[0]
    t = tile or k
    q_ct = tiled(q_int.T, t)  # (C, n_tiles, T)
    pos = jnp.sum(jnp.maximum(q_ct, 0.0), axis=-1)
    neg = jnp.sum(jnp.minimum(q_ct, 0.0), axis=-1)
    return pos, neg


def certify(
    q_int: jax.Array,
    act: Alphabet,
    p_bits: int,
    tile: int | None = None,
    sparsity: str | None = None,
) -> CertReport:
    """Analytic overflow certificate for ``q_int`` (K, C).

    Monolithic: every channel's worst-case dot product must fit a signed
    ``p_bits`` register. Multi-stage: every (channel, tile) partial must fit
    ``p_bits`` (= P_I) and the total must fit P_O from Eq. 22.

    ``sparsity="2:4"`` asserts (loudly) that the codes satisfy the 2:4
    pattern and records it on the report. The Eq. 6 worst cases are computed
    from the codes' actual signed sums, so masked zeros already contribute
    nothing — the sparse certificate is *automatically* tighter; recording
    the pattern additionally halves the effective depth entering every
    later Eq. 22 re-derivation (:func:`min_feasible_p_bits`).
    """
    k = q_int.shape[0]
    if sparsity is not None:
        from .sparsity import check_2to4, validate_sparsity

        validate_sparsity(sparsity)
        check_2to4(q_int)
    pos, neg = tile_signed_sums(q_int, tile)  # (C, n_tiles)
    hi = act.nu * pos + act.mu * neg  # worst-case max per tile (Eq. 6/7)
    lo = act.mu * pos + act.nu * neg  # worst-case min per tile (Eq. 6/8)

    lo_lim, hi_lim = accumulator_range(p_bits)
    worst_hi = float(jnp.max(hi))
    worst_lo = float(jnp.min(lo))
    inner_ok = worst_hi <= hi_lim and worst_lo >= lo_lim

    if tile is None or tile >= k:
        p_outer = p_bits
        outer_hi, outer_lo, outer_ok = worst_hi, worst_lo, inner_ok
    else:
        p_outer = outer_accumulator_bits(p_bits, k, tile, sparsity=sparsity)
        o_lo_lim, o_hi_lim = accumulator_range(p_outer)
        # outer accumulator sums the tile partials; worst cases add up
        outer_hi = float(jnp.max(jnp.sum(hi, axis=-1)))
        outer_lo = float(jnp.min(jnp.sum(lo, axis=-1)))
        outer_ok = outer_hi <= o_hi_lim and outer_lo >= o_lo_lim

    # note: an all-zero site clamps to peak=1.0, so headroom stays *finite*
    # (= log2(hi_lim)) — search ordering still needs the name tie-break in
    # search_plan because distinct sites can share that exact value
    peak = max(worst_hi, -worst_lo, 1.0)
    headroom = float(np.log2(hi_lim) - np.log2(peak)) if peak > 0 else float("inf")
    return CertReport(
        ok=inner_ok,
        p_bits=p_bits,
        p_outer=p_outer,
        tile=tile,
        worst_hi=worst_hi,
        worst_lo=worst_lo,
        headroom_bits=headroom,
        outer_hi=outer_hi,
        outer_lo=outer_lo,
        outer_ok=outer_ok,
        k=k,
        sparsity=sparsity,
    )


def certify_stacked(
    q_int: jax.Array,
    act: Alphabet,
    p_bits: int,
    tile: int | None = None,
    sparsity: str | None = None,
) -> StackedCertReport:
    """Per-expert analytic certificates for stacked (E, K, C) weights."""
    return StackedCertReport(
        reports=tuple(
            certify(q_int[e], act, p_bits, tile, sparsity=sparsity)
            for e in range(q_int.shape[0])
        )
    )


def min_feasible_p_bits(
    report: CertReport | StackedCertReport,
    k: int | None = None,
    margin_bits: float = 0.0,
) -> int:
    """Smallest inner accumulator width the *already-certified* codes fit.

    The analytic certificate records the exact worst-case partial sums of a
    site's integer codes against its activation alphabet (Eq. 6) — those
    extrema are properties of the codes alone, so any P_I whose register
    holds them is certified for the *same* codes with no re-solve and no
    accuracy change. This is the certificate-exact floor the
    mixed-precision search (:mod:`repro.quant.observe.search`) spends:
    ``headroom_bits`` says how far below the configured P_I the site
    peaks; this converts that margin into the tightest integer width.

    ``k`` (the site's reduction depth) lets the multi-stage check also
    re-derive P_O via Eq. 22 at each candidate — tightening P_I tightens
    P_O, and the *outer* worst case must still fit. When ``k`` is omitted
    the report's own recorded depth backs the re-derivation, so a tiled
    report never returns a P_I whose derived P_O overflows. The report's
    sparsity pattern feeds Eq. 22's effective depths. ``margin_bits`` adds
    a log2 safety factor on the recorded peaks (0 = exact); if the inflated
    peaks no longer fit even the certified ``p_bits`` register there is no
    feasible floor and a ``ValueError`` is raised instead of silently
    returning an infeasible width. Never returns more than the certified
    ``p_bits``; stacked reports take the max over experts (one datapath
    serves the stack).
    """
    if isinstance(report, StackedCertReport):
        return max(min_feasible_p_bits(r, k, margin_bits) for r in report.reports)
    grow = 2.0**margin_bits
    hi, lo = report.worst_hi * grow, report.worst_lo * grow
    o_hi, o_lo = report.outer_hi * grow, report.outer_lo * grow
    tile = report.tile
    depth = k if k is not None else report.k
    for p in range(2, report.p_bits + 1):
        lo_lim, hi_lim = accumulator_range(p)
        if hi > hi_lim or lo < lo_lim:
            continue
        if tile is not None and depth is not None and tile < depth:
            po = outer_accumulator_bits(p, depth, tile, sparsity=report.sparsity)
            o_lo_lim, o_hi_lim = accumulator_range(po)
            if o_hi > o_hi_lim or o_lo < o_lo_lim:
                continue
        return p
    raise ValueError(
        f"no feasible accumulator floor: margin_bits={margin_bits} inflates the "
        f"recorded worst-case peaks (hi={hi:.6g}, lo={lo:.6g}) past the certified "
        f"P_I={report.p_bits} register itself"
    )


def simulate_accumulation(
    q_int: jax.Array,
    x_int: jax.Array,
    tile: int | None = None,
) -> dict:
    """Evaluate integer dot products exactly (int64) and report watermarks.

    ``q_int``: (K, C), ``x_int``: (D, K) integer activation codes. Returns
    per-tile partial-sum extrema and the bit width actually needed — used by
    property tests to confirm the analytic certificate is an upper bound.
    Runs in numpy int64 (JAX defaults to 32-bit ints; this check must be
    exact).
    """
    q = np.asarray(q_int, np.int64)
    x = np.asarray(x_int, np.int64)
    k = q.shape[0]
    t = tile or k
    n_tiles = (k + t - 1) // t
    pad = n_tiles * t - k
    if pad:
        q = np.pad(q, [(0, pad), (0, 0)])
        x = np.pad(x, [(0, 0), (0, pad)])
    q_t = q.T.reshape(q.shape[1], n_tiles, t)  # (C, n_tiles, T)
    x_t = x.reshape(x.shape[0], n_tiles, t)  # (D, n_tiles, T)
    # partials: (D, C, n_tiles)
    partials = np.einsum("dnt,cnt->dcn", x_t, q_t)
    totals = np.sum(partials, axis=-1)  # (D, C)
    p_hi = partials.max()
    p_lo = partials.min()
    t_hi = totals.max()
    t_lo = totals.min()

    def bits_needed(hi, lo):
        peak = max(int(hi), -int(lo), 1)
        return int(np.ceil(np.log2(peak + 1))) + 1

    return {
        "partial_hi": int(p_hi),
        "partial_lo": int(p_lo),
        "total_hi": int(t_hi),
        "total_lo": int(t_lo),
        "inner_bits_used": bits_needed(p_hi, p_lo),
        "outer_bits_used": bits_needed(t_hi, t_lo),
    }


def worst_case_inputs(q_int: jax.Array, act: Alphabet) -> tuple[jax.Array, jax.Array]:
    """The maximizing / minimizing activation vectors u, v of Eq. 6 per channel.

    Returns (u, v) with shape (C, K): dotting u[c] with q[:, c] attains the
    analytic worst-case maximum (and v the minimum) — used by tests to show
    the certificate is *tight*.
    """
    qt = q_int.T  # (C, K)
    u = jnp.where(qt >= 0, act.nu, act.mu)
    v = jnp.where(qt >= 0, act.mu, act.nu)
    return u, v
