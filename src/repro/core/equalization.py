"""Graph equalization (SmoothQuant, Xiao et al. 2023) and bias correction
(Nagel et al. 2019) — the pre-/post-processing steps of the paper's
quantization recipe (§C.1).

These are *functionally invariant* rewrites of the float network: for every
linear with a foldable preceding scale (an RMSNorm/LayerNorm weight or the
previous linear's output channels),

    y = (x / s) @ (diag(s) W) == x @ W,

with s chosen to migrate quantization difficulty from activations to weights
(SmoothQuant's alpha-balanced scales). Bias correction then absorbs the
expected quantization error E[x]^T (W - W_q) into the bias.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def smoothquant_scales(
    act_absmax: jax.Array,
    weight_absmax: jax.Array,
    alpha: float = 0.5,
    eps: float = 1e-5,
) -> jax.Array:
    """s_j = max|X_j|^alpha / max|W_j.|^(1-alpha)  (SmoothQuant Eq. 4).

    ``act_absmax``: (K,) per-input-dim activation abs-max (from the
    :class:`~repro.core.calibration.ActObserver`); ``weight_absmax``: (K,)
    per-input-dim (row) abs-max of the consuming weight(s). Scales are
    clamped away from zero and normalized so the no-op scale is 1 when either
    side is degenerate.
    """
    a = jnp.maximum(jnp.asarray(act_absmax), eps)
    w = jnp.maximum(jnp.asarray(weight_absmax), eps)
    s = jnp.power(a, alpha) / jnp.power(w, 1.0 - alpha)
    return jnp.clip(s, eps, 1.0 / eps)


def equalize_linear(w: jax.Array, s: jax.Array) -> jax.Array:
    """Scale the rows (input dims) of ``w`` (K, C) by ``s`` (K,)."""
    return w * s[:, None]


def equalize_norm_weight(norm_w: jax.Array, s: jax.Array) -> jax.Array:
    """Fold 1/s into the preceding norm's elementwise weight."""
    return norm_w / s


def equalize_norm_bias(norm_b: jax.Array, s: jax.Array) -> jax.Array:
    return norm_b / s


def bias_correction(
    x_mean: jax.Array, w: jax.Array, w_q: jax.Array, bias: jax.Array | None
) -> jax.Array:
    """b' = b + E[x]^T (W - W_q)   (Nagel et al. 2019, paper §C.1).

    ``x_mean``: (K,), ``w``/``w_q``: (K, C). Returns the corrected (C,) bias
    (created from zero when the layer had none).
    """
    delta = x_mean @ (w - w_q)  # (C,)
    if bias is None:
        return delta
    return bias + delta
