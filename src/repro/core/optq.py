"""OPTQ / GPTQ (Frantar et al., 2022) with accumulator-aware extensions
(paper Algorithm 2).

Same conventions as :mod:`repro.core.gpfq`: W is (K, C) rows = input dims,
the loop runs in the integer weight domain, and the AXE constraints
(soft threshold + strict budget clipping) are applied per row before
quantization, with error propagated through the inverse-Hessian Cholesky
factor exactly as in standard OPTQ.

Note OPTQ's scale-equivariance: the update
``W_{i:} -= ((W_i - Q_i)/Hinv_ii) * Hinv_{i,i:}`` is linear in W per channel,
so running in the integer domain (W / per-channel scale) commutes with the
real-domain algorithm, like GPFQ.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .alphabet import Alphabet
from .gpfq import AxeConfig, GreedyResult, constrain_row, make_axe_state
from .sparsity import mask_2to4, validate_sparsity
from .quantizers import (
    ROUND_NEAREST,
    quantize_int,
    to_int_domain,
    weight_scales,
)


def hessian_proxy(xq: jax.Array, damp_frac: float = 0.01) -> jax.Array:
    """H = 2 Xq Xq^T + eta I with eta = damp_frac * mean(diag)   (paper App. A).

    ``xq``: (K, D) quantized-input sample rows. The (K, K) proxy can also be
    accumulated streaming via :mod:`repro.core.calibration`.
    """
    h = 2.0 * (xq @ xq.T)
    eta = damp_frac * jnp.mean(jnp.diag(h)) + 1e-12
    return h + eta * jnp.eye(h.shape[0], dtype=h.dtype)


def inverse_cholesky(h: jax.Array) -> jax.Array:
    """Upper-triangular R with H^-1 = R^T R (torch.linalg.cholesky(.., upper))."""
    h_inv = jnp.linalg.inv(h)
    # symmetrize against numerical drift before factorization
    h_inv = 0.5 * (h_inv + h_inv.T)
    return jnp.linalg.cholesky(h_inv).T


@partial(
    jax.jit,
    static_argnames=("w_bits", "w_signed", "rounding", "strict", "mode", "has_axe", "has_mask"),
)
def _optq_loop(
    w_int,  # (K, C)
    hinv_u,  # (K, K) upper-triangular factor
    lam,
    A,
    B,
    tile_ids,
    pos0,
    neg0,
    mask,  # (K, C) {0,1} sparsity support, or (1, C) dummy when dense
    *,
    w_bits: int,
    w_signed: bool,
    rounding: str,
    strict: bool,
    mode: str,
    has_axe: bool,
    has_mask: bool,
):
    K, C = w_int.shape
    alphabet = Alphabet(bits=w_bits, signed=w_signed, symmetric=True)
    col = jnp.arange(K)

    def body(i, carry):
        W, Q, pos, neg = carry
        w_i = jax.lax.dynamic_slice_in_dim(W, i, 1, axis=0)[0]  # (C,)
        if has_mask:
            # mask-then-quantize: pruned positions target exactly 0; the error
            # term below keeps the unmasked w_i, so the pruned energy is
            # propagated through the Cholesky factor to later rows
            m_i = jax.lax.dynamic_slice_in_dim(mask, i, 1, axis=0)[0]
            target = w_i * m_i
        else:
            target = w_i
        if has_axe:
            q, pos, neg = constrain_row(
                target, tile_ids[i], lam, A, B, pos, neg,
                strict=strict, mode=mode, alphabet=alphabet, rounding=rounding,
            )
        else:
            q = quantize_int(target, alphabet, rounding)
        d = hinv_u[i, i]
        err = (w_i - q) / d  # (C,)
        # propagate to not-yet-quantized rows only (j > i)
        row = jnp.where(col > i, hinv_u[i, :], 0.0)  # (K,)
        W = W - jnp.outer(row, err)
        Q = jax.lax.dynamic_update_slice_in_dim(Q, q[None, :], i, axis=0)
        return (W, Q, pos, neg)

    Q0 = jnp.zeros_like(w_int)
    W, Q, pos, neg = jax.lax.fori_loop(0, K, body, (w_int, Q0, pos0, neg0))
    return Q, pos, neg


def optq(
    w: jax.Array,
    hessian: jax.Array,
    w_alphabet: Alphabet,
    act_alphabet: Alphabet | None = None,
    axe: AxeConfig | None = None,
    rounding: str = ROUND_NEAREST,
    act_order: bool = True,
    sparsity: str | None = None,
) -> GreedyResult:
    """OPTQ with optional AXE constraints (Algorithm 2).

    ``hessian``: the (K, K) proxy from :func:`hessian_proxy` (already damped).
    ``act_order``: quantize rows in descending diag(H) order (the GPTQ
    `--act-order` trick the paper also adopts, §C.1).
    ``sparsity="2:4"``: per-group-of-4 magnitude mask fixed before the solve;
    error feedback runs against the masked support (see :mod:`.sparsity`).
    """
    K = w.shape[0]
    if hessian.shape != (K, K):
        raise ValueError(f"hessian must be ({K}, {K}), got {hessian.shape}")

    validate_sparsity(sparsity)
    scale = weight_scales(w, w_alphabet)
    w_int = to_int_domain(w, scale)
    state = make_axe_state(w_int, axe, act_alphabet, rounding, K)
    if sparsity is not None:
        mask = mask_2to4(w_int)  # original K indexing; survives act_order
    else:
        mask = jnp.ones((1, w.shape[1]), w_int.dtype)

    if act_order:
        order = jnp.argsort(-jnp.diag(hessian))
    else:
        order = jnp.arange(K)
    inv_order = jnp.argsort(order)
    h_perm = hessian[order][:, order]
    hinv_u = inverse_cholesky(h_perm)

    if state is None:
        C = w.shape[1]
        lam = jnp.zeros((1, C), w_int.dtype)
        A = jnp.asarray(0.0)
        B = jnp.asarray(0.0)
        tile_ids = jnp.zeros((K,), jnp.int32)
        pos0 = jnp.zeros((1, C), w_int.dtype)
        neg0 = jnp.zeros((1, C), w_int.dtype)
        strict, mode, has_axe = False, "split", False
    else:
        lam, A, B = state["lam"], state["A"], state["B"]
        tile_ids, pos0, neg0 = state["tile_ids"], state["pos"], state["neg"]
        strict, mode, has_axe = state["strict"], state["mode"], True

    Q_perm, pos, neg = _optq_loop(
        w_int[order],
        hinv_u,
        lam,
        A,
        B,
        tile_ids[order] if state is not None else tile_ids,
        pos0,
        neg0,
        mask[order] if sparsity is not None else mask,
        w_bits=w_alphabet.bits,
        w_signed=w_alphabet.signed,
        rounding=rounding,
        strict=strict,
        mode=mode,
        has_axe=has_axe,
        has_mask=sparsity is not None,
    )
    q_int = Q_perm[inv_order]
    return GreedyResult(
        q_int=q_int,
        scale=scale,
        w_alphabet=w_alphabet,
        act_alphabet=act_alphabet,
        axe=axe,
        aux={"pos": pos, "neg": neg},
    )
