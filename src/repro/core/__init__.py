"""repro.core — AXE: accumulator-aware post-training quantization.

The paper's contribution as a composable JAX library:

  * :mod:`alphabet`     — integer alphabets + accumulator bound algebra
                          (Eqs. 3, 4, 17, 21, 22)
  * :mod:`quantizers`   — uniform affine quantizers, scales, rounding modes
  * :mod:`ep_init`      — l1-ball projection, Lagrangian lambda (Eqs. 15-16),
                          EP-init baseline
  * :mod:`gpfq`         — GPFQ + AXE (Alg. 1) + memory-efficient form (Thm B.1)
  * :mod:`optq`         — OPTQ/GPTQ + AXE (Alg. 2)
  * :mod:`overflow`     — analytic overflow certificates + int64 simulation
  * :mod:`calibration`  — streaming O(K^2) layer statistics
  * :mod:`equalization` — SmoothQuant scales + bias correction
  * :mod:`axe`          — the one-call layer quantization orchestration
"""

from .alphabet import (
    Alphabet,
    Budgets,
    SPARSITY_2_4,
    accumulator_range,
    act_alphabet,
    effective_depth,
    l1_budget_zero_centered,
    min_accumulator_bits,
    outer_accumulator_bits,
    strict_budgets,
    weight_alphabet,
)
from .axe import (
    EPINIT,
    GPFQ,
    OPTQ,
    PTQConfig,
    QuantizedLinear,
    RTN,
    quantize_linear,
    sweep_config,
)
from .calibration import ActObserver, LayerStats
from .ep_init import (
    ep_init,
    l1_projection_threshold,
    project_l1_ball,
    soft_threshold,
    tiled,
    untiled,
)
from .equalization import (
    bias_correction,
    equalize_linear,
    equalize_norm_weight,
    smoothquant_scales,
)
from .gpfq import AxeConfig, GreedyResult, gpfq, gpfq_memory_efficient, me_stats
from .optq import hessian_proxy, inverse_cholesky, optq
from .overflow import (
    CertReport,
    StackedCertReport,
    certify,
    certify_stacked,
    min_feasible_p_bits,
    simulate_accumulation,
    worst_case_inputs,
)
from .sparsity import apply_mask, check_2to4, is_2to4, mask_2to4, validate_sparsity
from .quantizers import (
    ActQuantParams,
    ROUND_NEAREST,
    ROUND_ZERO,
    calibrate_act_quant,
    dequantize_act,
    fake_quantize_act,
    quantize_act,
    quantize_int,
    quantize_weights_rtn,
    weight_scales,
)

__all__ = [
    "Alphabet", "Budgets", "SPARSITY_2_4", "accumulator_range", "act_alphabet",
    "effective_depth", "l1_budget_zero_centered", "min_accumulator_bits",
    "outer_accumulator_bits", "strict_budgets", "weight_alphabet",
    "apply_mask", "check_2to4", "is_2to4", "mask_2to4", "validate_sparsity",
    "EPINIT", "GPFQ", "OPTQ", "RTN", "PTQConfig", "QuantizedLinear",
    "quantize_linear", "sweep_config",
    "ActObserver", "LayerStats",
    "ep_init", "l1_projection_threshold", "project_l1_ball",
    "soft_threshold", "tiled", "untiled",
    "bias_correction", "equalize_linear", "equalize_norm_weight",
    "smoothquant_scales",
    "AxeConfig", "GreedyResult", "gpfq", "gpfq_memory_efficient", "me_stats",
    "hessian_proxy", "inverse_cholesky", "optq",
    "CertReport", "StackedCertReport", "certify", "certify_stacked",
    "min_feasible_p_bits", "simulate_accumulation", "worst_case_inputs",
    "ActQuantParams", "ROUND_NEAREST", "ROUND_ZERO", "calibrate_act_quant",
    "dequantize_act", "fake_quantize_act", "quantize_act", "quantize_int",
    "quantize_weights_rtn", "weight_scales",
]
