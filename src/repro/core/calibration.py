"""Streaming calibration statistics (paper App. B's memory argument).

GPFQ's standard form needs all D calibration samples per layer — O(D * K)
memory, which is exactly what Theorem B.1 removes. This module accumulates
the square-matrix sufficient statistics one batch at a time:

    h_raw = sum_b  Xq_b^T Xq_b     (= Xq Xq^T in the paper's (K, D) layout)
    g_raw = sum_b  X_b^T  Xq_b     (= X  Xq^T)

plus the input mean (for bias correction), per-tensor activation ranges
(percentile calibrated, §C.1) and per-input-dim abs-max (for SmoothQuant
equalization). Everything is O(K^2) regardless of the number of samples.
Batches are (n, K) row-major activations, the natural layout coming out of a
model forward pass.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .alphabet import Alphabet
from .quantizers import ActQuantParams, calibrate_act_quant


@dataclass
class ActObserver:
    """Per-tensor activation range observer (running mean of per-batch
    percentiles, Brevitas-style) + per-dim abs-max for equalization."""

    k: int
    percentile: float = 99.0
    n_batches: int = 0
    lo_sum: float = 0.0
    hi_sum: float = 0.0
    min_seen: float = float("inf")
    max_seen: float = -float("inf")
    dim_absmax: np.ndarray = field(default=None)  # (K,)

    def __post_init__(self):
        if self.dim_absmax is None:
            self.dim_absmax = np.zeros((self.k,), np.float64)

    def update(self, x: jax.Array) -> None:
        x = np.asarray(x, np.float64).reshape(-1, self.k)
        q_lo = 100.0 - self.percentile
        lo, hi = np.percentile(x, [q_lo, self.percentile])
        self.lo_sum += float(lo)
        self.hi_sum += float(hi)
        self.n_batches += 1
        self.min_seen = min(self.min_seen, float(x.min()))
        self.max_seen = max(self.max_seen, float(x.max()))
        np.maximum(self.dim_absmax, np.abs(x).max(axis=0), out=self.dim_absmax)

    @property
    def lo(self) -> float:
        return self.lo_sum / max(self.n_batches, 1)

    @property
    def hi(self) -> float:
        return self.hi_sum / max(self.n_batches, 1)

    def act_quant(self, alphabet: Alphabet) -> ActQuantParams:
        return calibrate_act_quant(self.lo, self.hi, alphabet)

    def snapshot(self) -> dict:
        """Plain-data summary of everything this observer saw — what the
        calibration-time observer layer (repro.quant.observe) records per
        site. ``lo``/``hi`` are the percentile-calibrated quantizer range;
        ``min_seen``/``max_seen`` the true extremes (their gap to lo/hi is
        the expected static-quantizer clip mass the serving saturation
        counters then measure for real)."""
        seen = self.n_batches > 0
        return {
            "k": self.k,
            "percentile": self.percentile,
            "n_batches": self.n_batches,
            "lo": self.lo,
            "hi": self.hi,
            "min_seen": self.min_seen if seen else 0.0,
            "max_seen": self.max_seen if seen else 0.0,
            "absmax": float(self.dim_absmax.max()) if seen else 0.0,
        }


@dataclass
class LayerStats:
    """Streaming sufficient statistics for one linear layer (input dim K)."""

    k: int
    dtype: jnp.dtype = jnp.float32
    n_samples: int = 0
    h_raw: jax.Array = None  # (K, K)  sum Xq^T Xq
    g_raw: jax.Array = None  # (K, K)  sum X^T Xq
    x_sum: jax.Array = None  # (K,)    sum of analog inputs (bias correction)
    observer: ActObserver = None

    def __post_init__(self):
        if self.h_raw is None:
            self.h_raw = jnp.zeros((self.k, self.k), self.dtype)
        if self.g_raw is None:
            self.g_raw = jnp.zeros((self.k, self.k), self.dtype)
        if self.x_sum is None:
            self.x_sum = jnp.zeros((self.k,), self.dtype)
        if self.observer is None:
            self.observer = ActObserver(k=self.k)

    def update(self, x: jax.Array, xq: jax.Array | None = None) -> None:
        """Accumulate one batch. ``x``: (n, K) analog inputs; ``xq``: their
        quantized-network counterparts (defaults to ``x`` for the common
        PTQ pipeline where the observer pass and quantization pass reuse
        the same inputs)."""
        x = x.reshape(-1, self.k).astype(self.dtype)
        xq = x if xq is None else xq.reshape(-1, self.k).astype(self.dtype)
        self.h_raw = self.h_raw + xq.T @ xq
        self.g_raw = self.g_raw + x.T @ xq
        self.x_sum = self.x_sum + jnp.sum(x, axis=0)
        self.n_samples += x.shape[0]
        self.observer.update(x)

    # -- finalized statistics -------------------------------------------------
    @property
    def x_mean(self) -> jax.Array:
        return self.x_sum / max(self.n_samples, 1)

    def optq_hessian(self, damp_frac: float = 0.01) -> jax.Array:
        """2 Xq Xq^T + eta I (Algorithm 2's proxy)."""
        h = 2.0 * self.h_raw
        eta = damp_frac * jnp.mean(jnp.diag(h)) + 1e-12
        return h + eta * jnp.eye(self.k, dtype=self.dtype)

    def gpfq_stats(self, eta: float = 1e-6) -> tuple[jax.Array, jax.Array]:
        """(H, G) of Theorem B.1 with H = (h_raw + eta*mean_diag*I)^(1/2)."""
        damp = eta * jnp.mean(jnp.diag(self.h_raw)) + 1e-12
        hh = self.h_raw + damp * jnp.eye(self.k, dtype=self.dtype)
        evals, evecs = jnp.linalg.eigh(hh)
        evals = jnp.maximum(evals, 0.0)
        h_half = (evecs * jnp.sqrt(evals)) @ evecs.T
        return h_half, self.g_raw

    def memory_bytes(self) -> int:
        itemsize = jnp.dtype(self.dtype).itemsize
        return (2 * self.k * self.k + self.k) * itemsize
