"""GPFQ (Lybrand & Saab, 2021) with accumulator-aware extensions (paper §3.2,
Algorithm 1) and the memory-efficient square-matrix reformulation
(Theorem B.1).

All greedy state runs in the *integer weight domain*: the caller's real
weights are divided by their per-channel scale up-front, so that the l1
budgets of Eq. 21 and the soft threshold of Eq. 16 are exact integer-unit
quantities. GPFQ's iteration is exactly scale-equivariant (the update rules
are linear in (W_i, U)), so this is functionally identical to running in the
real domain, as in the paper.

Shapes follow Algorithm 1:   W (K, C) rows = input dims, X (K, D) samples of
the *analog* network, Xq (K, D) samples of the quantized network (real,
dequantized). The memory-efficient path replaces (X, Xq) by (G H^-1, H) with
H = (Xq Xq^T + eta I)^(1/2) and G = X Xq^T, both (K, K) — Theorem B.1.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp

from .alphabet import (
    Alphabet,
    Budgets,
    l1_budget_zero_centered,
    strict_budgets,
)
from .ep_init import l1_projection_threshold, soft_threshold, tiled
from .sparsity import apply_mask, mask_2to4, validate_sparsity
from .quantizers import (
    ROUND_NEAREST,
    ROUNDING_SLACK,
    quantize_int,
    to_int_domain,
    weight_scales,
)


@dataclass(frozen=True)
class AxeConfig:
    """Accumulator-aware extension knobs (paper §3.3).

    ``p_bits`` is the *inner* accumulator bit width when ``tile`` is set
    (multi-stage accumulation) and the monolithic accumulator width
    otherwise. ``soft``/``strict`` toggle the two constraints — the
    AXE-HCO ablation of Table 2 is ``soft=False, strict=True``.
    """

    p_bits: int
    tile: int | None = None
    soft: bool = True
    strict: bool = True
    z_multiplier: float = 1.0


@dataclass
class GreedyResult:
    q_int: jax.Array  # (K, C) integer-domain quantized weights (float carrier)
    scale: jax.Array  # (1, C) per-channel scale
    w_alphabet: Alphabet
    act_alphabet: Alphabet | None = None
    axe: AxeConfig | None = None
    aux: dict = field(default_factory=dict)

    @property
    def w_q(self) -> jax.Array:
        """Dequantized real-domain weights."""
        return self.q_int * self.scale


# ---------------------------------------------------------------------------
# Constraint state shared by GPFQ and OPTQ loops.
# ---------------------------------------------------------------------------
def make_axe_state(
    w_int: jax.Array,
    axe: AxeConfig | None,
    act_alphabet: Alphabet | None,
    rounding: str,
    k: int,
):
    """Precompute (lambda, budgets, tile_ids) for the greedy loop.

    Returns a dict of arrays:
      lam      (n_tiles, C)  soft thresholds (0 disables)
      A, B     scalars       strict budget limits (Eq. 21)
      tile_ids (K,)          original-index -> tile id
      pos, neg (n_tiles, C)  running committed sums (init 0)
    or None when ``axe`` is None (plain GPFQ/OPTQ).
    """
    if axe is None:
        return None
    if act_alphabet is None:
        raise ValueError("AXE requires quantized activations (paper §3.3)")
    K, C = w_int.shape
    tile = axe.tile or k
    n_tiles = (k + tile - 1) // tile
    tile_ids = jnp.arange(K) // tile

    budgets: Budgets = strict_budgets(axe.p_bits, act_alphabet, ROUNDING_SLACK[rounding])

    if axe.soft:
        z = axe.z_multiplier * l1_budget_zero_centered(axe.p_bits, act_alphabet)
        # per (channel, tile) threshold; w tiles: (C, n_tiles, T)
        w_ct = tiled(w_int.T, tile)  # (C, n_tiles, T)
        lam = l1_projection_threshold(w_ct, z)  # (C, n_tiles)
        lam = lam.T  # (n_tiles, C)
    else:
        lam = jnp.zeros((n_tiles, C), w_int.dtype)

    return {
        "lam": lam,
        "A": jnp.asarray(budgets.A, w_int.dtype),
        "B": jnp.asarray(budgets.B, w_int.dtype),
        "mode": budgets.mode,
        "strict": axe.strict,
        "tile_ids": tile_ids,
        "pos": jnp.zeros((n_tiles, C), w_int.dtype),
        "neg": jnp.zeros((n_tiles, C), w_int.dtype),
    }


def constrain_row(
    v,
    t,
    lam,
    A,
    B,
    pos,
    neg,
    *,
    strict: bool,
    mode: str,
    alphabet: Alphabet,
    rounding: str,
):
    """Pi_lambda then Psi_{a,b} then Q for one row (paper Eq. 18), plus the
    budget bookkeeping of Eqs. 19-20.

    ``v`` (C,) raw values for input dim with tile id ``t``; ``pos``/``neg``
    (n_tiles, C) committed sums. The clip interval is clamped to contain 0 so
    a spent budget can never *force* a non-zero weight (zero is always
    admissible and consumes no budget). Returns (q_row, pos, neg).
    Shared by the GPFQ and OPTQ loops; traceable under jit (``strict``,
    ``mode``, ``rounding`` are static).
    """
    v = soft_threshold(v, lam[t])
    if strict:
        pos_t, neg_t = pos[t], neg[t]
        if mode == "split":
            lo = jnp.minimum(A - neg_t, 0.0)
            hi = jnp.maximum(B - pos_t, 0.0)
        else:  # joint l1 budget (signed activations)
            rem = jnp.maximum(B - (pos_t - neg_t), 0.0)
            lo, hi = -rem, rem
        v = jnp.clip(v, lo, hi)
    q = quantize_int(v, alphabet, rounding)
    pos = pos.at[t].add(jnp.maximum(q, 0.0))
    neg = neg.at[t].add(jnp.minimum(q, 0.0))
    return q, pos, neg


# ---------------------------------------------------------------------------
# The GPFQ greedy loop (shared by the standard and memory-efficient paths).
# ---------------------------------------------------------------------------
@partial(
    jax.jit,
    static_argnames=("w_bits", "w_signed", "rounding", "strict", "mode", "has_axe", "has_mask"),
)
def _gpfq_loop(
    w_int,  # (K, C) integer-domain weights
    xg,  # (K, D) analog inputs (rows)
    xh,  # (K, D) quantized inputs (rows)
    lam,  # (n_tiles, C) or (1, C) zeros
    A,
    B,
    tile_ids,  # (K,)
    pos0,
    neg0,
    mask,  # (K, C) {0,1} sparsity support, or (1, C) dummy when dense
    *,
    w_bits: int,
    w_signed: bool,
    rounding: str,
    strict: bool,
    mode: str,
    has_axe: bool,
    has_mask: bool,
):
    K, C = w_int.shape
    D = xg.shape[1]
    alphabet = Alphabet(bits=w_bits, signed=w_signed, symmetric=True)
    h_norm2 = jnp.maximum(jnp.sum(xh * xh, axis=1), 1e-20)  # (K,)
    hg_dot = jnp.sum(xh * xg, axis=1)  # (K,) <Xq_i, X_i>

    def body(i, carry):
        U, Q, pos, neg = carry
        h_i = jax.lax.dynamic_slice_in_dim(xh, i, 1, axis=0)[0]  # (D,)
        w_i = jax.lax.dynamic_slice_in_dim(w_int, i, 1, axis=0)[0]  # (C,)
        g_i = jax.lax.dynamic_slice_in_dim(xg, i, 1, axis=0)[0]  # (D,)
        denom = h_norm2[i]
        v = w_i * (hg_dot[i] / denom) + (h_i @ U) / denom  # (C,)

        if has_mask:
            # mask-then-quantize: pruned positions quantize to exactly 0 (zero
            # passes the soft threshold / budget clip untouched and consumes no
            # budget); the residual U keeps the full w_i term, so the pruned
            # energy is redistributed into later rows by the greedy update
            m_i = jax.lax.dynamic_slice_in_dim(mask, i, 1, axis=0)[0]  # (C,)
            v = v * m_i

        if has_axe:
            q, pos, neg = constrain_row(
                v, tile_ids[i], lam, A, B, pos, neg,
                strict=strict, mode=mode, alphabet=alphabet, rounding=rounding,
            )
        else:
            q = quantize_int(v, alphabet, rounding)

        U = U + jnp.outer(g_i, w_i) - jnp.outer(h_i, q)
        Q = jax.lax.dynamic_update_slice_in_dim(Q, q[None, :], i, axis=0)
        return (U, Q, pos, neg)

    U0 = jnp.zeros((D, C), w_int.dtype)
    Q0 = jnp.zeros_like(w_int)
    U, Q, pos, neg = jax.lax.fori_loop(0, K, body, (U0, Q0, pos0, neg0))
    return Q, U, pos, neg


def _prepare(w, w_alphabet):
    scale = weight_scales(w, w_alphabet)  # (1, C)
    return to_int_domain(w, scale), scale


def _run(
    w,
    xg,
    xh,
    w_alphabet: Alphabet,
    act_alphabet: Alphabet | None,
    axe: AxeConfig | None,
    rounding: str,
    act_order: bool,
    sparsity: str | None = None,
):
    validate_sparsity(sparsity)
    w_int, scale = _prepare(w, w_alphabet)
    K = w.shape[0]
    state = make_axe_state(w_int, axe, act_alphabet, rounding, K)

    if sparsity is not None:
        # magnitude top-2 per group-of-4, ranked on the integer-domain target
        # (per-channel positive scale preserves within-column ordering);
        # computed on the *original* K indexing so the pattern survives
        # act_order permutation of the solve
        mask = mask_2to4(w_int)
    else:
        mask = jnp.ones((1, w.shape[1]), w_int.dtype)

    if act_order:
        # descending diagonal of the Hessian proxy 2 Xq Xq^T == row norms of Xq
        order = jnp.argsort(-jnp.sum(xh * xh, axis=1))
    else:
        order = jnp.arange(K)
    inv_order = jnp.argsort(order)

    if state is None:
        C = w.shape[1]
        lam = jnp.zeros((1, C), w_int.dtype)
        A = jnp.asarray(0.0)
        B = jnp.asarray(0.0)
        tile_ids = jnp.zeros((K,), jnp.int32)
        pos0 = jnp.zeros((1, C), w_int.dtype)
        neg0 = jnp.zeros((1, C), w_int.dtype)
        strict, mode, has_axe = False, "split", False
    else:
        lam, A, B = state["lam"], state["A"], state["B"]
        tile_ids, pos0, neg0 = state["tile_ids"], state["pos"], state["neg"]
        strict, mode, has_axe = state["strict"], state["mode"], True

    Q_perm, U, pos, neg = _gpfq_loop(
        w_int[order],
        xg[order],
        xh[order],
        lam,
        A,
        B,
        tile_ids[order] if state is not None else tile_ids,
        pos0,
        neg0,
        mask[order] if sparsity is not None else mask,
        w_bits=w_alphabet.bits,
        w_signed=w_alphabet.signed,
        rounding=rounding,
        strict=strict,
        mode=mode,
        has_axe=has_axe,
        has_mask=sparsity is not None,
    )
    q_int = Q_perm[inv_order]
    aux = {"residual_norm": jnp.linalg.norm(U), "pos": pos, "neg": neg}
    return GreedyResult(
        q_int=q_int,
        scale=scale,
        w_alphabet=w_alphabet,
        act_alphabet=act_alphabet,
        axe=axe,
        aux=aux,
    )


def gpfq(
    w: jax.Array,
    x: jax.Array,
    xq: jax.Array,
    w_alphabet: Alphabet,
    act_alphabet: Alphabet | None = None,
    axe: AxeConfig | None = None,
    rounding: str = ROUND_NEAREST,
    act_order: bool = False,
    sparsity: str | None = None,
) -> GreedyResult:
    """Standard GPFQ (Algorithm 1). ``x``/``xq``: (K, D) sample rows.

    ``sparsity="2:4"`` inserts a mask-then-quantize step: a per-group-of-4
    magnitude mask is fixed before the greedy solve and the error feedback
    runs against the masked support.
    """
    if w.shape[0] != x.shape[0] or x.shape != xq.shape:
        raise ValueError(f"shape mismatch: w {w.shape}, x {x.shape}, xq {xq.shape}")
    return _run(w, x, xq, w_alphabet, act_alphabet, axe, rounding, act_order, sparsity)


def me_stats(x: jax.Array, xq: jax.Array, eta: float = 1e-6) -> tuple[jax.Array, jax.Array]:
    """(H, G) of Theorem B.1: H = (Xq Xq^T + eta*mean_diag*I)^(1/2), G = X Xq^T.

    Streaming accumulation of Xq Xq^T / X Xq^T lives in
    :mod:`repro.core.calibration`; this helper is the from-samples path.
    """
    hh = xq @ xq.T
    damp = eta * jnp.mean(jnp.diag(hh)) + 1e-12
    hh = hh + damp * jnp.eye(hh.shape[0], dtype=hh.dtype)
    evals, evecs = jnp.linalg.eigh(hh)
    evals = jnp.maximum(evals, 0.0)
    h_half = (evecs * jnp.sqrt(evals)) @ evecs.T
    g = x @ xq.T
    return h_half, g


def gpfq_memory_efficient(
    w: jax.Array,
    h_half: jax.Array,
    g: jax.Array,
    w_alphabet: Alphabet,
    act_alphabet: Alphabet | None = None,
    axe: AxeConfig | None = None,
    rounding: str = ROUND_NEAREST,
    act_order: bool = False,
    sparsity: str | None = None,
) -> GreedyResult:
    """Memory-efficient GPFQ (Theorem B.1): GPFQ(W, G H^-1, H)."""
    k = w.shape[0]
    if h_half.shape != (k, k) or g.shape != (k, k):
        raise ValueError("h_half and g must be (K, K)")
    # (G H^-1)^T = H^-1 G^T  (H symmetric PSD)
    gh_inv = jnp.linalg.solve(h_half, g.T).T
    return _run(w, gh_inv, h_half, w_alphabet, act_alphabet, axe, rounding, act_order, sparsity)
