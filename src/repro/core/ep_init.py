"""Euclidean l1-ball projection, the Lagrangian threshold lambda (Eqs. 15-16),
and the EP-init baseline of A2Q+ (Colbert et al., 2024) evaluated in the PTQ
setting (paper §2.3 / §4.1).

The projection follows Duchi et al. (2008): for w in R^K and radius Z,

    v* = argmin_v  0.5 * ||v - w||^2   s.t.  ||v||_1 <= Z
    v*_i = sign(w_i) * max(|w_i| - lambda, 0)
    lambda = (sum_{i<=rho} mu_i - Z) / rho          (Eq. 16)

with mu = sort(|w|, desc) and rho the number of non-zeros in v*. All functions
are vectorized over channels (and, for multi-stage accumulation, over tiles):
the channel/tile axes are leading and the reduction axis is the last one.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .alphabet import Alphabet
from .quantizers import ROUND_ZERO, quantize_int


def soft_threshold(x: jax.Array, lam: jax.Array) -> jax.Array:
    """Pi_lambda(x) = sign(x) * relu(|x| - lambda)  (paper Eq. 14's shrinkage)."""
    return jnp.sign(x) * jax.nn.relu(jnp.abs(x) - lam)


def l1_projection_threshold(w: jax.Array, radius: jax.Array | float) -> jax.Array:
    """Lagrangian lambda of the projection of ``w`` onto the l1 ball (Eq. 16).

    ``w``: (..., K); ``radius``: scalar or broadcastable to (...,).
    Returns lambda >= 0 with shape (...,). lambda == 0 iff ||w||_1 <= radius.
    """
    w = jnp.asarray(w)
    radius = jnp.broadcast_to(jnp.asarray(radius, w.dtype), w.shape[:-1])
    k = w.shape[-1]
    mu = jnp.sort(jnp.abs(w), axis=-1)[..., ::-1]  # descending magnitudes
    cssv = jnp.cumsum(mu, axis=-1) - radius[..., None]
    idx = jnp.arange(1, k + 1, dtype=w.dtype)
    # rho = max { j : mu_j > (cumsum_j - Z) / j }
    cond = mu * idx > cssv
    rho = jnp.sum(cond, axis=-1)  # at least 1 whenever ||w||_1 > Z
    rho_safe = jnp.maximum(rho, 1)
    gathered = jnp.take_along_axis(cssv, (rho_safe - 1)[..., None], axis=-1)[..., 0]
    lam = gathered / rho_safe.astype(w.dtype)
    inside = jnp.sum(jnp.abs(w), axis=-1) <= radius
    return jnp.where(inside, 0.0, jnp.maximum(lam, 0.0))


def project_l1_ball(w: jax.Array, radius: jax.Array | float) -> jax.Array:
    """Euclidean projection of ``w`` (..., K) onto the l1 ball of ``radius``."""
    lam = l1_projection_threshold(w, radius)
    return soft_threshold(w, lam[..., None])


def ep_init(
    w_int: jax.Array,
    radius: jax.Array | float,
    alphabet: Alphabet,
) -> jax.Array:
    """EP-init baseline (A2Q+ applied post-training, paper §2.3).

    ``w_int``: integer-domain weights, shape (..., K) with K the reduction
    (input) axis. Projects each row onto the l1 ball of ``radius`` (integer
    units) and quantizes with **round-to-zero**, which guarantees
    |Q(v_i)| <= |v_i| and hence ||q||_1 <= ||v||_1 <= radius. No error
    correction — this is the property AXE improves on.
    """
    v = project_l1_ball(w_int, radius)
    return quantize_int(v, alphabet, rounding=ROUND_ZERO)


def tiled(w_int: jax.Array, tile: int) -> jax.Array:
    """Reshape (..., K) -> (..., n_tiles, T), zero-padding K to a tile multiple.

    Zero padding is safe for every consumer in this package: zeros have no l1
    mass, quantize to zero, and contribute nothing to dot products.
    """
    k = w_int.shape[-1]
    n_tiles = (k + tile - 1) // tile
    pad = n_tiles * tile - k
    if pad:
        w_int = jnp.pad(w_int, [(0, 0)] * (w_int.ndim - 1) + [(0, pad)])
    return w_int.reshape(*w_int.shape[:-1], n_tiles, tile)


def untiled(w_tiles: jax.Array, k: int) -> jax.Array:
    """Inverse of :func:`tiled` — flatten tiles and strip padding."""
    flat = w_tiles.reshape(*w_tiles.shape[:-2], -1)
    return flat[..., :k]
