"""l1-ball projection / lambda threshold (Eqs. 15-16) and EP-init tests."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given
from hypothesis import strategies as st

from repro.core import (
    ep_init,
    l1_projection_threshold,
    project_l1_ball,
    soft_threshold,
    tiled,
    untiled,
    weight_alphabet,
)


def _reference_project(w, z):
    """O(K log K) reference projection (Duchi et al. 2008), pure numpy."""
    w = np.asarray(w, np.float64)
    if np.abs(w).sum() <= z:
        return w
    mu = np.sort(np.abs(w))[::-1]
    cssv = np.cumsum(mu) - z
    idx = np.arange(1, len(w) + 1)
    rho = idx[mu * idx > cssv][-1]
    lam = cssv[rho - 1] / rho
    return np.sign(w) * np.maximum(np.abs(w) - lam, 0)


@given(
    seed=st.integers(0, 10_000),
    k=st.integers(1, 64),
    z=st.floats(0.1, 50.0),
)
def test_projection_matches_reference(seed, k, z):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(k,)) * rng.uniform(0.1, 5)
    got = np.asarray(project_l1_ball(jnp.asarray(w, jnp.float32), z))
    want = _reference_project(w, z)
    np.testing.assert_allclose(got, want, atol=1e-4)


@given(seed=st.integers(0, 10_000), k=st.integers(1, 64), z=st.floats(0.1, 50.0))
def test_projection_satisfies_constraint(seed, k, z):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(k,)) * 3, jnp.float32)
    v = project_l1_ball(w, z)
    assert float(jnp.sum(jnp.abs(v))) <= z * (1 + 1e-4)


def test_lambda_zero_inside_ball():
    w = jnp.asarray([0.5, -0.25, 0.1])
    lam = l1_projection_threshold(w, 10.0)
    assert float(lam) == 0.0


def test_lambda_batched_channels(rng):
    w = jnp.asarray(rng.normal(size=(8, 32)), jnp.float32)  # 8 channels
    lam = l1_projection_threshold(w, 2.0)
    assert lam.shape == (8,)
    v = soft_threshold(w, lam[:, None])
    l1 = np.asarray(jnp.sum(jnp.abs(v), axis=-1))
    assert np.all(l1 <= 2.0 * (1 + 1e-4))


def test_soft_threshold_shrinks():
    x = jnp.asarray([-3.0, -1.0, 0.5, 2.0])
    y = soft_threshold(x, 1.0)
    np.testing.assert_allclose(np.asarray(y), [-2.0, 0.0, 0.0, 1.0])


@given(seed=st.integers(0, 1000), k=st.integers(1, 50), tile=st.integers(1, 16))
def test_tiled_untiled_roundtrip(seed, k, tile):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(3, k)), jnp.float32)
    t = tiled(w, tile)
    assert t.shape[-1] == tile
    back = untiled(t, k)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(w))


@given(seed=st.integers(0, 5000), z=st.floats(1.0, 20.0))
def test_ep_init_l1_guarantee(seed, z):
    """RTZ after projection keeps the integer l1 norm within the radius."""
    rng = np.random.default_rng(seed)
    w_int = jnp.asarray(rng.normal(size=(4, 48)) * 5, jnp.float32)
    q = ep_init(w_int, z, weight_alphabet(4))
    l1 = np.asarray(jnp.sum(jnp.abs(q), axis=-1))
    assert np.all(l1 <= z + 1e-5)
    assert np.all(np.asarray(q) == np.round(np.asarray(q)))  # integers
