"""End-to-end PTQ pipeline: certification, quality ordering, kernel parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import PTQConfig
from repro.data import DataConfig, TokenBatcher
from repro.models.transformer import init_model
from repro.quant import calibrate_and_quantize, quantized_forward
from repro.quant.pipeline import float_ppl, quantized_ppl


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tiny-lm-xs")
    params = init_model(jax.random.key(0), cfg)
    data = TokenBatcher(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=2))
    calib = [data.batch(100 + i) for i in range(2)]
    evalb = list(data.eval_batches(2))
    return cfg, params, calib, evalb


def test_pipeline_certified_and_close_to_float(setup):
    cfg, params, calib, evalb = setup
    ptq = PTQConfig(w_bits=4, act_bits=8, p_bits=16, tile=64)
    qm = calibrate_and_quantize(params, cfg, calib, ptq)
    assert qm.certified
    summary = qm.cert_summary()
    assert summary["n_certified"] == cfg.n_layers * 7
    ppl_f = float_ppl(params, cfg, evalb)
    ppl_q = quantized_ppl(qm, evalb)
    # untrained net: quantization should not blow up perplexity
    assert ppl_q < ppl_f * 2.0


def test_unconstrained_base_not_certified_at_small_p(setup):
    """Base GPFQ (no AXE) at W4A8 genuinely risks a 14-bit accumulator."""
    from repro.core import certify

    cfg, params, calib, _ = setup
    ptq = PTQConfig(w_bits=4, act_bits=8, constrain=False)
    qm = calibrate_and_quantize(params, cfg, calib, ptq)
    bad = 0
    for b in qm.blocks:
        for ql in (b.wq, b.wo, b.wg, b.wd):
            cert = certify(ql.q_int, ptq.act_alphabet, p_bits=14, tile=None)
            bad += 0 if bool(cert) else 1
    assert bad > 0


def test_axe_monolithic_16_certified(setup):
    cfg, params, calib, _ = setup
    ptq = PTQConfig(w_bits=4, act_bits=8, p_bits=16, tile=None)
    qm = calibrate_and_quantize(params, cfg, calib, ptq)
    assert qm.certified


def test_quantized_forward_shapes(setup):
    cfg, params, calib, evalb = setup
    ptq = PTQConfig(w_bits=4, act_bits=8, p_bits=16, tile=64)
    qm = calibrate_and_quantize(params, cfg, calib, ptq)
    logits = quantized_forward(qm, evalb[0])
    assert logits.shape == (*evalb[0]["tokens"].shape, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_kernel_path_matches_simulation(setup):
    """w4a8 Pallas kernel (interpret) == fake-quant simulation for one linear."""
    from repro.core.quantizers import quantize_act
    from repro.kernels import pack_int4, quantized_linear_w4a8

    cfg, params, calib, _ = setup
    ptq = PTQConfig(w_bits=4, act_bits=8, p_bits=16, tile=64)
    qm = calibrate_and_quantize(params, cfg, calib, ptq)
    b0 = qm.blocks[0]
    ql = b0.wq
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, cfg.d_model)), jnp.float32)

    # simulation path (QuantizedLinear.__call__ without bias)
    from repro.core.quantizers import fake_quantize_act

    y_sim = fake_quantize_act(x, ql.act) @ ql.w_q

    # kernel path: uint8 codes x packed int4
    codes = jnp.asarray(quantize_act(x, ql.act), jnp.uint8)
    packed = pack_int4(jnp.asarray(np.asarray(ql.q_int, np.int8)))
    y_ker = quantized_linear_w4a8(
        codes, packed, ql.scale[0], ql.act.scale, ql.act.zero_point,
        interpret=True, block_m=64, block_n=64, block_k=64,
    )
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_sim),
                               rtol=1e-4, atol=1e-4)


def test_dense_ppl_matches_pre_refactor_pipeline(setup):
    """The registry refactor is behavior-preserving on the dense family:
    golden perplexities recorded from the pre-refactor monolithic loop on
    the same seed/batches (default W4A8 / T=128 / P=16 config)."""
    cfg, params, calib, evalb = setup
    qm = calibrate_and_quantize(params, cfg, calib, PTQConfig())
    assert qm.certified
    # rtol accommodates cross-jax/BLAS reduction-order drift while still
    # catching any semantic change in the recipe
    np.testing.assert_allclose(float_ppl(params, cfg, evalb),
                               818.2583482083, rtol=1e-4)
    np.testing.assert_allclose(quantized_ppl(qm, evalb),
                               813.0594335265, rtol=1e-4)
    np.testing.assert_allclose(qm.cert_summary()["min_headroom_bits"],
                               0.005602534910700285, rtol=1e-3)


def test_unregistered_family_raises_with_registry_listing():
    """The adapter-lookup error names what IS registered and points at the
    protocol docs (no more dangling DESIGN.md §4 reference)."""
    from repro.quant.families import get_adapter, registered_families

    with pytest.raises(NotImplementedError) as ei:
        get_adapter("mixer", "hyena")
    msg = str(ei.value)
    for name in registered_families()["mixer"]:
        assert name in msg
    assert "BlockAdapter" in msg
    assert "docs/families.md" in msg
    assert "DESIGN.md" not in msg
