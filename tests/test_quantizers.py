"""Quantizer unit tests: rounding modes, scales, activation calibration."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given
from hypothesis import strategies as st

from repro.core import (
    ROUND_NEAREST,
    ROUND_ZERO,
    act_alphabet,
    calibrate_act_quant,
    dequantize_act,
    fake_quantize_act,
    quantize_act,
    quantize_int,
    quantize_weights_rtn,
    weight_alphabet,
    weight_scales,
)


def test_round_to_zero_magnitude_never_grows():
    x = jnp.asarray([-2.7, -0.5, 0.0, 0.49, 1.99, 3.2])
    q = quantize_int(x, weight_alphabet(4), rounding=ROUND_ZERO)
    assert np.all(np.abs(np.asarray(q)) <= np.abs(np.asarray(x)))
    np.testing.assert_array_equal(np.asarray(q), [-2.0, 0.0, 0.0, 0.0, 1.0, 3.0])


def test_round_nearest():
    x = jnp.asarray([-2.7, -0.5, 0.49, 1.5, 7.9, 100.0])
    q = quantize_int(x, weight_alphabet(4), rounding=ROUND_NEAREST)
    # banker's rounding on .5 (rint), clip at alphabet edge
    np.testing.assert_array_equal(np.asarray(q), [-3.0, 0.0, 0.0, 2.0, 7.0, 7.0])


def test_weight_scales_per_channel(rng):
    w = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)
    s = weight_scales(w, weight_alphabet(4))
    assert s.shape == (1, 4)
    # max |w/s| lands exactly on qmax
    np.testing.assert_allclose(np.abs(np.asarray(w / s)).max(axis=0), 7.0, rtol=1e-5)


@given(bits=st.integers(2, 8))
def test_rtn_roundtrip_error_bound(bits):
    rng = np.random.default_rng(bits)
    w = jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)
    q, s = quantize_weights_rtn(w, weight_alphabet(bits))
    # RTN error per element <= s/2
    err = np.abs(np.asarray(q * s - w))
    assert np.all(err <= np.asarray(s) / 2 + 1e-6)


def test_act_quant_zero_exact():
    """Zero must be exactly representable (uniform integer quantization)."""
    p = calibrate_act_quant(-1.3, 2.7, act_alphabet(8))
    z = dequantize_act(jnp.asarray(float(p.zero_point)), p)
    assert float(z) == 0.0


def test_act_quant_codes_in_range(rng):
    x = jnp.asarray(rng.normal(size=(128,)) * 3, jnp.float32)
    p = calibrate_act_quant(np.percentile(x, 1), np.percentile(x, 99), act_alphabet(8))
    codes = np.asarray(quantize_act(x, p))
    assert codes.min() >= 0 and codes.max() <= 255


def test_fake_quant_error_bound(rng):
    x = jnp.asarray(rng.uniform(-2, 2, size=(256,)), jnp.float32)
    p = calibrate_act_quant(-2.0, 2.0, act_alphabet(8))
    xq = fake_quantize_act(x, p)
    assert float(jnp.max(jnp.abs(xq - x))) <= p.scale / 2 + 1e-6


def test_signed_act_quant_symmetric():
    p = calibrate_act_quant(-3.0, 1.0, act_alphabet(8, signed=True))
    assert p.zero_point == 0
    assert abs(p.scale - 3.0 / 127) < 1e-9
