"""OPTQ tests: error correction, Hessian machinery, AXE budget compliance."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    AxeConfig,
    act_alphabet,
    calibrate_act_quant,
    certify,
    fake_quantize_act,
    hessian_proxy,
    inverse_cholesky,
    optq,
    quantize_weights_rtn,
    weight_alphabet,
)


def _layer(seed, k=48, c=16, d=192, scale=0.5):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(k, c)) * scale, jnp.float32)
    x = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)
    aq = calibrate_act_quant(np.percentile(x, 1), np.percentile(x, 99), act_alphabet(8))
    xq = fake_quantize_act(x, aq)
    return w, x, xq


def _recon_err(w, x, xq, w_q):
    return float(jnp.linalg.norm(x.T @ w - xq.T @ w_q))


def test_hessian_proxy_spd():
    _, _, xq = _layer(0)
    h = hessian_proxy(xq)
    evals = np.linalg.eigvalsh(np.asarray(h))
    assert evals.min() > 0


def test_inverse_cholesky_factorization():
    _, _, xq = _layer(1, k=24)
    h = np.asarray(hessian_proxy(xq), np.float64)
    r = np.asarray(inverse_cholesky(jnp.asarray(h, jnp.float32)), np.float64)
    assert np.allclose(r, np.triu(r))  # upper triangular
    np.testing.assert_allclose(r.T @ r, np.linalg.inv(h), rtol=2e-2, atol=2e-4)


def test_optq_beats_rtn():
    w, x, xq = _layer(0, k=64, c=24, d=256)
    wa = weight_alphabet(4)
    r = optq(w, hessian_proxy(xq), wa)
    q_rtn, s_rtn = quantize_weights_rtn(w, wa)
    assert _recon_err(w, x, xq, r.w_q) < _recon_err(w, x, xq, q_rtn * s_rtn)


def test_act_order_consistent():
    """act_order permutes internally but output rows stay aligned with input."""
    w, x, xq = _layer(2, k=32, c=8)
    wa = weight_alphabet(8)
    h = hessian_proxy(xq)
    r1 = optq(w, h, wa, act_order=True)
    # at 8 bits quantization error is tiny; dequantized weights ~ originals
    np.testing.assert_allclose(np.asarray(r1.w_q), np.asarray(w), atol=0.05)


@given(
    seed=st.integers(0, 50),
    p_bits=st.integers(10, 16),
    tile=st.sampled_from([8, 16, None]),
)
@settings(max_examples=10)
def test_axe_optq_certified(seed, p_bits, tile):
    w, x, xq = _layer(seed, k=32, c=8, d=96, scale=2.0)
    wa, na = weight_alphabet(4), act_alphabet(8)
    axe = AxeConfig(p_bits=p_bits, tile=tile)
    r = optq(w, hessian_proxy(xq), wa, na, axe=axe)
    cert = certify(r.q_int, na, p_bits, tile)
    assert bool(cert), (cert.worst_hi, cert.worst_lo)


def test_axe_noop_when_loose():
    w, _, xq = _layer(3, k=32, c=8)
    wa, na = weight_alphabet(4), act_alphabet(8)
    h = hessian_proxy(xq)
    r_plain = optq(w, h, wa)
    r_loose = optq(w, h, wa, na, axe=AxeConfig(p_bits=32, tile=None))
    np.testing.assert_array_equal(np.asarray(r_plain.q_int), np.asarray(r_loose.q_int))
