"""Paged decode-attention kernel vs the gather reference — interpret-mode
shape/raggedness sweeps (the w4a8_mm testing pattern), plus agreement of
the gather reference with the dense-slab ``attention_decode`` math."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention import (
    paged_attention_reference,
    paged_decode_attention,
)


def _random_case(rng, B, nkv, g, hd, bs, P, extra_blocks=4, dtype=jnp.float32):
    """Distinct pages per row, ragged lengths, sentinel tail entries."""
    nb = B * P + extra_blocks
    nh = nkv * g
    q = jnp.asarray(rng.normal(size=(B, nh, hd)), dtype)
    kp = jnp.asarray(rng.normal(size=(nb, bs, nkv, hd)), dtype)
    vp = jnp.asarray(rng.normal(size=(nb, bs, nkv, hd)), dtype)
    tab = np.full((B, P), nb, np.int32)  # sentinel = nb
    perm = rng.permutation(nb)
    lens = rng.integers(1, P * bs + 1, size=B).astype(np.int32)
    o = 0
    for b in range(B):
        n_pages = -(-int(lens[b]) // bs)
        tab[b, :n_pages] = perm[o:o + n_pages]
        o += n_pages
    return q, kp, vp, jnp.asarray(tab), jnp.asarray(lens)


@pytest.mark.parametrize(
    "B,nkv,g,hd,bs,P",
    [
        (1, 1, 1, 8, 4, 2),
        (3, 2, 2, 16, 8, 4),  # GQA
        (4, 2, 1, 32, 16, 3),  # MHA-as-GQA
        (2, 4, 4, 8, 8, 2),
    ],
)
def test_kernel_matches_reference(rng, B, nkv, g, hd, bs, P):
    q, kp, vp, tab, lens = _random_case(rng, B, nkv, g, hd, bs, P)
    ref = paged_attention_reference(q, kp, vp, tab, lens)
    ker = paged_decode_attention(q, kp, vp, tab, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_kernel_matches_reference_softcap(rng):
    q, kp, vp, tab, lens = _random_case(rng, 3, 2, 2, 16, 8, 4)
    ref = paged_attention_reference(q, kp, vp, tab, lens, softcap=10.0)
    ker = paged_decode_attention(q, kp, vp, tab, lens, softcap=10.0,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_kernel_exact_page_boundary_lengths(rng):
    """Lengths exactly on page boundaries (incl. the full table) — the
    last-page-exactly-full edge the scheduler also exercises."""
    B, nkv, g, hd, bs, P = 3, 2, 2, 8, 8, 2
    q, kp, vp, tab, _ = _random_case(rng, B, nkv, g, hd, bs, P)
    lens = jnp.asarray([bs, 2 * bs, 1], jnp.int32)
    ref = paged_attention_reference(q, kp, vp, tab, lens)
    ker = paged_decode_attention(q, kp, vp, tab, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_reference_matches_dense_slab_math(rng):
    """The gather reference is bit-identical to the dense ``(B, S, nkv,
    hd)`` decode-attention math when pages are laid out contiguously —
    the property the engine golden tests build on."""
    B, nkv, g, hd, bs, P = 2, 2, 2, 16, 8, 2
    nh = nkv * g
    S = P * bs
    q = jnp.asarray(rng.normal(size=(B, nh, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, nkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, nkv, hd)), jnp.float32)
    lens = jnp.asarray([5, 13], jnp.int32)

    # dense-slab math (attention_decode's score path, index = lens - 1)
    qg = q.reshape(B, nkv, g, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k).astype(jnp.float32) / math.sqrt(hd)
    valid = jnp.arange(S)[None, :] < lens[:, None]
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    dense = jnp.einsum("bkgs,bskd->bkgd", p, v).reshape(B, nh, hd)

    # the same KV as per-row contiguous pages
    kp = k.reshape(B * P, bs, nkv, hd)
    vp = v.reshape(B * P, bs, nkv, hd)
    tab = jnp.arange(B * P, dtype=jnp.int32).reshape(B, P)
    ref = paged_attention_reference(q, kp, vp, tab, lens)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(dense))
