"""Paged decode-attention kernel vs the gather reference — interpret-mode
shape/raggedness sweeps (the w4a8_mm testing pattern), plus agreement of
the gather reference with the dense-slab ``attention_decode`` math, for
both the float and the int8-quantized KV paths."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention import (
    dequantize_kv_pages,
    paged_attention_reference,
    paged_decode_attention,
    quantize_kv_pages,
)


def _random_case(rng, B, nkv, g, hd, bs, P, extra_blocks=4, dtype=jnp.float32):
    """Distinct pages per row, ragged lengths, sentinel tail entries."""
    nb = B * P + extra_blocks
    nh = nkv * g
    q = jnp.asarray(rng.normal(size=(B, nh, hd)), dtype)
    kp = jnp.asarray(rng.normal(size=(nb, bs, nkv, hd)), dtype)
    vp = jnp.asarray(rng.normal(size=(nb, bs, nkv, hd)), dtype)
    tab = np.full((B, P), nb, np.int32)  # sentinel = nb
    perm = rng.permutation(nb)
    lens = rng.integers(1, P * bs + 1, size=B).astype(np.int32)
    o = 0
    for b in range(B):
        n_pages = -(-int(lens[b]) // bs)
        tab[b, :n_pages] = perm[o:o + n_pages]
        o += n_pages
    return q, kp, vp, jnp.asarray(tab), jnp.asarray(lens)


def _quantize_case(kp, vp):
    (kc, ks), (vc, vs) = quantize_kv_pages(kp), quantize_kv_pages(vp)
    return kc, vc, {"k_scales": ks, "v_scales": vs}


@pytest.mark.parametrize(
    "B,nkv,g,hd,bs,P",
    [
        (1, 1, 1, 8, 4, 2),
        (3, 2, 2, 16, 8, 4),  # GQA
        (4, 2, 1, 32, 16, 3),  # MHA-as-GQA
        (2, 4, 4, 8, 8, 2),
    ],
)
def test_kernel_matches_reference(rng, B, nkv, g, hd, bs, P):
    q, kp, vp, tab, lens = _random_case(rng, B, nkv, g, hd, bs, P)
    ref = paged_attention_reference(q, kp, vp, tab, lens)
    ker = paged_decode_attention(q, kp, vp, tab, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_kernel_matches_reference_softcap(rng):
    q, kp, vp, tab, lens = _random_case(rng, 3, 2, 2, 16, 8, 4)
    ref = paged_attention_reference(q, kp, vp, tab, lens, softcap=10.0)
    ker = paged_decode_attention(q, kp, vp, tab, lens, softcap=10.0,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_kernel_exact_page_boundary_lengths(rng):
    """Lengths exactly on page boundaries (incl. the full table) — the
    last-page-exactly-full edge the scheduler also exercises."""
    B, nkv, g, hd, bs, P = 3, 2, 2, 8, 8, 2
    q, kp, vp, tab, _ = _random_case(rng, B, nkv, g, hd, bs, P)
    lens = jnp.asarray([bs, 2 * bs, 1], jnp.int32)
    ref = paged_attention_reference(q, kp, vp, tab, lens)
    ker = paged_decode_attention(q, kp, vp, tab, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_reference_matches_dense_slab_math(rng):
    """The gather reference is bit-identical to the dense ``(B, S, nkv,
    hd)`` decode-attention math when pages are laid out contiguously —
    the property the engine golden tests build on."""
    B, nkv, g, hd, bs, P = 2, 2, 2, 16, 8, 2
    nh = nkv * g
    S = P * bs
    q = jnp.asarray(rng.normal(size=(B, nh, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, nkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, nkv, hd)), jnp.float32)
    lens = jnp.asarray([5, 13], jnp.int32)

    # dense-slab math (attention_decode's score path, index = lens - 1)
    qg = q.reshape(B, nkv, g, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k).astype(jnp.float32) / math.sqrt(hd)
    valid = jnp.arange(S)[None, :] < lens[:, None]
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    dense = jnp.einsum("bkgs,bskd->bkgd", p, v).reshape(B, nh, hd)

    # the same KV as per-row contiguous pages
    kp = k.reshape(B * P, bs, nkv, hd)
    vp = v.reshape(B * P, bs, nkv, hd)
    tab = jnp.arange(B * P, dtype=jnp.int32).reshape(B, P)
    ref = paged_attention_reference(q, kp, vp, tab, lens)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(dense))


# ---------------------------------------------------------------------------
# Ragged-shape parity sweep: interpret-mode kernel vs gather reference over
# page-boundary lengths and awkward head shapes — float AND int8 KV paths.
# ---------------------------------------------------------------------------
def _sweep_lens(rng, B, bs, P, mode):
    """Row lengths exercising the mode: not-divisible, exact last block,
    and the ±1 brackets around an exact last block."""
    full = P * bs
    if mode == "ragged":  # S % bs != 0 everywhere
        lens = [(i * bs + 1 + int(rng.integers(0, bs - 1))) % full or 1
                for i in range(B)]
        lens = [ln if ln % bs else ln - 1 or 1 for ln in lens]
    elif mode == "exact":  # every row ends exactly on a page boundary
        lens = [((i % P) + 1) * bs for i in range(B)]
    else:  # "exact±1": brackets around the boundary (and the full table)
        lens = [max(1, bs - 1), bs + 1, full, max(1, full - 1)][:B]
    return jnp.asarray(lens, jnp.int32)


@pytest.mark.parametrize("kv", ["float", "int8"])
@pytest.mark.parametrize("bs", [8, 16, 128])
@pytest.mark.parametrize("mode", ["ragged", "exact", "exact±1"])
def test_kernel_parity_sweep_block_sizes(rng, kv, bs, mode):
    B, nkv, g, hd, P = 4, 2, 2, 16, 2 if bs == 128 else 3
    q, kp, vp, tab, _ = _random_case(rng, B, nkv, g, hd, bs, P)
    lens = _sweep_lens(rng, B, bs, P, mode)
    if kv == "float":
        ref = paged_attention_reference(q, kp, vp, tab, lens)
        ker = paged_decode_attention(q, kp, vp, tab, lens, interpret=True)
        tol = dict(rtol=1e-5, atol=1e-5)
    else:
        kc, vc, scales = _quantize_case(kp, vp)
        ref = paged_attention_reference(q, kc, vc, tab, lens, **scales)
        ker = paged_decode_attention(q, kc, vc, tab, lens, interpret=True,
                                     assert_bounds=True, **scales)
        # the kernel runs the integer datapath (q and softmax probabilities
        # quantized on top of the shared KV codes); the reference dequantizes
        # and runs float math — agreement is to quantization tolerance
        tol = dict(rtol=5e-2, atol=5e-2)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), **tol)


@pytest.mark.parametrize("kv", ["float", "int8"])
@pytest.mark.parametrize(
    "B,nkv,g,hd,bs,P",
    [
        (3, 1, 3, 7, 8, 3),   # odd nh (3) and odd hd (7)
        (2, 3, 1, 16, 8, 2),  # odd nkv == nh
        (3, 1, 5, 11, 16, 2),  # odd everything, MQA grouping
    ],
)
def test_kernel_parity_odd_heads(rng, kv, B, nkv, g, hd, bs, P):
    q, kp, vp, tab, lens = _random_case(rng, B, nkv, g, hd, bs, P)
    if kv == "float":
        ref = paged_attention_reference(q, kp, vp, tab, lens)
        ker = paged_decode_attention(q, kp, vp, tab, lens, interpret=True)
        tol = dict(rtol=1e-5, atol=1e-5)
    else:
        kc, vc, scales = _quantize_case(kp, vp)
        ref = paged_attention_reference(q, kc, vc, tab, lens, **scales)
        ker = paged_decode_attention(q, kc, vc, tab, lens, interpret=True,
                                     assert_bounds=True, **scales)
        tol = dict(rtol=5e-2, atol=5e-2)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), **tol)


# ---------------------------------------------------------------------------
# int8 KV: the quantized gather reference is the quantize→dequantize image
# of the dense-slab math — bit-identical (the golden anchor the engine
# accuracy test builds on).
# ---------------------------------------------------------------------------
def test_quantized_reference_is_dequantized_dense_math(rng):
    B, nkv, g, hd, bs, P = 2, 2, 2, 16, 8, 2
    nh = nkv * g
    S = P * bs
    q = jnp.asarray(rng.normal(size=(B, nh, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, nkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, nkv, hd)), jnp.float32)
    lens = jnp.asarray([5, 13], jnp.int32)

    # quantize per page, then lay the *dequantized* values back into a
    # dense slab and run the dense-slab decode-attention math on them
    kc, ks = quantize_kv_pages(k.reshape(B * P, bs, nkv, hd))
    vc, vs = quantize_kv_pages(v.reshape(B * P, bs, nkv, hd))
    k_dq = dequantize_kv_pages(kc, ks).reshape(B, S, nkv, hd)
    v_dq = dequantize_kv_pages(vc, vs).reshape(B, S, nkv, hd)
    qg = q.reshape(B, nkv, g, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_dq).astype(jnp.float32)
    s = s / math.sqrt(hd)
    valid = jnp.arange(S)[None, :] < lens[:, None]
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    dense = jnp.einsum("bkgs,bskd->bkgd", p, v_dq).reshape(B, nh, hd)

    tab = jnp.arange(B * P, dtype=jnp.int32).reshape(B, P)
    ref = paged_attention_reference(q, kc, vc, tab, lens,
                                    k_scales=ks, v_scales=vs)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(dense))


def test_quantize_kv_pages_roundtrip(rng):
    """Per-(page, head) symmetric quantization: codes bounded by the int8
    alphabet, round-trip error within half a step of each page's scale,
    and all-zero pages keep the 1e-8 floor scale (no NaNs)."""
    pages = jnp.asarray(rng.normal(size=(5, 8, 3, 16)) * 4.0, jnp.float32)
    pages = pages.at[0].set(0.0)
    codes, scales = quantize_kv_pages(pages)
    assert codes.dtype == jnp.int8 and scales.shape == (5, 3)
    assert int(jnp.max(jnp.abs(codes))) <= 127
    assert float(scales[0].min()) == pytest.approx(1e-8)
    err = jnp.abs(dequantize_kv_pages(codes, scales) - pages)
    assert float(jnp.max(err / scales[:, None, :, None])) <= 0.5 + 1e-6


def test_quantized_scale_indexing_follows_block_table(rng):
    """Pages with wildly different magnitudes: the reference must pair each
    gathered page with *its* scale through the same table indirection (a
    mispairing is off by orders of magnitude, not tolerance)."""
    B, nkv, g, hd, bs, P = 2, 2, 1, 8, 4, 2
    q, kp, vp, tab, lens = _random_case(rng, B, nkv, g, hd, bs, P)
    # scale page magnitudes by their pool index so every page differs
    mags = jnp.exp(jnp.linspace(0.0, 4.0, kp.shape[0]))[:, None, None, None]
    kc, vc, scales = _quantize_case(kp * mags, vp * mags)
    ref = paged_attention_reference(q, kc, vc, tab, lens, **scales)
    ker = paged_decode_attention(q, kc, vc, tab, lens, interpret=True,
                                 **scales)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=5e-2, atol=5e-2)
