"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU; the kernels target TPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import me_stats
from repro.kernels import (
    gpfq_quantize_panel,
    norm_and_quantize,
    pack_int4,
    unpack_int4,
    w4a8_decode_matmul,
    w4a8_matmul,
)
from repro.kernels.ref import (
    gpfq_solve_ref,
    quant_rmsnorm_ref,
    w4a8_matmul_ref,
    w4a8_tile_partials_ref,
)


@pytest.mark.parametrize("k", [2, 64, 256])
def test_pack_unpack_roundtrip(k, rng):
    q = rng.integers(-8, 8, size=(k, 32))
    packed = pack_int4(jnp.asarray(q))
    assert packed.shape == (k // 2, 32) and packed.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(unpack_int4(packed)), q)


@pytest.mark.parametrize(
    "m,k,n,bm,bn,bk",
    [
        (64, 128, 64, 64, 64, 64),
        (128, 256, 128, 64, 64, 128),
        (64, 512, 128, 32, 128, 64),
        (256, 128, 256, 128, 128, 128),
    ],
)
def test_w4a8_matmul_shape_sweep(m, k, n, bm, bn, bk, rng):
    q = rng.integers(-7, 8, size=(k, n))
    wp = pack_int4(jnp.asarray(q))
    x = jnp.asarray(rng.integers(0, 256, size=(m, k)), jnp.uint8)
    scale = jnp.asarray(rng.uniform(0.001, 0.1, size=(n,)), jnp.float32)
    y = w4a8_matmul(x, wp, scale, 0.02, 131, interpret=True,
                    block_m=bm, block_n=bn, block_k=bk)
    y_ref = w4a8_matmul_ref(x, wp, scale, 0.02, 131)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("m", [1, 2, 3, 4, 7, 8, 16, 100, 130, 250])
def test_w4a8_matmul_ragged_m(m, rng):
    """M is padded internally: ragged last batches (and decode-shaped M < 8)
    no longer crash on the old ``m % block_m == 0`` assert."""
    k, n = 128, 64
    q = rng.integers(-7, 8, size=(k, n))
    wp = pack_int4(jnp.asarray(q))
    x = jnp.asarray(rng.integers(0, 256, size=(m, k)), jnp.uint8)
    scale = jnp.asarray(rng.uniform(0.001, 0.1, size=(n,)), jnp.float32)
    y = w4a8_matmul(x, wp, scale, 0.02, 131, interpret=True)
    assert y.shape == (m, n)
    y_ref = w4a8_matmul_ref(x, wp, scale, 0.02, 131)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("m", [1, 2, 4, 8, 16, 3, 5, 13])
@pytest.mark.parametrize("k,n", [(128, 128), (64, 48), (256, 36)])
def test_w4a8_decode_matmul_sweep(m, k, n, rng):
    """Decode-shaped path: GEMV-style M blocks, ragged N/K tiling, and the
    pack-time ``col_sums`` zero-point term — exact vs the ref oracle."""
    q = rng.integers(-7, 8, size=(k, n))
    wp = pack_int4(jnp.asarray(q))
    col_sums = jnp.sum(jnp.asarray(q, jnp.int32), axis=0)
    x = jnp.asarray(rng.integers(0, 256, size=(m, k)), jnp.uint8)
    scale = jnp.asarray(rng.uniform(0.001, 0.1, size=(n,)), jnp.float32)
    y = w4a8_decode_matmul(x, wp, scale, col_sums, 0.02, 131, interpret=True)
    assert y.shape == (m, n)
    y_ref = w4a8_matmul_ref(x, wp, scale, 0.02, 131)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5, atol=1e-4)


def test_w4a8_decode_matmul_assert_inner(rng):
    """The decode path carries the same P_I certificate semantics: with
    weights whose per-tile l1 mass respects the bound, the in-kernel
    debug check passes; the bound itself matches the tile-partials oracle."""
    k, n, bk, p = 128, 32, 64, 16
    q = rng.choice([-1, 0, 1], size=(k, n))  # |partial| <= 64*255 < 2^15
    wp = pack_int4(jnp.asarray(q))
    col_sums = jnp.sum(jnp.asarray(q, jnp.int32), axis=0)
    x = jnp.asarray(rng.integers(0, 256, size=(2, k)), jnp.uint8)
    scale = jnp.ones((n,), jnp.float32)
    y = w4a8_decode_matmul(x, wp, scale, col_sums, 0.01, 131,
                           block_k=bk, p_inner=p, assert_inner=True,
                           interpret=True)
    parts = w4a8_tile_partials_ref(x, wp, bk)
    assert int(jnp.max(jnp.abs(parts))) <= 2 ** (p - 1) - 1
    y_ref = w4a8_matmul_ref(x, wp, scale, 0.01, 131)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_w4a8_matmul_out_dtype(out_dtype, rng):
    q = rng.integers(-7, 8, size=(128, 64))
    wp = pack_int4(jnp.asarray(q))
    x = jnp.asarray(rng.integers(0, 256, size=(64, 128)), jnp.uint8)
    y = w4a8_matmul(x, wp, jnp.ones((64,)), 0.01, 128, interpret=True,
                    block_m=64, block_n=64, block_k=64, out_dtype=out_dtype)
    assert y.dtype == out_dtype
    y_ref = w4a8_matmul_ref(x, wp, jnp.ones((64,)), 0.01, 128)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(y_ref),
                               rtol=2e-2, atol=2e-2)


def test_w4a8_inner_accumulator_watermark_with_axe(rng):
    """AXE-quantized weights keep every K-tile partial within P_I bits even
    for adversarial inputs; the kernel's tile partials confirm it."""
    from repro.core import AxeConfig, act_alphabet, gpfq_memory_efficient, weight_alphabet

    K, C, T, P = 128, 64, 64, 16
    w = jnp.asarray(rng.normal(size=(K, C)) * 2, jnp.float32)
    xs = jnp.asarray(rng.normal(size=(K, 256)), jnp.float32)
    h_half, g = me_stats(xs, xs)
    r = gpfq_memory_efficient(
        w, h_half, g, weight_alphabet(4), act_alphabet(8),
        axe=AxeConfig(p_bits=P, tile=T),
    )
    wp = pack_int4(jnp.asarray(np.asarray(r.q_int, np.int8)))
    x_adv = jnp.asarray(
        np.where(np.asarray(r.q_int).T >= 0, 255, 0)[:C], jnp.uint8
    )  # worst-case codes per channel... use as batch rows
    parts = w4a8_tile_partials_ref(x_adv, wp, T)
    assert int(jnp.max(jnp.abs(parts))) <= 2 ** (P - 1) - 1


@pytest.mark.parametrize("m,d,bm", [(128, 64, 64), (256, 128, 128), (64, 32, 64)])
def test_quant_rmsnorm_sweep(m, d, bm, rng):
    x = jnp.asarray(rng.normal(size=(m, d)) * 2, jnp.float32)
    g = jnp.asarray(rng.normal(size=(d,)) * 0.1 + 1.0, jnp.float32)
    out = norm_and_quantize(x, g, 0.02, 128, interpret=True, block_m=bm)
    ref = quant_rmsnorm_ref(x, g, 0.02, 128)
    assert out.dtype == jnp.uint8
    # rint at exact .5 boundaries may differ by one code ULP in rare cases
    diff = np.abs(np.asarray(out, np.int32) - np.asarray(ref, np.int32))
    assert diff.max() <= 1 and (diff > 0).mean() < 0.01


@pytest.mark.parametrize("k,c,tile,bc", [(32, 64, 16, 64), (64, 128, 32, 64)])
def test_gpfq_solve_matches_core(k, c, tile, bc, rng):
    """Pallas GPFQ panel solver == the core lax.fori_loop implementation."""
    w = jnp.asarray(rng.normal(size=(k, c)) * 3, jnp.float32)
    xs = jnp.asarray(rng.normal(size=(k, 3 * k)), jnp.float32)
    h_half, g = me_stats(xs, xs)
    ghinv = jnp.linalg.solve(h_half, g.T).T
    n_tiles = k // tile
    lam = jnp.asarray(rng.uniform(0, 0.3, size=(n_tiles, c)), jnp.float32)
    qk = gpfq_quantize_panel(w, ghinv, h_half, lam, 12.0, w_bits=4,
                             tile=tile, block_c=bc, interpret=True)
    q_ref = gpfq_solve_ref(w, ghinv, h_half, w_bits=4, lam=lam,
                           budget_b=12.0, tile=tile)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(q_ref))


def test_gpfq_solve_budget_respected(rng):
    k, c, tile, b = 64, 64, 16, 6.0
    w = jnp.asarray(rng.normal(size=(k, c)) * 5, jnp.float32)
    xs = jnp.asarray(rng.normal(size=(k, 128)), jnp.float32)
    h_half, g = me_stats(xs, xs)
    ghinv = jnp.linalg.solve(h_half, g.T).T
    lam = jnp.zeros((k // tile, c), jnp.float32)
    q = np.asarray(gpfq_quantize_panel(w, ghinv, h_half, lam, b, w_bits=4,
                                       tile=tile, block_c=64, interpret=True))
    qt = q.T.reshape(c, k // tile, tile)
    pos = np.maximum(qt, 0).sum(-1)
    neg = np.minimum(qt, 0).sum(-1)
    assert pos.max() <= b + 0.5 + 1e-6 and neg.min() >= -b - 0.5 - 1e-6
