"""The paper's central guarantee, as property tests: quantized weights from
AXE never overflow the target accumulator for ANY admissible input."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    LayerStats,
    PTQConfig,
    act_alphabet,
    accumulator_range,
    certify,
    quantize_linear,
    simulate_accumulation,
    worst_case_inputs,
)


def _quantized_layer(seed, k, c, p_bits, tile, n_bits=8, algorithm="gpfq"):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(k, c)) * 2.0, jnp.float32)
    x = jnp.asarray(rng.normal(size=(192, k)), jnp.float32)
    stats = LayerStats(k=k)
    stats.update(x)
    cfg = PTQConfig(
        w_bits=4, act_bits=n_bits, p_bits=p_bits, tile=tile, algorithm=algorithm
    )
    return quantize_linear(w, stats, cfg), cfg


@given(
    seed=st.integers(0, 200),
    p_bits=st.integers(10, 16),
    tile=st.sampled_from([8, 16, None]),
    algorithm=st.sampled_from(["gpfq", "optq", "ep_init"]),
)
@settings(max_examples=15)
def test_certificate_holds(seed, p_bits, tile, algorithm):
    ql, cfg = _quantized_layer(seed, k=32, c=8, p_bits=p_bits, tile=tile,
                               algorithm=algorithm)
    assert bool(ql.cert), (algorithm, ql.cert)


@given(seed=st.integers(0, 100), tile=st.sampled_from([8, 16]))
@settings(max_examples=10)
def test_worst_case_simulation_never_overflows(seed, tile):
    """Exhaustive adversary: dot the quantized weights with the analytic
    worst-case inputs AND random integer inputs; int64 accumulation must stay
    within the certified inner/outer ranges."""
    p_bits = 12
    ql, cfg = _quantized_layer(seed, k=32, c=8, p_bits=p_bits, tile=tile)
    na = cfg.act_alphabet
    q = np.asarray(ql.q_int)

    u, v = worst_case_inputs(ql.q_int, na)  # (C, K) adversarial codes
    rng = np.random.default_rng(seed)
    rand = rng.integers(na.qmin, na.qmax + 1, size=(64, q.shape[0]))
    x_all = np.concatenate([np.asarray(u), np.asarray(v), rand], axis=0)

    sim = simulate_accumulation(q, x_all, tile=tile)
    lo_i, hi_i = accumulator_range(p_bits)
    assert sim["partial_hi"] <= hi_i and sim["partial_lo"] >= lo_i
    lo_o, hi_o = accumulator_range(cfg.outer_bits(q.shape[0]))
    assert sim["total_hi"] <= hi_o and sim["total_lo"] >= lo_o


def test_certificate_is_tight():
    """The analytic bound equals the dot product with the worst-case input."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.integers(-7, 8, size=(16, 4)), jnp.float32)
    na = act_alphabet(8)
    cert = certify(q, na, p_bits=32, tile=None)
    u, _ = worst_case_inputs(q, na)
    dots = np.einsum("ck,kc->c", np.asarray(u), np.asarray(q))
    assert cert.worst_hi == dots.max()


def test_unconstrained_violates_small_accumulator():
    """Sanity: WITHOUT AXE, a small accumulator is genuinely at risk —
    the guarantee is not vacuous."""
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(64, 8)) * 2.0, jnp.float32)
    x = jnp.asarray(rng.normal(size=(128, 64)), jnp.float32)
    stats = LayerStats(k=64)
    stats.update(x)
    cfg = PTQConfig(w_bits=4, act_bits=8, constrain=False)
    ql = quantize_linear(w, stats, cfg)
    cert = certify(ql.q_int, cfg.act_alphabet, p_bits=14, tile=None)
    assert not bool(cert)


def test_headroom_reported():
    ql, _ = _quantized_layer(0, k=32, c=8, p_bits=14, tile=None)
    assert ql.cert.headroom_bits >= 0
