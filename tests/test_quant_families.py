"""Family-agnostic PTQ: per-family end-to-end certification + perplexity,
stacked expert quantization, cert-summary semantics, registry protocol."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import PTQConfig, quantize_linear
from repro.core.calibration import LayerStats
from repro.data import DataConfig, TokenBatcher
from repro.models.transformer import init_model
from repro.quant import (
    QuantizedModel,
    calibrate_and_quantize,
    float_ppl,
    quantized_forward,
    quantized_ppl,
)

FAMILY_ARCHS = ["tiny-moe", "tiny-ssm", "tiny-xlstm", "tiny-hybrid"]


def _setup(arch):
    cfg = get_config(arch)
    params = init_model(jax.random.key(0), cfg)
    data = TokenBatcher(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=2))
    calib = [data.batch(100 + i) for i in range(2)]
    evalb = list(data.eval_batches(2))
    return cfg, params, calib, evalb


@pytest.mark.slow  # CI covers this ground via scripts/smoke.sh
@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_family_end_to_end_certified(arch):
    """Every family quantizes + certifies under the default W4A8 / T=128 /
    P=16 recipe, and the simulated-integer model stays close to float."""
    cfg, params, calib, evalb = _setup(arch)
    qm = calibrate_and_quantize(params, cfg, calib, PTQConfig())
    assert qm.certified
    summary = qm.cert_summary()
    assert summary["ok"] is True
    assert summary["n_certified"] > 0
    assert summary["min_headroom_bits"] >= 0.0

    logits = quantized_forward(qm, evalb[0])
    assert logits.shape == (*evalb[0]["tokens"].shape, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    ppl_f = float_ppl(params, cfg, evalb)
    ppl_q = quantized_ppl(qm, evalb)
    # untrained net: quantization should not blow up perplexity
    assert ppl_q < ppl_f * 2.0


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_family_site_enumeration_matches_artifacts(arch):
    """The registry's config-only site enumeration is exactly what the
    calibrated model carries, with matching shapes."""
    from repro.quant.families import get_adapter

    cfg, params, calib, _ = _setup(arch)
    qm = calibrate_and_quantize(params, cfg, calib, PTQConfig())
    for block in qm.blocks:
        for kind, comp in (("mixer", block.mixer), ("ffn", block.ffn)):
            if comp is None:
                continue
            specs = get_adapter(kind, comp.adapter).enumerate_sites(cfg)
            assert {s.name for s in specs} == set(comp.linears)
            for s in specs:
                ql = comp.linears[s.name]
                expect = (s.k, s.c) if s.stacked is None else (s.stacked, s.k, s.c)
                assert ql.q_int.shape == expect, s.name


def test_stacked_expert_quantization_matches_independent_slices():
    """Vmapped quantize_linear on an (E, K, C) MoE weight == quantizing each
    expert slice independently with the same shared statistics — including
    the per-expert certificates."""
    rng = np.random.default_rng(0)
    e, k, c = 3, 32, 48
    w = jnp.asarray(rng.normal(size=(e, k, c)), jnp.float32) * 0.05
    x = jnp.asarray(rng.normal(size=(256, k)), jnp.float32)
    stats = LayerStats(k=k)
    stats.update(x, x)
    ptq = PTQConfig(tile=16)

    ql_stack = quantize_linear(w, stats, ptq)
    assert ql_stack.stacked
    assert ql_stack.q_int.shape == (e, k, c)
    assert len(ql_stack.cert.reports) == e
    assert bool(ql_stack.cert)

    for i in range(e):
        ql_i = quantize_linear(w[i], stats, ptq)
        np.testing.assert_array_equal(
            np.asarray(ql_stack.q_int[i]), np.asarray(ql_i.q_int)
        )
        np.testing.assert_allclose(
            np.asarray(ql_stack.scale[i]), np.asarray(ql_i.scale), rtol=1e-6
        )
        np.testing.assert_allclose(
            np.asarray(ql_stack.bias[i, 0]), np.asarray(ql_i.bias),
            rtol=1e-4, atol=1e-6,
        )
        r_s, r_i = ql_stack.cert.reports[i], ql_i.cert
        assert (r_s.ok, r_s.outer_ok) == (r_i.ok, r_i.outer_ok)
        np.testing.assert_allclose(r_s.headroom_bits, r_i.headroom_bits, rtol=1e-6)
        # per-expert act params are the shared ones
        assert ql_stack.act == ql_i.act

    # stacked __call__ broadcasts over the expert axis
    xe = jnp.asarray(rng.normal(size=(e, 7, k)), jnp.float32)
    y = ql_stack(xe)
    assert y.shape == (e, 7, c)
    np.testing.assert_allclose(
        np.asarray(y[1]), np.asarray(quantize_linear(w[1], stats, ptq)(xe[1])),
        rtol=1e-5, atol=1e-5,
    )


def test_cert_summary_empty_is_explicitly_not_ok():
    """No certificates (constrain=False or no blocks) must NOT read as a
    vacuous guarantee: min_headroom_bits is None (not inf) and ok is False."""
    cfg = get_config("tiny-lm-xs")
    qm = QuantizedModel(cfg=cfg, ptq=PTQConfig(constrain=False),
                        embedding={}, final_norm={})
    s = qm.cert_summary()
    assert s == {"n_certified": 0, "min_headroom_bits": None,
                 "min_headroom_site": None, "ok": False}
    assert qm.certified  # the per-layer predicate stays vacuous-true...
    assert s["ok"] is False  # ...but the summary is explicit about it


def test_cert_summary_unconstrained_pipeline_not_ok():
    cfg, params, calib, _ = _setup("tiny-ssm")
    qm = calibrate_and_quantize(params, cfg, calib,
                                PTQConfig(constrain=False))
    s = qm.cert_summary()
    assert s["n_certified"] == 0
    assert s["min_headroom_bits"] is None
    assert s["ok"] is False


def test_equalization_toggle_consistent_across_families():
    """equalize=False must also produce a certified model (the SmoothQuant
    fold is an optional pre-step, not a correctness requirement)."""
    cfg, params, calib, evalb = _setup("tiny-moe")
    qm = calibrate_and_quantize(params, cfg, calib, PTQConfig(), equalize=False)
    assert qm.certified
    assert np.isfinite(quantized_ppl(qm, evalb))


def test_moe_router_stays_high_precision():
    """§C.1-style exclusions: the router weight is never quantized and is
    retained in the block's float params."""
    cfg, params, calib, _ = _setup("tiny-moe")
    qm = calibrate_and_quantize(params, cfg, calib, PTQConfig())
    ffn = qm.blocks[0].ffn
    assert "router" not in ffn.linears
    assert ffn.params["router"] is not None
    assert ffn.params["router"].shape == (cfg.d_model, cfg.moe.n_experts)
    # quantized expert weights were stripped from the float params
    assert ffn.params["wg"] is None and ffn.params["wd"] is None