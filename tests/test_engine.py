"""GenerationEngine: fused on-device loop vs host-loop reference — greedy
bit-identity, EOS semantics (early exit, post-EOS padding, per-sequence
done masks), single-host-sync and one-compile-per-bucket guarantees."""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.transformer import init_model
from repro.serving import GenerationEngine, SamplerConfig


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke("smollm-360m").scaled(n_layers=2, vocab=128)
    params = init_model(jax.random.key(0), cfg)
    prompts = np.random.default_rng(0).integers(0, 128, size=(3, 8)).astype(np.int32)
    return cfg, params, prompts


def _eos_from_greedy(cfg, params, prompts, pos: int) -> int:
    """A token the greedy rollout actually emits => EOS fires mid-sequence."""
    eng = GenerationEngine(params, cfg, SamplerConfig(temperature=0.0))
    out = eng.generate(prompts, 8)
    return int(out[0, prompts.shape[1] + pos])


def test_greedy_bit_identical_fused_vs_host(setup):
    cfg, params, prompts = setup
    eng = GenerationEngine(params, cfg, SamplerConfig(temperature=0.0))
    fused = eng.generate(prompts, 8)
    host = eng.generate_host_loop(prompts, 8)
    np.testing.assert_array_equal(fused, host)


def test_sampled_bit_identical_fused_vs_host(setup):
    cfg, params, prompts = setup
    eng = GenerationEngine(params, cfg, SamplerConfig(temperature=1.0, seed=7))
    fused = eng.generate(prompts, 8)
    host = eng.generate_host_loop(prompts, 8)
    np.testing.assert_array_equal(fused, host)


@pytest.mark.parametrize("temperature", [0.0, 1.0])
def test_eos_semantics_identical(setup, temperature):
    """Early exit, post-EOS padding and per-sequence done masks agree
    between the host-loop reference and the fused on-device loop."""
    cfg, params, prompts = setup
    eos = _eos_from_greedy(cfg, params, prompts, pos=2)
    eng = GenerationEngine(
        params, cfg, SamplerConfig(temperature=temperature, eos_id=eos, seed=3)
    )
    fused = eng.generate(prompts, 10)
    host = eng.generate_host_loop(prompts, 10)
    np.testing.assert_array_equal(fused, host)
    # post-EOS positions are EOS-padded per sequence
    S0 = prompts.shape[1]
    gen = fused[:, S0:]
    for row in gen:
        hits = np.flatnonzero(row == eos)
        if hits.size:
            assert (row[hits[0]:] == eos).all()


def test_eos_early_exit_all_done(setup):
    """EOS at the very first sampled token: every generated position is EOS
    and both loops exit early with identical padding."""
    cfg, params, prompts = setup
    eos = _eos_from_greedy(cfg, params, prompts, pos=0)
    # eos chosen from row 0; other rows may run longer — also try a config
    # where ALL rows hit at t=0 by generating once and reading each row
    eng = GenerationEngine(params, cfg, SamplerConfig(temperature=0.0, eos_id=eos))
    fused = eng.generate(prompts, 6)
    host = eng.generate_host_loop(prompts, 6)
    np.testing.assert_array_equal(fused, host)
    assert (fused[0, prompts.shape[1]:] == eos).all()


def test_single_host_sync_per_generate(setup, monkeypatch):
    """The fused loop performs exactly one device->host transfer per call —
    the final explicit jax.device_get; implicit transfers are banned for
    the whole call via jax's transfer guard."""
    cfg, params, prompts = setup
    eng = GenerationEngine(params, cfg, SamplerConfig(temperature=0.0, eos_id=5))
    eng.generate(prompts, 4)  # compile outside the guarded region

    calls = []
    real = jax.device_get

    def counting(x):
        calls.append(1)
        return real(x)

    monkeypatch.setattr(jax, "device_get", counting)
    with jax.transfer_guard_device_to_host("disallow"):
        out = eng.generate(prompts, 4)
    assert len(calls) == 1
    assert out.shape == (3, 12)


def test_host_loop_no_transfers_without_eos(setup):
    """Satellite regression: with eos_id=None the host loop does zero
    per-token device->host round-trips (the old engine np.asarray'd every
    token) — the whole call runs under a disallow-implicit-transfer guard;
    the only fetch is the final explicit device_get."""
    cfg, params, prompts = setup
    eng = GenerationEngine(params, cfg, SamplerConfig(temperature=0.0))
    eng.generate_host_loop(prompts, 3)  # compile
    with jax.transfer_guard_device_to_host("disallow"):
        out = eng.generate_host_loop(prompts, 3)
    assert out.shape == (3, 11)


def test_one_compile_per_bucket(setup):
    cfg, params, prompts = setup
    eng = GenerationEngine(params, cfg, SamplerConfig(temperature=0.0))
    eng.generate(prompts, 4)
    eng.generate(prompts, 4)
    eng.generate(prompts, 4)
    assert eng.gen_traces == 1  # same (B, S0, max_new) bucket: one trace
    eng.generate(prompts, 6)
    assert eng.gen_traces == 2  # new max_len bucket
    eng.generate(prompts[:2], 4)
    assert eng.gen_traces == 3  # new batch bucket
