"""Data pipeline, optimizer, checkpointing, serving engine, compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.data import DataConfig, SyntheticMarkovSource, TokenBatcher
from repro.optim import OptimizerConfig, adamw_update, init_opt_state, lr_at_step


# ---------------------------------------------------------------------------
# Data
# ---------------------------------------------------------------------------
def test_batcher_deterministic_and_skippable():
    cfg = DataConfig(vocab=64, seq_len=32, global_batch=8, seed=3)
    b1 = TokenBatcher(cfg)
    b2 = TokenBatcher(cfg)
    np.testing.assert_array_equal(b1.batch(17)["tokens"], b2.batch(17)["tokens"])
    # O(1) skip-ahead: batch(i) independent of history
    _ = b1.batch(0)
    np.testing.assert_array_equal(b1.batch(17)["tokens"], b2.batch(17)["tokens"])


def test_host_sharded_batches_cover_global():
    cfg = DataConfig(vocab=64, seq_len=16, global_batch=8)
    full = TokenBatcher(cfg).batch(5)["tokens"]
    parts = [
        TokenBatcher(cfg, host_index=i, host_count=2).batch(5)["tokens"]
        for i in range(2)
    ]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_synthetic_source_learnable_structure():
    """The Markov teacher's conditional entropy is far below uniform."""
    src = SyntheticMarkovSource(vocab=64, seed=0, branching=4)
    toks = src.sample(64, 256, np.random.default_rng(0))
    # empirical bigram entropy
    pairs = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            pairs.setdefault(int(a), []).append(int(b))
    ents = []
    for a, succ in pairs.items():
        if len(succ) < 20:
            continue
        _, counts = np.unique(succ, return_counts=True)
        p = counts / counts.sum()
        ents.append(-(p * np.log(p)).sum())
    assert np.mean(ents) < 0.7 * np.log(64)


def test_tokens_in_vocab_range():
    cfg = DataConfig(vocab=17, seq_len=16, global_batch=4)
    t = TokenBatcher(cfg).batch(0)["tokens"]
    assert t.min() >= 0 and t.max() < 17


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------
def test_adamw_matches_reference_math(rng):
    params = {"w": jnp.asarray(rng.normal(size=(8,)), jnp.float32)}
    grads = {"w": jnp.asarray(rng.normal(size=(8,)), jnp.float32)}
    cfg = OptimizerConfig(lr=1e-2, warmup_steps=0, total_steps=1,
                          weight_decay=0.0, clip_norm=1e9, min_lr_ratio=1.0)
    state = init_opt_state(params, cfg)
    new_params, new_state, _ = adamw_update(params, grads, state, cfg)
    # closed form for step 1: m_hat = g, v_hat = g^2 -> update = sign-ish
    g = np.asarray(grads["w"])
    expect = np.asarray(params["w"]) - 1e-2 * g / (np.abs(g) + cfg.eps)
    np.testing.assert_allclose(np.asarray(new_params["w"]), expect, rtol=1e-5)
    assert int(new_state["step"]) == 1


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_ratio=0.1)
    assert float(lr_at_step(cfg, 0)) == 0.0
    assert abs(float(lr_at_step(cfg, 10)) - 1.0) < 0.06
    assert abs(float(lr_at_step(cfg, 110)) - 0.1) < 1e-6
    assert float(lr_at_step(cfg, 60)) < 1.0


def test_grad_clipping_applied(rng):
    params = {"w": jnp.zeros((4,), jnp.float32)}
    grads = {"w": jnp.asarray([100.0, 0, 0, 0], jnp.float32)}
    cfg = OptimizerConfig(clip_norm=1.0, warmup_steps=0, total_steps=1,
                          weight_decay=0.0, min_lr_ratio=1.0, lr=1.0)
    state = init_opt_state(params, cfg)
    _, _, metrics = adamw_update(params, grads, state, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(100.0)


# ---------------------------------------------------------------------------
# Checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path, rng):
    tree = {
        "a": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32),
        "nest": {"b": jnp.arange(7, dtype=jnp.int32)},
    }
    d = str(tmp_path / "ck")
    save_pytree(tree, d, {"step": 5})
    target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored, meta = load_pytree(target, d)
    assert meta["step"] == 5
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(restored["nest"]["b"]),
                                  np.asarray(tree["nest"]["b"]))


def test_checkpoint_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.ones((2,))}
    for s in (10, 20, 30):
        mgr.save(s, tree, blocking=True)
    assert mgr.latest_step() == 30
    assert not os.path.exists(mgr.directory(10))  # retention
    step, restored, meta = mgr.restore_latest(tree)
    assert step == 30 and meta["step"] == 30


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ck")
    save_pytree({"w": jnp.ones((4,))}, d)
    with pytest.raises(ValueError):
        load_pytree({"w": jnp.ones((5,))}, d)


def test_train_resume_bit_exact(tmp_path):
    """Interrupted-and-resumed training == uninterrupted training."""
    from repro.configs import get_smoke
    from repro.data import DataConfig, TokenBatcher
    from repro.runtime.steps import TrainRunConfig, init_train_state, make_train_step

    cfg = get_smoke("smollm-360m").scaled(n_layers=2, vocab=64, remat="none")
    run = TrainRunConfig()
    data = TokenBatcher(DataConfig(vocab=64, seq_len=16, global_batch=4))
    step_fn = jax.jit(make_train_step(cfg, run))

    def run_steps(state, lo, hi):
        for i in range(lo, hi):
            state, m = step_fn(state, jax.tree.map(jnp.asarray, data.batch(i)))
        return state, float(m["loss"])

    # uninterrupted
    s = init_train_state(jax.random.key(0), cfg, run)
    s_full, loss_full = run_steps(s, 0, 8)
    # interrupted at 4 + checkpoint + restore + resume
    s = init_train_state(jax.random.key(0), cfg, run)
    s_half, _ = run_steps(s, 0, 4)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(4, s_half, blocking=True)
    target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s_half)
    _, s_restored, _ = mgr.restore_latest(target)
    s_resumed, loss_resumed = run_steps(s_restored, 4, 8)
    assert loss_resumed == pytest.approx(loss_full, rel=1e-6)
    for a, b in zip(jax.tree.leaves(s_full["params"]), jax.tree.leaves(s_resumed["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


# ---------------------------------------------------------------------------
# Serving engine
# ---------------------------------------------------------------------------
def test_generation_engine_greedy_deterministic():
    from repro.configs import get_smoke
    from repro.models.transformer import init_model
    from repro.serving import GenerationEngine, SamplerConfig

    cfg = get_smoke("smollm-360m").scaled(n_layers=2, vocab=64)
    params = init_model(jax.random.key(0), cfg)
    eng = GenerationEngine(params, cfg, SamplerConfig(temperature=0.0))
    prompts = np.random.default_rng(0).integers(0, 64, size=(2, 8)).astype(np.int32)
    out1 = eng.generate(prompts, 6)
    out2 = eng.generate(prompts, 6)
    assert out1.shape == (2, 14)
    np.testing.assert_array_equal(out1, out2)


def test_generation_matches_forward_argmax():
    """Greedy engine tokens == argmax over the full-forward logits chain."""
    from repro.configs import get_smoke
    from repro.models import transformer as T
    from repro.models.transformer import init_model
    from repro.serving import GenerationEngine, SamplerConfig

    cfg = get_smoke("smollm-360m").scaled(n_layers=2, vocab=64)
    params = init_model(jax.random.key(0), cfg)
    eng = GenerationEngine(params, cfg, SamplerConfig(temperature=0.0))
    prompts = np.random.default_rng(1).integers(0, 64, size=(1, 8)).astype(np.int32)
    out = eng.generate(prompts, 4)
    toks = prompts
    for _ in range(4):
        logits, _ = T.forward(params, {"tokens": jnp.asarray(toks)}, cfg)
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1), np.int32)[:, None]
        toks = np.concatenate([toks, nxt], axis=1)
    np.testing.assert_array_equal(out, toks)


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------
def test_int8_psum_close_to_exact():
    from jax.sharding import PartitionSpec as P

    from repro.runtime.compression import int8_psum

    if len(jax.devices()) < 1:
        pytest.skip("needs devices")
    if not hasattr(jax, "shard_map") or not hasattr(jax.sharding, "AxisType"):
        pytest.skip("needs jax.shard_map with axis_types meshes")
    mesh = jax.make_mesh((1,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(32,)), jnp.float32)}

    out = jax.shard_map(
        lambda t: int8_psum(t, "pod"), mesh=mesh,
        in_specs=(P(),), out_specs=P(), axis_names=frozenset({"pod"}),
        check_vma=False,
    )(g)
    rel = np.abs(np.asarray(out["w"]) - np.asarray(g["w"])).max() / np.abs(
        np.asarray(g["w"])
    ).max()
    assert rel < 0.01  # 8-bit quantization error bound
