"""End-to-end integration: train -> checkpoint -> quantize -> certify ->
serve, through the real launchers."""

import jax
import numpy as np
import pytest


@pytest.mark.slow
def test_train_quantize_serve_roundtrip(tmp_path):
    from repro.launch.quantize import main as quantize_main
    from repro.launch.serve import main as serve_main
    from repro.launch.train import main as train_main

    ckpt = str(tmp_path / "run")
    state, losses = train_main(
        ["--arch", "tiny-lm-xs", "--steps", "80", "--batch", "8",
         "--seq", "64", "--ckpt-dir", ckpt, "--ckpt-every", "40",
         "--log-every", "40", "--lr", "1e-3"]
    )
    assert losses[-1] < losses[0]

    report = quantize_main(
        ["--arch", "tiny-lm-xs", "--ckpt-dir", ckpt, "--algorithm", "gpfq",
         "--p-bits", "16", "--tile", "64", "--calib-batches", "2",
         "--calib-batch-size", "2", "--seq", "64", "--eval-batches", "2",
         "--out", str(tmp_path / "q")]
    )
    assert report["cert"]["ok"]
    assert report["quant_ppl"] < report["float_ppl"] * 1.5
    # artifact written
    import os

    assert os.path.exists(tmp_path / "q" / "quantized" / "manifest.json")

    out = serve_main(
        ["--arch", "tiny-lm-xs", "--ckpt-dir", ckpt, "--batch", "4",
         "--prompt-len", "16", "--max-new", "8"]
    )
    assert out.shape == (4, 24)
    assert out.min() >= 0 and out.max() < 512


def test_compressed_training_step_runs():
    """int8-pod gradient compression path executes on a 1-device pod mesh."""
    from repro.configs import get_smoke
    from repro.launch.mesh import make_mesh
    from repro.runtime.sharding import axis_rules
    from repro.runtime.steps import TrainRunConfig, init_train_state, make_train_step

    cfg = get_smoke("smollm-360m").scaled(n_layers=2, vocab=64, remat="none")
    mesh = make_mesh((1, 1, 1))  # (pod, data, model)
    run = TrainRunConfig(grad_compression="int8-pod")
    state = init_train_state(jax.random.key(0), cfg, run)
    step = make_train_step(cfg, run, mesh)

    def wrapped(state, batch):
        with axis_rules(mesh):
            return step(state, batch)

    from repro.runtime.sharding import set_mesh

    batch = {"tokens": jax.random.randint(jax.random.key(1), (4, 16), 0, 64)}
    with set_mesh(mesh):
        new_state, metrics = jax.jit(wrapped)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
