"""Packed-int4 serving path: correctness vs float, abstract tracing,
sharding rules for packed leaves."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import transformer as T
from repro.quant.serve_packed import pack_decode_params, packed_weight_bytes


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke("smollm-360m").scaled(n_layers=2, vocab=128)
    params = T.init_model(jax.random.key(0), cfg)
    return cfg, params


def test_packed_decode_tracks_float(setup):
    cfg, params = setup
    pparams = pack_decode_params(params, cfg)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 12), 0, 128)}
    _, cache_f = T.prefill(params, batch, cfg, max_len=16)
    _, cache_q = T.prefill(pparams, batch, cfg, max_len=16)
    tok = jnp.ones((2, 1), jnp.int32)
    l_f, _ = T.decode_step(params, tok, cache_f, jnp.int32(12), cfg)
    l_q, _ = T.decode_step(pparams, tok, cache_q, jnp.int32(12), cfg)
    corr = float(jnp.corrcoef(l_f.ravel(), l_q.ravel())[0, 1])
    assert corr > 0.85, corr  # int4 RTN on random weights
    assert bool(jnp.all(jnp.isfinite(l_q)))


def test_pack_works_under_eval_shape(setup):
    cfg, _ = setup
    abstract = jax.eval_shape(lambda k: T.init_model(k, cfg), jax.random.key(0))
    packed = jax.eval_shape(lambda p: pack_decode_params(p, cfg), abstract)
    leaf = packed["layers"][0]["mixer"]["wq"]
    k = cfg.d_model
    assert leaf["packed"].dtype == jnp.int8
    assert leaf["packed"].shape[-2] == k // 2  # 2 codes per byte


def test_packed_param_shardings_resolve(setup):
    from repro.launch.mesh import make_mesh
    from repro.runtime.sharding import SERVING_QUANT_RULES, param_shardings

    cfg, params = setup
    pparams = pack_decode_params(params, cfg)
    mesh = make_mesh((1, 1))
    sh = param_shardings(pparams, mesh, SERVING_QUANT_RULES)
    # every packed/scale leaf got a sharding (no KeyErrors / rank mismatches)
    n = len(jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec")))
    assert n == len(jax.tree.leaves(pparams))


def test_packed_weight_bytes_accounting(setup):
    """The analytic accounting matches the *actual* packed tree byte for
    byte, per leaf kind — codes, per-channel scales, the col_sums
    zero-point term and the spec twin all counted (the old accounting
    undercounted by omitting everything but the codes)."""
    cfg, params = setup
    wb = packed_weight_bytes(cfg)
    assert wb["packed_code_bytes"] * 4 == wb["bf16_bytes"]
    assert wb["weight_elems"] > 0
    assert wb["packed_bytes"] == sum(
        wb[k] for k in ("packed_code_bytes", "scale_bytes", "col_sums_bytes",
                        "spec_bytes", "act_bytes", "bias_bytes")
    )

    pparams = pack_decode_params(params, cfg)
    actual = {"packed_code_bytes": 0, "scale_bytes": 0, "col_sums_bytes": 0,
              "spec_bytes": 0, "act_bytes": 0, "bias_bytes": 0}
    key_map = {"packed": "packed_code_bytes", "scale": "scale_bytes",
               "col_sums": "col_sums_bytes", "spec_arr": "spec_bytes",
               "act_scale": "act_bytes", "act_zp": "act_bytes",
               "bias": "bias_bytes"}

    def walk(node):
        if isinstance(node, dict):
            if "packed" in node:
                for k, v in node.items():
                    if k != "spec":
                        actual[key_map[k]] += v.size * v.dtype.itemsize
                return
            for v in node.values():
                walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(pparams["layers"])
    for k, v in actual.items():
        assert wb[k] == v, (k, wb[k], v)
    assert sum(actual.values()) == wb["packed_bytes"]


def test_packed_weight_bytes_static_act_and_bias(setup):
    """Calibrated artifacts (f32 scales, static act quantizers, corrected
    biases on the output projections) are counted exactly too."""
    import jax.numpy as jnp

    from repro.core import PTQConfig
    from repro.quant import calibrate_and_quantize
    from repro.quant.serve_packed import serving_params_from_quantized

    cfg, params = setup
    batches = [{"tokens": jax.random.randint(jax.random.key(3), (2, 16), 0, 128)}]
    qm = calibrate_and_quantize(params, cfg, batches, PTQConfig(algorithm="rtn"))
    sp = serving_params_from_quantized(qm)
    wb = packed_weight_bytes(cfg, scale_bytes_per=4, static_act=True,
                             with_bias=True)

    total = 0

    def walk(node):
        nonlocal total
        if isinstance(node, dict):
            if "packed" in node:
                total += sum(v.size * v.dtype.itemsize
                             for k, v in node.items() if k != "spec")
                return
            for v in node.values():
                walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(sp["layers"])
    assert total == wb["packed_bytes"], (total, wb["packed_bytes"])


def test_hybrid_family_packs_under_eval_shape():
    """Registry-driven packing covers the Jamba-style hybrid (mamba + moe +
    attn + mlp) that the hardcoded PACKABLE tuple used to reject."""
    cfg = get_smoke("jamba-1.5-large-398b")
    abstract = jax.eval_shape(lambda k: T.init_model(k, cfg), jax.random.key(0))
    packed = jax.eval_shape(lambda p: pack_decode_params(p, cfg), abstract)
    # a mamba in_proj and a stacked moe expert weight both got packed
    slot0 = packed["layers"][0]
    assert "packed" in slot0["mixer"]["in_proj"]
    moe_slot = next(
        s for s, spec in zip(packed["layers"], cfg.pattern) if spec.ffn == "moe"
    )
    wd = moe_slot["ffn"]["wd"]
    assert wd["packed"].dtype == jnp.int8
    assert wd["packed"].shape[-2] * 2 == cfg.moe.d_ff_expert


def test_packed_ssm_forward_tracks_float():
    from repro.configs import get_config

    cfg = get_config("tiny-ssm")
    params = T.init_model(jax.random.key(0), cfg)
    pparams = pack_decode_params(params, cfg)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab)}
    l_f, _ = T.forward(params, batch, cfg)
    l_q, _ = T.forward(pparams, batch, cfg)
    corr = float(jnp.corrcoef(l_f.ravel(), l_q.ravel())[0, 1])
    assert corr > 0.85, corr
    assert bool(jnp.all(jnp.isfinite(l_q)))


def test_vocab_padding_masks_pad_logits():
    cfg = get_smoke("smollm-360m").scaled(n_layers=1, vocab=100,
                                          vocab_pad_multiple=128)
    assert cfg.vocab_padded == 128
    params = T.init_model(jax.random.key(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (1, 8), 0, 100)}
    logits, _ = T.forward(params, batch, cfg)
    assert logits.shape[-1] == 128
    pad = np.asarray(logits[..., 100:])
    real = np.asarray(logits[..., :100])
    assert pad.max() < real.min()  # -inf-masked: never selected
