"""Sharding rules: divisibility fallbacks, param/batch/cache specs."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke
from repro.launch.mesh import make_mesh
from repro.models.transformer import abstract_params, init_cache
from repro.runtime.sharding import (
    DEFAULT_RULES,
    batch_shardings,
    cache_shardings,
    param_shardings,
    resolve_spec,
)


@pytest.fixture(scope="module")
def mesh():
    if len(jax.devices()) != 1:
        pytest.skip("expects the default single-device test env")
    return make_mesh((1, 1))  # shape-logic only; axis sizes 1 divide anything


def test_resolve_spec_divisibility_fallback():
    import jax

    m = make_mesh((1, 1))
    # fabricate a mesh dict-alike: use real mesh but sizes 1 always divide;
    # exercise the arithmetic directly instead
    spec = resolve_spec((7, 64), ("vocab", "data_in"), m, DEFAULT_RULES)
    assert spec == P("model", "data")


def test_param_shardings_structure(mesh):
    cfg = get_smoke("smollm-360m")
    params = abstract_params(cfg)
    sh = param_shardings(params, mesh)
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec"))
    assert len(flat_p) == len(flat_s)
    # stacked layer leaves never shard the repeats axis
    import jax.tree_util as jtu

    for path, leaf in jtu.tree_flatten_with_path(params)[0]:
        keys = [getattr(e, "key", None) for e in path]
        if "layers" in keys:
            s = sh
            for e in path:
                if hasattr(e, "key"):
                    s = s[e.key]
                else:
                    s = s[e.idx]
            assert s.spec[0] is None


def test_batch_shardings_batch_axis(mesh):
    b = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    sh = batch_shardings(b, mesh)
    assert sh["tokens"].spec[0] in (("data",), "data", ("pod", "data"))


def test_cache_shardings_kv_fallback(mesh):
    """kv_heads indivisible by model axis -> sequence-sharded KV."""
    cfg = get_smoke("llama3-405b")  # kv=2 in smoke
    cache = init_cache(cfg, batch=4, max_len=32, abstract=True)
    sh = cache_shardings(cache, cfg, mesh)
    spec = sh[0]["k"].spec
    assert len(spec) == 5


def test_paged_cache_shardings(mesh):
    """Paged cache leaves resolve: pools shard kv_heads only (page axis
    never sharded), bookkeeping leaves replicate."""
    from repro.models.transformer import init_paged_cache

    cfg = get_smoke("smollm-360m")
    cache = init_paged_cache(cfg, num_slots=4, num_blocks=16, block_size=8,
                             max_pages=4, abstract=True)
    sh = cache_shardings(cache, cfg, mesh)
    kp = sh["pools"][0]["k_pages"].spec
    assert len(kp) == 5
    assert kp[0] is None and kp[1] is None and kp[2] is None  # R/pages/block
    for name in ("block_table", "seq_lens", "free_list", "free_top", "active"):
        assert sh[name].spec == P()


def test_paged_cache_shardings_int8_scale_leaves(mesh):
    """int8 KV pools: the per-(page, head) scale leaves resolve and
    co-shard their kv_heads dim with the code pools (a device holding a
    head's codes must hold its scales); page axis never sharded."""
    from repro.models.transformer import init_paged_cache

    cfg = get_smoke("smollm-360m")
    cache = init_paged_cache(cfg, num_slots=4, num_blocks=16, block_size=8,
                             max_pages=4, abstract=True, kv_dtype="int8")
    pool = cache["pools"][0]
    assert pool["k_pages"].dtype == jnp.int8
    assert pool["k_scales"].shape == (cfg.repeats, 16, cfg.n_kv_heads)
    sh = cache_shardings(cache, cfg, mesh)
    for name in ("k_scales", "v_scales"):
        spec = sh["pools"][0][name].spec
        assert len(spec) == 3
        assert spec[0] is None and spec[1] is None  # repeats / page axis
        assert spec[2] == sh["pools"][0]["k_pages"].spec[3]  # kv_heads dim


def test_paged_admin_leaves_enumerate_cache(mesh):
    """_PAGED_ADMIN_LEAVES must exactly enumerate the non-pool top-level
    leaves of init_paged_cache — adding a leaf to one without the other
    is the silent-replication bug this contract exists to catch."""
    from repro.models.transformer import init_paged_cache
    from repro.runtime.sharding import _PAGED_ADMIN_LEAVES

    cfg = get_smoke("smollm-360m")
    for kv_dtype in (None, "int8"):
        cache = init_paged_cache(cfg, num_slots=4, num_blocks=16, block_size=8,
                                 max_pages=4, abstract=True, kv_dtype=kv_dtype)
        assert set(cache) - {"pools"} == set(_PAGED_ADMIN_LEAVES)


def test_paged_cache_unknown_leaf_raises(mesh):
    """A paged-cache leaf outside pools/_PAGED_ADMIN_LEAVES must error
    loudly at sharding-resolution time, not silently replicate."""
    from repro.models.transformer import init_paged_cache

    cfg = get_smoke("smollm-360m")
    cache = dict(init_paged_cache(cfg, num_slots=4, num_blocks=16,
                                  block_size=8, max_pages=4, abstract=True))
    cache["mystery_counter"] = jax.ShapeDtypeStruct((16,), jnp.int32)
    with pytest.raises(ValueError, match="mystery_counter"):
        cache_shardings(cache, cfg, mesh)


def test_packed_moe_scales_coshard_expert_axis(mesh):
    """(E, K, C) packed MoE decode stacks: scale/col_sums co-shard the
    expert axis with the codes, so an EP device dequantizes its experts
    without gathering metadata; spec_arr twins stay replicated."""
    from repro.configs import get_config
    from repro.models.transformer import init_model
    from repro.quant.serve_packed import pack_decode_params
    from repro.runtime.sharding import SERVING_QUANT_RULES

    cfg = get_config("tiny-moe")
    params = pack_decode_params(init_model(jax.random.key(0), cfg), cfg)
    sh = param_shardings(params, mesh, SERVING_QUANT_RULES)
    wg = sh["layers"][0]["ffn"]["wg"]
    e_axis = wg["packed"].spec[1]
    assert e_axis is not None  # expert axis actually expert-parallel
    for name in ("scale", "col_sums"):
        assert wg[name].spec[1] == e_axis
        assert wg[name].spec[-1] == wg["packed"].spec[-1]  # channel dim too
    assert wg["spec_arr"].spec == P(None, None, None)


def test_logical_constraint_noop_without_rules():
    from repro.runtime.sharding import logical_constraint

    x = jnp.ones((4, 4))
    y = logical_constraint(x, ("batch", None))
    assert y is x
