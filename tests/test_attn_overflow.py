"""The attention analogue of ``test_overflow.py``: the
:class:`~repro.quant.spec.AttnDatapathSpec` accumulator record certifies
that the quantized paged-attention reductions — the hd-deep QK^T dot and
the per-page block_size-deep PV dot — never overflow their P_qk / P_pv
registers for ANY codes in their alphabets, and that both bounds are
*tight*: one fewer bit genuinely wraps on the adversarial ±max-code pages.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention import paged_decode_attention
from repro.quant.spec import (
    AttnDatapathSpec,
    DatapathMismatchError,
    attn_accumulator_bits,
    validate_attn_datapath,
)


def _signed_limit(p):
    return 2 ** (p - 1) - 1


def _wrap(x, p_bits):
    """Two's-complement wrap of an exact int64 value into a P-bit register."""
    m = np.int64(1) << (p_bits - 1)
    return ((x + m) % (2 * m)) - m


@pytest.mark.parametrize("hd,bs", [(8, 8), (20, 16), (64, 64), (128, 128),
                                   (7, 8), (11, 16)])
def test_spec_bounds_hold_and_are_tight(hd, bs):
    spec = AttnDatapathSpec.for_cache(hd, bs)
    assert spec.certify()
    # tight: P-1 bits does overflow for both registers
    assert spec.qk_worst_abs() > _signed_limit(spec.p_qk - 1)
    assert spec.pv_worst_abs() > _signed_limit(spec.p_pv - 1)


def test_accumulator_bits_matches_analytic_bound():
    # hd * q_qmax * kv_qmax for hd=128 int8xint8: 128 * 127 * 127
    #   = 2064512 <= 2^21 - 1, so a 22-bit register holds it
    assert attn_accumulator_bits(128, 127, 127) == 22
    # bs * prob_qmax * kv_qmax for bs=128: 128 * 255 * 127
    #   = 4145280 <= 2^22 - 1 -> 23 bits
    assert attn_accumulator_bits(128, 255, 127) == 23
    # the defaults are exactly the hd=128 / bs=128 recipe
    d = AttnDatapathSpec()
    assert (d.p_qk, d.p_pv) == (22, 23) and d.certify()
    with pytest.raises(ValueError, match="depth"):
        attn_accumulator_bits(0, 127, 127)


@pytest.mark.parametrize("hd,bs", [(16, 8), (64, 32)])
def test_adversarial_max_code_pages_never_wrap(rng, hd, bs):
    """Exhaustive adversary, mirroring ``simulate_accumulation``: dot the
    ±max-code K/V pages against ±max query / probability codes plus random
    codes; the exact int64 accumulation must survive a P-bit register
    unchanged (no wrap) — and genuinely wrap at P-1 bits."""
    spec = AttnDatapathSpec.for_cache(hd, bs)

    # QK^T: q codes x k codes over hd
    k_adv = np.full((bs, hd), spec.kv_qmax, np.int64)
    q_rows = np.stack([
        np.full(hd, spec.q_qmax, np.int64),
        np.full(hd, -spec.q_qmax, np.int64),
        rng.integers(-spec.q_qmax, spec.q_qmax + 1, size=hd),
    ])
    s_exact = q_rows @ k_adv.T  # int64, worst |value| = qk_worst_abs
    assert np.abs(s_exact).max() == spec.qk_worst_abs()
    assert np.abs(s_exact).max() <= _signed_limit(spec.p_qk)
    np.testing.assert_array_equal(_wrap(s_exact, spec.p_qk), s_exact)
    assert (_wrap(s_exact, spec.p_qk - 1) != s_exact).any()  # P-1 wraps

    # PV: probability codes x v codes over the page
    v_adv = np.full((bs, hd), -spec.kv_qmax, np.int64)
    p_rows = np.stack([
        np.full(bs, spec.prob_qmax, np.int64),
        rng.integers(0, spec.prob_qmax + 1, size=bs),
    ])
    pv_exact = p_rows @ v_adv
    assert np.abs(pv_exact).max() == spec.pv_worst_abs()
    assert np.abs(pv_exact).max() <= _signed_limit(spec.p_pv)
    np.testing.assert_array_equal(_wrap(pv_exact, spec.p_pv), pv_exact)
    assert (_wrap(pv_exact, spec.p_pv - 1) != pv_exact).any()


def test_kernel_register_checks_hold_on_adversarial_pages():
    """Drive the interpret-mode kernel over ±max-code pages with a query
    that quantizes to ±max codes, with ``assert_bounds=True``: the QK^T
    watermark achieves exactly ``hd * q_qmax * kv_qmax`` and the in-kernel
    register checks must still pass (the certificate is not vacuous —
    these are the worst inputs the alphabet admits)."""
    B, nkv, g, hd, bs, P = 2, 2, 2, 16, 8, 2
    nh = nkv * g
    nb = B * P
    spec = AttnDatapathSpec.for_cache(hd, bs)
    # constant-sign max-magnitude rows quantize to exactly ±q_qmax codes
    q = jnp.ones((B, nh, hd), jnp.float32) * 3.0
    kc = jnp.full((nb, bs, nkv, hd), spec.kv_qmax, jnp.int8)
    vc = jnp.full((nb, bs, nkv, hd), -spec.kv_qmax, jnp.int8)
    scales = jnp.full((nb, nkv), 0.01, jnp.float32)
    tab = jnp.arange(nb, dtype=jnp.int32).reshape(B, P)
    lens = jnp.asarray([P * bs, P * bs - 1], jnp.int32)
    out = paged_decode_attention(q, kc, vc, tab, lens, k_scales=scales,
                                 v_scales=scales, interpret=True,
                                 assert_bounds=True)
    # every value row is the constant -kv_qmax * scale vector; the weighted
    # average of a constant is that constant, whatever the probabilities
    want = -float(spec.kv_qmax) * 0.01
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-3)


def test_validate_attn_datapath_contract():
    spec = AttnDatapathSpec.for_cache(16, 8)
    validate_attn_datapath(spec, AttnDatapathSpec.for_cache(16, 8))
    with pytest.raises(DatapathMismatchError, match="float KV"):
        validate_attn_datapath(None, spec)
    with pytest.raises(DatapathMismatchError, match="attention datapath"):
        validate_attn_datapath(spec, AttnDatapathSpec.for_cache(16, 16))
    # scale_bound is calibration numerics, not datapath identity
    import dataclasses

    validate_attn_datapath(spec, dataclasses.replace(spec, scale_bound=0.5))


def test_kernel_spec_request_validated_like_weight_sites(rng):
    """A quantized-kernel call with a disagreeing AttnDatapathSpec request
    raises loudly (the packed_linear contract), and the matching request
    passes."""
    B, nkv, g, hd, bs, P = 2, 2, 1, 8, 4, 2
    nh, nb = nkv * g, B * P
    q = jnp.asarray(rng.normal(size=(B, nh, hd)), jnp.float32)
    kc = jnp.asarray(rng.integers(-127, 128, size=(nb, bs, nkv, hd)), jnp.int8)
    vc = jnp.asarray(rng.integers(-127, 128, size=(nb, bs, nkv, hd)), jnp.int8)
    sc = jnp.full((nb, nkv), 0.05, jnp.float32)
    tab = jnp.arange(nb, dtype=jnp.int32).reshape(B, P)
    lens = jnp.asarray([3, 7], jnp.int32)
    good = AttnDatapathSpec.for_cache(hd, bs)
    paged_decode_attention(q, kc, vc, tab, lens, k_scales=sc, v_scales=sc,
                           attn_spec=good, interpret=True)
    with pytest.raises(DatapathMismatchError, match="attention datapath"):
        paged_decode_attention(q, kc, vc, tab, lens, k_scales=sc, v_scales=sc,
                               attn_spec=AttnDatapathSpec.for_cache(hd, 2 * bs),
                               interpret=True)
    # a request against FLOAT pages must raise too (absence of a record is
    # a mismatch, not a match) — never a silent float fallback
    kf = jnp.asarray(rng.normal(size=(nb, bs, nkv, hd)), jnp.float32)
    with pytest.raises(DatapathMismatchError, match="float KV"):
        paged_decode_attention(q, kf, kf, tab, lens, attn_spec=good,
                               interpret=True)


def test_layer_ref_impl_validates_spec_request(rng):
    """The gather-reference impl (the CPU default) enforces the same
    request validation as the kernel: a disagreeing AttnDatapathSpec (or
    any request against a float pool) raises from the layer seam."""
    import jax

    from repro.configs import get_smoke
    from repro.models.layers import init_attention, paged_attention_decode
    from repro.models.transformer import init_paged_cache

    cfg = get_smoke("smollm-360m")
    p = init_attention(jax.random.key(0), cfg)
    B, bs = 2, 8
    cache = init_paged_cache(cfg, B, 8, bs, 2, kv_dtype="int8")
    pool = {k: v[0] for k, v in cache["pools"][0].items()}  # strip R
    x = jnp.asarray(rng.normal(size=(B, 1, cfg.d_model)), jnp.float32)
    table = jnp.zeros((B, 2), jnp.int32).at[1, 0].set(1)
    lens = jnp.asarray([1, 1], jnp.int32)
    active = jnp.ones((B,), bool)
    good = AttnDatapathSpec.for_cache(cfg.head_dim, bs)
    y, new_pool = paged_attention_decode(p, x, cfg, pool, table, lens,
                                         active, impl="ref", attn_spec=good)
    assert "k_scales" in new_pool and y.shape == (B, 1, cfg.d_model)
    with pytest.raises(DatapathMismatchError, match="attention datapath"):
        paged_attention_decode(p, x, cfg, pool, table, lens, active,
                               impl="ref",
                               attn_spec=AttnDatapathSpec.for_cache(
                                   cfg.head_dim, 2 * bs))
    float_pool = {k: v for k, v in pool.items() if "scales" not in k}
    float_pool = {k: v.astype(jnp.float32) for k, v in float_pool.items()}
    with pytest.raises(DatapathMismatchError, match="float KV"):
        paged_attention_decode(p, x, cfg, float_pool, table, lens, active,
                               impl="ref", attn_spec=good)
