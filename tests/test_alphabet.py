"""Unit + property tests for the accumulator bound algebra (Eqs. 3/4/17/21/22)."""

import pytest
pytest.importorskip("hypothesis")
from hypothesis import given
from hypothesis import strategies as st

from repro.core.alphabet import (
    Alphabet,
    accumulator_range,
    act_alphabet,
    l1_budget_zero_centered,
    min_accumulator_bits,
    outer_accumulator_bits,
    strict_budgets,
    weight_alphabet,
    worst_case_dot_bounds,
)


def test_weight_alphabet_sign_magnitude():
    a = weight_alphabet(4)
    assert a.qmin == -7 and a.qmax == 7 and a.span == 14


def test_act_alphabet_unsigned():
    a = act_alphabet(8)
    assert a.qmin == 0 and a.qmax == 255 and a.span == 255
    assert a.mu == 0 and a.nu == 255


def test_act_alphabet_signed():
    a = act_alphabet(8, signed=True)
    assert a.qmin == -127 and a.qmax == 127


def test_accumulator_range():
    lo, hi = accumulator_range(16)
    assert hi == 32767 and lo == -32767


def test_eq3_paper_example():
    # paper §4.2: W4A8, K == T == 128 gives P* == 20
    assert min_accumulator_bits(128, 8, 4, signed_input=False) == 20


@given(
    k=st.integers(1, 1 << 20),
    n=st.integers(2, 8),
    m=st.integers(2, 8),
    signed=st.booleans(),
)
def test_eq3_is_sufficient(k, n, m, signed):
    """P* must cover the exact worst-case dot product magnitude."""
    p = min_accumulator_bits(k, n, m, signed)
    w_max = 2 ** (m - 1) - 1
    x_max = (2 ** (n - 1) - 1) if signed else (2**n - 1)
    worst = k * w_max * x_max
    lo, hi = accumulator_range(p)
    assert worst <= hi  # Eq. 3 is a sufficient (not tight) datatype bound


@given(p=st.integers(8, 32), n=st.integers(2, 8))
def test_eq4_budget_positive(p, n):
    b = l1_budget_zero_centered(p, act_alphabet(n))
    assert b > 0


@given(p=st.integers(10, 32), n=st.integers(2, 8), slack=st.sampled_from([0.0, 0.5]))
def test_strict_budgets_guarantee(p, n, slack):
    """Committing pos <= B + slack implies nu*pos <= 2^(P-1)-1 (Eq. 17/21)."""
    act = act_alphabet(n)
    bud = strict_budgets(p, act, slack)
    assert bud.mode == "split"
    _, hi = accumulator_range(p)
    assert act.nu * (bud.B + slack) <= hi + 1e-6


@given(
    p_i=st.integers(8, 24),
    log_k=st.integers(6, 18),
    log_t=st.integers(4, 10),
)
def test_eq22_outer_bits(p_i, log_k, log_t):
    k, t = 1 << log_k, 1 << log_t
    if t > k:
        t = k
    p_o = outer_accumulator_bits(p_i, k, t)
    # summing k/t partials each bounded by 2^(P_I-1)-1 must fit P_O
    n_tiles = k // t
    worst = n_tiles * (2 ** (p_i - 1) - 1)
    _, hi = accumulator_range(p_o)
    assert worst <= hi


def test_worst_case_dot_bounds_unsigned():
    act = act_alphabet(4)  # nu = 15
    lo, hi = worst_case_dot_bounds(pos_sum=10.0, neg_sum=-4.0, act=act)
    assert hi == 150.0 and lo == -60.0


def test_strict_budget_too_small_raises():
    with pytest.raises(ValueError):
        strict_budgets(4, act_alphabet(8), 0.5)


def test_alphabet_validation():
    with pytest.raises(ValueError):
        Alphabet(bits=0, signed=True)
