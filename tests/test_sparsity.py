"""2:4 semi-structured sparsity under the accumulator certificate.

Covers the mask/compress primitives, the mask-aware GPFQ/OPTQ solves, the
sparse decode kernel's bit-identity with dense-with-zeros through
``packed_linear``, the effective-depth certificate math (analytic AND
adversarial — a sparse site's register floor is strictly tighter than the
dense floor at equal code width), and the certificate-floor regressions
(margin-saturated peaks, tiled reports re-deriving Eq. 22 from their own
recorded depth). Property batteries run as seeded loops — tier-1 must not
depend on hypothesis.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    LayerStats,
    PTQConfig,
    accumulator_range,
    act_alphabet,
    certify,
    effective_depth,
    is_2to4,
    mask_2to4,
    min_accumulator_bits,
    min_feasible_p_bits,
    quantize_linear,
    simulate_accumulation,
    worst_case_inputs,
)
from repro.core.sparsity import check_2to4
from repro.kernels.w4a8_mm import (
    compress_2to4,
    pack_int4,
    unpack_sparse24,
    w4a8_decode_matmul,
    w4a8_sparse_decode_matmul,
)
from repro.quant.spec import DatapathSpec


# ---------------------------------------------------------------------------
# Mask and compressed-layout primitives
# ---------------------------------------------------------------------------
def test_mask_2to4_properties(rng):
    """Every group of 4 keeps exactly the 2 largest magnitudes."""
    for seed in range(8):
        r = np.random.default_rng(seed)
        w = jnp.asarray(r.standard_normal((32, 6)), jnp.float32)
        m = np.asarray(mask_2to4(w))
        assert set(np.unique(m)) <= {0.0, 1.0}
        g = m.reshape(8, 4, 6)
        assert np.all(g.sum(axis=1) == 2)
        # kept entries dominate dropped entries within each group
        aw = np.abs(np.asarray(w)).reshape(8, 4, 6)
        kept_min = np.where(g > 0, aw, np.inf).min(axis=1)
        drop_max = np.where(g == 0, aw, -np.inf).max(axis=1)
        assert np.all(kept_min >= drop_max)


def test_mask_requires_group_aligned_k():
    with pytest.raises(ValueError, match="4"):
        mask_2to4(jnp.ones((6, 3)))


def test_is_2to4_and_check(rng):
    q = rng.integers(-7, 8, size=(16, 4)).astype(np.int8)
    q = np.asarray(jnp.asarray(q) * mask_2to4(jnp.asarray(q)))
    assert is_2to4(q)
    dense = np.full((4, 2), 3, np.int8)  # 4 nonzeros in the single group
    assert not is_2to4(dense)
    with pytest.raises(ValueError, match="2:4"):
        check_2to4(dense)


def test_compress_round_trip_exact(rng):
    """compress_2to4 -> unpack_sparse24 reproduces the dense-with-zeros
    codes bit for bit, including stacked leading axes."""
    for shape in ((32, 8), (2, 16, 4), (3, 2, 8, 5)):
        q = rng.integers(-7, 8, size=shape).astype(np.int8)
        q = np.asarray(jnp.asarray(q) * mask_2to4(jnp.asarray(q)).astype(jnp.int8))
        packed, meta = compress_2to4(jnp.asarray(q))
        assert packed.shape[-2] == shape[-2] // 4
        assert meta.shape[-2] == shape[-2] // 4
        back = np.asarray(unpack_sparse24(packed, meta))
        np.testing.assert_array_equal(back, q)


def test_effective_depth():
    assert effective_depth(128, None) == 128
    assert effective_depth(128, "2:4") == 64
    assert effective_depth(2, "2:4") == 1
    with pytest.raises(ValueError):
        effective_depth(16, "1:8")
    # Eq. 3 with the halved depth saves exactly one bit at power-of-two K
    assert (
        min_accumulator_bits(128, 8, 4, False, sparsity="2:4")
        == min_accumulator_bits(128, 8, 4, False) - 1
    )


# ---------------------------------------------------------------------------
# Mask-aware solvers: valid codes, certified, error feedback helps
# ---------------------------------------------------------------------------
def _sparse_layer(seed, algorithm, k=32, c=8, p_bits=14, tile=8):
    r = np.random.default_rng(seed)
    w = jnp.asarray(r.normal(size=(k, c)) * 2.0, jnp.float32)
    x = jnp.asarray(r.normal(size=(192, k)), jnp.float32)
    stats = LayerStats(k=k)
    stats.update(x)
    cfg = PTQConfig(p_bits=p_bits, tile=tile, algorithm=algorithm,
                    sparsity="2:4")
    return w, x, quantize_linear(w, stats, cfg), cfg


@pytest.mark.parametrize("algorithm", ["gpfq", "optq", "rtn", "ep_init"])
def test_sparse_solvers_emit_valid_certified_codes(algorithm):
    for seed in range(3):
        _, _, ql, _ = _sparse_layer(seed, algorithm)
        q = np.asarray(ql.q_int)
        assert is_2to4(q), algorithm
        assert ql.cert is not None and bool(ql.cert), algorithm
        assert ql.cert.sparsity == "2:4"
        assert ql.spec.sparsity == "2:4"


@pytest.mark.parametrize("algorithm", ["gpfq", "optq"])
def test_error_feedback_beats_mask_then_round(algorithm):
    """The greedy solves redistribute pruned energy through the unmasked
    support: on-calibration layer reconstruction error (the objective the
    solvers actually minimize, under the accumulator constraint) must beat
    the no-feedback mask-then-RTN baseline in aggregate."""
    err = err_rtn = 0.0
    for seed in range(4):
        w, x, ql, cfg = _sparse_layer(seed, algorithm)
        _, _, ql_rtn, _ = _sparse_layer(seed, "rtn")
        err += float(jnp.mean((x @ w - x @ ql.w_q) ** 2))
        err_rtn += float(jnp.mean((x @ w - x @ ql_rtn.w_q) ** 2))
    assert err < err_rtn, f"{algorithm}: {err} vs mask-then-RTN {err_rtn}"


def test_sparse_certificate_adversarially_sound():
    """Masked-input adversary battery (seeded loops): the analytic sparse
    certificate upper-bounds int64 accumulation of the worst-case AND
    random admissible inputs, for every seed."""
    na = act_alphabet(8)
    for seed in range(6):
        _, _, ql, cfg = _sparse_layer(seed, "gpfq", k=32, c=8, p_bits=14, tile=8)
        q = np.asarray(ql.q_int)
        u, v = worst_case_inputs(ql.q_int, na)
        r = np.random.default_rng(seed)
        rand = r.integers(na.qmin, na.qmax + 1, size=(64, q.shape[0]))
        x_all = np.concatenate([np.asarray(u), np.asarray(v), rand], axis=0)
        sim = simulate_accumulation(q, x_all, tile=8)
        assert sim["partial_hi"] <= ql.cert.worst_hi
        assert sim["partial_lo"] >= ql.cert.worst_lo
        lo_i, hi_i = accumulator_range(ql.cert.p_bits)
        assert sim["partial_hi"] <= hi_i and sim["partial_lo"] >= lo_i
        lo_o, hi_o = accumulator_range(ql.cert.p_outer)
        assert sim["total_hi"] <= hi_o and sim["total_lo"] >= lo_o


def test_sparse_floor_strictly_tighter_than_dense():
    """Acceptance criterion: at equal code width, a 2:4 site's certified
    register floor is strictly below the dense floor — analytically via
    ``min_feasible_p_bits`` and adversarially via ``simulate_accumulation``
    (equal-magnitude codes make the halved per-tile sums cross a bit
    boundary: 7*128 needs 19 bits against A8u, 7*64 needs 18)."""
    k, c = 128, 4
    na = act_alphabet(8)
    dense = jnp.full((k, c), 7.0, jnp.float32)
    sparse = jnp.asarray(np.tile([7.0, 7.0, 0.0, 0.0], k // 4)[:, None] *
                         np.ones((1, c)), jnp.float32)
    assert is_2to4(np.asarray(sparse))

    cert_d = certify(dense, na, p_bits=32, tile=None)
    cert_s = certify(sparse, na, p_bits=32, tile=None, sparsity="2:4")
    floor_d = min_feasible_p_bits(cert_d)
    floor_s = min_feasible_p_bits(cert_s)
    assert floor_s < floor_d, (floor_s, floor_d)

    # the analytic gap is real: the adversarial extrema need exactly those
    # register widths in an int64 simulation
    for q, floor in ((dense, floor_d), (sparse, floor_s)):
        u, v = worst_case_inputs(q, na)
        sim = simulate_accumulation(
            np.asarray(q), np.concatenate([np.asarray(u), np.asarray(v)])
        )
        assert sim["inner_bits_used"] == floor
    assert floor_s == floor_d - 1


def test_sparse_tiled_floor_tighter_and_outer_consistent():
    """Multi-stage: the tiled sparse floor is tighter too, and Eq. 22's
    re-derivation (halving depth and tile together) keeps P_O - P_I
    invariant, so the tightened floor never implies an overflowing outer."""
    k, c, t = 128, 4, 32
    na = act_alphabet(8)
    dense = jnp.full((k, c), 7.0, jnp.float32)
    sparse = jnp.asarray(np.tile([7.0, 7.0, 0.0, 0.0], k // 4)[:, None] *
                         np.ones((1, c)), jnp.float32)
    cert_d = certify(dense, na, p_bits=20, tile=t)
    cert_s = certify(sparse, na, p_bits=20, tile=t, sparsity="2:4")
    assert cert_d.p_outer - cert_d.p_bits == cert_s.p_outer - cert_s.p_bits
    floor_d = min_feasible_p_bits(cert_d, k)
    floor_s = min_feasible_p_bits(cert_s, k)
    assert floor_s < floor_d
    from repro.core import outer_accumulator_bits

    for cert, floor in ((cert_d, floor_d), (cert_s, floor_s)):
        # the floor's re-derived Eq. 22 outer register holds the recorded
        # outer extrema (this is exactly what min_feasible_p_bits checks)
        po = outer_accumulator_bits(floor, k, t, sparsity=cert.sparsity)
        lo_o, hi_o = accumulator_range(po)
        assert cert.outer_hi <= hi_o and cert.outer_lo >= lo_o


def test_certify_sparse_rejects_dense_codes():
    na = act_alphabet(8)
    dense = jnp.full((8, 2), 3.0, jnp.float32)
    with pytest.raises(ValueError, match="2:4"):
        certify(dense, na, p_bits=16, tile=None, sparsity="2:4")


# ---------------------------------------------------------------------------
# Certificate-floor regressions (bugfix satellites)
# ---------------------------------------------------------------------------
def test_min_feasible_p_bits_raises_when_margin_saturates():
    """Regression: a certificate whose peaks already saturate the certified
    register must RAISE under a margin that inflates them past it — the old
    code silently returned ``report.p_bits`` (an infeasible width)."""
    na = act_alphabet(8)
    q = jnp.full((16, 2), 7.0, jnp.float32)  # peak 7*16*255 = 28560
    p = min_accumulator_bits(16, 8, 4, False)  # exactly-fitting register
    cert = certify(q, na, p_bits=p, tile=None)
    assert bool(cert)
    assert min_feasible_p_bits(cert) <= p
    with pytest.raises(ValueError, match="margin"):
        min_feasible_p_bits(cert, margin_bits=4.0)


def test_min_feasible_p_bits_tiled_respects_outer_without_k():
    """Regression: a tiled report consulted WITHOUT the caller-supplied
    ``k`` must still re-derive P_O from its own recorded depth — the old
    code skipped the outer check entirely and could return a P_I whose
    Eq. 22 outer register overflows the recorded outer extrema."""
    na = act_alphabet(8)
    r = np.random.default_rng(3)
    q = jnp.asarray(r.integers(-7, 8, size=(256, 4)), jnp.float32)
    cert = certify(q, na, p_bits=24, tile=8)
    assert cert.k == 256
    floor_with_k = min_feasible_p_bits(cert, k=256)
    floor_without = min_feasible_p_bits(cert)
    assert floor_without == floor_with_k
    # and the floor's implied outer register really holds the extrema
    from repro.core import outer_accumulator_bits

    po = outer_accumulator_bits(floor_without, 256, 8)
    lo_o, hi_o = accumulator_range(po)
    assert cert.outer_hi <= hi_o and cert.outer_lo >= lo_o


def test_all_zero_site_headroom_finite():
    """An all-zero site reports finite headroom (= log2 of the register
    limit), not inf — so the search can order it deterministically."""
    na = act_alphabet(8)
    cert = certify(jnp.zeros((16, 2)), na, p_bits=16, tile=None)
    assert np.isfinite(cert.headroom_bits)
    assert cert.headroom_bits == pytest.approx(np.log2(2.0**15 - 1))


# ---------------------------------------------------------------------------
# Sparse decode kernel: bit-identity through packed_linear
# ---------------------------------------------------------------------------
def _leaves_for(q, scale, spec_dense, spec_sparse):
    q = jnp.asarray(q)
    col = jnp.sum(q.astype(jnp.int32), axis=-2)
    packed, meta = compress_2to4(q)
    dense = {
        "packed": pack_int4(q), "scale": scale, "col_sums": col,
        "spec": spec_dense,
        "spec_arr": jnp.asarray(spec_dense.to_array(), jnp.float32),
    }
    sparse = {
        "packed": packed, "meta": meta, "scale": scale, "col_sums": col,
        "spec": spec_sparse,
        "spec_arr": jnp.asarray(spec_sparse.to_array(), jnp.float32),
    }
    return dense, sparse


@pytest.mark.parametrize("m,k,n,t", [(4, 128, 64, 128), (130, 256, 128, 128),
                                     (8, 32, 16, 16)])
def test_sparse_kernel_bit_identical_through_packed_linear(rng, m, k, n, t):
    """The Pallas sparse decode path (interpret-validated) produces the
    exact float outputs of the dense kernel on dense-with-zeros codes, for
    ragged M, multi-K-tile grids and small shapes alike."""
    from repro.models.layers import packed_linear, use_packed_backend

    q = rng.integers(-7, 8, size=(k, n)).astype(np.int8)
    q = np.asarray(jnp.asarray(q) * mask_2to4(jnp.asarray(q)).astype(jnp.int8))
    scale = jnp.asarray(rng.random((1, n)) * 0.02 + 0.01, jnp.float32)
    sd = DatapathSpec(tile=t, p_inner=16, p_outer=20)
    ss = DatapathSpec(tile=t, p_inner=16, p_outer=20, sparsity="2:4")
    dense, sparse = _leaves_for(q, scale, sd, ss)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    with use_packed_backend("interpret"):
        yd = packed_linear(x, dense)
        ys = packed_linear(x, sparse)
    np.testing.assert_array_equal(np.asarray(yd), np.asarray(ys))


def test_sparse_kernel_matches_gather_reference(rng):
    """w4a8_sparse_decode_matmul == the dense kernel on the expanded codes,
    called directly (no layer dispatch in the loop)."""
    k, n = 64, 32
    q = rng.integers(-7, 8, size=(k, n)).astype(np.int8)
    q = np.asarray(jnp.asarray(q) * mask_2to4(jnp.asarray(q)).astype(jnp.int8))
    packed, meta = compress_2to4(jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(unpack_sparse24(packed, meta)), q)
    scale = jnp.asarray(rng.random((n,)), jnp.float32)
    col = jnp.sum(jnp.asarray(q, jnp.int32), axis=0)
    x = rng.integers(0, 256, size=(8, k)).astype(np.uint8)
    kw = dict(block_m=8, block_n=16, block_k=16, p_inner=16, interpret=True)
    yd = w4a8_decode_matmul(jnp.asarray(x), pack_int4(jnp.asarray(q)), scale,
                            col, jnp.float32(0.01), jnp.float32(3.0), **kw)
    ys = w4a8_sparse_decode_matmul(jnp.asarray(x), packed, meta, scale, col,
                                   jnp.float32(0.01), jnp.float32(3.0), **kw)
    np.testing.assert_array_equal(np.asarray(yd), np.asarray(ys))


def test_packed_linear_rejects_sparsity_layout_mismatch(rng):
    from repro.models.layers import packed_linear, use_packed_backend
    from repro.quant.spec import DatapathMismatchError

    k, n = 32, 16
    q = rng.integers(-7, 8, size=(k, n)).astype(np.int8)
    q = np.asarray(jnp.asarray(q) * mask_2to4(jnp.asarray(q)).astype(jnp.int8))
    scale = jnp.ones((1, n), jnp.float32)
    sd = DatapathSpec(tile=16, p_inner=16, p_outer=17)
    ss = DatapathSpec(tile=16, p_inner=16, p_outer=17, sparsity="2:4")
    dense, sparse = _leaves_for(q, scale, sd, ss)
    x = jnp.asarray(rng.standard_normal((2, k)), jnp.float32)
    # dense layout claiming a sparse spec, and vice versa
    bad1 = {**dense, "spec": ss, "spec_arr": sparse["spec_arr"]}
    bad2 = {**sparse, "spec": sd, "spec_arr": dense["spec_arr"]}
    for bad in (bad1, bad2):
        with use_packed_backend("interpret"):
            with pytest.raises(DatapathMismatchError, match="sparsity"):
                packed_linear(x, bad)


# ---------------------------------------------------------------------------
# Pipeline integration: sparse sites certify at the halved depth
# ---------------------------------------------------------------------------
def test_sparse_site_floor_tighter_in_pipeline():
    """End to end through quantize_linear: the same layer solved dense vs
    2:4 yields a sparse floor no worse than dense, and the sparse
    certificate records its pattern for Eq. 22 re-derivations."""
    r = np.random.default_rng(0)
    k, c = 64, 8
    w = jnp.asarray(r.normal(size=(k, c)) * 2.0, jnp.float32)
    x = jnp.asarray(r.normal(size=(192, k)), jnp.float32)
    stats = LayerStats(k=k)
    stats.update(x)
    ql_d = quantize_linear(w, stats, PTQConfig(p_bits=16, tile=16))
    ql_s = quantize_linear(
        w, stats, PTQConfig(p_bits=16, tile=16, sparsity="2:4")
    )
    floor_d = min_feasible_p_bits(ql_d.cert, k)
    floor_s = min_feasible_p_bits(ql_s.cert, k)
    assert floor_s <= floor_d
    assert ql_s.cert.sparsity == "2:4" and ql_d.cert.sparsity is None
