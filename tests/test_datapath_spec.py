"""DatapathSpec end-to-end: one spec object from calibration to kernel.

Covers the spec schema round trip (array encoding, flat artifact on disk),
the kwarg-free packed_linear dispatch, the loud datapath-mismatch error,
the legacy-artifact upgrade shims (bit-identical decode, one-time cost),
static activation quantizers in the serving jaxpr (no dynamic per-tensor
max/min reduction — dense and SSM), and the engine's datapath-fingerprint
retrace key.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke
from repro.core import PTQConfig
from repro.models import transformer as T
from repro.models.layers import packed_linear, use_packed_backend
from repro.quant import calibrate_and_quantize
from repro.quant.serve_packed import (
    _pack_leaf,
    export_quantized_artifact,
    load_flat_artifact,
    pack_decode_params,
    packed_params_from_artifact,
    serving_params_from_quantized,
    upgrade_packed_params,
)
from repro.quant.spec import (
    ARTIFACT_VERSION,
    DatapathMismatchError,
    DatapathSpec,
    leaf_datapath,
    tree_datapath_fingerprint,
    validate_datapath,
)


def _corr(a, b) -> float:
    return float(jnp.corrcoef(jnp.ravel(a), jnp.ravel(b))[0, 1])


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_smoke("smollm-360m").scaled(n_layers=2, vocab=128)
    params = T.init_model(jax.random.key(0), cfg)
    batches = [{"tokens": jax.random.randint(jax.random.key(1), (2, 16), 0, 128)}]
    qm = calibrate_and_quantize(params, cfg, batches, PTQConfig(algorithm="rtn"))
    return cfg, params, qm


# ---------------------------------------------------------------------------
# Spec object: encoding, identity, defaults
# ---------------------------------------------------------------------------
def test_spec_array_round_trip():
    for spec in (
        DatapathSpec(),
        DatapathSpec(tile=None, p_inner=32, p_outer=32),
        DatapathSpec(w_bits=3, act_bits=6, act_signed=True, tile=64,
                     p_inner=12, p_outer=18).with_act(0.0123, 131),
    ):
        back = DatapathSpec.from_array(spec.to_array())
        assert back == spec
        assert back.spec_hash() == spec.spec_hash()


def test_spec_single_source_of_truth():
    """The p_inner=16 / T=128 recipe defaults exist in exactly one place:
    DatapathSpec. PTQConfig derives the same datapath, and packed_linear
    has no p_inner kwarg to disagree with."""
    import inspect

    assert PTQConfig().to_datapath_spec(256).key() == DatapathSpec(
        p_outer=PTQConfig().outer_bits(256)
    ).key()
    params = inspect.signature(packed_linear).parameters
    assert "p_inner" not in params and "tile" not in params


def test_ptq_to_datapath_spec_per_site_depth():
    ptq = PTQConfig()
    s_small, s_big = ptq.to_datapath_spec(128), ptq.to_datapath_spec(4096)
    assert s_small.p_inner == s_big.p_inner == ptq.p_bits
    assert s_big.p_outer > s_small.p_outer  # Eq. 22 grows with K/T
    act = None
    qm_spec = ptq.to_datapath_spec(128, act)
    assert not qm_spec.static_act


# ---------------------------------------------------------------------------
# Leaf dispatch: spec-driven kernel, loud mismatch
# ---------------------------------------------------------------------------
def test_packed_linear_nondefault_spec_drives_kernel(rng):
    """A (T=64, P_I=12) leaf rides the kernel with *its own* datapath — no
    kwargs anywhere — and matches the dequant fallback."""
    spec = DatapathSpec(tile=64, p_inner=12, p_outer=20)
    w = jnp.asarray(rng.normal(size=(128, 48)), jnp.float32)
    leaf = _pack_leaf(w, spec)
    assert leaf["spec"].key() == spec.key()
    x = jnp.asarray(rng.normal(size=(3, 128)), jnp.float32)
    with use_packed_backend("dequant"):
        yd = packed_linear(x, leaf)
    with use_packed_backend("interpret"):
        yk = packed_linear(x, leaf)
    assert _corr(yd, yk) > 0.999


def test_packed_linear_matching_request_ok(rng):
    leaf = _pack_leaf(jnp.asarray(rng.normal(size=(64, 32)), jnp.float32))
    x = jnp.asarray(rng.normal(size=(2, 64)), jnp.float32)
    with use_packed_backend("interpret"):
        packed_linear(x, leaf, spec=DatapathSpec())  # same datapath: fine


def test_packed_linear_mismatch_is_loud(rng):
    leaf = _pack_leaf(jnp.asarray(rng.normal(size=(64, 32)), jnp.float32))
    x = jnp.asarray(rng.normal(size=(2, 64)), jnp.float32)
    with use_packed_backend("interpret"):
        with pytest.raises(DatapathMismatchError, match="datapath mismatch"):
            packed_linear(x, leaf, spec=DatapathSpec(tile=256, p_inner=20))


def test_engine_requested_datapath_validated(dense_setup):
    from dataclasses import replace

    from repro.serving import GenerationEngine

    cfg, params, qm = dense_setup
    pparams = pack_decode_params(params, cfg)
    with pytest.raises(DatapathMismatchError):
        GenerationEngine(pparams, cfg, datapath=DatapathSpec(p_inner=24))
    eng = GenerationEngine(pparams, cfg, datapath=DatapathSpec())
    assert eng.datapath_fingerprint
    # a calibrated artifact has per-site P_O (derived from each site's K) —
    # one requested datapath must still validate across all of them
    sp = serving_params_from_quantized(qm)
    req = replace(qm.ptq.to_datapath_spec(cfg.d_model), static_act=True)
    GenerationEngine(sp, cfg, datapath=req)  # no spurious mismatch
    with pytest.raises(DatapathMismatchError):
        GenerationEngine(sp, cfg, datapath=replace(req, tile=64))


def test_pack_leaf_never_claims_static_act(rng):
    """RTN packing ships no act quantizers, so a wished-for static_act on
    the incoming spec is cleared — the embedded record describes the
    datapath the leaf actually serves."""
    w = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    leaf = _pack_leaf(w, DatapathSpec().with_act(0.02, 128))
    assert not leaf["spec"].static_act
    assert "act_scale" not in leaf
    assert not leaf_datapath({k: v for k, v in leaf.items()
                              if k != "spec"}).static_act  # twin agrees


def test_validate_datapath_rejects_legacy():
    w = jnp.ones((8, 4), jnp.float32)
    legacy = {k: v for k, v in _pack_leaf(w).items() if k in ("packed", "scale")}
    with pytest.raises(DatapathMismatchError, match="no DatapathSpec"):
        validate_datapath({"layers": ({"mixer": {"wq": legacy}},)}, DatapathSpec())


def test_pre_sparsity_spec_array_loads_as_dense():
    """Strict back-compat: a 10-slot spec array written by the pre-sparsity
    v2 schema decodes with ``sparsity=None`` (absent field == dense), and
    the 11-slot encoding round-trips the pattern."""
    dense = DatapathSpec(tile=64, p_inner=14, p_outer=16)
    legacy_arr = dense.to_array()[:10]  # exactly what old artifacts stored
    assert DatapathSpec.from_array(legacy_arr).sparsity is None
    assert DatapathSpec.from_array(legacy_arr).matches(dense)
    sparse = DatapathSpec(tile=64, p_inner=14, p_outer=16, sparsity="2:4")
    round_tripped = DatapathSpec.from_array(sparse.to_array())
    assert round_tripped.sparsity == "2:4"
    assert round_tripped.matches(sparse)
    assert not round_tripped.matches(dense)  # sparsity is identity-bearing
    # truncated below the legacy length is still an error, not a guess
    with pytest.raises(ValueError, match="slots"):
        DatapathSpec.from_array(dense.to_array()[:9])
    # unknown pattern codes refuse to decode
    bad = sparse.to_array()
    bad[10] = 99.0
    with pytest.raises(ValueError, match="sparsity code"):
        DatapathSpec.from_array(bad)


def test_validate_datapath_refuses_sparse_request_naming_field():
    """A dense artifact served under a sparse request (or vice versa) is a
    datapath mismatch whose error names the sparsity field — absence of
    the pattern is not a match."""
    w = jnp.ones((8, 4), jnp.float32)
    dense_leaf = _pack_leaf(w, DatapathSpec())
    tree = {"layers": ({"mixer": {"wq": dense_leaf}},)}
    sparse_req = dataclasses.replace(DatapathSpec(), sparsity="2:4")
    with pytest.raises(DatapathMismatchError, match="sparsity=2:4"):
        validate_datapath(tree, sparse_req)
    # and the sparse artifact refuses the dense request symmetrically
    w8 = jnp.asarray(np.tile([1.0, 1.0, 0.0, 0.0], 2)[:, None] *
                     np.ones((1, 4)), jnp.float32)
    sparse_leaf = _pack_leaf(w8, sparse_req)
    assert "meta" in sparse_leaf
    tree_s = {"layers": ({"mixer": {"wq": sparse_leaf}},)}
    assert validate_datapath(tree_s, dataclasses.replace(
        sparse_req, static_act=False)) == 1
    with pytest.raises(DatapathMismatchError, match="sparsity=2:4"):
        validate_datapath(tree_s, DatapathSpec())


# ---------------------------------------------------------------------------
# Calibration -> pack -> save -> load -> packed_linear (the full round trip)
# ---------------------------------------------------------------------------
def test_calibrated_round_trip_disk_bit_identical(dense_setup, tmp_path):
    """A DatapathSpec produced by calibrate_and_quantize survives
    pack -> save -> load and the reloaded artifact decodes bit-identically
    to the in-memory serving tree — with no kwarg re-specification."""
    from repro.checkpoint import save_pytree

    cfg, params, qm = dense_setup
    sp_mem = serving_params_from_quantized(qm)

    artifact, meta = export_quantized_artifact(qm)
    assert meta["artifact_version"] == ARTIFACT_VERSION
    save_pytree(artifact, str(tmp_path / "quantized"), meta)
    flat, meta_loaded = load_flat_artifact(str(tmp_path / "quantized"))
    sp_disk = packed_params_from_artifact(flat, params, cfg, meta=meta_loaded)

    # identical specs and fingerprints on both sides of the disk
    leaf_m = sp_mem["layers"][0]["mixer"]["wq"]
    leaf_d = sp_disk["layers"][0]["mixer"]["wq"]
    assert leaf_m["spec"] == leaf_d["spec"]
    assert leaf_m["spec"].static_act
    assert tree_datapath_fingerprint(sp_mem) == tree_datapath_fingerprint(sp_disk)

    batch = {"tokens": jax.random.randint(jax.random.key(2), (2, 8), 0, 128)}
    tok = jnp.ones((2, 1), jnp.int32)
    outs = {}
    for name, p in (("mem", sp_mem), ("disk", sp_disk)):
        with use_packed_backend("interpret"):
            _, cache = T.prefill(p, batch, cfg, max_len=12)
            logits, _ = T.decode_step(p, tok, cache, jnp.int32(8), cfg)
        outs[name] = np.asarray(logits)
    np.testing.assert_array_equal(outs["mem"], outs["disk"])


def test_artifact_version_mismatch_is_loud(dense_setup):
    cfg, params, qm = dense_setup
    artifact, meta = export_quantized_artifact(qm)
    with pytest.raises(DatapathMismatchError, match="artifact schema version"):
        packed_params_from_artifact(artifact, params, cfg,
                                    meta={"artifact_version": 1})


def test_artifact_arch_mismatch_is_loud(dense_setup):
    """An artifact exported for a different arch must refuse to load —
    every site key would miss and the float model would silently serve
    under the artifact's banner."""
    cfg, params, qm = dense_setup
    artifact, meta = export_quantized_artifact(qm)
    with pytest.raises(DatapathMismatchError, match="arch"):
        packed_params_from_artifact(artifact, params, cfg,
                                    meta={**meta, "arch": "tiny-ssm"})
    # metadata-free foreign dict: zero sites matched is loud too
    with pytest.raises(DatapathMismatchError, match="no quantized site"):
        packed_params_from_artifact({"bogus/leaf": np.zeros((2, 2))},
                                    params, cfg)


def test_calibrated_tree_tracks_simulated_forward(dense_setup):
    """The packed serving tree built from calibration tracks the simulated
    quantized model (same codes, same static act quantizers; differences
    are only the kernel's integer carrier and bf16 IO)."""
    from repro.quant import quantized_forward

    cfg, params, qm = dense_setup
    sp = serving_params_from_quantized(qm)
    batch = {"tokens": jax.random.randint(jax.random.key(5), (2, 12), 0, 128)}
    ref = quantized_forward(qm, batch)
    with use_packed_backend("interpret"):
        got, _ = T.forward(sp, batch, cfg)
    assert _corr(ref, got) > 0.99


# ---------------------------------------------------------------------------
# Legacy artifacts: upgrade shims (satellite)
# ---------------------------------------------------------------------------
def test_legacy_artifact_upgrade_bit_identical(dense_setup):
    """An unversioned (pre-col_sums, pre-spec) artifact upgraded through
    ensure_col_sums + ensure_datapath_spec decodes bit-identically to a
    natively packed v2 artifact."""
    cfg, params, _ = dense_setup
    v2 = pack_decode_params(params, cfg)

    def strip(node):
        if isinstance(node, dict):
            if "packed" in node:
                return {k: node[k] for k in ("packed", "scale")}
            return {k: strip(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(strip(v) for v in node)
        return node

    legacy = strip(v2)
    upgraded = upgrade_packed_params(legacy)
    leaf = upgraded["layers"][0]["mixer"]["wq"]
    assert set(leaf) >= {"packed", "scale", "col_sums", "spec", "spec_arr"}
    # the reconstructed zero-point term is exact
    np.testing.assert_array_equal(
        np.asarray(leaf["col_sums"]),
        np.asarray(v2["layers"][0]["mixer"]["wq"]["col_sums"]),
    )
    # upgraded legacy leaves record the legacy schema they came from
    assert leaf["spec"].version == 0
    assert leaf["spec"].key() == DatapathSpec().key()

    batch = {"tokens": jax.random.randint(jax.random.key(2), (2, 8), 0, 128)}
    tok = jnp.ones((2, 1), jnp.int32)
    outs = {}
    for name, p in (("v2", v2), ("upgraded", upgraded)):
        with use_packed_backend("interpret"):
            _, cache = T.prefill(p, batch, cfg, max_len=12)
            logits, _ = T.decode_step(p, tok, cache, jnp.int32(8), cfg)
        outs[name] = np.asarray(logits)
    np.testing.assert_array_equal(outs["v2"], outs["upgraded"])


def test_upgrade_cost_is_one_time(dense_setup):
    """The upgrade runs once, outside any trace: re-upgrading a complete
    tree passes every packed-leaf member through by identity, and the
    upgraded tree's decode jaxpr contains no full-weight unpack (the
    per-step fallback the shim exists to avoid)."""
    cfg, params, _ = dense_setup
    v2 = pack_decode_params(params, cfg)
    legacy_leafless = {
        "layers": tuple(
            {kind: {k: ({kk: vv for kk, vv in v.items() if kk in ("packed", "scale")}
                        if isinstance(v, dict) and "packed" in v else v)
                    for k, v in comp.items()}
             for kind, comp in slot.items()}
            for slot in v2["layers"]
        ),
        "embedding": v2["embedding"],
        "final_norm": v2["final_norm"],
    }
    up1 = upgrade_packed_params(legacy_leafless)
    up2 = upgrade_packed_params(up1)
    l1 = up1["layers"][0]["mixer"]["wq"]
    l2 = up2["layers"][0]["mixer"]["wq"]
    for k in l1:
        assert l2[k] is l1[k], f"{k} was rebuilt on a second upgrade"

    # no (K, N)-shaped tensor in the traced decode graph (kernel backend);
    # slice the stacked repeats axis the way the layer scan does
    K = cfg.d_model
    x = jnp.ones((2, K), jnp.float32)
    l1_rep = {k: (v if k == "spec" else v[0]) for k, v in l1.items()}
    with use_packed_backend("interpret"):
        jaxpr = jax.make_jaxpr(lambda a, l: packed_linear(a, l))(x, l1_rep).jaxpr

    kn = [e for e in _all_eqns(jaxpr, [])
          for ov in e.outvars
          if getattr(ov.aval, "shape", None) == (K, l1["packed"].shape[-1])]
    assert not kn, f"full-weight tensors after upgrade: {kn}"


def test_spec_survives_array_only_round_trip(dense_setup):
    """spec_arr is the persistence twin: stripping the static node (as any
    array-only checkpoint round trip does) and re-running the shim restores
    the *identical* static node (numerics-free leaf form, so the treedef —
    and therefore every jit cache key — matches a natively packed leaf)
    while leaving the authoritative spec_arr array untouched."""
    cfg, params, qm = dense_setup
    sp = serving_params_from_quantized(qm)
    leaf = sp["layers"][0]["ffn"]["wd"]
    stripped = {k: v for k, v in leaf.items() if k != "spec"}
    restored = upgrade_packed_params({"x": stripped})["x"]
    assert restored["spec"] == leaf["spec"]  # full equality: leaf_spec form
    assert restored["spec"].act_scale is None  # no calibration floats in aux
    assert restored["spec_arr"] is stripped["spec_arr"]  # not rebuilt
    assert leaf_datapath(stripped).key() == leaf["spec"].key()


# ---------------------------------------------------------------------------
# High-precision fallbacks: wide codes / odd K never corrupt, never drop bias
# ---------------------------------------------------------------------------
def test_pack_leaf_rejects_wide_codes(rng):
    """pack_int4 masks to 4 bits — packing w_bits > 4 must refuse loudly
    instead of silently corrupting the weights."""
    w = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    with pytest.raises(ValueError, match="w_bits <= 4"):
        _pack_leaf(w, DatapathSpec(w_bits=8))


def test_pack_decode_params_w8_falls_back_to_float(dense_setup, rng):
    """An 8-bit datapath request keeps every site as a high-precision
    RTN-dequantized leaf that still tracks the float function."""
    cfg, params, _ = dense_setup
    t8 = pack_decode_params(params, cfg, ptq=PTQConfig(w_bits=8))
    leaf = t8["layers"][0]["mixer"]["wq"]
    assert not isinstance(leaf, dict)  # float leaf, not a packed artifact
    assert leaf.shape == params["layers"][0]["mixer"]["wq"].shape
    batch = {"tokens": jax.random.randint(jax.random.key(2), (2, 8), 0, 128)}
    lf, _ = T.forward(params, batch, cfg)
    with use_packed_backend("interpret"):
        l8, _ = T.forward(t8, batch, cfg)
    assert _corr(lf, l8) > 0.99  # int8 RTN: near-float, never garbage


def test_fallback_site_leaf_keeps_corrected_bias(dense_setup):
    """Sites without an int4 container (w_bits > 4) serve as {"w", "bias"}
    leaves: the bias-corrected function calibration certified, not a
    silently bias-stripped one."""
    from repro.models.layers import pmm

    cfg, params, _ = dense_setup
    batches = [{"tokens": jax.random.randint(jax.random.key(1), (2, 16), 0, 128)}]
    qm8 = calibrate_and_quantize(params, cfg, batches,
                                 PTQConfig(algorithm="rtn", w_bits=8))
    sp8 = serving_params_from_quantized(qm8)
    wd = sp8["layers"][0]["ffn"]["wd"]  # use_bias site
    assert isinstance(wd, dict) and set(wd) == {"w", "bias"}
    # pmm applies the bias on the fallback leaf
    rep0 = {k: v[0] for k, v in wd.items()}
    x = jnp.ones((1, rep0["w"].shape[0]), jnp.float32)
    y = pmm({"wd": rep0}, "wd", x)
    np.testing.assert_allclose(
        np.asarray(y),
        np.asarray(x @ rep0["w"] + rep0["bias"].reshape(-1)),
        rtol=1e-6,
    )
    # end to end the served tree runs and stays near-float (int8 RTN
    # weights + corrected bias; activations unquantized on this fallback,
    # so the simulated fake-quant model is not the bit reference here)
    batch = {"tokens": jax.random.randint(jax.random.key(5), (2, 12), 0, 128)}
    lf, _ = T.forward(params, batch, cfg)
    with use_packed_backend("interpret"):
        got, _ = T.forward(sp8, batch, cfg)
    assert _corr(lf, got) > 0.99


# ---------------------------------------------------------------------------
# Static activation quantizers: serving jaxpr hygiene (satellite)
# ---------------------------------------------------------------------------
def _all_eqns(jaxpr, out):
    for eqn in jaxpr.eqns:
        out.append(eqn)
        for v in eqn.params.values():
            vals = v if isinstance(v, (list, tuple)) else [v]
            for x in vals:
                inner = getattr(x, "jaxpr", x)
                if hasattr(inner, "eqns"):
                    _all_eqns(inner, out)
    return out


def _decode_reduce_min_count(params, cfg) -> int:
    """Dynamic per-tensor activation quantization is the only reduce_min
    producer in the decode graph (softmax uses reduce_max only), so its
    count detects dynamic-vs-static activation quantization."""
    tok = jnp.ones((2, 1), jnp.int32)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab)}
    with use_packed_backend("interpret"):
        _, cache = T.prefill(params, batch, cfg, max_len=12)
        jaxpr = jax.make_jaxpr(
            lambda p, t, c: T.decode_step(p, t, c, jnp.int32(8), cfg)
        )(params, tok, cache).jaxpr
    return sum(1 for e in _all_eqns(jaxpr, [])
               if e.primitive.name == "reduce_min")


@pytest.mark.parametrize("arch", ["dense", "tiny-ssm"])
def test_static_act_serving_jaxpr_has_no_dynamic_quant(arch, dense_setup):
    if arch == "dense":
        cfg, params, qm = dense_setup
    else:
        cfg = get_config(arch)
        params = T.init_model(jax.random.key(0), cfg)
        batches = [
            {"tokens": jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)}
        ]
        qm = calibrate_and_quantize(params, cfg, batches,
                                    PTQConfig(algorithm="rtn"))
    static_tree = serving_params_from_quantized(qm)
    dynamic_tree = pack_decode_params(params, cfg)
    # detector sanity: the dynamic artifact DOES quantize in-graph
    assert _decode_reduce_min_count(dynamic_tree, cfg) > 0
    # the calibrated artifact serves on its static act quantizers alone
    assert _decode_reduce_min_count(static_tree, cfg) == 0


# ---------------------------------------------------------------------------
# Engine: datapath fingerprint is a retrace key
# ---------------------------------------------------------------------------
def test_engine_datapath_fingerprint_differs_per_datapath(dense_setup):
    cfg, params, qm = dense_setup
    t128 = pack_decode_params(params, cfg)
    t64 = pack_decode_params(params, cfg, ptq=PTQConfig(tile=64, p_bits=12))
    assert (tree_datapath_fingerprint(t128)
            != tree_datapath_fingerprint(t64))
    static_tree = serving_params_from_quantized(qm)
    assert (tree_datapath_fingerprint(static_tree)
            != tree_datapath_fingerprint(t128))


def test_engine_generates_on_calibrated_static_artifact(dense_setup):
    from repro.serving import GenerationEngine, SamplerConfig

    cfg, params, qm = dense_setup
    sp = serving_params_from_quantized(qm)
    eng = GenerationEngine(sp, cfg, SamplerConfig(temperature=0.0))
    prompts = np.random.default_rng(0).integers(0, 128, size=(2, 6)).astype(np.int32)
    with use_packed_backend("interpret"):
        out = eng.generate(prompts, 4)
        eng.generate(prompts, 4)
    assert out.shape == (2, 10)
    assert eng.gen_traces == 1  # fingerprint stable: no spurious retrace
    np.testing.assert_array_equal(out[:, :6], prompts)
