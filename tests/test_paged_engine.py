"""PagedEngine (paged KV + continuous batching) vs the dense-slab
GenerationEngine: golden bit-identity, mid-flight admission, page
exhaustion stalls, exact-block-boundary sequences, free-list reuse after
early EOS, trace/bucket accounting — plus the int8 quantized-KV golden
accuracy battery (briefly *trained* tiny models, whose greedy gaps dwarf
the int8 page-quantization noise, so token-for-token equality is a
structural property rather than seed luck) and the randomized device
free-list property sweep."""

import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke
from repro.models.transformer import init_model
from repro.serving import (
    GenerationEngine,
    PagedConfig,
    PagedEngine,
    Request,
    SamplerConfig,
)

GREEDY = SamplerConfig(temperature=0.0)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke("smollm-360m").scaled(n_layers=2, vocab=128)
    params = init_model(jax.random.key(0), cfg)
    prompts = np.random.default_rng(0).integers(0, 128, size=(3, 8)).astype(np.int32)
    return cfg, params, prompts


def _train_briefly(cfg, steps=250, lr=2e-3):
    from repro.data import DataConfig, TokenBatcher
    from repro.optim import OptimizerConfig
    from repro.runtime.steps import TrainRunConfig, init_train_state, make_train_step

    run = TrainRunConfig(optimizer=OptimizerConfig(
        lr=lr, warmup_steps=10, total_steps=steps))
    state = init_train_state(jax.random.key(0), cfg, run)
    step = jax.jit(make_train_step(cfg, run), donate_argnums=(0,))
    data = TokenBatcher(DataConfig(vocab=cfg.vocab, seq_len=32,
                                   global_batch=8, seed=7))
    for i in range(steps):
        state, _ = step(state, jax.tree.map(jnp.asarray, data.batch(i)))
    return state["params"]


@pytest.fixture(scope="module")
def trained_dense():
    cfg = get_smoke("smollm-360m").scaled(n_layers=2, vocab=128)
    return cfg, _train_briefly(cfg)


@pytest.fixture(scope="module")
def trained_hybrid():
    cfg = get_config("tiny-hybrid")
    return cfg, _train_briefly(cfg)


def _paged(cfg, params, sampler=GREEDY, attn_datapath=None, **kw):
    pc = dict(block_size=8, num_blocks=16, max_concurrency=3,
              max_pages_per_seq=4, chunk_max=4, attn_impl="ref")
    pc.update(kw)
    return PagedEngine(params, cfg, PagedConfig(**pc), sampler,
                       attn_datapath=attn_datapath)


def test_golden_equal_length_batch_bit_identical(setup):
    """Acceptance golden: an equal-length greedy batch through the paged
    engine is bit-identical to the dense-slab engine."""
    cfg, params, prompts = setup
    dense = GenerationEngine(params, cfg, GREEDY)
    ref = dense.generate(prompts, 8)
    out = _paged(cfg, params).generate(prompts, 8)
    np.testing.assert_array_equal(out, ref)


def test_mid_flight_admission_bit_identical(setup):
    """Requests admitted into freed slots mid-flight produce the same
    tokens as running each prompt alone in a fresh fixed-slot engine —
    continuous batching must not leak state across co-batched traffic."""
    cfg, params, _ = setup
    rng = np.random.default_rng(1)
    reqs = [
        Request(uid=0, prompt=rng.integers(0, 128, size=8).astype(np.int32), max_new=8),
        Request(uid=1, prompt=rng.integers(0, 128, size=4).astype(np.int32), max_new=12),
        Request(uid=2, prompt=rng.integers(0, 128, size=12).astype(np.int32), max_new=4),
    ]
    # 2 slots for 3 requests: uid 2 is admitted only when a slot frees
    eng = _paged(cfg, params, max_concurrency=2, num_blocks=8,
                 max_pages_per_seq=2, chunk_max=3)
    res = eng.serve(reqs)
    for r in reqs:
        dense = GenerationEngine(params, cfg, GREEDY)
        want = dense.generate(r.prompt[None], r.max_new)[0]
        np.testing.assert_array_equal(res[r.uid], want)


def test_exhaustion_stalls_then_completes(setup):
    """Pool smaller than the workload: admission stalls (queue waits)
    instead of corrupting live sequences, and every request still
    finishes with the right tokens."""
    cfg, params, _ = setup
    rng = np.random.default_rng(2)
    reqs = [Request(uid=u, prompt=rng.integers(0, 128, size=8).astype(np.int32),
                    max_new=9) for u in range(3)]
    # each request needs 2 pages; the pool holds 3 -> one request in
    # flight at a time despite 3 free slots
    eng = _paged(cfg, params, max_concurrency=3, num_blocks=3,
                 max_pages_per_seq=2, chunk_max=4)
    res = eng.serve(reqs)
    assert int(jax.device_get(eng.cache["free_top"])) == 0  # all pages back
    for r in reqs:
        dense = GenerationEngine(params, cfg, GREEDY)
        want = dense.generate(r.prompt[None], r.max_new)[0]
        np.testing.assert_array_equal(res[r.uid], want)


@pytest.mark.parametrize("max_new", [8, 9, 10])
def test_sequence_filling_last_block_exactly(setup, max_new):
    """S0=8, block_size=8: max_new=9 writes exactly 16 positions (last
    block exactly full); 8 and 10 bracket the boundary."""
    cfg, params, prompts = setup
    dense = GenerationEngine(params, cfg, GREEDY)
    ref = dense.generate(prompts[:1], max_new)
    out = _paged(cfg, params, max_concurrency=1).generate(prompts[:1], max_new)
    np.testing.assert_array_equal(out, ref)


def test_free_list_reuse_after_early_eos(setup):
    """A sequence hitting EOS early frees its pages; the next queued
    request reuses them (stale page contents must be invisible)."""
    cfg, params, prompts = setup
    # pick an eos the greedy rollout actually emits mid-sequence
    probe = GenerationEngine(params, cfg, GREEDY).generate(prompts, 8)
    eos = int(probe[0, prompts.shape[1] + 2])
    samp = SamplerConfig(temperature=0.0, eos_id=eos)
    rng = np.random.default_rng(3)
    reqs = [Request(uid=0, prompt=prompts[0], max_new=8),
            Request(uid=1, prompt=rng.integers(0, 128, size=8).astype(np.int32),
                    max_new=8)]
    # one slot, pool sized for exactly one request: uid 1 runs entirely on
    # pages recycled from uid 0
    eng = _paged(cfg, params, sampler=samp, max_concurrency=1, num_blocks=2,
                 max_pages_per_seq=2)
    res = eng.serve(reqs)
    assert res[0][-1] == eos and res[0].size <= prompts.shape[1] + 8
    for r in reqs:
        dense = GenerationEngine(params, cfg, samp)
        want = dense.generate(r.prompt[None], r.max_new)[0]
        # paged output is trimmed at eos; dense pads post-eos with eos
        np.testing.assert_array_equal(res[r.uid], want[: res[r.uid].size])
    assert int(jax.device_get(eng.cache["free_top"])) == 0


def test_one_trace_per_bucket(setup):
    """Admissions retrace per (prompt_len, n_pages) bucket only; every
    chunk length shares one trace (dynamic trip count)."""
    cfg, params, prompts = setup
    eng = _paged(cfg, params)
    eng.generate(prompts, 4)
    assert (eng.admit_traces, eng.chunk_traces) == (1, 1)
    eng.generate(prompts, 6)  # same S0, same page need -> same buckets
    assert (eng.admit_traces, eng.chunk_traces) == (1, 1)
    eng.generate(prompts[:, :4], 4)  # new prompt bucket
    assert eng.admit_traces == 2
    assert eng.chunk_traces == 1


def test_kernel_impl_matches_ref_impl(setup):
    """The Pallas block-table kernel (interpret mode) drives the engine to
    the same greedy tokens as the gather reference."""
    cfg, params, prompts = setup
    ref = _paged(cfg, params, attn_impl="ref").generate(prompts, 8)
    ker = _paged(cfg, params, attn_impl="interpret").generate(prompts, 8)
    np.testing.assert_array_equal(ker, ref)


def test_sampled_request_determinism(setup):
    """Sampled decode keys fold (uid, step): a request's tokens do not
    depend on co-batched traffic — alone vs batched gives the same
    rollout."""
    cfg, params, prompts = setup
    samp = SamplerConfig(temperature=1.0, seed=7)
    alone = _paged(cfg, params, sampler=samp, max_concurrency=1).serve(
        [Request(uid=5, prompt=prompts[0], max_new=8)])
    batched = _paged(cfg, params, sampler=samp).serve(
        [Request(uid=5, prompt=prompts[0], max_new=8),
         Request(uid=9, prompt=prompts[1], max_new=6),
         Request(uid=11, prompt=prompts[2], max_new=3)])
    np.testing.assert_array_equal(alone[5], batched[5])


# ---------------------------------------------------------------------------
# int8 quantized KV pages: golden accuracy + datapath validation
# ---------------------------------------------------------------------------
def test_int8_kv_golden_greedy_matches_float_dense(trained_dense):
    """Acceptance golden: greedy decode over int8 quantized pages matches
    float-KV decode token-for-token (dense attention-only config), for
    both gather-reference and interpret-mode kernel implementations."""
    cfg, params = trained_dense
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, size=(3, 8)).astype(np.int32)
    ref = _paged(cfg, params).generate(prompts, 8)
    np.testing.assert_array_equal(
        ref, GenerationEngine(params, cfg, GREEDY).generate(prompts, 8))
    q8 = _paged(cfg, params, kv_dtype="int8").generate(prompts, 8)
    np.testing.assert_array_equal(q8, ref)
    q8k = _paged(cfg, params, kv_dtype="int8",
                 attn_impl="interpret").generate(prompts, 8)
    np.testing.assert_array_equal(q8k, ref)


def test_int8_kv_golden_greedy_matches_float_hybrid(trained_hybrid):
    """Same golden on the hybrid attn+mamba pattern: quantized attention
    pages coexist with dense recurrent per-slot state, including a
    mid-flight admission into recycled pages."""
    cfg, params = trained_hybrid
    rng = np.random.default_rng(1)
    reqs = [Request(uid=u, prompt=rng.integers(0, cfg.vocab, size=8).astype(np.int32),
                    max_new=[8, 12, 4][u]) for u in range(3)]
    # 2 slots for 3 requests: uid 2 admits into pages freed mid-flight
    kw = dict(max_concurrency=2, num_blocks=8, max_pages_per_seq=3,
              chunk_max=3)
    res_f = _paged(cfg, params, **kw).serve(reqs)
    res_q = _paged(cfg, params, kv_dtype="int8", **kw).serve(reqs)
    for r in reqs:
        np.testing.assert_array_equal(res_q[r.uid], res_f[r.uid])


def test_int8_kv_attn_datapath_validation(setup):
    """The engine's attention accumulator record is validated like the
    weight-site datapath: a matching request passes, a disagreeing one (or
    a float-KV cache given any request) raises DatapathMismatchError."""
    from repro.quant.spec import AttnDatapathSpec, DatapathMismatchError

    cfg, params, _ = setup
    spec = AttnDatapathSpec.for_cache(cfg.head_dim, 8)
    eng = _paged(cfg, params, kv_dtype="int8", attn_datapath=spec)
    assert eng.attn_spec.matches(spec) and eng.attn_spec.certify()
    with pytest.raises(DatapathMismatchError, match="attention datapath"):
        _paged(cfg, params, kv_dtype="int8",
               attn_datapath=AttnDatapathSpec.for_cache(cfg.head_dim, 16))
    with pytest.raises(DatapathMismatchError, match="float KV"):
        _paged(cfg, params, attn_datapath=spec)
    with pytest.raises(ValueError, match="kv_dtype"):
        _paged(cfg, params, kv_dtype="fp8")


def _paged_kw(eng):
    from repro.models.layers import packed_backend, resolve_paged_attn_impl

    return dict(backend=packed_backend(),
                attn_impl=resolve_paged_attn_impl(eng.paged.attn_impl))


def _assert_pool_invariants(eng, sched):
    """Device free-list stack vs host accounting, *counted with
    refcounts*: after every admit/append/release/evict transition, the
    free stack (``free_list[top:]``) and the live block-table pages plus
    cache-held pages partition the pool — ``rc[p]`` equals the number of
    live rows containing ``p`` plus one if the prefix cache holds it, the
    free stack is exactly ``{p : rc[p] == 0}`` with no duplicates (no
    double-free, no leak), and device state mirrors the host pool state
    bit for bit."""
    state = jax.device_get({k: eng.cache[k] for k in
                            ("free_list", "free_top", "block_table",
                             "page_refcounts")})
    nb = eng.paged.num_blocks
    top = int(state["free_top"])
    expect_rc = np.zeros(nb, np.int64)
    for slot, a in sched.active.items():
        row = state["block_table"][slot][:a.n_pages]
        assert ((0 <= row) & (row < nb)).all()  # live tables: real pages
        assert len(set(row.tolist())) == row.size  # row never repeats a page
        np.testing.assert_array_equal(np.sort(row), np.sort(a.row))
        np.add.at(expect_rc, row, 1)
    if eng.prefix_cache is not None:
        for node in eng.prefix_cache.nodes.values():
            expect_rc[node.page] += 1
    # refcount conservation: device rc == host mirror rc == recount
    np.testing.assert_array_equal(state["page_refcounts"], expect_rc)
    np.testing.assert_array_equal(state["page_refcounts"],
                                  eng.pool_state.page_rc)
    # free stack == the rc-zero pages, exactly once each
    free = state["free_list"][top:].tolist()
    assert len(set(free)) == len(free)
    assert set(free) == set(np.flatnonzero(expect_rc == 0).tolist())
    assert top == int((expect_rc > 0).sum())
    # host mirror lockstep (the scheduler hands *physical* pages around)
    assert eng.pool_state.free_top == top
    np.testing.assert_array_equal(state["free_list"], eng.pool_state.free_list)
    assert sched.free_pages == nb - top


def _serve_checked(eng, reqs, late_reqs=()):
    """``PagedEngine.serve`` with pool invariants asserted after every
    transition (``_probe``) and mid-flight arrivals injected after decode
    chunks (``_late``)."""
    late = list(late_reqs)

    def probe(engine, sched):
        _assert_pool_invariants(engine, sched)

    def inject(sched, chunk_idx):
        if late:
            sched.submit(late.pop())

    return eng.serve(reqs, _probe=probe, _late=inject)


@pytest.mark.parametrize("seed,kv_dtype", [(0, "act"), (1, "int8"),
                                           (2, "act")])
def test_randomized_trace_free_list_property(setup, seed, kv_dtype):
    """Randomized arrival/length traces through the *real* engine: the
    device free-list stack and the per-slot block tables conserve the
    pool, no page is ever double-allocated, exhaustion only stalls
    admission, and every request completes at its exact length. (The
    pure-host scheduler property sweep lives in test_scheduler.py; seeded
    ``random`` is the hypothesis fallback per the conftest convention.)"""
    cfg, params, _ = setup
    r = random.Random(seed)
    eng = _paged(cfg, params, max_concurrency=2, num_blocks=4,
                 max_pages_per_seq=3, chunk_max=3, kv_dtype=kv_dtype)
    # lengths around page boundaries; the tiny pool forces stalls + reuse
    reqs, late = [], []
    for uid in range(5):
        req = Request(
            uid=uid,
            prompt=np.asarray(r.choices(range(cfg.vocab),
                                        k=r.choice([3, 8, 9])), np.int32),
            max_new=r.choice([1, 4, 8]))
        (late if uid >= 3 else reqs).append(req)
    results = _serve_checked(eng, reqs, late)
    assert int(jax.device_get(eng.cache["free_top"])) == 0  # all pages back
    assert eng.release_traces == 1  # dynamic count: one trace, any n_pages
    for req in reqs + late:
        assert results[req.uid].size == req.prompt.size + req.max_new


# ---------------------------------------------------------------------------
# Prefix cache: shared prompt blocks, CoW tails, refcounted release
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kv_dtype", ["act", "int8"])
def test_prefix_cache_greedy_identity_warm_vs_cold(trained_dense, kv_dtype):
    """Acceptance golden: a shared-system-prompt mix served through the
    prefix cache is token-for-token identical to the cold engine — float
    and int8 KV alike, covering all three admit variants (cold insert,
    shared-prefix suffix prefill, fully cached with a CoW tail) plus a
    second serve on the persistent warm engine whose popped tail pages are
    recycled from the first."""
    cfg, params = trained_dense
    rng = np.random.default_rng(5)
    system = rng.integers(0, cfg.vocab, size=16).astype(np.int32)  # 2 blocks
    reqs = [
        Request(uid=0, max_new=6,
                prompt=np.concatenate(
                    [system, rng.integers(0, cfg.vocab, size=5)]
                ).astype(np.int32)),
        Request(uid=1, prompt=system.copy(), max_new=6),  # fully cached
        Request(uid=2, max_new=6,
                prompt=np.concatenate(
                    [system, rng.integers(0, cfg.vocab, size=3)]
                ).astype(np.int32)),
        Request(uid=3, max_new=6,  # unrelated: stays a cold admission
                prompt=rng.integers(0, cfg.vocab, size=12).astype(np.int32)),
    ]
    cold = _paged(cfg, params, kv_dtype=kv_dtype).serve(reqs)
    eng = _paged(cfg, params, kv_dtype=kv_dtype, prefix_cache=True)
    warm = _serve_checked(eng, reqs)
    for r in reqs:
        np.testing.assert_array_equal(warm[r.uid], cold[r.uid])
    # the mix exercised every admit variant and actually hit the cache
    assert eng.admit_traces >= 1 and eng.suffix_traces >= 1
    assert eng.cached_traces == 1
    stats = eng.prefix_cache.stats()
    assert stats["hits"] > 0 and 0 < stats["hit_rate"] <= 1
    # second serve on the warm engine: the cache persists across serve()
    # calls and the fresh pops land on recycled pages
    warm2 = _serve_checked(eng, reqs)
    for r in reqs:
        np.testing.assert_array_equal(warm2[r.uid], cold[r.uid])


@pytest.mark.parametrize("kv_dtype", ["act", "int8"])
def test_prefix_cache_eviction_recycles_shared_pages(trained_dense, kv_dtype):
    """LRU eviction under pool pressure: three distinct system prompts
    compete for a 6-page pool, so admissions must evict cold cache entries
    and land on recycled *previously-shared* pages — including mid-flight
    arrivals injected after decode chunks. Outputs stay identical to the
    cold engine, and one release trace serves finishes and evictions
    alike."""
    cfg, params = trained_dense
    rng = np.random.default_rng(6)
    systems = [rng.integers(0, cfg.vocab, size=16).astype(np.int32)
               for _ in range(3)]
    reqs = []
    for uid in range(7):
        tail = rng.integers(0, cfg.vocab,
                            size=int(rng.integers(0, 4))).astype(np.int32)
        reqs.append(Request(
            uid=uid, prompt=np.concatenate([systems[uid % 3], tail]),
            max_new=int(rng.integers(1, 6))))
    kw = dict(num_blocks=6, max_concurrency=2, max_pages_per_seq=3,
              chunk_max=3, kv_dtype=kv_dtype)
    cold = _paged(cfg, params, **kw).serve(reqs)
    eng = _paged(cfg, params, prefix_cache=True, **kw)
    evictions = []
    orig_evict = eng.prefix_cache.evict
    eng.prefix_cache.evict = lambda plan: (evictions.append(len(plan)),
                                           orig_evict(plan))[1]
    warm = _serve_checked(eng, reqs[:4], late_reqs=reqs[4:])
    for r in reqs:
        np.testing.assert_array_equal(warm[r.uid], cold[r.uid])
    assert evictions, "pool pressure must actually evict cache entries"
    assert eng.release_traces == 1  # finishes + evictions share one trace


@pytest.mark.parametrize("seed,kv_dtype", [(3, "act"), (4, "int8")])
def test_prefix_cache_randomized_churn_property(setup, seed, kv_dtype):
    """Randomized shared-prefix traffic through the real engine with the
    cache on: the refcounted pool partition (free stack + live rows +
    cached pages, counted with multiplicity) holds after every transition,
    host and device stay in bit-for-bit lockstep, and at quiescence the
    cache is the pool's only page holder."""
    cfg, params, _ = setup
    r = random.Random(seed)
    eng = _paged(cfg, params, prefix_cache=True, kv_dtype=kv_dtype,
                 num_blocks=6, max_concurrency=2, max_pages_per_seq=3,
                 chunk_max=3)
    blocks = [np.asarray(r.choices(range(cfg.vocab), k=8), np.int32)
              for _ in range(3)]
    reqs = []
    for uid in range(6):
        body = np.concatenate(
            [blocks[i] for i in r.choices(range(3), k=r.choice([1, 2]))])
        tail = np.asarray(r.choices(range(cfg.vocab), k=r.choice([0, 3])),
                          np.int32)
        reqs.append(Request(uid=uid, prompt=np.concatenate([body, tail]),
                            max_new=r.choice([1, 4])))
    results = _serve_checked(eng, reqs[:3], late_reqs=reqs[3:])
    for req in reqs:
        assert results[req.uid].size == req.prompt.size + req.max_new
    assert (int(jax.device_get(eng.cache["free_top"]))
            == eng.prefix_cache.pages_held)


@pytest.mark.parametrize("kv_dtype", ["act", "int8"])
def test_fully_cached_admit_is_structurally_flop_free(setup, kv_dtype):
    """Acceptance: admitting a fully cached prompt runs ZERO prefill FLOPs
    — the cached-admit program takes no model params and its jaxpr holds
    no dot_general/conv primitive (recursively), for float and int8 pools;
    and the serving path actually routes a repeated block-aligned prompt
    through that program."""
    cfg, params, _ = setup
    eng = _paged(cfg, params, prefix_cache=True, kv_dtype=kv_dtype)
    prims = eng.cached_admit_primitives()
    assert prims  # non-trivial program: gathers/scatters at least
    assert not (prims & eng._FLOP_PRIMITIVES)
    eng.assert_cached_admit_flop_free()
    prompt = np.random.default_rng(8).integers(
        0, cfg.vocab, size=8).astype(np.int32)
    eng.serve([Request(uid=0, prompt=prompt, max_new=4)])
    assert eng.cached_traces == 0
    eng.serve([Request(uid=1, prompt=prompt.copy(), max_new=4)])
    assert eng.cached_traces == 1 and eng.suffix_traces == 0


def test_duplicate_inflight_uid_rejected(setup):
    """Two in-flight requests with one uid would silently clobber each
    other in the results dict — submit fails loudly instead. A finished
    uid is reusable in a later serve."""
    cfg, params, _ = setup
    eng = _paged(cfg, params)
    prompt = np.arange(8, dtype=np.int32)
    with pytest.raises(ValueError, match="already in flight"):
        eng.serve([Request(uid=7, prompt=prompt, max_new=2),
                   Request(uid=7, prompt=prompt, max_new=2)])
    eng.serve([Request(uid=7, prompt=prompt, max_new=2)])
    out = eng.serve([Request(uid=7, prompt=prompt, max_new=2)])
    assert out[7].size == 10


def test_prefix_cache_requires_attention_only_pattern():
    """Recurrent mixers keep dense per-slot state that cannot be shared —
    the engine refuses prefix_cache=True for hybrid patterns at init."""
    cfg = get_config("tiny-hybrid")
    params = init_model(jax.random.key(0), cfg)
    with pytest.raises(ValueError, match="attention-only"):
        _paged(cfg, params, prefix_cache=True)


def test_hybrid_family_paged_decode():
    """Attention pages + recurrent (Mamba) per-slot state swap coexist in
    one paged cache (hybrid pattern). The oracle is per-request fixed-slot
    rollouts: tiny-hybrid carries capacity-bounded MoE blocks, where a
    *batched* prefill lets co-batched rows compete for expert capacity —
    the paged engine's per-request prefill is the serving-correct
    semantics (see docs/serving_scheduler.md)."""
    cfg = get_config("tiny-hybrid")
    params = init_model(jax.random.key(0), cfg)
    prompts = np.random.default_rng(4).integers(0, cfg.vocab, size=(2, 8)).astype(np.int32)
    out = _paged(cfg, params, max_concurrency=2, num_blocks=8,
                 max_pages_per_seq=2).generate(prompts, 8)
    for b in range(2):
        want = GenerationEngine(params, cfg, GREEDY).generate(prompts[b:b + 1], 8)
        np.testing.assert_array_equal(out[b], want[0])


# ---------------------------------------------------------------------------
# Throughput policy: batched admission, chunked prefill, preempt-and-requeue
# ---------------------------------------------------------------------------
from repro.serving import SchedulerPolicy  # noqa: E402

THROUGHPUT = SchedulerPolicy(admit_window=4, batch_max=3, prefill_chunk=16)


def test_throughput_policy_greedy_bit_identical_to_fifo(setup):
    """The tentpole identity: the throughput serve loop — windowed batched
    admission, FLOP-free stub admits, chunked prefill interleaved with
    decode — produces byte-identical token streams to the legacy FIFO
    loop for every request, while actually exercising the batched and
    chunked device programs (trace counters prove the paths ran)."""
    cfg, params, _ = setup
    rng = np.random.default_rng(7)
    reqs = [Request(uid=u, prompt=rng.integers(0, 128, size=s0).astype(np.int32),
                    max_new=mn, priority=p)
            for u, (s0, mn, p) in enumerate(
                [(8, 6, 0), (8, 4, 1), (24, 6, 0), (8, 5, 0), (16, 4, 1),
                 (8, 3, 0)])]
    fifo = _paged(cfg, params, max_concurrency=4, num_blocks=24,
                  max_pages_per_seq=4)
    want = fifo.serve([Request(**r.__dict__) for r in reqs])
    thr = _paged(cfg, params, max_concurrency=4, num_blocks=24,
                 max_pages_per_seq=4, sched=THROUGHPUT)
    got = _serve_checked(thr, [Request(**r.__dict__) for r in reqs])
    assert set(got) == set(want)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid])
    assert thr.batch_traces >= 1          # padded multi-row prefill ran
    assert thr.stub_traces >= 1           # FLOP-free chunked stub ran
    assert thr.prefill_chunk_traces >= 1  # page-aligned chunks ran
    assert int(jax.device_get(thr.cache["free_top"])) == 0


@pytest.mark.parametrize("kv_dtype", ["act", "int8"])
def test_throughput_preemption_bit_identical(setup, kv_dtype):
    """Watermark admission over-commits the pool; decode growth then
    preempts the lowest-priority youngest victim, releases its pages, and
    requeues it — and the restarted request's final tokens are still
    bit-identical to an uninterrupted FIFO run (the per-request
    ``fold_in(uid, step)`` sampling stream replays from step 0), float
    and int8 KV pages alike."""
    cfg, params, _ = setup
    rng = np.random.default_rng(9)
    reqs = [Request(uid=u, prompt=rng.integers(0, 128, size=8).astype(np.int32),
                    max_new=24, priority=p)
            for u, p in enumerate([0, 1, 1])]
    fifo = _paged(cfg, params, max_concurrency=3, num_blocks=16,
                  max_pages_per_seq=4, kv_dtype=kv_dtype)
    want = fifo.serve([Request(**r.__dict__) for r in reqs])
    thr = _paged(cfg, params, max_concurrency=3, num_blocks=6,
                 max_pages_per_seq=4, kv_dtype=kv_dtype,
                 sched=SchedulerPolicy(admit_window=2, watermark=(1, 4)))
    got = _serve_checked(thr, [Request(**r.__dict__) for r in reqs])
    assert thr.preemptions >= 1, "pool pressure never forced a preemption"
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid])
    assert int(jax.device_get(thr.cache["free_top"])) == 0


def test_throughput_prefix_cache_identity(trained_dense):
    """Prefix-cache admits (suffix prefill, CoW fully-cached) coexist
    with batched and chunked admission in one trace: cache-hit requests
    keep their specialized n=1 programs, cold ones batch, the long cold
    prompt chunks with its cache insert deferred to the final chunk — and
    everything stays bit-identical to the cold FIFO engine."""
    cfg, params = trained_dense
    rng = np.random.default_rng(11)
    sys_prompt = rng.integers(0, 128, size=16).astype(np.int32)
    mk = lambda uid, tail, mn, p=0: Request(
        uid=uid, prompt=np.concatenate([sys_prompt,
                                        rng.integers(0, 128, size=tail
                                                     ).astype(np.int32)])
        if tail else sys_prompt.copy(), max_new=mn, priority=p)
    reqs = [mk(0, 8, 6), mk(1, 8, 4), mk(2, 0, 4),
            Request(uid=3, prompt=rng.integers(0, 128, size=24
                                               ).astype(np.int32), max_new=5),
            mk(4, 4, 4, p=1)]
    fifo = _paged(cfg, params, max_concurrency=3, num_blocks=24,
                  max_pages_per_seq=4)
    want = fifo.serve([Request(**r.__dict__) for r in reqs])
    thr = _paged(cfg, params, max_concurrency=3, num_blocks=24,
                 max_pages_per_seq=4, prefix_cache=True, sched=THROUGHPUT)
    got = _serve_checked(thr, [Request(**r.__dict__) for r in reqs])
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid])
    assert thr.suffix_traces + thr.cached_traces >= 1  # cache paths ran
    assert thr.prefill_chunk_traces >= 1


def test_throughput_policy_pattern_gates():
    """Batched/chunked admission requires an attention-only, MoE-free
    pattern (routing and stepwise state break bit-identity); chunk sizes
    must scatter whole pages; the watermark must be reachable."""
    from repro.configs import get_smoke

    moe_cfg = get_smoke("granite-moe-3b-a800m").scaled(vocab=128)
    moe_params = init_model(jax.random.key(0), moe_cfg)
    with pytest.raises(ValueError, match="MoE-free"):
        _paged(moe_cfg, moe_params, sched=SchedulerPolicy(batch_max=2))
    hyb_cfg = get_config("tiny-hybrid")
    hyb_params = init_model(jax.random.key(0), hyb_cfg)
    with pytest.raises(ValueError, match="attention-only"):
        _paged(hyb_cfg, hyb_params,
               sched=SchedulerPolicy(prefill_chunk=8))
    cfg = get_smoke("smollm-360m").scaled(n_layers=2, vocab=128)
    params = init_model(jax.random.key(0), cfg)
    with pytest.raises(ValueError, match="multiple of"):
        _paged(cfg, params, sched=SchedulerPolicy(prefill_chunk=12))
    with pytest.raises(ValueError, match="admission could never resume"):
        _paged(cfg, params, num_blocks=4,
               sched=SchedulerPolicy(watermark=(1, 8)))
