"""PagedEngine (paged KV + continuous batching) vs the dense-slab
GenerationEngine: golden bit-identity, mid-flight admission, page
exhaustion stalls, exact-block-boundary sequences, free-list reuse after
early EOS, and trace/bucket accounting."""

import jax
import numpy as np
import pytest

from repro.configs import get_config, get_smoke
from repro.models.transformer import init_model
from repro.serving import (
    GenerationEngine,
    PagedConfig,
    PagedEngine,
    Request,
    SamplerConfig,
)

GREEDY = SamplerConfig(temperature=0.0)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke("smollm-360m").scaled(n_layers=2, vocab=128)
    params = init_model(jax.random.key(0), cfg)
    prompts = np.random.default_rng(0).integers(0, 128, size=(3, 8)).astype(np.int32)
    return cfg, params, prompts


def _paged(cfg, params, sampler=GREEDY, **kw):
    pc = dict(block_size=8, num_blocks=16, max_concurrency=3,
              max_pages_per_seq=4, chunk_max=4, attn_impl="ref")
    pc.update(kw)
    return PagedEngine(params, cfg, PagedConfig(**pc), sampler)


def test_golden_equal_length_batch_bit_identical(setup):
    """Acceptance golden: an equal-length greedy batch through the paged
    engine is bit-identical to the dense-slab engine."""
    cfg, params, prompts = setup
    dense = GenerationEngine(params, cfg, GREEDY)
    ref = dense.generate(prompts, 8)
    out = _paged(cfg, params).generate(prompts, 8)
    np.testing.assert_array_equal(out, ref)


def test_mid_flight_admission_bit_identical(setup):
    """Requests admitted into freed slots mid-flight produce the same
    tokens as running each prompt alone in a fresh fixed-slot engine —
    continuous batching must not leak state across co-batched traffic."""
    cfg, params, _ = setup
    rng = np.random.default_rng(1)
    reqs = [
        Request(uid=0, prompt=rng.integers(0, 128, size=8).astype(np.int32), max_new=8),
        Request(uid=1, prompt=rng.integers(0, 128, size=4).astype(np.int32), max_new=12),
        Request(uid=2, prompt=rng.integers(0, 128, size=12).astype(np.int32), max_new=4),
    ]
    # 2 slots for 3 requests: uid 2 is admitted only when a slot frees
    eng = _paged(cfg, params, max_concurrency=2, num_blocks=8,
                 max_pages_per_seq=2, chunk_max=3)
    res = eng.serve(reqs)
    for r in reqs:
        dense = GenerationEngine(params, cfg, GREEDY)
        want = dense.generate(r.prompt[None], r.max_new)[0]
        np.testing.assert_array_equal(res[r.uid], want)


def test_exhaustion_stalls_then_completes(setup):
    """Pool smaller than the workload: admission stalls (queue waits)
    instead of corrupting live sequences, and every request still
    finishes with the right tokens."""
    cfg, params, _ = setup
    rng = np.random.default_rng(2)
    reqs = [Request(uid=u, prompt=rng.integers(0, 128, size=8).astype(np.int32),
                    max_new=9) for u in range(3)]
    # each request needs 2 pages; the pool holds 3 -> one request in
    # flight at a time despite 3 free slots
    eng = _paged(cfg, params, max_concurrency=3, num_blocks=3,
                 max_pages_per_seq=2, chunk_max=4)
    res = eng.serve(reqs)
    assert int(jax.device_get(eng.cache["free_top"])) == 0  # all pages back
    for r in reqs:
        dense = GenerationEngine(params, cfg, GREEDY)
        want = dense.generate(r.prompt[None], r.max_new)[0]
        np.testing.assert_array_equal(res[r.uid], want)


@pytest.mark.parametrize("max_new", [8, 9, 10])
def test_sequence_filling_last_block_exactly(setup, max_new):
    """S0=8, block_size=8: max_new=9 writes exactly 16 positions (last
    block exactly full); 8 and 10 bracket the boundary."""
    cfg, params, prompts = setup
    dense = GenerationEngine(params, cfg, GREEDY)
    ref = dense.generate(prompts[:1], max_new)
    out = _paged(cfg, params, max_concurrency=1).generate(prompts[:1], max_new)
    np.testing.assert_array_equal(out, ref)


def test_free_list_reuse_after_early_eos(setup):
    """A sequence hitting EOS early frees its pages; the next queued
    request reuses them (stale page contents must be invisible)."""
    cfg, params, prompts = setup
    # pick an eos the greedy rollout actually emits mid-sequence
    probe = GenerationEngine(params, cfg, GREEDY).generate(prompts, 8)
    eos = int(probe[0, prompts.shape[1] + 2])
    samp = SamplerConfig(temperature=0.0, eos_id=eos)
    rng = np.random.default_rng(3)
    reqs = [Request(uid=0, prompt=prompts[0], max_new=8),
            Request(uid=1, prompt=rng.integers(0, 128, size=8).astype(np.int32),
                    max_new=8)]
    # one slot, pool sized for exactly one request: uid 1 runs entirely on
    # pages recycled from uid 0
    eng = _paged(cfg, params, sampler=samp, max_concurrency=1, num_blocks=2,
                 max_pages_per_seq=2)
    res = eng.serve(reqs)
    assert res[0][-1] == eos and res[0].size <= prompts.shape[1] + 8
    for r in reqs:
        dense = GenerationEngine(params, cfg, samp)
        want = dense.generate(r.prompt[None], r.max_new)[0]
        # paged output is trimmed at eos; dense pads post-eos with eos
        np.testing.assert_array_equal(res[r.uid], want[: res[r.uid].size])
    assert int(jax.device_get(eng.cache["free_top"])) == 0


def test_one_trace_per_bucket(setup):
    """Admissions retrace per (prompt_len, n_pages) bucket only; every
    chunk length shares one trace (dynamic trip count)."""
    cfg, params, prompts = setup
    eng = _paged(cfg, params)
    eng.generate(prompts, 4)
    assert (eng.admit_traces, eng.chunk_traces) == (1, 1)
    eng.generate(prompts, 6)  # same S0, same page need -> same buckets
    assert (eng.admit_traces, eng.chunk_traces) == (1, 1)
    eng.generate(prompts[:, :4], 4)  # new prompt bucket
    assert eng.admit_traces == 2
    assert eng.chunk_traces == 1


def test_kernel_impl_matches_ref_impl(setup):
    """The Pallas block-table kernel (interpret mode) drives the engine to
    the same greedy tokens as the gather reference."""
    cfg, params, prompts = setup
    ref = _paged(cfg, params, attn_impl="ref").generate(prompts, 8)
    ker = _paged(cfg, params, attn_impl="interpret").generate(prompts, 8)
    np.testing.assert_array_equal(ker, ref)


def test_sampled_request_determinism(setup):
    """Sampled decode keys fold (uid, step): a request's tokens do not
    depend on co-batched traffic — alone vs batched gives the same
    rollout."""
    cfg, params, prompts = setup
    samp = SamplerConfig(temperature=1.0, seed=7)
    alone = _paged(cfg, params, sampler=samp, max_concurrency=1).serve(
        [Request(uid=5, prompt=prompts[0], max_new=8)])
    batched = _paged(cfg, params, sampler=samp).serve(
        [Request(uid=5, prompt=prompts[0], max_new=8),
         Request(uid=9, prompt=prompts[1], max_new=6),
         Request(uid=11, prompt=prompts[2], max_new=3)])
    np.testing.assert_array_equal(alone[5], batched[5])


def test_hybrid_family_paged_decode():
    """Attention pages + recurrent (Mamba) per-slot state swap coexist in
    one paged cache (hybrid pattern). The oracle is per-request fixed-slot
    rollouts: tiny-hybrid carries capacity-bounded MoE blocks, where a
    *batched* prefill lets co-batched rows compete for expert capacity —
    the paged engine's per-request prefill is the serving-correct
    semantics (see docs/serving_scheduler.md)."""
    cfg = get_config("tiny-hybrid")
    params = init_model(jax.random.key(0), cfg)
    prompts = np.random.default_rng(4).integers(0, cfg.vocab, size=(2, 8)).astype(np.int32)
    out = _paged(cfg, params, max_concurrency=2, num_blocks=8,
                 max_pages_per_seq=2).generate(prompts, 8)
    for b in range(2):
        want = GenerationEngine(params, cfg, GREEDY).generate(prompts[b:b + 1], 8)
        np.testing.assert_array_equal(out[b], want[0])
