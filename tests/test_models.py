"""Per-arch smoke tests (reduced configs, one forward/train step, shapes +
no NaNs) and cross-path consistency (decode == forward, chunkwise == scan)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.models import transformer as T
from repro.models.config import param_count


def _batch(cfg, b=2, s=16, seed=0):
    key = jax.random.key(seed)
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    if cfg.frontend == "vision_stub" and cfg.frontend_tokens:
        batch["pixel_embeds"] = jax.random.normal(
            jax.random.key(seed + 1), (b, cfg.frontend_tokens, cfg.d_model),
            jnp.dtype(cfg.act_dtype),
        )
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """One forward + one grad step on the reduced config of each assigned
    architecture: output shapes correct, logits and gradients finite."""
    cfg = get_smoke(arch)
    params = T.init_model(jax.random.key(0), cfg)
    batch = _batch(cfg)
    logits, aux = T.forward(params, batch, cfg)
    s_total = batch["tokens"].shape[1] + (
        cfg.frontend_tokens if cfg.frontend == "vision_stub" else 0
    )
    assert logits.shape == (2, s_total, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"

    (loss, metrics), grads = jax.value_and_grad(T.loss_fn, has_aux=True)(
        params, batch, cfg
    )
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grad norm"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_param_count_positive(arch):
    pc = param_count(get_smoke(arch))
    assert 0 < pc["active"] <= pc["total"]


@pytest.mark.slow
@pytest.mark.parametrize(
    "arch", ["smollm-360m", "xlstm-350m", "jamba-1.5-large-398b", "dbrx-132b"]
)
def test_decode_matches_forward(arch):
    """Prefill(S-1) + decode(1 step) logits == full forward logits at pos S-1,
    for a representative of each family (attn / xlstm / hybrid / moe)."""
    cfg = get_smoke(arch)
    if cfg.moe is not None:
        # avoid capacity-drop nondeterminism between batch layouts
        from dataclasses import replace

        cfg = cfg.scaled(moe=replace(cfg.moe, capacity_factor=8.0))
    params = T.init_model(jax.random.key(0), cfg)
    batch = _batch(cfg, b=2, s=16)
    lf, _ = T.forward(params, batch, cfg)

    pre = {"tokens": batch["tokens"][:, :15]}
    if "pixel_embeds" in batch:
        pre["pixel_embeds"] = batch["pixel_embeds"]
    _, cache = T.prefill(params, pre, cfg, max_len=32)
    p = cfg.frontend_tokens if cfg.frontend == "vision_stub" else 0
    ld, _ = T.decode_step(
        params, batch["tokens"][:, 15:16], cache, jnp.int32(15 + p), cfg
    )
    np.testing.assert_allclose(
        np.asarray(ld[:, 0]), np.asarray(lf[:, 15 + p]), atol=2e-3, rtol=2e-3
    )


def test_mlstm_chunkwise_matches_recurrent(rng):
    from repro.models.xlstm import mlstm_cell_chunkwise, mlstm_cell_recurrent

    B, H, S, dh = 2, 3, 24, 8
    q = jnp.asarray(rng.normal(size=(B, H, S, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, S, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, S, dh)), jnp.float32)
    ig = jnp.asarray(rng.normal(size=(B, H, S)) * 2, jnp.float32)
    fg = jnp.asarray(rng.normal(size=(B, H, S)) * 2 + 1, jnp.float32)
    h_rec = mlstm_cell_recurrent(q, k, v, ig, fg)
    for chunk in (6, 8, 24):
        h_chk = mlstm_cell_chunkwise(q, k, v, ig, fg, chunk=chunk)
        np.testing.assert_allclose(
            np.asarray(h_chk), np.asarray(h_rec), atol=1e-4, rtol=1e-3
        )


def test_chunked_attention_matches_full(rng):
    from repro.models.config import ModelConfig, uniform_pattern
    from repro.models.layers import _chunked_causal_attention, _full_causal_attention

    cfg = ModelConfig(
        name="t", family="dense", n_layers=1, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab=64, pattern=uniform_pattern(),
        attn_chunk=8,
    )
    B, S, nh, hd = 2, 24, 4, 8
    q = jnp.asarray(rng.normal(size=(B, S, nh, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, 2, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, 2, hd)), jnp.float32)
    full = _full_causal_attention(q, k, v, cfg)
    chunked = _chunked_causal_attention(q, k, v, cfg)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               atol=1e-5, rtol=1e-4)


def test_mamba_chunked_scan_matches_stepwise(rng):
    """Chunked associative scan == exact per-step recurrence."""
    from repro.models.config import ModelConfig, SSMConfig, LayerSpec
    from repro.models.ssm import init_mamba, mamba, mamba_decode

    cfg = ModelConfig(
        name="t", family="ssm", n_layers=1, d_model=16, n_heads=2,
        n_kv_heads=2, d_ff=32, vocab=64,
        pattern=(LayerSpec("mamba", "none"),),
        ssm=SSMConfig(d_state=4, d_conv=4, expand=2),
    )
    params = init_mamba(jax.random.key(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 12, 16)), jnp.float32)
    y_par = mamba(params, x, cfg, chunk=4)

    conv = jnp.zeros((2, 3, 32), jnp.float32)
    ssm = jnp.zeros((2, 32, 4), jnp.float32)
    ys = []
    for t in range(12):
        y_t, conv, ssm = mamba_decode(params, x[:, t : t + 1], cfg, conv, ssm)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=1e-4, rtol=1e-3)


def test_loss_decreases_quickly():
    """A few SGD-ish steps reduce loss on the synthetic corpus."""
    from repro.data import DataConfig, TokenBatcher
    from repro.optim import OptimizerConfig
    from repro.runtime.steps import TrainRunConfig, init_train_state, make_train_step

    cfg = get_smoke("smollm-360m").scaled(n_layers=2, vocab=128)
    run = TrainRunConfig(optimizer=OptimizerConfig(lr=3e-3, warmup_steps=5,
                                                   total_steps=40))
    state = init_train_state(jax.random.key(0), cfg, run)
    step = jax.jit(make_train_step(cfg, run), donate_argnums=(0,))
    data = TokenBatcher(DataConfig(vocab=128, seq_len=32, global_batch=8))
    losses = []
    for i in range(40):
        state, m = step(state, jax.tree.map(jnp.asarray, data.batch(i)))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[:: len(losses) // 8]


def test_microbatched_grads_match_full_batch():
    """Gradient accumulation over microbatches == single large batch."""
    from repro.runtime.steps import TrainRunConfig, init_train_state, make_train_step

    cfg = get_smoke("smollm-360m").scaled(n_layers=2, vocab=64, remat="none")
    run1 = TrainRunConfig(num_microbatches=1)
    run4 = TrainRunConfig(num_microbatches=4)
    state = init_train_state(jax.random.key(0), cfg, run1)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 32), 0, 64)}
    s1, m1 = jax.jit(make_train_step(cfg, run1))(state, batch)
    state2 = init_train_state(jax.random.key(0), cfg, run1)
    s4, m4 = jax.jit(make_train_step(cfg, run4))(state2, batch)
    l1 = jax.tree.leaves(s1["params"])
    l4 = jax.tree.leaves(s4["params"])
    for a, b in zip(l1, l4):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
