"""Host-side continuous-batching scheduler: page accounting, FIFO
admission, exhaustion stalls, release bookkeeping, pool-HBM accounting —
plus randomized arrival/length property tests driving the scheduler (and,
in ``test_paged_engine.py``-adjacent form, the real ``PagedEngine`` page
pool) through admit/decode/release churn. Property tests run under
hypothesis when it is installed and fall back to a seeded ``random``
sweep otherwise (the conftest convention: hypothesis is optional)."""

import random

import numpy as np
import pytest

from repro.serving.scheduler import (
    Request,
    Scheduler,
    blocks_for_budget,
    kv_page_bytes,
    kv_pool_bytes,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - minimal CI boxes
    HAVE_HYPOTHESIS = False


def property_test(body, max_examples: int = 25, fallback_seeds: int = 12):
    """hypothesis ``@given(seed=...)`` when available; otherwise the same
    body swept over a fixed seed range (deterministic, no dependency)."""
    if HAVE_HYPOTHESIS:
        return settings(max_examples=max_examples, deadline=None)(
            given(seed=st.integers(0, 40_000))(body))

    def sweep():
        for seed in range(fallback_seeds):
            body(seed=seed)

    sweep.__name__ = body.__name__
    sweep.__doc__ = body.__doc__
    return sweep


def _req(uid, s0=8, max_new=8):
    return Request(uid=uid, prompt=np.zeros(s0, np.int32), max_new=max_new)


def test_pages_for_counts_written_positions():
    sched = Scheduler(max_concurrency=2, num_blocks=8, block_size=8,
                      max_pages_per_seq=4)
    # positions written: S0 + max_new - 1 (final token never fed back)
    assert sched.pages_for(8, 8) == 2   # 15 positions -> 2 pages
    assert sched.pages_for(8, 9) == 2   # 16 positions -> exactly 2 pages
    assert sched.pages_for(8, 10) == 3  # 17 positions -> 3 pages
    assert sched.pages_for(1, 1) == 1


def test_admission_is_fifo_and_respects_slots():
    sched = Scheduler(max_concurrency=2, num_blocks=8, block_size=8,
                      max_pages_per_seq=4)
    for uid in range(3):
        sched.submit(_req(uid))
    a = sched.try_admit()
    b = sched.try_admit()
    assert a.req.uid == 0 and b.req.uid == 1
    assert sched.try_admit() is None  # no free slot
    sched.finish(a.slot)
    c = sched.try_admit()
    assert c.req.uid == 2 and c.slot == a.slot  # freed slot reused


def test_admission_stalls_on_page_exhaustion():
    """Not enough free pages: the head request stays queued and nothing
    is allocated (stall, not corruption)."""
    sched = Scheduler(max_concurrency=4, num_blocks=3, block_size=8,
                      max_pages_per_seq=4)
    sched.submit(_req(0, s0=8, max_new=9))   # 2 pages
    sched.submit(_req(1, s0=8, max_new=9))   # 2 pages -> only 1 left
    adm0 = sched.try_admit()
    assert adm0.n_pages == 2 and sched.free_pages == 1
    assert sched.try_admit() is None          # stalls despite free slots
    assert len(sched.queue) == 1 and sched.free_pages == 1
    sched.finish(adm0.slot)
    assert sched.free_pages == 3
    assert sched.try_admit() is not None      # admitted after the free


def test_submit_rejects_never_admissible_requests():
    sched = Scheduler(max_concurrency=1, num_blocks=2, block_size=8,
                      max_pages_per_seq=2)
    with pytest.raises(ValueError, match="block table width"):
        sched.submit(_req(0, s0=8, max_new=64))
    sched2 = Scheduler(max_concurrency=1, num_blocks=1, block_size=8,
                       max_pages_per_seq=8)
    with pytest.raises(ValueError, match="can never be admitted"):
        sched2.submit(_req(0, s0=8, max_new=9))
    with pytest.raises(ValueError, match="max_new"):
        Request(uid=0, prompt=np.zeros(4, np.int32), max_new=0)
    with pytest.raises(ValueError, match="empty prompt"):
        Request(uid=0, prompt=np.zeros(0, np.int32), max_new=1)


def test_submit_rejects_duplicate_inflight_uid():
    """serve() keys results by uid — a duplicate in-flight uid would
    silently clobber one request's output, so submit fails loudly whether
    the first holder is still queued or already active; the uid frees
    again at finish."""
    sched = Scheduler(max_concurrency=2, num_blocks=8, block_size=8,
                      max_pages_per_seq=4)
    sched.submit(_req(0))
    with pytest.raises(ValueError, match="already in flight"):
        sched.submit(_req(0))  # duplicate of a *queued* request
    slot = sched.try_admit().slot
    with pytest.raises(ValueError, match="already in flight"):
        sched.submit(_req(0))  # duplicate of an *active* request
    sched.finish(slot)
    sched.submit(_req(0))  # finished: the uid is reusable


def test_blocks_for_budget_below_one_page_raises():
    """A budget below one page can never admit anything — the error names
    the per-page byte cost so the misconfiguration is actionable."""
    from repro.configs import get_config

    cfg = get_config("tiny-lm-xs")
    per_page = kv_page_bytes(cfg, 16, "act")
    with pytest.raises(ValueError, match=f"costs {per_page} B"):
        blocks_for_budget(per_page - 1, cfg, 16, "act")
    assert blocks_for_budget(per_page, cfg, 16, "act") == 1


def test_record_remaining_and_min_remaining():
    sched = Scheduler(max_concurrency=2, num_blocks=8, block_size=8,
                      max_pages_per_seq=4)
    sched.submit(_req(0, max_new=8))
    sched.submit(_req(1, max_new=3))
    s0 = sched.try_admit().slot
    s1 = sched.try_admit().slot
    sched.record(s0, [1])
    sched.record(s1, [2])
    assert sched.remaining(s0) == 7 and sched.remaining(s1) == 2
    assert sched.min_remaining() == 2
    sched.record(s1, [3, 4])
    assert sched.remaining(s1) == 0
    st = sched.finish(s1)
    assert st.tokens == [2, 3, 4]
    assert sched.min_remaining() == 7


def test_page_accounting_balances_after_churn():
    sched = Scheduler(max_concurrency=2, num_blocks=6, block_size=4,
                      max_pages_per_seq=4)
    for uid in range(5):
        sched.submit(_req(uid, s0=4, max_new=5))  # 2 pages each
    admitted = []
    while True:
        adm = sched.try_admit()
        if adm is None:
            break
        admitted.append(adm.slot)
    assert len(admitted) == 2
    for slot in admitted:
        sched.finish(slot)
    assert sched.free_pages == 6
    assert sorted(sched.free_slots, reverse=True) == sched.free_slots
    assert sched.has_work  # three still queued


# ---------------------------------------------------------------------------
# Pool HBM accounting (int8 KV pages halve the pool)
# ---------------------------------------------------------------------------
def test_kv_page_bytes_int8_shrinks_by_itemsize_plus_scales():
    from repro.configs import get_config

    cfg = get_config("tiny-lm-xs")
    act = kv_page_bytes(cfg, 16, "act")
    int8 = kv_page_bytes(cfg, 16, "int8")
    n_attn = sum(1 for s in cfg.pattern if s.mixer == "attn") * cfg.repeats
    itemsize = np.dtype(cfg.act_dtype).itemsize
    scale_overhead = n_attn * 2 * cfg.n_kv_heads * 4  # k+v f32 scale leaves
    # codes shrink by the act itemsize (2x for bf16 serving dtypes, 4x for
    # the f32 tiny configs); the per-(page, head) scales ride on top
    assert int8 == act // itemsize + scale_overhead
    assert kv_pool_bytes(cfg, 10, 16, "int8") == 10 * int8


def test_int8_budget_admits_about_twice_the_sequences():
    """The admission-capacity consequence: with a fixed HBM budget the
    int8 pool holds ~2x the pages, so the worst-case reservation admits
    ~2x the sequences before the queue stalls."""
    from repro.configs import get_config

    cfg = get_config("tiny-lm-xs")
    bs, budget = 16, 512 * 1024
    nb_act = blocks_for_budget(budget, cfg, bs, "act")
    nb_int8 = blocks_for_budget(budget, cfg, bs, "int8")
    assert nb_int8 >= int(1.9 * nb_act)  # ~2x minus the scale-leaf overhead

    def admitted(num_blocks):
        sched = Scheduler(max_concurrency=1_000, num_blocks=num_blocks,
                          block_size=bs, max_pages_per_seq=8)
        for uid in range(1_000):
            sched.submit(_req(uid, s0=16, max_new=17))  # 2 pages each
        n = 0
        while sched.try_admit() is not None:
            n += 1
        return n

    assert admitted(nb_int8) >= int(1.9 * admitted(nb_act))


# ---------------------------------------------------------------------------
# Randomized arrival/length property: scheduler bookkeeping under churn
# ---------------------------------------------------------------------------
def _check_sched_invariants(sched: Scheduler):
    held = sum(a.n_pages for a in sched.active.values())
    assert sched.free_pages + held == sched.num_blocks  # conservation
    assert sched.free_pages >= 0
    slots = set(sched.free_slots) | set(sched.active)
    assert len(sched.free_slots) + len(sched.active) == sched.max_concurrency
    assert slots == set(range(sched.max_concurrency))  # no slot lost/duped


@property_test
def test_randomized_churn_conserves_pages_and_slots(seed):
    """Random request mix + random admit/record/finish interleaving with
    mid-flight arrivals: page/slot conservation holds after every
    transition, admitted uids stay FIFO, and a stalled admission is always
    *explained* (no free slot, or the head's worst case exceeds the free
    pages) and never mutates state."""
    r = random.Random(seed)
    bs = r.choice([4, 8])
    sched = Scheduler(max_concurrency=r.randint(1, 4),
                      num_blocks=r.randint(4, 12), block_size=bs,
                      max_pages_per_seq=4)
    uid, pending = 0, []

    def submit_some(n):
        nonlocal uid
        for _ in range(n):
            s0 = r.randint(1, 2 * bs)
            max_new = r.randint(1, 2 * bs)
            if sched.pages_for(s0, max_new) > min(4, sched.num_blocks):
                continue  # would be rejected at submit; not churn
            sched.submit(_req(uid, s0=s0, max_new=max_new))
            pending.append(uid)
            uid += 1

    submit_some(r.randint(1, 6))
    admitted_order = []
    for _ in range(200):
        if not sched.has_work:
            break
        action = r.random()
        if action < 0.45:
            before = (sched.free_pages, len(sched.free_slots),
                      len(sched.queue))
            adm = sched.try_admit()
            if adm is None:
                # stall must be explained and must not mutate anything
                if sched.queue:
                    head = sched.queue[0]
                    need = sched.pages_for(head.prompt.size, head.max_new)
                    assert not sched.free_slots or need > sched.free_pages
                assert before == (sched.free_pages, len(sched.free_slots),
                                  len(sched.queue))
            else:
                admitted_order.append(adm.req.uid)
                assert adm.n_pages == sched.pages_for(adm.req.prompt.size,
                                                      adm.req.max_new)
        elif action < 0.75 and sched.active:
            slot = r.choice(list(sched.active))
            sched.record(slot, [1] * r.randint(1, sched.remaining(slot)))
            if sched.remaining(slot) == 0:
                sched.finish(slot)
        elif action < 0.9:
            submit_some(1)  # mid-flight arrival
        elif sched.active:
            # early EOS: finish before max_new is exhausted
            sched.finish(r.choice(list(sched.active)))
        _check_sched_invariants(sched)
    # FIFO: admissions happen in submission order
    assert admitted_order == sorted(admitted_order)
    # drain everything: exhaustion can only ever have *stalled* admission,
    # so the queue empties once actives finish
    for _ in range(200):
        if not sched.has_work:
            break
        adm = sched.try_admit()
        if adm is None and sched.active:
            sched.finish(next(iter(sched.active)))
        _check_sched_invariants(sched)
    assert not sched.has_work
    assert sched.free_pages == sched.num_blocks


# ---------------------------------------------------------------------------
# Randomized sharing property: refcounted pool partition under churn
# ---------------------------------------------------------------------------
def _check_sharing_invariants(sched: Scheduler):
    """With a prefix cache attached, the exclusive-ownership partition
    generalizes to a *refcounted* one: ``page_rc[p]`` must equal the
    number of live block-table rows containing ``p`` plus one if the
    cache holds ``p``, and the free stack ``free_list[top:]`` must be
    exactly the rc-zero pages, once each (no double-free, no leak)."""
    pool, nb = sched.pool, sched.num_blocks
    rc = np.zeros(nb, np.int64)
    for a in sched.active.values():
        assert len(set(a.row.tolist())) == a.row.size  # rows never repeat
        np.add.at(rc, a.row, 1)
    readers: dict[bytes, int] = {}
    for a in sched.active.values():
        for node in a.nodes:
            readers[node.key] = readers.get(node.key, 0) + 1
    if sched.prefix_cache is not None:
        for key, node in sched.prefix_cache.nodes.items():
            rc[node.page] += 1
            assert node.readers == readers.get(key, 0)
    np.testing.assert_array_equal(pool.page_rc, rc)  # rc conservation
    free = pool.free_list[pool.free_top:].tolist()
    assert len(set(free)) == len(free)
    assert set(free) == set(np.flatnonzero(rc == 0).tolist())
    assert len(sched.free_slots) + len(sched.active) == sched.max_concurrency


@property_test
def test_randomized_sharing_conserves_refcounts(seed):
    """Random shared-prefix traffic (prompts drawn from a small block
    vocabulary so digests collide) through admit/record/finish churn with
    a live prefix cache: the refcounted partition holds after every
    transition, a stalled admission mutates nothing (cache included), and
    draining leaves the cache as the only page holder."""
    from repro.serving.prefix_cache import PrefixCache

    r = random.Random(seed)
    bs = r.choice([4, 8])
    nb = r.randint(6, 12)
    sched = Scheduler(max_concurrency=r.randint(1, 3), num_blocks=nb,
                      block_size=bs, max_pages_per_seq=4,
                      prefix_cache=PrefixCache(nb, bs))
    blocks = [np.asarray([r.randrange(64) for _ in range(bs)], np.int32)
              for _ in range(3)]
    uid = 0

    def submit_some(n):
        nonlocal uid
        for _ in range(n):
            body = np.concatenate(
                [blocks[r.randrange(3)] for _ in range(r.randint(1, 2))])
            tail = np.asarray([r.randrange(64)
                               for _ in range(r.randrange(bs))], np.int32)
            prompt = np.concatenate([body, tail])
            max_new = r.randint(1, bs)
            if sched.pages_for(prompt.size, max_new) > min(4, nb):
                continue
            sched.submit(Request(uid=uid, prompt=prompt, max_new=max_new))
            uid += 1

    submit_some(r.randint(1, 5))
    for _ in range(200):
        if not sched.has_work:
            break
        action = r.random()
        if action < 0.45:
            before = (sched.free_pages, len(sched.free_slots),
                      len(sched.queue), sched.prefix_cache.pages_held)
            if sched.try_admit() is None:
                # a stall must not have moved pages, slots, queue entries
                # or cache nodes (all-or-nothing eviction planning)
                assert before == (sched.free_pages, len(sched.free_slots),
                                  len(sched.queue),
                                  sched.prefix_cache.pages_held)
        elif action < 0.75 and sched.active:
            slot = r.choice(list(sched.active))
            sched.record(slot, [1] * r.randint(1, sched.remaining(slot)))
            if sched.remaining(slot) == 0:
                sched.finish(slot)
        elif action < 0.9:
            submit_some(1)  # mid-flight arrival
        elif sched.active:
            sched.finish(r.choice(list(sched.active)))  # early EOS
        _check_sharing_invariants(sched)
    for _ in range(200):
        if not sched.has_work:
            break
        if sched.try_admit() is None and sched.active:
            sched.finish(next(iter(sched.active)))
        _check_sharing_invariants(sched)
    assert not sched.has_work
    # quiescent: every page is either free or held by the cache alone
    assert sched.free_pages == nb - sched.prefix_cache.pages_held


# ---------------------------------------------------------------------------
# Throughput policy: batched admission, chunked prefill, watermark preemption
# ---------------------------------------------------------------------------
from repro.serving.scheduler import SchedulerPolicy  # noqa: E402


def _preq(uid, s0=8, max_new=8, priority=0):
    return Request(uid=uid, prompt=np.zeros(s0, np.int32), max_new=max_new,
                   priority=priority)


def test_policy_validation_and_legacy_default():
    assert SchedulerPolicy().is_legacy
    assert not SchedulerPolicy(admit_window=2).is_legacy
    with pytest.raises(ValueError, match="admit_window"):
        SchedulerPolicy(admit_window=0)
    with pytest.raises(ValueError, match="batch_max"):
        SchedulerPolicy(batch_max=0)
    with pytest.raises(ValueError, match="prefill_chunk"):
        SchedulerPolicy(prefill_chunk=0)
    with pytest.raises(ValueError, match="watermark"):
        SchedulerPolicy(watermark=(4, 2))


def test_admit_pass_groups_cold_arrivals():
    """Five cold arrivals, batch_max=3: one admission pass commits all
    five (slots allow) as groups [3, 2] in FIFO order, each admission
    carrying its own pages."""
    sched = Scheduler(max_concurrency=5, num_blocks=16, block_size=8,
                      max_pages_per_seq=4,
                      policy=SchedulerPolicy(admit_window=5, batch_max=3))
    for uid in range(5):
        sched.submit(_preq(uid))
    groups = sched.admit_pass()
    assert [len(g) for g in groups] == [3, 2]
    uids = [a.req.uid for g in groups for a in g]
    assert uids == [0, 1, 2, 3, 4]
    rows = np.concatenate([a.row for g in groups for a in g])
    assert len(set(rows.tolist())) == rows.size  # disjoint pages
    _check_sched_invariants(sched)


def test_admit_pass_prefers_low_priority_class_within_window():
    """Window sorting is by (priority, FIFO): an urgent request two
    positions back jumps a same-window lower class, but FIFO order is
    kept inside each class."""
    sched = Scheduler(max_concurrency=4, num_blocks=32, block_size=8,
                      max_pages_per_seq=4,
                      policy=SchedulerPolicy(admit_window=3, batch_max=4))
    sched.submit(_preq(0, priority=1))
    sched.submit(_preq(1, priority=1))
    sched.submit(_preq(2, priority=0))
    sched.submit(_preq(3, priority=0))
    uids = [a.req.uid for g in sched.admit_pass() for a in g]
    assert uids == [2, 0, 3, 1] or uids == [2, 3, 0, 1]


def test_chunked_prefill_state_machine():
    """A 24-token prompt with prefill_chunk=8 stub-admits FLOP-free and
    advances one page-aligned chunk at a time; only the final chunk flips
    the slot to decoding."""
    pol = SchedulerPolicy(admit_window=1, batch_max=1, prefill_chunk=8)
    sched = Scheduler(max_concurrency=2, num_blocks=8, block_size=8,
                      max_pages_per_seq=4, policy=pol)
    sched.submit(_preq(0, s0=24, max_new=8))
    (adm,), = sched.admit_pass()
    assert adm.chunked and sched.active[adm.slot].prefilling
    assert sched.active[adm.slot].seq == 0
    assert sched.plan_chunk(4) is None  # nothing decoding yet
    seen = []
    while sched.prefilling_slots():
        tokens, n_prior, final, _ = sched.take_prefill_chunk(adm.slot)
        seen.append((tokens.size, n_prior, final))
    assert seen == [(8, 0, False), (8, 1, False), (8, 2, True)]
    st = sched.active[adm.slot]
    assert not st.prefilling and st.seq == 24
    sched.record(adm.slot, [7])  # the final chunk's sampled token
    assert sched.remaining(adm.slot) == 7
    _check_sched_invariants(sched)


def test_preemption_picks_lowest_class_youngest_and_requeues_front():
    """Pool pressure with watermark admission: the victim is the
    lowest-priority class (ties: youngest admit tick), its pages free
    exactly, and the request rejoins the queue *front* protected from
    re-victimization until it produces a token."""
    pol = SchedulerPolicy(admit_window=1, batch_max=1, watermark=(1, 4))
    sched = Scheduler(max_concurrency=3, num_blocks=7, block_size=8,
                      max_pages_per_seq=4, policy=pol)
    sched.submit(_preq(0, s0=8, max_new=17, priority=0))  # worst case 3 pages
    sched.submit(_preq(1, s0=8, max_new=17, priority=1))
    sched.submit(_preq(2, s0=8, max_new=17, priority=1))
    slots = [g[0].slot for g in sched.admit_pass()]
    assert len(slots) == 3  # watermark admits under worst-case pool
    for s in slots:
        sched.record(s, [1])
    free0 = sched.free_pages
    # march decode until the plan must preempt
    victims = []
    for _ in range(40):
        plan = sched.plan_chunk(2)
        if plan is None:
            break
        for v in plan.victims:
            victims.append(sched.active[v].req.uid)
            sched.preempt(v)
        for slot, n_new in plan.grow:
            sched.commit_grow(slot, n_new)
        if not plan.slots:
            continue
        sched.advance_decode(plan.k)
        for s in plan.slots:
            sched.record(s, [1] * plan.k)
            if sched.remaining(s) == 0:
                sched.finish(s)
        _check_sched_invariants(sched)
    assert victims, "pressure never forced a preemption"
    # uid 0 is class 0 (urgent): never victimized while class-1 slots run
    assert 0 not in victims
    assert sched.queue and sched.queue[0].uid == victims[-1]
    assert sched.preemptions == len(victims)
    del free0


@property_test
def test_throughput_churn_conserves_and_never_livelocks(seed):
    """Poisson arrivals (the bench's shared trace generator) with random
    priorities driven through the full throughput loop — windowed batched
    admission, chunked prefill, watermark growth, preempt-and-requeue —
    checking after every transition: page/slot conservation, stall
    purity, the no-livelock guard (a preempted uid is never re-victimized
    before producing a token), and that every request eventually
    completes with exactly ``max_new`` tokens."""
    from benchmarks.common import poisson_trace

    r = random.Random(seed)
    bs = 8
    nb = r.randint(6, 14)
    conc = r.randint(2, 4)
    pol = SchedulerPolicy(
        admit_window=r.randint(1, 4),
        batch_max=r.randint(1, 3),
        prefill_chunk=bs if r.random() < 0.5 else None,
        watermark=(1, min(4, nb)) if r.random() < 0.6 else None,
    )
    sched = Scheduler(max_concurrency=conc, num_blocks=nb, block_size=bs,
                      max_pages_per_seq=4, policy=pol)
    raw, arrivals = poisson_trace(
        r.randint(4, 10), 1000.0, seed,
        prompt_lens=[4, 8, 16, 24], max_news=[2, 5, 9],
        priorities=(0, 1), vocab=64)
    feed = [rq for rq in raw
            if sched.pages_for(len(rq["prompt"]), rq["max_new"]) <= 4]
    done: dict[int, int] = {}
    last_preempt_produced: dict[int, bool] = {}

    def check():
        _check_sched_invariants(sched)

    passes = 0
    while feed or sched.has_work:
        passes += 1
        assert passes < 500, "scheduler livelocked"
        # arrivals drip in a couple per pass (sim time = pass count)
        for _ in range(min(len(feed), r.randint(1, 2))):
            sched.submit(Request(**feed.pop(0)))
        # (1) in-flight prefills first (mirrors the engine pass order)
        for slot in sched.prefilling_slots():
            _, _, final, _ = sched.take_prefill_chunk(slot)
            if final:
                sched.record(slot, [1])
                last_preempt_produced[sched.active[slot].req.uid] = True
            check()
        # (2) admission pass
        before = (sched.free_pages, len(sched.free_slots), len(sched.queue))
        groups = sched.admit_pass()
        if not groups and sched.queue and sched.free_slots:
            assert before == (sched.free_pages, len(sched.free_slots),
                              len(sched.queue))  # stall purity
        for g in groups:
            for adm in g:
                if not adm.chunked:
                    sched.record(adm.slot, [1])  # prefill's sampled token
                    last_preempt_produced[adm.req.uid] = True
                    if sched.remaining(adm.slot) == 0:
                        st = sched.finish(adm.slot)
                        done[st.req.uid] = len(st.tokens)
            check()
        # (3) decode chunk with escalation
        plan = sched.plan_chunk(chunk_max=r.choice([1, 2, 4]))
        if plan is None:
            continue
        for v in plan.victims:
            st = sched.active[v]
            uid = st.req.uid
            # livelock guard: a re-victimized uid produced since last time
            if uid in last_preempt_produced:
                assert last_preempt_produced[uid], (
                    f"uid {uid} re-victimized before producing a token")
            sched.preempt(v)
            last_preempt_produced[uid] = False
            check()
        if plan.evict_nodes:
            sched._commit_evict(plan.evict_nodes)
            check()
        for slot, n_new in plan.grow:
            sched.commit_grow(slot, n_new)
            check()
        if plan.slots:
            sched.advance_decode(plan.k)
            for s in plan.slots:
                sched.record(s, [1] * plan.k)
                last_preempt_produced[sched.active[s].req.uid] = True
                if sched.remaining(s) == 0:
                    st = sched.finish(s)
                    done[st.req.uid] = len(st.tokens)
            check()
    assert set(done) == {rq["uid"] for rq in raw
                         if sched.pages_for(len(rq["prompt"]),
                                            rq["max_new"]) <= 4}
    for rq in raw:
        if rq["uid"] in done:
            assert done[rq["uid"]] == rq["max_new"]
    assert sched.free_pages == nb  # every page returned


def test_poisson_trace_is_reproducible_and_pinned():
    """The bench and the tests share one seeded arrival-trace generator;
    the digests of the two committed latency-grid workloads are pinned so
    a generator change cannot silently re-baseline the gate."""
    from benchmarks.common import poisson_trace, trace_digest

    fast = poisson_trace(8, 2000.0, 13, prompt_lens=[8, 8, 8, 16, 16, 48],
                         max_news=[4, 8, 8, 16], priorities=(0, 0, 1),
                         vocab=128)
    again = poisson_trace(8, 2000.0, 13, prompt_lens=[8, 8, 8, 16, 16, 48],
                          max_news=[4, 8, 8, 16], priorities=(0, 0, 1),
                          vocab=128)
    assert trace_digest(*fast) == trace_digest(*again)
    assert trace_digest(*fast) == "1f8566a34d637b1415d71368851f2e5a"
    full = poisson_trace(24, 2000.0, 62,
                         prompt_lens=[16, 16, 16, 32, 32, 96],
                         max_news=[8, 16, 16, 32], priorities=(0, 0, 1),
                         vocab=128)
    assert trace_digest(*full) == "4d4c01b0aa0855a5ee286f144a06b18b"
    # arrival times are strictly increasing and the long prompt leads
    # both grids (the head-of-line-blocking arrangement the bench gates)
    assert all(b > a for a, b in zip(fast[1], fast[1][1:]))
    assert fast[0][0]["prompt"].size == 48
    assert full[0][0]["prompt"].size == 96
