"""Host-side continuous-batching scheduler: page accounting, FIFO
admission, exhaustion stalls, and release bookkeeping — device-free."""

import numpy as np
import pytest

from repro.serving.scheduler import Request, Scheduler


def _req(uid, s0=8, max_new=8):
    return Request(uid=uid, prompt=np.zeros(s0, np.int32), max_new=max_new)


def test_pages_for_counts_written_positions():
    sched = Scheduler(max_concurrency=2, num_blocks=8, block_size=8,
                      max_pages_per_seq=4)
    # positions written: S0 + max_new - 1 (final token never fed back)
    assert sched.pages_for(8, 8) == 2   # 15 positions -> 2 pages
    assert sched.pages_for(8, 9) == 2   # 16 positions -> exactly 2 pages
    assert sched.pages_for(8, 10) == 3  # 17 positions -> 3 pages
    assert sched.pages_for(1, 1) == 1


def test_admission_is_fifo_and_respects_slots():
    sched = Scheduler(max_concurrency=2, num_blocks=8, block_size=8,
                      max_pages_per_seq=4)
    for uid in range(3):
        sched.submit(_req(uid))
    a = sched.try_admit()
    b = sched.try_admit()
    assert a[1].uid == 0 and b[1].uid == 1
    assert sched.try_admit() is None  # no free slot
    sched.finish(a[0])
    c = sched.try_admit()
    assert c[1].uid == 2 and c[0] == a[0]  # freed slot reused


def test_admission_stalls_on_page_exhaustion():
    """Not enough free pages: the head request stays queued and nothing
    is allocated (stall, not corruption)."""
    sched = Scheduler(max_concurrency=4, num_blocks=3, block_size=8,
                      max_pages_per_seq=4)
    sched.submit(_req(0, s0=8, max_new=9))   # 2 pages
    sched.submit(_req(1, s0=8, max_new=9))   # 2 pages -> only 1 left
    slot0, _, n0 = sched.try_admit()
    assert n0 == 2 and sched.free_pages == 1
    assert sched.try_admit() is None          # stalls despite free slots
    assert len(sched.queue) == 1 and sched.free_pages == 1
    sched.finish(slot0)
    assert sched.free_pages == 3
    assert sched.try_admit() is not None      # admitted after the free


def test_submit_rejects_never_admissible_requests():
    sched = Scheduler(max_concurrency=1, num_blocks=2, block_size=8,
                      max_pages_per_seq=2)
    with pytest.raises(ValueError, match="block table width"):
        sched.submit(_req(0, s0=8, max_new=64))
    sched2 = Scheduler(max_concurrency=1, num_blocks=1, block_size=8,
                       max_pages_per_seq=8)
    with pytest.raises(ValueError, match="can never be admitted"):
        sched2.submit(_req(0, s0=8, max_new=9))
    with pytest.raises(ValueError, match="max_new"):
        Request(uid=0, prompt=np.zeros(4, np.int32), max_new=0)
    with pytest.raises(ValueError, match="empty prompt"):
        Request(uid=0, prompt=np.zeros(0, np.int32), max_new=1)


def test_record_remaining_and_min_remaining():
    sched = Scheduler(max_concurrency=2, num_blocks=8, block_size=8,
                      max_pages_per_seq=4)
    sched.submit(_req(0, max_new=8))
    sched.submit(_req(1, max_new=3))
    s0, _, _ = sched.try_admit()
    s1, _, _ = sched.try_admit()
    sched.record(s0, [1])
    sched.record(s1, [2])
    assert sched.remaining(s0) == 7 and sched.remaining(s1) == 2
    assert sched.min_remaining() == 2
    sched.record(s1, [3, 4])
    assert sched.remaining(s1) == 0
    st = sched.finish(s1)
    assert st.tokens == [2, 3, 4]
    assert sched.min_remaining() == 7


def test_page_accounting_balances_after_churn():
    sched = Scheduler(max_concurrency=2, num_blocks=6, block_size=4,
                      max_pages_per_seq=4)
    for uid in range(5):
        sched.submit(_req(uid, s0=4, max_new=5))  # 2 pages each
    admitted = []
    while True:
        adm = sched.try_admit()
        if adm is None:
            break
        admitted.append(adm[0])
    assert len(admitted) == 2
    for slot in admitted:
        sched.finish(slot)
    assert sched.free_pages == 6
    assert sorted(sched.free_slots, reverse=True) == sched.free_slots
    assert sched.has_work  # three still queued
