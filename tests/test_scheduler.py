"""Host-side continuous-batching scheduler: page accounting, FIFO
admission, exhaustion stalls, release bookkeeping, pool-HBM accounting —
plus randomized arrival/length property tests driving the scheduler (and,
in ``test_paged_engine.py``-adjacent form, the real ``PagedEngine`` page
pool) through admit/decode/release churn. Property tests run under
hypothesis when it is installed and fall back to a seeded ``random``
sweep otherwise (the conftest convention: hypothesis is optional)."""

import random

import numpy as np
import pytest

from repro.serving.scheduler import (
    Request,
    Scheduler,
    blocks_for_budget,
    kv_page_bytes,
    kv_pool_bytes,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - minimal CI boxes
    HAVE_HYPOTHESIS = False


def property_test(body, max_examples: int = 25, fallback_seeds: int = 12):
    """hypothesis ``@given(seed=...)`` when available; otherwise the same
    body swept over a fixed seed range (deterministic, no dependency)."""
    if HAVE_HYPOTHESIS:
        return settings(max_examples=max_examples, deadline=None)(
            given(seed=st.integers(0, 40_000))(body))

    def sweep():
        for seed in range(fallback_seeds):
            body(seed=seed)

    sweep.__name__ = body.__name__
    sweep.__doc__ = body.__doc__
    return sweep


def _req(uid, s0=8, max_new=8):
    return Request(uid=uid, prompt=np.zeros(s0, np.int32), max_new=max_new)


def test_pages_for_counts_written_positions():
    sched = Scheduler(max_concurrency=2, num_blocks=8, block_size=8,
                      max_pages_per_seq=4)
    # positions written: S0 + max_new - 1 (final token never fed back)
    assert sched.pages_for(8, 8) == 2   # 15 positions -> 2 pages
    assert sched.pages_for(8, 9) == 2   # 16 positions -> exactly 2 pages
    assert sched.pages_for(8, 10) == 3  # 17 positions -> 3 pages
    assert sched.pages_for(1, 1) == 1


def test_admission_is_fifo_and_respects_slots():
    sched = Scheduler(max_concurrency=2, num_blocks=8, block_size=8,
                      max_pages_per_seq=4)
    for uid in range(3):
        sched.submit(_req(uid))
    a = sched.try_admit()
    b = sched.try_admit()
    assert a[1].uid == 0 and b[1].uid == 1
    assert sched.try_admit() is None  # no free slot
    sched.finish(a[0])
    c = sched.try_admit()
    assert c[1].uid == 2 and c[0] == a[0]  # freed slot reused


def test_admission_stalls_on_page_exhaustion():
    """Not enough free pages: the head request stays queued and nothing
    is allocated (stall, not corruption)."""
    sched = Scheduler(max_concurrency=4, num_blocks=3, block_size=8,
                      max_pages_per_seq=4)
    sched.submit(_req(0, s0=8, max_new=9))   # 2 pages
    sched.submit(_req(1, s0=8, max_new=9))   # 2 pages -> only 1 left
    slot0, _, n0 = sched.try_admit()
    assert n0 == 2 and sched.free_pages == 1
    assert sched.try_admit() is None          # stalls despite free slots
    assert len(sched.queue) == 1 and sched.free_pages == 1
    sched.finish(slot0)
    assert sched.free_pages == 3
    assert sched.try_admit() is not None      # admitted after the free


def test_submit_rejects_never_admissible_requests():
    sched = Scheduler(max_concurrency=1, num_blocks=2, block_size=8,
                      max_pages_per_seq=2)
    with pytest.raises(ValueError, match="block table width"):
        sched.submit(_req(0, s0=8, max_new=64))
    sched2 = Scheduler(max_concurrency=1, num_blocks=1, block_size=8,
                       max_pages_per_seq=8)
    with pytest.raises(ValueError, match="can never be admitted"):
        sched2.submit(_req(0, s0=8, max_new=9))
    with pytest.raises(ValueError, match="max_new"):
        Request(uid=0, prompt=np.zeros(4, np.int32), max_new=0)
    with pytest.raises(ValueError, match="empty prompt"):
        Request(uid=0, prompt=np.zeros(0, np.int32), max_new=1)


def test_submit_rejects_duplicate_inflight_uid():
    """serve() keys results by uid — a duplicate in-flight uid would
    silently clobber one request's output, so submit fails loudly whether
    the first holder is still queued or already active; the uid frees
    again at finish."""
    sched = Scheduler(max_concurrency=2, num_blocks=8, block_size=8,
                      max_pages_per_seq=4)
    sched.submit(_req(0))
    with pytest.raises(ValueError, match="already in flight"):
        sched.submit(_req(0))  # duplicate of a *queued* request
    slot, _, _ = sched.try_admit()
    with pytest.raises(ValueError, match="already in flight"):
        sched.submit(_req(0))  # duplicate of an *active* request
    sched.finish(slot)
    sched.submit(_req(0))  # finished: the uid is reusable


def test_blocks_for_budget_below_one_page_raises():
    """A budget below one page can never admit anything — the error names
    the per-page byte cost so the misconfiguration is actionable."""
    from repro.configs import get_config

    cfg = get_config("tiny-lm-xs")
    per_page = kv_page_bytes(cfg, 16, "act")
    with pytest.raises(ValueError, match=f"costs {per_page} B"):
        blocks_for_budget(per_page - 1, cfg, 16, "act")
    assert blocks_for_budget(per_page, cfg, 16, "act") == 1


def test_record_remaining_and_min_remaining():
    sched = Scheduler(max_concurrency=2, num_blocks=8, block_size=8,
                      max_pages_per_seq=4)
    sched.submit(_req(0, max_new=8))
    sched.submit(_req(1, max_new=3))
    s0, _, _ = sched.try_admit()
    s1, _, _ = sched.try_admit()
    sched.record(s0, [1])
    sched.record(s1, [2])
    assert sched.remaining(s0) == 7 and sched.remaining(s1) == 2
    assert sched.min_remaining() == 2
    sched.record(s1, [3, 4])
    assert sched.remaining(s1) == 0
    st = sched.finish(s1)
    assert st.tokens == [2, 3, 4]
    assert sched.min_remaining() == 7


def test_page_accounting_balances_after_churn():
    sched = Scheduler(max_concurrency=2, num_blocks=6, block_size=4,
                      max_pages_per_seq=4)
    for uid in range(5):
        sched.submit(_req(uid, s0=4, max_new=5))  # 2 pages each
    admitted = []
    while True:
        adm = sched.try_admit()
        if adm is None:
            break
        admitted.append(adm[0])
    assert len(admitted) == 2
    for slot in admitted:
        sched.finish(slot)
    assert sched.free_pages == 6
    assert sorted(sched.free_slots, reverse=True) == sched.free_slots
    assert sched.has_work  # three still queued


# ---------------------------------------------------------------------------
# Pool HBM accounting (int8 KV pages halve the pool)
# ---------------------------------------------------------------------------
def test_kv_page_bytes_int8_shrinks_by_itemsize_plus_scales():
    from repro.configs import get_config

    cfg = get_config("tiny-lm-xs")
    act = kv_page_bytes(cfg, 16, "act")
    int8 = kv_page_bytes(cfg, 16, "int8")
    n_attn = sum(1 for s in cfg.pattern if s.mixer == "attn") * cfg.repeats
    itemsize = np.dtype(cfg.act_dtype).itemsize
    scale_overhead = n_attn * 2 * cfg.n_kv_heads * 4  # k+v f32 scale leaves
    # codes shrink by the act itemsize (2x for bf16 serving dtypes, 4x for
    # the f32 tiny configs); the per-(page, head) scales ride on top
    assert int8 == act // itemsize + scale_overhead
    assert kv_pool_bytes(cfg, 10, 16, "int8") == 10 * int8


def test_int8_budget_admits_about_twice_the_sequences():
    """The admission-capacity consequence: with a fixed HBM budget the
    int8 pool holds ~2x the pages, so the worst-case reservation admits
    ~2x the sequences before the queue stalls."""
    from repro.configs import get_config

    cfg = get_config("tiny-lm-xs")
    bs, budget = 16, 512 * 1024
    nb_act = blocks_for_budget(budget, cfg, bs, "act")
    nb_int8 = blocks_for_budget(budget, cfg, bs, "int8")
    assert nb_int8 >= int(1.9 * nb_act)  # ~2x minus the scale-leaf overhead

    def admitted(num_blocks):
        sched = Scheduler(max_concurrency=1_000, num_blocks=num_blocks,
                          block_size=bs, max_pages_per_seq=8)
        for uid in range(1_000):
            sched.submit(_req(uid, s0=16, max_new=17))  # 2 pages each
        n = 0
        while sched.try_admit() is not None:
            n += 1
        return n

    assert admitted(nb_int8) >= int(1.9 * admitted(nb_act))


# ---------------------------------------------------------------------------
# Randomized arrival/length property: scheduler bookkeeping under churn
# ---------------------------------------------------------------------------
def _check_sched_invariants(sched: Scheduler):
    held = sum(a.n_pages for a in sched.active.values())
    assert sched.free_pages + held == sched.num_blocks  # conservation
    assert sched.free_pages >= 0
    slots = set(sched.free_slots) | set(sched.active)
    assert len(sched.free_slots) + len(sched.active) == sched.max_concurrency
    assert slots == set(range(sched.max_concurrency))  # no slot lost/duped


@property_test
def test_randomized_churn_conserves_pages_and_slots(seed):
    """Random request mix + random admit/record/finish interleaving with
    mid-flight arrivals: page/slot conservation holds after every
    transition, admitted uids stay FIFO, and a stalled admission is always
    *explained* (no free slot, or the head's worst case exceeds the free
    pages) and never mutates state."""
    r = random.Random(seed)
    bs = r.choice([4, 8])
    sched = Scheduler(max_concurrency=r.randint(1, 4),
                      num_blocks=r.randint(4, 12), block_size=bs,
                      max_pages_per_seq=4)
    uid, pending = 0, []

    def submit_some(n):
        nonlocal uid
        for _ in range(n):
            s0 = r.randint(1, 2 * bs)
            max_new = r.randint(1, 2 * bs)
            if sched.pages_for(s0, max_new) > min(4, sched.num_blocks):
                continue  # would be rejected at submit; not churn
            sched.submit(_req(uid, s0=s0, max_new=max_new))
            pending.append(uid)
            uid += 1

    submit_some(r.randint(1, 6))
    admitted_order = []
    for _ in range(200):
        if not sched.has_work:
            break
        action = r.random()
        if action < 0.45:
            before = (sched.free_pages, len(sched.free_slots),
                      len(sched.queue))
            adm = sched.try_admit()
            if adm is None:
                # stall must be explained and must not mutate anything
                if sched.queue:
                    head = sched.queue[0]
                    need = sched.pages_for(head.prompt.size, head.max_new)
                    assert not sched.free_slots or need > sched.free_pages
                assert before == (sched.free_pages, len(sched.free_slots),
                                  len(sched.queue))
            else:
                slot, req, n_pages = adm
                admitted_order.append(req.uid)
                assert n_pages == sched.pages_for(req.prompt.size,
                                                  req.max_new)
        elif action < 0.75 and sched.active:
            slot = r.choice(list(sched.active))
            sched.record(slot, [1] * r.randint(1, sched.remaining(slot)))
            if sched.remaining(slot) == 0:
                sched.finish(slot)
        elif action < 0.9:
            submit_some(1)  # mid-flight arrival
        elif sched.active:
            # early EOS: finish before max_new is exhausted
            sched.finish(r.choice(list(sched.active)))
        _check_sched_invariants(sched)
    # FIFO: admissions happen in submission order
    assert admitted_order == sorted(admitted_order)
    # drain everything: exhaustion can only ever have *stalled* admission,
    # so the queue empties once actives finish
    for _ in range(200):
        if not sched.has_work:
            break
        adm = sched.try_admit()
        if adm is None and sched.active:
            sched.finish(next(iter(sched.active)))
        _check_sched_invariants(sched)
    assert not sched.has_work
    assert sched.free_pages == sched.num_blocks


# ---------------------------------------------------------------------------
# Randomized sharing property: refcounted pool partition under churn
# ---------------------------------------------------------------------------
def _check_sharing_invariants(sched: Scheduler):
    """With a prefix cache attached, the exclusive-ownership partition
    generalizes to a *refcounted* one: ``page_rc[p]`` must equal the
    number of live block-table rows containing ``p`` plus one if the
    cache holds ``p``, and the free stack ``free_list[top:]`` must be
    exactly the rc-zero pages, once each (no double-free, no leak)."""
    pool, nb = sched.pool, sched.num_blocks
    rc = np.zeros(nb, np.int64)
    for a in sched.active.values():
        assert len(set(a.row.tolist())) == a.row.size  # rows never repeat
        np.add.at(rc, a.row, 1)
    readers: dict[bytes, int] = {}
    for a in sched.active.values():
        for node in a.nodes:
            readers[node.key] = readers.get(node.key, 0) + 1
    if sched.prefix_cache is not None:
        for key, node in sched.prefix_cache.nodes.items():
            rc[node.page] += 1
            assert node.readers == readers.get(key, 0)
    np.testing.assert_array_equal(pool.page_rc, rc)  # rc conservation
    free = pool.free_list[pool.free_top:].tolist()
    assert len(set(free)) == len(free)
    assert set(free) == set(np.flatnonzero(rc == 0).tolist())
    assert len(sched.free_slots) + len(sched.active) == sched.max_concurrency


@property_test
def test_randomized_sharing_conserves_refcounts(seed):
    """Random shared-prefix traffic (prompts drawn from a small block
    vocabulary so digests collide) through admit/record/finish churn with
    a live prefix cache: the refcounted partition holds after every
    transition, a stalled admission mutates nothing (cache included), and
    draining leaves the cache as the only page holder."""
    from repro.serving.prefix_cache import PrefixCache

    r = random.Random(seed)
    bs = r.choice([4, 8])
    nb = r.randint(6, 12)
    sched = Scheduler(max_concurrency=r.randint(1, 3), num_blocks=nb,
                      block_size=bs, max_pages_per_seq=4,
                      prefix_cache=PrefixCache(nb, bs))
    blocks = [np.asarray([r.randrange(64) for _ in range(bs)], np.int32)
              for _ in range(3)]
    uid = 0

    def submit_some(n):
        nonlocal uid
        for _ in range(n):
            body = np.concatenate(
                [blocks[r.randrange(3)] for _ in range(r.randint(1, 2))])
            tail = np.asarray([r.randrange(64)
                               for _ in range(r.randrange(bs))], np.int32)
            prompt = np.concatenate([body, tail])
            max_new = r.randint(1, bs)
            if sched.pages_for(prompt.size, max_new) > min(4, nb):
                continue
            sched.submit(Request(uid=uid, prompt=prompt, max_new=max_new))
            uid += 1

    submit_some(r.randint(1, 5))
    for _ in range(200):
        if not sched.has_work:
            break
        action = r.random()
        if action < 0.45:
            before = (sched.free_pages, len(sched.free_slots),
                      len(sched.queue), sched.prefix_cache.pages_held)
            if sched.try_admit() is None:
                # a stall must not have moved pages, slots, queue entries
                # or cache nodes (all-or-nothing eviction planning)
                assert before == (sched.free_pages, len(sched.free_slots),
                                  len(sched.queue),
                                  sched.prefix_cache.pages_held)
        elif action < 0.75 and sched.active:
            slot = r.choice(list(sched.active))
            sched.record(slot, [1] * r.randint(1, sched.remaining(slot)))
            if sched.remaining(slot) == 0:
                sched.finish(slot)
        elif action < 0.9:
            submit_some(1)  # mid-flight arrival
        elif sched.active:
            sched.finish(r.choice(list(sched.active)))  # early EOS
        _check_sharing_invariants(sched)
    for _ in range(200):
        if not sched.has_work:
            break
        if sched.try_admit() is None and sched.active:
            sched.finish(next(iter(sched.active)))
        _check_sharing_invariants(sched)
    assert not sched.has_work
    # quiescent: every page is either free or held by the cache alone
    assert sched.free_pages == nb - sched.prefix_cache.pages_held
