"""The trip-count-corrected HLO cost model: validated against XLA's own
cost_analysis on scan-free modules, and against hand-counted FLOPs on
scanned ones."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze, shape_bytes


def test_shape_bytes():
    assert shape_bytes("f32[64,64]{1,0}") == 64 * 64 * 4
    assert shape_bytes("bf16[2,3]") == 12
    assert shape_bytes("(s32[], f32[8]{0})") == 4 + 32
    assert shape_bytes("pred[7]") == 7


def test_scan_flops_exact():
    def scanned(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        y, _ = jax.lax.scan(body, x, None, length=13)
        return y

    c = jax.jit(scanned).lower(jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    r = analyze(c.as_text())
    assert r.flops == 13 * 2 * 32**3
    assert r.n_while == 1 and r.max_trip_product == 13


def test_matches_cost_analysis_when_unrolled():
    def f(w1, w2, x):
        return jnp.sum(jax.nn.relu(x @ w1) @ w2)

    g = jax.jit(jax.grad(f, argnums=(0, 1)))
    specs = (
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 32), jnp.float32),
        jax.ShapeDtypeStruct((16, 64), jnp.float32),
    )
    c = g.lower(*specs).compile()
    r = analyze(c.as_text())
    ca = c.cost_analysis()
    if isinstance(ca, list):  # old jax: one dict per device
        ca = ca[0]
    # dots dominate; elementwise flops are not counted by the parser
    assert abs(r.flops - ca["flops"]) / ca["flops"] < 0.05


def test_nested_scan_multiplies():
    def inner(c):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, c, None, length=3)
        return y

    def outer(x):
        def body(c, _):
            return inner(c), None
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y

    c = jax.jit(outer).lower(jax.ShapeDtypeStruct((16, 16), jnp.float32)).compile()
    r = analyze(c.as_text())
    assert r.flops == 15 * 2 * 16**3
    assert r.max_trip_product == 15


@pytest.mark.slow
def test_model_scan_vs_unrolled_parity():
    """The full train step: parsed costs identical whether layers are
    scanned or python-unrolled (the correction is exact, not approximate)."""
    from repro.configs import get_smoke
    from repro.runtime.steps import TrainRunConfig, abstract_train_state, make_train_step

    run = TrainRunConfig()
    base = get_smoke("smollm-360m").scaled(n_layers=4, remat="none",
                                           attn_chunk_threshold=10**9)
    bspec = {"tokens": jax.ShapeDtypeStruct((2, 32), jnp.int32)}
    flops = {}
    for scan in (False, True):
        cfg = base.scaled(scan_layers=scan)
        state = abstract_train_state(cfg, run)
        c = jax.jit(make_train_step(cfg, run)).lower(state, bspec).compile()
        flops[scan] = analyze(c.as_text()).flops
    assert flops[True] == flops[False]
