"""GPFQ tests: Theorem B.1 equivalence, error-correction quality, AXE budgets."""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    AxeConfig,
    act_alphabet,
    calibrate_act_quant,
    certify,
    fake_quantize_act,
    gpfq,
    gpfq_memory_efficient,
    me_stats,
    quantize_weights_rtn,
    strict_budgets,
    weight_alphabet,
)


def _layer(seed, k=48, c=16, d=128, scale=0.5):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(k, c)) * scale, jnp.float32)
    x = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)
    aq = calibrate_act_quant(np.percentile(x, 1), np.percentile(x, 99), act_alphabet(8))
    xq = fake_quantize_act(x, aq)
    return w, x, xq, aq


def _recon_err(w, x, xq, w_q):
    return float(jnp.linalg.norm(x.T @ w - xq.T @ w_q))


@given(seed=st.integers(0, 100))
@settings(max_examples=8)
def test_theorem_b1_exact_equivalence(seed):
    """GPFQ(W, X, Xq) == GPFQ(W, G H^-1, H) — exact integer agreement."""
    w, x, xq, _ = _layer(seed, k=32, c=8, d=96)
    wa = weight_alphabet(4)
    r_std = gpfq(w, x, xq, wa)
    h_half, g = me_stats(x, xq)
    r_me = gpfq_memory_efficient(w, h_half, g, wa)
    np.testing.assert_array_equal(np.asarray(r_std.q_int), np.asarray(r_me.q_int))


def test_theorem_b1_with_act_order():
    w, x, xq, _ = _layer(7, k=32, c=8, d=96)
    wa = weight_alphabet(4)
    r_std = gpfq(w, x, xq, wa, act_order=True)
    h_half, g = me_stats(x, xq)
    r_me = gpfq_memory_efficient(w, h_half, g, wa, act_order=True)
    np.testing.assert_array_equal(np.asarray(r_std.q_int), np.asarray(r_me.q_int))


def test_gpfq_beats_rtn():
    """Greedy error correction reduces reconstruction error vs direct RTN."""
    w, x, xq, _ = _layer(0, k=64, c=24, d=256)
    wa = weight_alphabet(4)
    r = gpfq(w, x, xq, wa)
    q_rtn, s_rtn = quantize_weights_rtn(w, wa)
    assert _recon_err(w, x, xq, r.w_q) < _recon_err(w, x, xq, q_rtn * s_rtn)


@given(
    seed=st.integers(0, 50),
    p_bits=st.integers(10, 16),
    tile=st.sampled_from([8, 16, None]),
)
@settings(max_examples=10)
def test_axe_budgets_respected(seed, p_bits, tile):
    """Committed per-tile signed sums never exceed the true Eq. 17 budget."""
    w, x, xq, _ = _layer(seed, k=32, c=8, d=96, scale=2.0)
    wa, na = weight_alphabet(4), act_alphabet(8)
    h_half, g = me_stats(x, xq)
    axe = AxeConfig(p_bits=p_bits, tile=tile)
    r = gpfq_memory_efficient(w, h_half, g, wa, na, axe=axe)
    cert = certify(r.q_int, na, p_bits, tile)
    assert bool(cert), (cert.worst_hi, cert.worst_lo)


def test_axe_functional_noop_when_loose():
    """With a 32-bit accumulator the constraints must be no-ops (paper §3.2)."""
    w, x, xq, _ = _layer(3, k=32, c=8, d=96)
    wa, na = weight_alphabet(4), act_alphabet(8)
    h_half, g = me_stats(x, xq)
    r_plain = gpfq_memory_efficient(w, h_half, g, wa)
    r_loose = gpfq_memory_efficient(
        w, h_half, g, wa, na, axe=AxeConfig(p_bits=32, tile=None)
    )
    np.testing.assert_array_equal(np.asarray(r_plain.q_int), np.asarray(r_loose.q_int))


def test_soft_constraint_reduces_l1():
    w, x, xq, _ = _layer(1, k=48, c=8, d=128, scale=2.0)
    wa, na = weight_alphabet(4), act_alphabet(8)
    h_half, g = me_stats(x, xq)
    r_hco = gpfq_memory_efficient(
        w, h_half, g, wa, na, axe=AxeConfig(p_bits=13, soft=False)
    )
    r_full = gpfq_memory_efficient(
        w, h_half, g, wa, na, axe=AxeConfig(p_bits=13, soft=True)
    )
    def l1(q):
        return float(jnp.sum(jnp.abs(q)))

    assert l1(r_full.q_int) <= l1(r_hco.q_int) * (1 + 1e-6)


def test_signed_activation_joint_budget():
    w, x, xq, _ = _layer(5, k=32, c=8, d=96, scale=2.0)
    wa, na = weight_alphabet(4), act_alphabet(8, signed=True)
    h_half, g = me_stats(x, xq)
    r = gpfq_memory_efficient(w, h_half, g, wa, na, axe=AxeConfig(p_bits=12, tile=8))
    cert = certify(r.q_int, na, 12, 8)
    assert bool(cert)
    bud = strict_budgets(12, na, 0.5)
    l1_tiles = np.abs(np.asarray(r.q_int)).reshape(4, 8, -1).sum(axis=1)
    assert np.all(l1_tiles <= bud.B + 0.5 + 1e-5)


def test_shape_validation():
    w = jnp.zeros((4, 2))
    x = jnp.zeros((5, 8))
    with pytest.raises(ValueError):
        gpfq(w, x, x, weight_alphabet(4))
