"""The bench regression gate must fail loudly when a perf metric exists
on only one side — dropped benches ("MISSING") and new uncommitted
sections ("NO BASELINE") both used to pass silently."""

import importlib.util
import os

_spec = importlib.util.spec_from_file_location(
    "bench_compare",
    os.path.join(os.path.dirname(__file__), "..", "scripts",
                 "bench_compare.py"))
bench_compare = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bench_compare)

compare_file = bench_compare.compare_file


def _statuses(base, cur, threshold=0.25, min_us=0.0):
    return {m: s for m, _, _, _, s in
            compare_file("BENCH_x.json", base, cur, threshold, min_us)}


def test_matching_metrics_ok():
    base = {"decode": {"us_per_tok": 100.0, "toks_s": 50.0}}
    cur = {"decode": {"us_per_tok": 101.0, "toks_s": 49.0}}
    assert set(_statuses(base, cur).values()) == {"ok"}


def test_regression_both_directions():
    base = {"decode": {"us_per_tok": 100.0, "toks_s": 50.0}}
    cur = {"decode": {"us_per_tok": 200.0, "toks_s": 10.0}}
    s = _statuses(base, cur)
    assert s["decode.us_per_tok"] == "REGRESSED"
    assert s["decode.toks_s"] == "REGRESSED"


def test_baseline_metric_gone_is_failure():
    base = {"decode": {"us_per_tok": 100.0}}
    s = _statuses(base, {"decode": {}})
    assert s["decode.us_per_tok"] == "MISSING"


def test_current_only_section_needs_a_baseline():
    """A freshly added section (the mesh_serving case) must commit its
    baseline in the same change, or the gate cannot gate it."""
    base = {"decode": {"us_per_tok": 100.0}}
    cur = {"decode": {"us_per_tok": 100.0},
           "mesh_serving": {"toks_s_sharded": 40.0, "note": "cfg echo"}}
    s = _statuses(base, cur)
    assert s["mesh_serving.toks_s_sharded"] == "NO BASELINE"
    # non-perf leaves (config echoes, notes) stay exempt on both sides
    assert "mesh_serving.note" not in s


def test_main_counts_one_sided_metrics_as_failures(tmp_path, capsys):
    b = tmp_path / "base"
    c = tmp_path / "cur"
    b.mkdir(), c.mkdir()
    (b / "BENCH_x.json").write_text('{"decode": {"us_per_tok": 100.0}}')
    (c / "BENCH_x.json").write_text('{"serving": {"toks_s": 10.0}}')
    rc = bench_compare.main(["--baseline", str(b), "--current", str(c)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "MISSING" in out and "NO BASELINE" in out
