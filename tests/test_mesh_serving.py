"""SPMD mesh paged serving: the sharded engine is *bit-identical* to the
1-device engine on every serving path.

These tests need more than one device, so CI runs them in the dedicated
``mesh`` job under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(the whole module skips otherwise, keeping the default ``tests`` job
fast). The model is tiny-lm-xs (n_kv_heads=4) so a tp=2 model axis
genuinely shards the KV pools — smollm's nkv=1 would silently replicate.

The true multi-process lane (``jax.distributed`` + gloo collectives)
lives in scripts/run_multiprocess.py; ``test_multiprocess_battery``
shells out to it so a local ``pytest -m mesh`` run covers both worlds.
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models.transformer import init_model
from repro.runtime import sharding as shardlib
from repro.serving import (
    PagedConfig,
    PagedEngine,
    Request,
    SamplerConfig,
    SchedulerPolicy,
)

pytestmark = pytest.mark.mesh

if len(jax.devices()) < 2:
    pytest.skip("mesh serving tests need >= 2 devices "
                "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
                allow_module_level=True)

GREEDY = SamplerConfig(temperature=0.0)
SAMPLED = SamplerConfig(temperature=0.8, seed=5)


def _mesh2d():
    n = len(jax.devices())
    return make_mesh((n // 2, 2))


@pytest.fixture(scope="module")
def cfg():
    # nkv=4: divisible by tp=2 so pools shard; 2 layers keep traces fast
    return get_config("tiny-lm-xs").scaled(n_layers=2, vocab=128)


@pytest.fixture(scope="module")
def params(cfg):
    return init_model(jax.random.key(0), cfg)


def _pc(**kw):
    pc = dict(block_size=8, num_blocks=16, max_concurrency=3,
              max_pages_per_seq=4, chunk_max=4, attn_impl="ref")
    pc.update(kw)
    return PagedConfig(**pc)


def _reqs(lens, seed=11, vocab=128):
    rng = np.random.default_rng(seed)
    return [Request(uid=u, prompt=rng.integers(0, vocab, size=s).astype(np.int32),
                    max_new=m, priority=p)
            for u, (s, m, p) in enumerate(lens)]


def _pair(params, cfg, pc, sampler=GREEDY, ref_pc=None):
    ref = PagedEngine(params, cfg, ref_pc or pc, sampler)
    eng = PagedEngine(params, cfg, pc, sampler, mesh=_mesh2d())
    return ref, eng


def _assert_identical(ref, eng, reqs, check_free=True):
    want = ref.serve([Request(r.uid, r.prompt.copy(), r.max_new, r.priority)
                      for r in reqs])
    got = eng.serve([Request(r.uid, r.prompt.copy(), r.max_new, r.priority)
                     for r in reqs])
    assert set(got) == set(want)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid])
    if check_free:
        for leaf in ("free_list", "page_refcounts", "free_top"):
            np.testing.assert_array_equal(
                np.asarray(shardlib.host_read(eng.cache[leaf])),
                np.asarray(jax.device_get(ref.cache[leaf])))
    return want, got


LENS = [(8, 8, 0), (8, 6, 1), (16, 8, 0), (8, 12, 1), (24, 4, 0)]


def test_cold_greedy_identity(params, cfg):
    ref, eng = _pair(params, cfg, _pc())
    _assert_identical(ref, eng, _reqs(LENS))


def test_shared_prefix_identity(params, cfg):
    """Prefix-cache hits (refcounted shared pages) under the mesh."""
    rng = np.random.default_rng(3)
    stem = rng.integers(0, 128, size=16).astype(np.int32)
    reqs = [Request(uid=u,
                    prompt=np.concatenate(
                        [stem, rng.integers(0, 128, size=4).astype(np.int32)]),
                    max_new=6)
            for u in range(4)]
    ref, eng = _pair(params, cfg, _pc(prefix_cache=True))
    _assert_identical(ref, eng, reqs)
    assert eng.prefix_cache.hits == ref.prefix_cache.hits
    assert eng.prefix_cache.hits > 0


def test_int8_kv_identity(params, cfg):
    ref, eng = _pair(params, cfg, _pc(kv_dtype="int8"))
    _assert_identical(ref, eng, _reqs(LENS))


def test_preempted_and_resumed_identity(params, cfg):
    """Watermark preemption on the sharded engine: the tight pool forces a
    preempt-and-requeue mid-decode, and the resumed stream still matches
    the roomy FIFO reference token for token."""
    reqs = _reqs([(8, 24, 0), (8, 24, 1), (8, 24, 1)])
    sched = SchedulerPolicy(admit_window=2, watermark=(1, 4))
    ref = PagedEngine(params, cfg, _pc(), GREEDY)  # roomy FIFO reference
    eng = PagedEngine(params, cfg, _pc(num_blocks=6, sched=sched), GREEDY,
                      mesh=_mesh2d())
    _assert_identical(ref, eng, reqs, check_free=False)
    assert eng.preemptions >= 1


def test_batched_admit_identity(params, cfg):
    """Throughput policy under the mesh: batched admission + chunked
    prefill trace and run as SPMD programs (per-host prompt rows)."""
    sched = SchedulerPolicy(admit_window=4, batch_max=3, prefill_chunk=16)
    lens = [(8, 6, 0), (8, 4, 1), (24, 6, 0), (8, 5, 0), (16, 4, 1), (8, 3, 0)]
    pc = _pc(num_blocks=24, max_concurrency=4, sched=sched)
    ref, eng = _pair(params, cfg, pc)
    _assert_identical(ref, eng, _reqs(lens, seed=7))
    assert eng.batch_traces >= 1 and eng.prefill_chunk_traces >= 1


def test_sampled_identity(params, cfg):
    """temperature > 0: per-request fold_in(uid, step) keys are
    collective-safe, so sampled streams match the 1-device engine too."""
    ref, eng = _pair(params, cfg, _pc(), sampler=SAMPLED)
    _assert_identical(ref, eng, _reqs(LENS))
    eng.assert_sampling_keys_collective_safe()


def test_out_shardings_contract(params, cfg):
    """The cache the engine actually serves from obeys the contract:
    pools shard kv_heads along the model axis, every admin leaf is
    fully replicated (that is what makes the one-device_get-per-chunk
    host read multihost-safe)."""
    eng = PagedEngine(params, cfg, _pc(kv_dtype="int8"), GREEDY,
                      mesh=_mesh2d())
    eng.serve(_reqs(LENS[:2]))
    kp = eng.cache["pools"][0]["k_pages"]
    assert kp.sharding.spec == P(None, None, None, "model", None)
    assert not kp.is_fully_replicated  # tp=2 divides nkv=4: real sharding
    assert kp.sharding.spec[3] == eng.cache["pools"][0]["k_scales"].sharding.spec[2]
    for name in shardlib._PAGED_ADMIN_LEAVES:
        leaf = eng.cache[name]
        assert leaf.is_fully_replicated, name
        assert leaf.sharding.spec == P(), name


@pytest.mark.slow
def test_multiprocess_battery():
    """True multi-controller lane: 2 OS processes x 2 devices rendezvous
    through jax.distributed + gloo and must produce byte-identical
    streams, free state, and cross-process digests."""
    script = os.path.join(os.path.dirname(__file__), "..", "scripts",
                          "run_multiprocess.py")
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(script), "..", "src"),
         env.get("PYTHONPATH", "")])
    res = subprocess.run(
        [sys.executable, script, "--procs", "2", "--devices-per-proc", "2",
         "--port", "29613"],
        capture_output=True, text=True, timeout=900, env=env)
    assert res.returncode == 0, res.stdout + res.stderr
